"""Unit + property tests for the bucket-based result buffer (paper Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buffer as rb


def _dists(rng, n, d=64, concentrated=True):
    """Distance-concentrated synthetic distances (high-d Gaussian pairs)."""
    if concentrated:
        q = rng.standard_normal(d).astype(np.float32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        return np.linalg.norm(x - q, axis=1)
    return rng.uniform(0.0, 10.0, n).astype(np.float32)


# ------------------------------ codebook ---------------------------------

def test_codebook_edges_monotone(rng):
    d = _dists(rng, 20000)
    cb = rb.build_codebook(jnp.asarray(d), k=5000, m=128)
    edges = np.asarray(cb.edges)
    assert np.all(np.diff(edges) > 0)
    assert edges[0] <= np.partition(d, 0)[0] + 1e-3


def test_codebook_equal_depth(rng):
    """Bucket occupancy over the top-k sample should be ~uniform (equal-depth)."""
    d = _dists(rng, 50000)
    k, m = 10000, 64
    cb = rb.build_codebook(jnp.asarray(d), k=k, m=m)
    topk = np.sort(d)[:k]
    b = np.asarray(rb.bucketize(cb, jnp.asarray(topk)))
    counts = np.bincount(b[b < m], minlength=m)
    # equal depth: each bucket ~k/m; allow generous skew from the 256-bin front end
    assert counts.max() < 6 * k / m
    assert (counts > 0).sum() > m // 2


def test_bucketize_matches_edges(rng):
    d = _dists(rng, 10000)
    cb = rb.build_codebook(jnp.asarray(d), k=2000, m=32)
    x = jnp.asarray(d[:1000])
    b = np.asarray(rb.bucketize(cb, x))
    edges = np.asarray(cb.edges)
    # Items labelled with bucket j < m must satisfy d < edges[j+1] roughly
    # (up to one 256-bin front-end quantum).
    quantum = float(cb.delta)
    for j in range(31):  # last bucket absorbs the 2% safety margin by design
        sel = b == j
        if sel.any():
            assert np.asarray(x)[sel].max() <= edges[j + 1] + quantum + 1e-5


def test_bucketize_overflow_lane(rng):
    d = _dists(rng, 5000)
    cb = rb.build_codebook(jnp.asarray(d), k=500, m=16)
    far = jnp.asarray([1e9], jnp.float32)
    assert int(rb.bucketize(cb, far)[0]) == 16  # overflow bucket m


# --------------------------- threshold bucket -----------------------------

def test_threshold_bucket_cumcount():
    hist = jnp.asarray([3, 2, 5, 1, 0, 9], jnp.int32)  # m=5 + overflow
    tau, n_before = rb.threshold_bucket(hist, k=8)
    assert int(tau) == 2 and int(n_before) == 5          # 3+2 < 8 <= 3+2+5
    tau, n_before = rb.threshold_bucket(hist, k=3)
    assert int(tau) == 0 and int(n_before) == 0
    tau, _ = rb.threshold_bucket(hist, k=100)            # fewer than k stored
    assert int(tau) == 5                                  # == m ("infinity")


def test_paper_figure3_example():
    """Figure 3: k=8; buckets sized [1,2,2,2,1,...] -> threshold bucket 5th (idx 4);
    inserting one more into bucket 4 (idx 3) shifts it to idx 3."""
    hist = jnp.asarray([1, 2, 2, 2, 1, 0], jnp.int32)
    tau, _ = rb.threshold_bucket(hist, k=8)
    assert int(tau) == 4
    hist = hist.at[3].add(1)  # push object 9 into bucket 4 (0-indexed 3)
    tau, _ = rb.threshold_bucket(hist, k=8)
    assert int(tau) == 3


# ------------------------------ collect -----------------------------------

@pytest.mark.parametrize("k", [100, 1000, 5000])
def test_collect_exact_topk_set(rng, k):
    n = 50000
    d = _dists(rng, n)
    ids = np.arange(n, dtype=np.int32)
    cb = rb.build_codebook(jnp.asarray(d), k=k, m=128)
    b = rb.bucketize(cb, jnp.asarray(d))
    got_d, got_i = rb.collect(cb, jnp.asarray(d), jnp.asarray(ids), b, k)
    oracle = np.sort(d)[:k]
    np.testing.assert_allclose(np.sort(np.asarray(got_d)), oracle, rtol=1e-6)
    # ids must be the argsort set (distances distinct w.h.p.)
    oracle_ids = set(np.argsort(d)[:k].tolist())
    assert set(np.asarray(got_i).tolist()) == oracle_ids


def test_collect_with_padding(rng):
    n, k = 20000, 1000
    d = _dists(rng, n)
    valid = np.ones(n, bool)
    valid[::7] = False
    dv = np.where(valid, d, 0.0).astype(np.float32)  # poison invalid lanes low
    cb = rb.build_codebook(jnp.asarray(d), k=k, m=64,
                           valid=jnp.asarray(valid))
    b = rb.bucketize(cb, jnp.where(jnp.asarray(valid), jnp.asarray(dv), jnp.inf))
    got_d, got_i = rb.collect(cb, jnp.asarray(dv), jnp.arange(n, dtype=jnp.int32),
                              b, k, valid=jnp.asarray(valid))
    oracle = np.sort(d[valid])[:k]
    np.testing.assert_allclose(np.sort(np.asarray(got_d)), oracle, rtol=1e-6)
    assert not set(np.asarray(got_i).tolist()) & set(np.where(~valid)[0].tolist())


def test_compact_mask_order_and_budget():
    mask = jnp.asarray([0, 1, 1, 0, 1, 0, 1, 1], bool)
    idx, ok = rb.compact_mask(mask, budget=3)
    assert np.asarray(idx).tolist() == [1, 2, 4]
    assert np.asarray(ok).all()
    idx, ok = rb.compact_mask(jnp.zeros(8, bool), budget=3)
    assert not np.asarray(ok).any()


# Property tests (hypothesis) live in test_buffer_properties.py, guarded by
# pytest.importorskip so this module stays runnable without hypothesis.
