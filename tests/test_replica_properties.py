"""Hypothesis property tests for the multi-replica serving tier (ISSUE 6).

Split from test_replica.py so the deterministic unit tests stay runnable
when ``hypothesis`` is not installed (optional dev dependency, same pattern
as test_buffer_properties.py).

Two properties over random traces x seeded fault schedules:

* **router determinism** — an identical trace plus an identical
  ``FaultSchedule`` seed replays to identical per-request outcomes AND
  identical replica assignments (the tier's whole decision log);
* **request conservation** — retries and hedges never duplicate or drop a
  request id: ``summarize()`` sees every offered rid exactly once, with
  completed + shed + failed == offered.

PR 10 adds the transport-tier analogue: random traces x seeded *wire*
fault schedules (frame drop / dup / slow / truncate / disconnect, plus a
worker kill) through the loopback transport sim, asserting the extended
conservation law completed + shed + failed + rejected == offered and
run-to-run digest determinism.
"""
import json

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import faults as flt                   # noqa: E402
from repro.serving import server as sv                    # noqa: E402
from repro.serving.router import outcome_digest           # noqa: E402
from test_replica import make_server, make_trace, req     # noqa: E402


def _run(trace_seed, fault_seed, n_replicas, n_req, n_faults):
    trace = make_trace(n_req, seed=trace_seed)
    horizon = max(r.arrival for r in trace)
    faults = flt.FaultSchedule.seeded(
        np.random.default_rng(fault_seed), n_replicas, horizon,
        n_faults=n_faults)
    srv = make_server(n_replicas=n_replicas, faults=faults)
    outcomes = srv.run_trace(trace)
    return trace, srv, outcomes


@settings(max_examples=12, deadline=None)
@given(
    trace_seed=st.integers(0, 2**31 - 1),
    fault_seed=st.integers(0, 2**31 - 1),
    n_replicas=st.integers(2, 4),
    n_req=st.integers(6, 28),
    n_faults=st.integers(0, 4),
)
def test_property_router_determinism(trace_seed, fault_seed, n_replicas,
                                     n_req, n_faults):
    """Identical trace + identical fault seed => identical outcomes,
    assignments, and summaries — byte for byte."""
    t1, s1, o1 = _run(trace_seed, fault_seed, n_replicas, n_req, n_faults)
    t2, s2, o2 = _run(trace_seed, fault_seed, n_replicas, n_req, n_faults)
    assert outcome_digest(o1) == outcome_digest(o2)
    assert s1.assignments == s2.assignments
    assert s1.stats == s2.stats
    assert json.dumps(sv.summarize(o1), sort_keys=True) == \
        json.dumps(sv.summarize(o2), sort_keys=True)


@settings(max_examples=12, deadline=None)
@given(
    trace_seed=st.integers(0, 2**31 - 1),
    fault_seed=st.integers(0, 2**31 - 1),
    n_replicas=st.integers(2, 4),
    n_req=st.integers(6, 28),
    n_faults=st.integers(0, 5),
)
def test_property_retry_hedge_conserves_request_ids(
        trace_seed, fault_seed, n_replicas, n_req, n_faults):
    """No duplicated or dropped rids, whatever the fault schedule throws:
    every offered request terminates exactly once and the summary's
    conservation invariant holds."""
    trace, srv, outcomes = _run(trace_seed, fault_seed, n_replicas, n_req,
                                n_faults)
    rids = [o.request.rid for o in outcomes]
    assert rids == sorted(r.rid for r in trace)      # once each, in order
    assert len(set(rids)) == len(trace)
    s = sv.summarize(outcomes)
    assert s["conserved"], s
    assert s["completed"] + s["shed"] + s["failed"] == len(trace)
    # results only on completions; absent (never wrong) otherwise
    for o in outcomes:
        if o.status in (sv.OK, sv.DEGRADED):
            assert o.ids is not None and len(o.ids) == o.k_effective
        else:
            assert o.ids is None and o.dists is None


# --------------------------------------------------------------------------
# transport tier (PR 10): conservation under wire faults
# --------------------------------------------------------------------------

from repro.serving.batcher import k_ceilings                # noqa: E402
from repro.serving.queue import make_zipf_trace             # noqa: E402
from repro.transport.core import MasterConfig, MasterCore   # noqa: E402
from repro.transport.sim import LoopbackSim                 # noqa: E402

_T_KS = (10, 100)


def _t_exec(q, k, n_probe):
    h = int(np.abs(np.asarray(q, dtype=np.float64)).sum() * 1e3) % 997
    return (np.arange(k, dtype=np.float32) * 0.01 + h % 7,
            np.arange(k, dtype=np.int64) + h)


def _t_run(trace_seed, wire_seed, n_workers, n_req, drop, dup, slow,
           truncate, disconnect, kill):
    rng = np.random.default_rng(trace_seed)
    centroids = rng.standard_normal((16, 8)).astype(np.float32)
    pool = rng.standard_normal((24, 8)).astype(np.float32)
    trace = make_zipf_trace(rng, pool, n_req, _T_KS, rate=400.0,
                            deadline=0.5, n_probe=4)
    wire = flt.WireSchedule(seed=wire_seed, drop=drop, dup=dup, slow=slow,
                            truncate=truncate, disconnect=disconnect)
    core = MasterCore(MasterConfig(n_workers=n_workers,
                                   ceilings=k_ceilings(_T_KS)), centroids)
    sim = LoopbackSim(core, _t_exec, lambda b: 0.001 + b.k * 1e-6,
                      wire=wire,
                      kill_at={0: 0.05} if kill else None)
    return trace, core, sim.run(trace)


@settings(max_examples=12, deadline=None)
@given(
    trace_seed=st.integers(0, 2**31 - 1),
    wire_seed=st.integers(0, 2**31 - 1),
    n_workers=st.integers(1, 4),
    n_req=st.integers(8, 60),
    drop=st.floats(0.0, 0.1),
    dup=st.floats(0.0, 0.05),
    slow=st.floats(0.0, 0.2),
    truncate=st.floats(0.0, 0.03),
    disconnect=st.floats(0.0, 0.03),
    kill=st.booleans(),
)
def test_property_transport_conserves_under_wire_faults(
        trace_seed, wire_seed, n_workers, n_req, drop, dup, slow,
        truncate, disconnect, kill):
    """Whatever the wire does — dropped frames, duplicate delivery, seeded
    latency jitter, truncation-induced disconnects, a worker kill — every
    offered request terminates exactly once:
    completed + shed + failed + rejected == offered."""
    trace, core, outcomes = _t_run(
        trace_seed, wire_seed, n_workers, n_req, drop, dup, slow,
        truncate, disconnect, kill)
    rids = [o.request.rid for o in outcomes]
    assert len(rids) == len(set(rids)) == len(trace)
    s = sv.summarize(outcomes)
    assert s["conserved"], s
    assert s["completed"] + s["shed"] + s["failed"] + s["rejected"] \
        == len(trace)
    # duplicate deliveries never double-reply or double-count
    assert core.stats["offered"] == len(trace)
    for o in outcomes:
        if o.status in (sv.OK, sv.DEGRADED):
            d, i = _t_exec(o.request.q, o.request.k, o.request.n_probe)
            np.testing.assert_array_equal(o.ids, i)
        else:
            assert o.ids is None and o.dists is None


@settings(max_examples=8, deadline=None)
@given(
    trace_seed=st.integers(0, 2**31 - 1),
    wire_seed=st.integers(0, 2**31 - 1),
    n_req=st.integers(8, 40),
)
def test_property_transport_faulted_run_is_deterministic(
        trace_seed, wire_seed, n_req):
    """Same trace + same wire seed => byte-identical outcome digest and
    identical decision log, faults and all."""
    a = _t_run(trace_seed, wire_seed, 3, n_req, 0.05, 0.02, 0.1, 0.02,
               0.02, True)
    b = _t_run(trace_seed, wire_seed, 3, n_req, 0.05, 0.02, 0.1, 0.02,
               0.02, True)
    assert outcome_digest(a[2]) == outcome_digest(b[2])
    assert a[1].assignments == b[1].assignments
    assert a[1].stats == b[1].stats
