"""Hypothesis property tests for the multi-replica serving tier (ISSUE 6).

Split from test_replica.py so the deterministic unit tests stay runnable
when ``hypothesis`` is not installed (optional dev dependency, same pattern
as test_buffer_properties.py).

Two properties over random traces x seeded fault schedules:

* **router determinism** — an identical trace plus an identical
  ``FaultSchedule`` seed replays to identical per-request outcomes AND
  identical replica assignments (the tier's whole decision log);
* **request conservation** — retries and hedges never duplicate or drop a
  request id: ``summarize()`` sees every offered rid exactly once, with
  completed + shed + failed == offered.
"""
import json

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import faults as flt                   # noqa: E402
from repro.serving import server as sv                    # noqa: E402
from repro.serving.router import outcome_digest           # noqa: E402
from test_replica import make_server, make_trace, req     # noqa: E402


def _run(trace_seed, fault_seed, n_replicas, n_req, n_faults):
    trace = make_trace(n_req, seed=trace_seed)
    horizon = max(r.arrival for r in trace)
    faults = flt.FaultSchedule.seeded(
        np.random.default_rng(fault_seed), n_replicas, horizon,
        n_faults=n_faults)
    srv = make_server(n_replicas=n_replicas, faults=faults)
    outcomes = srv.run_trace(trace)
    return trace, srv, outcomes


@settings(max_examples=12, deadline=None)
@given(
    trace_seed=st.integers(0, 2**31 - 1),
    fault_seed=st.integers(0, 2**31 - 1),
    n_replicas=st.integers(2, 4),
    n_req=st.integers(6, 28),
    n_faults=st.integers(0, 4),
)
def test_property_router_determinism(trace_seed, fault_seed, n_replicas,
                                     n_req, n_faults):
    """Identical trace + identical fault seed => identical outcomes,
    assignments, and summaries — byte for byte."""
    t1, s1, o1 = _run(trace_seed, fault_seed, n_replicas, n_req, n_faults)
    t2, s2, o2 = _run(trace_seed, fault_seed, n_replicas, n_req, n_faults)
    assert outcome_digest(o1) == outcome_digest(o2)
    assert s1.assignments == s2.assignments
    assert s1.stats == s2.stats
    assert json.dumps(sv.summarize(o1), sort_keys=True) == \
        json.dumps(sv.summarize(o2), sort_keys=True)


@settings(max_examples=12, deadline=None)
@given(
    trace_seed=st.integers(0, 2**31 - 1),
    fault_seed=st.integers(0, 2**31 - 1),
    n_replicas=st.integers(2, 4),
    n_req=st.integers(6, 28),
    n_faults=st.integers(0, 5),
)
def test_property_retry_hedge_conserves_request_ids(
        trace_seed, fault_seed, n_replicas, n_req, n_faults):
    """No duplicated or dropped rids, whatever the fault schedule throws:
    every offered request terminates exactly once and the summary's
    conservation invariant holds."""
    trace, srv, outcomes = _run(trace_seed, fault_seed, n_replicas, n_req,
                                n_faults)
    rids = [o.request.rid for o in outcomes]
    assert rids == sorted(r.rid for r in trace)      # once each, in order
    assert len(set(rids)) == len(trace)
    s = sv.summarize(outcomes)
    assert s["conserved"], s
    assert s["completed"] + s["shed"] + s["failed"] == len(trace)
    # results only on completions; absent (never wrong) otherwise
    for o in outcomes:
        if o.status in (sv.OK, sv.DEGRADED):
            assert o.ids is not None and len(o.ids) == o.k_effective
        else:
            assert o.ids is None and o.dists is None
