"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs.  Also exercises the decode path
(one serve step against fresh caches) for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as model_mod
from repro.optim import adamw

ARCHS = configs.ARCHS


def _batch_for(cfg, rng, b=2, s=32):
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frames, cfg.d_model)), cfg.dtype)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
        batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
        return batch
    batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(rng, arch):
    cfg = configs.get(arch, smoke=True)
    model = model_mod.build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(cfg, rng)

    logits = jax.jit(model.forward)(params, batch)
    b, s = batch["tokens"].shape
    want_s = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, want_s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    opt_cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)
    train_step = jax.jit(model_mod.make_train_step(model, opt_cfg))
    opt_state = adamw.init(params)
    params2, opt_state2, metrics = train_step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))
    # and a second step still finite (optimizer state wiring)
    _, _, m2 = train_step(params2, opt_state2, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(rng, arch):
    cfg = configs.get(arch, smoke=True)
    model = model_mod.build(cfg)
    params = model.init(jax.random.key(0))
    b, max_seq = 2, 64
    caches = model.init_caches(b, max_seq)
    batch = {
        "token": jnp.asarray(rng.integers(0, cfg.vocab, (b,))),
        "pos": jnp.zeros((b,), jnp.int32),
    }
    if cfg.family == "encdec":
        frames = jnp.asarray(
            rng.standard_normal((b, cfg.n_frames, cfg.d_model)), cfg.dtype)
        from repro.models import encdec
        batch["enc_out"] = encdec.encode(params, cfg, frames)
    logits, new_caches = jax.jit(model.decode_step)(params, batch, caches)
    assert logits.shape == (b, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # cache pytree structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


def test_full_configs_match_assignment():
    """The exact published dims from the assignment block."""
    want = {
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab=50280, d_state=128),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv=8, d_ff=512, vocab=49155,
                                     n_experts=32, top_k=8),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48, n_kv=8,
                          d_ff=10752, vocab=100352, n_experts=16, top_k=4),
        "smollm-135m": dict(n_layers=30, d_model=576, n_heads=9, n_kv=3,
                            d_ff=1536, vocab=49152),
        "qwen1.5-32b": dict(n_layers=64, d_model=5120, n_heads=40, n_kv=40,
                            d_ff=27392, vocab=152064, qkv_bias=True),
        "deepseek-coder-33b": dict(n_layers=62, d_model=7168, n_heads=56,
                                   n_kv=8, d_ff=19200, vocab=32256),
        "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14, n_kv=2,
                           d_ff=4864, vocab=151936, qkv_bias=True),
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32, n_kv=32,
                            d_ff=8192, vocab=32000, d_state=64),
        "internvl2-2b": dict(n_layers=24, d_model=2048, n_heads=16, n_kv=8,
                             d_ff=8192, vocab=92553),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, n_kv=6,
                             d_ff=1536, vocab=51865),
    }
    for arch_id, dims in want.items():
        cfg = configs.get(arch_id)
        for k, v in dims.items():
            assert getattr(cfg, k) == v, (arch_id, k, getattr(cfg, k), v)
