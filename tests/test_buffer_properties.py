"""Hypothesis property tests for the result buffer (paper Alg. 1).

Split from test_buffer.py so the deterministic unit tests stay runnable when
``hypothesis`` is not installed (it is an optional dev dependency).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import buffer as rb  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(200, 3000),
    k_frac=st.floats(0.01, 0.5),
    m=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_collect_equals_oracle(n, k_frac, m, seed):
    """BBC collect returns the exact top-k *multiset of distances* for any
    distance distribution with distinct values."""
    rng = np.random.default_rng(seed)
    d = rng.standard_normal(n).astype(np.float32) * 3 + 10
    d += np.arange(n, dtype=np.float32) * 1e-4  # break ties deterministically
    k = max(1, int(n * k_frac))
    cb = rb.build_codebook(jnp.asarray(d), k=k, m=m)
    b = rb.bucketize(cb, jnp.asarray(d))
    got_d, _ = rb.collect(cb, jnp.asarray(d), jnp.arange(n, dtype=jnp.int32),
                          b, k, slack_buckets=8)
    np.testing.assert_allclose(
        np.sort(np.asarray(got_d)), np.sort(d)[:k], rtol=1e-5
    )


@settings(max_examples=25, deadline=None)
@given(
    counts=st.lists(st.integers(0, 50), min_size=2, max_size=64),
    k=st.integers(1, 500),
)
def test_property_threshold_bucket_invariant(counts, k):
    """tau is the minimal index whose cumulative count reaches k; n_before < k
    and n_before + hist[tau] >= k whenever total >= k."""
    hist = jnp.asarray(counts + [0], jnp.int32)
    tau, n_before = rb.threshold_bucket(hist, k)
    tau, n_before = int(tau), int(n_before)
    total = sum(counts)
    m = len(counts)
    if total < k:
        assert tau == m
    else:
        assert 0 <= tau < m
        assert n_before < k
        assert n_before + counts[tau] >= k
        assert sum(counts[:tau]) == n_before


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), budget=st.integers(1, 64))
def test_property_compact_mask(seed, budget):
    rng = np.random.default_rng(seed)
    mask = rng.random(200) < 0.3
    idx, ok = rb.compact_mask(jnp.asarray(mask), budget)
    want = np.where(mask)[0][:budget]
    got = np.asarray(idx)[np.asarray(ok)]
    np.testing.assert_array_equal(got, want)
