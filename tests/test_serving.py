"""Async micro-batching serving subsystem.

Scheduling logic (batcher firing rules, admission shed/degrade) is tested
against a FIXED service-time model and seeded traces so behavior is exactly
reproducible; the correctness contract — shape-bucket padding and batch
composition never change results — is tested against the real engine by
comparing every completed request's ids with a direct engine call at its
bucket (a singleton batch through ``search_batch``, the entry point serving
drives), trimmed to its k (the pattern ``benchmarks/bench_serve.py`` gates
on at scale).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rerank
from repro.data import synthetic
from repro.index import search
from repro.serving import admission as adm
from repro.serving import batcher as bt
from repro.serving import queue as rq
from repro.serving import server as sv
from repro.serving.state import ServingState

N, D = 4000, 32
CEILS = (64, 128)
BATCH = 4
N_PROBE = 8


def req(rid, k=50, arrival=0.0, deadline=10.0, n_probe=N_PROBE, d=D,
        seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return rq.Request(rid=rid, q=rng.standard_normal(d).astype(np.float32),
                      k=k, n_probe=n_probe, arrival=arrival,
                      deadline=deadline)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    x = jnp.asarray(synthetic.clustered(rng, N, D, n_centers=32))
    qs = synthetic.queries_from(rng, np.asarray(x), 48)
    return x, qs


@pytest.fixture(scope="module")
def pq_index(corpus):
    x, _ = corpus
    return search.build_pq_index(jax.random.key(0), x, 32, n_iter=3)


# ---------------------------- queue + traces --------------------------------

def test_queue_validates_and_drains():
    q = rq.RequestQueue()
    with pytest.raises(ValueError):
        q.push(req(0, k=0))
    with pytest.raises(ValueError):
        q.push(req(0, arrival=2.0, deadline=1.0))
    q.push(req(0, arrival=0.0))
    q.push(req(1, arrival=1.0, deadline=11.0))
    with pytest.raises(ValueError):           # arrivals must be ordered
        q.push(req(2, arrival=0.5, deadline=10.5))
    got = q.drain_arrived(0.5)
    assert [r.rid for r in got] == [0] and len(q) == 1


def test_traces_are_seeded_and_ordered():
    rng = np.random.default_rng(3)
    qs = rng.standard_normal((64, D)).astype(np.float32)
    for pattern in ("poisson", "bursty"):
        t1 = rq.make_trace(np.random.default_rng(7), qs, (50, 120),
                           rate=100.0, deadline=0.5, n_probe=N_PROBE,
                           pattern=pattern)
        t2 = rq.make_trace(np.random.default_rng(7), qs, (50, 120),
                           rate=100.0, deadline=0.5, n_probe=N_PROBE,
                           pattern=pattern)
        arr = np.array([r.arrival for r in t1])
        assert np.all(np.diff(arr) >= 0)
        assert [r.k for r in t1] == [r.k for r in t2]
        assert arr == pytest.approx([r.arrival for r in t2])
        assert {r.k for r in t1} <= {50, 120}
    # bursty arrivals really cluster: the max inter-arrival gap dwarfs the
    # within-burst spread
    bursty = rq.bursty_arrivals(np.random.default_rng(1), 64, 100.0, burst=8)
    gaps = np.diff(bursty)
    assert np.max(gaps) > 100 * np.min(gaps)
    # regression: at high rates a short Poisson epoch gap can undercut the
    # within-burst window — arrivals must stay monotone for EVERY seed, not
    # by seed luck (RequestQueue.push enforces ordering)
    for seed in range(25):
        t = rq.bursty_arrivals(np.random.default_rng(seed), 200, 300.0,
                               burst=8)
        assert np.all(np.diff(t) >= 0), seed
        rq.RequestQueue(rq.make_trace(
            np.random.default_rng(seed), np.zeros((16, 4), np.float32) + 1,
            (8,), rate=300.0, deadline=0.5, n_probe=2, pattern="bursty"))


# ---------------------------- shape buckets ---------------------------------

def test_bucket_of_picks_smallest_ceiling():
    assert bt.bucket_of(50, N_PROBE, CEILS, BATCH).k == 64
    assert bt.bucket_of(64, N_PROBE, CEILS, BATCH).k == 64
    assert bt.bucket_of(65, N_PROBE, CEILS, BATCH).k == 128
    with pytest.raises(KeyError):
        bt.bucket_of(200, N_PROBE, CEILS, BATCH)


def test_batcher_fires_on_fill():
    b = bt.MicroBatcher(CEILS, BATCH, service_est=lambda _: 0.01)
    for i in range(BATCH - 1):
        b.submit(req(i))
    assert b.fire_ready(0.0) == []            # not full, slack ample
    b.submit(req(BATCH - 1))
    fired = b.fire_ready(0.0)
    assert len(fired) == 1 and fired[0].n_real == BATCH
    assert fired[0].queries.shape == (BATCH, D)
    assert b.pending() == 0


def test_batcher_fires_on_deadline_slack():
    est = 0.5
    b = bt.MicroBatcher(CEILS, BATCH, service_est=lambda _: est)
    r = req(0, deadline=2.0)
    b.submit(r)
    assert b.fire_ready(0.0) == []            # slack 2.0 > est 0.5
    due = b.next_fire_time(0.0)
    assert due == pytest.approx(2.0 - est)
    assert b.fire_ready(due - 1e-6) == []
    fired = b.fire_ready(due)
    assert len(fired) == 1 and fired[0].n_real == 1
    # pad lanes cycle the real query
    assert np.array_equal(fired[0].queries[0], fired[0].queries[1])
    assert fired[0].queries.shape == (BATCH, D)


def test_batcher_max_wait_bounds_idle_latency():
    b = bt.MicroBatcher(CEILS, BATCH, service_est=lambda _: 0.01,
                        max_wait=0.1)
    b.submit(req(0, arrival=1.0, deadline=100.0))
    assert b.next_fire_time(1.0) == pytest.approx(1.1)
    assert b.fire_ready(1.05) == []
    assert len(b.fire_ready(1.1)) == 1


# ---------------------------- admission -------------------------------------

def _seeded_service(vals):
    s = adm.ServiceEMA()
    for (k, npb), sec in vals.items():
        s.observe(bt.ShapeBucket(k=k, batch=BATCH, n_probe=npb), sec)
    return s


def test_admission_accepts_when_feasible():
    svc = _seeded_service({(64, N_PROBE): 0.1, (128, N_PROBE): 0.2})
    ac = adm.AdmissionController(svc, CEILS, BATCH)
    d = ac.decide(req(0, k=50, deadline=1.0), 0.0, {})
    assert d.action == adm.ACCEPT and d.bucket.k == 64 and d.k == 50


def test_admission_degrades_k_to_meet_deadline():
    # the request's own bucket (k=128) cannot meet the deadline but the
    # smaller rung can: k is capped to that ceiling, flagged, not shed
    svc = _seeded_service({(64, N_PROBE): 0.05, (128, N_PROBE): 5.0})
    ac = adm.AdmissionController(svc, CEILS, BATCH)
    d = ac.decide(req(0, k=120, deadline=1.0), 0.0, {})
    assert d.action == adm.DEGRADE and d.bucket.k == 64 and d.k == 64
    # with degrading disabled the same request is shed
    ac2 = adm.AdmissionController(svc, CEILS, BATCH, allow_degrade=False)
    assert ac2.decide(req(0, k=120, deadline=1.0), 0.0, {}).action == adm.SHED


def test_admission_sheds_on_backlog():
    svc = _seeded_service({(64, N_PROBE): 0.4, (128, N_PROBE): 0.4})
    ac = adm.AdmissionController(svc, CEILS, BATCH)
    depths = {bt.ShapeBucket(k=64, batch=BATCH, n_probe=N_PROBE): 8 * BATCH}
    d = ac.decide(req(0, k=50, deadline=1.0), 0.0, depths)   # wait ~3.2s
    assert d.action == adm.SHED


def test_oversized_k_is_capped_at_top_rung():
    svc = _seeded_service({(64, N_PROBE): 0.01, (128, N_PROBE): 0.01})
    ac = adm.AdmissionController(svc, CEILS, BATCH)
    d = ac.decide(req(0, k=500, deadline=1.0), 0.0, {})
    assert d.action == adm.DEGRADE and d.k == 128


def test_admission_folds_in_flight_remainder():
    """A request whose deadline is feasible on an idle executor becomes
    infeasible when the in-flight batch's remaining EMA service time is
    folded in — the ROADMAP PR-4 backlog-model gap."""
    svc = _seeded_service({(64, N_PROBE): 0.4, (128, N_PROBE): 0.4})
    ac = adm.AdmissionController(svc, CEILS, BATCH, allow_degrade=False)
    r = req(0, k=50, deadline=1.0)
    assert ac.decide(r, 0.0, {}).action == adm.ACCEPT
    # 0.4s of wait still fits a 1.0s deadline; 0.7s of in-flight does not
    assert ac.decide(r, 0.0, {}, in_flight=0.4).action == adm.ACCEPT
    assert ac.decide(r, 0.0, {}, in_flight=0.7).action == adm.SHED
    # in-flight time stacks with the queued-batch backlog
    depths = {bt.ShapeBucket(k=64, batch=BATCH, n_probe=N_PROBE): BATCH}
    assert ac.decide(r, 0.0, depths, in_flight=0.3).action == adm.SHED
    # pure function: identical arguments replay the identical decision
    d1 = ac.decide(r, 0.0, depths, in_flight=0.3)
    d2 = ac.decide(r, 0.0, depths, in_flight=0.3)
    assert d1 == d2


def test_server_admits_mid_batch_arrivals_at_arrival_time(corpus, pq_index):
    """Requests arriving while a batch executes are decided at their
    arrival instant with the in-flight remainder: with an injected service
    model making the executor busy for 2s, a mid-batch arrival whose
    deadline falls inside that window is shed AT ITS ARRIVAL TIME (not
    judged after the batch completes), deterministically."""
    _, qs = corpus
    svc_time = 2.0
    reqs = [
        rq.Request(rid=0, q=np.asarray(qs[0]), k=50, n_probe=N_PROBE,
                   arrival=0.0, deadline=10.0),
        # arrives at t=0.5 while the first batch (fired at 0, 2s long)
        # occupies the executor; deadline 1.0 < 0 + est-remainder -> shed
        rq.Request(rid=1, q=np.asarray(qs[1]), k=50, n_probe=N_PROBE,
                   arrival=0.5, deadline=1.0),
        # same arrival, generous deadline -> accepted and served
        rq.Request(rid=2, q=np.asarray(qs[2]), k=50, n_probe=N_PROBE,
                   arrival=0.5, deadline=30.0),
    ]
    state = ServingState(pq_index, use_bbc=True)
    srv = sv.Server(state, CEILS, BATCH, allow_degrade=False,
                    service_time_fn=lambda b: svc_time,
                    service_cold=svc_time)
    outcomes = srv.run_trace(reqs, warmup=False)
    by_rid = {o.request.rid: o for o in outcomes}
    assert by_rid[0].status == sv.OK
    assert by_rid[1].status == sv.SHED
    # shed decision is stamped at the request's arrival, not batch end
    assert by_rid[1].t_done == pytest.approx(0.5)
    assert by_rid[2].status == sv.OK
    # deterministic replay
    srv2 = sv.Server(state, CEILS, BATCH, allow_degrade=False,
                     service_time_fn=lambda b: svc_time,
                     service_cold=svc_time)
    outcomes2 = srv2.run_trace(reqs, warmup=False)
    assert [(o.request.rid, o.status, o.t_done) for o in outcomes] == \
        [(o.request.rid, o.status, o.t_done) for o in outcomes2]


# ---------------------------- end-to-end serving ----------------------------

def test_padding_parity_mixed_k_vs_direct_engine(corpus, pq_index):
    """Shape-bucket padding, trimming, and batch composition must not change
    results: every completed request's ids equal a direct singleton-batch
    engine call at its bucket, trimmed to its k."""
    _, qs = corpus
    trace = rq.make_trace(np.random.default_rng(5), qs, (50, 120),
                          rate=500.0, deadline=30.0, n_probe=N_PROBE)
    state = ServingState(pq_index, use_bbc=True)
    srv = sv.Server(state, CEILS, BATCH,
                    service_time_fn=lambda b: 0.01)
    outcomes = srv.run_trace(trace)
    assert all(o.status == sv.OK for o in outcomes)
    for o in outcomes:
        assert len(o.ids) == o.k_effective == o.request.k
        direct = state.engine(o.bucket).search_batch(
            jnp.asarray(o.request.q)[None])
        _, want = sv.trim_topk(np.asarray(direct.dists)[0],
                               np.asarray(direct.ids)[0], o.k_effective)
        assert set(want.tolist()) == set(o.ids.tolist()), o.request.rid
        # trimming preserves the sorted-by-reported-distance order
        assert np.all(np.diff(o.dists) >= 0)


def test_overlapped_assembly_outcomes_identical(corpus, pq_index):
    """Double-buffered host batch assembly (``overlap=True``, the default)
    changes WHEN the next batch's padded array is built — inside the
    current batch's device window — never WHAT is served: with a fixed
    service-time model both modes produce identical outcome streams
    (status, batch composition, ids, timestamps)."""
    _, qs = corpus
    trace = rq.make_trace(np.random.default_rng(7), qs, (50, 120),
                          rate=800.0, deadline=30.0, n_probe=N_PROBE)
    runs = {}
    for overlap in (False, True):
        state = ServingState(pq_index, use_bbc=True)
        srv = sv.Server(state, CEILS, BATCH,
                        service_time_fn=lambda b: 0.01, overlap=overlap)
        runs[overlap] = srv.run_trace(trace)
    assert len(runs[False]) == len(runs[True])
    for a, b in zip(runs[False], runs[True]):
        assert a.request.rid == b.request.rid
        assert a.status == b.status
        assert a.bucket == b.bucket
        assert a.t_done == b.t_done
        assert (a.ids is None) == (b.ids is None)
        if a.ids is not None:
            np.testing.assert_array_equal(a.ids, b.ids)


@pytest.mark.parametrize("kind", ["ivf", "ivfrabitq"])
def test_parity_other_method_kinds(corpus, pq_index, kind):
    """The serving layer is method-agnostic: the same trim-vs-direct parity
    holds for plain IVF (exact in-scan) and RaBitQ (whose rows interleave
    bound-certified and re-ranked members — trim_topk sorts by reported
    distance so served and direct trims pick identical rows)."""
    x, qs = corpus
    if kind == "ivf":
        state = ServingState(pq_index.ivf, use_bbc=True, vectors=x)
    else:
        index = search.build_rabitq_index(jax.random.key(0), x, 32, n_iter=3)
        state = ServingState(index, use_bbc=True)
    trace = rq.make_trace(np.random.default_rng(5), qs[:16], (50, 120),
                          rate=500.0, deadline=30.0, n_probe=N_PROBE)
    srv = sv.Server(state, CEILS, BATCH, service_time_fn=lambda b: 0.01)
    for o in srv.run_trace(trace):
        direct = state.engine(o.bucket).search_batch(
            jnp.asarray(o.request.q)[None])
        _, want = sv.trim_topk(np.asarray(direct.dists)[0],
                               np.asarray(direct.ids)[0], o.k_effective)
        assert set(want.tolist()) == set(o.ids.tolist()), (kind,
                                                           o.request.rid)
        assert np.all(np.diff(o.dists) >= 0)


def test_shedding_is_deterministic_and_absent_not_incorrect(corpus,
                                                            pq_index):
    """Overload trace + fixed service model: the shed set replays exactly,
    sheds actually happen, and shed outcomes carry NO results while every
    completed one still matches the direct engine call."""
    _, qs = corpus

    def run_once():
        trace = rq.make_trace(np.random.default_rng(9), qs, (50, 120),
                              rate=300.0, deadline=0.08, n_probe=N_PROBE,
                              pattern="bursty")
        state = ServingState(pq_index, use_bbc=True)
        srv = sv.Server(state, CEILS, BATCH,
                        service_time_fn=lambda b: 0.05)
        return state, srv.run_trace(trace)

    state, o1 = run_once()
    _, o2 = run_once()
    shed1 = [o.request.rid for o in o1 if o.status == sv.SHED]
    shed2 = [o.request.rid for o in o2 if o.status == sv.SHED]
    assert shed1 == shed2
    assert 0 < len(shed1) < len(o1)
    for o in o1:
        if o.status == sv.SHED:
            assert o.ids is None and o.dists is None
            assert not o.deadline_met
    parity, n_checked = sv.parity_vs_direct(state, o1)
    assert parity == 1.0 and n_checked == len(o1) - len(shed1)
    # the vacuous case reports zero checked — callers must fail it
    assert sv.parity_vs_direct(
        state, [o for o in o1 if o.status == sv.SHED]) == (1.0, 0)


def test_predictor_state_per_bucket_converges(corpus, pq_index):
    """tau_pred serving under varying batch composition: each shape bucket
    owns an independent predictor that warms up and stabilizes on its own
    histogram stream."""
    _, qs = corpus
    state = ServingState(pq_index, use_bbc=True, tau_pred=True)
    buckets = [bt.bucket_of(k, N_PROBE, CEILS, BATCH) for k in (50, 120)]
    taus = {b: [] for b in buckets}
    for step in range(6):
        for b in buckets:
            rows = np.asarray(qs[(4 * step) % 32:(4 * step) % 32 + 4])
            reqs = [rq.Request(rid=step * 10 + j, q=rows[j], k=b.k,
                               n_probe=N_PROBE, arrival=0.0, deadline=1.0)
                    for j in range(len(rows))]
            state.run(bt.assemble(b, reqs))
            st = state.pred_state(b)
            taus[b].append(int(rerank.predict_tau(
                st, state.engine(b).pred_count)))
    states = state.pred_states()
    assert len(states) == 2
    for b in buckets:
        st = state.pred_state(b)
        assert float(st.weight) > 0.0
        # warm from the first batch on (never the cold -1 after step 0) and
        # converged to a band: the EMA absorbs per-batch jitter, so the last
        # three predictions sit within a ~10%-of-m spread
        assert all(t >= 0 for t in taus[b])
        assert max(taus[b][-3:]) - min(taus[b][-3:]) <= 12
    # the two buckets self-tune independently (different pred_count targets
    # over the same corpus -> different states)
    s64, s128 = (state.pred_state(b) for b in buckets)
    assert not np.allclose(np.asarray(s64.ema), np.asarray(s128.ema))


def test_engine_warmup_compiles_serving_shapes(pq_index):
    from repro.index import engine as engine_mod
    eng = engine_mod.SearchEngine.build(pq_index, k=64, n_probe=N_PROBE)
    assert eng.warmup(batch_sizes=(1, BATCH), predictive=True) is eng
    res = eng.search_batch(jnp.zeros((BATCH, eng.dim), jnp.float32))
    assert res.ids.shape == (BATCH, 64)
    with pytest.raises(ValueError):
        eng.warmup(batch_sizes=(0,))
