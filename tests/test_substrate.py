"""Substrate tests: checkpoint roundtrip/restart determinism, data pipeline
determinism + shard disjointness, fault-tolerant train loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, tree)
    got, step = mgr.restore(tree)
    assert step == 7
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_checkpoint_keep_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(1000.0)}
    mgr.save(1, tree, wait=False)
    mgr.wait()
    got, step = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.arange(1000.0))


def test_pipeline_determinism():
    p = TokenPipeline(vocab=100, global_batch=4, seq_len=16, seed=3)
    b1 = p.batch_at(5)
    b2 = p.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # next-token supervision
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_pipeline_host_sharding():
    ps = [TokenPipeline(100, 8, 16, seed=1, host_index=i, n_hosts=2)
          for i in range(2)]
    b0, b1 = ps[0].batch_at(0), ps[1].batch_at(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_iterator_resume():
    p = TokenPipeline(100, 4, 16, seed=0)
    it = p.iterate(start_step=10)
    step, batch = next(it)
    assert step == 10
    np.testing.assert_array_equal(batch["tokens"], p.batch_at(10)["tokens"])


def test_train_restart_determinism(tmp_path):
    """Run 30 steps straight vs 30 steps with an injected failure+restart at
    step 20 (checkpoint at 20): identical final loss."""
    from repro.launch import train as train_mod

    d1 = str(tmp_path / "a")
    out1 = train_mod.train(arch="smollm-135m", steps=30, ckpt_dir=d1,
                           smoke=True, batch=4, seq=32, ckpt_every=10)

    d2 = str(tmp_path / "b")
    out2 = train_mod.run_with_restarts(
        arch="smollm-135m", steps=30, ckpt_dir=d2, smoke=True, batch=4,
        seq=32, ckpt_every=10, fail_at=25)
    assert out2["start"] > 0  # actually resumed
    np.testing.assert_allclose(out1["final_loss"], out2["final_loss"],
                               rtol=1e-5)


def test_train_loss_decreases(tmp_path):
    from repro.launch import train as train_mod
    out = train_mod.train(arch="qwen2-0.5b", steps=25, ckpt_dir=str(tmp_path),
                          smoke=True, batch=4, seq=32, ckpt_every=100)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first
