"""Fault-tolerant multi-replica serving tier (ISSUE 6).

Scheduling, routing, and failure recovery are tested against a stub engine
state with a FIXED service-time model, so every scenario is exactly
reproducible (crash/stall/slow/corrupt faults, hedges, retries, brownout,
the degrade ladder, supervisor respawn).  The correctness contract — a
completed request's ids match a direct engine call at its bucket — is
tested once against the real engine, under a crash fault, exactly the way
``benchmarks/bench_failover.py`` gates it at scale.
"""
import copy
import json
import os

import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointManager,
                                      CorruptCheckpointError)
from repro.core import rerank
from repro.serving import admission as adm
from repro.serving import faults as flt
from repro.serving import health as hlt
from repro.serving import queue as rq
from repro.serving import server as sv
from repro.serving.batcher import ShapeBucket
from repro.serving.replica import ReplicaPool, ReplicaResponse
from repro.serving.router import (HedgePolicy, ReplicaServer, RetryPolicy,
                                  outcome_digest)

D = 8
SVC = 0.01      # fixed per-batch service model (seconds)


def req(rid, k=16, arrival=0.0, deadline=None, n_probe=4, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return rq.Request(rid=rid, q=rng.standard_normal(D).astype(np.float32),
                      k=k, n_probe=n_probe, arrival=arrival,
                      deadline=(arrival + 12 * SVC if deadline is None
                                else deadline))


class _Result:
    def __init__(self, dists, ids):
        self.dists, self.ids = dists, ids


class _StubState:
    """Engine-free ServingState: deterministic ids from each row's query,
    ascending distances — enough for the scheduler, router, and fault layer
    to run a full timeline without jit."""

    def __init__(self, n_centroids=16, m=8):
        rng = np.random.default_rng(0)
        self._cents = rng.standard_normal((n_centroids, D)) \
            .astype(np.float32)
        self.m = m
        self._pred = {}

    @property
    def centroids(self):
        return self._cents

    def fork(self, clone_engines=False):
        twin = copy.copy(self)
        twin._pred = {}
        return twin

    def warmup(self, buckets):
        return self

    def pred_states(self):
        return dict(self._pred)

    @staticmethod
    def ids_for(q, k):
        base = int(abs(float(np.sum(q))) * 1e4) % 100_000
        return base + np.arange(k, dtype=np.int64)

    def run(self, batch):
        k = batch.bucket.k
        ids = np.stack([self.ids_for(q, k) for q in batch.queries])
        dists = np.tile(np.arange(k, dtype=np.float32), (len(ids), 1))
        return _Result(dists, ids)


def make_server(n_replicas=3, faults=None, ladder=None, batch=4,
                ceilings=(16, 32), hedge=True, retry=None, **kw):
    kw.setdefault("hb_interval", 0.005)
    kw.setdefault("respawn_delay", 0.02)
    kw.setdefault("max_wait", 4 * SVC)
    return ReplicaServer(
        _StubState(), n_replicas, ceilings, batch,
        retry=retry or RetryPolicy(timeout_mult=2.0),
        hedge=HedgePolicy(enabled=hedge, slack_mult=6.0),
        ladder=ladder, faults=faults,
        service_time_fn=lambda bucket: SVC, **kw)


def make_trace(n, rate=200.0, seed=5, **kw):
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate, n))
    return [req(i, arrival=float(times[i]), **kw) for i in range(n)]


def conserved(outcomes, trace):
    assert len(outcomes) == len(trace)
    assert [o.request.rid for o in outcomes] == \
        sorted(r.rid for r in trace)
    s = sv.summarize(outcomes)
    assert s["conserved"], s
    return s


# ------------------------- request validation (satellite) -------------------

@pytest.mark.parametrize("kw", [
    dict(k=0), dict(k=-3), dict(n_probe=0), dict(n_probe=-1),
    dict(deadline=float("nan")), dict(deadline=float("inf")),
    dict(deadline=-0.5), dict(arrival=float("nan")),
])
def test_request_validates_at_construction(kw):
    with pytest.raises(ValueError):
        req(0, **kw)


def test_request_degraded_flags():
    r = req(0, k=32, n_probe=8)
    assert not r.degraded
    assert r.k_capped(64) is r and r.n_probe_capped(8) is r
    capped = r.k_capped(16).n_probe_capped(4)
    assert (capped.k, capped.n_probe) == (16, 4)
    assert (capped.k_requested, capped.n_probe_requested) == (32, 8)
    assert capped.degraded
    # double-capping keeps the ORIGINAL request values
    assert capped.k_capped(8).k_requested == 32


# ------------------------------ fault taxonomy ------------------------------

def test_fault_spec_parse_and_validation():
    sched = flt.FaultSchedule.parse(
        "crash@1:t=0.5; stall@2:t=1.0,dur=0.4;"
        "slow@0:t=0.2,dur=1.0,factor=4;corrupt@3:t=0.8,dur=0.3")
    assert [f.kind for f in sched.faults] == \
        ["slow", "crash", "corrupt", "stall"]       # sorted by time
    assert sched.crashed(1, now=0.6) and not sched.crashed(1, now=0.4)
    for bad in ("crash@1", "nap@1:t=0.5", "stall@1:t=1.0",
                "slow@0:t=0.2,dur=1.0,factor=0.5",
                "crash@1:t=0.5,bogus=2"):
        with pytest.raises(ValueError):
            flt.FaultSchedule.parse(bad)


def test_fault_seeded_is_deterministic():
    a = flt.FaultSchedule.seeded(np.random.default_rng(3), 4, 10.0, 6)
    b = flt.FaultSchedule.seeded(np.random.default_rng(3), 4, 10.0, 6)
    assert a.faults == b.faults and len(a) == 6


def test_perturb_semantics():
    sched = flt.FaultSchedule([
        flt.Fault(t=1.0, replica=0, kind=flt.SLOW, duration=1.0, factor=4.0),
        flt.Fault(t=5.0, replica=0, kind=flt.STALL, duration=0.5),
        flt.Fault(t=9.0, replica=0, kind=flt.CRASH),
    ])
    assert sched.perturb(0, 1.5, 0.1) == (0.4, True)     # slow: 4x
    assert sched.perturb(0, 3.0, 0.1) == (0.1, True)     # outside window
    dt, ok = sched.perturb(0, 4.8, 0.4)                  # stall overlaps
    assert ok and dt == pytest.approx(0.9)
    assert sched.perturb(0, 8.95, 0.2)[1] is False       # crash mid-service
    assert sched.perturb(1, 8.95, 0.2) == (0.2, True)    # other replica
    # a respawn consumes every fault at or before it
    assert sched.perturb(0, 8.95, 0.2, since=9.0) == (0.2, True)
    assert sched.crashed(0, 9.5, since=9.0) is False


def test_payload_checksum_catches_corruption():
    dists = np.arange(8, dtype=np.float32).reshape(2, 4)
    ids = np.arange(8, dtype=np.int64).reshape(2, 4)
    resp = ReplicaResponse(dists, ids, flt.payload_checksum(dists, ids))
    assert resp.verified()
    bad = ReplicaResponse(dists, flt.corrupt_payload(ids), resp.checksum)
    assert not bad.verified()
    assert not np.array_equal(bad.ids, ids)


# --------------------------------- health -----------------------------------

def test_health_transitions():
    hv = hlt.HealthView(2, hb_interval=0.1, miss_factor=3.0,
                        anomaly_factor=3.0)
    hv.start(0.0)
    assert hv.status(0, 0.2) == hlt.HEALTHY
    assert hv.status(0, 0.31) == hlt.DOWN                # missed 3 beats
    hv.beat(0, 0.5)
    assert hv.status(0, 0.6) == hlt.HEALTHY
    for _ in range(6):                                   # anomaly EMA -> 8x
        hv.observe(1, 8 * SVC, baseline=SVC)
    hv.beat(1, 0.5)
    assert hv.status(1, 0.55) == hlt.SUSPECT
    assert hv.healthy(0.55) == [0] and hv.alive(0.55) == [0, 1]
    hv.reset(1, 0.6)                                     # respawn: history gone
    assert hv.status(1, 0.65) == hlt.HEALTHY


# ----------------------- checkpoint checksums (satellite) -------------------

def _tree():
    return {"a": np.arange(6, dtype=np.float32),
            "b": np.ones((2, 3), np.float32)}


def test_checkpoint_roundtrip_verifies(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    mgr.verify(1)
    tree, step = mgr.restore(_tree())
    assert step == 1
    np.testing.assert_allclose(np.asarray(tree["a"]), _tree()["a"])


def _leaf_paths(tmp_path, step=1):
    d = os.path.join(str(tmp_path), f"step_{step:08d}")
    return d, sorted(p for p in os.listdir(d) if p.endswith(".npy"))


def test_checkpoint_detects_corrupt_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    d, leaves = _leaf_paths(tmp_path)
    with open(os.path.join(d, leaves[0]), "r+b") as f:
        f.seek(-1, os.SEEK_END)
        byte = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(CorruptCheckpointError):
        mgr.verify(1)
    with pytest.raises(CorruptCheckpointError):
        mgr.restore(_tree())


def test_checkpoint_detects_missing_leaf_and_bad_manifest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    d, leaves = _leaf_paths(tmp_path)
    os.remove(os.path.join(d, leaves[0]))
    with pytest.raises(CorruptCheckpointError):
        mgr.verify(1)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(CorruptCheckpointError):
        mgr.restore(_tree())


def test_checkpoint_legacy_manifest_passes(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    d, _ = _leaf_paths(tmp_path)
    mpath = os.path.join(d, "manifest.json")
    manifest = json.load(open(mpath))
    manifest.pop("checksum")
    for meta in manifest["leaves"].values():
        meta.pop("sha256")
    json.dump(manifest, open(mpath, "w"))
    mgr.verify(1)                       # nothing recorded: nothing to fail
    tree, _ = mgr.restore(_tree())
    np.testing.assert_allclose(np.asarray(tree["b"]), _tree()["b"])


def test_respawn_restores_pred_state_and_falls_back_cold(tmp_path):
    bucket = ShapeBucket(k=16, batch=4, n_probe=4)
    pool = ReplicaPool(_StubState(), 2, (16, 32), 4,
                       service_est=lambda b: SVC,
                       checkpoint_dir=str(tmp_path), checkpoint_every=1)
    state = rerank.predictor_init(8)
    state = state._replace(ema=state.ema + 3.5)
    pool[0].state._pred[bucket] = state
    pool[0].served_batches = 1
    assert pool.maybe_checkpoint(0)
    # intact checkpoint: the respawned replica resumes the warmed state
    rep = pool.respawn(0, now=1.0)
    assert rep.respawned_at == 1.0 and rep.batcher.pending() == 0
    got = rep.state._pred[bucket]
    np.testing.assert_allclose(np.asarray(got.ema), np.asarray(state.ema))
    # corrupt the leaf: the next respawn must come up cold, not garbled
    ckpt_root = os.path.join(str(tmp_path), "replica_0")
    step_dir = os.path.join(ckpt_root, sorted(os.listdir(ckpt_root))[-1])
    leaf = sorted(p for p in os.listdir(step_dir) if p.endswith(".npy"))[0]
    with open(os.path.join(step_dir, leaf), "r+b") as f:
        f.seek(-1, os.SEEK_END)
        byte = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([byte[0] ^ 0xFF]))
    rep = pool.respawn(0, now=2.0)
    assert rep.state._pred == {}


# --------------------------------- routing ----------------------------------

def test_router_affinity_prefers_warm_working_set():
    srv = make_server(n_replicas=3)
    srv.health.start(0.0)
    r0 = req(0)
    top = srv.router.top_centroids(r0.q)
    srv.pool[2].note_probed(top, 0.0)
    dec = srv.router.route(r0, 0.001)
    assert (dec.replica, dec.reason) == (2, "affinity")
    # cold working sets everywhere: deterministic least-loaded (lowest rid)
    dec = srv.router.route(req(1, seed=99), 0.001)
    assert dec.reason == "least-loaded" and dec.replica == 0


def test_router_brownout_when_nothing_healthy():
    srv = make_server(n_replicas=2, hb_interval=0.1)
    srv.health.start(0.0)
    for _ in range(6):                  # both replicas anomaly-flagged
        srv.health.observe(0, 8 * SVC, SVC)
        srv.health.observe(1, 8 * SVC, SVC)
    dec = srv.router.route(req(0), 0.05)
    assert dec.brownout and dec.reason == "brownout"
    # nothing alive at all: route declines
    srv2 = make_server(n_replicas=2, hb_interval=0.001)
    srv2.health.start(0.0)
    assert srv2.router.route(req(0), 10.0) is None


# ------------------------- end-to-end fault scenarios -----------------------

def test_fault_free_pool_serves_everything():
    srv = make_server(n_replicas=3)
    trace = make_trace(24)
    out = srv.run_trace(trace)
    s = conserved(out, trace)
    assert s["completed"] == 24 and s["failed"] == 0 and s["shed"] == 0
    for o in out:
        want = _StubState.ids_for(o.request.q, o.bucket.k)[: o.k_effective]
        got = np.sort(o.ids)
        np.testing.assert_array_equal(got, np.sort(want))


def test_crash_fault_recovers_without_losing_requests():
    trace = make_trace(32)
    horizon = max(r.arrival for r in trace)
    faults = flt.FaultSchedule(
        [flt.Fault(t=0.4 * horizon, replica=1, kind=flt.CRASH)])
    srv = make_server(n_replicas=3, faults=faults)
    out = srv.run_trace(trace)
    s = conserved(out, trace)
    assert s["completed"] == 32 and s["failed"] == 0
    assert s["retried"] + s["hedged"] > 0        # recovery actually happened
    assert srv.stats["respawns"] >= 1


def test_corrupt_fault_is_detected_and_retried():
    trace = make_trace(16, rate=400.0)
    horizon = max(r.arrival for r in trace)
    faults = flt.FaultSchedule([flt.Fault(
        t=0.0, replica=0, kind=flt.CORRUPT, duration=2 * horizon + 1.0)])
    srv = make_server(n_replicas=2, faults=faults, hedge=False)
    out = srv.run_trace(trace)
    s = conserved(out, trace)
    assert srv.stats["corrupt_detected"] > 0
    assert s["completed"] == 16 and s["failed"] == 0
    # every completion came from the clean replica with TRUE ids
    for o in out:
        assert o.replica == 1
        want = _StubState.ids_for(o.request.q, o.bucket.k)[: o.k_effective]
        np.testing.assert_array_equal(np.sort(o.ids), np.sort(want))


def test_all_replicas_dead_terminates_failed_not_hung():
    trace = make_trace(8, rate=400.0)
    faults = flt.FaultSchedule(
        [flt.Fault(t=0.0, replica=r, kind=flt.CRASH) for r in range(2)])
    srv = make_server(n_replicas=2, faults=faults, respawn_delay=999.0)
    out = srv.run_trace(trace)
    s = conserved(out, trace)
    assert s["failed"] == 8 and s["completed"] == 0
    assert all(o.ids is None for o in out)


def test_degrade_ladder_caps_under_overload():
    ladder = adm.DegradeLadder(((1.0, 16, None), (2.5, 16, 2)))
    srv = make_server(n_replicas=2, ladder=ladder, batch=4)
    trace = [req(i, k=32, arrival=i * 1e-6, deadline=0.5)
             for i in range(40)]
    out = srv.run_trace(trace)
    s = conserved(out, trace)
    degraded = [o for o in out if o.status == sv.DEGRADED]
    assert degraded, s
    assert all(o.request.k_requested == 32 and o.k_effective == 16
               for o in degraded if o.request.k_requested)
    narrowed = [o for o in degraded if o.request.n_probe_requested]
    assert all(o.request.n_probe == 2 for o in narrowed)


def test_stall_marks_suspect_and_brownout_still_serves():
    trace = make_trace(24, rate=300.0)
    horizon = max(r.arrival for r in trace)
    # both replicas slowed 8x for the whole run: anomaly EMAs cross the
    # 3x threshold, nothing is healthy, yet brownout keeps serving
    faults = flt.FaultSchedule([
        flt.Fault(t=0.0, replica=r, kind=flt.SLOW,
                  duration=horizon + 10.0, factor=8.0)
        for r in range(2)])
    srv = make_server(n_replicas=2, faults=faults, respawn_delay=999.0,
                      hb_interval=0.05)
    out = srv.run_trace(trace)
    s = conserved(out, trace)
    assert s["completed"] == 24
    assert srv.stats["brownouts"] > 0
    assert any(o.status == sv.DEGRADED for o in out)     # brownout flag


def test_hedge_fires_and_first_response_wins():
    trace = make_trace(12, rate=50.0)
    horizon = max(r.arrival for r in trace)
    # replica 0 stalls hard mid-run: requests stuck there are recovered by
    # hedges to replica 1 well before their timeouts
    faults = flt.FaultSchedule([flt.Fault(
        t=0.0, replica=0, kind=flt.STALL, duration=horizon + 5.0)])
    srv = make_server(n_replicas=2, faults=faults, respawn_delay=999.0,
                      hb_interval=0.2)    # liveness never flags: hedges only
    out = srv.run_trace(trace)
    s = conserved(out, trace)
    assert s["completed"] == 12 and s["failed"] == 0
    assert srv.stats["hedges_sent"] > 0 and srv.stats["hedges_won"] > 0
    assert all(o.replica == 1 for o in out if o.hedged)


# ------------------------------- determinism --------------------------------

def _digest_run(seed, n_replicas, n_req, fault_seed):
    trace = make_trace(n_req, seed=seed)
    horizon = max(r.arrival for r in trace)
    faults = flt.FaultSchedule.seeded(
        np.random.default_rng(fault_seed), n_replicas, horizon, n_faults=3)
    srv = make_server(n_replicas=n_replicas, faults=faults)
    out = srv.run_trace(trace)
    return out, srv, trace


def test_seeded_fault_run_replays_byte_identical():
    o1, s1, trace = _digest_run(5, 3, 24, 11)
    o2, s2, _ = _digest_run(5, 3, 24, 11)
    assert outcome_digest(o1) == outcome_digest(o2)
    assert s1.assignments == s2.assignments
    assert json.dumps(sv.summarize(o1), sort_keys=True) == \
        json.dumps(sv.summarize(o2), sort_keys=True)
    conserved(o1, trace)
