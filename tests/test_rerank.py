"""Tests for Algorithms 2-4 (minimal / greedy bounded / early re-rank)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rerank


def _bounded_instance(rng, n=5000, d=96, noise=0.15):
    """Exact distances + probabilistic bounds (RaBitQ-like: est +/- radius)."""
    q = rng.standard_normal(d).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    exact = np.linalg.norm(x - q, axis=1).astype(np.float32)
    err = rng.standard_normal(n).astype(np.float32) * noise
    est = exact + err
    radius = np.full(n, noise * 4.0, np.float32)  # ~4 sigma: bound holds w.h.p.
    lb, ub = est - radius, est + radius
    # clip the rare violations so bounds are valid (paper: 99% guarantee; the
    # correctness statements assume validity)
    lb = np.minimum(lb, exact)
    ub = np.maximum(ub, exact)
    return exact, lb, ub


def test_minimal_set_definition(rng):
    exact, lb, ub = _bounded_instance(rng)
    k = 500
    mask = np.asarray(rerank.minimal_rerank_set(
        jnp.asarray(lb), jnp.asarray(ub), jnp.asarray(exact), k))
    dist_k = np.sort(exact)[k - 1]
    np.testing.assert_array_equal(mask, (lb <= dist_k) & (dist_k <= ub))
    # the boundary object itself is always in the minimal set
    assert mask[np.argsort(exact)[k - 1]]


@pytest.mark.parametrize("k", [50, 500])
def test_minimal_rerank_correct_and_minimal(rng, k):
    exact, lb, ub = _bounded_instance(rng, n=2000)
    calls = []

    def exact_fn(i):
        calls.append(i)
        return float(exact[i])

    ids, ds, n_rr = rerank.minimal_rerank(lb, ub, k, exact_fn)
    oracle_ids = np.argsort(exact, kind="stable")[:k]
    np.testing.assert_allclose(np.sort(ds), np.sort(exact[oracle_ids]), rtol=1e-6)
    assert set(ids.tolist()) == set(oracle_ids.tolist())
    # near-minimality: within small factor of the theoretical minimal set
    dist_k = np.sort(exact)[k - 1]
    minimal = int(((lb <= dist_k) & (dist_k <= ub)).sum())
    assert n_rr <= max(4 * minimal, minimal + 32)


@pytest.mark.parametrize("k", [100, 1000])
def test_greedy_bounded_rerank_exact_set(rng, k):
    """With valid bounds the greedy re-rank returns the exact top-k ID set;
    re-ranked members carry exact distances, certain-in members carry their
    estimate (paper semantics: skipped objects keep quantized distances)."""
    exact, lb, ub = _bounded_instance(rng, n=8000)
    ids = np.arange(len(lb), dtype=np.int32)
    res = rerank.greedy_bounded_rerank(
        jnp.asarray(lb), jnp.asarray(ub), jnp.asarray(ids),
        k, jnp.asarray(exact), m=128)
    assert set(np.asarray(res.topk_ids).tolist()) == set(np.argsort(exact)[:k].tolist())
    # distances of re-ranked members are exact
    got_ids = np.asarray(res.topk_ids)
    got_d = np.asarray(res.topk_dists)
    rr = np.asarray(res.rerank_mask)
    sel = rr[got_ids]
    np.testing.assert_allclose(got_d[sel], exact[got_ids][sel], rtol=1e-6)
    # certain-in members are genuinely within the exact top-k
    ci = np.asarray(res.certain_in)
    dist_k = np.sort(exact)[k - 1]
    assert (exact[ci] <= dist_k + 1e-6).all()


def test_greedy_reranks_fewer_than_threshold_only(rng):
    """Paper Exp-5: greedy re-ranks ~half of the baseline criterion's set.
    Bound width 4*0.03 ~ RaBitQ-realistic (small vs the distance spread)."""
    exact, lb, ub = _bounded_instance(rng, n=20000, noise=0.03)
    k = 2000
    base = int(np.asarray(rerank.threshold_only_rerank_mask(
        jnp.asarray(lb), jnp.asarray(ub), k)).sum())
    res = rerank.greedy_bounded_rerank(
        jnp.asarray(lb), jnp.asarray(ub), jnp.arange(len(lb), dtype=jnp.int32),
        k, jnp.asarray(exact), m=128)
    greedy = int(res.n_reranked)
    dist_k = np.sort(exact)[k - 1]
    minimal = int(((lb <= dist_k) & (dist_k <= ub)).sum())
    assert minimal <= greedy <= base
    assert greedy < 0.9 * base  # meaningful reduction


def test_early_rerank_plan(rng):
    """Alg. 4: tau_pred predicts the n_cand-th distance bucket; the predicted
    survivor mask must cover (almost all of) the true candidate set."""
    q = rng.standard_normal(64).astype(np.float32)
    x = rng.standard_normal((30000, 64)).astype(np.float32)
    est = np.linalg.norm(x - q, axis=1).astype(np.float32)
    n_cand, n_sample = 3000, 5000
    plan = rerank.early_rerank_plan(
        jnp.asarray(est[:n_sample]), n_cand=n_cand, n_sample=n_sample,
        n_total=len(est), m=128)
    mask = np.asarray(rerank.early_rerank_mask(plan, jnp.asarray(est)))
    true_cand = np.zeros(len(est), bool)
    true_cand[np.argsort(est)[:n_cand]] = True
    # prediction needn't be exact, but must be correlated and not explosive
    recall = (mask & true_cand).sum() / n_cand
    assert recall > 0.5
    assert mask.sum() < 10 * n_cand
    # refreshing with the full scan tightens the prediction
    plan2 = rerank.update_tau_pred(plan, jnp.asarray(est), len(est), len(est), n_cand)
    mask2 = np.asarray(rerank.early_rerank_mask(plan2, jnp.asarray(est)))
    recall2 = (mask2 & true_cand).sum() / n_cand
    assert recall2 >= 0.9
