"""Tombstone lane-mask semantics (streaming-ingest deletes).

The contract under test: a ``live`` mask threaded into any searcher makes
dead lanes behave exactly like unprobed lanes — so every method's top-k on
a tombstoned corpus equals a post-filter oracle (exact distances with dead
rows forced to +inf, then top-k), deleted ids never surface, the ref and
Pallas-interpret backends agree lane for lane, and the bucket-histogram
machinery counts only live lanes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.index import engine, ivf as ivf_mod, search
from repro.kernels import ops

N, D, NQ = 6000, 32, 5
K, C = 150, 24


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    x = synthetic.clustered(rng, N, D, n_centers=48)
    qs = synthetic.queries_from(rng, x, NQ)
    return jnp.asarray(x), jnp.asarray(qs)


@pytest.fixture(scope="module")
def tombstones(corpus):
    """Corpus-row live mask deleting ~15% of rows INCLUDING each query's
    exact top-10 (so the oracle answer provably moves)."""
    x, qs = corpus
    rng = np.random.default_rng(3)
    live = np.ones(N, dtype=bool)
    live[rng.choice(N, size=N // 7, replace=False)] = False
    d = np.asarray(ops.l2_exact_batch(x, qs))
    for bi in range(NQ):
        live[np.argsort(d[bi])[:10]] = False
    return live


@pytest.fixture(scope="module")
def indexes(corpus):
    x, _ = corpus
    key = jax.random.key(0)
    return {
        "ivf": ivf_mod.build(key, x, C, n_iter=4),
        "ivfpq": search.build_pq_index(key, x, C, n_iter=4),
        "ivfrabitq": search.build_rabitq_index(key, x, C, n_iter=4),
    }


def oracle_topk(corpus, live, k):
    """Post-filter oracle: exact distances, dead rows -> +inf, top-k."""
    x, qs = corpus
    d = np.asarray(ops.l2_exact_batch(x, qs))
    d = np.where(live[None, :], d, np.inf)
    pos = np.argsort(d, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(d, pos, axis=1), pos


def _assert_matches_oracle(res, corpus, live, k, exact_dists=True):
    od, oids = oracle_topk(corpus, live, k)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    for bi in range(NQ):
        got, want = set(ids[bi].tolist()) - {-1}, set(oids[bi].tolist())
        assert got == want, (bi, sorted(got ^ want)[:10])
        assert not (got & set(np.flatnonzero(~live).tolist()))
        if exact_dists:
            np.testing.assert_allclose(np.sort(dists[bi]), np.sort(od[bi]),
                                       rtol=2e-4, atol=2e-4)


# -------------------- exact equivalence to the oracle -----------------------

@pytest.mark.parametrize("kind", ["ivf", "ivfpq", "ivfrabitq"])
@pytest.mark.parametrize("use_bbc", [False, True])
def test_engine_with_live_matches_post_filter_oracle(
        corpus, tombstones, indexes, kind, use_bbc):
    """Full-probe search with tombstones == post-filter oracle, for every
    method x collector.  (ivf is exact in-scan; pq re-ranks every live
    candidate at n_cand >= n_live; rabitq's second pass is
    bound-certified.)"""
    x, qs = corpus
    n_live = int(tombstones.sum())
    kw = dict(k=K, n_probe=C, use_bbc=use_bbc, m=64)
    if kind == "ivf":
        kw["vectors"] = x
    if kind == "ivfpq":
        kw["n_cand"] = n_live
    eng = engine.SearchEngine.build(indexes[kind], **kw)
    eng = eng.with_live(tombstones)
    # rabitq's BBC path keeps estimator distances for bound-certified
    # lanes (id-set exact, dists approximate); the other methods emit
    # exact distances
    _assert_matches_oracle(eng.search(qs), corpus, tombstones, K,
                           exact_dists=(kind != "ivfrabitq"))


def test_with_live_none_is_identity(corpus, indexes):
    """with_live(None) clears the mask; results equal the frozen engine."""
    x, qs = corpus
    eng = engine.SearchEngine.build(indexes["ivfpq"], k=K, n_probe=C,
                                    use_bbc=True, m=64)
    masked = eng.with_live(np.ones(N, dtype=bool))
    cleared = masked.with_live(None)
    assert cleared.live is None
    r0, r1 = eng.search(qs), cleared.search(qs)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))


def test_search_one_routes_through_live_mask(corpus, tombstones, indexes):
    """Single-query search honors tombstones (it must route through the
    batched path — the single-query searchers don't take a mask)."""
    x, qs = corpus
    eng = engine.SearchEngine.build(indexes["ivfrabitq"], k=K, n_probe=C,
                                    use_bbc=True, m=64)
    eng = eng.with_live(tombstones)
    res = eng.search(qs[0])
    dead = set(np.flatnonzero(~tombstones).tolist())
    assert not (set(np.asarray(res.ids).tolist()) & dead)


def test_flipping_tombstones_does_not_recompile(corpus, indexes):
    """live is traced, not static: two different masks share one trace."""
    x, qs = corpus
    index = indexes["ivfpq"]
    layout = ivf_mod.flat_layout(index.ivf)
    traces = []

    @jax.jit
    def run(qs, live):
        traces.append(1)
        return search.ivf_pq_search_batch(index, qs, layout, K, 8, 1024,
                                          use_bbc=True, m=64, live=live)

    rng = np.random.default_rng(0)
    for n_dead in (50, 500):
        live = np.ones(layout.n_flat, dtype=bool)
        live[rng.choice(layout.n_flat, n_dead, replace=False)] = False
        res = run(qs, jnp.asarray(live))
        jax.block_until_ready((res.dists, res.ids))
    assert len(traces) == 1


# -------------------- backend parity (ref vs pallas-interpret) --------------

@pytest.mark.parametrize("kind", ["ivf", "ivfpq", "ivfrabitq"])
def test_backend_parity_under_tombstones(corpus, tombstones, indexes, kind):
    """ref and Pallas-interpret backends return identical id sets under a
    live mask (property: masking commutes with the backend choice)."""
    x, qs = corpus
    kw = dict(k=K, n_probe=C, use_bbc=True, m=64)
    if kind == "ivf":
        kw["vectors"] = x
    if kind == "ivfpq":
        kw["n_cand"] = 2048
    results = {}
    for backend in ("ref", "pallas"):
        eng = engine.SearchEngine.build(indexes[kind], backend=backend, **kw)
        results[backend] = eng.with_live(tombstones).search(qs)
    a, b = results["ref"], results["pallas"]
    for bi in range(NQ):
        got = set(np.asarray(a.ids)[bi].tolist())
        want = set(np.asarray(b.ids)[bi].tolist())
        assert got == want, (bi, sorted(got ^ want)[:10])


# -------------------- masked-lane histogram counts --------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_bucket_hist_counts_only_live_lanes(corpus, tombstones, indexes,
                                            backend):
    """The (m+1)-bucket histogram over a tombstoned lane mask (a) sums to
    the live-lane count per query, (b) is invariant to the dead lanes'
    distance values, and (c) agrees across backends."""
    x, qs = corpus
    m, n_probe = 64, C
    index = indexes["ivf"]
    layout = ivf_mod.flat_layout(index)
    probed, lane_valid, _ = search._routing(index, layout, qs, n_probe)
    stream_live = tombstones[np.clip(np.asarray(layout.order), 0, N - 1)]
    stream_live &= np.asarray(layout.valid)
    lv = lane_valid & jnp.asarray(stream_live)[None, :]
    stream_vecs = x[layout.order]
    dists = ops.l2_exact_batch(stream_vecs, qs)
    dists = jnp.where(lv, dists, search.INF)
    cbs = search._sample_codebooks(layout, probed, dists, 4, index.cap, K, m)
    _, hist = ops.bucket_hist_batch(dists, lv, cbs.d_min, cbs.delta,
                                    cbs.ew_map, m, backend=backend)
    hist = np.asarray(hist)
    # (a) total mass == live lanes
    np.testing.assert_array_equal(hist.sum(axis=1),
                                  np.asarray(lv.sum(axis=1)))
    # (b) dead lanes' values don't matter: poison them and recompute
    poisoned = jnp.where(lv, dists, 0.0)
    _, hist2 = ops.bucket_hist_batch(poisoned, lv, cbs.d_min, cbs.delta,
                                     cbs.ew_map, m, backend=backend)
    np.testing.assert_array_equal(hist, np.asarray(hist2))
    # (c) cross-backend agreement
    other = "pallas" if backend == "ref" else "ref"
    _, hist3 = ops.bucket_hist_batch(dists, lv, cbs.d_min, cbs.delta,
                                     cbs.ew_map, m, backend=other)
    np.testing.assert_array_equal(hist, np.asarray(hist3))


# -------------------- searcher-level live masks (direct calls) --------------

def test_ivf_search_batch_live_equals_prefiltered_corpus(corpus, tombstones,
                                                         indexes):
    """Direct searcher call with live= returns the same ids as physically
    deleting the rows and searching the survivor corpus (full probe)."""
    x, qs = corpus
    index = indexes["ivf"]
    layout = ivf_mod.flat_layout(index)
    stream_live = tombstones[np.clip(np.asarray(layout.order), 0, N - 1)]
    stream_live &= np.asarray(layout.valid)
    res = search.ivf_search_batch(index, x, qs, layout, K, C,
                                  live=jnp.asarray(stream_live))
    od, oids = oracle_topk(corpus, tombstones, K)
    for bi in range(NQ):
        assert set(np.asarray(res.ids)[bi].tolist()) == \
            set(oids[bi].tolist())


def test_engine_generation_field(indexes, corpus):
    """Engine carries the build generation for swap bookkeeping."""
    x, _ = corpus
    eng = engine.SearchEngine.build(indexes["ivfpq"], k=K, n_probe=8,
                                    generation=3)
    assert eng.generation == 3
    assert dataclasses.replace(eng, generation=4).generation == 4
