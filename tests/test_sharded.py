"""Mesh-sharded search engine: layout partition correctness (host-side) and
sharded-vs-single-device parity for all three methods (subprocess with 8
forced host devices, marked ``multidevice``)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.index import ivf as ivf_mod


def _toy_index(rng, n=5000, d=16, n_clusters=24):
    from repro.data import synthetic
    x = jax.numpy.asarray(synthetic.clustered(rng, n, d))
    return ivf_mod.build(jax.random.key(0), x, n_clusters), n


def test_sharded_layout_reconstructs_flat_stream(rng):
    index, n = _toy_index(rng)
    flat = ivf_mod.flat_layout(index)
    for n_shards in (2, 8):
        sl, cap_shard = ivf_mod.sharded_layout(index, n_shards)
        assert sl.n_shards == n_shards
        order = np.asarray(sl.order)
        cluster_of = np.asarray(sl.cluster_of)
        offsets = np.asarray(sl.offsets)
        valid = np.asarray(sl.valid)
        # every corpus id appears exactly once across shards
        live = order[valid]
        assert live.shape[0] == n
        assert set(live.tolist()) == set(range(n))
        # per cluster, shard segments reconstruct the flat stream's members
        f_order = np.asarray(flat.order)
        f_off = np.asarray(flat.offsets)
        max_seg = 0
        for c in range(index.n_clusters):
            want = set(f_order[f_off[c]:f_off[c + 1]].tolist())
            got = set()
            for j in range(n_shards):
                seg = order[j, offsets[j, c]:offsets[j, c + 1]]
                assert np.all(cluster_of[j, offsets[j, c]:offsets[j, c + 1]]
                              == c)
                max_seg = max(max_seg, len(seg))
                got |= set(seg.tolist())
            assert got == want
        # segments are balanced (round-robin: sizes differ by at most 1)
        sizes = offsets[:, 1:] - offsets[:, :-1]       # (S, C)
        assert int((sizes.max(0) - sizes.min(0)).max()) <= 1
        assert cap_shard == max_seg
        # each shard's block is a coherent FlatLayout view
        loc = sl.local(0)
        assert loc.order.shape[0] == sl.shard_flat
        assert int(np.asarray(loc.offsets)[-1]) == int(valid[0].sum())


PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data import synthetic
    from repro.index import engine, ivf as ivf_mod, search

    rng = np.random.default_rng(0)
    n, d, C = 25000, 48, 64
    k, n_probe, B = 5000, 56, 32
    x = jnp.asarray(synthetic.clustered(rng, n, d, n_centers=96))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), B))
    key = jax.random.key(0)
    mesh = jax.make_mesh((8,), ("model",))

    def assert_parity(name, single_eng, sharded_eng):
        r1 = single_eng.search(qs)
        r2 = sharded_eng.search(qs)
        for b in range(B):
            s1 = set(np.asarray(r1.ids[b]).tolist()) - {-1}
            s2 = set(np.asarray(r2.ids[b]).tolist()) - {-1}
            assert len(s1) == k, (name, b, len(s1))
            assert s1 == s2, (name, b, len(s1 - s2), len(s2 - s1))
        print(name, "OK", flush=True)

    ivf_index = ivf_mod.build(key, x, C)
    assert_parity(
        "ivf",
        engine.SearchEngine.build(ivf_index, k=k, n_probe=n_probe, vectors=x),
        engine.SearchEngine.build(ivf_index, k=k, n_probe=n_probe, vectors=x,
                                  mesh=mesh))
    # naive distributed collector is exact for IVF (local top-k superset)
    assert_parity(
        "ivf_naive",
        engine.SearchEngine.build(ivf_index, k=k, n_probe=n_probe, vectors=x),
        engine.SearchEngine.build(ivf_index, k=k, n_probe=n_probe, vectors=x,
                                  mesh=mesh, use_bbc=False))

    pq_index = search.build_pq_index(key, x, C)
    assert_parity(
        "ivfpq",
        engine.SearchEngine.build(pq_index, k=k, n_probe=n_probe),
        engine.SearchEngine.build(pq_index, k=k, n_probe=n_probe, mesh=mesh))

    rq_index = search.build_rabitq_index(key, x, C)
    assert_parity(
        "ivfrabitq",
        engine.SearchEngine.build(rq_index, k=k, n_probe=n_probe),
        engine.SearchEngine.build(rq_index, k=k, n_probe=n_probe, mesh=mesh))

    # single-query entry point on the sharded engine
    eng = engine.SearchEngine.build(pq_index, k=k, n_probe=n_probe, mesh=mesh)
    r = eng.search(qs[0])
    assert r.ids.shape == (k,)
    print("SHARDED_PARITY_OK")
    """
)


HIER_PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data import synthetic
    from repro.index import engine, ivf as ivf_mod, search

    rng = np.random.default_rng(1)
    n, d, C = 12000, 32, 48
    k, n_probe, B = 1500, 40, 16
    x = jnp.asarray(synthetic.clustered(rng, n, d, n_centers=64))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), B))
    key = jax.random.key(0)
    # 2-D ("host", "model") mesh: 2 emulated hosts x 4 chips; the searchers
    # run the hierarchical collective schedule (intra-host, then inter-host)
    mesh2d = jax.make_mesh((2, 4), ("host", "model"))

    def assert_parity(name, single_eng, sharded_eng):
        r1 = single_eng.search(qs)
        r2 = sharded_eng.search(qs)
        for b in range(B):
            s1 = set(np.asarray(r1.ids[b]).tolist()) - {-1}
            s2 = set(np.asarray(r2.ids[b]).tolist()) - {-1}
            assert len(s1) == k, (name, b, len(s1))
            assert s1 == s2, (name, b, len(s1 - s2), len(s2 - s1))
        print(name, "OK", flush=True)

    ivf_index = ivf_mod.build(key, x, C)
    assert_parity(
        "ivf_2d",
        engine.SearchEngine.build(ivf_index, k=k, n_probe=n_probe, vectors=x),
        engine.SearchEngine.build(ivf_index, k=k, n_probe=n_probe, vectors=x,
                                  mesh=mesh2d))

    pq_index = search.build_pq_index(key, x, C)
    assert_parity(
        "ivfpq_2d",
        engine.SearchEngine.build(pq_index, k=k, n_probe=n_probe),
        engine.SearchEngine.build(pq_index, k=k, n_probe=n_probe,
                                  mesh=mesh2d))

    rq_index = search.build_rabitq_index(key, x, C)
    assert_parity(
        "ivfrabitq_2d",
        engine.SearchEngine.build(rq_index, k=k, n_probe=n_probe),
        engine.SearchEngine.build(rq_index, k=k, n_probe=n_probe,
                                  mesh=mesh2d))
    print("SHARDED_2D_PARITY_OK")
    """
)


@pytest.mark.multidevice
def test_sharded_engine_parity_2d_hierarchical_mesh():
    """On a 2-D ("host", "model") 2x4 mesh — the hierarchical psum /
    gather schedule — all three methods return top-k id sets identical to
    the single-device batched engine."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", HIER_PARITY_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "SHARDED_2D_PARITY_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])


@pytest.mark.multidevice
def test_sharded_engine_parity_all_methods():
    """Acceptance: on a forced 8-device host mesh, SearchEngine(mesh=...)
    returns top-k id sets identical to the single-device batched engine for
    ivf, ivfpq, and ivfrabitq at k=5000, B=32."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", PARITY_SCRIPT], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "SHARDED_PARITY_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])
