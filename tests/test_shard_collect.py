"""Fused shard-collect kernel (bucketize + histogram + speculative
compaction): Pallas (interpret=True) vs pure-jnp oracle, and the
three-tier speculative survivor selection in
``core.distributed.bbc_survivors_batch`` vs the unfused exact path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buffer as rb
from repro.core import distributed as dist
from repro.kernels import ops, ref


def _stream(rng, b, n, m, frac=0.7):
    d = (rng.standard_normal((b, n)).astype(np.float32)) ** 2 + 0.05
    valid = rng.random((b, n)) < frac
    d = np.where(valid, d, np.inf).astype(np.float32)
    dj, vj = jnp.asarray(d), jnp.asarray(valid)
    k_cb = max(8, min(n // 2, 512))
    cbs = jax.vmap(lambda s: rb.build_codebook(s, k=k_cb, m=m))(dj)
    return dj, vj, cbs


@pytest.mark.parametrize("b,n", [(8, 512), (4, 1024), (16, 256)])
@pytest.mark.parametrize("m", [32, 128])
def test_shard_collect_parity(rng, b, n, m):
    dj, vj, cbs = _stream(rng, b, n, m)
    budget = 48
    for tau_spec in (
        jnp.full((b,), -1, jnp.int32),                        # cold
        jnp.full((b,), m, jnp.int32),                         # everything
        jnp.asarray(rng.integers(-1, m + 1, b), jnp.int32),   # mixed
    ):
        want = ref.shard_collect_batch(dj, vj, cbs.d_min, cbs.delta,
                                       cbs.ew_map, m, tau_spec, budget)
        for backend in ("ref", "pallas"):
            got = ops.shard_collect_batch(dj, vj, cbs.d_min, cbs.delta,
                                          cbs.ew_map, m, tau_spec, budget,
                                          backend=backend)
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("b,n,budget", [(8, 512, 32), (3, 768, 96)])
def test_spec_compact_parity(rng, b, n, budget):
    m = 64
    dj, vj, cbs = _stream(rng, b, n, m)
    bucket = ref.bucket_hist_batch(dj, vj, cbs.d_min, cbs.delta,
                                   cbs.ew_map, m)[0]
    tau_spec = jnp.asarray(rng.integers(-1, m + 1, b), jnp.int32)
    want = ref.spec_compact_batch(bucket, vj, tau_spec, budget)
    for backend in ("ref", "pallas"):
        got = ops.spec_compact_batch(bucket, vj, tau_spec, budget,
                                     backend=backend)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_spec_compact_stream_order_and_overflow(rng):
    """The buffer holds the FIRST ``budget`` at-or-below-tau lanes in
    stream order; the count is the true total (the overflow signal)."""
    b, n, m, budget = 4, 512, 16, 16
    dj, vj, cbs = _stream(rng, b, n, m, frac=0.9)
    bucket = ref.bucket_hist_batch(dj, vj, cbs.d_min, cbs.delta,
                                   cbs.ew_map, m)[0]
    tau_spec = jnp.full((b,), m, jnp.int32)
    pos, ok, cnt = ops.spec_compact_batch(bucket, vj, tau_spec, budget,
                                          backend="pallas")
    bucket_np, v_np = np.asarray(bucket), np.asarray(vj)
    for q in range(b):
        match = np.nonzero(v_np[q])[0]
        assert int(cnt[q]) == len(match)
        take = min(len(match), budget)
        np.testing.assert_array_equal(np.asarray(pos[q][:take]),
                                      match[:take])
        assert bool(np.all(np.asarray(ok[q][:take])))
        assert not np.any(np.asarray(ok[q][take:]))


def _idsets(pos, ok, n):
    return [set(np.asarray(p)[np.asarray(o)].tolist())
            for p, o in zip(pos, ok)]


@pytest.mark.parametrize("count,budget", [(60, 96), (60, 24), (400, 64)])
def test_bbc_survivors_spec_tiers_match_unfused(rng, count, budget):
    """Speculative compaction never changes the survivor id SET: covered
    (warm tau_pred at/above tau), undershoot (bounded correction pass),
    overflow and cold (exact fallback) all reproduce the unfused path,
    including the degenerate count > n_probed regime (tau == m)."""
    b, n, m = 8, 512, 32
    dj, vj, cbs = _stream(rng, b, n, m)
    bucket, hist = ref.bucket_hist_batch(dj, vj, cbs.d_min, cbs.delta,
                                         cbs.ew_map, m)
    key = jnp.where(vj, dj, jnp.inf)

    def run(spec):
        return dist.bbc_survivors_batch(bucket, key, vj, hist, count,
                                        budget, axis_name=(), spec=spec)

    pos0, ok0, tau0, _, _ = run(None)
    want = _idsets(pos0, ok0, n)
    taus = {
        "warm_exact": tau0,
        "cold": jnp.full((b,), -1, jnp.int32),
        "overshoot": jnp.minimum(tau0 + 3, m),
        "undershoot": jnp.maximum(tau0 - 1, -1),
        "max": jnp.full((b,), m, jnp.int32),
    }
    for name, ts in taus.items():
        _, _, spos, sok, scnt = ref.shard_collect_batch(
            dj, vj, cbs.d_min, cbs.delta, cbs.ew_map, m, ts, budget)
        pos1, ok1, tau1, _, _ = run((spos, sok, scnt, ts))
        np.testing.assert_array_equal(np.asarray(tau0), np.asarray(tau1))
        assert _idsets(pos1, ok1, n) == want, name


def test_budget_exceeds_stream_clamps(rng):
    """satellite fix: budget > stream length F no longer crashes top_k —
    outputs keep the static (B, budget) shape, padded invalid."""
    b, n, m, budget = 4, 128, 16, 512
    dj, vj, cbs = _stream(rng, b, n, m)
    bucket, hist = ref.bucket_hist_batch(dj, vj, cbs.d_min, cbs.delta,
                                         cbs.ew_map, m)
    key = jnp.where(vj, dj, jnp.inf)
    pos, ok, tau, n_surv, _ = dist.bbc_survivors_batch(
        bucket, key, vj, hist, 64, budget, axis_name=())
    assert pos.shape == (b, budget) and ok.shape == (b, budget)
    assert int(jnp.sum(ok)) == int(jnp.sum(n_surv))


HIER_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import distributed as dist

    mesh = jax.make_mesh((2, 4), ("host", "model"))
    x = jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)

    def body(xs):
        s = dist.hier_psum(jnp.sum(xs, axis=0), ("host", "model"))
        (g,) = dist.gather_survivors(("host", "model"), xs)
        return s, g

    s, g = dist.shard_map(body, mesh,
                          in_specs=(P(("host", "model"), None),),
                          out_specs=(P(), P()))(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(x.sum(axis=0)))
    # hierarchical gather is a permutation of the flat concat; every row
    # of x appears exactly once
    got = np.asarray(g).reshape(-1, 6)
    want = np.asarray(x)
    got_rows = {tuple(r) for r in got.tolist()}
    assert got_rows == {tuple(r) for r in want.tolist()}
    print("HIER_COLLECTIVES_OK")
    """
)


@pytest.mark.multidevice
def test_hierarchical_collectives_on_2d_mesh():
    """hier_psum / gather_survivors over a ("host", "model") 2-D mesh
    reduce and gather exactly (subprocess with 8 forced host devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", HIER_SCRIPT], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert "HIER_COLLECTIVES_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])
