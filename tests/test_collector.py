"""Collector equivalence tests: every collector returns the exact top-k."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collector as col


def _stream(rng, n_tiles=20, tile=512, d=64):
    q = rng.standard_normal(d).astype(np.float32)
    x = rng.standard_normal((n_tiles * tile, d)).astype(np.float32)
    dists = np.linalg.norm(x - q, axis=1).reshape(n_tiles, tile)
    dists += rng.random(dists.shape).astype(np.float32) * 1e-5  # break ties
    ids = np.arange(n_tiles * tile, dtype=np.int32).reshape(n_tiles, tile)
    valid = np.ones((n_tiles, tile), bool)
    valid[-1, tile // 2:] = False  # padded tail tile
    return col.StreamInput(jnp.asarray(dists), jnp.asarray(ids), jnp.asarray(valid))


@pytest.mark.parametrize("name", ["bbc", "topk", "sorted", "lazy"])
@pytest.mark.parametrize("k", [128, 1024])
def test_collector_exact(rng, name, k):
    s = _stream(rng)
    d = np.asarray(s.dists).ravel()
    v = np.asarray(s.valid).ravel()
    oracle = np.sort(d[v])[:k]
    got_d, got_i = col.COLLECTORS[name](s, k)
    np.testing.assert_allclose(np.sort(np.asarray(got_d)), oracle, rtol=1e-6)
    # ids consistent with distances
    ids = np.asarray(got_i)
    assert len(set(ids.tolist())) == k
    full = np.asarray(s.dists).ravel()
    np.testing.assert_allclose(np.sort(full[ids]), oracle, rtol=1e-6)


@pytest.mark.parametrize("name", ["bbc", "bbc_streamed", "topk", "topk_flat"])
def test_streamed_and_flat_agree(rng, name):
    """Every variant of a collector returns the same exact top-k set."""
    s = _stream(rng, n_tiles=8)
    k = 300
    got_d, got_i = col.COLLECTORS[name](s, k)
    d = np.asarray(s.dists).ravel()
    v = np.asarray(s.valid).ravel()
    np.testing.assert_allclose(np.sort(np.asarray(got_d)),
                               np.sort(d[v])[:k], rtol=1e-6)


@pytest.mark.parametrize("k", [128, 1024])
@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_batch_collectors_exact(rng, k, backend):
    """Batched collectors return each query's exact top-k over the shared
    stream, honoring per-query validity masks."""
    b, n, d = 5, 6144, 32
    qs = rng.standard_normal((b, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    dists = np.linalg.norm(x[None] - qs[:, None], axis=-1).astype(np.float32)
    dists += rng.random(dists.shape).astype(np.float32) * 1e-5
    valid = rng.random((b, n)) < 0.8
    ids = np.arange(n, dtype=np.int32)
    bd, bi = col.bbc_collect_batch(jnp.asarray(dists), jnp.asarray(ids),
                                   jnp.asarray(valid), k, backend=backend)
    td, ti = col.topk_collect_batch(jnp.asarray(dists), jnp.asarray(ids),
                                    jnp.asarray(valid), k)
    for q in range(b):
        oracle = np.sort(dists[q][valid[q]])[:k]
        np.testing.assert_allclose(np.sort(np.asarray(bd[q])), oracle,
                                   rtol=1e-6)
        np.testing.assert_allclose(np.sort(np.asarray(td[q])), oracle,
                                   rtol=1e-6)
        assert not set(np.asarray(bi[q]).tolist()) & \
            set(np.where(~valid[q])[0].tolist())


def test_batch_collector_underfill(rng):
    """Fewer than k live lanes: (+inf, -1) padding, real lanes intact."""
    b, n, k = 3, 1024, 256
    dists = (rng.random((b, n)) * 5 + 1).astype(np.float32)
    valid = np.zeros((b, n), bool)
    valid[:, :100] = True
    ids = np.arange(n, dtype=np.int32)
    td, ti = col.topk_collect_batch(jnp.asarray(dists), jnp.asarray(ids),
                                    jnp.asarray(valid), k)
    ti = np.asarray(ti)
    td = np.asarray(td)
    assert (ti[:, 100:] == -1).all() and np.isinf(td[:, 100:]).all()
    for q in range(b):
        np.testing.assert_allclose(np.sort(td[q][:100]),
                                   np.sort(dists[q][:100]), rtol=1e-6)


def test_stats_scaling():
    """BBC cross-tile state is O(m), independent of k — the paper's point."""
    small = col.collector_stats("bbc", k=5_000, m=128, n=10**6, tile=512)
    big = col.collector_stats("bbc", k=100_000, m=128, n=10**6, tile=512)
    assert small["cross_tile_state_bytes"] == big["cross_tile_state_bytes"]
    heap_small = col.collector_stats("topk", k=5_000, m=128, n=10**6, tile=512)
    heap_big = col.collector_stats("topk", k=100_000, m=128, n=10**6, tile=512)
    assert heap_big["cross_tile_state_bytes"] == 20 * heap_small["cross_tile_state_bytes"]
