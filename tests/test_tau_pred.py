"""Predictive early-exact re-rank subsystem: EMA predictor unit tests plus
undershoot/overshoot exact-id parity with the static paths for all three
methods (single, batch, and a multidevice-marked sharded case).

Parity cases run with ``pred_count == n_cand`` where the predictive pool is
STRUCTURALLY equal to the static selection (survivors form an est-prefix and
the est-priority truncation width matches the static cut), so id equality
must hold for ANY tau_pred — the cases force the prediction to both extremes
to exercise the inline-early and fallback legs of the machinery.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buffer as rb
from repro.core import rerank
from repro.data import synthetic
from repro.index import engine, ivf as ivf_mod, search

N, D, NQ = 8000, 64, 6
K, N_PROBE = 200, 12
M_BUCKETS = 128


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    x = synthetic.clustered(rng, N, D, n_centers=64)
    qs = synthetic.queries_from(rng, x, NQ)
    return jnp.asarray(x), jnp.asarray(qs)


@pytest.fixture(scope="module")
def pq_index(corpus):
    x, _ = corpus
    return search.build_pq_index(jax.random.key(0), x, 32, n_iter=4)


@pytest.fixture(scope="module")
def rq_index(corpus):
    x, _ = corpus
    return search.build_rabitq_index(jax.random.key(0), x, 32, n_iter=4)


def _overshoot_state(m: int, count: int) -> rerank.PredictorState:
    """Warm state whose cumulative EMA reaches ``count`` only at the last
    in-range bucket: predict_tau pins to m - 1 (maximal overshoot)."""
    ema = jnp.zeros((m + 1,), jnp.float32).at[m - 2].set(float(2 * count))
    return rerank.PredictorState(ema=ema, weight=jnp.float32(1.0))


def _undershoot_state(m: int) -> rerank.PredictorState:
    """Warm state with all EMA mass in bucket 0: predict_tau returns the
    smallest possible threshold (1 with the default margin)."""
    ema = jnp.zeros((m + 1,), jnp.float32).at[0].set(1e9)
    return rerank.PredictorState(ema=ema, weight=jnp.float32(1.0))


def _ids_equal(res_a, res_b):
    a, b = np.asarray(res_a.ids), np.asarray(res_b.ids)
    for i in range(a.shape[0]):
        sa, sb = set(a[i].tolist()), set(b[i].tolist())
        assert sa == sb, (i, len(sa - sb), len(sb - sa))


# ---------------------------- predictor unit --------------------------------

def test_predictor_cold_is_disabled():
    state = rerank.predictor_init(M_BUCKETS)
    assert float(state.weight) == 0.0
    assert int(rerank.predict_tau(state, 100)) == -1


def test_predictor_ema_converges_on_stationary_stream():
    """On a stationary histogram stream the bias-corrected EMA converges to
    the stream's histogram, so predict_tau lands on its threshold bucket
    (plus the safety margin)."""
    m = 64
    rng = np.random.default_rng(3)
    base = rng.integers(5, 20, m + 1).astype(np.int32)
    hist = jnp.asarray(np.stack([base] * 4))              # (B, m+1), B=4
    count = int(base[:m].cumsum()[m // 2])                # mid-range target
    want_tau, _ = rb.threshold_bucket(jnp.asarray(base), count)

    state = rerank.predictor_init(m)
    taus = []
    for _ in range(40):
        state = rerank.predictor_update(state, hist)
        taus.append(int(rerank.predict_tau(state, count, margin=0)))
    assert abs(float(state.weight) - 1.0) < 1e-3
    np.testing.assert_allclose(np.asarray(state.ema / state.weight),
                               base.astype(np.float32), rtol=1e-3)
    # converged: the last predictions all equal the stream's true threshold
    assert set(taus[-10:]) == {int(want_tau)}
    # margin shifts the prediction conservatively upward
    assert int(rerank.predict_tau(state, count, margin=2)) == int(want_tau) + 2


def test_predictor_update_accepts_single_and_batched_hists():
    m = 16
    state = rerank.predictor_init(m)
    s1 = rerank.predictor_update(state, jnp.ones((m + 1,), jnp.int32))
    s2 = rerank.predictor_update(state, jnp.ones((4, m + 1), jnp.int32))
    np.testing.assert_allclose(np.asarray(s1.ema), np.asarray(s2.ema))


def test_predicted_fallback_mask():
    bucket = jnp.arange(8)[None, :]                        # (1, 8)
    valid = jnp.ones((1, 8), bool)
    # undershoot: prediction at 2, truth at 5 -> fallback covers (2, 5]
    mask = rerank.predicted_fallback_mask(
        bucket, valid, jnp.int32(2), jnp.int32(5))
    np.testing.assert_array_equal(
        np.asarray(mask[0]), [False, False, False, True, True, True, False,
                              False])
    # overshoot: prediction at or past truth -> nothing left for the fallback
    mask = rerank.predicted_fallback_mask(
        bucket, valid, jnp.int32(5), jnp.int32(3))
    assert not bool(jnp.any(mask))


# ---------------------------- batch parity ----------------------------------

@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("case", ["cold", "undershoot", "overshoot"])
def test_pq_batch_predictive_parity(pq_index, corpus, case, fused):
    """PQ predictive path vs the static BBC path at pred_count == n_cand:
    exact id parity for cold (no history), forced-undershoot (everything
    through the fallback pass), and forced-overshoot (everything inline on
    the fused path) predictions."""
    _, qs = corpus
    lay = ivf_mod.flat_layout(pq_index.ivf)
    n_cand = 8 * K
    static = search.ivf_pq_search_batch(
        pq_index, qs, lay, k=K, n_probe=N_PROBE, n_cand=n_cand, use_bbc=True)
    state = {"cold": rerank.predictor_init(M_BUCKETS),
             "undershoot": _undershoot_state(M_BUCKETS),
             "overshoot": _overshoot_state(M_BUCKETS, n_cand)}[case]
    kwargs = dict(fused=True, backend="pallas") if fused else \
        dict(fused=False)
    pred, new_state = search.ivf_pq_search_batch(
        pq_index, qs, lay, k=K, n_probe=N_PROBE, n_cand=n_cand, use_bbc=True,
        pred_state=state, pred_count=n_cand, **kwargs)
    _ids_equal(static, pred)
    assert float(new_state.weight) > float(state.weight) or \
        float(state.weight) == 1.0
    if fused and case == "overshoot":
        # maximal prediction: the scan covered (almost) the whole selection
        # inline; only overflow-bucket stragglers reach the second pass
        assert int(jnp.sum(pred.n_second_pass)) \
            < int(jnp.sum(pred.n_reranked))
    if case in ("cold", "undershoot") and not fused:
        # nothing predicted inline: the fallback re-ranks the entire pool
        np.testing.assert_array_equal(np.asarray(pred.n_second_pass),
                                      np.asarray(pred.n_reranked))


def test_pq_predictive_shrinks_rerank_pool(pq_index, corpus):
    """With a warm self-trained predictor and the default pred_count the PQ
    pool drops well below the static n_cand cut."""
    _, qs = corpus
    lay = ivf_mod.flat_layout(pq_index.ivf)
    n_cand = 8 * K
    state = rerank.predictor_init(M_BUCKETS)
    for _ in range(3):
        res, state = search.ivf_pq_search_batch(
            pq_index, qs, lay, k=K, n_probe=N_PROBE, n_cand=n_cand,
            use_bbc=True, pred_state=state)
    assert float(state.weight) > 0.4
    assert int(jnp.max(res.n_reranked)) < n_cand


@pytest.mark.parametrize("case", ["cold", "undershoot", "overshoot"])
def test_ivf_batch_predictive_parity(pq_index, corpus, case):
    """IVF distances are exact in-scan, so predictive selection must equal
    the static result for ANY prediction."""
    x, qs = corpus
    ivf_index = pq_index.ivf
    lay = ivf_mod.flat_layout(ivf_index)
    static = search.ivf_search_batch(ivf_index, x, qs, lay, k=K,
                                     n_probe=N_PROBE, use_bbc=True)
    state = {"cold": rerank.predictor_init(M_BUCKETS),
             "undershoot": _undershoot_state(M_BUCKETS),
             "overshoot": _overshoot_state(M_BUCKETS, K)}[case]
    pred, _ = search.ivf_search_batch(ivf_index, x, qs, lay, k=K,
                                      n_probe=N_PROBE, use_bbc=True,
                                      pred_state=state)
    _ids_equal(static, pred)


@pytest.mark.parametrize("case", ["cold", "overshoot"])
def test_rabitq_batch_predictive_parity(rq_index, corpus, case):
    """RaBitQ's band is bound-determined: the predictive path must return
    bit-identical results while only the second-pass accounting moves."""
    _, qs = corpus
    lay = ivf_mod.flat_layout(rq_index.ivf)
    static = search.ivf_rabitq_search_batch(rq_index, qs, lay, k=K,
                                            n_probe=N_PROBE, use_bbc=True)
    state = {"cold": rerank.predictor_init(M_BUCKETS),
             "overshoot": _overshoot_state(M_BUCKETS, K)}[case]
    pred, _ = search.ivf_rabitq_search_batch(rq_index, qs, lay, k=K,
                                             n_probe=N_PROBE, use_bbc=True,
                                             pred_state=state)
    _ids_equal(static, pred)
    np.testing.assert_array_equal(np.asarray(static.n_reranked),
                                  np.asarray(pred.n_reranked))
    if case == "cold":
        # nothing predicted: the whole band is second-pass work
        np.testing.assert_array_equal(np.asarray(pred.n_second_pass),
                                      np.asarray(pred.n_reranked))
    else:
        # maximal prediction covers the whole band inline
        assert int(jnp.sum(pred.n_second_pass)) == 0


def test_rabitq_warm_predictor_reduces_second_pass(rq_index, corpus):
    _, qs = corpus
    lay = ivf_mod.flat_layout(rq_index.ivf)
    state = rerank.predictor_init(M_BUCKETS)
    cold, state = search.ivf_rabitq_search_batch(
        rq_index, qs, lay, k=K, n_probe=N_PROBE, use_bbc=True,
        pred_state=state)
    warm, state = search.ivf_rabitq_search_batch(
        rq_index, qs, lay, k=K, n_probe=N_PROBE, use_bbc=True,
        pred_state=state)
    assert int(jnp.sum(warm.n_second_pass)) < int(jnp.sum(cold.n_second_pass))


def test_predictive_requires_bbc(pq_index, corpus):
    _, qs = corpus
    lay = ivf_mod.flat_layout(pq_index.ivf)
    with pytest.raises(ValueError, match="use_bbc"):
        search.ivf_pq_search_batch(
            pq_index, qs, lay, k=K, n_probe=N_PROBE, n_cand=8 * K,
            use_bbc=False, pred_state=rerank.predictor_init(M_BUCKETS))


# ---------------------------- engine / single -------------------------------

def test_engine_threads_state_and_single_query(pq_index, corpus):
    _, qs = corpus
    eng = engine.SearchEngine.build(pq_index, k=64, n_probe=8,
                                    pred_count=8 * 64)
    state = eng.predictor_init()
    rb_, state = eng.search(qs[:3], pred_state=state)
    assert rb_.ids.shape == (3, 64)
    assert float(state.weight) > 0
    # the single-query predictive entry point serves a singleton batch
    r1, state2 = eng.search(qs[0], pred_state=state)
    assert r1.ids.shape == (64,)
    assert float(state2.weight) > float(state.weight)
    rbatch, _ = eng.search(qs[:1], pred_state=state)
    assert set(np.asarray(r1.ids).tolist()) \
        == set(np.asarray(rbatch.ids[0]).tolist())
    # predictive result matches the static batched engine result
    static = eng.search(qs[:3])
    _ids_equal(static, rb_)


# ---------------------------- sharded (multidevice) -------------------------

SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import rerank
    from repro.data import synthetic
    from repro.index import engine, ivf as ivf_mod, search

    rng = np.random.default_rng(0)
    n, d, C = 12000, 32, 48
    k, n_probe, B = 500, 24, 8
    x = jnp.asarray(synthetic.clustered(rng, n, d, n_centers=48))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), B))
    key = jax.random.key(0)
    mesh = jax.make_mesh((8,), ("model",))

    def ids_equal(ra, rb, name, min_overlap=1.0):
        for b in range(B):
            sa = set(np.asarray(ra.ids[b]).tolist()) - {-1}
            sb = set(np.asarray(rb.ids[b]).tolist()) - {-1}
            overlap = len(sa & sb) / max(len(sa), 1)
            assert overlap >= min_overlap, (name, b, len(sa - sb),
                                            len(sb - sa))
            if min_overlap >= 1.0:
                assert sa == sb, (name, b, len(sa - sb), len(sb - sa))
        print(name, "OK", flush=True)

    # --- PQ: sharded predictive vs batched predictive and vs static --------
    # high-accuracy PQ regime (M=d/2, 8-bit): on concentrated synthetic data
    # the default M=d/4 4-bit estimate ordering is too noisy for ~pred_count
    # pools to cover the true top-k (see bench_tau_pred.py's rationale)
    pq = search.build_pq_index(key, x, C, n_sub=d // 2, n_bits=8)
    n_cand = 8 * k
    e1 = engine.SearchEngine.build(pq, k=k, n_probe=n_probe)
    e2 = engine.SearchEngine.build(pq, k=k, n_probe=n_probe, mesh=mesh)
    s1, s2 = e1.predictor_init(), e2.predictor_init()
    for it in range(3):
        r1, s1 = e1.search(qs, pred_state=s1)
        r2, s2 = e2.search(qs, pred_state=s2)
        # codebook samples are gathered in layout order, so the two
        # deployments' bucket edges differ at float level; when survivors
        # undershoot the truncation width the pools may diverge by a few
        # edge candidates (same tolerance as the static rabitq parity test)
        ids_equal(r1, r2, f"ivfpq_pred_batch_vs_sharded_{it}",
                  min_overlap=0.99)

    # forced undershoot/overshoot at pred_count == n_cand: structural parity
    # of the sharded predictive result with the STATIC sharded result
    e2n = engine.SearchEngine.build(pq, k=k, n_probe=n_probe, mesh=mesh,
                                    pred_count=n_cand)
    static = e2n.search(qs)
    for name, st in (
        ("cold", e2n.predictor_init()),
        ("overshoot", rerank.PredictorState(
            ema=jnp.zeros((e2n.m + 1,), jnp.float32).at[e2n.m - 2].set(
                float(2 * n_cand)),
            weight=jnp.float32(1.0))),
    ):
        rp, _ = e2n.search(qs, pred_state=st)
        ids_equal(static, rp, f"ivfpq_pred_{name}_vs_static")

    # --- IVF: exact in-scan, predictive sharded == static sharded ----------
    ei = engine.SearchEngine.build(pq.ivf, k=k, n_probe=n_probe, vectors=x,
                                   mesh=mesh)
    ri_static = ei.search(qs)
    ri, _ = ei.search(qs, pred_state=ei.predictor_init())
    ids_equal(ri_static, ri, "ivf_pred_vs_static")

    # --- RaBitQ: predictive sharded == static sharded ----------------------
    rq = search.build_rabitq_index(key, x, C)
    er = engine.SearchEngine.build(rq, k=k, n_probe=n_probe, mesh=mesh)
    rr_static = er.search(qs)
    rr, sr = er.search(qs, pred_state=er.predictor_init())
    assert float(sr.weight) > 0
    ids_equal(rr_static, rr, "ivfrabitq_pred_vs_static")
    print("TAU_PRED_SHARDED_OK")
    """
)


@pytest.mark.multidevice
def test_sharded_predictive_parity():
    """On a forced 8-device host mesh the predictive sharded engines must
    match the predictive batched engine (same pool semantics) and, at
    pred_count == n_cand, the static sharded results for forced
    undershoot/overshoot predictions."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "TAU_PRED_SHARDED_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])
