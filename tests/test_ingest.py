"""Streaming ingest: segment lifecycle, merge + crash recovery, drift-tested
predictor carry, and generation-aware zero-shed rolling swaps.

The churned-corpus parity acceptance (delta segment dealt across an
8-device mesh, sharded results == single-device results) runs in a
subprocess with forced host devices, marked ``multidevice`` like
``test_sharded.py``.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CorruptCheckpointError
from repro.data import synthetic
from repro.index import search
from repro.ingest import (DeltaSegment, IngestConfig, MergeCrash, MergeJob,
                          MutableIndex, carry_state, resume_merge,
                          tv_distance)
from repro.kernels import ops
from repro.core import rerank

N, D, NQ, K = 3000, 24, 4, 100


@pytest.fixture()
def corpus():
    rng = np.random.default_rng(5)
    x = synthetic.clustered(rng, N, D, n_centers=32)
    qs = synthetic.queries_from(rng, x, NQ)
    return x.astype(np.float32), qs.astype(np.float32)


def mi_n_probe(x):
    return max(4, int(round(np.sqrt(len(x)))) // 2)


def mutable(x, **kw):
    kw.setdefault("k", K)
    kw.setdefault("n_probe", mi_n_probe(x))
    kw.setdefault("n_cand", 2048)
    kw.setdefault("config", IngestConfig(segment_capacity=256,
                                         merge_trigger=0.10))
    return MutableIndex(x, **kw)


def live_oracle(mi, qs, k):
    """Exact top-k over the live corpus, by external id."""
    x, ids = mi.live_corpus()
    d = np.asarray(ops.l2_exact_batch(jnp.asarray(x), jnp.asarray(qs)))
    pos = np.argsort(d, axis=1, kind="stable")[:, :k]
    return ids[pos]


def recall_vs_oracle(mi, qs, k):
    want = live_oracle(mi, qs, k)
    got = np.asarray(mi.search(qs).ids)
    hits = sum(len(set(got[bi].tolist()) & set(want[bi].tolist()))
               for bi in range(len(qs)))
    return hits / want.size


# ---------------------------- delta segments --------------------------------

def test_segment_append_delete_roundtrip():
    seg = DeltaSegment(8, D)
    rng = np.random.default_rng(0)
    ids = np.arange(100, 105, dtype=np.int64)
    seg.append(rng.normal(size=(5, D)).astype(np.float32), ids)
    assert seg.size == 5 and seg.room == 3 and seg.n_live == 5
    assert seg.delete(102) and not seg.delete(999)
    assert seg.n_live == 4
    with pytest.raises(ValueError):
        seg.append(rng.normal(size=(9, D)).astype(np.float32),
                   np.arange(200, 209, dtype=np.int64))


def test_ids_monotone_and_never_reused(corpus):
    x, _ = corpus
    mi = mutable(x)
    a = mi.insert(np.ones((3, D), np.float32))
    mi.delete(a)
    b = mi.insert(np.ones((3, D), np.float32))
    assert a.tolist() == [N, N + 1, N + 2]
    assert b.tolist() == [N + 3, N + 4, N + 5]       # deleted ids stay dead
    assert np.all(np.diff(mi.row_ids) > 0)


# ---------------------------- search semantics ------------------------------

def test_search_merges_base_and_delta_streams(corpus):
    """Inserted vectors are immediately searchable; results equal the
    exact oracle over the live corpus."""
    x, qs = corpus
    mi = mutable(x, n_probe=mi_n_probe(x))
    new_ids = mi.insert(qs + 0.001)      # near-duplicates of the queries
    res = mi.search(qs)
    ids = np.asarray(res.ids)
    for bi in range(NQ):
        assert new_ids[bi] in ids[bi]    # delta hit ranks into the top-k
    assert recall_vs_oracle(mi, qs, K) >= 0.95


def test_deleted_ids_never_surface(corpus):
    x, qs = corpus
    mi = mutable(x, n_probe=mi_n_probe(x))
    # delete each query's current top-5 (base rows) and a few delta rows
    first = np.asarray(mi.search(qs).ids)
    doomed = np.unique(first[:, :5].reshape(-1))
    delta_ids = mi.insert(qs + 0.001)
    assert mi.delete(doomed) == len(doomed)
    assert mi.delete(delta_ids) == len(delta_ids)
    res = np.asarray(mi.search(qs).ids)
    dead = set(doomed.tolist()) | set(delta_ids.tolist())
    assert not (set(res.reshape(-1).tolist()) & dead)
    assert recall_vs_oracle(mi, qs, K) >= 0.95


def test_churn_accounting_and_merge_trigger(corpus):
    x, _ = corpus
    mi = mutable(x)
    assert not mi.needs_merge()
    ins = mi.insert(np.ones((N // 8, D), np.float32))
    mi.delete(ins[: N // 100])
    frac = mi.churn_fraction()
    assert frac == pytest.approx((N // 8 + N // 100) / N)
    assert mi.needs_merge()              # > 10% trigger


# ---------------------------- merge lifecycle -------------------------------

def test_merge_folds_delta_and_reapplies_mid_merge_deletes(corpus, tmp_path):
    x, qs = corpus
    mi = mutable(x, n_probe=mi_n_probe(x))
    new_ids = mi.insert(qs + 0.001)
    mi.delete(np.arange(0, 50))
    snap_gen = mi.generation
    job = MergeJob(mi, str(tmp_path))
    with pytest.raises(MergeCrash):
        job.run(crash_after_checkpoint=True)
    # serving continues on the sealed state mid-crash
    assert recall_vs_oracle(mi, qs, K) >= 0.95
    # deletes landing DURING the merge window must not resurrect
    mi.delete(np.array([new_ids[0], 60]))
    resume_merge(mi, str(tmp_path))
    assert mi.generation == snap_gen + 1
    # only the two mid-merge deletes (applied as tombstones on the new
    # generation) remain as churn; the folded segments are gone
    assert mi.churn_fraction() < 0.01 and not mi.segments
    res = np.asarray(mi.search(qs).ids)
    dead = {int(new_ids[0]), 60} | set(range(50))
    assert not (set(res.reshape(-1).tolist()) & dead)
    assert new_ids[1] in res[1]          # surviving delta row folded in
    assert recall_vs_oracle(mi, qs, K) >= 0.95


def test_merge_abort_on_failure_restores_serving_state(corpus, tmp_path,
                                                       monkeypatch):
    x, qs = corpus
    mi = mutable(x, n_probe=mi_n_probe(x))
    mi.insert(qs + 0.001)
    before = np.asarray(mi.search(qs).ids)
    monkeypatch.setattr(mi, "build_engine",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("boom")))
    with pytest.raises(RuntimeError, match="boom"):
        MergeJob(mi, str(tmp_path)).run()
    assert mi._sealed is None            # seal unwound
    after = np.asarray(mi.search(qs).ids)
    np.testing.assert_array_equal(before, after)


def test_corrupt_checkpoint_refuses_resume(corpus, tmp_path):
    x, _ = corpus
    mi = mutable(x)
    mi.insert(np.ones((4, D), np.float32))
    with pytest.raises(MergeCrash):
        MergeJob(mi, str(tmp_path)).run(crash_after_checkpoint=True)
    # flip bytes in the payload; the checksummed restore must refuse
    step_dir = next(p for p in tmp_path.iterdir() if p.name.startswith("step"))
    victim = next(p for p in step_dir.iterdir() if p.suffix != ".json")
    data = bytearray(victim.read_bytes())
    data[len(data) // 2] ^= 0xFF
    victim.write_bytes(bytes(data))
    with pytest.raises(CorruptCheckpointError):
        resume_merge(mi, str(tmp_path))
    # recovery path: abort and re-run fresh from live state
    mi.abort_merge()
    eng = MergeJob(mi, str(tmp_path / "fresh")).run()
    assert eng is mi.engine and mi.generation == 1


# ---------------------------- drift detector --------------------------------

def test_tv_distance_bounds():
    p = np.array([0.5, 0.5, 0.0])
    q = np.array([0.0, 0.5, 0.5])
    assert tv_distance(p, p) == 0.0
    assert tv_distance(p, q) == pytest.approx(0.5)


def _warm_state(m, hist):
    st = rerank.predictor_init(m)
    return rerank.predictor_update(st, jnp.asarray(hist, jnp.float32))


def test_carry_state_decisions():
    m = 7
    base = np.zeros((1, m + 1)); base[0, 2] = 100.0
    near = np.zeros((1, m + 1)); near[0, 2] = 90.0; near[0, 3] = 10.0
    far = np.zeros((1, m + 1)); far[0, 6] = 100.0
    old = _warm_state(m, base)
    # slow drift: carried, EMA object preserved
    kept, tv, carried = carry_state(old, _warm_state(m, near), 0.25)
    assert carried and kept is old and tv == pytest.approx(0.1)
    # distribution shift: cold reset
    kept, tv, carried = carry_state(old, _warm_state(m, far), 0.25)
    assert not carried and float(np.asarray(kept.weight)) == 0.0
    # cold old state carries trivially
    cold = rerank.predictor_init(m)
    kept, tv, carried = carry_state(cold, _warm_state(m, far), 0.25)
    assert carried and kept is cold


# ---------------------------- swap + rolling swap ---------------------------

def _serving_fixture(x, qs):
    from repro.serving.state import ServingState
    from repro.serving.batcher import ShapeBucket
    idx = search.build_pq_index(jax.random.key(0), jnp.asarray(x), 16,
                                n_iter=3)
    st = ServingState(idx, use_bbc=True, tau_pred=True, m=64, pred_count=64)
    bucket = ShapeBucket(k=K, batch=NQ, n_probe=8)
    return st, bucket, idx


def _mk_batch(bucket, qs):
    from repro.serving.batcher import Batch, Request
    reqs = tuple(Request(rid=i, q=qs[i], k=bucket.k, n_probe=bucket.n_probe,
                         arrival=0.0, deadline=1.0)
                 for i in range(len(qs)))
    return Batch(bucket=bucket, requests=reqs, queries=qs)


def test_swap_is_copy_on_swap(corpus):
    """Forks taken before the swap keep resolving the OLD generation's
    engine cache; the swapping state gets a NEW dict."""
    x, qs = corpus
    st, bucket, _ = _serving_fixture(x, qs)
    st.engine(bucket)
    fork = st.fork()
    old_engines = fork._engines
    idx2 = search.build_pq_index(jax.random.key(1), jnp.asarray(x), 16,
                                 n_iter=3)
    st.swap(idx2)
    assert st.generation == 1 and fork.generation == 0
    assert fork._engines is old_engines          # old fork: untouched cache
    assert st._engines is not old_engines
    assert fork.engine(bucket).generation == 0
    assert st.engine(bucket).generation == 1


def test_rolling_swap_zero_shed_mixed_generations(corpus):
    """Mid-roll, old- and new-generation replicas serve side by side; every
    batch completes; post-roll every replica is on the new generation with
    carried (or reset, per the drift report) predictor states."""
    from repro.serving.replica import ReplicaPool
    x, qs = corpus
    st, bucket, _ = _serving_fixture(x, qs)
    pool = ReplicaPool(st, 3, [K], NQ, service_est=lambda b: 1e-3)
    for r in pool:
        for _ in range(2):
            r.state.run(_mk_batch(bucket, qs))
    idx2 = search.build_pq_index(jax.random.key(1), jnp.asarray(x), 16,
                                 n_iter=3)
    gens, done = [], []
    def on_step(rid):
        for r in pool:
            res = r.state.run(_mk_batch(bucket, qs))
            gens.append(r.generation)
            done.append(np.asarray(res.ids).shape == (NQ, K))
    report = pool.rolling_swap(idx2, probe_qs=qs, warm_buckets=[bucket],
                               on_step=on_step)
    assert set(gens) == {0, 1} and all(done) and len(done) == 9
    assert all(r.generation == 1 for r in pool)
    entry = report[(bucket.k, bucket.n_probe)]
    assert len(entry["replicas"]) == 3
    for r in pool:
        states = r.state.pred_states()
        if entry["carried"]:
            assert float(np.asarray(states[bucket].weight)) > 0.0


def test_rolling_swap_resets_predictors_on_heavy_drift(corpus):
    from repro.serving.replica import ReplicaPool
    x, qs = corpus
    st, bucket, _ = _serving_fixture(x, qs)
    pool = ReplicaPool(st, 2, [K], NQ, service_est=lambda b: 1e-3)
    for r in pool:
        r.state.run(_mk_batch(bucket, qs))
    rng = np.random.default_rng(9)
    x2 = (rng.normal(size=(N, D)) * 25 + 10).astype(np.float32)
    idx2 = search.build_pq_index(jax.random.key(1), jnp.asarray(x2), 16,
                                 n_iter=3)
    report = pool.rolling_swap(idx2, vectors=None, probe_qs=qs,
                               drift_threshold=0.02)
    entry = report[(bucket.k, bucket.n_probe)]
    assert not entry["carried"] and entry["tv"] > 0.02
    for r in pool:
        assert float(np.asarray(
            r.state.pred_states()[bucket].weight)) == 0.0


# ---------------------------- tuned resolution under drift ------------------

def test_mutable_resolves_tuned_points_with_drift_flag(corpus):
    """build_engine passes the live churn fraction into PointStore.resolve;
    past the threshold the resolution is flagged, warned, and attributed —
    never a silent stale hit."""
    from repro.tuning.knobs import KnobConfig
    from repro.tuning.points import OperatingPoint, PointStore, \
        corpus_fingerprint
    x, _ = corpus
    fp = corpus_fingerprint(jnp.asarray(x))
    point = OperatingPoint(
        method="ivfpq", k=K, recall_target=0.95,
        knobs=KnobConfig(n_probe=12, n_cand=1500),
        recall=0.97, cost_units=1.0, feasible=True,
        corpus={"fingerprint": fp})
    store = PointStore([point])
    mi = mutable(x, tuned=store)
    assert "tuned" in (mi.engine.tuned_from or "")
    mi.insert(np.ones((N // 5, D), np.float32))   # 20% churn
    with pytest.warns(UserWarning, match="drift"):
        eng = mi.build_engine(mi.live_corpus()[0], mi.generation + 1)
    assert "tuned-drifted" in eng.tuned_from


# ---------------------------- sharded parity (multidevice) ------------------

SHARDED_CHURN_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data import synthetic
    from repro.ingest import IngestConfig, MutableIndex

    rng = np.random.default_rng(0)
    n, d, B, k = 20000, 32, 8, 2000
    x = synthetic.clustered(rng, n, d, n_centers=64).astype(np.float32)
    qs = synthetic.queries_from(rng, x, B).astype(np.float32)
    mesh = jax.make_mesh((8,), ("model",))

    def churn(mi):
        ins = mi.insert(qs + 0.001)
        first = np.asarray(mi.search(qs).ids)
        doomed = np.unique(first[:, :5].reshape(-1))
        doomed = doomed[doomed >= 0]
        mi.delete(doomed)
        mi.delete(ins[:2])
        return set(doomed.tolist()) | set(ins[:2].tolist())

    cfg = IngestConfig(segment_capacity=512)
    kw = dict(k=k, n_clusters=64, n_probe=24, n_cand=6144, config=cfg,
              seed=0)
    single = MutableIndex(x, "ivfpq", **kw)
    sharded = MutableIndex(x, "ivfpq", mesh=mesh, **kw)
    dead_s = churn(single)
    dead_m = churn(sharded)
    assert dead_s == dead_m
    r1 = np.asarray(single.search(qs).ids)
    r2 = np.asarray(sharded.search(qs).ids)
    for bi in range(B):
        a = set(r1[bi].tolist()) - {-1}
        b = set(r2[bi].tolist()) - {-1}
        assert not (a & dead_s) and not (b & dead_m)
        overlap = len(a & b) / k
        assert overlap >= 0.99, (bi, overlap)
    print("CHURNED_PARITY_OK")
    """
)


@pytest.mark.multidevice
def test_churned_corpus_parity_sharded_vs_batched():
    """Acceptance: a churned corpus (delta segment dealt across an 8-device
    mesh, tombstones in both tiers) returns the same top-k through the
    sharded path as through the single-device batched path."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_CHURN_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "CHURNED_PARITY_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])
