"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buffer as rb
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [256, 1000, 4096])
@pytest.mark.parametrize("m_sub,k_codes", [(16, 16), (32, 16), (33, 16)])
@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32])
def test_pq_adc(rng, n, m_sub, k_codes, dtype):
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), dtype)
    lut = jnp.asarray(rng.random((m_sub, k_codes)), jnp.float32)
    got = ops.pq_adc(codes, lut)
    want = ref.pq_adc(codes, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d", [(256, 64), (300, 96), (1024, 128), (512, 100)])
def test_rabitq_est(rng, n, d):
    codes = jnp.asarray(rng.choice([-1, 1], (n, d)), jnp.int8)
    norm_o = jnp.asarray(rng.random(n) * 5 + 0.5, jnp.float32)
    f_o = jnp.asarray(rng.random(n) * 0.3 + 0.6, jnp.float32)
    v = jnp.asarray(rng.standard_normal(d), jnp.float32)
    v = v / jnp.linalg.norm(v)
    norm_q = jnp.float32(3.3)
    got = ops.rabitq_est(codes, norm_o, f_o, v, norm_q)
    want = ref.rabitq_est(codes, norm_o, f_o, v, norm_q)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [512, 2000, 8192])
@pytest.mark.parametrize("m", [16, 64, 128])
def test_bucket_hist(rng, n, m):
    dists = jnp.asarray(rng.random(n) * 10 + 1, jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    dists = jnp.where(valid, dists, jnp.inf)
    cb = rb.build_codebook(dists, k=min(n // 2, 1000), m=m)
    got_b, got_h = ops.bucket_hist(dists, valid, cb.d_min, cb.delta,
                                   cb.ew_map, m)
    want_b, want_h = ref.bucket_hist(dists, valid, cb.d_min, cb.delta,
                                     cb.ew_map, m)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))
    np.testing.assert_array_equal(np.asarray(got_h), np.asarray(want_h))
    # kernel bucketize also agrees with the core-library bucketize
    core_b = rb.bucketize(cb, dists)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(core_b))


@pytest.mark.parametrize("n,d,m_sub", [(512, 64, 16), (1000, 128, 32),
                                       (256, 96, 24)])
def test_fused_scan(rng, n, d, m_sub):
    k_codes, m = 16, 64
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), jnp.uint8)
    vectors = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.95)
    lut = jnp.asarray(rng.random((m_sub, k_codes)) * 2, jnp.float32)
    est_ref = jnp.sqrt(jnp.maximum(ref.pq_adc(codes, lut), 0.0))
    cb = rb.build_codebook(jnp.where(valid, est_ref, jnp.inf),
                           k=min(n // 2, 500), m=m)
    tau = jnp.int32(m // 3)
    got = ops.fused_scan(codes, vectors, valid, lut, q, cb.d_min, cb.delta,
                         cb.ew_map, m, tau)
    want = ref.fused_scan(codes, vectors, valid, lut, q, cb.d_min, cb.delta,
                          cb.ew_map, m, tau)
    names = ["est", "bucket", "hist", "early", "nmiss"]
    for name, g, w in zip(names, got, want):
        if name == "est":
            # masked lanes are +inf in the kernel; oracle masks identically
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5)
        elif name in ("bucket", "hist", "nmiss"):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-4)
    # the miss count is the complement of the predicted lanes
    n_pred = int(jnp.sum(jnp.isfinite(got[3])))
    assert int(got[4]) == int(jnp.sum(valid)) - n_pred


@pytest.mark.parametrize("n,d", [(256, 64), (999, 1536), (4096, 96)])
def test_l2_exact(rng, n, d):
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    got = ops.l2_exact(x, q)
    want = ref.l2_exact(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------- batched kernels ---------------------------------

@pytest.mark.parametrize("b", [1, 5, 8])
@pytest.mark.parametrize("n,m_sub", [(512, 16), (1000, 33)])
def test_pq_adc_batch(rng, b, n, m_sub):
    k_codes = 16
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), jnp.uint8)
    luts = jnp.asarray(rng.random((b, m_sub, k_codes)), jnp.float32)
    want = ref.pq_adc_batch(codes, luts)
    for backend in ("pallas", "ref"):
        got = ops.pq_adc_batch(codes, luts, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    # rows agree with the single-query wrapper
    got = ops.pq_adc_batch(codes, luts, backend="pallas")
    for bi in range(min(b, 2)):
        np.testing.assert_allclose(np.asarray(got[bi]),
                                   np.asarray(ops.pq_adc(codes, luts[bi])),
                                   rtol=1e-5, atol=1e-5)


def _batch_codebooks(rng, est_rows, k, m):
    cbs = [rb.build_codebook(jnp.asarray(e), k=k, m=m) for e in est_rows]
    d_min = jnp.stack([c.d_min for c in cbs])
    delta = jnp.stack([c.delta for c in cbs])
    ew = jnp.stack([c.ew_map for c in cbs])
    return d_min, delta, ew


@pytest.mark.parametrize("b", [1, 4, 8, 11])
@pytest.mark.parametrize("n", [512, 1000])
def test_bucket_hist_batch(rng, b, n):
    m = 64
    dists = np.asarray(rng.random((b, n)) * 10 + 1, np.float32)
    valid = rng.random((b, n)) < 0.9
    dists = np.where(valid, dists, np.inf).astype(np.float32)
    d_min, delta, ew = _batch_codebooks(rng, dists, k=min(n // 2, 400), m=m)
    for backend in ("pallas", "ref"):
        got_b, got_h = ops.bucket_hist_batch(
            jnp.asarray(dists), jnp.asarray(valid), d_min, delta, ew, m,
            backend=backend)
        want_b, want_h = ref.bucket_hist_batch(
            jnp.asarray(dists), jnp.asarray(valid), d_min, delta, ew, m)
        np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))
        np.testing.assert_array_equal(np.asarray(got_h), np.asarray(want_h))
        # and each row agrees with the single-query kernel
        for bi in range(b):
            srow, shist = ops.bucket_hist(
                jnp.asarray(dists[bi]), jnp.asarray(valid[bi]), d_min[bi],
                delta[bi], ew[bi], m)
            np.testing.assert_array_equal(np.asarray(got_b[bi]),
                                          np.asarray(srow))
            np.testing.assert_array_equal(np.asarray(got_h[bi]),
                                          np.asarray(shist))


@pytest.mark.parametrize("b,n,d,m_sub", [(4, 512, 64, 16), (8, 768, 96, 24),
                                         (3, 512, 128, 32)])
def test_fused_scan_batch(rng, b, n, d, m_sub):
    k_codes, m = 16, 64
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), jnp.uint8)
    vectors = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    qs = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    valid = jnp.asarray(rng.random((b, n)) < 0.95)
    luts = jnp.asarray(rng.random((b, m_sub, k_codes)) * 2, jnp.float32)
    est_rows = np.stack([
        np.where(np.asarray(valid[i]),
                 np.sqrt(np.maximum(np.asarray(ref.pq_adc(codes, luts[i])),
                                    0.0)), np.inf)
        for i in range(b)])
    d_min, delta, ew = _batch_codebooks(rng, est_rows, k=n // 2, m=m)
    tau = jnp.asarray(rng.integers(0, m, b), jnp.int32)
    want = ref.fused_scan_batch(codes, vectors, valid, luts, qs, d_min,
                                delta, ew, m, tau)
    got = ops.fused_scan_batch(codes, vectors, valid, luts, qs, d_min,
                               delta, ew, m, tau, backend="pallas")
    names = ["est", "bucket", "hist", "early", "nmiss"]
    for name, g, w in zip(names, got, want):
        if name in ("bucket", "hist", "nmiss"):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-4)
    # per-row agreement with the single-query fused kernel
    for bi in range(min(b, 2)):
        single = ops.fused_scan(codes, vectors, valid[bi], luts[bi], qs[bi],
                                d_min[bi], delta[bi], ew[bi], m, tau[bi])
        np.testing.assert_allclose(np.asarray(got[0][bi]),
                                   np.asarray(single[0]), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(got[1][bi]),
                                      np.asarray(single[1]))
        np.testing.assert_array_equal(np.asarray(got[2][bi]),
                                      np.asarray(single[2]))
        assert int(got[4][bi]) == int(single[4])


@pytest.mark.parametrize("b,n,d", [(4, 512, 64), (9, 999, 96), (1, 256, 128)])
def test_l2_exact_batch(rng, b, n, d):
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    qs = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    want = ref.l2_exact_batch(x, qs)
    for backend in ("pallas", "ref"):
        got = ops.l2_exact_batch(x, qs, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    # rows agree with the single-query kernel
    got = ops.l2_exact_batch(x, qs, backend="pallas")
    for bi in range(min(b, 2)):
        np.testing.assert_allclose(np.asarray(got[bi]),
                                   np.asarray(ops.l2_exact(x, qs[bi])),
                                   rtol=2e-4, atol=2e-4)


def test_fused_scan_matches_search_semantics(rng):
    """The fused kernel's (est, hist) must agree with the core result-buffer
    pipeline so the searcher can swap implementations freely."""
    n, d, m_sub, m = 1024, 64, 16, 64
    k_codes = 16
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), jnp.uint8)
    vectors = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    valid = jnp.ones((n,), bool)
    lut = jnp.asarray(rng.random((m_sub, k_codes)) * 2, jnp.float32)
    est = jnp.sqrt(jnp.maximum(ref.pq_adc(codes, lut), 0.0))
    cb = rb.build_codebook(est, k=256, m=m)
    _, bucket, hist, _, _ = ops.fused_scan(
        codes, vectors, valid, lut, q, cb.d_min, cb.delta, cb.ew_map, m,
        jnp.int32(m))
    core_hist = rb.histogram(rb.bucketize(cb, est), m, valid)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(core_hist))
    tau_k, _ = rb.threshold_bucket(jnp.asarray(hist), 256)
    tau_c, _ = rb.threshold_bucket(core_hist, 256)
    assert int(tau_k) == int(tau_c)
