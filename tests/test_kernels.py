"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buffer as rb
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [256, 1000, 4096])
@pytest.mark.parametrize("m_sub,k_codes", [(16, 16), (32, 16), (33, 16)])
@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32])
def test_pq_adc(rng, n, m_sub, k_codes, dtype):
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), dtype)
    lut = jnp.asarray(rng.random((m_sub, k_codes)), jnp.float32)
    got = ops.pq_adc(codes, lut)
    want = ref.pq_adc(codes, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d", [(256, 64), (300, 96), (1024, 128), (512, 100)])
def test_rabitq_est(rng, n, d):
    codes = jnp.asarray(rng.choice([-1, 1], (n, d)), jnp.int8)
    norm_o = jnp.asarray(rng.random(n) * 5 + 0.5, jnp.float32)
    f_o = jnp.asarray(rng.random(n) * 0.3 + 0.6, jnp.float32)
    v = jnp.asarray(rng.standard_normal(d), jnp.float32)
    v = v / jnp.linalg.norm(v)
    norm_q = jnp.float32(3.3)
    got = ops.rabitq_est(codes, norm_o, f_o, v, norm_q)
    want = ref.rabitq_est(codes, norm_o, f_o, v, norm_q)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [512, 2000, 8192])
@pytest.mark.parametrize("m", [16, 64, 128])
def test_bucket_hist(rng, n, m):
    dists = jnp.asarray(rng.random(n) * 10 + 1, jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    dists = jnp.where(valid, dists, jnp.inf)
    cb = rb.build_codebook(dists, k=min(n // 2, 1000), m=m)
    got_b, got_h = ops.bucket_hist(dists, valid, cb.d_min, cb.delta,
                                   cb.ew_map, m)
    want_b, want_h = ref.bucket_hist(dists, valid, cb.d_min, cb.delta,
                                     cb.ew_map, m)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))
    np.testing.assert_array_equal(np.asarray(got_h), np.asarray(want_h))
    # kernel bucketize also agrees with the core-library bucketize
    core_b = rb.bucketize(cb, dists)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(core_b))


@pytest.mark.parametrize("n,d,m_sub", [(512, 64, 16), (1000, 128, 32),
                                       (256, 96, 24)])
def test_fused_scan(rng, n, d, m_sub):
    k_codes, m = 16, 64
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), jnp.uint8)
    vectors = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.95)
    lut = jnp.asarray(rng.random((m_sub, k_codes)) * 2, jnp.float32)
    est_ref = jnp.sqrt(jnp.maximum(ref.pq_adc(codes, lut), 0.0))
    cb = rb.build_codebook(jnp.where(valid, est_ref, jnp.inf),
                           k=min(n // 2, 500), m=m)
    tau = jnp.int32(m // 3)
    got = ops.fused_scan(codes, vectors, valid, lut, q, cb.d_min, cb.delta,
                         cb.ew_map, m, tau)
    want = ref.fused_scan(codes, vectors, valid, lut, q, cb.d_min, cb.delta,
                          cb.ew_map, m, tau)
    names = ["est", "bucket", "hist", "early"]
    for name, g, w in zip(names, got, want):
        if name == "est":
            # masked lanes are +inf in the kernel; oracle masks identically
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5)
        elif name in ("bucket", "hist"):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d", [(256, 64), (999, 1536), (4096, 96)])
def test_l2_exact(rng, n, d):
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    got = ops.l2_exact(x, q)
    want = ref.l2_exact(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_fused_scan_matches_search_semantics(rng):
    """The fused kernel's (est, hist) must agree with the core result-buffer
    pipeline so the searcher can swap implementations freely."""
    n, d, m_sub, m = 1024, 64, 16, 64
    k_codes = 16
    codes = jnp.asarray(rng.integers(0, k_codes, (n, m_sub)), jnp.uint8)
    vectors = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    valid = jnp.ones((n,), bool)
    lut = jnp.asarray(rng.random((m_sub, k_codes)) * 2, jnp.float32)
    est = jnp.sqrt(jnp.maximum(ref.pq_adc(codes, lut), 0.0))
    cb = rb.build_codebook(est, k=256, m=m)
    _, bucket, hist, _ = ops.fused_scan(
        codes, vectors, valid, lut, q, cb.d_min, cb.delta, cb.ew_map, m,
        jnp.int32(m))
    core_hist = rb.histogram(rb.bucketize(cb, est), m, valid)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(core_hist))
    tau_k, _ = rb.threshold_bucket(jnp.asarray(hist), 256)
    tau_c, _ = rb.threshold_bucket(core_hist, 256)
    assert int(tau_k) == int(tau_c)
