"""Constrained auto-tuner: solver, knob invariants, store, serving wiring.

The solver is tested on SYNTHETIC knob surfaces with known optima (no
engine builds — purity and constraint satisfaction are properties of the
solver alone); the store round-trips and nearest-cell resolution are
tested on hand-built points; the serving wiring (engine ``tuned=``,
``DegradeLadder.from_frontier``, ``Request.recall_target``) is tested
against a real tiny index so the cross-bucket clamps are exercised on the
production path.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.index import engine, search
from repro.serving import admission as adm
from repro.serving import queue as rq
from repro.tuning import knobs as kn
from repro.tuning import measure, solver
from repro.tuning import points as tp

CELL = kn.Cell(method="ivfpq", k=100, n=10_000, d=32, n_clusters=64)


def sample(n_probe, recall, cost, n_cand=None, pred_count=None):
    cfg = kn.clamp(kn.KnobConfig(n_probe=n_probe, n_cand=n_cand,
                                 pred_count=pred_count), CELL)
    return measure.Sample(knobs=cfg, recall=recall, scanned=cost,
                          reranked=0.0, second_pass=0.0, cost_units=cost)


def synthetic_surface():
    """A knob surface with a KNOWN optimum: recall and cost both rise with
    n_probe; the cheapest configuration meeting recall >= 0.95 is
    n_probe=32 (recall 0.96) — n_probe=16 is cheaper but infeasible."""
    return [sample(4, 0.40, 100.0), sample(8, 0.70, 200.0),
            sample(16, 0.90, 400.0), sample(32, 0.96, 800.0),
            sample(64, 0.99, 1600.0)]


# ------------------------------- solver -------------------------------------

def test_solve_known_optimum():
    best, lam, feasible = solver.solve(synthetic_surface(), target=0.95)
    assert feasible and best.knobs.n_probe == 32
    # the multiplier is large enough that the hinge dominates raw QPS
    assert solver.score(best, lam, 0.95) >= solver.score(
        sample(16, 0.90, 400.0), lam, 0.95)


def test_solve_constraint_binds_not_overshoots():
    # with a lower target the cheaper configuration wins: the solver
    # tracks the constraint, it does not just maximize recall
    best, _, feasible = solver.solve(synthetic_surface(), target=0.85)
    assert feasible and best.knobs.n_probe == 16


def test_solve_infeasible_surfaces_flagged():
    surface = [sample(4, 0.40, 100.0), sample(8, 0.70, 200.0)]
    best, _, feasible = solver.solve(surface, target=0.95)
    assert not feasible
    assert best.knobs.n_probe == 8      # highest-recall fallback


def test_coordinate_descent_deterministic_and_finds_optimum():
    grid = {"n_probe": (4, 8, 16, 32, 64)}
    # recall/cost depend on n_probe only (the solver may carry the default
    # config's other knobs through the sweep)
    by_np = {s.knobs.n_probe: s for s in synthetic_surface()}
    calls = []

    def evaluate(cfg):
        calls.append(cfg.key())
        ref = by_np[cfg.n_probe]
        return measure.Sample(knobs=cfg, recall=ref.recall,
                              scanned=ref.scanned, reranked=0.0,
                              second_pass=0.0, cost_units=ref.cost_units)

    memos = []
    samples = None
    for _ in range(2):
        memo = solver.coordinate_descent(evaluate, CELL, grid,
                                         target=0.95, seed=7)
        memos.append(sorted(memo))
        samples = list(memo.values())
    assert memos[0] == memos[1]          # same seed -> same sweep
    assert len(set(calls)) == len(calls) // 2   # memoized within each run
    best, _, feasible = solver.solve(samples, target=0.95)
    assert feasible and best.knobs.n_probe == 32


def test_pareto_frontier_monotone():
    front = solver.pareto_frontier(synthetic_surface())
    recalls = [s.recall for s in front]
    costs = [s.cost_units for s in front]
    assert recalls == sorted(recalls, reverse=True)
    assert costs == sorted(costs, reverse=True)   # cheaper as recall drops


# ----------------------------- knob invariants ------------------------------

def test_clamp_enforces_pool_subset_and_ranges():
    cfg = kn.clamp(kn.KnobConfig(n_probe=10_000, n_cand=50,
                                 pred_count=7), CELL)
    assert cfg.n_probe == CELL.n_clusters
    assert cfg.n_cand == CELL.k                    # raised to k
    assert CELL.k <= cfg.pred_count <= cfg.n_cand  # pool-subset contract
    assert kn.clamp(cfg, CELL) == cfg              # idempotent


def test_clamp_drops_ncand_off_pq():
    cell = kn.Cell(method="ivf", k=100, n=10_000, d=32, n_clusters=64)
    assert kn.clamp(kn.KnobConfig(n_probe=8, n_cand=500), cell).n_cand is None


def test_shard_budget_stream_clamp():
    b = kn.shard_budget("ivfrabitq", 5000, None, 8)
    assert b >= 1 and b % 128 == 0
    assert kn.shard_budget("ivfrabitq", 5000, None, 8, stream_len=37) == 37
    with pytest.raises(KeyError):
        kn.shard_budget("nope", 100, None, 8)


# ------------------------------- point store --------------------------------

def point(method="ivfpq", k=100, target=0.95, n_probe=16, recall=0.97,
          cost=100.0, feasible=True, fp="aaa"):
    return tp.OperatingPoint(
        method=method, k=k, recall_target=target,
        knobs=kn.KnobConfig(n_probe=n_probe), recall=recall,
        cost_units=cost, feasible=feasible,
        corpus={"kind": "clustered", "fingerprint": fp}, commit="test",
        seed=0)


def test_point_json_roundtrip_and_canonical(tmp_path):
    pts = [point(k=100), point(k=100, target=0.8, n_probe=8, cost=50.0),
           point(method="ivf", k=200)]
    assert tp.OperatingPoint.from_json(
        json.loads(json.dumps(pts[0].to_json()))) == pts[0]
    # canonical form is order-independent -> byte-identical replay
    assert tp.canonical_json(pts) == tp.canonical_json(pts[::-1])
    store = tp.PointStore(pts)
    path = store.save(str(tmp_path / "points.json"))
    # save writes canonical (sorted) order; the point set round-trips
    assert tp.canonical_json(tp.PointStore.load(path).points) == \
        tp.canonical_json(store.points)
    assert tp.PointStore.load(str(tmp_path / "missing.json")).points == []


def test_store_add_replaces_cell():
    store = tp.PointStore([point(n_probe=16)])
    store.add(point(n_probe=32))
    assert len(store) == 1 and store.points[0].knobs.n_probe == 32


def test_resolve_nearest_cell_rules():
    store = tp.PointStore([
        point(k=100), point(k=100, target=0.8, n_probe=8, cost=50.0),
        point(k=1000, n_probe=32), point(method="ivf", k=100, n_probe=24)])
    p, prov = store.resolve("ivfpq", 100, corpus_fp="aaa")
    assert (p.k, p.recall_target, prov) == (100, 0.95, "tuned")
    # smallest covering k wins; larger-k points are recall-safe below
    p, _ = store.resolve("ivfpq", 500)
    assert p.k == 1000
    # above every tuned k: the largest available
    p, _ = store.resolve("ivfpq", 5000)
    assert p.k == 1000
    # highest target <= requested
    p, _ = store.resolve("ivfpq", 100, target=0.9)
    assert p.recall_target == 0.8
    # method never crosses
    p, _ = store.resolve("ivf", 100)
    assert p.method == "ivf" and p.knobs.n_probe == 24
    assert store.resolve("ivfrabitq", 100) == (None, tp.HAND_TUNED)
    # corpus mismatch is flagged, not hidden
    _, prov = store.resolve("ivfpq", 100, corpus_fp="zzz")
    assert prov == "tuned-nearest"


def test_resolve_under_corpus_drift_flags_and_warns():
    """Past the drift threshold an exact fingerprint match is demoted to a
    nearest-cell prior with 'tuned-drifted' attribution and a warning —
    never a silent stale hit."""
    store = tp.PointStore([point(fp="aaa")])
    # below threshold: exact match behaves as before, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        p, prov = store.resolve("ivfpq", 100, corpus_fp="aaa", drift=0.05)
    assert p is not None and prov == "tuned"
    # past threshold: same knobs, flagged provenance, UserWarning
    with pytest.warns(UserWarning, match="drift"):
        p, prov = store.resolve("ivfpq", 100, corpus_fp="aaa", drift=0.2)
    assert p is not None and prov == "tuned-drifted(20%)"
    # drift=None (frozen corpus) never warns
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _, prov = store.resolve("ivfpq", 100, corpus_fp="aaa")
    assert prov == "tuned"


def test_resolve_prefers_feasible():
    store = tp.PointStore([point(n_probe=4, cost=10.0, recall=0.5,
                                 feasible=False),
                           point(n_probe=32, cost=800.0)])
    p, _ = store.resolve("ivfpq", 100)
    assert p.feasible and p.knobs.n_probe == 32


# ------------------------- degrade ladder / frontier ------------------------

def frontier_points():
    return [point(target=0.95, n_probe=32, recall=0.96, cost=800.0),
            point(target=0.9, n_probe=16, recall=0.90, cost=400.0),
            point(target=0.8, n_probe=8, recall=0.82, cost=200.0)]


def test_ladder_from_frontier_walks_monotonically():
    ladder = adm.DegradeLadder.from_frontier(frontier_points())
    assert len(ladder.rungs) == 2          # first point = healthy serving
    caps = [ladder.caps(lf) for lf in (0.5, 1.0, 1.5, 2.0, 5.0)]
    np_caps = [c[1] for c in caps if c[1] is not None]
    targets = [c[2] for c in caps if c[2] is not None]
    # deeper overload -> never wider routing, never higher recall promise
    assert np_caps == sorted(np_caps, reverse=True)
    assert targets == sorted(targets, reverse=True)
    assert ladder.caps(0.5) == (None, None, None)      # healthy: untouched
    assert ladder.caps(9.9) == (None, 8, 0.8)          # deepest rung


def test_ladder_rejects_increasing_recall_targets():
    with pytest.raises(ValueError):
        adm.DegradeLadder(((1.0, None, 16, 0.8), (2.0, None, 8, 0.9)))
    # legacy 3-tuple rungs still work, padded with no recall entry
    ladder = adm.DegradeLadder(((1.0, 500, 16),))
    assert ladder.caps(1.0) == (500, 16, None)


def test_ladder_apply_flags_degradation():
    ladder = adm.DegradeLadder.from_frontier(frontier_points())
    r = rq.Request(rid=0, q=np.zeros(4, np.float32), k=50, n_probe=64,
                   arrival=0.0, deadline=1.0, recall_target=0.95)
    out = ladder.apply(r, load_factor=5.0)
    assert out.n_probe == 8 and out.recall_target == 0.8
    assert out.recall_requested == 0.95 and out.degraded
    # idempotent at the same rung: already at the floor
    again = ladder.apply(out, load_factor=5.0)
    assert again.recall_requested == 0.95


def test_request_recall_target_validation():
    def mk(**kw):
        return rq.Request(rid=0, q=np.zeros(4, np.float32), k=10,
                          n_probe=4, arrival=0.0, deadline=1.0, **kw)
    for bad in (0.0, -0.1, 1.5, float("nan"), float("inf")):
        with pytest.raises(ValueError):
            mk(recall_target=bad)
        with pytest.raises(ValueError):
            mk(recall_requested=bad)
    r = mk()                               # no stated target
    r2 = r.recall_capped(0.9)
    assert r2.recall_target == 0.9 and not r2.degraded   # adopts un-flagged
    r3 = mk(recall_target=0.9).recall_capped(0.95)
    assert r3.recall_target == 0.9 and not r3.degraded   # never raises


# --------------------------- engine tuned= wiring ---------------------------

@pytest.fixture(scope="module")
def tiny_index():
    rng = np.random.default_rng(0)
    x = jnp.asarray(synthetic.clustered(rng, 2000, 16, n_centers=16))
    return search.build_pq_index(jax.random.key(0), x, 16, n_iter=3)


def test_engine_build_resolves_tuned_point(tiny_index):
    p = tp.OperatingPoint(
        method="ivfpq", k=100, recall_target=0.95,
        knobs=kn.KnobConfig(n_probe=12, n_cand=400, pred_count=150),
        recall=0.97, cost_units=10.0, feasible=True)
    eng = engine.SearchEngine.build(tiny_index, k=100, tuned=p)
    assert (eng.n_probe, eng.n_cand, eng.pred_count) == (12, 400, 150)
    assert eng.tuned_from and "(tuned)" in eng.tuned_from
    # explicit knobs always beat the point
    eng = engine.SearchEngine.build(tiny_index, k=100, n_probe=5, tuned=p)
    assert eng.n_probe == 5


def test_engine_build_reclamps_cross_bucket(tiny_index):
    # a point tuned at k=100 serving a k=600 bucket must re-clamp its
    # pools to [k, n] or the top-k could not be filled (pool-subset)
    p = tp.OperatingPoint(
        method="ivfpq", k=100, recall_target=0.95,
        knobs=kn.KnobConfig(n_probe=12, n_cand=400, pred_count=150),
        recall=0.97, cost_units=10.0, feasible=True)
    eng = engine.SearchEngine.build(tiny_index, k=600,
                                    tuned=tp.PointStore([p]))
    assert eng.n_cand >= 600 and eng.pred_count >= 600
    assert eng.pred_count <= eng.n_cand


def test_engine_build_clamps_oversized_tuned_knobs(tiny_index):
    # a point tuned on a LARGER corpus can name a probe width or candidate
    # pool wider than this index's stream: nearest-cell resolution hands
    # such a point to any smaller deployment, so build must clamp it to
    # feasible ranges instead of letting top_k reject the width
    p = tp.OperatingPoint(
        method="ivfpq", k=5000, recall_target=0.95,
        knobs=kn.KnobConfig(n_probe=244, n_cand=40_000, pred_count=20_000),
        recall=0.97, cost_units=10.0, feasible=True,
        corpus={"n": 60_000, "d": 128, "fingerprint": "deadbeef0000"})
    eng = engine.SearchEngine.build(tiny_index, k=100,
                                    tuned=tp.PointStore([p]))
    assert eng.n_probe <= tiny_index.ivf.n_clusters
    assert eng.n_cand <= 2000 and eng.pred_count <= eng.n_cand
    res = eng.search_batch(jnp.zeros((2, 16), jnp.float32))
    assert np.asarray(res.ids).shape == (2, 100)


def test_engine_build_requires_n_probe_without_point(tiny_index):
    with pytest.raises(ValueError, match="n_probe is required"):
        engine.SearchEngine.build(tiny_index, k=100,
                                  tuned=tp.PointStore())
