"""Distributed BBC search: shard_map correctness on a host-device mesh.

Runs in a subprocess with XLA_FLAGS forcing 8 host devices so the single-CPU
test environment can exercise real psum/all_gather lowering (the 512-device
production mesh is exercised by launch/dryrun.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from functools import partial
    if hasattr(jax, "shard_map"):                    # jax >= 0.6
        shard_map = partial(jax.shard_map, check_vma=False)
    else:                                            # jax 0.4.x
        from jax.experimental.shard_map import shard_map as _shard_map
        shard_map = partial(_shard_map, check_rep=False)

    from repro.core import buffer as rb
    from repro.core import distributed as dist

    rng = np.random.default_rng(0)
    n_shards, per_shard, k = 8, 4096, 777
    n = n_shards * per_shard
    q = rng.standard_normal(64).astype(np.float32)
    x = rng.standard_normal((n, 64)).astype(np.float32)
    d = np.linalg.norm(x - q, axis=1).astype(np.float32)
    d += rng.random(n).astype(np.float32) * 1e-5
    ids = np.arange(n, dtype=np.int32)
    valid = np.ones(n, bool); valid[:100] = False
    dv = np.where(valid, d, np.inf).astype(np.float32)

    cb = rb.build_codebook(jnp.asarray(dv[: 4 * per_shard]), k=k, m=128)
    mesh = jax.make_mesh((n_shards,), ("model",))

    def body(ld, li, lv):
        r = dist.bbc_shard_search(ld, li, lv, cb, k=k, n_shards=n_shards)
        return r.topk_dists, r.topk_ids

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model")),
        out_specs=(P(), P()),
    )
    got_d, got_i = jax.jit(fn)(jnp.asarray(dv), jnp.asarray(ids), jnp.asarray(valid))
    oracle = np.sort(d[valid])[:k]
    np.testing.assert_allclose(np.sort(np.asarray(got_d)), oracle, rtol=1e-6)
    assert set(np.asarray(got_i).tolist()) == set(np.argsort(dv)[:k].tolist())

    # naive baseline agrees too
    def body2(ld, li, lv):
        return dist.naive_shard_search(ld, li, lv, k=k)
    fn2 = shard_map(body2, mesh=mesh,
                    in_specs=(P("model"), P("model"), P("model")),
                    out_specs=(P(), P()))
    nd, ni = jax.jit(fn2)(jnp.asarray(dv), jnp.asarray(ids), jnp.asarray(valid))
    np.testing.assert_allclose(np.sort(np.asarray(nd)), oracle, rtol=1e-6)

    # cost model sanity: BBC moves far fewer bytes than naive for large k
    cm = dist.collective_cost_model(k=100_000, m=128, n_shards=16)
    assert cm["ratio"] > 4.0

    # shard_rows: row-split replicated work == running it replicated, for
    # row counts both divisible by S and requiring wrap padding, and for
    # pytree (tuple) outputs
    for b in (16, 11, 3):
        a = jnp.asarray(rng.standard_normal((b, 97)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((b, 97)).astype(np.float32))

        def rowfn(x2, y2):
            s = jnp.sort(x2, axis=1)
            return s, jnp.sum(x2 * y2, axis=1)

        def body3(x2, y2):
            return dist.shard_rows("model", (n_shards,), rowfn, x2, y2)

        fn3 = shard_map(body3, mesh=mesh, in_specs=(P(), P()),
                        out_specs=(P(), P()))
        gs, gr = jax.jit(fn3)(a, w)
        es, er = rowfn(a, w)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(es), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(gr), np.asarray(er), rtol=1e-5)
    print("DIST_OK")
    """
)


@pytest.mark.multidevice
def test_distributed_bbc_search():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "DIST_OK" in out.stdout, out.stderr[-3000:]
