import os

# Tests and benches see the single real CPU device; ONLY launch/dryrun.py sets
# the 512-placeholder-device flag (see system design).  Keep x64 off; fp32.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
