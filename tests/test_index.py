"""Integration tests: end-to-end searchers reach paper-level recall, BBC
variants match or beat their baselines' recall at identical settings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import flat, ivf, kmeans, pq, rabitq, search


@pytest.fixture(scope="module")
def corpus():
    from repro.data import synthetic
    rng = np.random.default_rng(7)
    n, d = 20000, 64
    x = synthetic.clustered(rng, n, d, n_centers=128)
    qs = synthetic.queries_from(rng, x, 4)
    return jnp.asarray(x), jnp.asarray(qs)


@pytest.fixture(scope="module")
def pq_index(corpus):
    x, _ = corpus
    return search.build_pq_index(jax.random.key(0), x, n_clusters=64, n_iter=6)


@pytest.fixture(scope="module")
def rq_index(corpus):
    x, _ = corpus
    return search.build_rabitq_index(jax.random.key(0), x, n_clusters=64, n_iter=6)


def _recall(got_ids, want_ids):
    return len(set(got_ids.tolist()) & set(want_ids.tolist())) / len(want_ids)


def test_kmeans_reduces_quantization_error(corpus):
    x, _ = corpus
    cent, a = kmeans.kmeans(jax.random.key(1), x[:5000], 16, n_iter=8)
    err = jnp.mean(jnp.sum((x[:5000] - cent[a]) ** 2, -1))
    base = jnp.mean(jnp.sum((x[:5000] - jnp.mean(x[:5000], 0)) ** 2, -1))
    assert float(err) < 0.99 * float(base)


def test_ivf_padded_layout(corpus):
    x, _ = corpus
    idx = ivf.build(jax.random.key(2), x[:4000], 16, n_iter=4)
    assert idx.member_ids.shape[1] % 128 == 0
    # every point appears exactly once
    mem = np.asarray(idx.member_ids)
    assert sorted(mem[mem >= 0].tolist()) == list(range(4000))


def test_pq_estimate_correlates(corpus, pq_index):
    x, qs = corpus
    q = qs[0]
    lut = pq.adc_table(pq_index.pq, q)
    est = np.sqrt(np.maximum(np.asarray(pq.estimate(lut, pq_index.codes[:2000])), 0))
    exact = np.linalg.norm(np.asarray(x[:2000]) - np.asarray(q), axis=1)
    r = np.corrcoef(est, exact)[0, 1]
    assert r > 0.7


def test_rabitq_bounds_hold(corpus, rq_index):
    """Paper: bounds hold w.h.p. (99%+) at eps0=1.9."""
    x, qs = corpus
    q = qs[0]
    idx = rq_index
    cid = 3
    members = np.asarray(idx.ivf.member_ids[cid])
    members = members[members >= 0][:512]
    qf = rabitq.query_factors(idx.rq, q, idx.ivf.centroids[cid])
    est, lb, ub = rabitq.estimate(
        idx.rq.codes[members], idx.rq.norm_o[members], idx.rq.f_o[members], qf)
    exact = np.linalg.norm(np.asarray(x)[members] - np.asarray(q), axis=1)
    ok = (np.asarray(lb) <= exact + 1e-4) & (exact <= np.asarray(ub) + 1e-4)
    assert ok.mean() > 0.98
    # and the estimate is close
    rel = np.abs(np.asarray(est) - exact) / exact
    assert np.median(rel) < 0.1


@pytest.mark.parametrize("use_bbc", [False, True])
def test_ivf_search_recall(corpus, use_bbc):
    """Gaussian corpora have weak cluster structure; assert the trade-off
    curve behaves (recall grows with n_probe; near-exhaustive probe ~ exact)
    rather than an absolute mid-probe level."""
    x, qs = corpus
    idx = ivf.build(jax.random.key(2), x, 64, n_iter=6)
    k = 500
    gt_d, gt_i = flat.search(x, qs[0], k)
    recs = []
    for n_probe in (2, 12, 48):
        r = search.ivf_search(idx, x, qs[0], k=k, n_probe=n_probe,
                              use_bbc=use_bbc)
        recs.append(_recall(np.asarray(r.ids), np.asarray(gt_i)))
    assert recs[0] <= recs[1] <= recs[2]
    assert recs[2] > 0.97


@pytest.mark.parametrize("use_bbc", [False, True])
def test_ivf_pq_search_recall(corpus, pq_index, use_bbc):
    x, qs = corpus
    k = 500
    gt_d, gt_i = flat.search(x, qs[1], k)
    # paper Table 4: n_cand is several-to-many times k; Gaussian corpora have
    # high PQ error (no low-dim structure), so use the large end.
    r = search.ivf_pq_search(pq_index, qs[1], k=k, n_probe=56, n_cand=8 * k,
                             use_bbc=use_bbc)
    rec = _recall(np.asarray(r.ids), np.asarray(gt_i))
    assert rec > 0.85, rec
    if use_bbc:
        # early re-rank must cover nearly all of the selection inline
        assert int(r.n_second_pass) < 0.25 * int(r.n_reranked)


@pytest.mark.parametrize("use_bbc", [False, True])
def test_ivf_rabitq_search_recall(corpus, rq_index, use_bbc):
    x, qs = corpus
    k = 500
    gt_d, gt_i = flat.search(x, qs[2], k)
    r = search.ivf_rabitq_search(rq_index, qs[2], k=k, n_probe=48,
                                 use_bbc=use_bbc)
    rec = _recall(np.asarray(r.ids), np.asarray(gt_i))
    assert rec > 0.9, rec


def test_bbc_reranks_fewer(corpus, rq_index):
    """Paper Exp-5: the greedy buffer re-rank spends fewer exact evaluations
    than the baseline threshold criterion at equal n_probe."""
    _, qs = corpus
    k = 1000
    base = search.ivf_rabitq_search(rq_index, qs[3], k=k, n_probe=48,
                                    use_bbc=False)
    bbc = search.ivf_rabitq_search(rq_index, qs[3], k=k, n_probe=48,
                                   use_bbc=True)
    assert int(bbc.n_reranked) < int(base.n_reranked)
