"""Parity tests: the natively batched searchers must return the same top-k
as the single-query paths (and hence as vmap-of-single-query) for all three
index types, plus edge cases (B=1, k larger than a cluster's population,
under-filled results) and the fused Pallas path in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.index import engine, ivf as ivf_mod, search


N, D, NQ = 8000, 64, 6
K, N_PROBE = 200, 12


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    x = synthetic.clustered(rng, N, D, n_centers=64)
    qs = synthetic.queries_from(rng, x, NQ)
    return jnp.asarray(x), jnp.asarray(qs)


@pytest.fixture(scope="module")
def ivf_index(corpus):
    x, _ = corpus
    return ivf_mod.build(jax.random.key(2), x, 32, n_iter=4)


@pytest.fixture(scope="module")
def pq_index(corpus):
    x, _ = corpus
    return search.build_pq_index(jax.random.key(0), x, 32, n_iter=4)


@pytest.fixture(scope="module")
def rq_index(corpus):
    x, _ = corpus
    return search.build_rabitq_index(jax.random.key(0), x, 32, n_iter=4)


def _assert_parity(batch_res, single_results, min_overlap=1.0):
    """Top-k id sets equal (up to min_overlap) and sorted dists allclose."""
    bids = np.asarray(batch_res.ids)
    bd = np.asarray(batch_res.dists)
    for bi, r1 in enumerate(single_results):
        sids = np.asarray(r1.ids)
        got, want = set(bids[bi].tolist()), set(sids.tolist())
        k = len(sids)
        overlap = len(got & want) / k
        assert overlap >= min_overlap, (bi, overlap)
        if min_overlap >= 1.0:
            assert got == want, (bi, got ^ want)
        np.testing.assert_allclose(
            np.sort(bd[bi]), np.sort(np.asarray(r1.dists)),
            rtol=2e-4, atol=2e-4)


# ---------------------------- layout ---------------------------------------

def test_flat_layout_covers_corpus(ivf_index):
    lay = ivf_mod.flat_layout(ivf_index)
    order = np.asarray(lay.order)
    valid = np.asarray(lay.valid)
    assert sorted(order[valid].tolist()) == list(range(N))
    # cluster_of consistent with offsets
    cl = np.asarray(lay.cluster_of)
    offs = np.asarray(lay.offsets)
    for c in range(ivf_index.n_clusters):
        seg = cl[offs[c]:offs[c + 1]]
        assert (seg == c).all()
    assert (cl[offs[-1]:] == ivf_index.n_clusters).all()  # padding tail


def test_probe_mask_matches_membership(ivf_index, corpus):
    _, qs = corpus
    lay = ivf_mod.flat_layout(ivf_index)
    probed = ivf_mod.route_batch(ivf_index, qs, 4)
    mask = np.asarray(ivf_mod.probe_mask(lay, probed, ivf_index.n_clusters))
    cl = np.asarray(lay.cluster_of)
    for bi in range(qs.shape[0]):
        want = np.isin(cl, np.asarray(probed[bi])) & np.asarray(lay.valid)
        np.testing.assert_array_equal(mask[bi], want)


# ---------------------------- parity ---------------------------------------

@pytest.mark.parametrize("use_bbc", [False, True])
def test_ivf_batch_parity(ivf_index, corpus, use_bbc):
    x, qs = corpus
    lay = ivf_mod.flat_layout(ivf_index)
    br = search.ivf_search_batch(ivf_index, x, qs, lay, k=K, n_probe=N_PROBE,
                                 use_bbc=use_bbc)
    singles = [search.ivf_search(ivf_index, x, q, k=K, n_probe=N_PROBE,
                                 use_bbc=use_bbc) for q in qs]
    _assert_parity(br, singles)


@pytest.mark.parametrize("use_bbc", [False, True])
def test_pq_batch_parity(pq_index, corpus, use_bbc):
    _, qs = corpus
    lay = ivf_mod.flat_layout(pq_index.ivf)
    br = search.ivf_pq_search_batch(pq_index, qs, lay, k=K, n_probe=N_PROBE,
                                    n_cand=8 * K, use_bbc=use_bbc)
    singles = [search.ivf_pq_search(pq_index, q, k=K, n_probe=N_PROBE,
                                    n_cand=8 * K, use_bbc=use_bbc)
               for q in qs]
    _assert_parity(br, singles)


@pytest.mark.parametrize("use_bbc", [False, True])
def test_rabitq_batch_parity(rq_index, corpus, use_bbc):
    _, qs = corpus
    lay = ivf_mod.flat_layout(rq_index.ivf)
    br = search.ivf_rabitq_search_batch(rq_index, qs, lay, k=K,
                                        n_probe=N_PROBE, use_bbc=use_bbc)
    singles = [search.ivf_rabitq_search(rq_index, q, k=K, n_probe=N_PROBE,
                                        use_bbc=use_bbc) for q in qs]
    # The batched estimator decomposes P(q-c) = Pq - Pc, so bounds differ
    # from the per-cluster matvec at float accumulation level; plan masks can
    # flip for boundary items.  Demand near-perfect set agreement.
    _assert_parity(br, singles, min_overlap=0.99 if use_bbc else 1.0)


def test_pq_batch_fused_interpret_matches_unfused(pq_index, corpus):
    """The fused Pallas kernel path (interpret mode on CPU) must agree with
    the jnp fallback path."""
    _, qs = corpus
    lay = ivf_mod.flat_layout(pq_index.ivf)
    rf = search.ivf_pq_search_batch(pq_index, qs[:4], lay, k=K,
                                    n_probe=N_PROBE, n_cand=8 * K,
                                    use_bbc=True, fused=True,
                                    backend="pallas")
    rn = search.ivf_pq_search_batch(pq_index, qs[:4], lay, k=K,
                                    n_probe=N_PROBE, n_cand=8 * K,
                                    use_bbc=True, fused=False)
    for bi in range(4):
        assert (set(np.asarray(rf.ids[bi]).tolist())
                == set(np.asarray(rn.ids[bi]).tolist()))
    np.testing.assert_allclose(np.sort(np.asarray(rf.dists), axis=1),
                               np.sort(np.asarray(rn.dists), axis=1),
                               rtol=1e-4, atol=1e-4)
    # the fused kernel's inline early re-rank must cover most of the
    # selection (the Alg. 4 story): stragglers only in the second pass
    assert int(jnp.sum(rf.n_second_pass)) < int(jnp.sum(rf.n_reranked))


# ---------------------------- edge cases ------------------------------------

@pytest.mark.parametrize("use_bbc", [False, True])
def test_batch_of_one(pq_index, corpus, use_bbc):
    _, qs = corpus
    lay = ivf_mod.flat_layout(pq_index.ivf)
    br = search.ivf_pq_search_batch(pq_index, qs[:1], lay, k=K,
                                    n_probe=N_PROBE, n_cand=8 * K,
                                    use_bbc=use_bbc)
    assert br.ids.shape == (1, K)
    r1 = search.ivf_pq_search(pq_index, qs[0], k=K, n_probe=N_PROBE,
                              n_cand=8 * K, use_bbc=use_bbc)
    _assert_parity(br, [r1])


def test_k_exceeds_cluster_population(ivf_index, corpus):
    """n_probe=1 with k larger than any single cluster: the result is the
    whole probed cluster plus (+inf, -1) padding — identical to the
    single-query path."""
    x, qs = corpus
    lay = ivf_mod.flat_layout(ivf_index)
    k = int(np.asarray(ivf_index.cluster_sizes).max()) + 64
    br = search.ivf_search_batch(ivf_index, x, qs, lay, k=k, n_probe=1)
    for bi, q in enumerate(qs):
        r1 = search.ivf_search(ivf_index, x, q, k=k, n_probe=1)
        np.testing.assert_array_equal(np.asarray(br.ids[bi]),
                                      np.asarray(r1.ids))
        np.testing.assert_allclose(np.asarray(br.dists[bi]),
                                   np.asarray(r1.dists), rtol=2e-4,
                                   atol=2e-4)
        n_valid = int(np.asarray(ivf_index.cluster_sizes)[
            int(ivf_mod.route(ivf_index, q, 1)[0])])
        assert (np.asarray(br.ids[bi])[n_valid:] == -1).all()
        assert np.isinf(np.asarray(br.dists[bi])[n_valid:]).all()


def test_all_invalid_tail_lanes(ivf_index, corpus):
    """Stream-tail padding lanes (the all-invalid-tile analogue of the
    compact layout) must never be selected."""
    x, qs = corpus
    lay = ivf_mod.flat_layout(ivf_index)
    br = search.ivf_search_batch(ivf_index, x, qs, lay, k=K,
                                 n_probe=ivf_index.n_clusters)
    ids = np.asarray(br.ids)
    assert (ids >= 0).all() and (ids < N).all()
    # exhaustive probe == exact search
    from repro.index import flat
    for bi in range(2):
        gd, gi = flat.search(x, qs[bi], K)
        assert set(ids[bi].tolist()) == set(np.asarray(gi).tolist())


# ---------------------------- engine ----------------------------------------

def test_engine_dispatch(pq_index, rq_index, ivf_index, corpus):
    x, qs = corpus
    for index, kwargs in ((pq_index, {}), (rq_index, {}),
                          (ivf_index, {"vectors": x})):
        eng = engine.SearchEngine.build(index, k=64, n_probe=8, use_bbc=True,
                                        **kwargs)
        rb_ = eng.search(qs[:3])
        assert rb_.ids.shape == (3, 64)
        r1 = eng.search(qs[0])
        assert r1.ids.shape == (64,)
        assert set(np.asarray(rb_.ids[0]).tolist()) \
            == set(np.asarray(r1.ids).tolist())


# ------------------------ deterministic tie-breaking -------------------------

def test_tie_broken_cut_is_order_invariant():
    """The selection SET of the (est, global-id) cut must be a function of
    the (value, id) multiset alone — identical for the batched stream order
    and any sharded gathered-pool permutation — even when PQ estimates tie
    exactly at the cut boundary (shared codes make such ties common)."""
    rng = np.random.default_rng(3)
    b, n, width = 4, 256, 41
    # few distinct levels -> boundary ties guaranteed
    vals = rng.choice(np.linspace(0.2, 2.0, 9).astype(np.float32),
                      size=(b, n))
    vals[:, -13:] = np.inf
    gids = rng.permutation(np.arange(n, dtype=np.int32))
    gids[-13:] = -1

    def kept_set(v_row, i_row):
        keep = search._kth_value_mask(jnp.asarray(v_row[None]),
                                      jnp.asarray(i_row[None]), width)
        sel = np.flatnonzero(np.asarray(keep)[0] & np.isfinite(v_row))
        return set(i_row[sel].tolist())

    neg, pos = search._topk_est_id(jnp.asarray(vals), jnp.asarray(gids),
                                   width)
    neg, pos = np.asarray(neg), np.asarray(pos)
    for bi in range(b):
        base = kept_set(vals[bi], gids)
        # lexicographic (value, id) oracle
        order = np.lexsort((gids.astype(np.int64) & 0x7FFFFFFF, vals[bi]))
        pick = order[:width]
        want = set(gids[pick[np.isfinite(vals[bi][pick])]].tolist())
        assert base == want
        # mask set survives any pool permutation (the sharded gather order)
        perm = rng.permutation(n)
        assert kept_set(vals[bi][perm], gids[perm]) == want
        # batched top_k-with-repair selects the same set
        got = set(gids[pos[bi][np.isfinite(-neg[bi])]].tolist())
        assert got == want


def test_topk_est_id_matches_topk_without_ties():
    """Tie-free rows must pay (and return) exactly the plain top_k."""
    rng = np.random.default_rng(4)
    vals = (rng.standard_normal((5, 128)).astype(np.float32)) ** 2
    gids = np.arange(128, dtype=np.int32)
    neg, pos = search._topk_est_id(jnp.asarray(vals), jnp.asarray(gids), 17)
    rneg, rpos = jax.lax.top_k(-jnp.asarray(vals), 17)
    assert np.array_equal(np.asarray(neg), np.asarray(rneg))
    assert np.array_equal(np.asarray(pos), np.asarray(rpos))
