"""Live-socket integration tests for the transport tier (PR 10).

Everything in this file runs REAL worker subprocesses over Unix-domain
sockets: round-trip parity against in-process engine calls, the exact-key
result cache over a Zipf trace, typed rejection of malformed / corrupt /
oversized frames (workers must survive all of it), byte-identical
record/replay of a live run, worker-death detection + respawn, and a
subprocess SIGTERM graceful-drain test of ``launch/serve.py --mode net``.

These tests spawn engines (~seconds of JAX compile per process), so the
file shares one module-scoped server across the fast tests and keeps the
expensive standalone scenarios (respawn, SIGTERM) to one server each.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.data import synthetic
from repro.serving.batcher import k_ceilings
from repro.serving.queue import make_zipf_trace
from repro.serving.router import RetryPolicy, outcome_digest
from repro.transport import frames
from repro.transport.client import NetClient
from repro.transport.core import MasterConfig
from repro.transport.enginehost import (build_spec, build_state_from_spec,
                                        make_dataset, make_exec_fn)
from repro.transport.master import MasterServer
from repro.transport.replay import replay_transcript
from repro.transport.wire import Transcript

KS = (10, 100)
SPEC = build_spec(n=4096, d=16, seed=0, ks=KS, n_probe=8)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand_q(rng):
    return rng.standard_normal(SPEC["d"]).astype(np.float32)


def _trace(n, seed=0, rate=150.0, deadline=5.0):
    rng = np.random.default_rng(seed)
    x = make_dataset(SPEC)
    pool = synthetic.queries_from(rng, x, 8)
    return make_zipf_trace(rng, pool, n, KS, rate=rate, deadline=deadline,
                           n_probe=SPEC["n_probe"])


@pytest.fixture(scope="module")
def net():
    """One live master + 2 worker subprocesses + an in-process twin engine
    (for parity and replay), shared by the fast tests below."""
    cfg = MasterConfig(n_workers=2, ceilings=k_ceilings(KS), cache_size=64)
    ms = MasterServer(cfg, SPEC, record=True)
    ms.start()
    assert ms.wait_workers(timeout=300.0), "workers never came up"
    stop = threading.Event()
    th = threading.Thread(target=lambda: ms.serve(until=stop.is_set),
                          daemon=True)
    th.start()
    state, ceilings = build_state_from_spec(SPEC)
    ns = SimpleNamespace(ms=ms, stop=stop, thread=th, cfg=cfg, state=state,
                         exec_fn=make_exec_fn(state, ceilings))
    yield ns
    stop.set()
    th.join(timeout=10.0)
    ms.shutdown()


def test_live_roundtrip_parity_and_cache(net):
    trace = _trace(40)
    with NetClient(net.ms.addr) as c:
        records = c.run_trace(trace, settle=30.0)
    assert len(records) == len(trace)
    by_rid = {r.rid: r for r in trace}
    for rid, rec in records.items():
        assert rec["status"] in ("ok", "degraded"), (rid, rec)
        req = by_rid[rid]
        _, ids = net.exec_fn(req.q, req.k, req.n_probe)
        # parity: what came over the wire == the direct in-process call,
        # cached or not (cache hits are byte-identical by construction)
        np.testing.assert_array_equal(np.asarray(rec["ids"]),
                                      np.asarray(ids))
    # the Zipf head actually hit the exact-key cache
    assert any(r["cached"] for r in records.values())
    assert net.ms.core.stats["cache_hits"] > 0


def test_live_malformed_frames_typed_errors_workers_survive(net):
    ms = net.ms
    # stream-level garbage: typed bad_frame error, then the server closes
    c = NetClient(ms.addr).connect()
    c.send_raw(b"\xff\xff\xff\xff garbage that is not a frame")
    r = c.recv_reply(timeout=10.0)
    assert r is not None and r["kind"] == frames.ERR
    assert r["code"] == "bad_frame"
    with pytest.raises(ConnectionError):    # no resync point: conn closed
        c.recv_reply(timeout=10.0)
    c.sock.close()

    # seeded fuzz over the real wire: corrupted copies of a valid frame
    rng = np.random.default_rng(7)
    base = frames.encode_frame(
        {"kind": frames.REQ, "rid": 1, "q": frames.pack_array(_rand_q(rng)),
         "k": 10, "n_probe": 8, "deadline_s": 1.0}, "json")
    for trial in range(8):
        blob = bytearray(base)
        for _ in range(3):
            blob[int(rng.integers(0, len(blob)))] = int(rng.integers(0, 256))
        cx = NetClient(ms.addr).connect()
        try:
            cx.send_raw(bytes(blob))
            reply = cx.recv_reply(timeout=5.0)
            # any reply must be typed protocol traffic, never silence from
            # a crashed master (None = corrupt bytes happened to parse as a
            # valid frame the server is still waiting to complete)
            if reply is not None:
                assert reply["kind"] in (frames.ERR, frames.RESP,
                                         frames.RETRY_AFTER)
        except ConnectionError:
            pass                            # closed on corruption: correct
        finally:
            cx.sock.close()

    # structurally-valid frames with hostile payloads: typed errors, the
    # connection stays open, and the next valid request still works
    with NetClient(ms.addr) as c2:
        c2.sock.sendall(frames.encode_frame(
            {"kind": frames.REQ, "rid": 1, "q": "not an array",
             "k": 10, "n_probe": 8, "deadline_s": 1.0}, c2.codec))
        r = c2.recv_reply(10.0)
        assert r["kind"] == frames.ERR and r["code"] == "bad_request"
        c2.send_request(2, np.full(SPEC["d"], np.nan, np.float32), 10, 8,
                        1.0)                # non-finite embedding
        r = c2.recv_reply(10.0)
        assert r["kind"] == frames.ERR and r["code"] == "bad_request"
        c2.sock.sendall(frames.encode_frame(
            {"kind": frames.REQ, "rid": 3,
             "q": frames.pack_array(_rand_q(rng)), "k": "lots",
             "n_probe": 8, "deadline_s": 1.0}, c2.codec))
        r = c2.recv_reply(10.0)
        assert r["kind"] == frames.ERR and r["code"] == "bad_request"
        c2.sock.sendall(frames.encode_frame(
            {"kind": "totally_unknown"}, c2.codec))
        r = c2.recv_reply(10.0)
        assert r["kind"] == frames.ERR and r["code"] == "bad_kind"
        # same connection, valid request: full service
        c2.send_request(9, _rand_q(rng), 10, 8, 10.0)
        r = c2.recv_reply(30.0)
        assert r["kind"] == frames.RESP and r["rid"] == 9

    # an oversized frame announcement is rejected before buffering
    c3 = NetClient(ms.addr).connect()
    c3.send_raw((64 * 1024 * 1024).to_bytes(4, "big") + b"J")
    r = c3.recv_reply(10.0)
    assert r is not None and r["code"] == "bad_frame"
    c3.sock.close()

    # none of that killed a worker
    assert all(p.poll() is None for p in ms.procs.values())
    assert ms.core.stats["malformed"] >= 2


def test_live_record_replay_digest_identical(net):
    """Stop the serve loop, then replay the recorded transcript through a
    fresh core with the in-process twin engine: the outcome digest must be
    byte-identical, and every re-executed payload must reproduce the
    checksum the worker subprocess computed over the wire."""
    net.stop.set()
    net.thread.join(timeout=10.0)
    ms = net.ms
    live_digest = outcome_digest(ms.core.outcome_list())
    assert len(ms.core.outcomes) > 0
    tr = Transcript.loads(ms.transcript.dumps())    # full serialize cycle
    res = replay_transcript(tr, net.cfg, net.state.centroids, net.exec_fn)
    assert res.digest == live_digest
    assert res.checksum_mismatches == []
    assert res.core.stats["offered"] == ms.core.stats["offered"]
    assert res.core.stats["cache_hits"] == ms.core.stats["cache_hits"]
    assert res.core.stats["malformed"] == ms.core.stats["malformed"]


def test_live_worker_death_detection_and_respawn(tmp_path, monkeypatch):
    """REPRO_WORKER_EXIT_AFTER makes the worker die mid-request: the
    master must detect the death, respawn the worker, and complete the
    orphaned request on the fresh process — the client just sees a slower
    answer, never an error."""
    monkeypatch.setenv("REPRO_WORKER_EXIT_AFTER", "3")
    cfg = MasterConfig(
        n_workers=1, ceilings=k_ceilings(KS),
        retry=RetryPolicy(relative=True, timeout_mult=6.0, max_retries=3,
                          backoff_base=0.005, backoff_cap=0.1))
    ms = MasterServer(cfg, SPEC, run_dir=str(tmp_path))
    ms.start()
    assert ms.wait_workers(timeout=300.0)
    # the replacement worker must NOT inherit the suicide hook
    monkeypatch.delenv("REPRO_WORKER_EXIT_AFTER")
    stop = threading.Event()
    th = threading.Thread(target=lambda: ms.serve(until=stop.is_set),
                          daemon=True)
    th.start()
    try:
        rng = np.random.default_rng(3)
        with NetClient(ms.addr) as c:
            for rid in range(2):
                c.send_request(rid, _rand_q(rng), 10, 8, 30.0)
                r = c.recv_reply(30.0)
                assert r is not None and r["kind"] == frames.RESP \
                    and r["rid"] == rid
            # the 3rd served request kills the worker before it replies;
            # completion requires detect -> respawn -> re-dispatch, so the
            # deadline must cover a full engine rebuild
            c.send_request(2, _rand_q(rng), 100, 8, 120.0)
            r = c.recv_reply(120.0)
            assert r is not None and r["kind"] == frames.RESP \
                and r["rid"] == 2, r
        assert ms.core.stats["worker_lost"] >= 1
        assert ms.core.stats["respawns"] >= 1
        out = [o for o in ms.core.outcome_list() if o.request.k == 100]
        assert out and out[-1].completed
    finally:
        stop.set()
        th.join(timeout=10.0)
        ms.shutdown()


def test_sigterm_graceful_drain_subprocess():
    """`launch/serve.py --mode net --serve-forever` under SIGTERM: one
    request completes while up, the drain terminates every in-flight or
    newly-arriving request with a typed reply (RESP or RETRY_AFTER), the
    summary conserves all offered requests, and the exit code is 0."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--mode", "net",
         "--workers", "1", "--n", "4096", "--d", "16", "--n-probe", "8",
         "--k-choices", "10,100", "--serve-forever"],
        cwd=REPO, env=env, stdout=subprocess.PIPE,
        stderr=subprocess.DEVNULL, text=True)
    addr = None
    try:
        while True:
            line = proc.stdout.readline()
            if not line:
                break
            try:
                obj = json.loads(line)
            except ValueError:
                continue
            if obj.get("event") == "listening":
                addr = obj["addr"]
                break
        assert addr is not None, "server never announced its address"
        rng = np.random.default_rng(0)
        c = NetClient(addr, timeout=30.0).connect()
        c.send_request(0, _rand_q(rng), 10, 8, 10.0)
        r = c.recv_reply(30.0)
        assert r is not None and r["kind"] == frames.RESP and r["rid"] == 0
        # put several requests in flight, then SIGTERM while they travel
        inflight = list(range(1, 6))
        for rid in inflight:
            c.send_request(rid, _rand_q(rng), 100, 8, 10.0)
        # first reply back proves the batch was read and admitted (one
        # recv parses the whole back-to-back burst), so the drain below
        # must account for every one of them
        got, closed = {}, False
        r = c.recv_reply(30.0)
        assert r is not None
        got[r.get("rid")] = r
        proc.send_signal(signal.SIGTERM)
        probe_rid = 100
        end = time.monotonic() + 20.0
        while time.monotonic() < end and not closed and \
                not all(i in got for i in inflight):
            try:                        # new arrivals during the drain
                c.send_request(probe_rid, _rand_q(rng), 10, 8, 10.0)
                probe_rid += 1
            except OSError:
                closed = True
                break
            try:
                r = c.recv_reply(0.1)
            except ConnectionError:
                closed = True
                break
            if r is not None:
                got[r.get("rid")] = r
        # drain contract: every reply that came back is a typed terminal
        # frame — completed work or an explicit retriable rejection
        assert got or closed
        for rid, r in got.items():
            assert r["kind"] in (frames.RESP, frames.RETRY_AFTER), (rid, r)
        for rid in inflight:            # in-flight never silently dropped
            if rid in got:
                assert got[rid]["kind"] in (frames.RESP,
                                            frames.RETRY_AFTER)
        try:
            c.sock.close()
        except OSError:
            pass
        rc = proc.wait(timeout=120)
        assert rc == 0, f"serve.py exited {rc}"
        summary = None
        for line in proc.stdout:
            try:
                obj = json.loads(line)
            except ValueError:
                continue
            if "conserved" in obj:
                summary = obj
        assert summary is not None and summary["conserved"], summary
        assert summary["requests"] >= 1 + len(inflight)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)
