"""Unit + property tests for the transport tier (no sockets, no jax).

Everything here runs against the pure pieces: the frame codec (including
a deterministic corruption fuzz over real encoded frames), the LRU
caches, the injectable clocks, the seeded wire-fault schedule, and the
``MasterCore`` state machine driven through the virtual-clock
``LoopbackSim`` — conservation, backpressure, draining, caching,
corruption recovery, and sim-level record/replay digest identity.
The real-socket integration tests live in test_transport_net.py.
"""
import json

import numpy as np
import pytest

from repro.serving import faults as flt
from repro.serving import server as srv
from repro.serving.batcher import k_ceilings
from repro.serving.clock import ManualClock, SystemClock
from repro.serving.health import DOWN, HEALTHY, HealthView
from repro.serving.queue import Request, make_zipf_trace, zipf_query_ids
from repro.serving.router import RetryPolicy, outcome_digest
from repro.transport import frames
from repro.transport.cache import LruCache, ResultCache, RouteMemo
from repro.transport.core import MasterConfig, MasterCore
from repro.transport.replay import replay_transcript
from repro.transport.sim import LoopbackSim
from repro.transport.wire import Transcript, WireShim

CODECS = ["json"] + (["msgpack"] if frames.msgpack is not None else [])


# --------------------------------------------------------------------------
# frames
# --------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
def test_frame_roundtrip(codec):
    frame = {"kind": "req", "rid": 7, "k": 100, "n_probe": 8,
             "q": frames.pack_array(np.arange(6, dtype=np.float32)),
             "note": "héllo"}
    data = frames.encode_frame(frame, codec)
    reader = frames.FrameReader()
    out = reader.feed(data)
    assert len(out) == 1
    got = out[0]
    assert got["kind"] == "req" and got["rid"] == 7
    arr = frames.unpack_array(got["q"])
    np.testing.assert_array_equal(arr, np.arange(6, dtype=np.float32))
    assert arr.dtype == np.float32


@pytest.mark.parametrize("codec", CODECS)
def test_frame_reader_incremental_and_pipelined(codec):
    f1 = frames.encode_frame({"kind": "a", "x": 1}, codec)
    f2 = frames.encode_frame({"kind": "b", "y": [1, 2]}, codec)
    reader = frames.FrameReader()
    blob = f1 + f2
    got = []
    for i in range(len(blob)):          # byte-at-a-time: never raises
        got.extend(reader.feed(blob[i:i + 1]))
    assert [g["kind"] for g in got] == ["a", "b"]
    assert reader.pending() == 0


def test_frame_reader_rejects_bad_length_and_codec():
    reader = frames.FrameReader(max_frame=1024)
    with pytest.raises(frames.FrameError):
        reader.feed((2048).to_bytes(4, "big") + b"J{}")
    reader = frames.FrameReader()
    with pytest.raises(frames.FrameError):
        reader.feed((3).to_bytes(4, "big") + b"Zxx")
    reader = frames.FrameReader()
    with pytest.raises(frames.FrameError):                  # zero length
        reader.feed((0).to_bytes(4, "big"))


def test_frame_payload_must_be_dict_with_kind():
    body = json.dumps([1, 2, 3]).encode()
    data = (len(body) + 1).to_bytes(4, "big") + b"J" + body
    with pytest.raises(frames.FrameError):
        frames.FrameReader().feed(data)
    body = json.dumps({"nokind": 1}).encode()
    data = (len(body) + 1).to_bytes(4, "big") + b"J" + body
    with pytest.raises(frames.FrameError):
        frames.FrameReader().feed(data)
    with pytest.raises(frames.FrameError):
        frames.encode_frame({"no": "kind"})


@pytest.mark.parametrize("codec", CODECS)
def test_frame_fuzz_corruption_is_contained(codec):
    """Arbitrary byte corruption of a real frame stream either decodes
    cleanly or raises FrameError — never hangs, never escapes as another
    exception type.  Deterministic: seeded corruption positions."""
    rng = np.random.default_rng(1234)
    base = b"".join(frames.encode_frame(
        {"kind": "req", "rid": i,
         "q": frames.pack_array(rng.standard_normal(4).astype(np.float32))},
        codec) for i in range(4))
    for trial in range(200):
        blob = bytearray(base)
        for _ in range(rng.integers(1, 6)):
            pos = int(rng.integers(0, len(blob)))
            blob[pos] = int(rng.integers(0, 256))
        reader = frames.FrameReader(max_frame=1 << 20)
        try:
            out = reader.feed(bytes(blob))
            for f in out:               # decoded frames are well-formed
                assert isinstance(f, dict) and isinstance(f["kind"], str)
        except frames.FrameError:
            assert reader.pending() == 0    # poisoned reader cleared


def test_frame_oversize_encode_rejected():
    big = {"kind": "x", "data": b"\x00" * (2 * frames.MAX_FRAME)}
    with pytest.raises(frames.FrameError):
        frames.encode_frame(big, "json")


def test_unpack_array_validates_untrusted_input():
    good = frames.pack_array(np.arange(4, dtype=np.int64))
    np.testing.assert_array_equal(frames.unpack_array(good),
                                  np.arange(4, dtype=np.int64))
    for bad in [
        None, 42, "x",
        {"dtype": "object", "shape": [1], "data": b"x"},
        {"dtype": "float32", "shape": [], "data": b""},
        {"dtype": "float32", "shape": [-1], "data": b""},
        {"dtype": "float32", "shape": ["a"], "data": b""},
        {"dtype": "float32", "shape": [2], "data": b"\x00" * 7},
        {"dtype": "float32", "shape": [2], "data": "notbytes"},
        {"dtype": "float32", "shape": [1 << 30], "data": b""},
    ]:
        with pytest.raises(frames.FrameError):
            frames.unpack_array(bad)


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def test_lru_eviction_and_refresh():
    c = LruCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1              # refreshes "a"
    c.put("c", 3)                       # evicts "b", the LRU
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    s = c.stats()
    assert s["evictions"] == 1 and s["size"] == 2
    assert 0.0 < s["hit_rate"] < 1.0
    with pytest.raises(ValueError):
        LruCache(0)


def test_result_cache_exact_key_and_isolation():
    rc = ResultCache(8)
    q = np.arange(4, dtype=np.float32)
    dists, ids = np.zeros(3, np.float32), np.arange(3, dtype=np.int64)
    rc.put(q, 3, 8, dists, ids)
    ids[0] = 99                         # caller mutation must not leak in
    hit = rc.get(q, 3, 8)
    assert hit is not None and hit[1][0] == 0
    assert rc.get(q, 3, 9) is None      # n_probe is part of the key
    assert rc.get(q.astype(np.float64), 3, 8) is None   # dtype too
    q2 = q.copy()
    q2[0] += np.float32(1e-7)           # last-bit difference: different key
    assert rc.get(q2, 3, 8) is None


def test_route_memo():
    rm = RouteMemo(4)
    q = np.arange(3, dtype=np.float32)
    assert rm.get(q) is None
    rm.put(q, 2)
    assert rm.get(np.arange(3, dtype=np.float32)) == 2


# --------------------------------------------------------------------------
# clocks + time-handling (satellite: no scattered time.time())
# --------------------------------------------------------------------------

def test_manual_clock_is_monotonic():
    c = ManualClock(5.0)
    assert c.now() == 5.0
    c.advance(1.5)
    assert c.now() == 6.5
    c.set(7.0)
    with pytest.raises(ValueError):
        c.advance(-1.0)
    with pytest.raises(ValueError):
        c.set(6.0)


def test_system_clock_monotone():
    c = SystemClock()
    assert c.now() <= c.now()


def test_health_view_with_injected_clock():
    clock = ManualClock(0.0)
    hv = HealthView(1, hb_interval=0.1, clock=clock)
    hv.start()
    assert hv.status(0) == HEALTHY
    clock.advance(10.0)                 # miss_factor exceeded
    assert hv.status(0) == DOWN
    hv.beat(0)
    assert hv.status(0) == HEALTHY
    # explicit now still wins over the clock
    assert hv.status(0, now=clock.now() + 100.0) == DOWN
    with pytest.raises(ValueError):
        HealthView(1).status(0)         # no clock, no explicit now


def test_retry_policy_relative_vs_anchored():
    anchored = RetryPolicy(timeout_mult=2.0)
    relative = RetryPolicy(timeout_mult=2.0, relative=True)
    # anchored: base is the deadline (discrete-event tier semantics)
    assert anchored.timeout_at(1.0, 5.0, est=0.1) == pytest.approx(5.2)
    # relative: base is now (transport/TCP-RTO semantics)
    assert relative.timeout_at(1.0, 5.0, est=0.1) == pytest.approx(1.2)


# --------------------------------------------------------------------------
# request validation + zipf trace (satellites)
# --------------------------------------------------------------------------

def _req(q, **kw):
    kw.setdefault("rid", 0)
    kw.setdefault("k", 4)
    kw.setdefault("n_probe", 2)
    kw.setdefault("arrival", 0.0)
    kw.setdefault("deadline", 1.0)
    return Request(q=q, **kw)


def test_request_rejects_bad_embeddings():
    _req(np.arange(4, dtype=np.float32))            # fine
    for bad in [np.array([1.0, np.nan]), np.array([np.inf, 0.0]),
                np.zeros((2, 2), np.float32), np.array([], np.float32),
                np.array(["a", "b"])]:
        with pytest.raises(ValueError):
            _req(bad)


def test_zipf_trace_head_heavy_and_pool_level_k():
    rng = np.random.default_rng(0)
    pool = rng.standard_normal((32, 8)).astype(np.float32)
    trace = make_zipf_trace(rng, pool, 300, [10, 100], rate=100.0,
                            deadline=1.0, n_probe=4)
    assert len(trace) == 300
    assert [r.rid for r in trace] == list(range(300))
    # head-heavy: the most common query dominates
    counts = {}
    k_of = {}
    for r in trace:
        key = r.q.tobytes()
        counts[key] = counts.get(key, 0) + 1
        # exact-key cache regime: a repeated query repeats its k
        assert k_of.setdefault(key, r.k) == r.k
    assert max(counts.values()) >= 0.15 * len(trace)
    ids = zipf_query_ids(np.random.default_rng(1), 1000, 32)
    assert ids.min() >= 0 and ids.max() < 32
    # determinism
    ids2 = zipf_query_ids(np.random.default_rng(1), 1000, 32)
    np.testing.assert_array_equal(ids, ids2)


# --------------------------------------------------------------------------
# wire-fault schedule
# --------------------------------------------------------------------------

def test_wire_schedule_seeded_and_timing_independent():
    ws = flt.WireSchedule(seed=3, drop=0.2, dup=0.1, slow=0.3)
    a = [ws.decide(0, "up", s).kind for s in range(200)]
    # same (seed, worker, direction, seq) -> same decision, any order
    ws2 = flt.WireSchedule(seed=3, drop=0.2, dup=0.1, slow=0.3)
    b = [ws2.decide(0, "up", s).kind for s in reversed(range(200))]
    assert a == list(reversed(b))
    assert set(a) > {None}              # faults actually fire at these rates
    # different key dimensions decouple
    assert a != [ws.decide(1, "up", s).kind for s in range(200)]
    assert a != [ws.decide(0, "down", s).kind for s in range(200)]
    d = flt.WireSchedule(seed=0, slow=1.0, slow_base=0.002,
                         slow_jitter=0.004).decide(0, "up", 0)
    assert d.kind == flt.WIRE_SLOW and 0.002 <= d.delay <= 0.006


def test_wire_schedule_parse_and_validation():
    ws = flt.WireSchedule.parse("drop=0.02,slow=0.1,slow_ms=2:8,seed=7")
    assert ws.seed == 7
    assert ws.rates[flt.WIRE_DROP] == 0.02
    assert ws.rates[flt.WIRE_SLOW] == 0.1
    assert ws.slow_base == pytest.approx(0.002)
    assert ws.slow_jitter == pytest.approx(0.008)
    assert flt.WireSchedule.parse("dup=0.5").rates[flt.WIRE_DUP] == 0.5
    assert json.dumps(ws.to_dict())     # JSON-able
    with pytest.raises(ValueError):
        flt.WireSchedule(drop=1.5)
    with pytest.raises(ValueError):
        flt.WireSchedule(drop=0.6, dup=0.6)     # sum > 1
    with pytest.raises(ValueError):
        flt.WireSchedule.parse("bogus=1")
    assert not flt.WireSchedule()       # rate-free schedule is falsy


def test_wire_shim_consumes_one_decision_per_frame():
    shim = WireShim(flt.WireSchedule(seed=1, drop=0.5))
    kinds = [shim.decide(0, "up").kind for _ in range(50)]
    assert flt.WIRE_DROP in kinds
    assert shim.fault_counts().get("drop") == \
        sum(k == flt.WIRE_DROP for k in kinds)
    clean = WireShim(None)
    assert clean.decide(0, "up").kind is None


# --------------------------------------------------------------------------
# MasterCore via the loopback sim
# --------------------------------------------------------------------------

KS = (10, 100)
CEILINGS = k_ceilings(KS)
SUM_KEYS = ("requests", "completed", "shed", "failed", "rejected",
            "conserved")


def _exec_fn(q, k, n_probe):
    h = int(np.abs(np.asarray(q, dtype=np.float64)).sum() * 1e3) % 997
    ids = np.arange(k, dtype=np.int64) + h
    dists = np.float32(h % 7) + np.arange(k, dtype=np.float32) * 0.01
    return dists, ids


def _service_fn(bucket):
    return 0.001 + bucket.k * 1e-6


def _setup(n_req=120, *, cfg=None, wire=None, kill_at=None, record=False,
           trace_seed=0, rate=300.0, deadline=0.5):
    rng = np.random.default_rng(trace_seed)
    centroids = rng.standard_normal((16, 8)).astype(np.float32)
    pool = rng.standard_normal((24, 8)).astype(np.float32)
    trace = make_zipf_trace(rng, pool, n_req, KS, rate=rate,
                            deadline=deadline, n_probe=4)
    cfg = cfg or MasterConfig(n_workers=3, ceilings=CEILINGS)
    core = MasterCore(cfg, centroids)
    sim = LoopbackSim(core, _exec_fn, _service_fn, wire=wire,
                      kill_at=kill_at, record=record)
    return core, sim, trace, cfg, centroids


def test_core_clean_run_conserves_and_matches_direct():
    core, sim, trace, _, _ = _setup()
    outs = sim.run(trace)
    s = srv.summarize(outs)
    assert s["conserved"] and s["completed"] == len(trace)
    for o in outs:
        d, i = _exec_fn(o.request.q, o.request.k, o.request.n_probe)
        np.testing.assert_array_equal(o.ids, i)


def test_core_conserves_under_wire_faults_and_kill():
    wire = flt.WireSchedule(seed=11, drop=0.05, dup=0.03, slow=0.1,
                            truncate=0.02, disconnect=0.02)
    core, sim, trace, _, _ = _setup(wire=wire, kill_at={1: 0.05})
    outs = sim.run(trace)
    s = srv.summarize(outs)
    assert s["conserved"], s
    assert s["completed"] + s["shed"] + s["failed"] + s["rejected"] \
        == len(trace)
    assert sim.shim.fault_counts()      # the schedule actually fired
    # completions still match the direct call exactly, faults or not
    for o in outs:
        if o.completed:
            _, i = _exec_fn(o.request.q, o.request.k, o.request.n_probe)
            np.testing.assert_array_equal(o.ids, i)


def test_core_backpressure_rejects_when_bounded_queues_full():
    cfg = MasterConfig(n_workers=1, ceilings=CEILINGS, lane_depth=1,
                       max_pending=2)
    core, sim, trace, _, _ = _setup(n_req=60, cfg=cfg, rate=5000.0)
    outs = sim.run(trace)
    s = srv.summarize(outs)
    assert s["conserved"]
    assert s["rejected"] > 0
    assert core.stats["rejected_backpressure"] > 0
    # rejected outcomes carry no payload
    for o in outs:
        if o.status == srv.REJECTED:
            assert o.ids is None and o.dists is None
    # and the client was told to retry later via a RETRY_AFTER frame
    retry_frames = [f for _, f in sim.replies
                    if f["kind"] == frames.RETRY_AFTER]
    assert len(retry_frames) == s["rejected"]
    assert all(f["delay_s"] > 0 for f in retry_frames)


def test_core_drain_rejects_new_keeps_old():
    core, sim, trace, _, _ = _setup(n_req=40, rate=200.0)
    # inject a drain event halfway through the trace timeline
    t_mid = trace[len(trace) // 2].arrival
    sim._push(t_mid, "core", {"ev": "drain"})
    outs = sim.run(trace)
    s = srv.summarize(outs)
    assert s["conserved"]
    assert core.stats["rejected_draining"] > 0
    # everything admitted before the drain still completed
    for o in outs:
        if o.request.arrival < t_mid and o.status != srv.REJECTED:
            assert o.completed


def test_core_cache_identical_results_with_hits():
    core_off, sim_off, trace, cfg, centroids = _setup(n_req=150)
    outs_off = sim_off.run(trace)
    cfg_on = MasterConfig(n_workers=3, ceilings=CEILINGS, cache_size=64)
    core_on = MasterCore(cfg_on, centroids)
    sim_on = LoopbackSim(core_on, _exec_fn, _service_fn)
    outs_on = sim_on.run(trace)
    assert core_on.results.stats()["hit_rate"] > 0
    a = {o.request.rid: o for o in outs_off if o.completed}
    b = {o.request.rid: o for o in outs_on if o.completed}
    for rid in set(a) & set(b):
        np.testing.assert_array_equal(a[rid].ids, b[rid].ids)
        np.testing.assert_array_equal(a[rid].dists, b[rid].dists)


def test_core_malformed_request_typed_error_no_outcome():
    core, sim, trace, _, _ = _setup(n_req=5)
    t0 = trace[0].arrival
    # non-finite embedding arrives as a raw event (bypasses Request's own
    # constructor, like a real wire payload would)
    bad_q = np.array([np.nan] * 8, dtype=np.float32)
    sim._push(t0, "core", {"ev": "req", "conn": 0, "crid": 777, "q": bad_q,
                           "k": 10, "n_probe": 4, "deadline_s": 1.0})
    outs = sim.run(trace)
    assert core.stats["malformed"] == 1
    errs = [f for _, f in sim.replies if f["kind"] == frames.ERR
            and f["rid"] == 777]
    assert len(errs) == 1 and errs[0]["code"] == "bad_request"
    assert all(o.request.rid != 777 for o in outs)
    s = srv.summarize(outs)
    assert s["conserved"]


def test_core_corrupt_response_retries_then_succeeds():
    rng = np.random.default_rng(0)
    centroids = rng.standard_normal((8, 8)).astype(np.float32)
    cfg = MasterConfig(n_workers=1, ceilings=CEILINGS)
    core = MasterCore(cfg, centroids)
    core.start(0.0)
    core.handle({"ev": "up", "t": 0.0, "wid": 0})
    q = np.arange(8, dtype=np.float32)
    acts = core.handle({"ev": "req", "t": 0.0, "conn": 1, "crid": 5,
                        "q": q, "k": 10, "n_probe": 4, "deadline_s": 1.0})
    sends = [a for a in acts if a[0] == "send"]
    assert len(sends) == 1
    rid = sends[0][2]["rid"]
    dists, ids = _exec_fn(q, 10, 4)
    # corrupt: checksum does not match the payload
    acts = core.handle({"ev": "resp", "t": 0.01, "wid": 0, "rid": rid,
                        "dists": dists, "ids": ids, "checksum": 1})
    assert core.stats["corrupt_detected"] == 1
    retry_timers = [a for a in acts if a[0] == "timer"
                    and a[2]["ev"] == "retry"]
    assert len(retry_timers) == 1
    acts = core.handle({**retry_timers[0][2], "t": retry_timers[0][1]})
    sends = [a for a in acts if a[0] == "send"]
    assert len(sends) == 1
    good = flt.payload_checksum(dists, ids)
    acts = core.handle({"ev": "resp", "t": 0.05, "wid": 0, "rid": rid,
                        "dists": dists, "ids": ids, "checksum": good})
    replies = [a for a in acts if a[0] == "reply"]
    assert len(replies) == 1 and replies[0][2]["kind"] == frames.RESP
    out = core.outcomes[rid]
    assert out.completed and out.retries == 1


def test_core_short_payload_detected_as_corrupt():
    rng = np.random.default_rng(0)
    cfg = MasterConfig(n_workers=1, ceilings=CEILINGS, retry=RetryPolicy(
        relative=True, max_retries=0))
    core = MasterCore(cfg, rng.standard_normal((8, 8)).astype(np.float32))
    core.start(0.0)
    core.handle({"ev": "up", "t": 0.0, "wid": 0})
    q = np.arange(8, dtype=np.float32)
    acts = core.handle({"ev": "req", "t": 0.0, "conn": 1, "crid": 5,
                        "q": q, "k": 10, "n_probe": 4, "deadline_s": 1.0})
    rid = [a for a in acts if a[0] == "send"][0][2]["rid"]
    # truncated-but-parseable: 3 rows instead of 10, checksum consistent
    d3 = np.zeros(3, np.float32)
    i3 = np.arange(3, dtype=np.int64)
    acts = core.handle({"ev": "resp", "t": 0.01, "wid": 0, "rid": rid,
                        "dists": d3, "ids": i3,
                        "checksum": flt.payload_checksum(d3, i3)})
    assert core.stats["corrupt_detected"] == 1
    assert core.outcomes[rid].status == srv.FAILED   # max_retries=0


def test_core_requires_relative_retry_policy():
    with pytest.raises(ValueError):
        MasterConfig(n_workers=1, ceilings=CEILINGS,
                     retry=RetryPolicy(relative=False))


def test_sim_deterministic_and_replayable():
    wire_kw = dict(seed=5, drop=0.04, dup=0.02, slow=0.12, truncate=0.01,
                   disconnect=0.01)
    core1, sim1, trace, cfg, centroids = _setup(
        wire=flt.WireSchedule(**wire_kw), kill_at={2: 0.08}, record=True)
    outs1 = sim1.run(trace)
    core2, sim2, trace2, _, _ = _setup(
        wire=flt.WireSchedule(**wire_kw), kill_at={2: 0.08})
    outs2 = sim2.run(trace2)
    d1 = outcome_digest(outs1)
    assert d1 == outcome_digest(outs2)
    assert core1.assignments == core2.assignments
    assert core1.stats == core2.stats
    # record -> serialize -> load -> replay: byte-identical digest
    tr = Transcript.loads(sim1.transcript.dumps())
    res = replay_transcript(tr, cfg, centroids, _exec_fn)
    assert res.digest == d1
    assert res.checksum_mismatches == []
    assert res.core.stats == core1.stats


def test_replay_strict_catches_nondeterministic_engine():
    core, sim, trace, cfg, centroids = _setup(n_req=20, record=True)
    sim.run(trace)
    tr = Transcript.loads(sim.transcript.dumps())

    def drifted(q, k, n_probe):         # a different engine build
        d, i = _exec_fn(q, k, n_probe)
        return d, i + 1
    from repro.transport.replay import ReplayError
    with pytest.raises(ReplayError):
        replay_transcript(tr, cfg, centroids, drifted)
    res = replay_transcript(tr, cfg, centroids, drifted, strict=False)
    assert res.checksum_mismatches


def test_transcript_strips_payloads_but_keeps_facts():
    core, sim, trace, *_ = _setup(n_req=30, record=True)
    sim.run(trace)
    resps = [e for e in sim.transcript.entries if e.get("ev") == "resp"]
    assert resps
    for e in resps:
        assert "dists" not in e and "ids" not in e
        assert "checksum" in e and "n_ids" in e and "ck_ok" in e
