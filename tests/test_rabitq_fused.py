"""Bound-fused RaBitQ scan parity suite.

Three layers of agreement, per the fused-kernel contract:

  * kernel oracle      — ``ops.fused_rabitq_scan_batch`` on the Pallas
    backend (interpret mode on CPU) vs the pure-jnp mirror in kernels/ref.py:
    identical bucket ids / histograms / certified masks / miss counts, and
    allclose float lanes (the kernel's per-tile matmuls associate
    differently from the full-stream matmul).
  * searcher parity    — the fused batch searcher (ref AND pallas backends)
    vs the two-phase reference path (``fused=False``): identical top-k id
    sets for any inline gate (the band always covers the bound-straddle
    set), with the ref-backend variants sharing one float source so
    cold / warm / static runs stay bitwise comparable.
  * accounting         — ``n_second_pass`` is the MEASURED straggler count:
    it must equal the model formula re-derived from the kernel's own
    outputs (band ∩ ~certified), collapse to the whole band when the
    predictor is cold, vanish under a maximal prediction, and shrink as
    the predictor warms.

The sharded multidevice case (forced 8-host-device mesh, subprocess like
the other sharded suites) checks fused-vs-two-phase id parity and the
psum'd measured straggler counters on the distributed path.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buffer as rb
from repro.core import rerank
from repro.data import synthetic
from repro.index import ivf as ivf_mod, search
from repro.kernels import ops

N, D, NQ = 8000, 64, 6
K, N_PROBE = 200, 12
M_BUCKETS = 128
EPS0 = 3.0


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    x = synthetic.clustered(rng, N, D, n_centers=64)
    qs = synthetic.queries_from(rng, x, NQ)
    return jnp.asarray(x), jnp.asarray(qs)


@pytest.fixture(scope="module")
def rq_index(corpus):
    x, _ = corpus
    return search.build_rabitq_index(jax.random.key(0), x, 32, n_iter=4)


@pytest.fixture(scope="module")
def scan_inputs(rq_index, corpus):
    """Shared high-level inputs of the fused scan: routing, stream, sample
    codebook and the static inline gate — exactly what the searcher feeds
    the ops wrapper."""
    x, qs = corpus
    lay = ivf_mod.flat_layout(rq_index.ivf)
    stream = search.rabitq_stream(rq_index, lay)
    probed, lane_valid, d2 = search._routing(rq_index.ivf, lay, qs, N_PROBE)
    st = min(4, N_PROBE)
    sample_ub, sok = search._rabitq_sample_ub(
        stream.codes, stream.norm_o, stream.f_o, stream.cl,
        rq_index.ivf.centroids, rq_index.rq.rot, lay, probed, qs, d2, st,
        rq_index.ivf.cap, EPS0)
    cbs, tau_static = search._rabitq_sample_plan(sample_ub, K, K, st,
                                                 N_PROBE, M_BUCKETS)
    return lay, stream, lane_valid, d2, cbs, tau_static


def _scan(rq_index, qs, si, tau, backend):
    lay, stream, lane_valid, d2, cbs, _ = si
    return ops.fused_rabitq_scan_batch(
        stream.codes, stream.vectors, stream.norm_o, stream.f_o, stream.cl,
        rq_index.ivf.centroids, rq_index.rq.rot, qs, d2, lane_valid,
        cbs.d_min, cbs.delta, cbs.ew_map, M_BUCKETS, tau, eps0=EPS0,
        backend=backend)


# ---------------------------- kernel oracle ---------------------------------

def test_kernel_matches_ref_mirror(rq_index, corpus, scan_inputs):
    _, qs = corpus
    tau = scan_inputs[5]
    kp = _scan(rq_index, qs, scan_inputs, tau, "pallas")
    kr = _scan(rq_index, qs, scan_inputs, tau, "ref")
    names = ("est", "lb", "ub", "bucket_lb", "bucket_ub", "hist_lb",
             "hist_ub", "exact", "certified", "nmiss")
    for name, a, b in zip(names, kp, kr):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind in "ib":
            np.testing.assert_array_equal(a, b, err_msg=name)
            continue
        np.testing.assert_array_equal(np.isfinite(a), np.isfinite(b),
                                      err_msg=f"{name} inf pattern")
        fin = np.isfinite(a)
        np.testing.assert_allclose(a[fin], b[fin], rtol=2e-4, atol=2e-4,
                                   err_msg=name)


def test_kernel_certified_semantics(rq_index, corpus, scan_inputs):
    """certified == valid & (bucket_lb <= tau_inline); exact finite exactly
    on certified lanes; nmiss counts the uncovered valid lanes."""
    _, qs = corpus
    lay, stream, lane_valid, d2, cbs, tau = scan_inputs
    (_, _, _, bucket_lb, _, _, _, exact, certified,
     nmiss) = _scan(rq_index, qs, scan_inputs, tau, "ref")
    want = np.asarray(lane_valid & (bucket_lb <= tau[:, None]))
    np.testing.assert_array_equal(np.asarray(certified), want)
    np.testing.assert_array_equal(np.isfinite(np.asarray(exact)), want)
    np.testing.assert_array_equal(
        np.asarray(nmiss),
        np.sum(np.asarray(lane_valid) & ~want, axis=1).astype(np.int32))


def test_kernel_cold_gate_certifies_nothing(rq_index, corpus, scan_inputs):
    _, qs = corpus
    cold = jnp.full((NQ,), -1, jnp.int32)
    outs = _scan(rq_index, qs, scan_inputs, cold, "pallas")
    assert not bool(jnp.any(outs[8]))
    assert not bool(jnp.any(jnp.isfinite(outs[7])))


def test_single_query_wrapper_matches_singleton_batch(rq_index, corpus,
                                                      scan_inputs):
    """The single-query wrapper is the batched scan on a singleton batch
    (bitwise — same ops, same shapes).  A row of a LARGER batch is only
    allclose: the batched matmuls associate differently per batch width."""
    _, qs = corpus
    lay, stream, lane_valid, d2, cbs, tau = scan_inputs
    args = (stream.codes, stream.vectors, stream.norm_o, stream.f_o,
            stream.cl, rq_index.ivf.centroids, rq_index.rq.rot)
    batch1 = ops.fused_rabitq_scan_batch(
        *args, qs[:1], d2[:1], lane_valid[:1], cbs.d_min[:1],
        cbs.delta[:1], cbs.ew_map[:1], M_BUCKETS, tau[:1], eps0=EPS0,
        backend="ref")
    one = ops.fused_rabitq_scan(
        *args, qs[0], d2[0], lane_valid[0], cbs.d_min[0], cbs.delta[0],
        cbs.ew_map[0], M_BUCKETS, tau[0], eps0=EPS0, backend="ref")
    for a, b in zip(one, batch1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[0])


# ---------------------------- searcher parity -------------------------------

def _idsets_equal(ra, rb_):
    a, b = np.asarray(ra.ids), np.asarray(rb_.ids)
    for i in range(a.shape[0]):
        sa, sb = set(a[i].tolist()), set(b[i].tolist())
        assert sa == sb, (i, len(sa - sb), len(sb - sa))


def _dists_compatible(ra, rb_):
    """Sorted reported distances agree up to certain-in classification
    flips (est-reported vs exact-reported boundary lanes): exact match for
    almost every entry, tiny mean deviation overall."""
    da = np.sort(np.asarray(ra.dists), axis=1)
    db = np.sort(np.asarray(rb_.dists), axis=1)
    assert np.mean(np.abs(da - db)) < 1e-3
    assert np.max(np.abs(da - db)) < 1.0


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_fused_matches_two_phase(rq_index, corpus, backend):
    _, qs = corpus
    lay = ivf_mod.flat_layout(rq_index.ivf)
    if backend == "pallas":
        qs = qs[:4]
    rf = search.ivf_rabitq_search_batch(rq_index, qs, lay, k=K,
                                        n_probe=N_PROBE, use_bbc=True,
                                        fused=True, backend=backend)
    rt = search.ivf_rabitq_search_batch(rq_index, qs, lay, k=K,
                                        n_probe=N_PROBE, use_bbc=True,
                                        fused=False)
    _idsets_equal(rf, rt)
    _dists_compatible(rf, rt)
    # the fused static gate covers most of the band inline: the measured
    # second pass must be well below the band the two-phase path gathers
    assert int(jnp.sum(rf.n_second_pass)) < int(jnp.sum(rt.n_second_pass))


def test_fused_ref_variants_bitwise_stable(rq_index, corpus):
    """On the ref backend every variant (static / cold / maximal gate)
    draws band exact distances from one shared matmul, so reported rows
    are bitwise identical whenever the certain-in classification agrees —
    the property the strict id-set assertions of the predictive suite
    rely on."""
    _, qs = corpus
    lay = ivf_mod.flat_layout(rq_index.ivf)
    static = search.ivf_rabitq_search_batch(
        rq_index, qs, lay, k=K, n_probe=N_PROBE, use_bbc=True, fused=True)
    cold, _ = search.ivf_rabitq_search_batch(
        rq_index, qs, lay, k=K, n_probe=N_PROBE, use_bbc=True, fused=True,
        pred_state=rerank.predictor_init(M_BUCKETS))
    np.testing.assert_array_equal(np.asarray(static.ids),
                                  np.asarray(cold.ids))
    np.testing.assert_array_equal(np.asarray(static.dists),
                                  np.asarray(cold.dists))


def test_fused_engine_default(rq_index, corpus):
    """The engine serves the fused path by default with the build-time
    stream cache; pinning fused=False must reproduce the same id sets."""
    from repro.index import engine
    _, qs = corpus
    ef = engine.SearchEngine.build(rq_index, k=K, n_probe=N_PROBE)
    et = engine.SearchEngine.build(rq_index, k=K, n_probe=N_PROBE,
                                   fused=False)
    assert ef.stream_cache is not None
    rf, rt = ef.search(qs), et.search(qs)
    _idsets_equal(rf, rt)


# ---------------------------- accounting ------------------------------------

def test_measured_straggler_count_matches_model(rq_index, corpus):
    """Regression guard against wiring drift: the searcher's reported
    ``n_second_pass`` must equal the model formula (band ∩ ~certified)
    re-derived from the kernel's own outputs for the same gate."""
    x, qs = corpus
    lay = ivf_mod.flat_layout(rq_index.ivf)
    stream = search.rabitq_stream(rq_index, lay)
    state = rerank.predictor_init(M_BUCKETS)
    for _ in range(2):
        res, state = search.ivf_rabitq_search_batch(
            rq_index, qs, lay, k=K, n_probe=N_PROBE, use_bbc=True,
            fused=True, pred_state=state)
    # re-derive the warm gate and the band exactly as the searcher does
    probed, lane_valid, d2 = search._routing(rq_index.ivf, lay, qs, N_PROBE)
    st = min(4, N_PROBE)
    spos, sok = ivf_mod.tile_positions(lay, probed[:, :st], rq_index.ivf.cap)
    _, _, ub = search._rabitq_batch_bounds(rq_index, stream, qs, lane_valid,
                                           EPS0, d2=d2)
    sample_ub = jnp.where(sok, jnp.take_along_axis(ub, spos, axis=1),
                          jnp.inf)
    cbs, _ = search._rabitq_sample_plan(sample_ub, K, K, st, N_PROBE,
                                        M_BUCKETS)
    count_s = max(1, -(-K // search._PRED_HIST_STRIDE))
    # ``state`` above has absorbed the second batch's histogram; the warm
    # run we model used the state AFTER batch 1, so replay it
    s1 = rerank.predictor_init(M_BUCKETS)
    _, s1 = search.ivf_rabitq_search_batch(
        rq_index, qs, lay, k=K, n_probe=N_PROBE, use_bbc=True, fused=True,
        pred_state=s1)
    tau_pred = jnp.full(
        (NQ,), rerank.predict_tau(s1, count_s,
                                  margin=search._PRED_GATE_MARGIN),
        jnp.int32)
    outs = ops.fused_rabitq_scan_batch(
        stream.codes, stream.vectors, stream.norm_o, stream.f_o, stream.cl,
        rq_index.ivf.centroids, rq_index.rq.rot, qs, d2, lane_valid,
        cbs.d_min, cbs.delta, cbs.ew_map, M_BUCKETS, tau_pred, eps0=EPS0,
        backend="ref")
    _, _, _, bucket_lb, bucket_ub, _, _, _, certified, _ = outs
    taus = search._tau_bucket_search(
        jnp.concatenate([bucket_ub, bucket_lb], axis=0),
        jnp.concatenate([lane_valid, lane_valid], axis=0), K, M_BUCKETS)
    tau_ub, tau_lb = taus[:NQ], taus[NQ:]
    certain_in = lane_valid & (bucket_ub < tau_lb[:, None])
    band = lane_valid & (bucket_lb <= tau_ub[:, None]) & ~certain_in
    modeled = jnp.sum(band & ~certified, axis=1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(res.n_second_pass),
                                  np.asarray(modeled))
    np.testing.assert_array_equal(
        np.asarray(res.n_reranked),
        np.asarray(jnp.sum(band, axis=1).astype(jnp.int32)))


def test_tau_bucket_search_equals_threshold_bucket():
    rng = np.random.default_rng(5)
    m = 32
    bucket = jnp.asarray(rng.integers(0, m + 1, (3, 500)), jnp.int32)
    valid = jnp.asarray(rng.random((3, 500)) < 0.8)
    for count in (1, 40, 200, 450):
        got = search._tau_bucket_search(bucket, valid, count, m)
        want = [rb.threshold_bucket(rb.histogram(bucket[i], m, valid[i]),
                                    count)[0] for i in range(3)]
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.stack(want)))


# ---------------------------- sharded (multidevice) -------------------------

SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import rerank
    from repro.data import synthetic
    from repro.index import engine, search

    rng = np.random.default_rng(0)
    n, d, C = 12000, 32, 48
    k, n_probe, B = 500, 24, 8
    x = jnp.asarray(synthetic.clustered(rng, n, d, n_centers=48))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), B))
    mesh = jax.make_mesh((8,), ("model",))
    rq = search.build_rabitq_index(jax.random.key(0), x, C)

    def idsets_equal(ra, rb, name):
        for b in range(B):
            sa = set(np.asarray(ra.ids[b]).tolist()) - {-1}
            sb = set(np.asarray(rb.ids[b]).tolist()) - {-1}
            assert sa == sb, (name, b, len(sa - sb), len(sb - sa))
        print(name, "OK", flush=True)

    ef = engine.SearchEngine.build(rq, k=k, n_probe=n_probe, mesh=mesh)
    et = engine.SearchEngine.build(rq, k=k, n_probe=n_probe, mesh=mesh,
                                   fused=False)
    rf, rt = ef.search(qs), et.search(qs)
    idsets_equal(rf, rt, "sharded_fused_vs_two_phase")
    # the fused static gate certifies most survivors on-shard: the
    # measured straggler-survivor collective volume is well below the
    # full survivor count the two-phase path gathers
    assert int(jnp.sum(rf.n_second_pass)) < int(jnp.sum(rf.n_reranked)), (
        np.asarray(rf.n_second_pass), np.asarray(rf.n_reranked))
    assert int(jnp.sum(rt.n_second_pass)) == 0

    # predictive: cold gate certifies nothing (every survivor is a
    # straggler), the warm gate shrinks the measured second pass, and id
    # sets never move
    state = ef.predictor_init()
    cold, state = ef.search(qs, pred_state=state)
    idsets_equal(rf, cold, "sharded_pred_cold_vs_static")
    np.testing.assert_array_equal(np.asarray(cold.n_second_pass),
                                  np.asarray(cold.n_reranked))
    warm, state = ef.search(qs, pred_state=state)
    idsets_equal(rf, warm, "sharded_pred_warm_vs_static")
    assert int(jnp.sum(warm.n_second_pass)) < int(jnp.sum(cold.n_second_pass))

    # batched engine agreement (same index, single-device deployment)
    eb = engine.SearchEngine.build(rq, k=k, n_probe=n_probe)
    rb_ = eb.search(qs)
    for b in range(B):
        sa = set(np.asarray(rb_.ids[b]).tolist()) - {-1}
        sb = set(np.asarray(rf.ids[b]).tolist()) - {-1}
        overlap = len(sa & sb) / max(len(sa), 1)
        assert overlap >= 0.99, (b, overlap)
    print("RABITQ_FUSED_SHARDED_OK")
    """
)


@pytest.mark.multidevice
def test_sharded_fused_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "RABITQ_FUSED_SHARDED_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])
