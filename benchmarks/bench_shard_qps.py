"""Mesh-sharded engine QPS: distributed BBC collector vs naive top-k
all-gather, on a forced 8-host-device ("model",) mesh.

The BBC collective moves (m+1)*4 bytes of histogram per query (psum) plus a
budgeted survivor gather; the naive distributed top-k all-gathers k (dist,
id) pairs per shard per query.  ``collective_cost_model`` prices both for
the roofline table; the measured QPS compares the two collectors end-to-end
through ``SearchEngine(mesh=...)`` (same index, same routing, same scan —
the collector is the only difference).

CPU-container caveat: the 8 "devices" here are host threads on one CPU, so
absolute QPS understates a real pod and the interconnect term is emulated
shared-memory copies — the wire-byte ratio from the cost model is the
hardware-independent claim; QPS shows both paths run end-to-end and the BBC
path is not paying for its smaller payload with serving throughput.

Writes ``BENCH_shard_qps.json`` (override with REPRO_BENCH_OUT).
"""
from __future__ import annotations

import os

N_SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", 8))
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={N_SHARDS}")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import distributed as dist
from repro.data import synthetic
from repro.index import engine

B = int(os.environ.get("REPRO_BENCH_B", 32))
K = int(os.environ.get("REPRO_BENCH_K", 5000))
N_PROBE = int(os.environ.get("REPRO_BENCH_NPROBE", 64))
M = 128
COST_MODEL_KS = (1000, 5000, 20000, 100000)


def _time_batch(fn, qs, repeats: int = 3):
    """(median wall seconds, last result) post-compile."""
    r = fn(qs)
    jax.block_until_ready(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(qs)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def run(b: int = B, k: int = K, n_probe: int = N_PROBE):
    mesh = jax.make_mesh((N_SHARDS,), ("model",))
    x, _ = common.corpus()
    rng = np.random.default_rng(7)
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), b))
    # The re-rank pool (and hence the survivor budget, ~pool/S * slack) is
    # sized from k exactly like the single-device engine default: a pool of
    # only 2k previously starved the BBC collector against the naive
    # baseline's implicit S*k pool at k=5000/8 shards
    # (topk_overlap_bbc_vs_naive = 0.8459) — the acceptance gate below
    # keeps the budget honest.
    n_cand = min(8 * k, common.N)

    pq_index = common.pq_index()
    rq_index = common.rq_index()
    indexes = {
        "ivf": (pq_index.ivf, dict(vectors=x)),
        "ivfpq": (pq_index, dict(n_cand=n_cand)),
        "ivfrabitq": (rq_index, {}),
    }
    method_budgets = {
        "ivf": dist.survivor_budget(k, N_SHARDS),
        "ivfpq": dist.survivor_budget(n_cand, N_SHARDS),
        "ivfrabitq": dist.survivor_budget(k, N_SHARDS, slack=4.0),
    }

    results = []
    for method, (index, extra) in indexes.items():
        row = {"method": method, "B": b, "k": k, "n_probe": n_probe,
               "n_shards": N_SHARDS}
        ids = {}
        for collector, use_bbc in (("bbc", True), ("naive", False)):
            # the recorded budget is the executed one: passed explicitly,
            # not re-derived, so the JSON cannot drift from the engine's
            # internal defaults
            eng = engine.SearchEngine.build(
                index, k=k, n_probe=n_probe, use_bbc=use_bbc, mesh=mesh,
                shard_budget=method_budgets[method], **extra)
            t, r = _time_batch(eng.search, qs)
            ids[collector] = np.asarray(r.ids)
            row[f"qps_{collector}"] = round(b / t, 2)
            row[f"ms_per_batch_{collector}"] = round(1e3 * t, 2)
            common.emit(
                f"shard_qps/{method}/{collector}/S{N_SHARDS}/B{b}/k{k}",
                t / b * 1e6, f"qps={b / t:.2f}")
        # collector-overlap acceptance signal: the BBC pool must produce
        # (nearly) the same top-k as the naive all-gather collector — a
        # low overlap means the pool/budget is starving the collector,
        # not a legitimate speed/accuracy trade
        row["survivor_budget"] = method_budgets[method]
        row["topk_overlap_bbc_vs_naive"] = round(float(np.mean([
            len(set(ids["bbc"][i].tolist()) & set(ids["naive"][i].tolist()))
            / k for i in range(b)])), 4)
        results.append(row)

    cost_model = []
    for ck in COST_MODEL_KS:
        cm = dist.collective_cost_model(k=ck, m=M, n_shards=N_SHARDS)
        cm["k"] = ck
        cost_model.append(cm)

    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_shard_qps.json")
    at_k = next(c for c in cost_model if c["k"] >= k)
    min_overlap = min(r["topk_overlap_bbc_vs_naive"] for r in results)
    payload = {
        "bench": "shard_qps",
        "corpus": {"n": common.N, "d": common.D},
        "config": {"B": b, "k": k, "n_probe": n_probe, "n_cand": n_cand,
                   "m": M, "n_shards": N_SHARDS,
                   "method_budgets": method_budgets},
        "platform": jax.devices()[0].platform,
        "results": results,
        "collective_cost_model": cost_model,
        "acceptance": {
            "claim": "BBC histogram collective moves fewer bytes per link "
                     "than naive distributed top-k at k >= 5000, at >= 0.95 "
                     "top-k overlap with the naive collector per method",
            "bbc_bytes_per_link_at_k": at_k["bbc_bytes_per_link"],
            "naive_bytes_per_link_at_k": at_k["naive_bytes_per_link"],
            "min_topk_overlap": min_overlap,
            "overlap_target": 0.95,
            "pass": all(c["bbc_bytes_per_link"] < c["naive_bytes_per_link"]
                        for c in cost_model if c["k"] >= 5000)
            and min_overlap >= 0.95,
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return payload


if __name__ == "__main__":
    run()
