"""Mesh-sharded engine QPS: distributed BBC collector vs naive top-k
all-gather, on a forced 8-host-device ("model",) mesh.

The BBC collective moves (m+1)*4 bytes of histogram per query (psum) plus a
budgeted survivor gather; the naive distributed top-k all-gathers k (dist,
id) pairs per shard per query.  ``collective_cost_model`` prices both for
the roofline table; the measured QPS compares the two collectors end-to-end
through ``SearchEngine(mesh=...)`` (same index, same routing, same scan —
the collector is the only difference).  Since the fused
shard-scan->histogram->compaction pipeline (kernels/shard_collect.py +
the speculative three-tier survivor selection) the BBC path must WIN this
measured comparison for every method at every k row — that is the
acceptance gate, not just the modeled wire bytes.

Rows run at k=5000 and the large-k extreme (k=100000, clamped to the
corpus size when it exceeds it — at the default 60k corpus the second row
exercises the k ~= N regime where the collector dominates end-to-end
cost).  Each k also records a per-stage breakdown at the executed
per-shard shapes (scan / collect / legacy compaction / collective /
re-rank / final-select) and a depth-1 pipelined QPS measurement — the
double-buffered host loop (dispatch batch j+1 while batch j runs) the
serving tier uses (``Server(overlap=True)``).

CPU-container caveat: the 8 "devices" here are host threads on one CPU, so
absolute QPS understates a real pod and the interconnect term is emulated
shared-memory copies — the wire-byte ratio from the cost model is the
hardware-independent claim; measured QPS shows the BBC path no longer pays
for its smaller payload with serving throughput.

Writes ``BENCH_shard_qps.json`` (override with REPRO_BENCH_OUT).
"""
from __future__ import annotations

import os

N_SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", 8))
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={N_SHARDS}")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks import common
from repro.core import buffer as rb
from repro.core import distributed as dist
from repro.data import synthetic
from repro.index import engine
from repro.kernels import ops
from repro.tuning import knobs as tn_knobs
from repro.tuning import points as tn_points

B = int(os.environ.get("REPRO_BENCH_B", 32))
KS = tuple(int(s) for s in
           os.environ.get("REPRO_BENCH_KS", "5000,100000").split(","))
N_PROBE = int(os.environ.get("REPRO_BENCH_NPROBE", 64))
M = 128
COST_MODEL_KS = (1000, 5000, 20000, 100000)
PIPE_DEPTH = 4   # batches in flight for the pipelined-QPS measurement


def _time_batch(fn, qs, repeats: int = 5):
    """(min wall seconds over ``repeats``, last result) post-compile.

    Min, not median: on the single-core emulated mesh every shard's compute
    serializes onto one CPU, so any stray host activity inflates a repeat
    by whole scheduler quanta.  The minimum is the reproducible compute
    floor; medians of 3 flipped ~5%-margin comparisons run to run."""
    r = fn(qs)
    jax.block_until_ready(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(qs)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)), r


def _time_pipelined(fn, qs, depth: int = PIPE_DEPTH, repeats: int = 5):
    """Min wall seconds per batch with a depth-1 double buffer: dispatch
    batch j+1 while batch j still occupies the executor (jax dispatch is
    async), block on each result one step late — the serving loop's
    ``Server(overlap=True)`` pattern as a raw engine measurement."""
    jax.block_until_ready(fn(qs))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        prev = None
        for _j in range(depth):
            r = fn(qs)
            if prev is not None:
                jax.block_until_ready(prev)
            prev = r
        jax.block_until_ready(prev)
        ts.append((time.perf_counter() - t0) / depth)
    return float(np.min(ts))


def _overlap(ids_a: np.ndarray, ids_b: np.ndarray) -> float:
    """Mean per-query id-set overlap, normalized by the NAIVE collector's
    returned set size (-1 pad lanes dropped) — at k ~= N both collectors
    legitimately return fewer than k ids (only probed lanes exist), so
    dividing by k would punish the regime instead of the collector."""
    fr = []
    for i in range(ids_a.shape[0]):
        sa = set(ids_a[i].tolist()) - {-1}
        sb = set(ids_b[i].tolist()) - {-1}
        fr.append(len(sa & sb) / max(len(sb), 1))
    return float(np.mean(fr))


# -------------------------------------------------------------------------
# Per-stage breakdown at the executed per-shard shapes
# -------------------------------------------------------------------------

def _median_ms(fn, *args, repeats: int = 3) -> float:
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return round(1e3 * float(np.median(ts)), 3)


def _stage_breakdown(mesh, b: int, k: int, shard_flat: int, bud: int,
                     d: int, m: int = M) -> dict:
    """Isolated per-stage costs at this row's per-shard shapes: one shard's
    scan and collect, the legacy full-stream top_k compaction it replaced,
    the psum+gather collective on the emulated mesh, the budget-width
    re-rank, and the replicated final selection over the gathered pool."""
    rng = np.random.default_rng(3)
    vecs = jnp.asarray(rng.standard_normal((shard_flat, d)), jnp.float32)
    qs = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    valid = jnp.asarray(rng.random((b, shard_flat)) < 0.3)
    dists = jnp.where(
        valid, jnp.asarray(rng.random((b, shard_flat)) * 9 + 1, jnp.float32),
        jnp.inf)
    k_cb = max(8, min(shard_flat // 2, 4096))
    cbs = jax.vmap(lambda s: rb.build_codebook(s, k=k_cb, m=m))(dists)
    tau_spec = jnp.full((b,), m // 2, jnp.int32)
    pos = jnp.asarray(rng.integers(0, shard_flat, (b, bud)), jnp.int32)
    hist = jnp.asarray(rng.integers(0, 50, (b, m + 1)), jnp.int32)
    surv = jnp.asarray(rng.standard_normal((b, bud)), jnp.float32)
    w = N_SHARDS * bud
    pool = jnp.asarray(rng.standard_normal((b, w)), jnp.float32)

    scan = jax.jit(lambda v, q: ops.l2_exact_batch(v, q))

    def collect():
        return ops.shard_collect_batch(dists, valid, cbs.d_min, cbs.delta,
                                       cbs.ew_map, m, tau_spec, bud)

    legacy = jax.jit(lambda x: jax.lax.top_k(-x, min(bud, shard_flat)))

    def _coll_body(h, s):
        gh = dist.hier_psum(h[0], "model")
        (g,) = dist.gather_survivors("model", s[0])
        return gh, g

    coll = jax.jit(dist.shard_map(
        _coll_body, mesh,
        in_specs=(P("model", None, None), P("model", None, None)),
        out_specs=(P(), P())))
    h_sh = jnp.broadcast_to(hist, (N_SHARDS, b, m + 1))
    s_sh = jnp.broadcast_to(surv, (N_SHARDS, b, bud))

    def _rerank(p, q):
        g = vecs[p]
        return jnp.sum((g - q[:, None, :]) ** 2, axis=-1)

    rerank = jax.jit(_rerank)
    final = jax.jit(lambda x: jax.lax.top_k(-x, min(k, w)))

    return {
        "shard_flat": shard_flat, "budget": bud, "B": b,
        "scan_ms": _median_ms(scan, vecs, qs),
        "collect_ms": _median_ms(collect),
        "legacy_compact_topk_ms": _median_ms(legacy, dists),
        "collective_ms": _median_ms(coll, h_sh, s_sh),
        "rerank_ms": _median_ms(rerank, pos, qs),
        "final_select_ms": _median_ms(final, pool),
    }


def _resolve_cell(store, fp, method: str, k: int):
    """(point, provenance) for this bench's (method, k) cell — tuned only
    on an EXACT corpus-fingerprint match (a pool/budget tuned on another
    corpus is a prior, not a contract the overlap gate should ride on);
    anything else is the documented hand-tuned fallback."""
    point, provenance = store.resolve(method, k, corpus_fp=fp)
    if point is None or provenance != "tuned":
        return None, tn_points.HAND_TUNED
    return point, f"{point.name} (tuned)"


def run(b: int = B, ks=KS, n_probe: int = N_PROBE):
    mesh = jax.make_mesh((N_SHARDS,), ("model",))
    x, _ = common.corpus()
    rng = np.random.default_rng(7)
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), b))
    store = tn_points.PointStore.load()
    corpus_fp = tn_points.corpus_fingerprint(np.asarray(x))

    pq_index = common.pq_index()
    rq_index = common.rq_index()
    indexes = {
        "ivf": (pq_index.ivf, dict(vectors=x)),
        "ivfpq": (pq_index, {}),
        "ivfrabitq": (rq_index, {}),
    }

    results, breakdowns = [], []
    shard_flat = None
    for k_req in ks:
        # clamp to the corpus: k rows beyond N would select everything
        # anyway, and top_k needs k <= pool width.  k == N is the honest
        # large-k extreme this corpus supports.
        k = min(k_req, common.N)
        # Pools and survivor budgets resolve through the constrained tuner's
        # operating points (tuning/: slack constants documented per method,
        # budget <= stream clamp applied in knobs.shard_budget).  The
        # hand-tuned fallback keeps the pre-tuner sizing: an n_cand pool of
        # 4k (2k starved the collector at k=5000/8 shards — overlap 0.8459
        # — and 8k overshoots the probed mass, going cut-vacuous), slacks
        # {ivf: 2.0, ivfpq: 1.25, ivfrabitq: 4.0} over the balanced share.
        # The overlap gate below catches any sizing that actually starves
        # the collector, tuned or hand-picked.
        method_pools, method_budgets, method_points = {}, {}, {}
        for method in indexes:
            point, provenance = _resolve_cell(store, corpus_fp, method, k)
            n_cand = None
            slack = None
            if method == "ivfpq":
                n_cand = min(4 * k, common.N)
                if point is not None and point.knobs.n_cand is not None:
                    n_cand = max(k, min(point.knobs.n_cand, common.N))
            if point is not None:
                slack = point.knobs.budget_slack
            method_pools[method] = n_cand
            method_budgets[method] = tn_knobs.shard_budget(
                method, k, n_cand, N_SHARDS, slack=slack)
            method_points[method] = provenance
        for method, (index, extra) in indexes.items():
            n_cand = method_pools[method]
            row = {"method": method, "B": b, "k": k, "k_requested": k_req,
                   "n_probe": n_probe, "n_shards": N_SHARDS,
                   "operating_point": method_points[method]}
            ids = {}
            for collector, use_bbc in (("bbc", True), ("naive", False)):
                # the recorded budget is the executed one: passed
                # explicitly, not re-derived, so the JSON cannot drift from
                # the engine's internal defaults
                kw = dict(extra)
                if method == "ivfpq":
                    kw["n_cand"] = n_cand
                eng = engine.SearchEngine.build(
                    index, k=k, n_probe=n_probe, use_bbc=use_bbc, mesh=mesh,
                    shard_budget=method_budgets[method], **kw)
                shard_flat = eng.shard_streams[-1].shape[1]
                t, r = _time_batch(eng.search, qs)
                ids[collector] = np.asarray(r.ids)
                row[f"qps_{collector}"] = round(b / t, 2)
                row[f"ms_per_batch_{collector}"] = round(1e3 * t, 2)
                if use_bbc:
                    row["qps_bbc_pipelined"] = round(
                        b / _time_pipelined(eng.search, qs), 2)
                common.emit(
                    f"shard_qps/{method}/{collector}/S{N_SHARDS}/B{b}/k{k}",
                    t / b * 1e6, f"qps={b / t:.2f}")
            # collector-overlap acceptance signal: the BBC pool must
            # produce (nearly) the same top-k as the naive all-gather
            # collector — a low overlap means the pool/budget is starving
            # the collector, not a legitimate speed/accuracy trade
            row["survivor_budget"] = method_budgets[method]
            row["topk_overlap_bbc_vs_naive"] = round(
                _overlap(ids["bbc"], ids["naive"]), 4)
            row["qps_win"] = bool(row["qps_bbc"] >= row["qps_naive"])
            results.append(row)
        bud_iv = max(8, min(method_budgets["ivf"], shard_flat))
        bd = _stage_breakdown(mesh, b, k, shard_flat, bud_iv, common.D)
        bd["k"] = k
        breakdowns.append(bd)

    cost_model = []
    for ck in COST_MODEL_KS:
        cm = dist.collective_cost_model(k=ck, m=M, n_shards=N_SHARDS,
                                        n_hosts=2)
        cm["k"] = ck
        cost_model.append(cm)

    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_shard_qps.json")
    min_overlap = min(r["topk_overlap_bbc_vs_naive"] for r in results)
    qps_all_win = all(r["qps_win"] for r in results)
    payload = {
        "bench": "shard_qps",
        "corpus": {"n": common.N, "d": common.D},
        "config": {"B": b, "ks": list(ks), "n_probe": n_probe, "m": M,
                   "n_shards": N_SHARDS, "pipeline_depth": PIPE_DEPTH},
        "platform": jax.devices()[0].platform,
        "results": results,
        "stage_breakdown": breakdowns,
        "collective_cost_model": cost_model,
        "acceptance": {
            "claim": "sharded BBC beats the naive distributed top-k on "
                     "MEASURED QPS for every method at every k row (fused "
                     "scan->histogram->compaction pipeline), at >= 0.95 "
                     "top-k overlap with the naive collector, and moves "
                     "fewer modeled bytes per link at k >= 5000",
            "qps_all_win": qps_all_win,
            "min_topk_overlap": min_overlap,
            "overlap_target": 0.95,
            "pass": qps_all_win and min_overlap >= 0.95 and all(
                c["bbc_bytes_per_link"] < c["naive_bytes_per_link"]
                for c in cost_model if c["k"] >= 5000),
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return payload


if __name__ == "__main__":
    payload = run()
    acc = payload["acceptance"]
    # REPRO_SHARD_STRICT=1 gates the collector-correctness half (top-k
    # overlap + modeled bytes) at ANY size; REPRO_SHARD_STRICT_QPS=1
    # additionally gates the measured-QPS win — meaningful only at sizes
    # where the per-query work dwarfs the BBC path's fixed overheads
    # (codebook build, sample threshold), i.e. the CI smoke sizes and up.
    bytes_ok = all(c["bbc_bytes_per_link"] < c["naive_bytes_per_link"]
                   for c in payload["collective_cost_model"] if c["k"] >= 5000)
    if os.environ.get("REPRO_SHARD_STRICT") == "1" \
            and (acc["min_topk_overlap"] < acc["overlap_target"]
                 or not bytes_ok):
        raise SystemExit(f"bench_shard_qps overlap/bytes gate failed: "
                         f"{json.dumps(acc, indent=2)}")
    if os.environ.get("REPRO_SHARD_STRICT_QPS") == "1" \
            and not acc["qps_all_win"]:
        rows = [(r["method"], r["k"], r["qps_bbc"], r["qps_naive"])
                for r in payload["results"] if not r["qps_win"]]
        raise SystemExit(f"bench_shard_qps QPS gate regressed "
                         f"(method, k, qps_bbc, qps_naive): {rows}")
