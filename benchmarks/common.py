"""Shared benchmark scaffolding: corpora, indexes, timing, CSV rows.

Scale note (DESIGN.md §6): the paper benches 10-100M-vector corpora on a
24-core AVX2 CPU; this container is a single CPU core with TPU as the target,
so corpora are 10^4-10^5 vectors and we validate the paper's RELATIVE claims
(orderings, scalings, counts) plus the structural quantities that determine
TPU cost.  Sizes are overridable via REPRO_BENCH_N / REPRO_BENCH_Q.
"""
from __future__ import annotations

import functools
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.index import flat, search

N = int(os.environ.get("REPRO_BENCH_N", 60_000))
D = int(os.environ.get("REPRO_BENCH_D", 128))
NQ = int(os.environ.get("REPRO_BENCH_Q", 5))
N_CLUSTERS = max(int(np.sqrt(N)), 16)

CORPUS_KINDS = ("clustered", "manifold", "isotropic")


def _corpus_kind() -> str:
    """Corpus generator selection: ``--corpus KIND`` on any bench's argv
    (scanned here so every suite gets the flag without its own argparse),
    else REPRO_BENCH_CORPUS, else the Gaussian-mixture default."""
    argv = sys.argv
    kind = os.environ.get("REPRO_BENCH_CORPUS", "clustered")
    for i, a in enumerate(argv):
        if a == "--corpus" and i + 1 < len(argv):
            kind = argv[i + 1]
        elif a.startswith("--corpus="):
            kind = a.split("=", 1)[1]
    if kind not in CORPUS_KINDS:
        raise SystemExit(f"--corpus must be one of {CORPUS_KINDS}, "
                         f"got {kind!r}")
    return kind


CORPUS = _corpus_kind()


def make_corpus(rng: np.random.Generator, n: int, d: int,
                kind: str | None = None,
                n_centers: int | None = None) -> np.ndarray:
    """Build a synthetic corpus of the requested kind (see data/synthetic)."""
    kind = kind or CORPUS
    n_centers = n_centers or max(n // 200, 32)
    if kind == "clustered":
        return synthetic.clustered(rng, n, d, n_centers=n_centers)
    if kind == "manifold":
        return synthetic.manifold(rng, n, d, n_centers=n_centers)
    if kind == "isotropic":
        return synthetic.isotropic(rng, n, d)
    raise ValueError(f"unknown corpus kind {kind!r}")

_ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def rows() -> list[str]:
    return list(_ROWS)


@functools.lru_cache(maxsize=1)
def corpus():
    rng = np.random.default_rng(42)
    x = make_corpus(rng, N, D)
    qs = synthetic.queries_from(rng, x, NQ)
    return jnp.asarray(x), jnp.asarray(qs)


@functools.lru_cache(maxsize=1)
def pq_index():
    x, _ = corpus()
    return search.build_pq_index(jax.random.key(0), x, N_CLUSTERS, n_iter=6)


@functools.lru_cache(maxsize=1)
def rq_index():
    x, _ = corpus()
    return search.build_rabitq_index(jax.random.key(0), x, N_CLUSTERS, n_iter=6)


@functools.lru_cache(maxsize=16)
def engine_for(kind: str, k: int, n_probe: int, n_cand: int | None = None,
               use_bbc: bool = True, pred_count: int | None = None):
    """Serving engine over the cached benchmark indexes — the same
    ``engine.SearchEngine`` entry point launch/serve.py drives, so suites
    that time "a method" time the production path (one engine per (kind,
    hyper-parameter) tuple, cached: the layout packing is one-time work)."""
    from repro.index import engine
    if kind == "ivfpq":
        return engine.SearchEngine.build(
            pq_index(), k=k, n_probe=n_probe, n_cand=n_cand,
            use_bbc=use_bbc, pred_count=pred_count)
    if kind == "ivfrabitq":
        return engine.SearchEngine.build(
            rq_index(), k=k, n_probe=n_probe, use_bbc=use_bbc,
            pred_count=pred_count)
    if kind == "ivf":
        x, _ = corpus()
        return engine.SearchEngine.build(
            pq_index().ivf, k=k, n_probe=n_probe, use_bbc=use_bbc,
            vectors=x, pred_count=pred_count)
    raise ValueError(kind)


@functools.lru_cache(maxsize=8)
def ground_truth(k: int):
    x, qs = corpus()
    ds, ids = [], []
    for q in qs:
        d, i = flat.search(x, q, k)
        ds.append(np.asarray(d))
        ids.append(np.asarray(i))
    return np.stack(ds), np.stack(ids)


def recall(got_ids: np.ndarray, want_ids: np.ndarray) -> float:
    return len(set(got_ids.tolist()) & set(want_ids.tolist())) / len(want_ids)


def timeit(fn, *args, repeats: int = 3) -> float:
    """Median wall seconds per call (post-compile)."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
