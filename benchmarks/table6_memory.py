"""Table 5/6 analogue: BBC auxiliary state (histogram + codebook + survivor
budget) vs k and m — negligible next to index size — plus the distributed
collective-payload comparison (the TPU cache-miss analogue)."""
from __future__ import annotations

from benchmarks import common
from repro.core import collector as col
from repro.core import distributed as dist


def run(ks=(5000, 100_000), ms=(64, 128, 512)):
    n = common.N
    for m in ms:
        for k in ks:
            s = col.collector_stats("bbc", k, m, n, 512)
            aux = (4 * (m + 1)            # histogram
                   + 4 * (m + 1)         # edges
                   + 4 * 256             # ew map
                   + 8 * s["final_selection_width"])
            common.emit(f"table6/bbc_aux/m{m}/k{k}", 0.0,
                        f"aux_bytes={aux};vs_heap_bytes={8*k}")
    for k in ks:
        cm = dist.collective_cost_model(k=k, m=128, n_shards=16)
        common.emit(
            f"table6/collective/k{k}", 0.0,
            f"bbc_link_bytes={int(cm['bbc_bytes_per_link'])};"
            f"naive_link_bytes={int(cm['naive_bytes_per_link'])};"
            f"ratio={cm['ratio']:.1f}x")
    return None


if __name__ == "__main__":
    run()
