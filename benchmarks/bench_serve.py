"""Async micro-batching serving vs the static batch-1 loop at matched load.

Acceptance benchmark for the serving subsystem (``repro.serving``): the same
seeded open-loop arrival trace (Poisson, heterogeneous k) is served two
ways —

* **static** — the ``--mode static --batch 1`` baseline: requests are
  executed one per engine call in arrival order on the same shape-bucketed
  engines (single-query jit path), the clock advancing by each call's
  measured wall time.  Throughput saturates at 1/service and the queue
  grows whenever the offered rate exceeds it.
* **dynamic** — the deadline-aware micro-batching server: admission
  control, shape-bucket batch assembly (fire on fill or slack expiry),
  padded (B, k) engine calls, post-hoc trim.

Offered load is set to a multiple (REPRO_SV_RATE_X, default 3x) of the
measured static capacity, so the baseline is past saturation and the
dynamic server must win on real batching throughput, not bookkeeping.

Acceptance (ISSUE 4): dynamic QPS >= 1.5x static QPS at matched offered
load, ZERO id mismatches vs direct engine calls for every completed
request, and shed requests return nothing (absent, never incorrect).

Writes ``BENCH_serve_qps.json`` (override with REPRO_BENCH_OUT).  Scale via
REPRO_SV_N / REPRO_SV_D / REPRO_SV_KS / REPRO_SV_NREQ / REPRO_SV_BATCH /
REPRO_SV_RATE_X / REPRO_SV_DEADLINE_X (CI smoke runs a tiny configuration).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.index import search
from repro.serving import batcher as sv_batcher
from repro.serving import queue as sv_queue
from repro.serving import server as sv_server
from repro.serving.state import ServingState

N = int(os.environ.get("REPRO_SV_N", 40_000))
D = int(os.environ.get("REPRO_SV_D", 64))
KS = tuple(int(s) for s in os.environ.get("REPRO_SV_KS", "500,2000").split(","))
NREQ = int(os.environ.get("REPRO_SV_NREQ", 64))
BATCH = int(os.environ.get("REPRO_SV_BATCH", 8))
RATE_X = float(os.environ.get("REPRO_SV_RATE_X", 3.0))
DEADLINE_X = float(os.environ.get("REPRO_SV_DEADLINE_X", 40.0))
N_PROBE = int(os.environ.get("REPRO_SV_NPROBE", 0)) or None


def _build():
    rng = np.random.default_rng(42)
    x = jnp.asarray(common.make_corpus(rng, N, D))
    qs = synthetic.queries_from(np.random.default_rng(7), np.asarray(x),
                                NREQ)
    n_clusters = max(int(np.sqrt(N)), 16)
    index = search.build_pq_index(jax.random.key(0), x, n_clusters, n_iter=6)
    return x, qs, index, n_clusters


def _measure_static_service(state: ServingState, qs, ceilings, n_probe):
    """Post-compile mean single-query seconds per bucket (the capacity the
    offered load is calibrated against)."""
    per_bucket = {}
    for k in ceilings:
        bucket = sv_batcher.bucket_of(k, n_probe, ceilings, 1)
        eng = state.engine(bucket).warmup(batch_sizes=(1,))
        ts = []
        for q in qs[:3]:
            t0 = time.perf_counter()
            jax.block_until_ready(eng.search(jnp.asarray(q)))
            ts.append(time.perf_counter() - t0)
        per_bucket[k] = float(np.median(ts))
    return per_bucket


def _run_static(state: ServingState, trace, ceilings, n_probe):
    """Arrival-ordered batch-1 loop on the same bucketed engines: the
    ``--mode static --batch 1`` baseline under the same offered load."""
    t = trace[0].arrival
    outcomes = []
    for req in sorted(trace, key=lambda r: (r.arrival, r.rid)):
        t = max(t, req.arrival)
        bucket = sv_batcher.bucket_of(req.k, n_probe, ceilings, 1)
        eng = state.engine(bucket)
        t0 = time.perf_counter()
        res = eng.search(jnp.asarray(req.q))
        jax.block_until_ready((res.dists, res.ids))
        t += time.perf_counter() - t0
        d_r, i_r = sv_server.trim_topk(np.asarray(res.dists),
                                       np.asarray(res.ids), req.k)
        outcomes.append(sv_server.Outcome(
            request=req, status=sv_server.OK, bucket=bucket,
            ids=i_r.copy(), dists=d_r.copy(),
            t_done=t, k_effective=req.k))
    return outcomes


def run():
    x, qs, index, n_clusters = _build()
    n_probe = N_PROBE or max(n_clusters // 4, 8)
    ceilings = sv_batcher.k_ceilings(KS)

    # calibrate offered load off the measured static capacity
    cal_state = ServingState(index, use_bbc=True)
    svc = _measure_static_service(cal_state, qs, ceilings, n_probe)
    mean_service = float(np.mean(list(svc.values())))
    rate = RATE_X / mean_service
    deadline = DEADLINE_X * mean_service
    trace = sv_queue.make_trace(np.random.default_rng(5), np.asarray(qs),
                                KS, rate=rate, deadline=deadline,
                                n_probe=n_probe, pattern="poisson")

    static_out = _run_static(cal_state, trace, ceilings, n_probe)
    static_sum = sv_server.summarize(static_out)

    dyn_state = ServingState(index, use_bbc=True)
    srv = sv_server.Server(dyn_state, ceilings, BATCH,
                           max_wait=deadline / 4)
    dyn_out = srv.run_trace(trace)
    # state= adds per-bucket operating-point attribution ("hand-tuned
    # fallback" here: the acceptance bench pins its own knobs)
    dyn_sum = sv_server.summarize(dyn_out, state=dyn_state)

    parity, n_checked = sv_server.parity_vs_direct(dyn_state, dyn_out)
    shed = [o for o in dyn_out if o.status == sv_server.SHED]
    shed_clean = all(o.ids is None and o.dists is None for o in shed)

    qps_ratio = dyn_sum["qps"] / max(static_sum["qps"], 1e-9)
    rows = [dict(mode="static_b1", **static_sum),
            dict(mode="dynamic", **dyn_sum)]
    for r in rows:
        common.emit(
            f"serve/{r['mode']}", 1e6 / max(r["qps"], 1e-9),
            f"qps={r['qps']};p99_ms={r['p99_ms']};shed={r['shed_rate']}")

    payload = {
        "bench": "serve_qps",
        "corpus": {"n": N, "d": D, "corpus": common.CORPUS},
        "config": {"ks": list(KS), "n_requests": NREQ, "batch": BATCH,
                   "n_probe": n_probe, "offered_rate": round(rate, 2),
                   "rate_x_capacity": RATE_X,
                   "deadline_ms": round(deadline * 1e3, 2),
                   "static_service_ms": {
                       str(k): round(v * 1e3, 3) for k, v in svc.items()}},
        "platform": jax.devices()[0].platform,
        "results": rows,
        "acceptance": {
            "qps_static": static_sum["qps"],
            "qps_dynamic": dyn_sum["qps"],
            "qps_ratio": round(qps_ratio, 2),
            "target_ratio": 1.5,
            "ids_match": round(parity, 4),
            "parity_checked": n_checked,
            "shed_returns_nothing": bool(shed_clean),
            # n_checked > 0 guards the vacuous case: an all-shed run has
            # parity 1.0 over zero requests and must not pass
            "pass": bool(qps_ratio >= 1.5 and parity == 1.0
                         and n_checked > 0 and shed_clean),
        },
    }
    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_serve_qps.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    if os.environ.get("REPRO_SV_STRICT") == "1" and \
            not payload["acceptance"]["pass"]:
        raise SystemExit(f"bench_serve acceptance failed: "
                         f"{payload['acceptance']}")
    return payload


if __name__ == "__main__":
    run()
