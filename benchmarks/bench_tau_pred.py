"""Predictive early-exact re-rank: tau_pred subsystem vs the static n_cand cut.

Acceptance benchmark for the cross-batch threshold predictor: on the IVF+PQ
path the predictive engine must re-rank >= 2x fewer candidates than the
static n_cand cut at k=5000 with IDENTICAL top-k id sets, with QPS reported
alongside.  k=100000 (k comparable to the corpus) is reported too: there the
static cut already covers everything, so the predictive path converges to it
(ratio ~1) — the subsystem degrades to the static path instead of below it.

Two regimes run side by side (select with REPRO_TP_REGIMES):

* ``hiacc`` — Gaussian-mixture corpus with the high-accuracy PQ config
  (M=d/2 subquantizers, 8-bit codes).  Gaussian mixtures concentrate
  distances far more than the paper's real embedding corpora (see
  data/synthetic.py), so the paper-default estimator has near-uninformative
  deep ranks on them and would understate ANY estimate-ordered re-ranker;
  M=d/2 restores the informative ordering.  This regime carries the
  acceptance gate: the predictive pool provably stays a subset of the
  static n_cand pool, so the id-parity check is meaningful, not vacuous.
* ``paper`` — ``synthetic.manifold`` corpus (low-dimensional manifold
  embedding + Zipf cluster sizes, the realistic distance geometry) with the
  paper-default M=d/4, 4-bit PQ.  On this corpus the default estimator's
  deep ranks ARE informative, so the paper's own config shows the same
  pool-shrink effect without the quantizer upgrade.

Writes ``BENCH_tau_pred.json`` (override path with REPRO_BENCH_OUT).  Scale
via REPRO_TP_N / REPRO_TP_D / REPRO_TP_KS / REPRO_TP_B / REPRO_TP_WARM /
REPRO_TP_PRED_COUNT / REPRO_TP_REGIMES (CI smoke runs a tiny configuration).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.index import engine, search
from repro.tuning import points as tn_points

N = int(os.environ.get("REPRO_TP_N", 120_000))
D = int(os.environ.get("REPRO_TP_D", 64))
B = int(os.environ.get("REPRO_TP_B", 8))
WARM = int(os.environ.get("REPRO_TP_WARM", 3))
KS = tuple(int(s) for s in
           os.environ.get("REPRO_TP_KS", "5000,100000").split(","))
PRED_COUNT = os.environ.get("REPRO_TP_PRED_COUNT", "")

# (regime, corpus kind, n_sub(d), n_bits, carries the acceptance gate)
ALL_REGIMES = (
    ("hiacc", "clustered", lambda d: max(d // 2, 1), 8, True),
    ("paper", "manifold", lambda d: max(d // 4, 1), 4, False),
)
_REGIME_NAMES = tuple(
    s for s in os.environ.get("REPRO_TP_REGIMES", "hiacc,paper").split(",")
    if s)
REGIMES = tuple(r for r in ALL_REGIMES if r[0] in _REGIME_NAMES)


def _build(corpus_kind, n_sub, n_bits):
    rng = np.random.default_rng(42)
    x = jnp.asarray(common.make_corpus(rng, N, D, kind=corpus_kind,
                                       n_centers=max(N // 200, 8)))
    qrng = np.random.default_rng(7)
    qs = jnp.asarray(synthetic.queries_from(qrng, np.asarray(x),
                                            B * (WARM + 1)))
    n_clusters = max(int(np.sqrt(N)), 16)
    index = search.build_pq_index(jax.random.key(0), x, n_clusters,
                                  n_sub=n_sub, n_bits=n_bits, n_iter=8)
    return x, qs, index, n_clusters


def _ids_match(a: np.ndarray, b: np.ndarray) -> float:
    hits = sum(set(a[i].tolist()) == set(b[i].tolist())
               for i in range(a.shape[0]))
    return hits / a.shape[0]


def _ids_overlap(a: np.ndarray, b: np.ndarray) -> float:
    """Mean fractional top-k id overlap (ids_match is all-or-nothing per
    query; this shows HOW close the predictive selection is on ungated
    regimes, where one swapped id out of k zeroes ids_match).  Normalized
    by the static row's unique-id count, not k: at k ~ corpus size rows
    carry -1 padding that set-dedup would otherwise count against."""
    overlaps = []
    for i in range(a.shape[0]):
        sa, sb = set(a[i].tolist()), set(b[i].tolist())
        sa.discard(-1)
        sb.discard(-1)
        overlaps.append(len(sa & sb) / max(len(sa), 1))
    return float(np.mean(overlaps))


def _run_regime(regime, corpus_kind, n_sub_fn, n_bits, gated, ks):
    x, qs, index, n_clusters = _build(corpus_kind, n_sub_fn(D), n_bits)
    n_probe = n_clusters // 2
    batches = [qs[i * B:(i + 1) * B] for i in range(WARM + 1)]
    measure = batches[-1]
    pq_desc = f"M=d/{D // n_sub_fn(D)}, {n_bits}-bit"
    results = []
    store = tn_points.PointStore.load()
    corpus_fp = tn_points.corpus_fingerprint(np.asarray(x))

    for k in ks:
        if k > N:
            continue
        # pool knobs resolve from the tuned operating points when one was
        # solved on THIS corpus (exact fingerprint — a pool tuned on a
        # different distance geometry is no contract for the id-parity
        # gate); else the documented hand-tuned fallback n_cand = min(8k, n)
        point, provenance = store.resolve("ivfpq", k, corpus_fp=corpus_fp)
        n_cand = min(8 * k, N)
        operating_point = tn_points.HAND_TUNED
        if point is not None and provenance == "tuned":
            operating_point = f"{point.name} (tuned)"
            if point.knobs.n_cand is not None:
                n_cand = max(k, min(point.knobs.n_cand, N))
        pred_count = int(PRED_COUNT) if PRED_COUNT else None
        if pred_count is None and operating_point != tn_points.HAND_TUNED:
            pred_count = point.knobs.pred_count
        eng = engine.SearchEngine.build(index, k=k, n_probe=n_probe,
                                        n_cand=n_cand, pred_count=pred_count)
        pred_count = eng.pred_count      # the engine default unless overridden

        t_static = common.timeit(eng.search, measure)
        r_static = eng.search(measure)

        # warm the predictor on distinct batches, then measure steady state
        state = eng.predictor_init()
        for wb in batches[:-1]:
            _, state = eng.search(wb, pred_state=state)

        def pred_call(qb, state=state):
            return eng.search(qb, pred_state=state)

        t_pred = common.timeit(pred_call, measure)
        r_pred, _ = pred_call(measure)

        match = _ids_match(np.asarray(r_static.ids), np.asarray(r_pred.ids))
        overlap = _ids_overlap(np.asarray(r_static.ids),
                               np.asarray(r_pred.ids))
        nrr_static = float(np.mean(np.asarray(r_static.n_reranked)))
        nrr_pred = float(np.mean(np.asarray(r_pred.n_reranked)))
        ratio = nrr_static / max(nrr_pred, 1.0)
        row = dict(
            regime=regime, corpus=corpus_kind, pq=pq_desc, gated=gated,
            k=k, n_cand=n_cand, pred_count=pred_count, B=B,
            n_probe=n_probe, operating_point=operating_point,
            n_reranked_static=round(nrr_static, 1),
            n_reranked_pred=round(nrr_pred, 1),
            rerank_ratio=round(ratio, 2),
            n_second_pass_pred=round(
                float(np.mean(np.asarray(r_pred.n_second_pass))), 1),
            qps_static=round(B / t_static, 2),
            qps_pred=round(B / t_pred, 2),
            qps_ratio=round(t_static / t_pred, 2),
            ids_match=round(match, 4),
            ids_overlap=round(overlap, 4),
        )
        results.append(row)
        common.emit(
            f"tau_pred/{regime}/ivfpq/k{k}", t_pred / B * 1e6,
            f"rerank_ratio={ratio:.2f}x;ids_match={match:.3f};"
            f"qps_ratio={row['qps_ratio']:.2f}x")
    return results


def run(ks=KS):
    # a typo'd REPRO_TP_REGIMES must fail loudly, not silently run (and
    # gate) nothing — an empty regime list would make the strict check and
    # the CI id-mismatch step both pass vacuously
    unknown = set(_REGIME_NAMES) - {r[0] for r in ALL_REGIMES}
    if unknown or not REGIMES:
        raise SystemExit(
            f"REPRO_TP_REGIMES must name regimes from "
            f"{[r[0] for r in ALL_REGIMES]}, got {_REGIME_NAMES}")
    results = []
    for regime, corpus_kind, n_sub_fn, n_bits, gated in REGIMES:
        results.extend(
            _run_regime(regime, corpus_kind, n_sub_fn, n_bits, gated, ks))

    # the acceptance gate rides on the documented regime only (gated rows);
    # the paper-default regime on the manifold corpus is reported so the
    # realistic-geometry effect is visible, not gated — a shallow pool on a
    # coarse estimator deliberately trades recall for fewer re-ranks
    k_target = 5000
    gated_rows = [r for r in results if r["gated"]]
    gate = [r for r in gated_rows if r["k"] == k_target] or gated_rows[:1]
    payload = {
        "bench": "tau_pred",
        "corpus": {"n": N, "d": D,
                   "regimes": [dict(regime=r[0], corpus=r[1],
                                    n_bits=r[3], gated=r[4])
                               for r in REGIMES]},
        "config": {"B": B, "warm_batches": WARM, "ks": list(ks)},
        "platform": jax.devices()[0].platform,
        "results": results,
        "acceptance": {
            "k": gate[0]["k"] if gate else None,
            "rerank_ratio": gate[0]["rerank_ratio"] if gate else None,
            "ids_match": gate[0]["ids_match"] if gate else None,
            "target_ratio": 2.0,
            "pass": bool(gate and gate[0]["rerank_ratio"] >= 2.0
                         and gate[0]["ids_match"] == 1.0),
        },
    }
    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_tau_pred.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    if os.environ.get("REPRO_TP_STRICT") == "1":
        bad = [r for r in results if r["gated"] and r["ids_match"] < 1.0]
        if bad:
            raise SystemExit(
                f"tau_pred id mismatch: {[(r['k'], r['ids_match']) for r in bad]}")
    return results


if __name__ == "__main__":
    run()
