"""§Perf hillclimb cell C: the BBC search pipeline itself (paper-representative).

Iterations (hypothesis -> change -> measure, EXPERIMENTS.md §Perf):
  C0 baseline : paper-faithful IVF+RaBitQ+BBC searcher (two-pass collect).
  C1 m tuning : bucket count sweep around Eq. 3' (CPU wall-clock).
  C2 fused    : single-pass fused kernel vs two-pass — HBM traffic per query
                (structural; the TPU term) + collect-stage wall-clock.
  C3 budget   : distributed survivor budget slack 2.0 -> 1.25 — collective
                bytes per query at exactness (validated on an 8-way mesh in
                tests/test_distributed.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import collector as col
from repro.core import distributed as dist
from repro.index import search


def run(k=4000):
    x, qs = common.corpus()
    q = qs[0]
    n_probe = int(np.clip(np.ceil(10 * k * common.N_CLUSTERS / common.N),
                          16, int(common.N_CLUSTERS * 0.8)))

    # ---- C0: baseline end-to-end (paper-faithful) --------------------------
    t0 = common.timeit(lambda: search.ivf_rabitq_search(
        common.rq_index(), q, k=k, n_probe=n_probe, use_bbc=True))
    base = search.ivf_rabitq_search(common.rq_index(), q, k=k,
                                    n_probe=n_probe, use_bbc=True)
    tb = common.timeit(lambda: search.ivf_rabitq_search(
        common.rq_index(), q, k=k, n_probe=n_probe, use_bbc=False))
    common.emit("perfC/C0_baseline_bbc", t0 * 1e6,
                f"vs_no_bbc={tb/t0:.2f}x;n_rerank={int(base.n_reranked)}")

    # ---- C1: m sweep around Eq. 3' -----------------------------------------
    rng = np.random.default_rng(9)
    n_tiles, tile = 64, 512
    d0 = np.abs(rng.standard_normal((n_tiles, tile)).astype(np.float32)) + 1
    s = col.StreamInput(
        jnp.asarray(d0),
        jnp.arange(n_tiles * tile, dtype=jnp.int32).reshape(n_tiles, tile),
        jnp.ones((n_tiles, tile), bool))
    best = (None, np.inf)
    for m in (32, 128, 256, 512):
        t = common.timeit(jax.jit(functools.partial(col.bbc_collect, k=k, m=m)), s)
        common.emit(f"perfC/C1_m{m}", t * 1e6, "")
        if t < best[1]:
            best = (m, t)
    common.emit("perfC/C1_best", best[1] * 1e6, f"m={best[0]}")

    # ---- C2: fused single-pass vs two-pass HBM traffic ---------------------
    n, d, M = common.N, common.D, common.D // 4
    # two-pass: read codes (ADC) + write/read estimates + 2nd read of fp32
    # vectors for the early-rerank pool (gathered rows)
    est_bytes = 4 * n
    two_pass = n * M + 2 * est_bytes + int(0.2 * n) * d * 4
    # fused: codes + vectors streamed once; hist stays in VMEM
    fused = n * M + n * d * 4
    common.emit("perfC/C2_fused_traffic", 0.0,
                f"two_pass_bytes={two_pass};fused_bytes={fused};"
                f"ratio={two_pass/fused:.2f}x_vs_1pass")
    # collect-stage wall-clock (the measurable CPU component)
    t_bbc = common.timeit(jax.jit(functools.partial(col.bbc_collect, k=k)), s)
    t_topk = common.timeit(jax.jit(functools.partial(col.topk_collect, k=k)), s)
    common.emit("perfC/C2_collect_stage", t_bbc * 1e6,
                f"topk_collector={t_topk*1e6:.0f}us;speedup={t_topk/t_bbc:.2f}x")

    # ---- C3: survivor budget slack -----------------------------------------
    for slack in (2.0, 1.5, 1.25):
        budget = dist.survivor_budget(k, 16, slack=slack)
        cm = dist.collective_cost_model(k, 128, 16, budget=budget)
        common.emit(f"perfC/C3_slack{slack}", 0.0,
                    f"budget={budget};link_bytes={int(cm['bbc_bytes_per_link'])};"
                    f"vs_naive={cm['ratio']:.1f}x")
    return None


if __name__ == "__main__":
    run()
