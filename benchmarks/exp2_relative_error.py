"""Exp-2 analogue: relative distance error of retrieved results vs exact,
across k and operating points (stays ~constant in k, drops with recall)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.index import search


def run(ks=(500, 2000, 8000), n_probes=(16, 48)):
    x, qs = common.corpus()
    for k in ks:
        if 8 * k > common.N:
            continue
        gt_d, gt_i = common.ground_truth(k)
        for n_probe in n_probes:
            errs, recs = [], []
            for qi, q in enumerate(qs[:3]):
                r = search.ivf_pq_search(
                    common.pq_index(), q, k=k, n_probe=n_probe,
                    n_cand=min(8 * k, common.N), use_bbc=True)
                got = np.sort(np.asarray(r.dists))
                want = gt_d[qi]
                errs.append(np.mean(got / np.maximum(want, 1e-9) - 1.0))
                recs.append(common.recall(np.asarray(r.ids), gt_i[qi]))
            common.emit(f"exp2/pq_bbc/k{k}/np{n_probe}", 0.0,
                        f"rel_err={np.mean(errs):.5f};recall={np.mean(recs):.3f}")
    return None


if __name__ == "__main__":
    run()
