"""Exp-4 / Theorem 3.1 analogue: gap between the relaxed (bucket upper-edge)
threshold and the exact k-th distance; also the 1/sqrt(d) scaling."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import buffer as rb


def run(ks=(1000, 5000), ds=(32, 128, 512), n=40000, m=128):
    rng = np.random.default_rng(2)
    for d in ds:
        q = rng.standard_normal(d).astype(np.float32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        dist = np.linalg.norm(x - q, axis=1)
        for k in ks:
            cb = rb.build_codebook(jnp.asarray(dist), k=k, m=m)
            b = rb.bucketize(cb, jnp.asarray(dist))
            hist = rb.histogram(b, m)
            tau, _ = rb.threshold_bucket(hist, k)
            relaxed = float(rb.relaxed_threshold(cb, tau))
            exact = float(np.sort(dist)[k - 1])
            gap = relaxed - exact
            rel = gap / exact
            common.emit(f"exp4/gap/d{d}/k{k}", 0.0,
                        f"gap={gap:.4f};relative={rel:.5f}")
    return None


if __name__ == "__main__":
    run()
