"""Bound-fused RaBitQ scan vs the two-phase estimate-then-gather path.

Acceptance benchmark for the executed fused kernel (PR 5): at k=5000 the
fused batch path must be >= 1.3x the two-phase path's QPS on the CPU
container with IDENTICAL top-k id sets, and the predictive path's measured
``n_second_pass`` (straggler lanes actually left to the second gather by
the EMA gate) must match the second-pass volume the two-phase path MODELS
for the same seed and warmup — the PR-3 counter the fused kernel turns
into an executed quantity.  k=100000 (k comparable to the corpus) is
reported too: there the two-phase plan's full-stream ub sort dominates and
the fused restructure wins even bigger.

Both contenders run through ``engine.SearchEngine`` (build-time stream
cache, the serving path) and differ ONLY in ``fused=``: same index, same
routing, same bounds math, same exact-distance source.

Writes ``BENCH_rabitq_fused.json`` (override with REPRO_BENCH_OUT).  Scale
via REPRO_RF_N / REPRO_RF_D / REPRO_RF_KS / REPRO_RF_B / REPRO_RF_WARM;
REPRO_RF_STRICT=1 exits non-zero on an id mismatch (CI smoke).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.index import engine, search

N = int(os.environ.get("REPRO_RF_N", 120_000))
D = int(os.environ.get("REPRO_RF_D", 64))
B = int(os.environ.get("REPRO_RF_B", 8))
WARM = int(os.environ.get("REPRO_RF_WARM", 3))
KS = tuple(int(s) for s in
           os.environ.get("REPRO_RF_KS", "5000,100000").split(","))


def _build():
    rng = np.random.default_rng(42)
    x = jnp.asarray(common.make_corpus(rng, N, D, kind="clustered",
                                       n_centers=max(N // 200, 8)))
    qrng = np.random.default_rng(7)
    qs = jnp.asarray(synthetic.queries_from(qrng, np.asarray(x),
                                            B * (WARM + 1)))
    n_clusters = max(int(np.sqrt(N)), 16)
    index = search.build_rabitq_index(jax.random.key(0), x, n_clusters,
                                      n_iter=8)
    return x, qs, index, n_clusters


def _ids_match(a: np.ndarray, b: np.ndarray) -> float:
    hits = sum(set(a[i].tolist()) == set(b[i].tolist())
               for i in range(a.shape[0]))
    return hits / a.shape[0]


def _time_pair(fn_a, fn_b, qs, repeats: int = 7):
    """Interleaved A/B timing: alternate the contenders within each rep so
    slow container-load drift hits both medians equally (back-to-back
    blocks can skew a ratio gate by ~10% here)."""
    for fn in (fn_a, fn_b):
        jax.block_until_ready(fn(qs))
    ta, tb = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(qs))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(qs))
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


def run(ks=KS):
    x, qs, index, n_clusters = _build()
    n_probe = n_clusters // 2
    batches = [qs[i * B:(i + 1) * B] for i in range(WARM + 1)]
    measure = batches[-1]
    results = []

    for k in ks:
        if k > N:
            continue
        ef = engine.SearchEngine.build(index, k=k, n_probe=n_probe,
                                       fused=True)
        et = engine.SearchEngine.build(index, k=k, n_probe=n_probe,
                                       fused=False)

        t_fused, t_two = _time_pair(ef.search, et.search, measure)
        r_fused = ef.search(measure)
        r_two = et.search(measure)
        match = _ids_match(np.asarray(r_fused.ids), np.asarray(r_two.ids))

        # predictive: each contender warms ITS OWN engine-owned EMA on the
        # same warmup batches, then the same measure batch is served —
        # the fused path's n_second_pass is the MEASURED straggler gather,
        # the two-phase path's is the MODELED volume (PR 3's counter)
        sf, st = ef.predictor_init(), et.predictor_init()
        for wb in batches[:-1]:
            _, sf = ef.search(wb, pred_state=sf)
            _, st = et.search(wb, pred_state=st)
        p_fused, _ = ef.search(measure, pred_state=sf)
        p_two, _ = et.search(measure, pred_state=st)
        match_pred = _ids_match(np.asarray(p_fused.ids),
                                np.asarray(p_two.ids))
        measured = float(np.mean(np.asarray(p_fused.n_second_pass)))
        modeled = float(np.mean(np.asarray(p_two.n_second_pass)))
        band = max(float(np.mean(np.asarray(p_fused.n_reranked))), 1.0)
        # "matches" as a fraction of the band: both counters are small
        # residues of a ~band-sized quantity, so a ratio of near-zeros
        # would be noise — the band-normalized gap is the stable metric
        gap = abs(measured - modeled) / band

        row = dict(
            k=k, B=B, n_probe=n_probe,
            qps_fused=round(B / t_fused, 2),
            qps_two_phase=round(B / t_two, 2),
            qps_ratio=round(t_two / t_fused, 2),
            ms_per_batch_fused=round(1e3 * t_fused, 2),
            ms_per_batch_two_phase=round(1e3 * t_two, 2),
            ids_match=round(match, 4),
            ids_match_pred=round(match_pred, 4),
            band_fused=round(float(np.mean(np.asarray(r_fused.n_reranked))),
                             1),
            band_two_phase=round(
                float(np.mean(np.asarray(r_two.n_reranked))), 1),
            n_second_static_fused=round(
                float(np.mean(np.asarray(r_fused.n_second_pass))), 1),
            n_second_measured=round(measured, 1),
            n_second_modeled=round(modeled, 1),
            second_pass_gap=round(gap, 4),
        )
        results.append(row)
        common.emit(
            f"rabitq_fused/k{k}/B{B}", t_fused / B * 1e6,
            f"qps_ratio={row['qps_ratio']:.2f}x;ids_match={match:.3f};"
            f"second_pass_gap={gap:.4f}")

    k_target = 5000
    gate = [r for r in results if r["k"] == k_target] or results[:1]
    g = gate[0] if gate else {}
    payload = {
        "bench": "rabitq_fused",
        "corpus": {"n": N, "d": D, "kind": "clustered"},
        "config": {"B": B, "warm_batches": WARM, "ks": list(ks),
                   "n_probe": n_probe, "n_clusters": n_clusters},
        "platform": jax.devices()[0].platform,
        "results": results,
        "acceptance": {
            "claim": "fused RaBitQ batch path >= 1.3x two-phase QPS at "
                     "k=5000 with identical top-k id sets; measured "
                     "second-pass volume matches the modeled volume",
            "k": g.get("k"),
            "qps_ratio": g.get("qps_ratio"),
            "ids_match": g.get("ids_match"),
            "second_pass_gap": g.get("second_pass_gap"),
            "target_ratio": 1.3,
            "pass": bool(g and g["qps_ratio"] >= 1.3
                         and g["ids_match"] == 1.0
                         and g["ids_match_pred"] == 1.0
                         and g["second_pass_gap"] <= 0.05),
        },
    }
    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_rabitq_fused.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    if os.environ.get("REPRO_RF_STRICT") == "1":
        bad = [r for r in results
               if r["ids_match"] < 1.0 or r["ids_match_pred"] < 1.0]
        if bad:
            raise SystemExit(
                f"rabitq_fused id mismatch: "
                f"{[(r['k'], r['ids_match'], r['ids_match_pred']) for r in bad]}")
    return results


if __name__ == "__main__":
    run()
