"""Multi-replica failover under deterministic fault injection.

Acceptance benchmark for the fault-tolerant serving tier
(``repro.serving.router.ReplicaServer``): one seeded open-loop trace at
**3x single-replica capacity** is served by a 4-replica pool three times —

* **fault_free** — no fault schedule: the healthy-path baseline the
  degraded run's tail is compared against;
* **faulted** — one of the four replicas takes a ``crash`` fault mid-trace
  (plus, optionally, extra seeded faults via REPRO_FO_EXTRA_FAULTS): its
  in-flight batch dies, its lanes strand, heartbeats stop, the supervisor
  respawns it through the checksummed predictor-checkpoint path, and the
  routed-around traffic is recovered by timeouts, retries, and hedges;
* **replay** — the faulted run again, same seeds: the outcome digests and
  the JSON summaries must be byte-identical (the deterministic-replay
  contract).

The engine calls are REAL (the same PQ engines ``bench_serve.py`` drives);
the timeline uses a fixed per-bucket service model measured post-compile,
so scheduling, fault timing, and the replay contract are exact while every
completed id set still comes from an actual search.

Acceptance (ISSUE 6):

* parity 1.0 vs direct engine calls for every NON-degraded completion;
* zero lost requests: completed + shed + failed == offered (conservation);
* p99 latency under the crash fault <= 3x the fault-free 4-replica p99;
* the replayed faulted run is byte-identical to the first.

Writes ``BENCH_failover.json`` (override with REPRO_BENCH_OUT).  Scale via
REPRO_FO_N / REPRO_FO_NREQ / REPRO_FO_REPLICAS / REPRO_FO_BATCH /
REPRO_FO_RATE_X / REPRO_FO_DEADLINE_X; CI's chaos smoke runs a tiny
configuration with REPRO_FO_STRICT=1.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.index import search
from repro.serving import admission as sv_adm
from repro.serving import batcher as sv_batcher
from repro.serving import faults as sv_faults
from repro.serving import queue as sv_queue
from repro.serving import server as sv_server
from repro.serving.router import HedgePolicy, ReplicaServer, RetryPolicy, \
    outcome_digest
from repro.serving.state import ServingState

N = int(os.environ.get("REPRO_FO_N", 40_000))
D = int(os.environ.get("REPRO_FO_D", 64))
KS = tuple(int(s) for s in os.environ.get("REPRO_FO_KS", "500,2000").split(","))
NREQ = int(os.environ.get("REPRO_FO_NREQ", 96))
BATCH = int(os.environ.get("REPRO_FO_BATCH", 8))
N_REPLICAS = int(os.environ.get("REPRO_FO_REPLICAS", 4))
RATE_X = float(os.environ.get("REPRO_FO_RATE_X", 3.0))
DEADLINE_X = float(os.environ.get("REPRO_FO_DEADLINE_X", 12.0))
N_PROBE = int(os.environ.get("REPRO_FO_NPROBE", 0)) or None
EXTRA_FAULTS = int(os.environ.get("REPRO_FO_EXTRA_FAULTS", 0))
FAULT_SEED = int(os.environ.get("REPRO_FO_FAULT_SEED", 11))


def _build():
    rng = np.random.default_rng(42)
    x = jnp.asarray(common.make_corpus(rng, N, D))
    qs = synthetic.queries_from(np.random.default_rng(7), np.asarray(x),
                                NREQ)
    n_clusters = max(int(np.sqrt(N)), 16)
    index = search.build_pq_index(jax.random.key(0), x, n_clusters, n_iter=6)
    return qs, index, n_clusters


def _measure_service(state: ServingState, qs, ceilings, n_probe):
    """Fixed per-bucket BATCH-call service model, measured post-compile —
    the deterministic clock every run (and the replay) shares."""
    per_bucket = {}
    for k in ceilings:
        bucket = sv_batcher.bucket_of(k, n_probe, ceilings, BATCH)
        eng = state.engine(bucket).warmup(batch_sizes=(BATCH,))
        batch_qs = jnp.asarray(np.asarray(qs)[:BATCH])
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            res = eng.search_batch(batch_qs)
            jax.block_until_ready((res.dists, res.ids))
            ts.append(time.perf_counter() - t0)
        per_bucket[(k, n_probe)] = float(np.median(ts))
    fallback = float(np.median(list(per_bucket.values())))

    def service_time_fn(bucket: sv_batcher.ShapeBucket) -> float:
        return per_bucket.get((bucket.k, bucket.n_probe), fallback)

    return per_bucket, service_time_fn


def _serve(index, trace, ceilings, n_probe, service_time_fn, schedule,
           ladder, deadline):
    # policy tuning, sized in estimated service times: batches wait at most
    # a third of the deadline budget (tail latency under LOW load must not
    # equal the deadline), every request hedges once remaining slack falls
    # to 6 service estimates (crash-stranded work recovers via the hedge
    # well before its timeout), and timeouts fire 2 estimates past the
    # deadline (the backstop for work stranded with no hedge slack left)
    state = ServingState(index, use_bbc=True)
    srv = ReplicaServer(
        state, N_REPLICAS, ceilings, BATCH,
        retry=RetryPolicy(timeout_mult=2.0),
        hedge=HedgePolicy(slack_mult=6.0),
        ladder=ladder, faults=schedule,
        service_time_fn=service_time_fn,
        max_wait=deadline / 3,
        # heartbeat / respawn cadence scaled to the trace's timescale
        # (deadline = DEADLINE_X service estimates): detection within ~one
        # estimated service time, supervisor restart ~1.5 estimates later
        hb_interval=float(os.environ.get("REPRO_FO_HB", deadline / 40)),
        respawn_delay=float(os.environ.get("REPRO_FO_RESPAWN",
                                           deadline / 8)))
    outcomes = srv.run_trace(trace)
    return state, srv, outcomes


def _row(mode, outcomes, srv):
    return dict(mode=mode, **sv_server.summarize(outcomes),
                digest=outcome_digest(outcomes),
                stats=dict(sorted(srv.stats.items())))


def run():
    qs, index, n_clusters = _build()
    n_probe = N_PROBE or max(n_clusters // 4, 8)
    ceilings = sv_batcher.k_ceilings(KS)

    cal_state = ServingState(index, use_bbc=True)
    per_bucket, service_time_fn = _measure_service(cal_state, qs, ceilings,
                                                   n_probe)
    # single-replica capacity = one executor draining BATCH-wide calls;
    # the pool is offered RATE_X times that, so with one of N_REPLICAS
    # replicas crash-faulted the survivors still have headroom and the
    # tier must degrade gracefully instead of collapsing
    mean_service = float(np.mean(list(per_bucket.values())))
    capacity_1 = BATCH / mean_service
    rate = RATE_X * capacity_1
    deadline = DEADLINE_X * mean_service
    trace = sv_queue.make_trace(np.random.default_rng(5), np.asarray(qs),
                                KS, rate=rate, deadline=deadline,
                                n_probe=n_probe, pattern="poisson")
    horizon = max(r.arrival for r in trace)
    ladder = sv_adm.DegradeLadder(
        ((2.0, min(KS), None), (4.0, min(KS), max(n_probe // 2, 1))))

    # one replica crash-faulted mid-trace, plus optional seeded extras
    faults = [sv_faults.Fault(t=0.5 * horizon, replica=1,
                              kind=sv_faults.CRASH)]
    if EXTRA_FAULTS:
        extra = sv_faults.FaultSchedule.seeded(
            np.random.default_rng(FAULT_SEED), N_REPLICAS, horizon,
            n_faults=EXTRA_FAULTS)
        faults.extend(extra.faults)
    schedule = sv_faults.FaultSchedule(faults)

    runs = {}
    for mode, sched in (("fault_free", sv_faults.FaultSchedule()),
                        ("faulted", schedule),
                        ("replay", schedule)):
        state, srv, outcomes = _serve(index, trace, ceilings, n_probe,
                                      service_time_fn, sched, ladder,
                                      deadline)
        runs[mode] = (state, srv, outcomes)

    rows = [_row(mode, outcomes, srv)
            for mode, (_, srv, outcomes) in runs.items()]
    by_mode = {r["mode"]: r for r in rows}

    # -- gates ---------------------------------------------------------------
    state_f, _, out_f = runs["faulted"]
    non_degraded = [o for o in out_f if o.status == sv_server.OK]
    parity, n_checked = sv_server.parity_vs_direct(state_f, non_degraded)
    conserved = all(r["conserved"] for r in rows)
    p99_free = by_mode["fault_free"]["p99_ms"]
    p99_fault = by_mode["faulted"]["p99_ms"]
    p99_ok = bool(p99_fault is not None and p99_free is not None
                  and p99_fault <= 3.0 * p99_free)
    def strip_mode(r):
        return {k: v for k, v in r.items() if k != "mode"}

    replay_identical = bool(
        by_mode["faulted"]["digest"] == by_mode["replay"]["digest"]
        and json.dumps(strip_mode(by_mode["faulted"]), sort_keys=True)
        == json.dumps(strip_mode(by_mode["replay"]), sort_keys=True))

    for r in rows:
        common.emit(
            f"failover/{r['mode']}", 1e6 / max(r["qps"], 1e-9),
            f"qps={r['qps']};p99_ms={r['p99_ms']};failed={r['failed']};"
            f"degraded={r['degraded']};retried={r['retried']};"
            f"hedged={r['hedged']}")

    payload = {
        "bench": "failover",
        "corpus": {"n": N, "d": D, "corpus": common.CORPUS},
        "config": {
            "ks": list(KS), "n_requests": NREQ, "batch": BATCH,
            "n_replicas": N_REPLICAS, "n_probe": n_probe,
            "offered_rate": round(rate, 2),
            "rate_x_single_replica_capacity": RATE_X,
            "deadline_ms": round(deadline * 1e3, 2),
            "faults": [
                {"kind": f.kind, "replica": f.replica,
                 "t": round(f.t, 4), "duration": round(f.duration, 4),
                 "factor": f.factor} for f in schedule.faults],
            "service_ms_per_bucket": {
                f"k{k}_np{np_}": round(v * 1e3, 3)
                for (k, np_), v in per_bucket.items()},
        },
        "platform": jax.devices()[0].platform,
        "results": rows,
        "acceptance": {
            "parity_non_degraded": round(parity, 4),
            "parity_checked": n_checked,
            "conserved": conserved,
            "p99_fault_free_ms": p99_free,
            "p99_faulted_ms": p99_fault,
            "p99_ratio_limit": 3.0,
            "replay_identical": replay_identical,
            # n_checked > 0 guards the vacuous case: a run where every
            # completion was degraded verified no parity at all
            "pass": bool(parity == 1.0 and n_checked > 0 and conserved
                         and p99_ok and replay_identical),
        },
    }
    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_failover.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    if os.environ.get("REPRO_FO_STRICT") == "1" and \
            not payload["acceptance"]["pass"]:
        raise SystemExit(f"bench_failover acceptance failed: "
                         f"{payload['acceptance']}")
    return payload


if __name__ == "__main__":
    run()
