"""Fig. 1 / Exp-1 analogue: QPS vs recall for each method at small and large k.

Validates: (1) BBC speeds up both quantized methods at large k; (2) the gain
grows with k; (3) no regression at small k (paper observation Exp-1(4)).

Runs on ``engine.SearchEngine`` — the same serving wrapper launch/serve.py
uses — so the figure measures the production entry point, not a bench-local
call path: each method processes the whole query set in one batched engine
call over the shared candidate stream (QPS is batch-amortized; recall is
averaged over the same batched results).  BFC stays per-query (no batched
path — it is the brute-force floor).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.index import flat


def run(ks=(100, 2000), n_probes=(24, 48)):
    x, qs = common.corpus()
    results = []
    for k in ks:
        gt_d, gt_i = common.ground_truth(k)
        n_cand = min(8 * k, common.N)
        for n_probe in n_probes:
            methods = {
                "ivf+pq": common.engine_for(
                    "ivfpq", k=k, n_probe=n_probe, n_cand=n_cand,
                    use_bbc=False).search,
                "ivf+pq+bbc": common.engine_for(
                    "ivfpq", k=k, n_probe=n_probe, n_cand=n_cand,
                    use_bbc=True).search,
                "ivf+rabitq": common.engine_for(
                    "ivfrabitq", k=k, n_probe=n_probe, use_bbc=False).search,
                "ivf+rabitq+bbc": common.engine_for(
                    "ivfrabitq", k=k, n_probe=n_probe, use_bbc=True).search,
            }
            for name, fn in methods.items():
                t = common.timeit(lambda: fn(qs)) / qs.shape[0]  # per query
                r = fn(qs)
                ids = np.asarray(r.ids)
                recs = [common.recall(ids[qi], gt_i[qi])
                        for qi in range(min(3, qs.shape[0]))]
                rec = float(np.mean(recs))
                qps = 1.0 / t
                common.emit(
                    f"fig1/{name}/k{k}/np{n_probe}", t * 1e6,
                    f"recall={rec:.3f};qps={qps:.2f}")
                results.append(dict(method=name, k=k, n_probe=n_probe,
                                    recall=rec, qps=qps))
        # brute-force floor, once per k
        t = common.timeit(lambda: flat.search(x, qs[0], k))
        recs = []
        for qi in range(min(3, qs.shape[0])):
            d, i = flat.search(x, qs[qi], k)
            recs.append(common.recall(np.asarray(i), gt_i[qi]))
        common.emit(f"fig1/bfc/k{k}/np{n_probes[0]}", t * 1e6,
                    f"recall={float(np.mean(recs)):.3f};qps={1.0 / t:.2f}")
        results.append(dict(method="bfc", k=k, n_probe=n_probes[0],
                            recall=float(np.mean(recs)), qps=1.0 / t))
    # headline: speedup of +bbc over base at the large k, matched n_probe
    for base in ("ivf+pq", "ivf+rabitq"):
        k = ks[-1]
        b = [r for r in results if r["method"] == base and r["k"] == k]
        a = [r for r in results if r["method"] == base + "+bbc" and r["k"] == k]
        if b and a:
            sp = np.mean([x["qps"] for x in a]) / np.mean([x["qps"] for x in b])
            common.emit(f"fig1/speedup/{base}+bbc/k{k}", 0.0,
                        f"speedup={sp:.2f}x")
    return results


if __name__ == "__main__":
    run()
