"""Fig. 1 / Exp-1 analogue: QPS vs recall for each method at small and large k.

Validates: (1) BBC speeds up both quantized methods at large k; (2) the gain
grows with k; (3) no regression at small k (paper observation Exp-1(4))."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.index import flat, search


def run(ks=(100, 2000), n_probes=(24, 48)):
    x, qs = common.corpus()
    results = []
    for k in ks:
        gt_d, gt_i = common.ground_truth(k)
        n_cand = min(8 * k, common.N)
        methods = {
            "ivf+pq": lambda q: search.ivf_pq_search(
                common.pq_index(), q, k=k, n_probe=n_probe, n_cand=n_cand),
            "ivf+pq+bbc": lambda q: search.ivf_pq_search(
                common.pq_index(), q, k=k, n_probe=n_probe, n_cand=n_cand,
                use_bbc=True),
            "ivf+rabitq": lambda q: search.ivf_rabitq_search(
                common.rq_index(), q, k=k, n_probe=n_probe),
            "ivf+rabitq+bbc": lambda q: search.ivf_rabitq_search(
                common.rq_index(), q, k=k, n_probe=n_probe, use_bbc=True),
            "bfc": lambda q: flat.search(x, q, k),
        }
        for n_probe in n_probes:
            for name, fn in methods.items():
                if name == "bfc" and n_probe != n_probes[0]:
                    continue
                t = common.timeit(lambda: fn(qs[0]))
                recs = []
                for qi, q in enumerate(qs[:3]):
                    r = fn(q)
                    ids = np.asarray(r[1] if isinstance(r, tuple) else r.ids)
                    recs.append(common.recall(ids, gt_i[qi]))
                rec = float(np.mean(recs))
                qps = 1.0 / t
                common.emit(
                    f"fig1/{name}/k{k}/np{n_probe}", t * 1e6,
                    f"recall={rec:.3f};qps={qps:.2f}")
                results.append(dict(method=name, k=k, n_probe=n_probe,
                                    recall=rec, qps=qps))
    # headline: speedup of +bbc over base at the large k, matched n_probe
    for base in ("ivf+pq", "ivf+rabitq"):
        k = ks[-1]
        b = [r for r in results if r["method"] == base and r["k"] == k]
        a = [r for r in results if r["method"] == base + "+bbc" and r["k"] == k]
        if b and a:
            sp = np.mean([x["qps"] for x in a]) / np.mean([x["qps"] for x in b])
            common.emit(f"fig1/speedup/{base}+bbc/k{k}", 0.0,
                        f"speedup={sp:.2f}x")
    return results


if __name__ == "__main__":
    run()
