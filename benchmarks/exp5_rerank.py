"""Exp-5 / Table 2 analogue: re-ranking counts and time.

  * bounded (RaBitQ): #exact evaluations — baseline threshold criterion vs
    BBC greedy vs the minimal-oracle lower bound (Observation 1), plus the
    Alg. 2 two-heap baseline's count.
  * unbounded (PQ): early-rerank inline coverage — second-pass gathers are
    the HBM-re-read / cache-miss analogue the paper counts in Table 2.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import rerank
from repro.index import search


def run(ks=(500, 2000, 4000)):
    x, qs = common.corpus()
    q = qs[0]
    for k in ks:
        if k * 8 > common.N:
            continue
        # paper operating point: candidates scanned ~= 10x k (n_probe is
        # recall-tuned per k in the paper; k ~ n_scanned is degenerate)
        n_probe = int(np.clip(np.ceil(10 * k * common.N_CLUSTERS / common.N),
                              16, int(common.N_CLUSTERS * 0.8)))
        base = search.ivf_rabitq_search(common.rq_index(), q, k=k,
                                        n_probe=n_probe)
        bbc = search.ivf_rabitq_search(common.rq_index(), q, k=k,
                                       n_probe=n_probe, use_bbc=True)
        t_base = common.timeit(
            lambda: search.ivf_rabitq_search(common.rq_index(), q, k=k,
                                             n_probe=n_probe))
        t_bbc = common.timeit(
            lambda: search.ivf_rabitq_search(common.rq_index(), q, k=k,
                                             n_probe=n_probe, use_bbc=True))
        common.emit(f"exp5/rabitq/k{k}", t_base * 1e6,
                    f"n_rerank_base={int(base.n_reranked)}")
        common.emit(f"exp5/rabitq+bbc/k{k}", t_bbc * 1e6,
                    f"n_rerank_bbc={int(bbc.n_reranked)};"
                    f"reduction={int(base.n_reranked)/max(int(bbc.n_reranked),1):.2f}x")

        # minimal-oracle lower bound on this query's candidate set
        mo = _minimal_count(q, k, n_probe)
        common.emit(f"exp5/minimal_oracle/k{k}", 0.0, f"n_minimal={mo}")

        pq = search.ivf_pq_search(common.pq_index(), q, k=k, n_probe=n_probe,
                                  n_cand=min(8 * k, common.N), use_bbc=True)
        cov = 1.0 - int(pq.n_second_pass) / max(int(pq.n_reranked), 1)
        common.emit(f"exp5/pq_early_rerank/k{k}", 0.0,
                    f"inline_coverage={cov:.3f};"
                    f"second_pass={int(pq.n_second_pass)}")
    return None


def _minimal_count(q, k, n_probe):
    from repro.index import ivf as ivf_mod
    from repro.index import rabitq as rq_mod
    idx = common.rq_index()
    probed = ivf_mod.route(idx.ivf, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(idx.ivf, probed)
    est_l, lb_l, ub_l, ex_l, v_l = [], [], [], [], []
    xs = np.asarray(idx.vectors)
    for c, cid in enumerate(np.asarray(probed)):
        qf = rq_mod.query_factors(idx.rq, q, idx.ivf.centroids[cid])
        cid_ids = np.asarray(ids[c])
        sel = np.maximum(cid_ids, 0)
        est, lb, ub = rq_mod.estimate(
            idx.rq.codes[sel], idx.rq.norm_o[sel], idx.rq.f_o[sel], qf)
        ex = np.linalg.norm(xs[sel] - np.asarray(q), axis=1)
        v = np.asarray(valid[c])
        lb_l.append(np.asarray(lb)); ub_l.append(np.asarray(ub))
        ex_l.append(ex); v_l.append(v)
    lb = np.concatenate(lb_l); ub = np.concatenate(ub_l)
    ex = np.concatenate(ex_l); v = np.concatenate(v_l)
    mask = rerank.minimal_rerank_set(
        jnp.asarray(lb), jnp.asarray(ub), jnp.asarray(np.where(v, ex, np.inf)),
        min(k, int(v.sum())), valid=jnp.asarray(v))
    return int(np.asarray(mask).sum())


if __name__ == "__main__":
    run()
