"""Exp-3 analogue: isolated top-k collector latency (RB vs Heap/Sorted/Lazy
analogues) on streams of estimated distances, k sweep + structural stats."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import collector as col


def run(ks=(500, 2000, 8000), n_tiles=64, tile=512):
    rng = np.random.default_rng(1)
    d = 64
    q = rng.standard_normal(d).astype(np.float32)
    xs = rng.standard_normal((n_tiles * tile, d)).astype(np.float32)
    dists = np.linalg.norm(xs - q, axis=1).reshape(n_tiles, tile)
    s = col.StreamInput(
        jnp.asarray(dists),
        jnp.arange(n_tiles * tile, dtype=jnp.int32).reshape(n_tiles, tile),
        jnp.ones((n_tiles, tile), bool))
    n = n_tiles * tile
    out = {}
    for k in ks:
        if k >= n:
            continue
        for name, fn in col.COLLECTORS.items():
            jfn = jax.jit(functools.partial(fn, k=k))
            t = common.timeit(jfn, s)
            stats = col.collector_stats(name, k, 128, n, tile)
            common.emit(
                f"exp3/{name}/k{k}", t * 1e6,
                f"state_bytes={stats['cross_tile_state_bytes']};"
                f"sel_width={stats['final_selection_width']}")
            out[(name, k)] = t
    # paper claim: RB stays flat with k while heap-analogue degrades
    for k in ks:
        if ("bbc", k) in out and ("topk", k) in out:
            common.emit(f"exp3/ratio_topk_over_bbc/k{k}", 0.0,
                        f"ratio={out[('topk', k)]/out[('bbc', k)]:.2f}")
    return out


if __name__ == "__main__":
    run()
