"""Exp-3 analogue: isolated top-k collector latency (RB vs Heap/Sorted/Lazy
analogues) on streams of estimated distances, k sweep + structural stats.

Two sections: the single-query contenders (including the tile-serial
"streamed" variants the paper benches, vs the single-pass rewrites the
search hot path now uses), and the batched collectors over a (B, n) stream —
per-query amortized latency of one batched collection vs B single ones."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import collector as col


def run(ks=(500, 2000, 8000), n_tiles=64, tile=512, batch=16):
    rng = np.random.default_rng(1)
    d = 64
    q = rng.standard_normal(d).astype(np.float32)
    xs = rng.standard_normal((n_tiles * tile, d)).astype(np.float32)
    dists = np.linalg.norm(xs - q, axis=1).reshape(n_tiles, tile)
    s = col.StreamInput(
        jnp.asarray(dists),
        jnp.arange(n_tiles * tile, dtype=jnp.int32).reshape(n_tiles, tile),
        jnp.ones((n_tiles, tile), bool))
    n = n_tiles * tile
    out = {}
    for k in ks:
        if k >= n:
            continue
        for name, fn in col.COLLECTORS.items():
            jfn = jax.jit(functools.partial(fn, k=k))
            t = common.timeit(jfn, s)
            stats = col.collector_stats(name, k, 128, n, tile)
            common.emit(
                f"exp3/{name}/k{k}", t * 1e6,
                f"state_bytes={stats['cross_tile_state_bytes']};"
                f"sel_width={stats['final_selection_width']}")
            out[(name, k)] = t
    # paper claim: RB stays flat with k while heap-analogue degrades
    for k in ks:
        if ("bbc", k) in out and ("topk", k) in out:
            common.emit(f"exp3/ratio_topk_over_bbc/k{k}", 0.0,
                        f"ratio={out[('topk', k)]/out[('bbc', k)]:.2f}")

    # ---- batched collectors: one (B, n) stream, per-query amortization ----
    qb = rng.standard_normal((batch, d)).astype(np.float32)
    db = np.linalg.norm(xs[None, :, :] - qb[:, None, :], axis=-1)
    dists_b = jnp.asarray(db)
    ids_b = jnp.arange(n, dtype=jnp.int32)
    valid_b = jnp.ones((batch, n), bool)
    for k in ks:
        if k >= n:
            continue
        jb = jax.jit(functools.partial(col.bbc_collect_batch, k=k))
        tb = common.timeit(jb, dists_b, ids_b, valid_b)
        jt = jax.jit(functools.partial(col.topk_collect_batch, k=k))
        tt = common.timeit(jt, dists_b, ids_b, valid_b)
        t1 = out.get(("bbc", k))
        amort = tb / batch
        common.emit(
            f"exp3/bbc_batch/B{batch}/k{k}", amort * 1e6,
            f"batch_total_us={tb * 1e6:.1f};"
            f"vs_single={'%.2f' % (t1 / amort) if t1 else 'n/a'}x")
        common.emit(f"exp3/topk_batch/B{batch}/k{k}", tt / batch * 1e6,
                    f"batch_total_us={tt * 1e6:.1f}")
        out[("bbc_batch", k)] = amort
    return out


if __name__ == "__main__":
    run()
