"""Fig. 2 analogue: per-phase time breakdown (estimate / collect / re-rank)
at small vs large k — shows collector+re-rank shares growing with k for the
baseline and shrinking under BBC."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import collector as col
from repro.index import ivf as ivf_mod
from repro.index import pq as pq_mod


def run(ks=(500, 8000), n_probe=48):
    x, qs = common.corpus()
    q = qs[0]
    index = common.pq_index()

    @jax.jit
    def phase_estimate(q):
        probed = ivf_mod.route(index.ivf, q, n_probe)
        ids, valid = ivf_mod.gather_candidates(index.ivf, probed)
        lut = pq_mod.adc_table(index.pq, q)
        codes = index.codes[jnp.maximum(ids, 0)]
        est = jax.vmap(lambda c: pq_mod.estimate(lut, c))(codes)
        est = jnp.sqrt(jnp.maximum(jnp.where(valid, est, jnp.inf), 0.0))
        return est, ids, valid

    est, ids, valid = phase_estimate(q)
    s = col.StreamInput(est, ids, valid)
    t_est = common.timeit(phase_estimate, q)

    for k in ks:
        n_cand = min(8 * k, common.N)
        t_collect_base = common.timeit(
            jax.jit(functools.partial(col.topk_collect, k=n_cand)), s)
        t_collect_bbc = common.timeit(
            jax.jit(functools.partial(col.bbc_collect, k=n_cand)), s)

        @jax.jit
        def phase_rerank(ci):
            v = x[jnp.maximum(ci, 0)]
            ex = jnp.sqrt(jnp.maximum(
                jnp.sum(v * v, -1) - 2 * (v @ q) + jnp.sum(q * q), 0))
            neg, order = jax.lax.top_k(-jnp.where(ci >= 0, ex, jnp.inf), k)
            return -neg, ci[order]

        _, ci = col.topk_collect(s, n_cand)
        t_rerank = common.timeit(phase_rerank, ci)

        tot_base = t_est + t_collect_base + t_rerank
        tot_bbc = t_est + t_collect_bbc + t_rerank
        common.emit(
            f"fig2/base/k{k}", tot_base * 1e6,
            f"estimate={t_est/tot_base:.2f};collect={t_collect_base/tot_base:.2f};"
            f"rerank={t_rerank/tot_base:.2f}")
        common.emit(
            f"fig2/bbc_collect/k{k}", tot_bbc * 1e6,
            f"collect_share={t_collect_bbc/tot_bbc:.2f};"
            f"collect_speedup={t_collect_base/max(t_collect_bbc,1e-9):.2f}x")
    return None


if __name__ == "__main__":
    run()
