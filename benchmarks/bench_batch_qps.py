"""Batched-engine QPS: search_batch vs sequential single-query calls.

Acceptance benchmark for the batched fused-kernel search engine: B=32
queries through ``search_batch`` must reach >= 3x the QPS of 32 sequential
single-query calls at identical settings, with the same top-k id sets.
Emits CSV rows and writes ``BENCH_batch_qps.json`` next to the repo root
(override with REPRO_BENCH_OUT).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.index import ivf as ivf_mod
from repro.index import search

B = int(os.environ.get("REPRO_BENCH_B", 32))
K = int(os.environ.get("REPRO_BENCH_K", 5000))
N_PROBE = int(os.environ.get("REPRO_BENCH_NPROBE", 64))


def _queries(b: int) -> jax.Array:
    x, _ = common.corpus()
    rng = np.random.default_rng(7)
    return jnp.asarray(synthetic.queries_from(rng, np.asarray(x), b))


def _time_sequential(fn, qs) -> float:
    r = fn(qs[0])
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for q in qs:
        r = fn(q)
    jax.block_until_ready(r)
    return time.perf_counter() - t0


def _time_batch(fn, qs, repeats: int = 3) -> float:
    r = fn(qs)
    jax.block_until_ready(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(qs)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _id_set_match(batch_ids: np.ndarray, single_ids: list[np.ndarray]) -> float:
    """Fraction of queries whose top-k id SET matches exactly.  (Elementwise
    order can differ between the paths only where exact distances tie within
    float accumulation error.)"""
    hits = 0
    for bi, si in enumerate(single_ids):
        hits += set(batch_ids[bi].tolist()) == set(si.tolist())
    return hits / len(single_ids)


def run(b: int = B, k: int = K, n_probe: int = N_PROBE):
    x, _ = common.corpus()
    qs = _queries(b)
    n_cand = min(8 * k, common.N)
    results = []

    pq_index = common.pq_index()
    rq_index = common.rq_index()
    ivf_index = pq_index.ivf       # reuse the routing index for plain IVF
    layout = ivf_mod.flat_layout(ivf_index)
    rq_layout = ivf_mod.flat_layout(rq_index.ivf)  # its OWN cluster layout

    methods = {
        "ivf_bbc": (
            lambda q: search.ivf_search(ivf_index, x, q, k=k,
                                        n_probe=n_probe, use_bbc=True),
            lambda Q: search.ivf_search_batch(ivf_index, x, Q, layout, k=k,
                                              n_probe=n_probe, use_bbc=True),
        ),
        "ivfpq_bbc": (
            lambda q: search.ivf_pq_search(pq_index, q, k=k, n_probe=n_probe,
                                           n_cand=n_cand, use_bbc=True),
            lambda Q: search.ivf_pq_search_batch(pq_index, Q, layout, k=k,
                                                 n_probe=n_probe,
                                                 n_cand=n_cand, use_bbc=True),
        ),
        "ivfrabitq_bbc": (
            lambda q: search.ivf_rabitq_search(rq_index, q, k=k,
                                               n_probe=n_probe, use_bbc=True),
            lambda Q: search.ivf_rabitq_search_batch(rq_index, Q, rq_layout,
                                                     k=k, n_probe=n_probe,
                                                     use_bbc=True),
        ),
    }

    for name, (single, batch) in methods.items():
        t_seq = _time_sequential(single, qs)
        t_batch = _time_batch(batch, qs)
        speedup = t_seq / t_batch
        qps_seq = b / t_seq
        qps_batch = b / t_batch
        # parity: batch ids vs the sequential per-query ids
        br = batch(qs)
        singles = [np.asarray(single(qs[i]).ids) for i in range(b)]
        match = _id_set_match(np.asarray(br.ids), singles)
        common.emit(
            f"batch_qps/{name}/B{b}/k{k}/np{n_probe}",
            t_batch / b * 1e6,
            f"speedup={speedup:.2f}x;qps_batch={qps_batch:.2f};"
            f"qps_seq={qps_seq:.2f};idset_match={match:.3f}")
        results.append(dict(
            method=name, B=b, k=k, n_probe=n_probe,
            seconds_sequential=round(t_seq, 4),
            seconds_batch=round(t_batch, 4),
            qps_sequential=round(qps_seq, 2),
            qps_batch=round(qps_batch, 2),
            speedup=round(speedup, 2),
            topk_idset_match=round(match, 4),
        ))

    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_batch_qps.json")
    payload = {
        "bench": "batch_qps",
        "corpus": {"n": common.N, "d": common.D},
        "config": {"B": b, "k": k, "n_probe": n_probe, "n_cand": n_cand},
        "platform": jax.devices()[0].platform,
        "results": results,
        "acceptance": {
            "min_speedup": min(r["speedup"] for r in results),
            "target": 3.0,
            "pass": all(r["speedup"] >= 3.0 for r in results),
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return results


if __name__ == "__main__":
    run()
