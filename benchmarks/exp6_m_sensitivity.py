"""Exp-6 / Table 3 analogue: collection latency vs number of buckets m.
Validates the flat optimum around the Eq.-3' value and degradation at the
extremes (tiny m -> costly final selection; huge m -> threshold-update cost)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import buffer as rb
from repro.core import collector as col


def run(ms=(8, 32, 128, 256, 512), k=4000, n_tiles=64, tile=512):
    rng = np.random.default_rng(3)
    d = 64
    q = rng.standard_normal(d).astype(np.float32)
    xs = rng.standard_normal((n_tiles * tile, d)).astype(np.float32)
    dists = np.linalg.norm(xs - q, axis=1).reshape(n_tiles, tile)
    s = col.StreamInput(
        jnp.asarray(dists),
        jnp.arange(n_tiles * tile, dtype=jnp.int32).reshape(n_tiles, tile),
        jnp.ones((n_tiles, tile), bool))
    eq3 = rb.default_num_buckets()
    common.emit("exp6/eq3_m", 0.0, f"m={eq3}")
    out = {}
    for m in ms:
        jfn = jax.jit(functools.partial(col.bbc_collect, k=k, m=m))
        t = common.timeit(jfn, s)
        out[m] = t
        common.emit(f"exp6/bbc/m{m}/k{k}", t * 1e6, "")
    return out


if __name__ == "__main__":
    run()
