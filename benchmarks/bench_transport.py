"""Multi-process serving front-end under transport faults (ISSUE 10).

Acceptance benchmark for the socket transport tier
(``repro.transport``): one seeded Zipf trace at **3x single-worker
capacity** (capacity measured live, over the real wire) is driven through
a master + N real worker subprocesses over Unix-domain sockets four
times —

* **fault_free** — clean wire, result cache off: the baseline the faulted
  run's tail and the cached run's payloads are compared against;
* **faulted** — a seeded ``WireSchedule`` (frame drops, duplicate
  delivery, slow-network jitter, truncation, disconnects) plus one worker
  SIGKILL mid-trace; the run is recorded through the wire shim;
* **replay** — the faulted run's transcript re-executed in process
  through a twin engine built from the same spec: the outcome digest must
  be byte-identical to the live run (the record/replay contract);
* **cached** — clean wire with the exact-key result cache on: payloads
  must be id-identical to fault_free and the Zipf head must actually hit.

Every engine call is REAL (workers host the same engines the tests
drive); latencies are client-side wall clock over the socket.

Acceptance (ISSUE 10):

* zero lost requests in every run: completed + shed + failed + rejected
  == offered (conservation over the wire, crash included);
* parity 1.0 vs direct in-process engine calls for every NON-degraded
  faulted-run completion (and n_checked > 0);
* faulted p99 <= 3x fault-free p99;
* replayed digest == recorded digest, zero checksum mismatches;
* cached run id-identical to fault_free on common completions, with a
  non-zero cache hit rate.

Writes ``BENCH_transport.json`` (override with REPRO_BENCH_OUT).  Scale
via REPRO_NET_N / REPRO_NET_D / REPRO_NET_KS / REPRO_NET_NREQ /
REPRO_NET_WORKERS / REPRO_NET_RATE_X / REPRO_NET_DEADLINE; fault rates
via REPRO_NET_DROP / _DUP / _SLOW / _TRUNCATE / _DISCONNECT /
_WIRE_SEED.  CI's transport chaos smoke runs a tiny configuration with
REPRO_NET_STRICT=1.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from repro.data import synthetic
from repro.serving import faults as flt
from repro.serving import server as sv_server
from repro.serving.batcher import k_ceilings
from repro.serving.queue import make_zipf_trace
from repro.serving.router import outcome_digest
from repro.transport.client import NetClient
from repro.transport.core import MasterConfig
from repro.transport.enginehost import (build_spec, build_state_from_spec,
                                        make_dataset, make_exec_fn)
from repro.transport.master import MasterServer
from repro.transport.replay import replay_transcript
from repro.transport.wire import Transcript

N = int(os.environ.get("REPRO_NET_N", 16_384))
D = int(os.environ.get("REPRO_NET_D", 32))
KS = tuple(int(s) for s in
           os.environ.get("REPRO_NET_KS", "10,100,1000").split(","))
NREQ = int(os.environ.get("REPRO_NET_NREQ", 400))
N_PROBE = int(os.environ.get("REPRO_NET_NPROBE", 16))
N_WORKERS = int(os.environ.get("REPRO_NET_WORKERS", 3))
RATE_X = float(os.environ.get("REPRO_NET_RATE_X", 3.0))
DEADLINE = float(os.environ.get("REPRO_NET_DEADLINE", 3.0))
SEED = int(os.environ.get("REPRO_NET_SEED", 0))
POOL = int(os.environ.get("REPRO_NET_POOL", 32))
CACHE = int(os.environ.get("REPRO_NET_CACHE", 256))
SETTLE = float(os.environ.get("REPRO_NET_SETTLE", 30.0))
CRASH_FRAC = float(os.environ.get("REPRO_NET_CRASH_FRAC", 0.4))
WIRE_SEED = int(os.environ.get("REPRO_NET_WIRE_SEED", 11))
DROP = float(os.environ.get("REPRO_NET_DROP", 0.02))
DUP = float(os.environ.get("REPRO_NET_DUP", 0.01))
SLOW = float(os.environ.get("REPRO_NET_SLOW", 0.08))
TRUNCATE = float(os.environ.get("REPRO_NET_TRUNCATE", 0.005))
DISCONNECT = float(os.environ.get("REPRO_NET_DISCONNECT", 0.005))
STRICT = os.environ.get("REPRO_NET_STRICT", "0") == "1"

# calibration probes use client-side rids far above the trace's; the
# master numbers requests itself, so runs exclude them by outcome
# snapshot (see _run), not by rid
PROBE_BASE = 10**6
PROBES_PER_K = int(os.environ.get("REPRO_NET_PROBES", 6))


def _cfg(cache: bool) -> MasterConfig:
    return MasterConfig(n_workers=N_WORKERS, ceilings=k_ceilings(KS),
                        cache_size=CACHE if cache else 0)


def _calibrate(addr) -> float:
    """Mean round-trip seconds of a singleton request over the real wire,
    averaged across the serving buckets — 1/this is what 'single-worker
    capacity' means for an open-loop trace."""
    rng = np.random.default_rng(SEED + 99)
    rtts: list[float] = []
    with NetClient(addr) as c:
        rid = PROBE_BASE
        for k in KS:
            for _ in range(PROBES_PER_K):
                q = rng.standard_normal(D).astype(np.float32)
                t0 = time.monotonic()
                c.send_request(rid, q, int(k), N_PROBE, 30.0)
                reply = c.recv_reply(timeout=30.0)
                assert reply is not None and reply.get("rid") == rid, reply
                rtts.append(time.monotonic() - t0)
                rid += 1
    # drop the slowest probe per bucket: first-touch jitter (page faults,
    # route-memo misses) is not steady-state capacity
    rtts = sorted(rtts)[:max(1, len(rtts) - len(KS))]
    return float(np.mean(rtts))


def _run(mode: str, server: MasterServer, trace, *,
         crash_at: float | None = None) -> dict:
    """Drive ``trace`` through a serving master; returns records + the
    master-side decision log.  Caller owns the serve loop and shutdown."""
    if crash_at is not None:
        def killer():
            time.sleep(crash_at)
            p = server.procs.get(0)
            if p is not None and p.poll() is None:
                p.kill()
        threading.Thread(target=killer, daemon=True).start()
    # the core numbers requests itself, so calibration probes are excluded
    # by snapshot, not by client-side rid
    pre = {o.request.rid for o in server.core.outcome_list()}
    t0 = time.monotonic()
    with NetClient(server.addr) as c:
        records = c.run_trace(trace, settle=SETTLE)
    wall = time.monotonic() - t0
    outcomes = [o for o in server.core.outcome_list()
                if o.request.rid not in pre]
    return {"mode": mode, "records": records, "outcomes": outcomes,
            "digest": outcome_digest(outcomes),
            "stats": dict(server.core.stats),
            "faults": server.shim.fault_counts(), "wall_s": wall}


def _row(run: dict, n_trace: int) -> dict:
    s = sv_server.summarize(run["outcomes"])
    lats = sorted(r["latency_s"] for r in run["records"].values()
                  if r["status"] in ("ok", "degraded"))
    def pct(p):
        if not lats:
            return None
        return round(lats[min(len(lats) - 1, int(p * len(lats)))] * 1e3, 3)
    stats = run["stats"]
    return {
        "mode": run["mode"], "digest": run["digest"],
        "offered": n_trace, "completed": s["completed"],
        "degraded": sum(1 for o in run["outcomes"]
                        if o.status == sv_server.DEGRADED),
        "shed": s["shed"], "failed": s["failed"],
        "rejected": s["rejected"], "conserved": bool(s["conserved"]),
        "client_replies": len(run["records"]),
        "p50_ms": pct(0.50), "p99_ms": pct(0.99),
        "qps": round(s["completed"] / max(run["wall_s"], 1e-9), 1),
        "retries": stats.get("retries", 0),
        "worker_lost": stats.get("worker_lost", 0),
        "corrupt_detected": stats.get("corrupt_detected", 0),
        "cache_hits": stats.get("cache_hits", 0),
        "wire_faults": dict(run["faults"]),
        "wall_s": round(run["wall_s"], 3),
    }


def main() -> None:
    spec = build_spec(n=N, d=D, seed=SEED, ks=KS, n_probe=N_PROBE)
    print(f"[transport] spec n={N} d={D} ks={KS} n_probe={N_PROBE} "
          f"workers={N_WORKERS}", flush=True)
    state, ceilings = build_state_from_spec(spec)
    exec_fn = make_exec_fn(state, ceilings)

    wire = flt.WireSchedule(seed=WIRE_SEED, drop=DROP, dup=DUP, slow=SLOW,
                            truncate=TRUNCATE, disconnect=DISCONNECT)
    rng = np.random.default_rng(SEED)
    pool = synthetic.queries_from(rng, make_dataset(spec), POOL)

    runs: dict[str, dict] = {}
    transcript_blob = None
    mean_rtt = rate = None
    plans = [("fault_free", None, False, False),
             ("faulted", wire, False, True),
             ("cached", None, True, False)]
    for mode, sched, cache, record in plans:
        server = MasterServer(_cfg(cache), spec, wire=sched, record=record)
        server.start()
        stop = threading.Event()
        th = threading.Thread(
            target=lambda: server.serve(until=stop.is_set), daemon=True)
        try:
            assert server.wait_workers(timeout=600.0), \
                f"{mode}: workers never came up"
            th.start()
            if rate is None:
                # capacity is measured over THIS wire, on the fault-free
                # server, before the trace exists — the offered rate is
                # 3x what one worker can serially sustain end to end
                mean_rtt = _calibrate(server.addr)
                rate = RATE_X / mean_rtt
                print(f"[transport] mean_rtt={mean_rtt * 1e3:.3f} ms "
                      f"-> offered rate {rate:.1f} req/s", flush=True)
                trace = make_zipf_trace(
                    np.random.default_rng(SEED + 1), pool, NREQ, KS,
                    rate=rate, deadline=DEADLINE, n_probe=N_PROBE)
                span = trace[-1].arrival - trace[0].arrival
            crash = span * CRASH_FRAC if mode == "faulted" else None
            runs[mode] = _run(mode, server, trace, crash_at=crash)
            if record:
                transcript_blob = server.transcript.dumps()
        finally:
            stop.set()
            if th.is_alive():
                th.join(timeout=10.0)
            server.shutdown()
        print(f"[transport] {mode}: "
              f"{json.dumps(_row(runs[mode], NREQ))}", flush=True)

    # -- replay: the faulted transcript through the in-process twin ----------
    tr = Transcript.loads(transcript_blob)
    t0 = time.monotonic()
    res = replay_transcript(tr, _cfg(False), state.centroids, exec_fn,
                            strict=False)
    runs["replay"] = {
        "mode": "replay", "records": {}, "outcomes": res.outcomes,
        "digest": res.digest, "stats": dict(res.core.stats),
        "faults": {}, "wall_s": time.monotonic() - t0}

    rows = {mode: _row(run, NREQ) for mode, run in runs.items()}

    # -- gates ---------------------------------------------------------------
    conserved = all(r["conserved"] for r in rows.values()) and all(
        r["completed"] + r["shed"] + r["failed"] + r["rejected"] == NREQ
        for r in rows.values())

    by_rid = {r.rid: r for r in trace}
    n_checked, n_match = 0, 0
    for rid, rec in runs["faulted"]["records"].items():
        if rec["status"] != "ok":       # non-degraded completions only
            continue
        req = by_rid[rid]
        _, ids = exec_fn(req.q, req.k, req.n_probe)
        n_checked += 1
        n_match += int(np.array_equal(np.asarray(rec["ids"]),
                                      np.asarray(ids)))
    parity = n_match / n_checked if n_checked else 0.0

    p99_free, p99_fault = rows["fault_free"]["p99_ms"], \
        rows["faulted"]["p99_ms"]
    p99_ok = bool(p99_free is not None and p99_fault is not None
                  and p99_fault <= 3.0 * p99_free)

    replay_identical = bool(
        res.digest == runs["faulted"]["digest"]
        and not res.checksum_mismatches
        and res.core.stats == runs["faulted"]["stats"])

    free_recs = runs["fault_free"]["records"]
    cache_recs = runs["cached"]["records"]
    common_done = [rid for rid in cache_recs
                   if cache_recs[rid]["status"] in ("ok", "degraded")
                   and free_recs.get(rid, {}).get("status")
                   in ("ok", "degraded")]
    cache_identical = bool(common_done) and all(
        np.array_equal(np.asarray(cache_recs[rid]["ids"]),
                       np.asarray(free_recs[rid]["ids"]))
        for rid in common_done)
    hit_rate = rows["cached"]["cache_hits"] / NREQ
    cache_ok = bool(cache_identical and hit_rate > 0.0)

    acceptance = {
        "conserved": conserved,
        "parity_non_degraded": round(parity, 4),
        "parity_checked": n_checked,
        "p99_fault_free_ms": p99_free,
        "p99_faulted_ms": p99_fault,
        "p99_ratio_limit": 3.0,
        "p99_ok": p99_ok,
        "replay_identical": replay_identical,
        "replay_checksum_mismatches": len(res.checksum_mismatches),
        "cache_identical_vs_fault_free": cache_identical,
        "cache_common_completions": len(common_done),
        "cache_hit_rate": round(hit_rate, 4),
        # n_checked > 0 guards the vacuous case (every completion degraded)
        "pass": bool(conserved and parity == 1.0 and n_checked > 0
                     and p99_ok and replay_identical and cache_ok),
    }

    payload = {
        "bench": "transport",
        "spec": spec,
        "config": {
            "n_requests": NREQ, "n_workers": N_WORKERS, "pool": POOL,
            "rate_x_single_worker_capacity": RATE_X,
            "mean_rtt_ms": round(mean_rtt * 1e3, 3),
            "offered_rate": round(rate, 1),
            "deadline_s": DEADLINE, "cache_size": CACHE,
            "crash_frac": CRASH_FRAC,
            "wire": wire.to_dict(),
        },
        "results": [rows[m] for m in
                    ("fault_free", "faulted", "replay", "cached")],
        "acceptance": acceptance,
    }
    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_transport.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[transport] acceptance: {json.dumps(acceptance)}", flush=True)
    print(f"[transport] wrote {out_path}", flush=True)
    if STRICT and not acceptance["pass"]:
        raise SystemExit("transport acceptance gates FAILED")


if __name__ == "__main__":
    main()
