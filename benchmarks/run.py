"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.  Scale via REPRO_BENCH_N."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_autotune, bench_batch_qps, bench_ingest,
                            bench_rabitq_fused, bench_serve, bench_tau_pred,
                            exp2_relative_error, exp3_collector_latency,
                            exp4_threshold_gap, exp5_rerank,
                            exp6_m_sensitivity, fig1_qps_recall,
                            fig2_breakdown, perf_cell_c, table4_ncand,
                            table6_memory)
    suites = [
        # first: later suites resolve knobs from the store this one writes
        ("bench_autotune", bench_autotune.run),
        ("fig1_qps_recall", fig1_qps_recall.run),
        ("bench_batch_qps", bench_batch_qps.run),
        ("bench_tau_pred", bench_tau_pred.run),
        ("bench_rabitq_fused", bench_rabitq_fused.run),
        ("bench_serve", bench_serve.run),
        ("bench_ingest", bench_ingest.run),
        ("fig2_breakdown", fig2_breakdown.run),
        ("exp2_relative_error", exp2_relative_error.run),
        ("exp3_collector_latency", exp3_collector_latency.run),
        ("exp4_threshold_gap", exp4_threshold_gap.run),
        ("exp5_rerank", exp5_rerank.run),
        ("exp6_m_sensitivity", exp6_m_sensitivity.run),
        ("table4_ncand", table4_ncand.run),
        ("table6_memory", table6_memory.run),
        ("perf_cell_c", perf_cell_c.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.monotonic()
        try:
            fn()
            print(f"# {name} done in {time.monotonic()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
