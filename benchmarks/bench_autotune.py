"""Constrained auto-tuning acceptance bench: solve, gate, persist.

Runs the tuner (``repro.tuning``) for every method at each k in
REPRO_AT_KS (default 5000 and the k ~= N extreme) on a held-out query set
with exact ground truth, then gates the solved operating points on the
ISSUE's acceptance criteria:

* **recall** — the tuned point at the primary target meets
  recall@k >= 0.95 on the held-out queries (``feasible`` from the solver);
* **QPS** — the tuned point's measured throughput is >= the hand-tuned
  baseline it replaces.  The baseline is the PR 1-7 default configuration
  when that configuration is itself feasible; when it is not (k ~= N, where
  n_probe=64 cannot reach the target), the baseline is the cheapest
  hand-style fix — the default with n_probe raised along the grid until
  feasible — because that is the configuration an operator would have
  hand-picked.  With no feasible hand baseline at all the QPS comparison is
  vacuous and reported null.  REPRO_AT_QPS_TOL (default 1.0) relaxes the
  ratio for tiny CI-smoke sizes where wall-clock noise dominates;
* **determinism** — with REPRO_AT_REPLAY=1 the whole sweep re-runs
  (untimed) and the canonical point JSON must be byte-identical.

Solved points are persisted to the operating-point store
(``tuned_points.json`` / REPRO_TUNED_POINTS) unless REPRO_AT_NO_STORE=1;
the bench JSON goes to BENCH_autotune.json (REPRO_BENCH_OUT).  Strict
gating for CI: REPRO_AT_STRICT=1.

Scale via REPRO_BENCH_N / REPRO_AT_KS / REPRO_AT_Q / REPRO_AT_SEED.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.tuning import autotune, knobs, measure
from repro.tuning import points as tn_points
from repro.tuning import solver

KS = tuple(int(s) for s in
           os.environ.get("REPRO_AT_KS", f"5000,{common.N}").split(","))
N_HELDOUT = int(os.environ.get("REPRO_AT_Q", 8))
SEED = int(os.environ.get("REPRO_AT_SEED", 0))
TARGET = 0.95
QPS_TOL = float(os.environ.get("REPRO_AT_QPS_TOL", 1.0))


def _heldout_queries(x: np.ndarray) -> np.ndarray:
    """Held-out query set: drawn from the corpus distribution with a seed
    DISJOINT from every other bench's query seed, so tuned points are never
    solved on the queries they are later evaluated with."""
    rng = np.random.default_rng(10_007)
    return np.asarray(synthetic.queries_from(rng, x, N_HELDOUT))


def _qps_wall(sample, n_queries: int) -> float | None:
    if sample is None or sample.wall_s is None:
        return None
    return round(n_queries / sample.wall_s, 2)


def _hand_baseline(cell, samples):
    """The hand-tuned configuration the tuned point must beat: the PR 1-7
    default when feasible, else the default with n_probe raised along the
    grid to the smallest feasible width (the fix an operator would
    hand-pick); None when no hand-style configuration reaches the target."""
    default = knobs.default_config(cell)
    by_key = {s.knobs.key(): s for s in samples}
    for n_probe in sorted(knobs.grid(cell)["n_probe"]):
        if n_probe < default.n_probe:
            continue
        cfg = knobs.clamp(knobs.KnobConfig(
            n_probe=n_probe, n_cand=default.n_cand,
            pred_count=default.pred_count, fused=default.fused,
            budget_slack=default.budget_slack), cell)
        s = by_key.get(cfg.key())
        if s is not None and s.recall >= TARGET:
            return s
    return None


def _tune_all(index_for, x, queries, gt_by_k, *, timed: bool):
    """One full tuner pass over every (method, k) cell; returns
    (points, per-cell records keyed "method/k")."""
    points, cells = [], {}
    fp = tn_points.corpus_fingerprint(x)
    corpus = {"kind": common.CORPUS, "fingerprint": fp}
    for method in knobs.METHODS:
        index, extra = index_for(method)
        for k_req in KS:
            k = min(k_req, common.N)
            out = autotune.tune_cell(
                index, k, queries, gt_by_k[k], vectors=extra.get("vectors"),
                seed=SEED, corpus=dict(corpus), timed=timed)
            points.extend(out["points"])
            cells[f"{method}/{k}"] = out
    return points, cells


def run():
    x_j, _ = common.corpus()
    x = np.asarray(x_j)
    queries = _heldout_queries(x)
    gt_by_k = {min(k, common.N): None for k in KS}
    for k in gt_by_k:
        gt_by_k[k] = measure.ground_truth_ids(x, queries, k)

    def index_for(method):
        if method == "ivf":
            return common.pq_index().ivf, {"vectors": x_j}
        if method == "ivfpq":
            return common.pq_index(), {}
        return common.rq_index(), {}

    points, cells = _tune_all(index_for, x, queries, gt_by_k, timed=True)

    # -- determinism gate: untimed replay must serialize identically -------
    replay_identical = None
    if os.environ.get("REPRO_AT_REPLAY") == "1":
        points2, _ = _tune_all(index_for, x, queries, gt_by_k, timed=False)
        replay_identical = bool(
            tn_points.canonical_json(points) ==
            tn_points.canonical_json(points2))

    # -- per-cell acceptance rows ------------------------------------------
    results = []
    for cell_key, out in cells.items():
        method, k_s = cell_key.split("/")
        k = int(k_s)
        primary = next(p for p in out["points"]
                       if p.recall_target == TARGET)
        tuned_sample = next(s for s in out["samples"]
                            if s.knobs.key() == primary.knobs.key())
        baseline = _hand_baseline(out["cell"], out["samples"])
        qps_tuned = _qps_wall(tuned_sample, len(queries))
        qps_base = _qps_wall(baseline, len(queries))
        qps_ok = True if qps_base is None or qps_tuned is None \
            else bool(qps_tuned >= QPS_TOL * qps_base)
        row = {
            "method": method, "k": k, "recall_target": TARGET,
            "point": primary.name, "knobs": primary.to_json()["knobs"],
            "recall": primary.recall, "feasible": primary.feasible,
            "cost_units": primary.cost_units,
            "qps_tuned": qps_tuned,
            "baseline_knobs": None if baseline is None
            else baseline.knobs.key(),
            "baseline_recall": None if baseline is None
            else baseline.recall,
            "qps_hand_baseline": qps_base,
            "qps_ratio": None if not qps_base or not qps_tuned
            else round(qps_tuned / qps_base, 3),
            "qps_ok": qps_ok,
            "default_recall": None if out["default"] is None
            else out["default"].recall,
            "qps_default": _qps_wall(out["default"], len(queries)),
            "n_configs": len(out["samples"]),
            "frontier": [{"recall": s.recall, "cost_units": s.cost_units,
                          "knobs": s.knobs.key()}
                         for s in out["frontier"]],
            "cost_model": out["cost_model"],
        }
        results.append(row)
        common.emit(
            f"autotune/{method}/k{k}",
            0.0 if tuned_sample.wall_s is None
            else tuned_sample.wall_s / len(queries) * 1e6,
            f"recall={primary.recall};feasible={primary.feasible};"
            f"qps_ratio={row['qps_ratio']}")

    # -- persist the store --------------------------------------------------
    store_path = None
    if os.environ.get("REPRO_AT_NO_STORE") != "1":
        store = tn_points.PointStore.load()
        for p in points:
            store.add(p)
        store_path = store.save()
        print(f"# wrote {store_path}", flush=True)

    recall_all = all(r["feasible"] for r in results)
    qps_all = all(r["qps_ok"] for r in results)
    payload = {
        "bench": "autotune",
        "corpus": {"n": common.N, "d": common.D, "kind": common.CORPUS,
                   "fingerprint": tn_points.corpus_fingerprint(x)},
        "config": {"ks": [min(k, common.N) for k in KS],
                   "n_heldout": len(queries), "seed": SEED,
                   "targets": list(autotune.DEFAULT_TARGETS),
                   "qps_tol": QPS_TOL,
                   "cost_weights": {"w_rerank": measure.W_RERANK,
                                    "w_second": measure.W_SECOND},
                   "lam_max": solver.LAM_MAX},
        "store_path": store_path,
        "results": results,
        "replay_identical": replay_identical,
        "acceptance": {
            "claim": "for every method/k cell the tuned operating point "
                     "meets recall@k >= 0.95 on held-out queries with QPS "
                     ">= the (feasible) hand-tuned baseline it replaces; "
                     "re-runs serialize byte-identically",
            "recall_all_feasible": recall_all,
            "qps_all_ok": qps_all,
            "replay_identical": replay_identical,
            "pass": bool(recall_all and qps_all
                         and replay_identical is not False),
        },
    }
    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_autotune.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)

    if os.environ.get("REPRO_AT_STRICT") == "1" \
            and not payload["acceptance"]["pass"]:
        raise SystemExit("bench_autotune acceptance gate failed: "
                         + json.dumps(payload["acceptance"], indent=2))
    return payload


if __name__ == "__main__":
    run()
