"""Table 4 analogue: smallest n_cand reaching the target recall per k
(the IVF+PQ tuning knob the paper tabulates per dataset)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.index import search


def run(ks=(500, 2000), target=0.9, n_probe=56):
    x, qs = common.corpus()
    for k in ks:
        gt_d, gt_i = common.ground_truth(k)
        found = None
        for mult in (2, 4, 8, 12):
            n_cand = min(mult * k, common.N)
            recs = []
            for qi, q in enumerate(qs[:3]):
                r = search.ivf_pq_search(common.pq_index(), q, k=k,
                                         n_probe=n_probe, n_cand=n_cand,
                                         use_bbc=True)
                recs.append(common.recall(np.asarray(r.ids), gt_i[qi]))
            if np.mean(recs) >= target:
                found = (n_cand, float(np.mean(recs)))
                break
        if found:
            common.emit(f"table4/k{k}", 0.0,
                        f"n_cand={found[0]};recall={found[1]:.3f}")
        else:
            common.emit(f"table4/k{k}", 0.0, f"n_cand>12k;target_missed")
    return None


if __name__ == "__main__":
    run()
