"""Streaming ingest under live queries: churn, merge, and rolling swaps.

Acceptance benchmark for ``src/repro/ingest``: one corpus is churned (10%
inserted, 5% deleted — deletes biased toward the queries' true neighbors
so staleness would be visible) while queries run continuously, then merged
back into a frozen index through the checkpointed background job — with an
injected crash + resume on the first attempt — and finally rolled through
a replica pool with ``ReplicaPool.rolling_swap``.

Acceptance (ISSUE 9), written to ``BENCH_ingest.json``:

* recall@k vs exact ground truth on the LIVE corpus >= 0.95 at every
  churn checkpoint (mid-churn, post-crash, post-merge);
* deleted ids NEVER appear in any result, at any point;
* the mid-merge crash recovers via the checksummed checkpoint
  (``resume_merge``) with no index corruption;
* the rolling engine swap completes with zero shed/failed requests
  (every batch offered mid-roll completes with full shape);
* post-merge QPS >= 0.9x a frozen index built directly on the same
  live corpus.

Scale via REPRO_IN_N / REPRO_IN_D / REPRO_IN_K / REPRO_IN_NQ /
REPRO_IN_NPROBE / REPRO_IN_REPLICAS; CI runs a tiny configuration with
REPRO_IN_STRICT=1.  Output path override: REPRO_BENCH_OUT.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data import synthetic
from repro.index import search
from repro.ingest import IngestConfig, MergeCrash, MergeJob, MutableIndex, \
    resume_merge
from repro.kernels import ops

N = int(os.environ.get("REPRO_IN_N", 40_000))
D = int(os.environ.get("REPRO_IN_D", 48))
K = int(os.environ.get("REPRO_IN_K", 5000))
NQ = int(os.environ.get("REPRO_IN_NQ", 16))
N_PROBE = int(os.environ.get("REPRO_IN_NPROBE", 0)) or None
N_REPLICAS = int(os.environ.get("REPRO_IN_REPLICAS", 3))
INSERT_FRAC = 0.10
DELETE_FRAC = 0.05
RECALL_FLOOR = 0.95
QPS_RATIO_FLOOR = 0.90


def _exact_live_gt(mi: MutableIndex, qs: np.ndarray, k: int) -> np.ndarray:
    x, ids = mi.live_corpus()
    d = np.asarray(ops.l2_exact_batch(jnp.asarray(x), jnp.asarray(qs)))
    pos = np.argsort(d, axis=1, kind="stable")[:, :k]
    return ids[pos]


def _recall_and_leaks(mi: MutableIndex, qs: np.ndarray, k: int,
                      dead: set) -> tuple[float, int]:
    want = _exact_live_gt(mi, qs, k)
    got = np.asarray(mi.search(qs).ids)
    hits = sum(len(set(got[bi].tolist()) & set(want[bi].tolist()))
               for bi in range(len(qs)))
    leaks = len(set(got.reshape(-1).tolist()) & dead)
    return hits / want.size, leaks


def _qps(search_fn, qs, repeats: int = 3) -> float:
    search_fn(qs)                                  # warm/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = search_fn(qs)
        if hasattr(res, "dists") and hasattr(res.dists, "block_until_ready"):
            jax.block_until_ready((res.dists, res.ids))
        ts.append(time.perf_counter() - t0)
    return len(qs) / float(np.median(ts))


def run():  # noqa: D103
    rng = np.random.default_rng(42)
    x = common.make_corpus(rng, N, D).astype(np.float32)
    qs = np.asarray(synthetic.queries_from(
        np.random.default_rng(7), x, NQ)).astype(np.float32)
    n_clusters = max(int(np.sqrt(N)), 16)
    # large-k default: covering the top-5000 of a 40k corpus takes a wide
    # probe (0.4 * n_clusters holds recall ~0.99 at the committed size)
    n_probe = N_PROBE or max(8, (2 * n_clusters) // 5)
    k = min(K, N // 2)
    kw = dict(k=k, n_probe=n_probe, n_clusters=n_clusters,
              n_cand=min(8 * k, N), seed=0,
              config=IngestConfig(segment_capacity=1024))
    mi = MutableIndex(x, "ivfpq", **kw)

    dead: set[int] = set()
    checkpoints = {}

    # ---- churn under live queries: 10% inserted, 5% deleted ---------------
    n_ins = int(N * INSERT_FRAC)
    n_del = int(N * DELETE_FRAC)
    ins_vecs = np.concatenate([
        qs + rng.normal(scale=1e-3, size=(NQ, D)).astype(np.float32),
        common.make_corpus(np.random.default_rng(13), n_ins - NQ, D,
                           ).astype(np.float32)])
    new_ids = np.concatenate([
        mi.insert(chunk) for chunk in np.array_split(ins_vecs, 4)])
    # deletes biased toward the queries' current neighbors (staleness
    # would surface immediately) + uniform base rows + a few delta rows
    first = np.asarray(mi.search(qs).ids)
    doomed = np.unique(first[:, :25].reshape(-1))
    doomed = doomed[doomed >= 0]
    uniform = rng.choice(N, size=n_del, replace=False)
    victims = np.unique(np.concatenate(
        [doomed, uniform, new_ids[:NQ // 2]]))[:n_del]
    mi.delete(victims)
    dead |= set(int(i) for i in victims)
    rec, leaks = _recall_and_leaks(mi, qs, k, dead)
    checkpoints["mid_churn"] = {"recall": round(rec, 4), "leaks": leaks,
                                "churn": round(mi.churn_fraction(), 4)}
    common.emit("ingest/mid_churn", 0.0,
                f"recall={rec:.4f};leaks={leaks}")

    # ---- crash-injected merge + checksummed recovery ----------------------
    with tempfile.TemporaryDirectory() as ckpt_dir:
        crashed = False
        try:
            MergeJob(mi, ckpt_dir).run(crash_after_checkpoint=True)
        except MergeCrash:
            crashed = True
        rec_c, leaks_c = _recall_and_leaks(mi, qs, k, dead)   # mid-crash
        # deletes landing DURING the merge window must not resurrect
        mid_merge_victim = int(np.asarray(mi.search(qs).ids)[0, 0])
        mi.delete(np.array([mid_merge_victim]))
        dead.add(mid_merge_victim)
        resume_merge(mi, ckpt_dir)
    rec_m, leaks_m = _recall_and_leaks(mi, qs, k, dead)
    recovered = bool(crashed and mi.generation == 1 and not mi.segments)
    checkpoints["post_crash_serving"] = {"recall": round(rec_c, 4),
                                         "leaks": leaks_c}
    checkpoints["post_merge"] = {"recall": round(rec_m, 4),
                                 "leaks": leaks_m,
                                 "generation": mi.generation}
    common.emit("ingest/post_merge", 0.0,
                f"recall={rec_m:.4f};leaks={leaks_m};recovered={recovered}")

    # ---- post-merge QPS vs a frozen index on the same live corpus ---------
    live_x, _ = mi.live_corpus()
    frozen_idx = search.build_pq_index(
        jax.random.key(0), jnp.asarray(live_x), n_clusters, n_iter=6)
    from repro.index import engine as engine_mod
    frozen = engine_mod.SearchEngine.build(
        frozen_idx, k=k, n_probe=n_probe, n_cand=min(8 * k, len(live_x)),
        use_bbc=True)
    jq = jnp.asarray(qs)
    qps_frozen = _qps(lambda q: frozen.search_batch(q), jq)
    qps_merged = _qps(lambda q: mi.search(np.asarray(q)), jq)
    qps_ratio = qps_merged / max(qps_frozen, 1e-9)
    common.emit("ingest/qps", 1e6 * NQ / max(qps_merged, 1e-9),
                f"qps_merged={qps_merged:.1f};qps_frozen={qps_frozen:.1f}")

    # ---- zero-shed rolling swap through the replica pool ------------------
    from repro.serving.batcher import Batch, Request, ShapeBucket
    from repro.serving.replica import ReplicaPool
    from repro.serving.state import ServingState
    base = ServingState(frozen_idx, use_bbc=True, tau_pred=True, m=128,
                        pred_count=min(8 * k, len(live_x)),
                        vectors=None)
    bucket = ShapeBucket(k=k, batch=NQ, n_probe=n_probe)
    pool = ReplicaPool(base, N_REPLICAS, [k], NQ,
                       service_est=lambda b: 1e-3)
    pool.base.warmup([bucket])

    def mk_batch():
        reqs = tuple(Request(rid=i, q=qs[i], k=k, n_probe=n_probe,
                             arrival=0.0, deadline=1.0)
                     for i in range(NQ))
        return Batch(bucket=bucket, requests=reqs, queries=jq)

    for r in pool:                                 # warm the predictors
        r.state.run(mk_batch())
    next_idx = search.build_pq_index(
        jax.random.key(1), jnp.asarray(live_x), n_clusters, n_iter=6)
    offered = completed = failed = 0

    def on_step(_rid):
        nonlocal offered, completed, failed
        for r in pool:
            offered += NQ
            try:
                res = r.state.run(mk_batch())
                ok = np.asarray(res.ids).shape == (NQ, k)
                completed += NQ if ok else 0
                failed += 0 if ok else NQ
            except Exception:  # noqa: BLE001
                failed += NQ
    report = pool.rolling_swap(next_idx, probe_qs=jq, warm_buckets=[bucket],
                               on_step=on_step)
    zero_shed = bool(offered > 0 and completed == offered and failed == 0)
    all_new_gen = all(r.generation == 1 for r in pool)
    drift = {f"k{kk}_np{np_}": {"tv": round(v["tv"], 4),
                                "carried": v["carried"]}
             for (kk, np_), v in report.items()}
    common.emit("ingest/rolling_swap", 0.0,
                f"offered={offered};completed={completed};failed={failed}")

    recall_ok = min(rec, rec_c, rec_m) >= RECALL_FLOOR
    payload = {
        "bench": "ingest",
        "corpus": {"n": N, "d": D, "corpus": common.CORPUS},
        "config": {
            "k": k, "n_probe": n_probe, "n_clusters": n_clusters,
            "n_queries": NQ, "n_replicas": N_REPLICAS,
            "inserted": int(len(new_ids)), "deleted": int(len(dead)),
            "insert_frac": INSERT_FRAC, "delete_frac": DELETE_FRAC,
        },
        "platform": jax.devices()[0].platform,
        "results": {
            "checkpoints": checkpoints,
            "qps_merged": round(qps_merged, 2),
            "qps_frozen": round(qps_frozen, 2),
            "qps_ratio": round(qps_ratio, 4),
            "swap": {"offered": offered, "completed": completed,
                     "failed": failed, "drift_report": drift},
        },
        "acceptance": {
            "recall_floor": RECALL_FLOOR,
            "recall_min": round(min(rec, rec_c, rec_m), 4),
            "deleted_surfaced": leaks + leaks_c + leaks_m,
            "crash_recovered": recovered,
            "swap_zero_shed": zero_shed,
            "swap_all_new_generation": all_new_gen,
            "qps_ratio_floor": QPS_RATIO_FLOOR,
            "qps_ratio": round(qps_ratio, 4),
            "pass": bool(recall_ok and leaks + leaks_c + leaks_m == 0
                         and recovered and zero_shed and all_new_gen
                         and qps_ratio >= QPS_RATIO_FLOOR),
        },
    }
    out_path = os.environ.get("REPRO_BENCH_OUT", "BENCH_ingest.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    if os.environ.get("REPRO_IN_STRICT") == "1" and \
            not payload["acceptance"]["pass"]:
        raise SystemExit(
            f"bench_ingest acceptance failed: {payload['acceptance']}")
    return payload


if __name__ == "__main__":
    run()
