"""Regenerate EXPERIMENTS.md tables from dryrun/refresh/hillclimb JSONs.

  PYTHONPATH=src python scripts/make_experiments.py
"""
from __future__ import annotations

import glob
import json
import os

BASE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_cells():
    cells = {}
    def ingest(path):
        try:
            for x in json.load(open(path)):
                key = (x["arch"], x["shape"], x["mesh"])
                cells[key] = x
        except Exception:
            pass
    ingest(os.path.join(BASE, "dryrun_results.json"))
    for p in sorted(glob.glob("/tmp/refresh_*.json")):
        ingest(p)
    return cells


def fmt_s(v):
    if v == 0:
        return "0"
    if v < 1e-4:
        return f"{v:.1e}"
    if v < 1:
        return f"{v*1e3:.1f}ms"
    return f"{v:.2f}s"


NOTE_BY_DOM = {
    "compute": "at/near the compute roofline for this step; further gains need "
               "lower-precision matmuls or fewer redundant FLOPs (remat/cf)",
    "memory": "bound by HBM streaming (weights/caches); KV-quant, weight "
              "re-use across microbatches, or fusion moves it",
    "collective": "bound by ICI traffic; resharding (head padding, EP combine "
                  "layout) or comm/compute overlap moves it",
}


def main():
    cells = load_cells()
    singles = [(a, s) for (a, s, m) in cells if m == "single"]

    # ---- dry-run table -----------------------------------------------------
    lines_dry = []
    lines_dry.append("| arch | shape | single-pod (256) | multi-pod (512) | "
                     "bytes/chip (single) | fits 16GB |")
    lines_dry.append("|---|---|---|---|---|---|")
    archs, shapes = [], ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for (a, s, m) in cells:
        if a not in archs and "-pad" not in a:
            archs.append(a)
    for a in archs:
        for s in shapes:
            c1 = cells.get((a, s, "single"))
            c2 = cells.get((a, s, "multi"))
            if c1 is None:
                continue
            st1 = c1["status"]
            st2 = c2["status"] if c2 else "-"
            if st1 == "ok":
                mem = c1["memory"]
                by = (mem.get("argument_size_in_bytes", 0) or 0) + (
                    mem.get("temp_size_in_bytes", 0) or 0)
                fits = "yes" if mem.get("fits_16gb_hbm") else "**no**"
                lines_dry.append(
                    f"| {a} | {s} | ok | {st2} | {by/1e9:.1f} GB | {fits} |")
            else:
                lines_dry.append(f"| {a} | {s} | skip | {st2} | - | - |")

    # ---- roofline table ----------------------------------------------------
    rows = []
    for (a, s, m), c in cells.items():
        if m != "single" or c["status"] != "ok" or "-pad" in a:
            continue
        rf = c["roofline"]
        rows.append((rf["roofline_fraction"], a, s, rf))
    rows.sort(reverse=True)
    lines_roof = []
    lines_roof.append("| arch | shape | compute | memory | collective | "
                      "dominant | useful FLOPs | roofline | next lever |")
    lines_roof.append("|---|---|---|---|---|---|---|---|---|")
    for frac, a, s, rf in rows:
        lines_roof.append(
            f"| {a} | {s} | {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])}"
            f" | {fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['useful_flops_ratio']:.2f} | {frac*100:.2f}% | "
            f"{NOTE_BY_DOM[rf['dominant']]} |")

    tmpl_path = os.path.join(BASE, "scripts", "experiments_template.md")
    out_path = os.path.join(BASE, "EXPERIMENTS.md")
    tmpl = open(tmpl_path).read()
    tmpl = tmpl.replace("{{DRYRUN_TABLE}}", "\n".join(lines_dry))
    tmpl = tmpl.replace("{{ROOFLINE_TABLE}}", "\n".join(lines_roof))
    open(out_path, "w").write(tmpl)
    print(f"wrote {out_path}: {len(lines_dry)-2} dry-run rows, "
          f"{len(lines_roof)-2} roofline rows")


if __name__ == "__main__":
    main()
