"""End-to-end retrieval serving: an LM encoder producing query embeddings in
front of the BBC large-k searcher (the paper's document-retrieval pipeline,
application #2 in its introduction).

  PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.index import search
from repro.models import model as model_mod

# --- embedding model: smollm backbone (smoke size), mean-pooled hidden ----
cfg = configs.get("smollm-135m", smoke=True)
model = model_mod.build(cfg)
params = model.init(jax.random.key(0))


@jax.jit
def embed(tokens):
    from repro.models import transformer as tf
    h = tf._hidden(params, cfg, tokens)          # (B, S, d)
    e = jnp.mean(h, axis=1)
    return e / jnp.linalg.norm(e, axis=-1, keepdims=True)


# --- corpus: embeddings of synthetic documents -----------------------------
rng = np.random.default_rng(1)
n_docs, seq = 20_000, 32
print("embedding corpus ...")
doc_tokens = rng.integers(0, cfg.vocab, (n_docs, seq))
embs = []
for i in range(0, n_docs, 2000):
    embs.append(np.asarray(embed(jnp.asarray(doc_tokens[i:i + 2000]))))
corpus = jnp.asarray(np.concatenate(embs) + rng.standard_normal(
    (n_docs, cfg.d_model)).astype(np.float32) * 0.05)  # spread for realism

print("building IVF+RaBitQ index over document embeddings ...")
index = search.build_rabitq_index(jax.random.key(1), corpus, n_clusters=141)

# --- serve batched large-k queries through the batched engine --------------
from repro.index import engine

k = 1_000
eng = engine.SearchEngine.build(index, k=k, n_probe=100, use_bbc=True)
query_tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, seq)))
q_emb = embed(query_tokens)
print(f"serving retrieve-and-rerank queries (k={k}) ...")
res = eng.search(q_emb)                  # warmup/compile
jax.block_until_ready(res.ids)
t0 = time.monotonic()
res = eng.search(q_emb)                  # one batched engine call
jax.block_until_ready(res.ids)
dt = time.monotonic() - t0
print(f"  {q_emb.shape[0]} queries in {dt:.2f}s "
      f"({q_emb.shape[0]/dt:.1f} QPS); last query re-ranked "
      f"{int(res.n_reranked[-1])} candidates")
print("top-5 doc ids:", np.asarray(res.ids[-1, :5]).tolist())
