"""Distributed BBC search on a host-device mesh: the O(m) histogram
all-reduce + survivor gather pattern from DESIGN.md §4.

  PYTHONPATH=src python examples/distributed_search.py   (spawns 8 devices)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import buffer as rb
from repro.core import distributed as dist

shard_map = functools.partial(jax.shard_map, check_vma=False)

n_shards, per_shard, k = 8, 8192, 2000
rng = np.random.default_rng(0)
q = rng.standard_normal(64).astype(np.float32)
x = rng.standard_normal((n_shards * per_shard, 64)).astype(np.float32)
d = jnp.asarray(np.linalg.norm(x - q, axis=1))
ids = jnp.arange(d.shape[0], dtype=jnp.int32)
valid = jnp.ones(d.shape[0], bool)

cb = rb.build_codebook(d[: 4 * per_shard], k=k, m=128)
mesh = jax.make_mesh((n_shards,), ("model",))

fn = shard_map(
    lambda ld, li, lv: dist.bbc_shard_search(ld, li, lv, cb, k=k,
                                             n_shards=n_shards)[:2],
    mesh=mesh, in_specs=(P("model"), P("model"), P("model")),
    out_specs=(P(), P()))
got_d, got_i = jax.jit(fn)(d, ids, valid)
oracle = np.sort(np.asarray(d))[:k]
print("exact:", np.allclose(np.sort(np.asarray(got_d)), oracle, rtol=1e-6))
cm = dist.collective_cost_model(k=k, m=128, n_shards=n_shards)
print(f"collective payload vs naive distributed top-k: {cm['ratio']:.1f}x less")
