"""Distributed BBC search on a host-device mesh, end-to-end on the REAL
index pipeline: build an IVF+PQ index, shard the candidate stream over an
8-device ("model",) mesh, and serve a query batch through the mesh-sharded
engine — per-shard fused scan, per-query (m+1)-histogram ``psum``,
survivor-only ``all_gather`` (the O(m)-collective pattern from
core/distributed.py), then the replicated re-rank/selection.

  PYTHONPATH=src python examples/distributed_search.py   (spawns 8 devices)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.data import synthetic
from repro.index import engine, search

n_shards, k, n_probe, batch = 8, 2_000, 48, 16
rng = np.random.default_rng(0)
x = jnp.asarray(synthetic.clustered(rng, 40_000, 64))
qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), batch))

print("building IVF+PQ index ...")
index = search.build_pq_index(jax.random.key(0), x, n_clusters=141)

mesh = jax.make_mesh((n_shards,), ("model",))
print(f"sharding the candidate stream over {n_shards} devices ...")
sharded = engine.SearchEngine.build(index, k=k, n_probe=n_probe, mesh=mesh)
single = engine.SearchEngine.build(index, k=k, n_probe=n_probe)

res = sharded.search(qs)          # (batch, k) through the distributed path
ref = single.search(qs)           # same engine config on one device
match = np.mean([
    len(set(np.asarray(res.ids[b]).tolist())
        & set(np.asarray(ref.ids[b]).tolist())) / k
    for b in range(batch)])
print(f"sharded vs single-device top-{k} id overlap: {match:.4f}")

cm = dist.collective_cost_model(k=k, m=128, n_shards=n_shards)
print(f"collective payload vs naive distributed top-k: "
      f"{cm['ratio']:.1f}x less on the wire "
      f"({cm['bbc_bytes_per_link']:.0f} vs {cm['naive_bytes_per_link']:.0f} "
      f"bytes/link per query)")
