"""Train a small LM with the fault-tolerant driver (checkpoint/restart).

  PYTHONPATH=src python examples/train_lm.py
"""
from repro.launch import train

out = train.run_with_restarts(
    arch="smollm-135m", steps=60, ckpt_dir="/tmp/repro_example_ckpt",
    smoke=True, batch=8, seq=64, ckpt_every=20)
print(f"final loss: {out['final_loss']:.4f}")
