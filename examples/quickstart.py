"""Quickstart: build a quantized ANN index and run a large-k BBC query.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.index import flat, search

rng = np.random.default_rng(0)
x = jnp.asarray(synthetic.clustered(rng, 20_000, 64))
queries = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), 3))
k = 2_000

print("building IVF+PQ index ...")
index = search.build_pq_index(jax.random.key(0), x, n_clusters=141)

print(f"large-k query (k={k}) with the bucket-based collector (BBC) ...")
for i, q in enumerate(queries):
    res = search.ivf_pq_search(index, q, k=k, n_probe=100,
                               n_cand=min(8 * k, x.shape[0]), use_bbc=True)
    gt_d, gt_i = flat.search(x, q, k)
    recall = len(set(np.asarray(res.ids).tolist())
                 & set(np.asarray(gt_i).tolist())) / k
    print(f"  query {i}: recall@{k} = {recall:.3f}, "
          f"re-ranked {int(res.n_reranked)} candidates "
          f"({int(res.n_second_pass)} in the second pass)")
print("done.")
