"""Versioned operating-point records: the tuner's persisted contract.

An :class:`OperatingPoint` is one solved cell — (method, k-bucket, recall
target) -> knob settings — together with the provenance needed to trust it:
the corpus fingerprint it was measured on, the code commit, the tuner seed,
and the deterministic sample numbers the solver saw.  Wall-clock
measurements are deliberately EXCLUDED from the record so a re-run of the
tuner with the same inputs serializes byte-identically (the replay gate in
``benchmarks/bench_autotune.py``); measured QPS lives in
``BENCH_autotune.json`` next to the points.

A :class:`PointStore` is an ordered collection persisted as one JSON file
(default ``tuned_points.json`` at the repo root, override with
``REPRO_TUNED_POINTS``).  Consumers resolve with :meth:`PointStore.resolve`:
exact method, the nearest k-bucket (smallest tuned k >= requested k, else
the largest tuned k), highest recall target <= the requested target.  A
resolution that crosses a corpus fingerprint is still returned — the knobs
are a better prior than the hand defaults — but flagged in ``provenance``
so serving summaries can attribute it.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.tuning.knobs import KnobConfig

SCHEMA_VERSION = 1
DEFAULT_PATH = "tuned_points.json"
HAND_TUNED = "hand-tuned fallback"


def corpus_fingerprint(x: np.ndarray) -> str:
    """12-hex-digit digest of the corpus bytes + shape (content identity)."""
    x = np.ascontiguousarray(np.asarray(x))
    h = hashlib.sha256()
    h.update(str(x.shape).encode())
    h.update(str(x.dtype).encode())
    h.update(x.tobytes())
    return h.hexdigest()[:12]


def commit_fingerprint() -> str:
    """Short git commit of the working tree ('unknown' outside a repo);
    '-dirty' is appended when tracked files have uncommitted changes."""
    try:
        base = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=base, capture_output=True, text=True,
                             timeout=10)
        if rev.returncode != 0:
            return "unknown"
        dirty = subprocess.run(["git", "status", "--porcelain", "-uno"],
                               cwd=base, capture_output=True, text=True,
                               timeout=10)
        suffix = "-dirty" if dirty.stdout.strip() else ""
        return rev.stdout.strip() + suffix
    except (OSError, subprocess.SubprocessError):
        return "unknown"


@dataclass(frozen=True)
class OperatingPoint:
    """One solved (method, k-bucket, recall-target) cell.

    ``knobs`` are the engine settings the solver chose; ``recall`` /
    ``cost_units`` are the deterministic sample numbers it chose them on
    (recall measured against exact ground truth on the held-out set);
    ``feasible`` records whether the recall constraint was actually met —
    consumers must treat an infeasible point as advisory, never as a
    recall promise.
    """

    method: str
    k: int
    recall_target: float
    knobs: KnobConfig
    recall: float
    cost_units: float
    feasible: bool
    corpus: dict = field(default_factory=dict)   # n / d / kind / fingerprint
    commit: str = "unknown"
    seed: int = 0
    version: int = SCHEMA_VERSION

    @property
    def name(self) -> str:
        """Stable human-readable identity for attribution in summaries."""
        return (f"{self.method}/k{self.k}@r{self.recall_target:g}"
                f"#{self.corpus.get('fingerprint', '?')}")

    def to_json(self) -> dict:
        """Plain-dict form (canonical: knob dataclass flattened)."""
        d = asdict(self)
        d["knobs"] = asdict(self.knobs)
        return d

    @staticmethod
    def from_json(d: dict) -> "OperatingPoint":
        """Inverse of :meth:`to_json` (unknown keys rejected loudly)."""
        d = dict(d)
        d["knobs"] = KnobConfig(**d["knobs"])
        return OperatingPoint(**d)


def canonical_json(points) -> str:
    """Byte-stable serialization of a point list (sorted keys, fixed
    separators, records ordered by (method, k, -target)) — the replay
    gate compares these strings directly."""
    recs = sorted((p.to_json() for p in points),
                  key=lambda d: (d["method"], d["k"], -d["recall_target"]))
    return json.dumps({"schema_version": SCHEMA_VERSION, "points": recs},
                      indent=2, sort_keys=True)


class PointStore:
    """Ordered collection of operating points with nearest-cell resolution."""

    def __init__(self, points=()):  # noqa: D107
        self.points: list[OperatingPoint] = list(points)

    # -- persistence --------------------------------------------------------

    @staticmethod
    def default_path() -> str:
        """Store location: REPRO_TUNED_POINTS or tuned_points.json at the
        repo root (next to the BENCH_*.json artifacts)."""
        env = os.environ.get("REPRO_TUNED_POINTS")
        if env:
            return env
        base = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        return os.path.join(base, DEFAULT_PATH)

    @classmethod
    def load(cls, path: str | None = None) -> "PointStore":
        """Load a store; missing or unreadable file -> empty store (every
        consumer has a documented hand-tuned fallback)."""
        path = path or cls.default_path()
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            return cls()
        if doc.get("schema_version") != SCHEMA_VERSION:
            return cls()
        return cls(OperatingPoint.from_json(d) for d in doc.get("points", ()))

    def save(self, path: str | None = None) -> str:
        """Persist canonically; returns the path written."""
        path = path or self.default_path()
        with open(path, "w") as f:
            f.write(canonical_json(self.points) + "\n")
        return path

    # -- mutation -----------------------------------------------------------

    def add(self, point: OperatingPoint) -> None:
        """Insert, replacing any existing point for the same (method, k,
        target, corpus fingerprint) cell."""
        key = (point.method, point.k, point.recall_target,
               point.corpus.get("fingerprint"))
        self.points = [p for p in self.points
                       if (p.method, p.k, p.recall_target,
                           p.corpus.get("fingerprint")) != key]
        self.points.append(point)

    # -- resolution ---------------------------------------------------------

    def resolve(self, method: str, k: int, target: float = 0.95,
                corpus_fp: str | None = None, *,
                drift: float | None = None,
                drift_threshold: float = 0.10
                ) -> tuple[OperatingPoint | None, str]:
        """(point, provenance) for a serving cell; (None, HAND_TUNED) when
        the store has nothing usable for the method.

        Nearest-cell rule: exact method match required; among those, the
        smallest tuned k >= requested k (a point tuned for a larger k is
        recall-safe at a smaller one), else the largest tuned k; among
        those, the highest recall_target <= requested (else the lowest
        available).  Feasible points are always preferred over infeasible
        ones.  Provenance is ``'tuned'`` for an exact corpus match,
        ``'tuned-nearest'`` when the fingerprint differs.

        ``drift`` is the live corpus's churn fraction (inserted + deleted
        over base size — streaming ingest).  Past ``drift_threshold`` an
        exact fingerprint match is NO LONGER trusted as exact: the stored
        point was measured on the pre-churn corpus bytes, so the resolution
        falls back to nearest-cell semantics with provenance
        ``'tuned-drifted(<pct>)'`` and a ``UserWarning`` — never a silent
        stale hit.  The knobs are still returned (a measured point on the
        pre-churn corpus beats hand defaults), but ``tuned_from``
        attribution makes the staleness auditable.
        """
        cands = [p for p in self.points if p.method == method]
        if not cands:
            return None, HAND_TUNED
        drifted = drift is not None and drift > drift_threshold
        if drifted:
            import warnings
            warnings.warn(
                f"operating-point store resolved under corpus drift "
                f"{drift:.0%} > {drift_threshold:.0%} for {method}/k{k}: "
                f"treating tuned points as nearest-cell priors, not exact "
                f"matches (re-run the tuner after the next merge)",
                UserWarning, stacklevel=2)
            provenance = f"tuned-drifted({drift:.0%})"
        elif corpus_fp is not None and any(
                p.corpus.get("fingerprint") == corpus_fp for p in cands):
            cands = [p for p in cands
                     if p.corpus.get("fingerprint") == corpus_fp]
            provenance = "tuned"
        else:
            provenance = "tuned" if corpus_fp is None else "tuned-nearest"
        covering = [p for p in cands if p.k >= k]
        pool = covering or cands
        k_best = min(p.k for p in pool) if covering else max(
            p.k for p in pool)
        pool = [p for p in pool if p.k == k_best]
        under = [p for p in pool if p.recall_target <= target]
        pool = under or pool
        t_best = max(p.recall_target for p in pool) if under else min(
            p.recall_target for p in pool)
        pool = [p for p in pool if p.recall_target == t_best]
        pool.sort(key=lambda p: (not p.feasible, p.cost_units,
                                 p.knobs.key()))
        return pool[0], provenance

    def frontier(self, method: str, k: int,
                 corpus_fp: str | None = None) -> list[OperatingPoint]:
        """Degradation frontier for a cell: the resolved k-bucket's points
        across recall targets, sorted by descending target (the order
        ``DegradeLadder.from_frontier`` consumes)."""
        seen: dict[float, OperatingPoint] = {}
        for p in self.points:
            q, _ = self.resolve(method, k, target=p.recall_target,
                                corpus_fp=corpus_fp)
            if q is not None:
                seen[q.recall_target] = q
        return [seen[t] for t in sorted(seen, reverse=True)]

    def __len__(self) -> int:
        return len(self.points)
