"""Constrained auto-tuning of the engine's knob surface.

The engine carries ~8 coupled knobs (``n_probe``, ``n_cand``,
``pred_count``, survivor-budget slack, the shape-bucket ladder, the
straggler gather budget, the fused-scan switch) that PRs 1-7 sized by hand
per benchmark.  This package replaces the hand sizing with the frame of
"Automating Nearest Neighbor Search Configuration with Constrained
Optimization" (PAPERS.md): **maximize QPS subject to recall@k >= target**,
solved per (method, k-bucket, corpus) over measured recall/latency samples
on a held-out query set with exact ground truth, via Lagrangian relaxation
with a deterministic seeded coordinate-descent search.

Layout:

* ``knobs``   — the knob surface: types, valid ranges, coupling invariants
  (max(tau_pred, tau_true), budget <= stream, pool-subset), default grids.
* ``measure`` — one knob configuration -> a :class:`measure.Sample`
  (deterministic recall + work features, plus wall-clock diagnostics).
* ``solver``  — pure functions from samples to a chosen configuration
  (Lagrangian bisection + seeded coordinate descent); same samples + seed
  -> byte-identical choice, so tuner runs replay.
* ``points``  — versioned :class:`points.OperatingPoint` records persisted
  as JSON with corpus/commit fingerprints, and the :class:`points.PointStore`
  consumers resolve against (``SearchEngine.build(..., tuned=...)``, the
  serving tier's ``DegradeLadder.from_frontier``, the benches).
* ``autotune``— the orchestration: sweep a cell, solve for each recall
  target, emit points.

See ``docs/tuning.md`` for the documented operating-point contract.
"""
from repro.tuning import autotune, knobs, measure, points, solver  # noqa: F401
from repro.tuning.knobs import KnobConfig  # noqa: F401
from repro.tuning.points import OperatingPoint, PointStore  # noqa: F401
