"""Tune one cell end-to-end: sweep -> solve -> versioned operating points.

``tune_cell`` is the orchestration the bench (``benchmarks/bench_autotune``)
and any offline tuning job call: build the cell from the index geometry,
run the seeded coordinate-descent sweep over the knob grid, then solve the
constrained problem once per recall target against the full memoized sample
set (the sweep's evaluations are reused across targets — one sweep, many
points).  The ivfpq cell is swept on the PREDICTIVE serving path so
``pred_count`` has a measurable effect; the predictive pool is a subset of
the static ``n_cand`` cut, so recall measured there lower-bounds the static
path and the constraint transfers (see ``measure.measure``).

Determinism: every function here is a deterministic composition of the pure
solver and ``measure``'s deterministic fields.  Wall-clock enters only the
per-sample ``wall_s`` diagnostics, which never reach the persisted points.
"""
from __future__ import annotations

import numpy as np

from repro.index import engine as engine_mod
from repro.tuning import knobs as kn
from repro.tuning import measure as ms
from repro.tuning import points as pts
from repro.tuning import solver as sv

# Recall targets solved per cell, descending: the primary serving target
# first (the CI gate), then the degradation rungs the DegradeLadder walks.
DEFAULT_TARGETS = (0.95, 0.9, 0.8)


def make_cell(index, k: int, vectors=None) -> kn.Cell:
    """Cell geometry from a built index (method resolved by engine dispatch,
    n/d/n_clusters taken from the index, never from caller intent)."""
    method = engine_mod.resolve_kind(index, vectors)
    ivf = getattr(index, "ivf", index)
    n, d = (np.asarray(vectors).shape if method == "ivf"
            else np.asarray(index.vectors).shape)
    return kn.Cell(method=method, k=k, n=int(n), d=int(d),
                   n_clusters=int(np.asarray(ivf.centroids).shape[0]))


def sweep_cell(index, cell: kn.Cell, queries: np.ndarray,
               gt_ids: np.ndarray, *, vectors=None, seed: int = 0,
               grid: dict | None = None, timed: bool = True,
               rounds: int = 2, n_starts: int = 2) -> dict[str, ms.Sample]:
    """Run the seeded coordinate-descent sweep; returns the full memo
    (every distinct configuration evaluated, keyed by knob key)."""
    grid = kn.grid(cell) if grid is None else grid
    ivf = getattr(index, "ivf", index)
    predictive = cell.method == "ivfpq"

    def evaluate(cfg: kn.KnobConfig) -> ms.Sample:
        return ms.measure(index, cell, cfg, queries, gt_ids,
                          vectors=vectors, ivf=ivf, predictive=predictive,
                          timed=timed)

    return sv.coordinate_descent(evaluate, cell, grid,
                                 target=max(DEFAULT_TARGETS), seed=seed,
                                 rounds=rounds, n_starts=n_starts)


def tune_cell(index, k: int, queries: np.ndarray, gt_ids: np.ndarray, *,
              vectors=None, targets=DEFAULT_TARGETS, seed: int = 0,
              corpus: dict | None = None, grid: dict | None = None,
              timed: bool = True, rounds: int = 2,
              n_starts: int = 2) -> dict:
    """Tune one (method, k) cell: one sweep, one solved point per target.

    Returns ``{"cell", "points", "samples", "frontier", "default",
    "cost_model"}`` — the points are ready to ``PointStore.add``; the
    frontier is the recall/cost Pareto subset of everything evaluated
    (what ``DegradeLadder.from_frontier`` consumes); ``default`` is the
    hand-tuned baseline's sample for the QPS-vs-default acceptance gate;
    ``cost_model`` is the wall-time calibration diagnostic.
    """
    cell = make_cell(index, k, vectors=vectors)
    memo = sweep_cell(index, cell, queries, gt_ids, vectors=vectors,
                      seed=seed, grid=grid, timed=timed, rounds=rounds,
                      n_starts=n_starts)
    samples = [memo[key] for key in sorted(memo)]
    corpus = dict(corpus or {})
    corpus.setdefault("n", cell.n)
    corpus.setdefault("d", cell.d)
    commit = pts.commit_fingerprint()

    points = []
    for target in targets:
        best, _lam, feasible = sv.solve(samples, target)
        points.append(pts.OperatingPoint(
            method=cell.method, k=cell.k, recall_target=float(target),
            knobs=best.knobs, recall=best.recall,
            cost_units=best.cost_units, feasible=feasible,
            corpus=corpus, commit=commit, seed=seed))

    default_cfg = kn.default_config(cell)
    default = memo.get(default_cfg.key())
    return {"cell": cell, "points": points, "samples": samples,
            "frontier": sv.pareto_frontier(samples), "default": default,
            "cost_model": ms.fit_cost_model(samples)}
