"""Measure one knob configuration: deterministic recall + work features.

The solver's acceptance criterion is byte-identical replay: the same corpus,
index, query set, and seed must produce the same operating point on every
re-run.  Wall-clock QPS is not replayable, so each evaluated configuration
is summarized by two kinds of numbers:

* **deterministic** — mean recall@k against exact ground truth on the
  held-out query set, and the work features the latency is made of (probed
  stream lanes from the routing geometry, re-ranked candidates and
  second-pass gathers reported by the engine).  The solver sees ONLY these.
* **diagnostic** — measured wall seconds per batch (post-compile), reported
  in ``BENCH_autotune.json`` and used by the acceptance gate (tuned QPS >=
  hand-tuned default QPS), never by the solver.

The deterministic latency surrogate is a fixed-weight linear model over the
work features (``cost_units``); ``fit_cost_model`` fits the same model to
the measured wall times as a calibration diagnostic so drift between the
reference weights and the machine's real cost surface is visible in the
bench output.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import engine as engine_mod
from repro.index import flat
from repro.tuning.knobs import Cell, KnobConfig

# Reference per-lane weights of the deterministic latency surrogate:
#   cost_units = scanned + W_RERANK * reranked + W_SECOND * second_pass
# Scanned lanes are estimate-kernel work (1 unit); a re-ranked candidate
# pays a d-wide gather + exact L2 (~4 lanes of estimate work at the bench
# dimensionalities); an uncovered second-pass gather pays the same compute
# plus a separate dispatch (~8).  The weights are FIXED so the solver is
# pure; fit_cost_model reports how far this machine's measured surface is
# from them.
W_RERANK = 4.0
W_SECOND = 8.0


@dataclass(frozen=True)
class Sample:
    """One evaluated configuration: deterministic objective inputs plus
    wall-clock diagnostics."""

    knobs: KnobConfig
    recall: float               # mean recall@k on held-out queries (det.)
    scanned: float              # mean probed stream lanes / query (det.)
    reranked: float             # mean exact re-ranks / query (det.)
    second_pass: float          # mean uncovered gathers / query (det.)
    cost_units: float           # fixed-weight surrogate (det.)
    wall_s: float | None = None     # measured seconds / batch (diagnostic)

    @property
    def qps_model(self) -> float:
        """Deterministic throughput surrogate (bigger is better)."""
        return 1e6 / max(self.cost_units, 1.0)


def ground_truth_ids(x: np.ndarray, queries: np.ndarray,
                     k: int) -> np.ndarray:
    """(Q, k) exact top-k ids for the held-out query set (brute force)."""
    out = []
    for q in queries:
        _, ids = flat.search(jnp.asarray(x), jnp.asarray(q), k)
        out.append(np.asarray(ids))
    return np.stack(out)


def mean_recall(ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Mean per-query recall@k; -1 pad lanes never count as hits."""
    rs = []
    for got, want in zip(ids, gt_ids):
        g = set(got.tolist()) - {-1}
        rs.append(len(g & set(want.tolist())) / max(len(want), 1))
    return float(np.mean(rs))


def scanned_lanes(index_ivf, queries: np.ndarray, n_probe: int) -> float:
    """Mean probed stream lanes per query — the routing geometry's
    deterministic share of the scan cost (sum of probed cluster sizes)."""
    cents = np.asarray(index_ivf.centroids, np.float64)
    sizes = np.asarray(index_ivf.cluster_sizes, np.int64)
    d2 = ((queries[:, None, :].astype(np.float64) - cents[None]) ** 2
          ).sum(-1)
    probed = np.argsort(d2, axis=1, kind="stable")[:, :n_probe]
    return float(sizes[probed].sum(axis=1).mean())


def build_engine(index, cell: Cell, cfg: KnobConfig, vectors=None,
                 backend: str | None = None) -> engine_mod.SearchEngine:
    """One single-device engine at this configuration (the sweep's unit)."""
    return engine_mod.SearchEngine.build(
        index, k=cell.k, n_probe=cfg.n_probe, n_cand=cfg.n_cand,
        pred_count=cfg.pred_count, fused=cfg.fused, vectors=vectors,
        backend=backend)


def measure(index, cell: Cell, cfg: KnobConfig, queries: np.ndarray,
            gt_ids: np.ndarray, *, vectors=None, ivf=None,
            predictive: bool = False, warm_batches: int = 2,
            repeats: int = 3, timed: bool = True) -> Sample:
    """Evaluate one configuration on the held-out query set.

    ``predictive=True`` measures the tau_pred serving path (the predictor
    warmed on ``warm_batches`` leading slices of the query set before the
    measured call) so ``pred_count`` has a measurable effect; the static
    path is measured otherwise.  Recall measured on the predictive path is
    a LOWER bound for the static path at the same knobs — the predictive
    pool is a subset of the static cut — so a constraint satisfied here
    transfers to non-predictive serving.

    Everything entering the returned sample except ``wall_s`` is a
    deterministic function of (index, cfg, queries); ``timed=False`` skips
    the wall-clock repeats entirely (tests, replay verification).
    """
    eng = build_engine(index, cell, cfg, vectors=vectors)
    qs = jnp.asarray(queries, jnp.float32)

    if predictive:
        state = eng.predictor_init()
        for _ in range(max(warm_batches, 1)):
            _, state = eng.search_batch(qs, pred_state=state)
        state = jax.block_until_ready(state)

        def call():
            res, _ = eng.search_batch(qs, pred_state=state)
            return res
    else:
        call = lambda: eng.search_batch(qs)    # noqa: E731

    res = jax.block_until_ready(call())
    wall = None
    if timed:
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(call())
            ts.append(time.perf_counter() - t0)
        wall = float(np.min(ts))

    ivf_index = ivf if ivf is not None else getattr(index, "ivf", index)
    scanned = scanned_lanes(ivf_index, np.asarray(queries, np.float64),
                            cfg.n_probe)
    reranked = float(np.mean(np.asarray(res.n_reranked)))
    second = float(np.mean(np.asarray(res.n_second_pass)))
    recall = mean_recall(np.asarray(res.ids), gt_ids)
    cost = scanned + W_RERANK * reranked + W_SECOND * second
    return Sample(knobs=cfg, recall=round(recall, 6),
                  scanned=round(scanned, 1), reranked=round(reranked, 1),
                  second_pass=round(second, 1), cost_units=round(cost, 1),
                  wall_s=wall)


def fit_cost_model(samples) -> dict:
    """Least-squares fit of wall seconds on the work features (calibration
    diagnostic only — the solver always uses the fixed reference weights).

    Returns the fitted per-feature seconds and the correlation between the
    fixed-weight surrogate and the measured wall times over the sample set
    (1.0 = the surrogate ranks configurations exactly like this machine).
    """
    timed = [s for s in samples if s.wall_s is not None]
    if len(timed) < 3:
        return {"n": len(timed)}
    feats = np.array([[s.scanned, s.reranked, s.second_pass, 1.0]
                      for s in timed])
    wall = np.array([s.wall_s for s in timed])
    coef, *_ = np.linalg.lstsq(feats, wall, rcond=None)
    surrogate = np.array([s.cost_units for s in timed])
    corr = float(np.corrcoef(surrogate, wall)[0, 1]) \
        if len(timed) > 1 and np.std(surrogate) > 0 and np.std(wall) > 0 \
        else None
    return {"n": len(timed),
            "s_per_scanned": float(coef[0]),
            "s_per_reranked": float(coef[1]),
            "s_per_second_pass": float(coef[2]),
            "s_intercept": float(coef[3]),
            "surrogate_wall_corr": None if corr is None else round(corr, 4)}
