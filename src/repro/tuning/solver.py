"""Pure constrained solver: maximize QPS subject to recall@k >= target.

The formulation follows the ScaNN auto-tuning paper ("Automating Nearest
Neighbor Search Configuration with Constrained Optimization", PAPERS.md):
relax the recall constraint into the objective with a Lagrange multiplier,

    L(c, lam) = qps(c) + lam * min(0, recall(c) - target)

and search the multiplier for the smallest ``lam`` whose unconstrained
argmax satisfies the constraint.  Two layers:

* ``solve`` — given an already-evaluated sample set, bisect ``lam`` and
  return the winning sample.  Pure: same samples + target -> same answer,
  with deterministic tie-breaking on (score, recall, -cost, knob key).
* ``coordinate_descent`` — the sweep driver: explore the discrete knob grid
  one knob at a time from seeded starting points, scoring candidates with
  the current multiplier and updating it by dual ascent between rounds.
  ``evaluate`` is memoized by knob key, so the expensive engine builds run
  once per distinct configuration.

Nothing here reads a clock or unseeded RNG; byte-identical replay of a
tuner run reduces to the determinism of ``measure.Sample``'s inputs.
"""
from __future__ import annotations

from typing import Callable, Iterable, Sequence

import numpy as np

from repro.tuning import knobs as kn
from repro.tuning.measure import Sample

LAM_MAX = 1e9       # feasibility-dominating multiplier ceiling
BISECT_ITERS = 60   # enough for lam to resolve to ~1e-9 relative


def score(s: Sample, lam: float, target: float) -> float:
    """Lagrangian score of one sample (hinge penalty below the target)."""
    return s.qps_model + lam * min(0.0, s.recall - target)


def _argmax(samples: Sequence[Sample], lam: float, target: float) -> Sample:
    """Deterministic argmax of the Lagrangian over a sample set."""
    return max(samples, key=lambda s: (score(s, lam, target), s.recall,
                                       -s.cost_units, s.knobs.key()))


def solve(samples: Sequence[Sample], target: float
          ) -> tuple[Sample, float, bool]:
    """(winning sample, lam*, feasible) for one recall target.

    Bisects the multiplier on [0, LAM_MAX]: below lam* the argmax chases
    raw QPS into infeasible configurations, above it the hinge penalty
    forces feasibility; the returned sample is the feasible argmax at the
    crossover — the cheapest configuration that meets the target.  When no
    evaluated sample is feasible the highest-recall sample is returned with
    ``feasible=False`` (callers must surface this, not serve it silently).
    """
    if not samples:
        raise ValueError("solve() needs at least one sample")
    if not any(s.recall >= target for s in samples):
        return _argmax(samples, LAM_MAX, target), LAM_MAX, False
    lo, hi = 0.0, LAM_MAX
    for _ in range(BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        if _argmax(samples, mid, target).recall >= target:
            hi = mid
        else:
            lo = mid
    best = _argmax(samples, hi, target)
    return best, hi, True


def pareto_frontier(samples: Iterable[Sample]) -> list[Sample]:
    """Recall/cost Pareto-optimal subset, sorted by descending recall
    (the tuned degradation frontier ``DegradeLadder.from_frontier`` walks)."""
    ordered = sorted(samples, key=lambda s: (-s.recall, s.cost_units,
                                             s.knobs.key()))
    out: list[Sample] = []
    best_cost = np.inf
    for s in ordered:
        if s.cost_units < best_cost:
            out.append(s)
            best_cost = s.cost_units
    return out


def coordinate_descent(
    evaluate: Callable[[kn.KnobConfig], Sample],
    cell: kn.Cell,
    grid: dict[str, tuple],
    target: float,
    seed: int = 0,
    rounds: int = 2,
    n_starts: int = 2,
    lam0: float = 1e3,
) -> dict[str, Sample]:
    """Seeded coordinate descent over the discrete knob grid.

    From each start (the hand-tuned default plus ``n_starts - 1`` seeded
    random grid draws), sweep the knobs in declaration order, evaluating
    every grid value of one knob with the others held fixed and keeping the
    best Lagrangian score; between rounds the multiplier takes a dual-ascent
    step ``lam += lam * (target - best recall)`` clipped to [0, LAM_MAX], so
    infeasible regions get progressively penalized.  Every evaluation is
    memoized by knob key and the full memo (the sample set ``solve`` and
    ``pareto_frontier`` consume) is returned.

    Determinism: the RNG is ``np.random.default_rng(seed)`` drawn in a fixed
    order, grid iteration order is the dict/tuple order, and ties break on
    the knob key — same (grid, seed, evaluate) -> same memo, same answer.
    """
    rng = np.random.default_rng(seed)
    memo: dict[str, Sample] = {}

    def ev(cfg: kn.KnobConfig) -> Sample:
        cfg = kn.clamp(cfg, cell)
        s = memo.get(cfg.key())
        if s is None:
            s = evaluate(cfg)
            memo[cfg.key()] = s
        return s

    starts = [kn.default_config(cell)]
    for _ in range(max(n_starts - 1, 0)):
        draw = {knob: values[int(rng.integers(len(values)))]
                for knob, values in grid.items()}
        starts.append(kn.clamp(
            kn.KnobConfig(n_probe=draw.get("n_probe", 1),
                          n_cand=draw.get("n_cand"),
                          pred_count=draw.get("pred_count"),
                          fused=draw.get("fused"),
                          budget_slack=draw.get(
                              "budget_slack",
                              kn.BUDGET_SLACK[cell.method])), cell))

    for start in starts:
        lam = float(lam0)
        cur = ev(start)
        for _ in range(rounds):
            for knob, values in grid.items():
                cands = [ev(c) for c in
                         kn.neighbors(cur.knobs, knob, values, cell)]
                cands.append(cur)
                cur = max(cands, key=lambda s: (score(s, lam, target),
                                                s.recall, -s.cost_units,
                                                s.knobs.key()))
            lam = float(np.clip(lam + lam * (target - cur.recall),
                                0.0, LAM_MAX))
    return memo
