"""The tunable knob surface: types, valid ranges, and coupling invariants.

Every knob the tuner may set is declared here with the invariant that bounds
it, so the solver cannot emit a configuration the engine would reject or —
worse — silently serve incorrectly.  The three contracts the engine's
correctness rides on (see ``docs/tuning.md`` for the full table):

* **threshold contract** — the predictive re-rank threshold is always
  ``max(tau_pred, tau_true)``: a mispredicted tau can only widen the pool,
  never narrow it below the true k-th bucket.  The tuner never touches tau
  directly; it only sizes the pools the contract operates on.
* **pool-subset contract** — the predictive pool is a subset of the static
  ``n_cand`` cut, so ``pred_count`` is clamped to ``[k, n_cand]``.
* **budget <= stream contract** — a per-shard survivor budget is a buffer
  width; it is clamped to the shard's stream length before any ``top_k``.

``clamp`` is the single normalization point: every configuration the sweep
evaluates and every configuration a persisted operating point resolves to
passes through it.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterator

from repro.core import distributed as dist

METHODS = ("ivf", "ivfpq", "ivfrabitq")

# Documented per-method survivor-budget slack over the balanced share
# (pool / n_shards).  These are the PR 5-7 hand constants, now named,
# versioned inside every OperatingPoint, and clamped against the stream
# (dist.survivor_budget + the budget <= stream clamp in shard_budget()):
#   ivf       2.0 — exact in-scan distances, survivor counts concentrate
#                   tightly around k/S under round-robin dealing;
#   ivfpq     1.25 — the pool is the (larger) n_cand cut, so the balanced
#                   share is already wide and per-shard skew is relatively
#                   smaller (hypergeometric concentration);
#   ivfrabitq 4.0 — survivors are the lb<=tau band, which is data-dependent
#                   and several times wider than k's share.
BUDGET_SLACK = {"ivf": 2.0, "ivfpq": 1.25, "ivfrabitq": 4.0}


@dataclass(frozen=True)
class KnobConfig:
    """One point on the knob surface (a single engine configuration).

    Fields mirror ``SearchEngine.build`` arguments; ``None`` means "use the
    engine's per-method default".  Instances are hashable so sweeps can
    memoize evaluations.
    """

    n_probe: int                    # routing width, in [1, n_clusters]
    n_cand: int | None = None       # ivfpq estimate cut, in [k, n]
    pred_count: int | None = None   # predictive pool target, in [k, n_cand]
    fused: bool | None = None       # fused-scan switch (None = per-searcher)
    budget_slack: float | None = None   # sharded survivor-budget slack

    def key(self) -> str:
        """Canonical string key (deterministic ordering / tie-breaking)."""
        return (f"np={self.n_probe},nc={self.n_cand},pc={self.pred_count},"
                f"fu={self.fused},bs={self.budget_slack}")


@dataclass(frozen=True)
class Cell:
    """One tuning cell: the (method, k-bucket, corpus shape) a sweep runs in.

    ``n`` / ``d`` / ``n_clusters`` pin the corpus geometry the invariants
    are clamped against; they come from the built index, not from the
    caller's intent, so a configuration can never reference structure the
    index does not have.
    """

    method: str
    k: int
    n: int
    d: int
    n_clusters: int

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}, "
                             f"got {self.method!r}")
        if not 1 <= self.k <= self.n:
            raise ValueError(f"k must be in [1, n={self.n}], got {self.k}")


def clamp(cfg: KnobConfig, cell: Cell) -> KnobConfig:
    """Normalize a configuration onto the valid knob surface of ``cell``.

    Applies every coupling invariant (n_probe within the routing grid,
    n_cand within [k, n], pred_count within [k, n_cand] — the pool-subset
    contract, slack positive).  Idempotent: ``clamp(clamp(c)) == clamp(c)``.
    """
    n_probe = max(1, min(int(cfg.n_probe), cell.n_clusters))
    n_cand = cfg.n_cand
    if cell.method != "ivfpq":
        n_cand = None               # the estimate cut exists only on PQ
    elif n_cand is not None:
        n_cand = max(cell.k, min(int(n_cand), cell.n))
    pred_count = cfg.pred_count
    if pred_count is not None:
        pred_count = max(cell.k, int(pred_count))
        if n_cand is not None:
            pred_count = min(pred_count, n_cand)    # pool-subset contract
    slack = cfg.budget_slack
    if slack is not None and slack <= 0:
        raise ValueError(f"budget_slack must be positive, got {slack}")
    return KnobConfig(n_probe=n_probe, n_cand=n_cand, pred_count=pred_count,
                      fused=cfg.fused, budget_slack=slack)


def default_config(cell: Cell) -> KnobConfig:
    """The hand-tuned default configuration PRs 1-7 shipped for this cell
    (the baseline the tuned point must beat): n_probe=64, n_cand=8k on PQ,
    engine-default pred_count, per-method budget slack."""
    n_cand = min(8 * cell.k, cell.n) if cell.method == "ivfpq" else None
    return clamp(KnobConfig(n_probe=64, n_cand=n_cand, pred_count=None,
                            fused=None,
                            budget_slack=BUDGET_SLACK[cell.method]), cell)


def grid(cell: Cell) -> dict[str, tuple]:
    """Per-knob discrete sweep values for a cell, every one pre-clamped.

    The grid is deliberately small (CPU jit compiles are the sweep's unit
    cost): a geometric n_probe ladder over the routing grid for every
    method, plus the n_cand multiplier and pred_count ladders on ivfpq —
    the knobs whose measured effect the cost model can see.  ``fused`` and
    ``budget_slack`` stay single-valued by default (their defaults are
    documented per-method contracts, not free parameters); callers may
    extend the returned dict to sweep them.
    """
    c = cell.n_clusters
    # geometric ladder up to the FULL routing width: at k ~ n the recall
    # target is only reachable by probing (nearly) every cluster, so the
    # grid must contain that point for the constraint to be satisfiable
    n_probe = sorted({max(1, c // 16), max(1, c // 8), max(1, c // 4),
                      max(1, c // 2), min(64, c), c})
    g: dict[str, tuple] = {"n_probe": tuple(n_probe)}
    if cell.method == "ivfpq":
        # multiplier ladder plus the vacuous cut (n_cand = n): on corpora
        # where the PQ estimate ordering is weakly informative the target
        # may be unreachable under ANY bounded cut, so — as with the full
        # routing width above — the grid must contain the point that makes
        # the constraint satisfiable
        g["n_cand"] = tuple(sorted({min(m * cell.k, cell.n)
                                    for m in (2, 4, 8)} | {cell.n}))
        # pred_count ladder: the engine default (~2.5k) and a shallower
        # pool one rung above the floor; both clamped to [k, n_cand]
        g["pred_count"] = (None, max(cell.k + 1024, 3 * cell.k // 2))
    return g


def neighbors(cfg: KnobConfig, knob: str, values: tuple,
              cell: Cell) -> Iterator[KnobConfig]:
    """All clamped variants of ``cfg`` with ``knob`` set to each grid value
    (the coordinate-descent move set)."""
    seen = set()
    for v in values:
        c = clamp(replace(cfg, **{knob: v}), cell)
        if c.key() not in seen:
            seen.add(c.key())
            yield c


def base_pool(method: str, k: int, n_cand: int | None) -> int:
    """The survivor pool a sharded budget is sized against: the n_cand cut
    on ivfpq (the collective carries estimate survivors), k elsewhere."""
    return n_cand if (method == "ivfpq" and n_cand is not None) else k


def shard_budget(method: str, k: int, n_cand: int | None, n_shards: int,
                 stream_len: int | None = None,
                 slack: float | None = None) -> int:
    """Per-shard survivor budget for a configuration, invariants applied.

    Wraps ``dist.survivor_budget`` (balanced share x slack, 128-aligned)
    with the two contracts the tuner owns: the slack defaults to the
    method's documented ``BUDGET_SLACK`` entry, and the result is clamped
    to ``stream_len`` when given (budget <= stream — a short-stream shard
    must not be asked to compact more lanes than it holds).
    """
    slack = BUDGET_SLACK[method] if slack is None else float(slack)
    b = dist.survivor_budget(base_pool(method, k, n_cand), n_shards,
                             slack=slack)
    if stream_len is not None:
        b = min(b, int(stream_len))
    return max(b, 1)
