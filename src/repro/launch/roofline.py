"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per (arch, shape, mesh) cell; see EXPERIMENTS.md §Roofline):
  compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF/s bf16, v5e)
  memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective = collective_bytes_per_chip / link_bw      (~50 GB/s/link ICI)

cost_analysis() runs on the PARTITIONED module, so flops/bytes are already
per-chip.  Collective bytes are not in cost_analysis — we parse the
compiled HLO text and sum *operand* sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (all-gather operands are
output/group_size; reduce-scatter operands are the unscattered input).
"""
from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_GROUPS_V1 = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind operand bytes summed over the module (per-chip module)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # async pair: count the -start only
        nbytes = _shape_bytes(type_str)
        gs = _group_size(line)
        if kind == "all-gather":
            nbytes = nbytes // max(gs, 1)       # operand = output / group
        elif kind == "reduce-scatter":
            nbytes = nbytes * max(gs, 1)        # operand = output * group
        out[kind] += nbytes
        counts[kind] += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["op_counts"] = counts
    return out


def collective_bytes_nested(hlo_text: str, depth_trips: list[int]) -> dict:
    """Collective operand bytes with while-nesting multipliers.

    Ops inside while bodies execute once per trip; HLO text shows them once.
    We build the computation graph via ``body=%name`` references from while
    instructions, walk it from ENTRY, and scale each computation's
    collectives by the product of enclosing loop trip counts taken from
    ``depth_trips`` (index = loop nesting depth; clamped to the last entry).
    Computations unreachable via while chains (cond branches etc.) get the
    depth-1 multiplier.
    """
    comp_re = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
    body_ref = re.compile(r"body=%?([\w.\-]+)")
    comps: dict[str, dict] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        m = comp_re.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = {"coll": [], "bodies": []}
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        cm = _COLL_RE.match(line)
        if cm and "-done(" not in line:
            type_str, kind = cm.group(1), cm.group(2)
            nbytes = _shape_bytes(type_str)
            gs = _group_size(line)
            if kind == "all-gather":
                nbytes //= max(gs, 1)
            elif kind == "reduce-scatter":
                nbytes *= max(gs, 1)
            comps[cur]["coll"].append((kind, nbytes))
        for b in body_ref.findall(line):
            comps[cur]["bodies"].append(b)

    def trip(depth: int) -> int:
        return depth_trips[min(depth, len(depth_trips) - 1)]

    mult: dict[str, float] = {}

    def walk(name: str, depth: int, m: float):
        if name not in comps:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        for b in comps[name]["bodies"]:
            walk(b, depth + 1, m * trip(depth + 1))

    if entry:
        walk(entry, 0, 1.0)
    default_m = float(trip(1))
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for name, c in comps.items():
        m = mult.get(name, default_m if c["coll"] else 0.0)
        for kind, nbytes in c["coll"]:
            out[kind] += nbytes * m
            counts[kind] += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["op_counts"] = counts
    return out


def depth_trips_for(cfg, mode: str, seq: int, n_mb: int = 8) -> list[int]:
    """Loop-nest trip counts for collective scaling (see DESIGN §Roofline).
    depth 0 = entry; deeper entries estimated from the scan structure."""
    if cfg.family == "hybrid":
        l1, l2 = cfg.n_segments, cfg.ssm_per_segment
    else:
        l1, l2 = _layer_count(cfg), max(seq // 1024, 1)
    if mode == "train":
        return [1, n_mb, l1, l2, max(seq // 1024, 1)]
    return [1, l1, l2, max(seq // 1024, 1)]


def roofline_terms(cost: dict[str, Any], coll: dict, n_chips: int,
                   model_flops_global: float,
                   analytic_flops_global: float | None = None,
                   analytic_bytes_chip: float | None = None) -> dict:
    hlo_flops = float(cost.get("flops", 0.0) or 0.0)
    hlo_bytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    # primary terms from the analytic model (cost_analysis undercounts scan
    # bodies — measured values retained as the cross-check)
    flops_chip = (analytic_flops_global / n_chips
                  if analytic_flops_global else hlo_flops)
    bytes_chip = (analytic_bytes_chip
                  if analytic_bytes_chip is not None else hlo_bytes)
    compute_s = flops_chip / PEAK_FLOPS
    memory_s = bytes_chip / HBM_BW
    coll_s = coll["total"] / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "analytic_flops_per_chip": flops_chip,
        "analytic_bytes_per_chip": bytes_chip,
        "hlo_flops_per_chip_measured": hlo_flops,
        "hlo_bytes_per_chip_measured": hlo_bytes,
        "collective_bytes_per_chip": coll["total"],
        "collective_breakdown": {k: v for k, v in coll.items()
                                 if k not in ("total", "op_counts")},
        "collective_op_counts": coll["op_counts"],
        "model_flops_global": model_flops_global,
        "useful_flops_ratio": (model_flops_global / (flops_chip * n_chips)
                               if flops_chip else 0.0),
        "roofline_fraction": (model_flops_global / n_chips / PEAK_FLOPS
                              / max(max(terms.values()), 1e-30)),
    }


# --------------------------------------------------------------------------
# Analytic cost model (primary source for the roofline terms)
#
# XLA's cost_analysis() counts while/scan bodies ONCE (verified empirically:
# an 8-step scanned matmul reports 1/8 the flops of its unrolled twin), and
# every model here is scan-over-layers by design.  We therefore derive FLOPs
# exactly from the einsum inventory (we wrote every matmul) and memory bytes
# from a principled traffic model; cost_analysis is kept as a per-body
# cross-check and memory_analysis (loop-aware) for capacity.
# --------------------------------------------------------------------------

def _attn_layer_flops(cfg, B, S, S_kv, causal_full=True):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    proj = 2 * B * S * d * (2 * h * hd) + 2 * B * S * d * (2 * kv * hd)
    # flash computes the full S x S_kv block grid (masked lanes included)
    attn = 2 * 2 * B * h * S * S_kv * hd
    return proj + attn


def _dense_mlp_flops(cfg, B, S, n_mats=3):
    return 2 * B * S * cfg.d_model * cfg.d_ff * n_mats


def _moe_mlp_flops(cfg, B, S):
    cf = cfg.capacity_factor
    router = 2 * B * S * cfg.d_model * cfg.n_experts
    tokens = B * S * cfg.top_k * cf           # E * C dispatch slots
    experts = 2 * tokens * cfg.d_model * cfg.d_ff * 3
    return router + experts


def _ssm_layer_flops(cfg, B, S):
    sd = cfg.ssm_dims()
    d = cfg.d_model
    lc = min(cfg.ssm_chunk, S)
    f = 2 * B * S * d * sd.d_in_proj                       # in_proj
    f += 2 * B * S * sd.d_conv_ch * sd.conv_width          # conv
    f += 2 * B * S * lc * sd.d_state                       # CB scores
    f += 2 * B * S * lc * sd.n_heads * sd.headdim          # intra mat @ x
    f += 2 * 2 * B * S * sd.d_state * sd.n_heads * sd.headdim  # inter+state
    f += 2 * B * S * sd.d_inner * d                        # out_proj
    return f


def _ssm_decode_flops(cfg, B):
    sd = cfg.ssm_dims()
    f = 2 * B * cfg.d_model * sd.d_in_proj
    f += 2 * 2 * B * sd.n_heads * sd.headdim * sd.d_state  # state upd + read
    f += 2 * B * sd.d_inner * cfg.d_model
    return f


def forward_flops(cfg, B: int, S: int, S_kv: int | None = None) -> float:
    """Exact global forward FLOPs for one pass (decode: S=1, S_kv=cache)."""
    S_kv = S_kv if S_kv is not None else S
    fam = cfg.family
    if fam in ("dense", "vlm"):
        # vlm: patches extend the sequence in train/prefill only; during
        # decode they are already in the cache (S == 1)
        pat = cfg.n_patches if (fam == "vlm" and S > 1) else 0
        S_eff = S + pat
        Skv_eff = S_kv + pat if S_kv == S else S_kv
        per = _attn_layer_flops(cfg, B, S_eff, Skv_eff) + _dense_mlp_flops(
            cfg, B, S_eff)
        return cfg.n_layers * per
    if fam == "moe":
        per = _attn_layer_flops(cfg, B, S, S_kv) + _moe_mlp_flops(cfg, B, S)
        return cfg.n_layers * per
    if fam == "ssm":
        if S == 1 and S_kv > 1:
            return cfg.n_layers * _ssm_decode_flops(cfg, B)
        return cfg.n_layers * _ssm_layer_flops(cfg, B, S)
    if fam == "hybrid":
        if S == 1 and S_kv > 1:
            ssm = cfg.n_layers * _ssm_decode_flops(cfg, B)
        else:
            ssm = cfg.n_layers * _ssm_layer_flops(cfg, B, S)
        shared = cfg.n_segments * (
            _attn_layer_flops(cfg, B, S, S_kv) + _dense_mlp_flops(cfg, B, S))
        return ssm + shared
    if fam == "encdec":
        F = cfg.n_frames
        dec_n = cfg.dec_layers or cfg.n_layers
        enc = cfg.n_layers * (_attn_layer_flops(cfg, B, F, F)
                              + _dense_mlp_flops(cfg, B, F, n_mats=2))
        d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
        self_a = _attn_layer_flops(cfg, B, S, S_kv)
        cross = (2 * B * S * d * 2 * h * hd           # q, o at S
                 + 2 * B * F * d * 2 * kv * hd        # k, v at F
                 + 2 * 2 * B * h * S * F * hd)        # scores + pv
        dec = dec_n * (self_a + cross + _dense_mlp_flops(cfg, B, S, n_mats=2))
        if S == 1 and S_kv > 1:
            enc = 0.0  # decode step consumes a precomputed encoder output
        return enc + dec
    raise ValueError(fam)


def head_flops(cfg, B, S, mode) -> float:
    if mode == "train":
        return 2 * B * S * cfg.d_model * cfg.vocab
    return 2 * B * cfg.d_model * cfg.vocab  # last-token logits


def analytic_flops(cfg, mode: str, seq: int, batch: int) -> float:
    """Global FLOPs for one step."""
    if mode == "train":
        fwd = forward_flops(cfg, batch, seq) + head_flops(cfg, batch, seq, mode)
        mult = 4.0 if cfg.remat else 3.0   # fwd + 2x bwd (+1x remat recompute)
        opt = 12.0 * _total_params(cfg)
        return fwd * mult + opt
    if mode == "prefill":
        return forward_flops(cfg, batch, seq) + head_flops(cfg, batch, seq, mode)
    # decode: one token against a seq-long cache
    return (forward_flops(cfg, batch, 1, S_kv=seq)
            + head_flops(cfg, batch, 1, mode))


def _total_params(cfg) -> int:
    emb = cfg.vocab * cfg.d_model * 2
    if cfg.family == "moe":
        d, ff = cfg.d_model, cfg.d_ff
        per = (2 * cfg.d_model * cfg.hd * (cfg.n_heads + cfg.n_kv)
               + 3 * d * ff * cfg.n_experts + d * cfg.n_experts)
        return emb + cfg.n_layers * per
    dense_eq = active_param_count(cfg)
    return emb + dense_eq


def _dtype_bytes(cfg) -> int:
    import jax.numpy as jnp
    return 2 if cfg.dtype == jnp.bfloat16 else 4


def _act_layer_bytes(cfg, B, S) -> float:
    """HBM bytes written+read for one layer's major intermediates, one pass.
    Flash attention scores stay in VMEM (fused) by design — q/k/v/out only."""
    dt = _dtype_bytes(cfg)
    d, ff, h, kv, hd = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv, cfg.hd)
    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "encdec"):
        qkvo = B * S * hd * (2 * h + 2 * kv)
        if fam == "moe":
            mlp = B * S * cfg.top_k * cfg.capacity_factor * (2 * ff + 2 * d)
        else:
            mlp = B * S * 3 * ff
        resid = 4 * B * S * d
        return 2 * dt * (qkvo + mlp + resid)     # write + read
    sd = cfg.ssm_dims()
    inner = B * S * (sd.d_in_proj + sd.d_conv_ch + 2 * sd.d_inner)
    return 2 * dt * (inner + 2 * B * S * d)


def analytic_bytes(cfg, mode: str, seq: int, batch: int, n_chips: int,
                   n_mb: int = 8) -> float:
    """Per-chip HBM traffic for one step (the memory roofline term)."""
    dt = _dtype_bytes(cfg)
    n_par = _total_params(cfg)
    par_chip = n_par * dt / n_chips          # fully sharded (model x data)
    if mode == "train":
        layer_passes = 3.0 if cfg.remat else 2.0   # fwd + recompute + bwd≈1
        # weights: re-read per microbatch per pass + grad write/read + Adam
        w = par_chip * (layer_passes * n_mb) + 2 * par_chip + 20 * (
            n_par / n_chips)
        acts = (_act_layer_bytes(cfg, batch, seq) * _layer_count(cfg)
                * (1 + layer_passes)) / n_chips
        head = 3 * batch * seq * cfg.vocab * dt / n_chips  # chunked loss
        return w + acts + head
    if mode == "prefill":
        acts = (_act_layer_bytes(cfg, batch, seq) * _layer_count(cfg)) / n_chips
        return par_chip + acts
    # decode: weights + full cache read + small writes
    cache = _cache_bytes(cfg, batch, seq)
    return par_chip + cache / n_chips + (
        _act_layer_bytes(cfg, batch, 1) * _layer_count(cfg)) / n_chips


def _layer_count(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers + cfg.n_segments
    if cfg.family == "encdec":
        return cfg.n_layers + (cfg.dec_layers or cfg.n_layers)
    return cfg.n_layers


def _cache_bytes(cfg, batch, seq) -> float:
    dt = _dtype_bytes(cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        per = cfg.n_layers * batch * seq * 2 * cfg.n_kv * cfg.hd
        if getattr(cfg, "kv_quant", False):
            return per + cfg.n_layers * batch * seq * 2 * 4  # int8 + scales
        return per * dt
    sd = cfg.ssm_dims() if cfg.d_state else None
    if cfg.family == "ssm":
        return cfg.n_layers * batch * sd.n_heads * sd.headdim * sd.d_state * 4
    if cfg.family == "hybrid":
        ssm = cfg.n_layers * batch * sd.n_heads * sd.headdim * sd.d_state * 4
        attn = cfg.n_segments * batch * seq * 2 * cfg.n_kv * cfg.hd * dt
        return ssm + attn
    if cfg.family == "encdec":
        dec_n = cfg.dec_layers or cfg.n_layers
        return dec_n * batch * seq * 2 * cfg.n_kv * cfg.hd * dt
    raise ValueError(cfg.family)


def model_flops(cfg, mode: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params.

    N excludes embedding tables (standard convention); MoE uses active
    experts only.  D = total tokens processed by the step."""
    n = active_param_count(cfg)
    if mode == "train":
        per_tok = 6 * n
        d_tok = batch * seq
    elif mode == "prefill":
        per_tok = 2 * n
        d_tok = batch * seq
    else:  # decode: one token per sequence
        per_tok = 2 * n
        d_tok = batch
    return float(per_tok) * float(d_tok)


def active_param_count(cfg) -> int:
    """Backbone parameters touched per token (analytic, excl. embeddings)."""
    d, ff, L_ = cfg.d_model, cfg.d_ff, cfg.n_layers
    hd = cfg.hd
    attn = d * hd * cfg.n_heads * 2 + d * hd * cfg.n_kv * 2   # q,o + k,v
    mlp = 3 * d * ff                                           # swiglu
    if cfg.family == "dense" or cfg.family == "vlm":
        return L_ * (attn + mlp)
    if cfg.family == "moe":
        active_mlp = 3 * d * ff * cfg.top_k + d * cfg.n_experts
        return L_ * (attn + active_mlp)
    if cfg.family == "ssm":
        sd = cfg.ssm_dims()
        ssm = (d * sd.d_in_proj + sd.d_inner * d
               + sd.conv_width * sd.d_conv_ch)
        return L_ * ssm
    if cfg.family == "hybrid":
        sd = cfg.ssm_dims()
        ssm = (d * sd.d_in_proj + sd.d_inner * d
               + sd.conv_width * sd.d_conv_ch)
        shared = attn + mlp
        return L_ * ssm + cfg.n_segments * shared
    if cfg.family == "encdec":
        dec_n = cfg.dec_layers or cfg.n_layers
        enc = cfg.n_layers * (attn + 2 * d * ff)
        dec = dec_n * (2 * attn + 2 * d * ff)
        return enc + dec
    raise ValueError(cfg.family)
