"""Fault-tolerant training driver.

Single-host reference implementation of the production loop the dry-run
lowers: checkpoint/restart, deterministic data resume, per-step watchdog
(straggler mitigation), and failure injection for the restart tests.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt

At pod scale the same loop runs per host under ``jax.distributed``; the
elements that change are noted inline.  Straggler/failure handling strategy:
  * every step runs under a watchdog budget (3x the trailing median step
    time); a breach raises and the runner restarts from the last checkpoint
    (on a pod: the coordinator evicts the slow host and re-meshes),
  * checkpoints are written asynchronously every --ckpt-every steps,
  * restart = restore(latest) + data stream resume at the stored step; the
    loss trajectory is bit-identical to an uninterrupted run (tested).
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models import model as model_mod
from repro.optim import adamw


class WatchdogTimeout(RuntimeError):
    """Raised when a training step exceeds the watchdog budget."""
    pass


def train(arch: str, steps: int, ckpt_dir: str, smoke: bool = True,
          batch: int = 8, seq: int = 64, ckpt_every: int = 20,
          fail_at: int | None = None, watchdog_factor: float = 10.0,
          seed: int = 0, log_every: int = 10) -> dict:
    cfg = configs.get(arch, smoke=smoke)
    model = model_mod.build(cfg)
    opt_cfg = adamw.AdamWConfig(lr_peak=3e-4, warmup_steps=10,
                                total_steps=steps)
    train_step = jax.jit(model_mod.make_train_step(model, opt_cfg),
                        donate_argnums=(0, 1))

    mgr = CheckpointManager(ckpt_dir)
    pipe = TokenPipeline(cfg.vocab, batch, seq, seed=seed)

    params = model.init(jax.random.key(seed))
    opt_state = adamw.init(params)
    start = 0
    if mgr.latest_step() is not None:
        (params, opt_state), start = mgr.restore((params, opt_state))
        print(f"[train] resumed from step {start}", flush=True)

    losses = []
    step_times: list[float] = []
    it = pipe.iterate(start_step=start)
    for step, np_batch in it:
        if step >= steps:
            break
        if fail_at is not None and step == fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.monotonic()
        b = {k: jnp.asarray(v) for k, v in np_batch.items()}
        params, opt_state, metrics = train_step(params, opt_state, b)
        loss = float(metrics["loss"])
        dt = time.monotonic() - t0
        # watchdog: a step exceeding watchdog_factor x trailing median is a
        # straggler -> abort so the runner restarts from the last checkpoint
        if len(step_times) >= 5:
            budget = watchdog_factor * statistics.median(step_times[-20:])
            if dt > budget:
                raise WatchdogTimeout(
                    f"step {step} took {dt:.2f}s > budget {budget:.2f}s")
        step_times.append(dt)
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step={step} loss={loss:.4f} "
                  f"dt={dt*1e3:.0f}ms", flush=True)
        if step > 0 and step % ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state), wait=False)
    mgr.wait()
    mgr.save(min(steps, step + 1), (params, opt_state), wait=True)
    return {"final_loss": losses[-1] if losses else None,
            "losses": losses, "start": start}


def run_with_restarts(max_restarts: int = 3, **kw) -> dict:
    """Supervisor: restart from the latest checkpoint on failure (the
    single-host stand-in for the pod coordinator's evict-and-restart)."""
    for attempt in range(max_restarts + 1):
        try:
            return train(**kw)
        except (WatchdogTimeout, RuntimeError) as e:  # noqa: PERF203
            print(f"[train] attempt {attempt} failed: {e}; restarting",
                  flush=True)
            kw["fail_at"] = None  # injected failure fires once
    raise RuntimeError("exceeded max restarts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    out = run_with_restarts(
        arch=args.arch, steps=args.steps, ckpt_dir=args.ckpt_dir,
        smoke=args.smoke, batch=args.batch, seq=args.seq,
        fail_at=args.fail_at)
    print(json.dumps({"final_loss": out["final_loss"]}))


if __name__ == "__main__":
    main()
