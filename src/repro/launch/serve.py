"""Batched large-k retrieval serving driver (the paper's workload).

Builds a quantized ANN index over a corpus and serves large-k queries
through the batched fused-kernel search engine (``index.engine``): one
routing matmul per batch, one shared candidate-stream gather, batched
estimate/bucketize/re-rank kernels.  ``--batch 1`` falls back to the
single-query searchers.  ``examples/serve_retrieval.py`` wires an LM encoder
in front of this.

  PYTHONPATH=src python -m repro.launch.serve --n 100000 --d 96 --k 5000 \
      --method ivfpq_bbc --queries 64 --batch 32

``--shards N`` serves the same index mesh-sharded over N devices (the
distributed BBC collector: per-shard scan, histogram psum, survivor-only
all-gather).  On a CPU host without real accelerators the flag forces N
host devices so the collective path is exercised end-to-end:

  PYTHONPATH=src python -m repro.launch.serve --method ivfpq_bbc --shards 8

``--tau-pred on`` switches on predictive early-exact re-ranking: the loop
maintains a cross-batch threshold predictor (EMA over the bucket histograms
of previous batches) and threads it through every engine call, so the
re-rank pool shrinks from the static n_cand cut to the predicted threshold
with a correctness fallback (see index/engine.py and core/rerank.py).

``--mode async`` serves an asynchronous open-loop request stream through
the micro-batching subsystem (``repro.serving``): a seeded synthetic trace
(``--trace poisson|bursty`` at ``--rate`` req/s, per-request deadline
``--deadline-ms``, heterogeneous k via ``--k-choices``) flows through
admission control and deadline-aware batch assembly onto AOT-warmed
(B, k)-bucketed engines; ``--mode static`` is the fixed-batch loop above.

  PYTHONPATH=src python -m repro.launch.serve --mode async --rate 200 \
      --deadline-ms 500 --k-choices 1000,5000 --max-batch 16

``--mode net`` serves over REAL sockets: a master process (bounded
queues, 429-style backpressure, retries, health, the exact-key result
cache) in front of N worker subprocesses it spawns and supervises, each
hosting a spec-built engine behind a framed Unix/TCP socket loop
(``repro.transport``).  By default it drives a seeded Zipf trace through
a framed client and prints a summary; ``--serve-forever`` keeps serving
until SIGTERM/SIGINT, which triggers a graceful drain — in-flight
requests finish, new ones are rejected with retriable ``retry_after``
frames, workers get ``bye``, and the process exits 0.

  PYTHONPATH=src python -m repro.launch.serve --mode net --workers 4 \
      --n 20000 --d 32 --k-choices 10,100,1000 --rate 300 \
      --wire-faults 'drop=0.02,slow=0.1,seed=7' --record /tmp/run.jsonl

The last stdout line of either mode is one machine-readable JSON summary
(QPS, latency percentiles, shed/deadline rates, recall sample); with
``--check-parity`` the async mode also verifies every completed request's
ids against a direct engine call and exits non-zero on any mismatch.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _forced_shards() -> int:
    """Pre-jax-import peek at --shards: forcing host devices only works via
    XLA_FLAGS set before jax initializes its backends.  Malformed values
    fall through to 1 so argparse reports them properly later."""
    argv = sys.argv
    for i, a in enumerate(argv):
        val = None
        if a == "--shards" and i + 1 < len(argv):
            val = argv[i + 1]
        elif a.startswith("--shards="):
            val = a.split("=", 1)[1]
        if val is not None:
            try:
                return int(val)
            except ValueError:
                return 1
    return 1


def _is_entrypoint() -> bool:
    """True when this module IS the serve entrypoint (``python -m`` or the
    ``repro-serve`` console script) — importing it for its helpers must not
    scan argv or rewrite the process environment."""
    return __name__ == "__main__" or \
        os.path.basename(sys.argv[0] or "").startswith("repro-serve")


if _is_entrypoint():
    _n_shards = _forced_shards()
    if _n_shards > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_n_shards}").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.index import engine, flat, search


METHODS = ("ivfpq", "ivfpq_bbc", "ivfrabitq", "ivfrabitq_bbc", "flat")
RECALL_SAMPLE = 8   # queries with exact ground truth for the recall estimate


def build_index(method: str, x, n_clusters: int, seed: int = 0):
    key = jax.random.key(seed)
    if method.startswith("ivfpq"):
        return search.build_pq_index(key, x, n_clusters)
    if method.startswith("ivfrabitq"):
        return search.build_rabitq_index(key, x, n_clusters)
    return None


def mean_recall_entries(x, entries) -> float:
    """Mean recall over (query, ids, k) triples, against exact ground truth
    (per-entry k so heterogeneous-k serving outcomes average correctly)."""
    recalls = []
    for q, ids, k in entries:
        _, gt_i = flat.search(x, q, k)
        got = set(np.asarray(ids).tolist()) - {-1}
        recalls.append(len(got & set(np.asarray(gt_i).tolist())) / k)
    return float(np.mean(recalls)) if recalls else float("nan")


def sample_indices(n: int, n_sample: int) -> np.ndarray:
    """Evenly spaced sample over [0, n) that always includes the LAST index,
    so the recall estimate covers the ragged tail batch instead of weighting
    only the leading full batches."""
    return np.unique(np.linspace(0, max(n - 1, 0),
                                 min(n_sample, n)).round().astype(int))


def run_static(args, x, qs, index, mesh, n_probe, tuned=None):
    """The fixed-batch synchronous loop (PR 1-3 behavior)."""
    tau_pred_on = args.tau_pred == "on"
    operating_point = "flat"
    if args.method == "flat":
        if tau_pred_on:
            raise SystemExit("--tau-pred does not apply to the flat baseline")
        searcher = lambda q: flat.search(x, q, args.k)  # noqa: E731
        batch = 1
    else:
        if tau_pred_on and not args.method.endswith("bbc"):
            raise SystemExit("--tau-pred on requires a *_bbc method")
        # n_cand / pred_count resolve from the tuned operating point when
        # one covers this (method, k) cell, else the engine's hand
        # defaults (the pre-tuner formula n_cand = min(8k, n))
        eng = engine.SearchEngine.build(
            index, k=args.k, n_probe=n_probe,
            use_bbc=args.method.endswith("bbc"), mesh=mesh,
            pred_count=args.pred_count, tuned=tuned,
            recall_target=args.recall_target)
        from repro.tuning.points import HAND_TUNED
        operating_point = eng.tuned_from or HAND_TUNED
        if tau_pred_on:
            # the serving loop owns the predictor: every request folds its
            # batch histogram into the EMA that thresholds the next request
            pred_state = [eng.predictor_init()]

            def searcher(qb):
                r, pred_state[0] = eng.search(qb, pred_state=pred_state[0])
                return r
        else:
            searcher = eng.search
        batch = max(1, args.batch)

    batches = [qs[i:i + batch] for i in range(0, args.queries, batch)]
    if batch == 1:
        batches = [q for q in qs]

    # warmup / compile — the final batch may be ragged (queries % batch),
    # which is a distinct jit shape; compile it outside the timed loop too
    r = searcher(batches[0])
    jax.block_until_ready(r)
    if batch > 1 and batches[-1].shape[0] != batches[0].shape[0]:
        r = searcher(batches[-1])
        jax.block_until_ready(r)

    t0 = time.monotonic()
    results = []
    for qb in batches:
        r = searcher(qb)
        ids = r.ids if hasattr(r, "ids") else r[1]   # flat returns a pair
        results.append(ids if ids.ndim > 1 else ids[None])
    jax.block_until_ready(r)
    dt = time.monotonic() - t0
    qps = args.queries / dt

    # recall sample vs exact ground truth, evenly spaced over the WHOLE
    # query stream (always includes the last query, so the ragged tail
    # batch is covered instead of sampling only the leading full batches)
    all_ids = [row for ids in results for row in np.asarray(ids)]
    idx = sample_indices(args.queries, RECALL_SAMPLE)
    recall = mean_recall_entries(
        x, [(qs[i], all_ids[i], args.k) for i in idx])
    print(json.dumps({
        "mode": "static",
        "method": args.method, "k": args.k, "batch": batch,
        "shards": args.shards, "tau_pred": args.tau_pred,
        "operating_point": operating_point,
        "qps": round(qps, 2),
        "ms_per_query": round(1e3 * dt / args.queries, 2),
        "ms_per_batch": round(1e3 * dt / len(batches), 2),
        "recall_mean": round(recall, 4),
        "recall_queries": int(len(idx))}))
    return 0


def run_async(args, x, qs, index, mesh, n_probe, tuned=None):
    """The micro-batching event loop over ``repro.serving``."""
    from repro.serving import batcher as sv_batcher
    from repro.serving import queue as sv_queue
    from repro.serving import server as sv_server
    from repro.serving.state import ServingState

    if args.method == "flat":
        raise SystemExit("--mode async does not apply to the flat baseline")
    tau_pred_on = args.tau_pred == "on"
    if tau_pred_on and not args.method.endswith("bbc"):
        raise SystemExit("--tau-pred on requires a *_bbc method")
    if tau_pred_on and args.check_parity:
        raise SystemExit(
            "--check-parity compares against non-predictive direct calls; "
            "run it with --tau-pred off")

    ks = tuple(int(s) for s in args.k_choices.split(",")) \
        if args.k_choices else (args.k,)
    deadline = args.deadline_ms / 1e3
    trace = sv_queue.make_trace(
        np.random.default_rng(args.seed), np.asarray(qs), ks,
        rate=args.rate, deadline=deadline, n_probe=n_probe,
        pattern=args.trace, burst=args.burst,
        recall_target=args.recall_target)

    state = ServingState(
        index, use_bbc=args.method.endswith("bbc"), tau_pred=tau_pred_on,
        mesh=mesh, pred_count=args.pred_count, tuned=tuned)
    max_wait = args.max_wait_ms / 1e3 if args.max_wait_ms else None
    if args.replicas > 1:
        # fault-tolerant multi-replica tier: affinity routing, health
        # checks, retries/hedges, supervisor respawn (serving/router.py)
        from repro.serving import faults as sv_faults
        from repro.serving.router import (HedgePolicy, ReplicaServer,
                                          RetryPolicy, outcome_digest)
        schedule = sv_faults.FaultSchedule.parse(args.faults) \
            if args.faults else None
        # degrade along the tuned recall/cost frontier when the store
        # covers this method (lower recall target + narrower n_probe per
        # rung), instead of the blunt hand-picked k-caps
        ladder = None
        if tuned is not None:
            from repro.serving.admission import DegradeLadder
            frontier = tuned.frontier(state.kind, max(ks))
            if len(frontier) > 1:
                ladder = DegradeLadder.from_frontier(frontier)
        srv = ReplicaServer(
            state, args.replicas, ceilings=sv_batcher.k_ceilings(ks),
            batch=args.max_batch, ladder=ladder,
            retry=RetryPolicy(max_retries=args.retries),
            hedge=HedgePolicy(enabled=args.hedge == "on"),
            faults=schedule, max_wait=max_wait,
            hb_interval=args.hb_ms / 1e3,
            respawn_delay=args.respawn_ms / 1e3)
    elif args.faults:
        raise SystemExit("--faults requires --replicas > 1 (faults are "
                         "injected at the replica service boundary)")
    else:
        srv = sv_server.Server(
            state, ceilings=sv_batcher.k_ceilings(ks),
            batch=args.max_batch, admission=not args.no_admission,
            max_wait=max_wait)
    n_buckets = len({(min(r.k, max(ks)), r.n_probe) for r in trace})
    t0 = time.monotonic()
    srv.warmup(trace)
    print(f"[serve] warmed {n_buckets} shape buckets in "
          f"{time.monotonic()-t0:.1f}s", flush=True)
    outcomes = srv.run_trace(trace, warmup=False)

    # per-bucket knob provenance rides in the summary line: which tuned
    # operating point (or "hand-tuned fallback") served each bucket
    summary = sv_server.summarize(outcomes, state=state)
    if args.replicas > 1:
        summary.update({
            "replicas": args.replicas, "faults": args.faults or "",
            "outcome_digest": outcome_digest(outcomes),
            "fault_stats": dict(sorted(srv.stats.items())),
        })
    done = [o for o in outcomes if o.status != sv_server.SHED]
    idx = sample_indices(len(done), RECALL_SAMPLE)
    # None (json null), not NaN, when everything was shed — the summary
    # line must stay strictly parseable exactly when it reports a pathology
    recall = mean_recall_entries(
        x, [(jnp.asarray(done[i].request.q), done[i].ids,
             done[i].k_effective) for i in idx]) if done else None

    parity = n_checked = None
    if args.check_parity:
        parity, n_checked = sv_server.parity_vs_direct(state, outcomes)

    summary.update({
        "mode": "async", "method": args.method, "trace": args.trace,
        "rate": args.rate, "deadline_ms": args.deadline_ms,
        "k_choices": list(ks), "max_batch": args.max_batch,
        "shards": args.shards, "tau_pred": args.tau_pred,
        "recall_mean": round(recall, 4) if recall is not None else None,
        "recall_queries": int(len(idx)),
    })
    if parity is not None:
        summary["parity"] = round(parity, 4)
        summary["parity_checked"] = n_checked
    print(json.dumps(summary))
    # an all-shed run verified nothing: that's a parity FAILURE, not a pass
    return 1 if (parity is not None and (parity < 1.0 or n_checked == 0)) \
        else 0


def _parse_net_addr(spec: str):
    """'' -> driver default; 'unix:/path' -> Unix socket; 'host:port' ->
    TCP."""
    from repro.transport.master import tcp_addr, unix_addr
    if not spec:
        return None
    if spec.startswith("unix:"):
        return unix_addr(spec[len("unix:"):])
    host, _, port = spec.rpartition(":")
    try:
        return tcp_addr(host or "127.0.0.1", int(port))
    except ValueError:
        raise SystemExit(f"--addr {spec!r}: want 'unix:/path' or "
                         f"'host:port'")


def run_net(args):
    """The multi-process socket front-end (``repro.transport``)."""
    import signal
    import threading

    from repro.serving import faults as sv_faults
    from repro.serving import server as sv_server
    from repro.serving.batcher import k_ceilings
    from repro.serving.queue import make_zipf_trace
    from repro.serving.router import outcome_digest
    from repro.transport.client import NetClient
    from repro.transport.core import MasterConfig
    from repro.transport.enginehost import build_spec, make_dataset
    from repro.transport.master import MasterServer

    ks = tuple(int(s) for s in args.k_choices.split(",")) \
        if args.k_choices else (args.k,)
    n_clusters = min(args.n_clusters, max(args.n // 64, 16))
    n_probe = min(args.n_probe, n_clusters)
    spec = build_spec(n=args.n, d=args.d, seed=args.seed, ks=ks,
                      n_probe=n_probe, n_clusters=n_clusters)
    wire = sv_faults.WireSchedule.parse(args.wire_faults) \
        if args.wire_faults else None
    cfg = MasterConfig(n_workers=args.workers, ceilings=k_ceilings(ks),
                       cache_size=args.net_cache,
                       hb_interval=args.hb_ms / 1e3)
    ms = MasterServer(cfg, spec, addr=_parse_net_addr(args.addr), wire=wire,
                      record=bool(args.record) or args.check_replay)
    t0 = time.monotonic()
    ms.start()
    if not ms.wait_workers(timeout=300.0):
        print(json.dumps({"error": "workers failed to come up"}))
        ms.shutdown()
        return 1
    print(f"[serve] {args.workers} workers ready in "
          f"{time.monotonic()-t0:.1f}s on {ms.addr}", flush=True)

    want_drain = threading.Event()
    signal.signal(signal.SIGTERM, lambda s, f: want_drain.set())
    signal.signal(signal.SIGINT, lambda s, f: want_drain.set())

    records: dict[int, dict] = {}
    client_thread = None
    if not args.serve_forever:
        rng = np.random.default_rng(args.seed + 1)
        x = make_dataset(spec)
        pool = synthetic.queries_from(rng, x,
                                      max(args.requests // 8, 4))
        trace = make_zipf_trace(rng, pool, args.requests, ks,
                                rate=args.rate,
                                deadline=args.deadline_ms / 1e3,
                                n_probe=n_probe)

        def _drive():
            try:
                with NetClient(ms.addr) as c:
                    records.update(c.run_trace(trace))
            finally:
                want_drain.set()
        client_thread = threading.Thread(target=_drive, daemon=True)
        client_thread.start()
    else:
        print(json.dumps({"event": "listening", "addr": ms.addr}),
              flush=True)

    while not ms.stopped:
        if want_drain.is_set():
            ms.drain()
        if ms._drain_started is not None and (
                ms.core.idle() or ms.clock.now() - ms._drain_started
                > ms.drain_timeout):
            ms.shutdown()
            break
        ms.step()
    if client_thread is not None:
        client_thread.join(timeout=10.0)

    outcomes = ms.core.outcome_list()
    summary = sv_server.summarize(outcomes)
    summary.update({
        "mode": "net", "workers": args.workers,
        "k_choices": list(ks), "rate": args.rate,
        "wire_faults": args.wire_faults or "",
        "outcome_digest": outcome_digest(outcomes),
        "net_stats": {k: v for k, v in sorted(ms.core.stats.items()) if v},
        "cache": ms.core.cache_stats(),
    })
    if records:
        done = [r for r in records.values()
                if r["status"] in ("ok", "degraded")]
        summary["client_completed"] = len(done)
        lat = sorted(r["latency_s"] for r in done)
        if lat:
            summary["client_p99_ms"] = round(
                1e3 * lat[min(int(0.99 * len(lat)), len(lat) - 1)], 2)
    rc = 0
    if args.check_replay:
        from repro.transport.enginehost import (build_state_from_spec,
                                                make_exec_fn)
        from repro.transport.replay import replay_transcript
        state, ceil = build_state_from_spec(spec)
        res = replay_transcript(ms.transcript, cfg, state.centroids,
                                make_exec_fn(state, ceil))
        summary["replay_digest"] = res.digest
        summary["replay_identical"] = \
            res.digest == summary["outcome_digest"]
        if not summary["replay_identical"]:
            rc = 1
    if args.record:
        ms.transcript.save(args.record)
        summary["transcript"] = args.record
    print(json.dumps(summary))
    return rc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=96)
    ap.add_argument("--k", type=int, default=5_000)
    ap.add_argument("--method", choices=METHODS, default="ivfpq_bbc")
    ap.add_argument("--n-probe", type=int, default=64)
    ap.add_argument("--n-clusters", type=int, default=316)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--mode", choices=("static", "async", "net"),
                    default="static",
                    help="static = fixed-batch synchronous loop; async = "
                         "deadline-aware micro-batching over an open-loop "
                         "arrival trace (repro.serving); net = real "
                         "multi-process socket front-end "
                         "(repro.transport)")
    ap.add_argument("--batch", type=int, default=32,
                    help="[static] queries per engine call (1 = "
                         "single-query path)")
    ap.add_argument("--shards", type=int, default=1,
                    help="mesh-shard the corpus over this many devices "
                         "(forces host devices when none are present)")
    ap.add_argument("--tau-pred", choices=("on", "off"), default="off",
                    help="predictive early-exact re-ranking: the serving "
                         "loop maintains a cross-batch threshold predictor "
                         "(EMA over previous batches' bucket histograms) "
                         "and threads it through every engine call "
                         "(per shape bucket in --mode async)")
    ap.add_argument("--pred-count", type=int, default=None,
                    help="predictive re-rank pool target (default ~2.5k). "
                         "The pool is a subset of the static n_cand cut, so "
                         "on coarse-estimate indexes (paper-default M=d/4 "
                         "4-bit PQ) a shallow pool trades recall for fewer "
                         "re-ranks; raise toward n_cand to recover the "
                         "static selection")
    ap.add_argument("--tuned", type=str, default="auto",
                    help="tuned operating points: 'auto' loads "
                         "tuned_points.json from the repo root (or "
                         "$REPRO_TUNED_POINTS) when present, 'off' forces "
                         "the hand-tuned defaults, anything else is a path "
                         "to a point-store JSON.  The summary line reports "
                         "which operating point (or 'hand-tuned fallback') "
                         "served each bucket")
    ap.add_argument("--recall-target", type=float, default=0.95,
                    help="recall@k requirement: selects the tuned operating "
                         "point knobs resolve from, and stamps async-mode "
                         "requests (the DegradeLadder may lower it under "
                         "overload, serving a cheaper tuned point)")
    # -- async-mode knobs ---------------------------------------------------
    ap.add_argument("--trace", choices=("poisson", "bursty"),
                    default="poisson", help="[async] arrival pattern")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="[async] offered load, requests/s")
    ap.add_argument("--deadline-ms", type=float, default=500.0,
                    help="[async] per-request deadline after arrival")
    ap.add_argument("--k-choices", type=str, default="",
                    help="[async] comma-separated k values sampled per "
                         "request (default: just --k); the bucket ladder")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="[async] padded batch width B of the shape buckets")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="[async] cap on queueing wait before a partial "
                         "batch fires (default: deadline-slack only)")
    ap.add_argument("--burst", type=int, default=8,
                    help="[async] burst size for --trace bursty")
    ap.add_argument("--no-admission", action="store_true",
                    help="[async] disable admission control (serve "
                         "everything, deadlines may blow)")
    ap.add_argument("--check-parity", action="store_true",
                    help="[async] verify every completed request's ids "
                         "against a direct engine call; exit non-zero on "
                         "any mismatch")
    # -- multi-replica fault-tolerance knobs (async mode) ---------------------
    ap.add_argument("--replicas", type=int, default=1,
                    help="[async] replica pool size; > 1 routes through the "
                         "fault-tolerant tier (affinity routing, health "
                         "checks, retries, hedges, supervisor respawn)")
    ap.add_argument("--faults", type=str, default="",
                    help="[async] deterministic fault schedule, e.g. "
                         "'crash@1:t=0.5;stall@0:t=0.2,dur=0.1;"
                         "slow@2:t=0.0,dur=1.0,factor=4;corrupt@3:t=0.3,"
                         "dur=0.2' (requires --replicas > 1)")
    ap.add_argument("--retries", type=int, default=2,
                    help="[async] max retry attempts per request after a "
                         "timeout or corrupt response (--replicas > 1)")
    ap.add_argument("--hedge", choices=("on", "off"), default="on",
                    help="[async] hedged second sends when deadline slack "
                         "runs low; first response wins (--replicas > 1)")
    ap.add_argument("--hb-ms", type=float, default=20.0,
                    help="[async] replica heartbeat interval, ms "
                         "(--replicas > 1)")
    ap.add_argument("--respawn-ms", type=float, default=50.0,
                    help="[async] supervisor respawn delay after a replica "
                         "is marked DOWN, ms (--replicas > 1)")
    # -- net-mode knobs (--mode net) ------------------------------------------
    ap.add_argument("--workers", type=int, default=4,
                    help="[net] worker subprocesses to spawn and supervise")
    ap.add_argument("--net-cache", type=int, default=256,
                    help="[net] exact-key result cache capacity in the "
                         "master (0 = off)")
    ap.add_argument("--wire-faults", type=str, default="",
                    help="[net] seeded wire-fault schedule, e.g. "
                         "'drop=0.02,dup=0.01,slow=0.1,slow_ms=2:8,"
                         "disconnect=0.005,seed=7'")
    ap.add_argument("--record", type=str, default="",
                    help="[net] write the run's record/replay transcript "
                         "to this path")
    ap.add_argument("--check-replay", action="store_true",
                    help="[net] after the run, replay the transcript "
                         "in-process and exit non-zero unless the "
                         "outcome digest is byte-identical")
    ap.add_argument("--serve-forever", action="store_true",
                    help="[net] keep serving until SIGTERM/SIGINT, then "
                         "drain gracefully and exit 0")
    ap.add_argument("--addr", type=str, default="",
                    help="[net] listen address: 'unix:/path' or "
                         "'host:port' (default: a Unix socket in a "
                         "fresh run dir)")
    ap.add_argument("--requests", type=int, default=200,
                    help="[net] trace length for the built-in driver")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace/corpus RNG seed")
    args = ap.parse_args()

    if args.mode == "net":
        sys.exit(run_net(args))

    mesh = None
    if args.shards > 1:
        if args.method == "flat":
            raise SystemExit("--shards does not apply to the flat baseline")
        if len(jax.devices()) < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs {args.shards} devices, have "
                f"{len(jax.devices())} (is XLA_FLAGS already set?)")
        mesh = jax.make_mesh((args.shards,), ("model",))

    n_probe = min(args.n_probe, args.n_clusters)
    rng = np.random.default_rng(args.seed)
    x = jnp.asarray(synthetic.clustered(rng, args.n, args.d))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), args.queries))

    t0 = time.monotonic()
    index = build_index(args.method, x, args.n_clusters)
    print(f"[serve] index built in {time.monotonic()-t0:.1f}s", flush=True)

    tuned = None
    if args.tuned != "off":
        from repro.tuning.points import PointStore
        store = PointStore.load(None if args.tuned == "auto" else args.tuned)
        if args.tuned != "auto" and not len(store):
            raise SystemExit(f"--tuned {args.tuned}: no usable point store")
        tuned = store if len(store) else None

    run = run_async if args.mode == "async" else run_static
    sys.exit(run(args, x, qs, index, mesh, n_probe, tuned=tuned))


if __name__ == "__main__":
    main()
