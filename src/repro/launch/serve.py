"""Batched large-k retrieval serving driver (the paper's workload).

Builds a quantized ANN index over a corpus and serves batched large-k
queries through the BBC search path.  This is the end-to-end driver for the
paper's kind of system (serving); ``examples/serve_retrieval.py`` wires an
LM encoder in front of it.

  PYTHONPATH=src python -m repro.launch.serve --n 100000 --d 96 --k 5000 \
      --method ivfpq_bbc --queries 20
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.index import flat, search


METHODS = ("ivfpq", "ivfpq_bbc", "ivfrabitq", "ivfrabitq_bbc", "flat")


def build_index(method: str, x, n_clusters: int, seed: int = 0):
    key = jax.random.key(seed)
    if method.startswith("ivfpq"):
        return search.build_pq_index(key, x, n_clusters)
    if method.startswith("ivfrabitq"):
        return search.build_rabitq_index(key, x, n_clusters)
    return None


def make_searcher(method: str, index, x, k: int, n_probe: int, n_cand: int):
    if method == "flat":
        return lambda q: flat.search(x, q, k)[:2]
    if method.startswith("ivfpq"):
        return lambda q: search.ivf_pq_search(
            index, q, k=k, n_probe=n_probe, n_cand=n_cand,
            use_bbc=method.endswith("bbc"))[:2]
    return lambda q: search.ivf_rabitq_search(
        index, q, k=k, n_probe=n_probe,
        use_bbc=method.endswith("bbc"))[:2]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=96)
    ap.add_argument("--k", type=int, default=5_000)
    ap.add_argument("--method", choices=METHODS, default="ivfpq_bbc")
    ap.add_argument("--n-probe", type=int, default=64)
    ap.add_argument("--n-clusters", type=int, default=316)
    ap.add_argument("--queries", type=int, default=20)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    x = jnp.asarray(synthetic.clustered(rng, args.n, args.d))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), args.queries))
    n_cand = min(8 * args.k, args.n)

    t0 = time.monotonic()
    index = build_index(args.method, x, args.n_clusters)
    print(f"[serve] index built in {time.monotonic()-t0:.1f}s", flush=True)

    searcher = make_searcher(args.method, index, x, args.k, args.n_probe,
                             n_cand)
    # warmup / compile
    d, i = searcher(qs[0])
    jax.block_until_ready((d, i))

    t0 = time.monotonic()
    for q in qs:
        d, i = searcher(q)
    jax.block_until_ready((d, i))
    dt = time.monotonic() - t0
    qps = args.queries / dt
    # recall vs exact on the last query
    gt_d, gt_i = flat.search(x, qs[-1], args.k)
    recall = len(set(np.asarray(i).tolist())
                 & set(np.asarray(gt_i).tolist())) / args.k
    print(json.dumps({"method": args.method, "k": args.k, "qps": round(qps, 2),
                      "ms_per_query": round(1e3 / qps, 2),
                      "recall_sample": round(recall, 4)}))


if __name__ == "__main__":
    main()
