"""Batched large-k retrieval serving driver (the paper's workload).

Builds a quantized ANN index over a corpus and serves large-k queries
through the batched fused-kernel search engine (``index.engine``): one
routing matmul per batch, one shared candidate-stream gather, batched
estimate/bucketize/re-rank kernels.  ``--batch 1`` falls back to the
single-query searchers.  ``examples/serve_retrieval.py`` wires an LM encoder
in front of this.

  PYTHONPATH=src python -m repro.launch.serve --n 100000 --d 96 --k 5000 \
      --method ivfpq_bbc --queries 64 --batch 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.index import engine, flat, search


METHODS = ("ivfpq", "ivfpq_bbc", "ivfrabitq", "ivfrabitq_bbc", "flat")


def build_index(method: str, x, n_clusters: int, seed: int = 0):
    key = jax.random.key(seed)
    if method.startswith("ivfpq"):
        return search.build_pq_index(key, x, n_clusters)
    if method.startswith("ivfrabitq"):
        return search.build_rabitq_index(key, x, n_clusters)
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=96)
    ap.add_argument("--k", type=int, default=5_000)
    ap.add_argument("--method", choices=METHODS, default="ivfpq_bbc")
    ap.add_argument("--n-probe", type=int, default=64)
    ap.add_argument("--n-clusters", type=int, default=316)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32,
                    help="queries per engine call (1 = single-query path)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    x = jnp.asarray(synthetic.clustered(rng, args.n, args.d))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), args.queries))
    n_cand = min(8 * args.k, args.n)

    t0 = time.monotonic()
    index = build_index(args.method, x, args.n_clusters)
    print(f"[serve] index built in {time.monotonic()-t0:.1f}s", flush=True)

    if args.method == "flat":
        searcher = lambda q: flat.search(x, q, args.k)  # noqa: E731
        batch = 1
    else:
        eng = engine.SearchEngine.build(
            index, k=args.k, n_probe=args.n_probe, n_cand=n_cand,
            use_bbc=args.method.endswith("bbc"))
        searcher = eng.search
        batch = max(1, args.batch)

    batches = [qs[i:i + batch] for i in range(0, args.queries, batch)]
    if batch == 1:
        batches = [q for q in qs]

    # warmup / compile — the final batch may be ragged (queries % batch),
    # which is a distinct jit shape; compile it outside the timed loop too
    r = searcher(batches[0])
    jax.block_until_ready(r)
    if batch > 1 and batches[-1].shape[0] != batches[0].shape[0]:
        r = searcher(batches[-1])
        jax.block_until_ready(r)

    t0 = time.monotonic()
    for qb in batches:
        r = searcher(qb)
    jax.block_until_ready(r)
    dt = time.monotonic() - t0
    qps = args.queries / dt
    # recall vs exact on the last query
    last_ids = r[1] if batch == 1 or r[1].ndim == 1 else r[1][-1]
    gt_d, gt_i = flat.search(x, qs[-1], args.k)
    recall = len(set(np.asarray(last_ids).tolist())
                 & set(np.asarray(gt_i).tolist())) / args.k
    print(json.dumps({
        "method": args.method, "k": args.k, "batch": batch,
        "qps": round(qps, 2),
        "ms_per_query": round(1e3 * dt / args.queries, 2),
        "ms_per_batch": round(1e3 * dt / len(batches), 2),
        "recall_sample": round(recall, 4)}))


if __name__ == "__main__":
    main()
