"""Batched large-k retrieval serving driver (the paper's workload).

Builds a quantized ANN index over a corpus and serves large-k queries
through the batched fused-kernel search engine (``index.engine``): one
routing matmul per batch, one shared candidate-stream gather, batched
estimate/bucketize/re-rank kernels.  ``--batch 1`` falls back to the
single-query searchers.  ``examples/serve_retrieval.py`` wires an LM encoder
in front of this.

  PYTHONPATH=src python -m repro.launch.serve --n 100000 --d 96 --k 5000 \
      --method ivfpq_bbc --queries 64 --batch 32

``--shards N`` serves the same index mesh-sharded over N devices (the
distributed BBC collector: per-shard scan, histogram psum, survivor-only
all-gather).  On a CPU host without real accelerators the flag forces N
host devices so the collective path is exercised end-to-end:

  PYTHONPATH=src python -m repro.launch.serve --method ivfpq_bbc --shards 8

``--tau-pred on`` switches on predictive early-exact re-ranking: the loop
maintains a cross-batch threshold predictor (EMA over the bucket histograms
of previous batches) and threads it through every engine call, so the
re-rank pool shrinks from the static n_cand cut to the predicted threshold
with a correctness fallback (see index/engine.py and core/rerank.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _forced_shards() -> int:
    """Pre-jax-import peek at --shards: forcing host devices only works via
    XLA_FLAGS set before jax initializes its backends.  Malformed values
    fall through to 1 so argparse reports them properly later."""
    argv = sys.argv
    for i, a in enumerate(argv):
        val = None
        if a == "--shards" and i + 1 < len(argv):
            val = argv[i + 1]
        elif a.startswith("--shards="):
            val = a.split("=", 1)[1]
        if val is not None:
            try:
                return int(val)
            except ValueError:
                return 1
    return 1


if __name__ == "__main__":
    # only when running as the serve entrypoint — importing this module for
    # its helpers must not scan argv or rewrite the process environment
    _n_shards = _forced_shards()
    if _n_shards > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_n_shards}").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.index import engine, flat, search


METHODS = ("ivfpq", "ivfpq_bbc", "ivfrabitq", "ivfrabitq_bbc", "flat")
RECALL_SAMPLE = 8   # queries with exact ground truth for the recall estimate


def build_index(method: str, x, n_clusters: int, seed: int = 0):
    key = jax.random.key(seed)
    if method.startswith("ivfpq"):
        return search.build_pq_index(key, x, n_clusters)
    if method.startswith("ivfrabitq"):
        return search.build_rabitq_index(key, x, n_clusters)
    return None


def mean_recall(x, qs, ids_by_query, k: int) -> float:
    """Mean recall@k over a query sample, against exact ground truth."""
    recalls = []
    for q, ids in zip(qs, ids_by_query):
        _, gt_i = flat.search(x, q, k)
        got = set(np.asarray(ids).tolist()) - {-1}
        recalls.append(len(got & set(np.asarray(gt_i).tolist())) / k)
    return float(np.mean(recalls))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=96)
    ap.add_argument("--k", type=int, default=5_000)
    ap.add_argument("--method", choices=METHODS, default="ivfpq_bbc")
    ap.add_argument("--n-probe", type=int, default=64)
    ap.add_argument("--n-clusters", type=int, default=316)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32,
                    help="queries per engine call (1 = single-query path)")
    ap.add_argument("--shards", type=int, default=1,
                    help="mesh-shard the corpus over this many devices "
                         "(forces host devices when none are present)")
    ap.add_argument("--tau-pred", choices=("on", "off"), default="off",
                    help="predictive early-exact re-ranking: the serving "
                         "loop maintains a cross-batch threshold predictor "
                         "(EMA over previous batches' bucket histograms) "
                         "and threads it through every engine call")
    ap.add_argument("--pred-count", type=int, default=None,
                    help="predictive re-rank pool target (default ~2.5k). "
                         "The pool is a subset of the static n_cand cut, so "
                         "on coarse-estimate indexes (paper-default M=d/4 "
                         "4-bit PQ) a shallow pool trades recall for fewer "
                         "re-ranks; raise toward n_cand to recover the "
                         "static selection")
    args = ap.parse_args()

    mesh = None
    if args.shards > 1:
        if args.method == "flat":
            raise SystemExit("--shards does not apply to the flat baseline")
        if len(jax.devices()) < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs {args.shards} devices, have "
                f"{len(jax.devices())} (is XLA_FLAGS already set?)")
        mesh = jax.make_mesh((args.shards,), ("model",))

    n_probe = min(args.n_probe, args.n_clusters)
    rng = np.random.default_rng(0)
    x = jnp.asarray(synthetic.clustered(rng, args.n, args.d))
    qs = jnp.asarray(synthetic.queries_from(rng, np.asarray(x), args.queries))
    n_cand = min(8 * args.k, args.n)

    t0 = time.monotonic()
    index = build_index(args.method, x, args.n_clusters)
    print(f"[serve] index built in {time.monotonic()-t0:.1f}s", flush=True)

    tau_pred_on = args.tau_pred == "on"
    if args.method == "flat":
        if tau_pred_on:
            raise SystemExit("--tau-pred does not apply to the flat baseline")
        searcher = lambda q: flat.search(x, q, args.k)  # noqa: E731
        batch = 1
    else:
        if tau_pred_on and not args.method.endswith("bbc"):
            raise SystemExit("--tau-pred on requires a *_bbc method")
        eng = engine.SearchEngine.build(
            index, k=args.k, n_probe=n_probe, n_cand=n_cand,
            use_bbc=args.method.endswith("bbc"), mesh=mesh,
            pred_count=args.pred_count)
        if tau_pred_on:
            # the serving loop owns the predictor: every request folds its
            # batch histogram into the EMA that thresholds the next request
            pred_state = [eng.predictor_init()]

            def searcher(qb):
                r, pred_state[0] = eng.search(qb, pred_state=pred_state[0])
                return r
        else:
            searcher = eng.search
        batch = max(1, args.batch)

    batches = [qs[i:i + batch] for i in range(0, args.queries, batch)]
    if batch == 1:
        batches = [q for q in qs]

    # warmup / compile — the final batch may be ragged (queries % batch),
    # which is a distinct jit shape; compile it outside the timed loop too
    r = searcher(batches[0])
    jax.block_until_ready(r)
    if batch > 1 and batches[-1].shape[0] != batches[0].shape[0]:
        r = searcher(batches[-1])
        jax.block_until_ready(r)

    t0 = time.monotonic()
    results = []
    for qb in batches:
        r = searcher(qb)
        ids = r.ids if hasattr(r, "ids") else r[1]   # flat returns a pair
        results.append(ids if ids.ndim > 1 else ids[None])
    jax.block_until_ready(r)
    dt = time.monotonic() - t0
    qps = args.queries / dt

    # recall over a sample of queries vs exact ground truth (the previous
    # single-query spot check was too noisy to mean anything)
    all_ids = [row for ids in results for row in np.asarray(ids)]
    n_sample = min(RECALL_SAMPLE, args.queries)
    recall = mean_recall(x, qs[:n_sample], all_ids[:n_sample], args.k)
    print(json.dumps({
        "method": args.method, "k": args.k, "batch": batch,
        "shards": args.shards, "tau_pred": args.tau_pred,
        "qps": round(qps, 2),
        "ms_per_query": round(1e3 * dt / args.queries, 2),
        "ms_per_batch": round(1e3 * dt / len(batches), 2),
        "recall_mean": round(recall, 4),
        "recall_queries": n_sample}))


if __name__ == "__main__":
    main()
