"""Production mesh + sharding rules for the assigned architecture matrix.

Mesh axes:
  single-pod : (16, 16)      ("data", "model")   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16)   ("pod", "data", "model") = 512 chips

Sharding policy (universal, divisibility-guarded — every arch must compile on
the SAME mesh, including awkward head counts like qwen2's 14 q-heads):

  * weights: the last axis divisible by |model| shards over "model"
    (output-feature / expert / vocab preference), and one further divisible
    axis shards over "data" (FSDP/ZeRO pattern — required to fit dbrx-132b's
    optimizer state); 1-D tensors replicate.  Layer-stacked leading axes are
    scan-carried and never sharded.
  * MoE expert stacks prefer the expert axis for "model" (EP).
  * optimizer state (m, v) mirrors its parameter's spec.
  * batch: global batch shards over ("pod", "data") when divisible, else
    ("data",), else replicated (long_500k has batch 1 — its big tensor is the
    KV/SSM cache, which shards over sequence/heads instead).
  * KV caches: batch -> batch axes; kv-heads or head_dim -> "model";
    sequence -> "data" when batch could not use it.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before importing jax (launch/dryrun.py does this)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def _leaf_spec(path, leaf, model_n: int, data_n: int, hybrid: bool) -> P:
    keys = [getattr(p, "key", "") for p in path]
    shape = leaf.shape
    ndim = len(shape)
    prefix = 0
    if "layers" in keys:
        prefix = 2 if hybrid else 1
    dims: list[Any] = [None] * ndim

    def divisible(ax, n):
        return shape[ax] >= n and shape[ax] % n == 0

    # prefer the expert axis for EP
    name = keys[-1] if keys else ""
    cand_model = list(range(ndim - 1, prefix - 1, -1))
    if name in ("w_gate", "w_up", "w_down") and ndim - prefix >= 3:
        cand_model = [prefix] + cand_model          # expert axis first
    for ax in cand_model:
        if dims[ax] is None and divisible(ax, model_n):
            dims[ax] = "model"
            break
    for ax in range(prefix, ndim):
        if dims[ax] is None and divisible(ax, data_n):
            dims[ax] = "data"
            break
    return P(*dims)


def param_specs(params_shapes, cfg, mesh: Mesh):
    model_n = axis_size(mesh, "model")
    data_n = axis_size(mesh, "data")
    hybrid = cfg.family == "hybrid"
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, model_n, data_n, hybrid),
        params_shapes)


def opt_state_specs(opt_shapes, p_specs):
    """m/v mirror params; step replicates."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), m=p_specs, v=p_specs)


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------

def batch_axes_for(global_batch: int, mesh: Mesh):
    pod_n = axis_size(mesh, "pod")
    data_n = axis_size(mesh, "data")
    if pod_n > 1 and global_batch % (pod_n * data_n) == 0:
        return ("pod", "data")
    if global_batch % data_n == 0:
        return ("data",)
    return None


def batch_specs(cfg, mesh: Mesh, global_batch: int, mode: str):
    ba = batch_axes_for(global_batch, mesh)
    tok = P(ba, None)
    if mode == "train" or mode == "prefill":
        specs = {"tokens": tok, "targets": tok}
        if cfg.family == "vlm":
            specs["patch_embeds"] = P(ba, None, None)
        if cfg.family == "encdec":
            specs = {"tokens": tok, "targets": tok,
                     "frames": P(ba, None, None)}
        if mode == "prefill":
            specs.pop("targets")
        return specs
    # decode
    specs = {"token": P(ba), "pos": P(ba)}
    if cfg.family == "encdec":
        specs["enc_out"] = P(ba, None, None)
    return specs


def cache_specs(cfg, mesh: Mesh, global_batch: int):
    """Specs for init_decode_caches output (family-dependent)."""
    model_n = axis_size(mesh, "model")
    data_n = axis_size(mesh, "data")
    ba = batch_axes_for(global_batch, mesh)
    seq_axis = None if ba is not None else ("data" if data_n > 1 else None)

    def kv_spec(n_lead):  # (lead..., B, S, kv, hd)
        kv_ax = "model" if cfg.n_kv % model_n == 0 else None
        hd_ax = None
        if kv_ax is None and cfg.hd % model_n == 0:
            hd_ax = "model"
        return P(*([None] * n_lead), ba, seq_axis, kv_ax, hd_ax)

    if cfg.family in ("dense", "moe", "vlm"):
        out = {"k": kv_spec(1), "v": kv_spec(1)}
        if cfg.kv_quant:
            out["k_scale"] = P(None, ba, seq_axis)
            out["v_scale"] = P(None, ba, seq_axis)
        return out
    sd = cfg.ssm_dims()

    def ssm_h_spec(n_lead):  # (lead..., B, H, P, N)
        h_ax = "model" if sd.n_heads % model_n == 0 else None
        return P(*([None] * n_lead), ba, h_ax, None, None)

    def conv_spec(n_lead):  # (lead..., B, W-1, C)
        c_ax = "model" if sd.d_conv_ch % model_n == 0 else None
        return P(*([None] * n_lead), ba, None, c_ax)

    if cfg.family == "ssm":
        return {"h": ssm_h_spec(1), "conv": conv_spec(1)}
    if cfg.family == "hybrid":
        return {"h": ssm_h_spec(2), "conv": conv_spec(2),
                "k": kv_spec(1), "v": kv_spec(1)}
    if cfg.family == "encdec":
        return {"k": kv_spec(1), "v": kv_spec(1)}
    raise ValueError(cfg.family)


def to_shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
