"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full-size config, abstract params/optimizer
state (ShapeDtypeStruct — nothing is allocated), the production mesh and
sharding specs, then runs jit(...).lower(...).compile() and records
memory_analysis / cost_analysis / parsed collective bytes into a JSON file
consumed by EXPERIMENTS.md §Dry-run / §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k [--multi-pod] [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all  # full 40-cell matrix
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

import argparse
import json
import sys
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.launch import roofline
from repro.models import model as model_mod
from repro.optim import adamw

SHAPES = {
    "train_4k": dict(mode="train", seq=4096, batch=256),
    "prefill_32k": dict(mode="prefill", seq=32768, batch=32),
    "decode_32k": dict(mode="decode", seq=32768, batch=128),
    "long_500k": dict(mode="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic decode state growth: SSM / hybrid only.
LONG_OK_FAMILIES = ("ssm", "hybrid")


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if sh["mode"] in ("train", "prefill"):
        batch = {"tokens": tok, "targets": tok}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            batch = {"tokens": tok, "targets": tok,
                     "frames": jax.ShapeDtypeStruct(
                         (b, cfg.n_frames, cfg.d_model), cfg.dtype)}
        if sh["mode"] == "prefill":
            batch.pop("targets")
        return batch
    batch = {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frames, cfg.d_model), cfg.dtype)
    return batch


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    sh = SHAPES[shape_name]
    cfg = configs.get(arch)
    if shape_name == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip",
                "reason": "full-attention arch: O(S^2) attention / O(S) KV "
                          "state per token makes 500k-decode quadratic; run "
                          "only for ssm/hybrid (DESIGN.md §Arch-applicability)"}

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = model_mod.build(cfg)

    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    p_specs = mesh_mod.param_specs(params_sds, cfg, mesh)
    p_shard = mesh_mod.to_shardings(p_specs, mesh)
    batch_sds = input_specs(cfg, shape_name)
    b_specs = mesh_mod.batch_specs(cfg, mesh, sh["batch"], sh["mode"])
    b_shard = mesh_mod.to_shardings(b_specs, mesh)

    if sh["mode"] == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_sds = jax.eval_shape(adamw.init, params_sds)
        o_specs = mesh_mod.opt_state_specs(opt_sds, p_specs)
        o_shard = mesh_mod.to_shardings(o_specs, mesh)
        # Microbatched grad accumulation: 8 microbatches bounds activation
        # transients to ~1-2 sequences per chip per microbatch at these
        # global batch sizes (production default for the big archs).
        step_fn = model_mod.make_train_step(model, opt_cfg, n_microbatches=8)
        metric_shard = mesh_mod.to_shardings(
            {"grad_norm": jax.sharding.PartitionSpec(),
             "lr": jax.sharding.PartitionSpec(),
             "loss": jax.sharding.PartitionSpec()}, mesh)
        fn = jax.jit(step_fn,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, metric_shard),
                     donate_argnums=(0, 1))   # params/opt buffers alias in->out
        args = (params_sds, opt_sds, batch_sds)
    elif sh["mode"] == "prefill":
        fn = jax.jit(model.prefill, in_shardings=(p_shard, b_shard))
        args = (params_sds, batch_sds)
    else:  # decode
        caches_sds = jax.eval_shape(
            lambda: model.init_caches(sh["batch"], sh["seq"]))
        c_specs = mesh_mod.cache_specs(cfg, mesh, sh["batch"])
        c_shard = mesh_mod.to_shardings(c_specs, mesh)
        fn = jax.jit(model.decode_step,
                     in_shardings=(p_shard, b_shard, c_shard),
                     out_shardings=(None, c_shard),
                     donate_argnums=(2,))     # KV/SSM caches update in place
        args = (params_sds, batch_sds, caches_sds)

    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    n_mb = 8 if sh["mode"] == "train" else 1
    coll = roofline.collective_bytes_nested(
        hlo, roofline.depth_trips_for(cfg, sh["mode"], sh["seq"], n_mb))
    mf = roofline.model_flops(cfg, sh["mode"], sh["seq"], sh["batch"])
    af = roofline.analytic_flops(cfg, sh["mode"], sh["seq"], sh["batch"])
    ab = roofline.analytic_bytes(cfg, sh["mode"], sh["seq"], sh["batch"],
                                 n_chips, n_mb)
    rf = roofline.roofline_terms(cost, coll, n_chips, mf,
                                 analytic_flops_global=af,
                                 analytic_bytes_chip=ab)

    mem_d = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        mem_d[attr] = getattr(mem, attr, None)
    args_b = mem_d.get("argument_size_in_bytes") or 0
    tmp_b = mem_d.get("temp_size_in_bytes") or 0
    mem_d["per_chip_total_bytes"] = args_b + tmp_b
    mem_d["fits_16gb_hbm"] = bool(args_b + tmp_b < 16e9)

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": int(n_chips),
        "status": "ok",
        "memory": mem_d,
        "roofline": rf,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        arch_ids = list(configs.ALIASES.keys())
        shapes = list(SHAPES)
    else:
        arch_ids = [args.arch]
        shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    results = []
    out_path = args.out or "dryrun_results.json"
    for arch in arch_ids:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                print(f"=== {tag}", flush=True)
                try:
                    r = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    r = {"arch": arch, "shape": shape,
                         "mesh": "multi" if mp else "single",
                         "status": "error", "error": repr(e)[:2000]}
                results.append(r)
                print(json.dumps(r, indent=None, default=str)[:600], flush=True)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1, default=str)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"DONE ok={n_ok} skip={n_skip} error={n_err} -> {out_path}")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
