"""Sharded synthetic token pipeline with deterministic resume.

Each global step's batch is a pure function of (seed, step) — restart at step
k reproduces the exact stream without replaying k-1 steps (the checkpoint
only stores the step counter).  Per-host sharding: a host materializes only
its ``(host_index, n_hosts)`` slice of the global batch.  A background
prefetch thread keeps ``buffer_size`` batches ready (host-side double
buffering; on TPU pods this overlaps host->device transfer with compute).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class TokenPipeline:
    def __init__(self, vocab: int, global_batch: int, seq_len: int,
                 seed: int = 0, host_index: int = 0, n_hosts: int = 1,
                 buffer_size: int = 2):
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seq = seq_len
        self.seed = seed
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.buffer_size = buffer_size

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (host-local slice)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))
        tokens = rng.integers(
            0, self.vocab, (self.local_batch, self.seq + 1), dtype=np.int32)
        return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        """Prefetching iterator resuming at ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=self.buffer_size)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch_at(step)))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                step, batch = q.get()
                yield step, batch
        finally:
            stop.set()
