"""Sharded synthetic token pipeline with deterministic resume.

Each global step's batch is a pure function of (seed, step) — restart at step
k reproduces the exact stream without replaying k-1 steps (the checkpoint
only stores the step counter).  Per-host sharding: a host materializes only
its ``(host_index, n_hosts)`` slice of the global batch.  A background
prefetch thread keeps ``buffer_size`` batches ready (host-side double
buffering; on TPU pods this overlaps host->device transfer with compute).

Tokens follow a fixed random first-order Markov (bigram) chain derived from
the seed, not uniform noise: uniform tokens pin the loss to the ln(vocab)
floor, so training smoke tests had no signal to descend (the seed failure
recorded in ROADMAP.md).  A peaked bigram table gives the stream a skewed
unigram distribution (fast early loss win) and low conditional entropy
(context signal), while staying a pure function of (seed, step, host) so
resume determinism is unchanged.  The table is capped at ``_MAX_BIGRAM``
active tokens so huge real-model vocabs don't materialize a vocab^2 table —
synthetic streams for such configs simply use the first ``_MAX_BIGRAM`` ids.
"""
from __future__ import annotations

import functools
import queue
import threading
from typing import Iterator

import numpy as np

_MAX_BIGRAM = 1024     # active-token cap: bigram table is at most this wide
_BIGRAM_PEAK = 6.0     # logit scale: cond. entropy ~1 nat, unigram ~4.1 vs ln(256)=5.5


@functools.lru_cache(maxsize=8)
def _bigram_cdf(seed: int, vocab: int) -> np.ndarray:
    """(v_eff, v_eff) per-row transition CDF, a pure function of the seed."""
    v_eff = min(vocab, _MAX_BIGRAM)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xB16A]))
    logits = rng.standard_normal((v_eff, v_eff)) * _BIGRAM_PEAK
    p = np.exp(logits - logits.max(axis=1, keepdims=True))
    p /= p.sum(axis=1, keepdims=True)
    return np.cumsum(p, axis=1)


class TokenPipeline:
    """Seeded synthetic token stream with per-host sharding and prefetch."""
    def __init__(self, vocab: int, global_batch: int, seq_len: int,
                 seed: int = 0, host_index: int = 0, n_hosts: int = 1,
                 buffer_size: int = 2):
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seq = seq_len
        self.seed = seed
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.buffer_size = buffer_size

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (host-local slice)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))
        cdf = _bigram_cdf(self.seed, self.vocab)
        v_eff = cdf.shape[0]
        b, s = self.local_batch, self.seq + 1
        tokens = np.zeros((b, s), np.int32)
        tokens[:, 0] = rng.integers(0, v_eff, b)
        u = rng.random((b, s - 1))
        for t in range(s - 1):
            rows = cdf[tokens[:, t]]                       # (b, v_eff)
            # clamp: float cumsum can leave cdf[-1] a hair under 1.0, and a
            # draw above it would index past the table
            nxt = (rows < u[:, [t]]).sum(axis=1)
            tokens[:, t + 1] = np.minimum(nxt, v_eff - 1)
        return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        """Prefetching iterator resuming at ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=self.buffer_size)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch_at(step)))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                step, batch = q.get()
                yield step, batch
        finally:
            stop.set()
