"""Synthetic vector corpora for tests/benchmarks.

Real embedding corpora (Wiki/C4/MSMARCO/Deep100M in the paper) are clustered —
they lie near low-dimensional manifolds with wide distance spread.  Isotropic
Gaussians are the worst case for every quantizer (no structure to exploit,
distance spread ~N(mu, 1/sqrt(2)) regardless of d), so benchmarks on them
understate every method.  ``clustered`` produces a Gaussian mixture whose
distance distribution exhibits the paper's Figure-4 shape: concentration with
a long informative left tail.
"""
from __future__ import annotations

import numpy as np


def clustered(
    rng: np.random.Generator,
    n: int,
    d: int,
    n_centers: int = 256,
    center_scale: float = 2.0,
    point_scale: float = 0.5,
    dtype=np.float32,
) -> np.ndarray:
    centers = rng.standard_normal((n_centers, d)) * center_scale
    asg = rng.integers(0, n_centers, n)
    x = centers[asg] + rng.standard_normal((n, d)) * point_scale
    return x.astype(dtype)


def queries_from(rng: np.random.Generator, x: np.ndarray, n_q: int,
                 jitter: float = 0.1) -> np.ndarray:
    """Queries near corpus points (the paper samples queries from the corpus)."""
    idx = rng.choice(len(x), n_q, replace=False)
    return (x[idx] + rng.standard_normal((n_q, x.shape[1])) * jitter).astype(x.dtype)


def isotropic(rng: np.random.Generator, n: int, d: int, dtype=np.float32) -> np.ndarray:
    return rng.standard_normal((n, d)).astype(dtype)
