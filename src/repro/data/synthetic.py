"""Synthetic vector corpora for tests/benchmarks.

Real embedding corpora (Wiki/C4/MSMARCO/Deep100M in the paper) are clustered —
they lie near low-dimensional manifolds with wide distance spread.  Isotropic
Gaussians are the worst case for every quantizer (no structure to exploit,
distance spread ~N(mu, 1/sqrt(2)) regardless of d), so benchmarks on them
understate every method.  ``clustered`` produces a Gaussian mixture whose
distance distribution exhibits the paper's Figure-4 shape: concentration with
a long informative left tail.
"""
from __future__ import annotations

import numpy as np


def clustered(
    rng: np.random.Generator,
    n: int,
    d: int,
    n_centers: int = 256,
    center_scale: float = 2.0,
    point_scale: float = 0.5,
    dtype=np.float32,
) -> np.ndarray:
    centers = rng.standard_normal((n_centers, d)) * center_scale
    asg = rng.integers(0, n_centers, n)
    x = centers[asg] + rng.standard_normal((n, d)) * point_scale
    return x.astype(dtype)


def queries_from(rng: np.random.Generator, x: np.ndarray, n_q: int,
                 jitter: float = 0.1) -> np.ndarray:
    """Queries near corpus points (the paper samples queries from the corpus)."""
    idx = rng.choice(len(x), n_q, replace=False)
    return (x[idx] + rng.standard_normal((n_q, x.shape[1])) * jitter).astype(x.dtype)


def isotropic(rng: np.random.Generator, n: int, d: int, dtype=np.float32) -> np.ndarray:
    return rng.standard_normal((n, d)).astype(dtype)


def manifold(
    rng: np.random.Generator,
    n: int,
    d: int,
    intrinsic_dim: int = 8,
    n_centers: int = 256,
    zipf_a: float = 1.3,
    center_scale: float = 2.0,
    point_scale: float = 0.35,
    curvature: float = 1.5,
    ambient_noise: float = 0.02,
    dtype=np.float32,
) -> np.ndarray:
    """Realistic corpus: low-dimensional manifold + heavy-tailed clusters.

    Real embedding corpora differ from Gaussian mixtures in two ways that
    matter for quantizer estimate ORDERING (the thing tau-prediction and
    estimate-priority re-ranking consume):

    * points lie near a LOW-dimensional nonlinear manifold embedded in R^d,
      so inter-point distances vary smoothly along a few directions instead
      of concentrating at sqrt(2)·sigma in all d of them — PQ subquantizer
      residuals become anisotropic and the ADC estimate keeps rank
      information deep into the candidate stream;
    * cluster populations are heavy-tailed (Zipf), not uniform: a few head
      clusters dominate the probed set, exactly the regime where the paper's
      per-query equal-depth codebooks pay off over global ones.

    Construction: latent cluster centers in R^intrinsic_dim, Zipf-distributed
    memberships, Gaussian latent spread, then a fixed smooth nonlinear lift
    z -> [z @ A + curvature * sin(z @ B + phase)] into R^d plus small
    isotropic ambient noise.  The lift is the same for every point, so the
    corpus is a (noisy) image of an intrinsic_dim-dimensional manifold.
    """
    if intrinsic_dim > d:
        raise ValueError(f"intrinsic_dim {intrinsic_dim} exceeds d {d}")
    ranks = np.arange(1, n_centers + 1, dtype=np.float64)
    weights = ranks ** -zipf_a
    weights /= weights.sum()
    sizes = rng.multinomial(n, weights)
    asg = np.repeat(np.arange(n_centers), sizes)

    z_centers = rng.standard_normal((n_centers, intrinsic_dim)) * center_scale
    z = z_centers[asg] + rng.standard_normal(
        (n, intrinsic_dim)) * point_scale

    lift_a = rng.standard_normal((intrinsic_dim, d)) / np.sqrt(intrinsic_dim)
    lift_b = rng.standard_normal((intrinsic_dim, d)) / np.sqrt(intrinsic_dim)
    phase = rng.uniform(0.0, 2.0 * np.pi, d)
    x = z @ lift_a + curvature * np.sin(z @ lift_b + phase)
    x += rng.standard_normal((n, d)) * ambient_noise
    rng.shuffle(x)
    return x.astype(dtype)
