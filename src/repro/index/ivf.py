"""IVF coarse index: k-means partition, padded-cluster layout, query routing.

Layout: clusters are stored as a dense (n_clusters, cap) id matrix with a
validity mask — XLA needs static shapes, and the padded layout is also what a
TPU serving deployment uses (fixed-size cluster tiles streaming HBM->VMEM).
``cap`` is the max cluster size rounded up to the lane width.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import kmeans as km


class IVFIndex(NamedTuple):
    """Coarse IVF index: centroids plus the padded per-cluster member table."""
    centroids: jax.Array      # (n_clusters, d)
    member_ids: jax.Array     # (n_clusters, cap) int32, -1 padded
    member_valid: jax.Array   # (n_clusters, cap) bool
    cluster_sizes: jax.Array  # (n_clusters,)

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def cap(self) -> int:
        return self.member_ids.shape[1]


def build(key: jax.Array, x: jax.Array, n_clusters: int, n_iter: int = 10,
          lane: int = 128) -> IVFIndex:
    """k-means + padded member table.  Host-side packing (build is offline)."""
    cent, a = km.kmeans(key, x, n_clusters, n_iter)
    a_np = np.asarray(a)
    sizes = np.bincount(a_np, minlength=n_clusters)
    cap = int(max(int(sizes.max()), 1))
    cap = ((cap + lane - 1) // lane) * lane
    ids = np.full((n_clusters, cap), -1, np.int32)
    for c in range(n_clusters):
        mem = np.where(a_np == c)[0]
        ids[c, : len(mem)] = mem
    return IVFIndex(
        centroids=cent,
        member_ids=jnp.asarray(ids),
        member_valid=jnp.asarray(ids >= 0),
        cluster_sizes=jnp.asarray(sizes.astype(np.int32)),
    )


def route(index: IVFIndex, q: jax.Array, n_probe: int) -> jax.Array:
    """Nearest-first probed cluster list (paper Alg. 4 relies on this order:
    'clusters are traversed from nearest to farthest')."""
    d2 = jnp.sum((index.centroids - q) ** 2, axis=-1)
    return jax.lax.top_k(-d2, n_probe)[1].astype(jnp.int32)


def route_batch_centroids(centroids: jax.Array, qs: jax.Array,
                          n_probe: int) -> tuple[jax.Array, jax.Array]:
    """Centroids-level batch routing: (B, n_probe) nearest-first probed
    clusters + the (B, C) squared query-centroid distances.

    Uses the same per-query distance expression as ``route`` (broadcast
    difference, not the norm-identity matmul) so the probed sets match the
    single-query path bit-for-bit; the centroid table is small enough that
    the (B, C, d) broadcast is cheap.  ``d2`` is returned so estimators that
    need the query-centroid norms (RaBitQ) don't rebuild the broadcast.
    The mesh-sharded searchers call this form directly inside their
    shard_map bodies (replicated routing) — single-device and sharded paths
    MUST route identically, so keep this the one implementation.
    """
    d2 = jnp.sum((centroids[None, :, :] - qs[:, None, :]) ** 2, axis=-1)
    return jax.lax.top_k(-d2, n_probe)[1].astype(jnp.int32), d2


def route_batch_d2(index: IVFIndex, qs: jax.Array,
                   n_probe: int) -> tuple[jax.Array, jax.Array]:
    """(B, n_probe) probed clusters + (B, C) squared distances — one shared
    routing pass (see ``route_batch_centroids``)."""
    return route_batch_centroids(index.centroids, qs, n_probe)


def route_batch(index: IVFIndex, qs: jax.Array, n_probe: int) -> jax.Array:
    """(B, n_probe) probed clusters (see ``route_batch_d2``)."""
    return route_batch_d2(index, qs, n_probe)[0]


def gather_candidates(
    index: IVFIndex, probed: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(n_probe, cap) candidate ids + validity for the probed clusters."""
    ids = index.member_ids[probed]
    valid = index.member_valid[probed]
    return ids, valid


# --------------------------------------------------------------------------
# Compact flat layout (batched search substrate)
# --------------------------------------------------------------------------

class FlatLayout(NamedTuple):
    """Corpus ids re-ordered by cluster, with zero per-cluster padding.

    The padded (n_clusters, cap) member table wastes (cap - |cluster|) lanes
    per probed cluster — on skewed corpora that is most of the scan.  The
    flat layout is the batched-search substrate: the candidate stream is
    gathered ONCE per batch in cluster order, and each query selects its
    probed lanes with a boolean mask (``probe_mask``).  Only the stream tail
    is padded (to the lane width).

    ``order``      : (n_flat,) int32 corpus ids, cluster-major.
    ``cluster_of`` : (n_flat,) int32 owning cluster; ``n_clusters`` on the
                     padding tail (maps to the always-False probe-mask slot).
    ``offsets``    : (n_clusters + 1,) int32 start offset of each cluster.
    ``valid``      : (n_flat,) bool, False on the padding tail.
    """

    order: jax.Array
    cluster_of: jax.Array
    offsets: jax.Array
    valid: jax.Array

    @property
    def n_flat(self) -> int:
        return self.order.shape[0]


def flat_layout(index: IVFIndex, lane: int = 128) -> FlatLayout:
    """Host-side packing of the member table into a FlatLayout (offline)."""
    ids = np.asarray(index.member_ids)
    sizes = np.asarray(index.cluster_sizes).astype(np.int64)
    n_clusters = ids.shape[0]
    n = int(sizes.sum())
    n_flat = ((n + lane - 1) // lane) * lane
    order = np.zeros(n_flat, np.int32)
    cluster_of = np.full(n_flat, n_clusters, np.int32)
    offsets = np.zeros(n_clusters + 1, np.int32)
    pos = 0
    for c in range(n_clusters):
        sz = int(sizes[c])
        offsets[c] = pos
        order[pos:pos + sz] = ids[c, :sz]
        cluster_of[pos:pos + sz] = c
        pos += sz
    offsets[n_clusters] = pos
    valid = np.arange(n_flat) < n
    return FlatLayout(
        order=jnp.asarray(order),
        cluster_of=jnp.asarray(cluster_of),
        offsets=jnp.asarray(offsets),
        valid=jnp.asarray(valid),
    )


def probe_mask(layout: FlatLayout, probed: jax.Array,
               n_clusters: int) -> jax.Array:
    """(B, n_flat) lane mask: lane j is live for query b iff its cluster is
    in ``probed[b]`` (and j is not stream-tail padding)."""
    b = probed.shape[0]
    hit = jnp.zeros((b, n_clusters + 1), bool)
    hit = hit.at[jnp.arange(b, dtype=jnp.int32)[:, None], probed].set(True)
    hit = hit.at[:, n_clusters].set(False)   # padding-tail slot stays dead
    return hit[:, layout.cluster_of] & layout.valid[None, :]


def tile_positions(layout: FlatLayout, clusters: jax.Array,
                   cap: int) -> tuple[jax.Array, jax.Array]:
    """Stream positions of the members of ``clusters`` (B, t), padded to
    ``cap`` lanes per cluster.

    Returns (positions (B, t * cap) int32, valid (B, t * cap)).  Used to
    gather per-query views (codebook samples, per-cluster re-rank tiles)
    out of batched (B, n_flat) stream quantities.
    """
    offs = layout.offsets[clusters]                       # (B, t)
    sizes = layout.offsets[clusters + 1] - offs           # (B, t)
    lane = jnp.arange(cap, dtype=jnp.int32)
    pos = offs[..., None] + lane[None, None, :]           # (B, t, cap)
    ok = lane[None, None, :] < sizes[..., None]
    pos = jnp.where(ok, pos, 0)
    b, t = clusters.shape
    return pos.reshape(b, t * cap), ok.reshape(b, t * cap)


# --------------------------------------------------------------------------
# Mesh-sharded layout (distributed search substrate)
# --------------------------------------------------------------------------

class ShardedLayout(NamedTuple):
    """Row-sharded partition of the ``FlatLayout`` candidate stream.

    Each cluster's members are dealt round-robin across shards, so every chip
    holds ~1/S of EVERY cluster — the per-chip scan work is balanced no
    matter which clusters a query probes, and the global top-k of any probe
    set spreads evenly over shards (which is what makes a small fixed
    per-shard survivor budget safe; see ``core.distributed``).

    All arrays are stacked with a leading shard axis so they shard over the
    mesh's ``model`` axis with ``P("model", None)`` and each chip's block is
    itself a valid ``FlatLayout`` (same field meanings, global corpus ids):

    ``order``      : (S, F) int32 global corpus ids, cluster-major per shard.
    ``cluster_of`` : (S, F) int32 owning cluster; ``n_clusters`` on padding.
    ``offsets``    : (S, C + 1) int32 per-shard cluster start offsets.
    ``valid``      : (S, F) bool, False on each shard's padding tail.

    Built host-side (offline, like ``flat_layout``); ``cap_shard`` — the max
    per-shard cluster segment length, needed as a static width by
    ``tile_positions`` on shard-local layouts — is returned alongside.
    """

    order: jax.Array
    cluster_of: jax.Array
    offsets: jax.Array
    valid: jax.Array

    @property
    def n_shards(self) -> int:
        return self.order.shape[0]

    @property
    def shard_flat(self) -> int:
        return self.order.shape[1]

    def local(self, j: int | jax.Array) -> FlatLayout:
        """Shard j's block as a FlatLayout (use inside shard_map bodies on
        the squeezed per-shard arrays, or host-side for tests)."""
        return FlatLayout(order=self.order[j], cluster_of=self.cluster_of[j],
                          offsets=self.offsets[j], valid=self.valid[j])


def sharded_layout(index: IVFIndex, n_shards: int,
                   lane: int = 128) -> tuple[ShardedLayout, int]:
    """Partition the member table into ``n_shards`` stream segments
    (host-side, offline).  Returns ``(layout, cap_shard)``.

    Shard j takes members ``j::n_shards`` of every cluster, preserving the
    cluster-major order inside each shard, so concatenating the shards'
    per-cluster segments reconstructs each cluster's member set exactly
    (asserted by tests/test_sharded.py).
    """
    ids = np.asarray(index.member_ids)
    sizes = np.asarray(index.cluster_sizes).astype(np.int64)
    n_clusters = ids.shape[0]
    seg = [[ids[c, : sizes[c]][j::n_shards] for c in range(n_clusters)]
           for j in range(n_shards)]
    flat_sizes = [sum(len(s) for s in segs) for segs in seg]
    f = max(max(flat_sizes), 1)
    f = ((f + lane - 1) // lane) * lane
    order = np.zeros((n_shards, f), np.int32)
    cluster_of = np.full((n_shards, f), n_clusters, np.int32)
    offsets = np.zeros((n_shards, n_clusters + 1), np.int32)
    valid = np.zeros((n_shards, f), bool)
    cap_shard = 1
    for j in range(n_shards):
        pos = 0
        for c in range(n_clusters):
            s = seg[j][c]
            offsets[j, c] = pos
            order[j, pos:pos + len(s)] = s
            cluster_of[j, pos:pos + len(s)] = c
            pos += len(s)
            cap_shard = max(cap_shard, len(s))
        offsets[j, n_clusters] = pos
        valid[j, :pos] = True
    return (
        ShardedLayout(
            order=jnp.asarray(order),
            cluster_of=jnp.asarray(cluster_of),
            offsets=jnp.asarray(offsets),
            valid=jnp.asarray(valid),
        ),
        int(cap_shard),
    )


