"""IVF coarse index: k-means partition, padded-cluster layout, query routing.

Layout: clusters are stored as a dense (n_clusters, cap) id matrix with a
validity mask — XLA needs static shapes, and the padded layout is also what a
TPU serving deployment uses (fixed-size cluster tiles streaming HBM->VMEM).
``cap`` is the max cluster size rounded up to the lane width.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import kmeans as km


class IVFIndex(NamedTuple):
    centroids: jax.Array      # (n_clusters, d)
    member_ids: jax.Array     # (n_clusters, cap) int32, -1 padded
    member_valid: jax.Array   # (n_clusters, cap) bool
    cluster_sizes: jax.Array  # (n_clusters,)

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def cap(self) -> int:
        return self.member_ids.shape[1]


def build(key: jax.Array, x: jax.Array, n_clusters: int, n_iter: int = 10,
          lane: int = 128) -> IVFIndex:
    """k-means + padded member table.  Host-side packing (build is offline)."""
    cent, a = km.kmeans(key, x, n_clusters, n_iter)
    a_np = np.asarray(a)
    sizes = np.bincount(a_np, minlength=n_clusters)
    cap = int(max(int(sizes.max()), 1))
    cap = ((cap + lane - 1) // lane) * lane
    ids = np.full((n_clusters, cap), -1, np.int32)
    for c in range(n_clusters):
        mem = np.where(a_np == c)[0]
        ids[c, : len(mem)] = mem
    return IVFIndex(
        centroids=cent,
        member_ids=jnp.asarray(ids),
        member_valid=jnp.asarray(ids >= 0),
        cluster_sizes=jnp.asarray(sizes.astype(np.int32)),
    )


def route(index: IVFIndex, q: jax.Array, n_probe: int) -> jax.Array:
    """Nearest-first probed cluster list (paper Alg. 4 relies on this order:
    'clusters are traversed from nearest to farthest')."""
    d2 = jnp.sum((index.centroids - q) ** 2, axis=-1)
    return jax.lax.top_k(-d2, n_probe)[1].astype(jnp.int32)


def gather_candidates(
    index: IVFIndex, probed: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(n_probe, cap) candidate ids + validity for the probed clusters."""
    ids = index.member_ids[probed]
    valid = index.member_valid[probed]
    return ids, valid


def shard_index(index: IVFIndex, n_shards: int) -> list[IVFIndex]:
    """Row-shard the member table over `model`-axis chips (clusters are
    scattered round-robin so every chip sees every probed cluster's local
    slice — balanced scan work per chip)."""
    cap = index.cap
    per = cap // n_shards
    assert per * n_shards == cap, "cap must divide by n_shards (lane-padded)"
    out = []
    for s in range(n_shards):
        sl = slice(s * per, (s + 1) * per)
        out.append(
            IVFIndex(
                centroids=index.centroids,
                member_ids=index.member_ids[:, sl],
                member_valid=index.member_valid[:, sl],
                cluster_sizes=jnp.sum(index.member_valid[:, sl], axis=1).astype(jnp.int32),
            )
        )
    return out
