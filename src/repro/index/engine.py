"""Batched fused-kernel search engine: the serving-side entry point.

Wraps a built index (IVF / IVF+PQ / IVF+RaBitQ) together with the compact
``ivf.FlatLayout`` candidate stream and static search hyper-parameters, and
serves query batches through the natively batched searchers in
``index.search`` (Pallas kernels on TPU, their jnp mirrors on CPU).

    eng = engine.SearchEngine.build(index, k=5000, n_probe=64, use_bbc=True)
    res = eng.search(qs)            # (B, d) -> SearchResult with (B, k) rows
    res = eng.search(q)             # (d,)   -> single-query SearchResult

The layout (and the one-time host-side packing it needs) is computed once at
engine construction, so steady-state serving is one jit-compiled call per
batch shape.  The engine is deliberately thin: all numerics live in
``search.py`` so the batched functions stay directly testable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax

from repro.index import ivf as ivf_mod
from repro.index import search as search_mod


@dataclass(frozen=True)
class SearchEngine:
    index: Any                       # IVFIndex | PQIndex | RabitqIndex
    layout: ivf_mod.FlatLayout
    kind: str                        # "ivf" | "ivfpq" | "ivfrabitq"
    k: int
    n_probe: int
    n_cand: int | None = None
    use_bbc: bool = True
    m: int = 128
    backend: str | None = None
    vectors: jax.Array | None = None  # required for kind == "ivf"

    @staticmethod
    def build(index, k: int, n_probe: int, n_cand: int | None = None,
              use_bbc: bool = True, m: int = 128,
              backend: str | None = None, vectors=None) -> "SearchEngine":
        if isinstance(index, search_mod.PQIndex):
            kind, ivf = "ivfpq", index.ivf
            if n_cand is None:
                n_cand = min(8 * k, int(index.vectors.shape[0]))
        elif isinstance(index, search_mod.RabitqIndex):
            kind, ivf = "ivfrabitq", index.ivf
        elif isinstance(index, ivf_mod.IVFIndex):
            kind, ivf = "ivf", index
            if vectors is None:
                raise ValueError("kind 'ivf' needs the corpus vectors")
        else:
            raise TypeError(f"unsupported index type: {type(index)!r}")
        layout = ivf_mod.flat_layout(ivf)
        return SearchEngine(index=index, layout=layout, kind=kind, k=k,
                            n_probe=n_probe, n_cand=n_cand, use_bbc=use_bbc,
                            m=m, backend=backend, vectors=vectors)

    # -- query-time ---------------------------------------------------------

    def search(self, qs: jax.Array) -> search_mod.SearchResult:
        """(B, d) batch or (d,) single query -> SearchResult."""
        if qs.ndim == 1:
            return self.search_one(qs)
        return self.search_batch(qs)

    def search_batch(self, qs: jax.Array) -> search_mod.SearchResult:
        if self.kind == "ivfpq":
            return search_mod.ivf_pq_search_batch(
                self.index, qs, self.layout, k=self.k, n_probe=self.n_probe,
                n_cand=self.n_cand, use_bbc=self.use_bbc, m=self.m,
                backend=self.backend)
        if self.kind == "ivfrabitq":
            return search_mod.ivf_rabitq_search_batch(
                self.index, qs, self.layout, k=self.k, n_probe=self.n_probe,
                use_bbc=self.use_bbc, m=self.m, backend=self.backend)
        return search_mod.ivf_search_batch(
            self.index, self.vectors, qs, self.layout, k=self.k,
            n_probe=self.n_probe, use_bbc=self.use_bbc, m=self.m,
            backend=self.backend)

    def search_one(self, q: jax.Array) -> search_mod.SearchResult:
        if self.kind == "ivfpq":
            return search_mod.ivf_pq_search(
                self.index, q, k=self.k, n_probe=self.n_probe,
                n_cand=self.n_cand, use_bbc=self.use_bbc, m=self.m)
        if self.kind == "ivfrabitq":
            return search_mod.ivf_rabitq_search(
                self.index, q, k=self.k, n_probe=self.n_probe,
                use_bbc=self.use_bbc, m=self.m)
        return search_mod.ivf_search(
            self.index, self.vectors, q, k=self.k, n_probe=self.n_probe,
            use_bbc=self.use_bbc, m=self.m)
