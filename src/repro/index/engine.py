"""Batched fused-kernel search engine: the serving-side entry point.

Wraps a built index (IVF / IVF+PQ / IVF+RaBitQ) together with the compact
``ivf.FlatLayout`` candidate stream and static search hyper-parameters, and
serves query batches through the natively batched searchers in
``index.search`` (Pallas kernels on TPU, their jnp mirrors on CPU).

    eng = engine.SearchEngine.build(index, k=5000, n_probe=64, use_bbc=True)
    res = eng.search(qs)            # (B, d) -> SearchResult with (B, k) rows
    res = eng.search(q)             # (d,)   -> single-query SearchResult

Each method (ivf / ivfpq / ivfrabitq) is a strategy object exposing
single-query, batched, and mesh-sharded entry points, so the engine itself
is one construction-time dispatch instead of a per-call ``if kind == ...``
ladder repeated across code paths.

Sharded deployment is a construction-time switch:

    mesh = jax.make_mesh((n_shards,), ("model",))
    eng = engine.SearchEngine.build(index, k=5000, n_probe=64, mesh=mesh)

The corpus stream is partitioned row-wise over the mesh's ``model`` axis
(``ivf.sharded_layout``: round-robin within each cluster) and the per-shard
stream tensors are placed with a sharded ``NamedSharding`` at build time, so
each chip holds and scans only its rows; queries run the distributed BBC
collector (histogram ``psum`` + survivor-only ``all_gather``; see
``core.distributed`` and the sharded searchers in ``index.search``).

Predictive serving (the cross-batch tau_pred subsystem) is a call-time
switch: thread a ``rerank.PredictorState`` through the search calls and the
engine self-tunes its re-rank threshold from the bucket histograms of
previous batches —

    state = eng.predictor_init()
    res, state = eng.search(qs, pred_state=state)   # every entry point

works identically on the single, batched, and sharded deployments (the
sharded paths feed the psum'd global histogram into the same state).

The layout (and the one-time host-side packing it needs) is computed once at
engine construction, so steady-state serving is one jit-compiled call per
batch shape.  The engine is deliberately thin: all numerics live in
``search.py`` so the batched functions stay directly testable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import rerank
from repro.index import ivf as ivf_mod
from repro.index import search as search_mod


# --------------------------------------------------------------------------
# Per-method strategies
# --------------------------------------------------------------------------

class _IvfStrategy:
    """IVF (no quantization): exact distances in-scan."""

    kind = "ivf"

    def default_n_cand(self, index, k: int) -> int | None:
        return None

    def default_pred_count(self, k: int, n_cand: int | None) -> int:
        # distances are exact in-scan: the pool target is k itself
        return k

    def search_one(self, eng: "SearchEngine", q: jax.Array):
        return search_mod.ivf_search(
            eng.index, eng.vectors, q, k=eng.k, n_probe=eng.n_probe,
            use_bbc=eng.use_bbc, m=eng.m)

    def search_batch(self, eng: "SearchEngine", qs: jax.Array,
                     pred_state=None):
        return search_mod.ivf_search_batch(
            eng.index, eng.vectors, qs, eng.layout, k=eng.k,
            n_probe=eng.n_probe, use_bbc=eng.use_bbc, m=eng.m,
            backend=eng.backend, pred_state=pred_state,
            pred_count=eng.pred_count, live=eng.live)

    def shard_streams(self, index, vectors, order: np.ndarray) -> tuple:
        return (np.asarray(vectors)[order],)

    def stream_specs(self, axes) -> tuple:
        return (P(axes, None, None),)

    def search_sharded(self, eng: "SearchEngine", qs: jax.Array,
                       pred_state=None):
        (svecs,) = eng.shard_streams
        return search_mod.ivf_search_sharded(
            eng.mesh, qs, eng.index.centroids, eng.slayout, svecs, k=eng.k,
            n_probe=eng.n_probe, use_bbc=eng.use_bbc, m=eng.m,
            cap_shard=eng.cap_shard, budget=eng.shard_budget,
            backend=eng.backend, pred_state=pred_state,
            pred_count=eng.pred_count, slive=eng.live)


class _IvfPqStrategy:
    """IVF+PQ: ADC estimate -> n_cand selection -> exact re-rank."""

    kind = "ivfpq"

    def default_n_cand(self, index, k: int) -> int | None:
        return min(8 * k, int(index.vectors.shape[0]))

    def default_pred_count(self, k: int, n_cand: int | None) -> int:
        return search_mod._resolve_pred_count(None, k, n_cand)

    def search_one(self, eng: "SearchEngine", q: jax.Array):
        return search_mod.ivf_pq_search(
            eng.index, q, k=eng.k, n_probe=eng.n_probe, n_cand=eng.n_cand,
            use_bbc=eng.use_bbc, m=eng.m)

    def search_batch(self, eng: "SearchEngine", qs: jax.Array,
                     pred_state=None):
        return search_mod.ivf_pq_search_batch(
            eng.index, qs, eng.layout, k=eng.k, n_probe=eng.n_probe,
            n_cand=eng.n_cand, use_bbc=eng.use_bbc, m=eng.m,
            backend=eng.backend, fused=eng.fused, pred_state=pred_state,
            pred_count=eng.pred_count, live=eng.live)

    def shard_streams(self, index, vectors, order: np.ndarray) -> tuple:
        return (np.asarray(index.codes)[order],
                np.asarray(index.vectors)[order])

    def stream_specs(self, axes) -> tuple:
        return (P(axes, None, None), P(axes, None, None))

    def search_sharded(self, eng: "SearchEngine", qs: jax.Array,
                       pred_state=None):
        scodes, svecs = eng.shard_streams
        return search_mod.ivf_pq_search_sharded(
            eng.mesh, qs, eng.index.pq, eng.index.ivf.centroids, eng.slayout,
            scodes, svecs, k=eng.k, n_probe=eng.n_probe, n_cand=eng.n_cand,
            use_bbc=eng.use_bbc, m=eng.m, cap_shard=eng.cap_shard,
            budget=eng.shard_budget, backend=eng.backend,
            pred_state=pred_state, pred_count=eng.pred_count,
            slive=eng.live)


class _IvfRabitqStrategy:
    """IVF+RaBitQ: bounded estimates -> greedy bounded re-rank."""

    kind = "ivfrabitq"

    def default_n_cand(self, index, k: int) -> int | None:
        return None

    def default_pred_count(self, k: int, n_cand: int | None) -> int:
        # the band is anchored at the k-th upper bound
        return k

    def search_one(self, eng: "SearchEngine", q: jax.Array):
        return search_mod.ivf_rabitq_search(
            eng.index, q, k=eng.k, n_probe=eng.n_probe, use_bbc=eng.use_bbc,
            m=eng.m)

    def search_batch(self, eng: "SearchEngine", qs: jax.Array,
                     pred_state=None):
        return search_mod.ivf_rabitq_search_batch(
            eng.index, qs, eng.layout, k=eng.k, n_probe=eng.n_probe,
            use_bbc=eng.use_bbc, m=eng.m, backend=eng.backend,
            fused=eng.fused, stream=eng.stream_cache,
            pred_state=pred_state, pred_count=eng.pred_count,
            live=eng.live)

    def shard_streams(self, index, vectors, order: np.ndarray) -> tuple:
        rq = index.rq
        return (np.asarray(rq.codes)[order], np.asarray(rq.norm_o)[order],
                np.asarray(rq.f_o)[order], np.asarray(index.vectors)[order])

    def stream_specs(self, axes) -> tuple:
        return (P(axes, None, None), P(axes, None),
                P(axes, None), P(axes, None, None))

    def search_sharded(self, eng: "SearchEngine", qs: jax.Array,
                       pred_state=None):
        scodes, snorm_o, sf_o, svecs = eng.shard_streams
        return search_mod.ivf_rabitq_search_sharded(
            eng.mesh, qs, eng.index.rq.rot, eng.index.ivf.centroids,
            eng.slayout, scodes, snorm_o, sf_o, svecs, k=eng.k,
            n_probe=eng.n_probe, use_bbc=eng.use_bbc, m=eng.m,
            cap_shard=eng.cap_shard, budget=eng.shard_budget,
            backend=eng.backend, fused=eng.fused, pred_state=pred_state,
            pred_count=eng.pred_count, slive=eng.live)


_STRATEGIES = {s.kind: s for s in
               (_IvfStrategy(), _IvfPqStrategy(), _IvfRabitqStrategy())}


def _resolve_strategy(index, vectors):
    if isinstance(index, search_mod.PQIndex):
        return _STRATEGIES["ivfpq"], index.ivf
    if isinstance(index, search_mod.RabitqIndex):
        return _STRATEGIES["ivfrabitq"], index.ivf
    if isinstance(index, ivf_mod.IVFIndex):
        if vectors is None:
            raise ValueError("kind 'ivf' needs the corpus vectors")
        return _STRATEGIES["ivf"], index
    raise TypeError(f"unsupported index type: {type(index)!r}")


def resolve_kind(index, vectors=None) -> str:
    """Method kind ("ivf" | "ivfpq" | "ivfrabitq") for an index object —
    the dispatch `build` uses, exposed for layers above the engine (the
    serving subsystem keys predictor state by it)."""
    return _resolve_strategy(index, vectors)[0].kind


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class SearchEngine:
    """Serving facade: index + layout + static knobs; dispatches single,
    batched, and mesh-sharded searches."""
    index: Any                       # IVFIndex | PQIndex | RabitqIndex
    layout: ivf_mod.FlatLayout | None   # single-device stream (None if sharded)
    kind: str                        # "ivf" | "ivfpq" | "ivfrabitq"
    k: int
    n_probe: int
    n_cand: int | None = None
    use_bbc: bool = True
    m: int = 128
    backend: str | None = None
    vectors: jax.Array | None = None  # required for kind == "ivf"
    pred_count: int | None = None     # predictive re-rank pool target
    # fused-scan switch for the quantized methods (None = per-searcher
    # default: bound-fused RaBitQ everywhere, fused PQ on TPU); False pins
    # the two-phase reference paths, e.g. for A/B benchmarking
    fused: bool | None = None
    # layout-ordered candidate stream materialized at build time (RaBitQ
    # single-device; saves the per-call 30+ MB stream gathers)
    stream_cache: Any = None
    # provenance of the knob values: the tuned OperatingPoint name that
    # filled caller-unset knobs at build time, or None for hand defaults
    # ("hand-tuned fallback" in serving summaries)
    tuned_from: str | None = None
    # -- streaming-ingest state --------------------------------------------
    # stream-ordered tombstone mask: (n_flat,) bool single-device, (S, F)
    # placed on the mesh when sharded; None = every lane live (the frozen
    # default, which keeps all pre-existing jit traces unchanged).  Build
    # from a corpus-row mask with ``with_live``.
    live: Any = None
    # monotone index-rebuild counter: bumped by each background merge; the
    # serving tier keys copy-on-swap engine caches by it
    generation: int = 0
    # -- sharded deployment state (all None/unused on a single device) ------
    mesh: Any = None
    slayout: ivf_mod.ShardedLayout | None = None
    cap_shard: int = 1
    shard_budget: int | None = None
    shard_streams: tuple = field(default=())

    @property
    def strategy(self):
        return _STRATEGIES[self.kind]

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @staticmethod
    def build(index, k: int, n_probe: int | None = None,
              n_cand: int | None = None,
              use_bbc: bool = True, m: int = 128,
              backend: str | None = None, vectors=None,
              mesh=None, shard_budget: int | None = None,
              pred_count: int | None = None,
              fused: bool | None = None, tuned=None,
              recall_target: float = 0.95,
              generation: int = 0) -> "SearchEngine":
        """Construct a serving engine; ``mesh`` switches on the sharded
        deployment — same code path, the corpus stream is partitioned and
        placed at build time.  A 1-D ("model",) mesh shards flat; a 2-D
        ("host", "model") mesh shards over both axes and the searchers run
        the hierarchical collective schedule (intra-host reduce, then the
        inter-host round — see ``core.distributed.hier_psum``).
        ``pred_count`` overrides the predictive re-rank pool target used
        when searches are called with a ``PredictorState``; ``fused``
        pins the quantized methods' fused-scan switch (None = per-searcher
        default).

        ``tuned`` resolves knobs the caller left unset from the
        constrained-tuner's persisted operating points instead of the hand
        defaults: a ``tuning.points.PointStore`` (nearest (method, k,
        recall_target) cell is resolved) or a single
        ``tuning.points.OperatingPoint``.  Explicit arguments always win
        over the tuned point; ``tuned_from`` on the built engine records
        which point (and resolution provenance) filled the gaps.  Without
        ``tuned``, ``n_probe`` is required."""
        strategy, ivf = _resolve_strategy(index, vectors)
        tuned_from = None
        if tuned is not None:
            from repro.tuning import points as tuning_points
            if isinstance(tuned, tuning_points.OperatingPoint):
                point, provenance = tuned, "tuned"
            else:
                point, provenance = tuned.resolve(
                    strategy.kind, k, target=recall_target)
            if point is not None:
                cfg = point.knobs
                n_probe = cfg.n_probe if n_probe is None else n_probe
                if n_cand is None and cfg.n_cand is not None:
                    # re-clamp pools tuned at a different k-bucket onto
                    # THIS k (pool-subset contract: k <= pred <= n_cand)
                    n_cand = max(cfg.n_cand, k)
                if pred_count is None and cfg.pred_count is not None:
                    pred_count = max(cfg.pred_count, k)
                    if n_cand is not None:
                        pred_count = min(pred_count, n_cand)
                fused = cfg.fused if fused is None else fused
                tuned_from = f"{point.name} ({provenance})"
        if n_probe is None:
            raise ValueError(
                "n_probe is required when no tuned operating point "
                "covers this (method, k) cell")
        if n_cand is None:
            n_cand = strategy.default_n_cand(index, k)
        if pred_count is None:
            pred_count = strategy.default_pred_count(k, n_cand)
        # resolved knobs are priors, not feasibility guarantees on THIS
        # index: a point tuned on a larger corpus can name a probe width or
        # candidate pool wider than the stream (top_k rejects the width)
        n_probe = min(n_probe, ivf.n_clusters)
        n_rows = int(np.asarray(ivf.cluster_sizes).sum())
        if n_cand is not None:
            n_cand = min(n_cand, n_rows)
            pred_count = min(pred_count, n_cand)
        layout, slayout, cap_shard, streams = None, None, 1, ()
        stream_cache = None
        if mesh is None:
            layout = ivf_mod.flat_layout(ivf)
            if strategy.kind == "ivfrabitq":
                stream_cache = search_mod.rabitq_stream(index, layout)
        else:
            axes = search_mod._shard_axes(mesh)
            n_shards = search_mod._n_shards(mesh)
            slayout, cap_shard = ivf_mod.sharded_layout(ivf, n_shards)
            order = np.asarray(slayout.order)          # (S, F) global ids
            raw = strategy.shard_streams(index, vectors, order)
            streams = tuple(
                jax.device_put(s, NamedSharding(mesh, spec))
                for s, spec in zip(raw, strategy.stream_specs(axes)))
            slayout = jax.device_put(
                slayout, NamedSharding(mesh, P(axes, None)))
        return SearchEngine(index=index, layout=layout, kind=strategy.kind,
                            k=k, n_probe=n_probe, n_cand=n_cand,
                            use_bbc=use_bbc, m=m, backend=backend,
                            vectors=vectors, pred_count=pred_count,
                            fused=fused, stream_cache=stream_cache,
                            tuned_from=tuned_from, generation=generation,
                            mesh=mesh, slayout=slayout, cap_shard=cap_shard,
                            shard_budget=shard_budget, shard_streams=streams)

    # -- query-time ---------------------------------------------------------
    #
    # The engine itself stays immutable; predictive serving threads the
    # ``rerank.PredictorState`` functionally: start from
    # ``eng.predictor_init()`` and feed each call's returned state into the
    # next — ``res, state = eng.search(qs, pred_state=state)`` — so the
    # engine self-tunes across batches without hidden mutability (the
    # serving loop in ``launch/serve.py`` is the reference consumer).

    def predictor_init(self) -> rerank.PredictorState:
        """Cold cross-batch threshold-predictor state for this engine."""
        return rerank.predictor_init(self.m)

    def with_live(self, corpus_live) -> "SearchEngine":
        """Engine with a tombstone mask: ``corpus_live[i]`` False deletes
        corpus row ``i`` from every search without touching the layout or
        the quantized streams — the mask is permuted into stream order
        (and placed on the mesh when sharded) and ANDed into the per-query
        probe masks at scan time.  All-True (or ``None``) restores the
        frozen behavior.  O(n) host work; the engine stays immutable
        (returns a new instance sharing every build-time artifact)."""
        if corpus_live is None:
            return dataclasses.replace(self, live=None)
        corpus_live = np.asarray(corpus_live, dtype=bool)
        if self.sharded:
            axes = search_mod._shard_axes(self.mesh)
            order = np.asarray(jax.device_get(self.slayout.order))
            # padding lanes carry order id 0: whatever they pick up here is
            # re-masked by layout.valid inside probe_mask
            slive = corpus_live[np.clip(order, 0, corpus_live.shape[0] - 1)]
            live = jax.device_put(
                slive, NamedSharding(self.mesh, P(axes, None)))
        else:
            order = np.asarray(self.layout.order)
            live = jnp.asarray(
                corpus_live[np.clip(order, 0, corpus_live.shape[0] - 1)])
        return dataclasses.replace(self, live=live)

    def replica_clone(self) -> "SearchEngine":
        """Replica-build hook for the multi-replica serving tier: a fresh
        engine INSTANCE sharing every build-time artifact by reference —
        the flat layout, the RaBitQ ``stream_cache``, the placed shard
        streams.  This is what a respawned replica process gets from a
        shared artifact store instead of re-running the host-side packing;
        the engine is immutable, so sharing is safe and the clone costs
        nothing.  (``ServingState.fork(clone_engines=True)`` is the
        consumer.)"""
        return dataclasses.replace(self)

    @property
    def dim(self) -> int:
        """Corpus dimensionality (the query width every entry point takes)."""
        src = self.vectors if self.kind == "ivf" else self.index.vectors
        return int(src.shape[1])

    def warmup(self, batch_sizes=(1,),
               predictive: bool = False) -> "SearchEngine":
        """AOT warmup: run (and block on) a dummy search through every jit
        shape serving will hit, so steady-state traffic never pays a
        compile.  ``batch_sizes`` are the padded batch widths to compile
        (B == 1 also compiles the dedicated single-query path on the
        single-device deployment; the sharded deployment is natively
        batched, so its collective program is compiled by the same
        ``search_batch`` calls).  ``predictive`` additionally compiles the
        tau_pred variants against a throwaway cold state — the EMA the
        serving loop owns is never touched."""
        qs = jnp.zeros((max(batch_sizes), self.dim), jnp.float32)
        state = self.predictor_init() if predictive else None
        for b in sorted(set(int(b) for b in batch_sizes)):
            if b < 1:
                raise ValueError(f"batch sizes must be >= 1, got {b}")
            jax.block_until_ready(self.search_batch(qs[:b]))
            if b == 1 and not self.sharded:
                jax.block_until_ready(self.search_one(qs[0]))
            if state is not None:
                res, _ = self.search_batch(qs[:b], pred_state=state)
                jax.block_until_ready(res)
        return self

    def search(self, qs: jax.Array, pred_state=None):
        """(B, d) batch or (d,) single query -> SearchResult (or
        ``(SearchResult, new_state)`` when ``pred_state`` is given)."""
        if qs.ndim == 1:
            return self.search_one(qs, pred_state=pred_state)
        return self.search_batch(qs, pred_state=pred_state)

    def search_batch(self, qs: jax.Array, pred_state=None):
        if self.sharded:
            return self.strategy.search_sharded(self, qs,
                                                pred_state=pred_state)
        return self.strategy.search_batch(self, qs, pred_state=pred_state)

    def search_one(self, q: jax.Array, pred_state=None):
        if pred_state is not None:
            # predictive search is natively batched; serve a singleton batch
            res, state = self.search_batch(q[None], pred_state=pred_state)
            return search_mod.SearchResult(*(x[0] for x in res)), state
        if self.sharded or self.live is not None:
            # the sharded path is natively batched, and tombstone masks
            # live on the batched searchers only; serve a singleton batch
            res = self.search_batch(q[None])
            return search_mod.SearchResult(*(x[0] for x in res))
        return self.strategy.search_one(self, q)
