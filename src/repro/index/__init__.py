"""ANN index substrate: IVF coarse index + PQ / RaBitQ quantizers + searchers."""
from repro.index import flat, ivf, kmeans, pq, rabitq, search  # noqa: F401
