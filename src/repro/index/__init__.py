"""ANN index substrate: IVF coarse index + PQ / RaBitQ quantizers + searchers
(single-query and natively batched) + the batched serving engine."""
from repro.index import engine, flat, ivf, kmeans, pq, rabitq, search  # noqa: F401
