"""End-to-end ANN searchers: IVF / IVF+PQ / IVF+RaBitQ, each ± BBC.

Two families of entry points:

  * Single-query functions (``ivf_search`` & co.), jit-compiled with static
    hyper-parameters.  Intermediates are O(n_probe * cap) over the padded
    member table.
  * Natively batched ``*_batch`` functions: one routing matmul for the whole
    query batch, ONE shared candidate-stream gather (the compact
    ``ivf.FlatLayout``, zero per-cluster padding), per-query probe masks, and
    batched estimate / bucketize / histogram / re-rank matmuls that run
    through the Pallas kernels on TPU (``kernels.ops.*_batch``) and their
    jnp mirrors on CPU.  Use these instead of ``jax.vmap`` over the single
    query functions — vmap replicates the padded gathers per query.

All paths return ``SearchResult`` with instrumentation counters used by the
benchmark suite (re-rank counts, second-pass gathers — the TPU analogues of
the paper's VTune/perf numbers); batched paths return per-query (B,) counters.

The batched and sharded searchers additionally support the predictive
early-exact subsystem: pass ``pred_state`` (a ``rerank.PredictorState``, the
engine-owned EMA of previous batches' bucket histograms) and the call returns
``(SearchResult, new_state)`` with the re-rank pool sized by the predicted
threshold bucket instead of the static knobs (see the predictive section
below and ``core.rerank.predict_tau``).

Method map (paper Table / Fig. 1):
  ivf_search(use_bbc=False)          -> IVF
  ivf_pq_search(use_bbc=False)       -> IVF+PQ          (unbounded, n_cand)
  ivf_pq_search(use_bbc=True)        -> IVF+PQ+BBC      (Alg. 4 early rerank)
  ivf_rabitq_search(use_bbc=False)   -> IVF+RaBitQ      (threshold rerank)
  ivf_rabitq_search(use_bbc=True)    -> IVF+RaBitQ+BBC  (Alg. 3 greedy)
  flat.search                        -> BFC
(IVF+RaBitQ+MIN lives in benchmarks — host-side heap baseline, Alg. 2.)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core import buffer as rb
from repro.core import collector as col
from repro.core import distributed as dist
from repro.core import rerank
from repro.index import ivf as ivf_mod
from repro.index import pq as pq_mod
from repro.index import rabitq as rq_mod
from repro.kernels import ops

INF = jnp.inf


class PQIndex(NamedTuple):
    ivf: ivf_mod.IVFIndex
    pq: pq_mod.PQCodebook
    codes: jax.Array    # (N, M) uint8
    vectors: jax.Array  # (N, d) fp32 (re-rank source)


class RabitqIndex(NamedTuple):
    ivf: ivf_mod.IVFIndex
    rq: rq_mod.RabitqCodes
    vectors: jax.Array


class SearchResult(NamedTuple):
    dists: jax.Array
    ids: jax.Array
    n_reranked: jax.Array       # exact distance computations spent
    n_second_pass: jax.Array    # re-rank gathers NOT covered inline (Alg. 4)


# --------------------------------------------------------------------------
# Index builders (offline)
# --------------------------------------------------------------------------

def build_pq_index(key, x, n_clusters: int, n_sub: int | None = None,
                   n_bits: int = 4, n_iter: int = 10) -> PQIndex:
    d = x.shape[1]
    n_sub = n_sub or d // 4          # paper: M = d/4, B = 4
    k1, k2 = jax.random.split(key)
    index = ivf_mod.build(k1, x, n_clusters, n_iter)
    cb = pq_mod.train(k2, x, n_sub, n_bits, n_iter)
    codes = pq_mod.encode(cb, x)
    return PQIndex(ivf=index, pq=cb, codes=codes, vectors=x)


def build_rabitq_index(key, x, n_clusters: int, n_iter: int = 10) -> RabitqIndex:
    k1, k2 = jax.random.split(key)
    index = ivf_mod.build(k1, x, n_clusters, n_iter)
    assignment = jnp.argmin(
        jnp.sum(x * x, 1, keepdims=True)
        - 2 * x @ index.centroids.T
        + jnp.sum(index.centroids ** 2, 1),
        axis=1,
    )
    rq = rq_mod.encode(k2, x, index.centroids, assignment)
    return RabitqIndex(ivf=index, rq=rq, vectors=x)


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------

def _exact_dists(vectors: jax.Array, ids: jax.Array, q: jax.Array) -> jax.Array:
    """Exact Euclidean distances for a gathered id set (ids may contain -1
    padding; callers mask)."""
    v = vectors[jnp.maximum(ids, 0)]
    return jnp.sqrt(jnp.maximum(
        jnp.sum(v * v, -1) - 2.0 * (v @ q) + jnp.sum(q * q), 0.0))


def _stream_from(est, ids, valid) -> col.StreamInput:
    return col.StreamInput(dists=est, ids=ids, valid=valid)


def _rerank_budget(k: int, cap: int) -> int:
    b = max(8 * k, 2048)
    return ((b + 127) // 128) * 128


# --------------------------------------------------------------------------
# Predictive early-exact re-rank (cross-batch tau_pred subsystem)
# --------------------------------------------------------------------------
#
# The static BBC paths size the exact-re-rank pool with a blunt static knob
# (n_cand for PQ; the full uncertain band for RaBitQ).  In predictive mode a
# searcher additionally takes the engine-owned ``rerank.PredictorState`` (the
# EMA of previous batches' bucket histograms) and returns
# ``(SearchResult, new_state)``:
#
#   * tau_pred = predict_tau(state, pred_count) is the bucket the cumulative
#     histogram is EXPECTED to reach pred_count at.  The scan early-exacts
#     lanes at or below it inline (fused kernel on TPU).
#   * tau_true from THIS batch's histogram guards correctness: survivors are
#     bucket <= max(tau_pred, tau_true), and survivors the prediction missed
#     (bucket in (tau_pred, tau_true]) get a fallback second-pass re-rank —
#     exactly the static path's gather, just (usually) empty.
#   * the new state folds this batch's histogram into the EMA.
#
# For PQ the pool shrinks from n_cand to ~pred_count (fewer re-ranks); for
# IVF/RaBitQ distances/bounds already bound the pool, so prediction moves
# work inline (fewer second-pass gathers) without changing the pool.


def _resolve_pred_count(pred_count: int | None, k: int,
                        n_cand: int | None = None) -> int:
    """Default predictive re-rank pool target (~2.5k): deep enough that the
    exact top-k inside it matches the static n_cand cut on realistic
    estimate error, ~3x shallower than the n_cand=8k default.  This is the
    single source of the default — the engine and bench_tau_pred both
    resolve through it (BENCH_tau_pred.json is measured at this value)."""
    if pred_count is None:
        pred_count = max(5 * k // 2, k + 1024)
    pred_count = max(pred_count, k)
    if n_cand is not None:
        pred_count = min(pred_count, n_cand)
    return pred_count


def _pred_budget(count: int, n: int) -> int:
    """Static selection width over the survivor pool: the threshold bucket
    overshoots ``count`` by at most its own occupancy; slack covers skew."""
    b = count + max(count // 2, 256)
    return int(min(n, ((b + 127) // 128) * 128))


def _sample_codebooks(layout: ivf_mod.FlatLayout, probed: jax.Array,
                      vals: jax.Array, st: int, cap: int, k_cb: int, m: int):
    """Per-query codebooks from the nearest ``st`` probed cluster tiles of a
    (B, n_flat) value matrix (the batched analogue of the paper's 5-10
    nearest-cluster sample)."""
    spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], cap)
    sample = jnp.where(sok, jnp.take_along_axis(vals, spos, axis=1), INF)
    k_cb = min(k_cb, sample.shape[1])
    return jax.vmap(lambda s: rb.build_codebook(s, k=k_cb, m=m))(sample)


def _pq_sample_est(layout: ivf_mod.FlatLayout, probed: jax.Array,
                   stream_codes: jax.Array, luts: jax.Array, st: int,
                   cap: int) -> jax.Array:
    """Per-query ADC estimates over the nearest ``st`` probed cluster tiles
    (the codebook sample of the batched PQ paths — static fused and
    predictive MUST sample identically so bucket indices stay comparable
    across batches for the EMA)."""
    spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], cap)

    def one(a):
        pos, ok, lut = a
        e = pq_mod.estimate(lut, stream_codes[pos])
        return jnp.where(ok, jnp.sqrt(jnp.maximum(e, 0.0)), INF)

    return jax.lax.map(one, (spos, sok, luts))


def _predictive_select(est: jax.Array, bucket: jax.Array, hist: jax.Array,
                       lane_valid: jax.Array, tau_pred: jax.Array,
                       count: int, budget: int):
    """Survivor selection under the predicted threshold.

    Survivors are lanes with bucket <= max(tau_pred, tau_true-at-count);
    they are picked est-priority into the static ``budget`` (ascending), so
    the first k columns are the exact top-k of the pool.  Returns
    (sel_est ascending (B, budget), sel_pos, sel_ok, tau_true).
    """
    tau_true, _ = jax.vmap(rb.threshold_bucket, in_axes=(0, None))(hist, count)
    tau_used = jnp.maximum(tau_pred, tau_true)
    masked = jnp.where(lane_valid & (bucket <= tau_used[:, None]), est, INF)
    neg, sel_pos = jax.lax.top_k(-masked, budget)
    return -neg, sel_pos, jnp.isfinite(-neg), tau_true


# --------------------------------------------------------------------------
# IVF (no quantization): exact distances in-scan + collector
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "n_probe", "use_bbc", "m"))
def ivf_search(index: ivf_mod.IVFIndex, vectors: jax.Array, q: jax.Array,
               k: int, n_probe: int, use_bbc: bool = False,
               m: int = 128) -> SearchResult:
    probed = ivf_mod.route(index, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(index, probed)    # (n_probe, cap)
    dists = jax.vmap(lambda i: _exact_dists(vectors, i, q))(ids)
    dists = jnp.where(valid, dists, INF)
    s = _stream_from(dists, ids, valid)
    if use_bbc:
        d, i = col.bbc_collect(s, k, m=m)
    else:
        d, i = col.topk_collect(s, k)
    n = jnp.sum(valid)
    return SearchResult(d, i, n, jnp.int32(0))


# --------------------------------------------------------------------------
# IVF + PQ (unbounded): ADC estimate -> n_cand selection -> re-rank
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "n_cand", "use_bbc", "m", "early_slack"),
)
def ivf_pq_search(
    index: PQIndex,
    q: jax.Array,
    k: int,
    n_probe: int,
    n_cand: int,
    use_bbc: bool = False,
    m: int = 128,
    early_slack: float = 4.0,
) -> SearchResult:
    """IVF+PQ (baseline) and IVF+PQ+BBC (Alg. 4 early re-rank).

    Baseline: running top-n_cand by estimate across cluster tiles ("Heap"
    collector), then one gather+exact pass over the n_cand selection.

    +BBC: bucket collector for the n_cand selection, plus early re-ranking —
    per cluster tile, objects whose estimate bucketizes at or below tau_pred
    have exact distances computed inline while the cluster's vectors are
    resident (TPU: same VMEM tile; see kernels/fused_scan.py).  The second
    gather pass only covers the few selected-but-not-predicted stragglers
    (``n_second_pass`` — the cache-miss analogue the paper counts in Table 2).
    """
    ivf = index.ivf
    probed = ivf_mod.route(ivf, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(ivf, probed)       # (n_probe, cap)
    cap = ids.shape[1]
    lut = pq_mod.adc_table(index.pq, q)

    codes = index.codes[jnp.maximum(ids, 0)]                  # (n_probe, cap, M)
    est = jax.vmap(lambda c: pq_mod.estimate(lut, c))(codes)  # squared dists
    est = jnp.sqrt(jnp.maximum(jnp.where(valid, est, INF), 0.0))

    flat_est = est.reshape(-1)
    flat_ids = ids.reshape(-1)
    flat_valid = valid.reshape(-1)

    if not use_bbc:
        # ---- baseline: heap-analogue selection, full second-pass re-rank --
        s = _stream_from(est, ids, valid)
        cd, ci = col.topk_collect(s, n_cand)
        ex = _exact_dists(index.vectors, ci, q)
        ex = jnp.where(ci >= 0, ex, INF)
        neg, order = jax.lax.top_k(-ex, k)
        return SearchResult(-neg, ci[order], jnp.int32(n_cand),
                            jnp.int32(n_cand))

    # ---- BBC path (Alg. 4) ------------------------------------------------
    n_sample_tiles = min(4, n_probe)
    sample = jnp.where(valid[:n_sample_tiles],
                       est[:n_sample_tiles], INF).reshape(-1)
    n_total = flat_valid.shape[0]
    # The TPU formulation materializes the whole estimate pass before the
    # early re-rank (tile-parallel, not streamed), so the sample prefix
    # seeds the CODEBOOK only while tau_pred comes from the full scan at
    # Alg. 4 line-14 granularity — the nearest-cluster prefix is
    # distance-skewed and its rank heuristic (early_rerank_plan, used by
    # the streaming fused-kernel path) lands systematically low on
    # concentrated corpora.  The refresh is the O(m) histogram threshold
    # (bucketize is monotone, so the first bucket whose cumulative count
    # reaches n_cand IS the bucket of the n_cand-th estimate — no O(n_cand)
    # selection), and the histogram is reused by the collection.
    cb = rb.build_codebook(sample, k=min(n_cand, sample.shape[0]), m=m)
    bucket_ids = rb.bucketize(cb, flat_est)
    hist = rb.histogram(bucket_ids, m, flat_valid)
    tau_scan, _ = rb.threshold_bucket(hist, n_cand)
    plan = rerank.EarlyRerankPlan(tau_pred=tau_scan, cb=cb)

    # Early re-rank: per-cluster inline exact for predicted survivors.
    early_budget = int(min(cap, max(128, round(n_cand / n_probe * early_slack))))
    early_budget = ((early_budget + 127) // 128) * 128
    early_budget = min(early_budget, cap)

    positions = jnp.arange(n_total, dtype=jnp.int32)
    flat_pos_matrix = positions.reshape(n_probe, cap)

    def per_cluster(c_est, c_ids, c_valid, row_pos):
        """Inline exact distances for predicted survivors of one cluster tile
        (Alg. 4 lines 9-11: the vectors are 'hot' — on TPU, the fused kernel
        streams them in the same VMEM tile as the codes)."""
        pred = rerank.early_rerank_mask(plan, c_est) & c_valid
        pos, ok = rb.compact_mask(pred, early_budget)
        safe = jnp.minimum(pos, cap - 1)
        e_ids = jnp.where(ok, c_ids[safe], -1)
        e_d = jnp.where(ok, _exact_dists(index.vectors, e_ids, q), INF)
        tgt = jnp.where(ok, row_pos[safe], n_total)  # flat scatter targets
        return e_d, tgt, jnp.sum(ok)

    e_d, e_tgt, e_counts = jax.vmap(per_cluster)(est, ids, valid, flat_pos_matrix)
    n_early = jnp.sum(e_counts)
    flat_e_d = jnp.full((n_total + 1,), INF, est.dtype)
    flat_e_d = flat_e_d.at[e_tgt.reshape(-1)].set(e_d.reshape(-1), mode="drop")
    flat_e_d = flat_e_d[:n_total]

    # n_cand selection by estimate with the bucket collector (Alg. 1 Collect).
    _, sel_pos = rb.collect(
        plan.cb, flat_est, positions, bucket_ids, n_cand, flat_valid,
        hist=hist)
    sel_ids = flat_ids[jnp.maximum(sel_pos, 0)]
    sel_ids = jnp.where(sel_pos >= 0, sel_ids, -1)

    # Inline results cover most of the selection; one small second pass for
    # the stragglers (n_second_pass ~ the paper's Table-2 cache-miss story).
    have = jnp.isfinite(flat_e_d[jnp.maximum(sel_pos, 0)]) & (sel_pos >= 0)
    miss = ~have & (sel_ids >= 0)
    second = jnp.sum(miss)
    miss_d = _exact_dists(index.vectors, jnp.where(miss, sel_ids, 0), q)
    ex = jnp.where(have, flat_e_d[jnp.maximum(sel_pos, 0)],
                   jnp.where(miss, miss_d, INF))

    neg, order = jax.lax.top_k(-ex, k)
    return SearchResult(-neg, sel_ids[order],
                        (n_early + second).astype(jnp.int32),
                        second.astype(jnp.int32))


# --------------------------------------------------------------------------
# IVF + RaBitQ (bounded): estimate+bounds -> rerank
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "use_bbc", "m", "eps0"),
)
def ivf_rabitq_search(
    index: RabitqIndex,
    q: jax.Array,
    k: int,
    n_probe: int,
    use_bbc: bool = False,
    m: int = 128,
    eps0: float = 3.0,
) -> SearchResult:
    """IVF+RaBitQ baseline (per-cluster threshold re-rank) and +BBC (Alg. 3
    closed-form greedy on two result buffers)."""
    ivf = index.ivf
    probed = ivf_mod.route(ivf, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(ivf, probed)
    n_probe_, cap = ids.shape
    rq = index.rq

    def est_cluster(cid, c_ids, c_valid):
        qf = rq_mod.query_factors(rq, q, ivf.centroids[cid])
        c = rq.codes[jnp.maximum(c_ids, 0)]
        no = rq.norm_o[jnp.maximum(c_ids, 0)]
        fo = rq.f_o[jnp.maximum(c_ids, 0)]
        est, lb, ub = rq_mod.estimate(c, no, fo, qf, eps0)
        bad = ~c_valid
        return (jnp.where(bad, INF, est), jnp.where(bad, INF, lb),
                jnp.where(bad, INF, ub))

    est, lb, ub = jax.vmap(est_cluster)(probed, ids, valid)

    if not use_bbc:
        # ---- baseline: per-cluster threshold re-ranking -------------------
        budget = min(cap, _rerank_budget(k, cap))

        def step(carry, xs):
            pool_d, pool_i, n_rr = carry
            c_lb, c_ids, c_valid = xs
            thresh = pool_d[k - 1]
            mask = c_valid & (c_lb < thresh)
            pos, ok = rb.compact_mask(mask, budget)
            safe = jnp.minimum(pos, cap - 1)
            r_ids = jnp.where(ok, c_ids[safe], -1)
            r_d = _exact_dists(index.vectors, r_ids, q)
            r_d = jnp.where(ok, r_d, INF)
            alld = jnp.concatenate([pool_d, r_d])
            alli = jnp.concatenate([pool_i, r_ids])
            neg, idx = jax.lax.top_k(-alld, k)
            return (-neg, alli[idx], n_rr + jnp.sum(ok)), None

        pool0 = (jnp.full((k,), INF, est.dtype), jnp.full((k,), -1, jnp.int32),
                 jnp.int32(0))
        (pd, pi, n_rr), _ = jax.lax.scan(step, pool0, (lb, ids, valid))
        order = jnp.argsort(pd)
        return SearchResult(pd[order], pi[order], n_rr, n_rr)

    # ---- BBC path (Alg. 3, two-phase greedy) -------------------------------
    flat_lb, flat_ub = lb.reshape(-1), ub.reshape(-1)
    flat_est = est.reshape(-1)
    flat_ids, flat_valid = ids.reshape(-1), valid.reshape(-1)
    n_flat = flat_ids.shape[0]
    plan = rerank.greedy_rerank_plan(flat_lb, flat_ub, k, flat_valid, m=m)

    exact_flat = jnp.full((n_flat,), INF, est.dtype)

    def eval_mask(mask, budget, exact_flat):
        """Exact distances for up to ``budget`` masked lanes (est-priority)."""
        key_est = jnp.where(mask, flat_est, INF)
        _, pos = jax.lax.top_k(-key_est, budget)
        ok = jnp.isfinite(key_est[pos])
        safe = jnp.minimum(pos, n_flat - 1)
        r_ids = jnp.where(ok, flat_ids[safe], -1)
        r_d = jnp.where(ok, _exact_dists(index.vectors, r_ids, q), INF)
        exact_flat = exact_flat.at[jnp.where(ok, safe, n_flat)].set(
            r_d, mode="drop")
        return exact_flat, r_d, jnp.sum(ok)

    # Phase 1: likely-in items (ub at/below the k-th-ub bucket).  Their exact
    # distances tighten the threshold, as in the paper's iterative loop.
    p1 = rerank.phase1_mask(plan)
    budget1 = min(n_flat, ((k + 1024 + 127) // 128) * 128)
    exact_flat, p1_d, n1 = eval_mask(p1, budget1, exact_flat)
    t2 = rerank.phase2_threshold(plan, p1_d, k)

    # Phase 2: remaining uncertain items whose lower bound is under the
    # tightened threshold (anything above is certainly out).
    p2 = plan.rerank_mask & ~p1 & jnp.isinf(exact_flat) & (flat_lb <= t2)
    budget2 = min(n_flat, _rerank_budget(k, cap))
    exact_flat, _, n2 = eval_mask(p2, budget2, exact_flat)

    res = rerank.greedy_rerank_finalize(
        plan, exact_flat, jnp.where(flat_valid, flat_lb, INF), flat_ids, k,
        est=flat_est)
    n_evals = (n1 + n2).astype(jnp.int32)
    return SearchResult(res.topk_dists, res.topk_ids, n_evals, n_evals)


# --------------------------------------------------------------------------
# Natively batched searchers (shared candidate stream + batched kernels)
# --------------------------------------------------------------------------

def _exact_dists_rows(vectors: jax.Array, ids: jax.Array,
                      qs: jax.Array) -> jax.Array:
    """Per-query exact distances for (B, w) id rows.  Sequential map keeps
    the (w, d) gather per query (the batched-gather alternative materializes
    (B, w, d)); each row uses the same formula as ``_exact_dists`` so values
    match the single-query path."""
    return jax.lax.map(lambda a: _exact_dists(vectors, a[0], a[1]), (ids, qs))


def _routing(ivf: ivf_mod.IVFIndex, layout: ivf_mod.FlatLayout,
             qs: jax.Array, n_probe: int):
    """Shared batch routing: probed clusters, per-query lane masks over the
    flat stream, and the (B, C) squared query-centroid distances (for
    estimators that need them, e.g. RaBitQ's norm_q)."""
    probed, d2 = ivf_mod.route_batch_d2(ivf, qs, n_probe)
    lane_valid = ivf_mod.probe_mask(layout, probed, ivf.n_clusters)
    return probed, lane_valid, d2


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "use_bbc", "m", "backend", "pred_count"))
def ivf_search_batch(
    index: ivf_mod.IVFIndex,
    vectors: jax.Array,
    qs: jax.Array,                 # (B, d)
    layout: ivf_mod.FlatLayout,
    k: int,
    n_probe: int,
    use_bbc: bool = False,
    m: int = 128,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
) -> SearchResult:
    """Batched IVF (exact distances in-scan): one shared vector-stream gather,
    one (B, n_flat) distance matmul, per-query bucket collection.

    With ``pred_state`` the selection runs predictively (survivors under
    max(tau_pred, tau_true) instead of a histogram-driven collect) and the
    call returns ``(SearchResult, new_state)``; distances are exact in-scan,
    so the result is identical to the static path for ANY prediction.
    """
    probed, lane_valid, _ = _routing(index, layout, qs, n_probe)
    stream_vecs = vectors[layout.order]                       # shared gather
    dists = ops.l2_exact_batch(stream_vecs, qs, backend=backend)
    dists = jnp.where(lane_valid, dists, INF)
    n = jnp.sum(lane_valid, axis=1).astype(jnp.int32)
    if pred_state is not None:
        if not use_bbc:
            raise ValueError("predictive search requires use_bbc=True")
        # distances are exact in-scan, so the pool target is k itself
        count = max(pred_count, k) if pred_count is not None else k
        st = min(4, n_probe)
        cbs = _sample_codebooks(layout, probed, dists, st, index.cap, k, m)
        bucket, hist = ops.bucket_hist_batch(
            dists, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
            backend=backend)
        tau_pred = rerank.predict_tau(pred_state, count)
        budget = _pred_budget(count, layout.n_flat)
        sel_d, sel_pos, sel_ok, _ = _predictive_select(
            dists, bucket, hist, lane_valid, tau_pred, count, budget)
        ids = jnp.where(sel_ok, layout.order[sel_pos], -1)
        res = SearchResult(sel_d[:, :k], ids[:, :k], n, jnp.zeros_like(n))
        return res, rerank.predictor_update(pred_state, hist)
    if use_bbc and ops.resolve_backend(backend) == "pallas":
        # Kernel path: O(m) histogram collection (bucket_hist kernel) + one
        # (k + slack)-wide selection.
        st = min(4, n_probe)
        spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], index.cap)
        sample = jnp.where(sok, jnp.take_along_axis(dists, spos, axis=1), INF)
        d, i = col.bbc_collect_batch(dists, layout.order, lane_valid, k, m=m,
                                     sample=sample, sample_valid=sok,
                                     backend=backend)
    else:
        # CPU fallback: XLA's flat top_k beats scatter-based compaction at
        # these widths; the selected set is identical (bucketize is monotone
        # in distance, so the bucket collection selects the exact top-k set).
        d, i = col.topk_collect_batch(dists, layout.order, lane_valid, k)
    return SearchResult(d, i, n, jnp.zeros_like(n))


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "n_cand", "use_bbc", "m", "backend",
                     "fused", "pred_count"),
)
def ivf_pq_search_batch(
    index: PQIndex,
    qs: jax.Array,                 # (B, d)
    layout: ivf_mod.FlatLayout,
    k: int,
    n_probe: int,
    n_cand: int,
    use_bbc: bool = False,
    m: int = 128,
    backend: str | None = None,
    fused: bool | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
) -> SearchResult:
    """Batched IVF+PQ (±BBC).

    The candidate stream (codes, and vectors for the fused path) is gathered
    once per batch; ADC runs for every query against the shared stream; the
    n_cand selection is the batched bucket collection.  With ``fused=True``
    (default on TPU) the whole estimate+bucketize+hist+early-exact pass is
    ``ops.fused_scan_batch`` — Alg. 4's early re-ranking happens while the
    vector tile is VMEM-resident and the second gather pass covers only the
    stragglers.  With ``fused=False`` (default on CPU, where there is no
    fusion win to collect) exact distances are computed once for the final
    selection; results are identical, only the ``n_second_pass`` accounting
    differs.

    With ``pred_state`` the blunt n_cand cut is replaced by the predictive
    early-exact pool: exact distances are spent on the ~pred_count candidates
    under max(tau_pred, tau_true) instead of all n_cand, tau_pred comes from
    the cross-batch EMA, and the call returns ``(SearchResult, new_state)``.
    """
    if fused is None:
        fused = ops.on_tpu()
    ivf = index.ivf
    b = qs.shape[0]
    probed, lane_valid, _ = _routing(ivf, layout, qs, n_probe)
    stream_codes = index.codes[layout.order]                  # shared gather
    luts = jax.vmap(lambda q: pq_mod.adc_table(index.pq, q))(qs)

    if pred_state is not None:
        if not use_bbc:
            raise ValueError("predictive search requires use_bbc=True")
        return _ivf_pq_predictive_batch(
            index, qs, layout, probed, lane_valid, stream_codes, luts, k,
            n_probe, n_cand, m, backend, fused, pred_state, pred_count)

    dense_rerank = 4 * n_cand >= layout.n_flat

    if not use_bbc:
        est2 = ops.pq_adc_batch(stream_codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        sel_est, sel_pos = jax.lax.top_k(-est, n_cand)
        ci = jnp.where(jnp.isfinite(sel_est), layout.order[sel_pos], -1)
        if dense_rerank:
            stream_vecs = index.vectors[layout.order]
            exact_all = ops.l2_exact_batch(stream_vecs, qs, backend=backend)
            ex = jnp.take_along_axis(exact_all, sel_pos, axis=1)
        else:
            ex = _exact_dists_rows(index.vectors, ci, qs)
        ex = jnp.where(ci >= 0, ex, INF)
        neg, order = jax.lax.top_k(-ex, k)
        counts = jnp.full((b,), n_cand, jnp.int32)
        return SearchResult(-neg, jnp.take_along_axis(ci, order, axis=1),
                            counts, counts)

    # ---- BBC path (Alg. 4, batched) ---------------------------------------
    n_flat = layout.n_flat
    if fused:
        # Kernel path: per-query codebooks + tau_pred from the nearest-tile
        # sample prefix, then ONE fused pass (est+bucketize+hist+early-exact)
        # over the shared stream; selection via the histogram; second gather
        # pass only for selected-but-not-predicted stragglers.
        st = min(4, n_probe)
        sample_est = _pq_sample_est(layout, probed, stream_codes, luts, st,
                                    ivf.cap)
        n_total = n_probe * ivf.cap
        plans = jax.vmap(
            lambda s: rerank.early_rerank_plan(
                s, n_cand=n_cand, n_sample=s.shape[0], n_total=n_total, m=m)
        )(sample_est)

        stream_vecs = index.vectors[layout.order]
        est, bucket, hist, early, nmiss = ops.fused_scan_batch(
            stream_codes, stream_vecs, lane_valid, luts, qs,
            plans.cb.d_min, plans.cb.delta, plans.cb.ew_map, m,
            plans.tau_pred, backend=backend)
        est = jnp.where(lane_valid, est, INF)
        positions = jnp.arange(n_flat, dtype=jnp.int32)
        _, sel_pos = col.collect_batch(est, positions, lane_valid, bucket,
                                       hist, n_cand, m)
        safe_pos = jnp.maximum(sel_pos, 0)
        sel_ids = jnp.where(sel_pos >= 0, layout.order[safe_pos], -1)
        e_at_sel = jnp.take_along_axis(early, safe_pos, axis=1)
        have = jnp.isfinite(e_at_sel) & (sel_pos >= 0)
        n_early = (jnp.sum(lane_valid, axis=1) - nmiss).astype(jnp.int32)
    else:
        # CPU fallback: there is no VMEM-residency win to collect inline, so
        # skip the prediction machinery and select the exact top-n_cand by
        # estimate with one batched top_k (same set the bucket collection
        # yields — bucketize is monotone in the estimate), then one exact
        # pass over the selection.
        est2 = ops.pq_adc_batch(stream_codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        sel_est, sel_pos = jax.lax.top_k(-est, n_cand)
        sel_ids = jnp.where(jnp.isfinite(-sel_est), layout.order[sel_pos], -1)
        e_at_sel = jnp.full(sel_pos.shape, INF, est.dtype)
        have = jnp.zeros(sel_pos.shape, bool)
        n_early = jnp.zeros((b,), jnp.int32)

    miss = ~have & (sel_ids >= 0)
    if fused:
        # stragglers only — keep the targeted per-row gather
        miss_d = _exact_dists_rows(index.vectors,
                                   jnp.where(miss, sel_ids, 0), qs)
    elif dense_rerank:
        # the whole selection misses (no inline pass on CPU): one shared
        # matmul over the stream beats n_cand per-row gathers
        stream_vecs = index.vectors[layout.order]
        exact_all = ops.l2_exact_batch(stream_vecs, qs, backend=backend)
        miss_d = jnp.take_along_axis(exact_all, jnp.maximum(sel_pos, 0),
                                     axis=1)
    else:
        miss_d = _exact_dists_rows(index.vectors,
                                   jnp.where(miss, sel_ids, 0), qs)
    ex = jnp.where(have, e_at_sel, jnp.where(miss, miss_d, INF))
    second = jnp.sum(miss, axis=1).astype(jnp.int32)

    neg, order = jax.lax.top_k(-ex, k)
    return SearchResult(-neg, jnp.take_along_axis(sel_ids, order, axis=1),
                        n_early + second, second)


def _ivf_pq_predictive_batch(index, qs, layout, probed, lane_valid,
                             stream_codes, luts, k, n_probe, n_cand, m,
                             backend, fused, pred_state, pred_count):
    """Predictive early-exact IVF+PQ (the tau_pred subsystem's PQ core).

    The re-rank pool is {bucket <= max(tau_pred, tau_true-at-pred_count)}
    instead of the top-n_cand-by-estimate cut: with a warm predictor that is
    ~pred_count candidates (default ~2k) instead of n_cand (default 8k).  On
    the fused path lanes under tau_pred were exacted inline during the scan;
    the fallback pass re-ranks only survivors the prediction missed.  The
    per-query codebooks are built exactly like the static fused path's, so
    bucket indices stay comparable batch-to-batch for the EMA.
    """
    ivf = index.ivf
    b = qs.shape[0]
    n_flat = layout.n_flat
    count = _resolve_pred_count(pred_count, k, n_cand)
    st = min(4, n_probe)
    sample_est = _pq_sample_est(layout, probed, stream_codes, luts, st,
                                ivf.cap)
    k_cb = min(n_cand, sample_est.shape[1])
    cbs = jax.vmap(lambda s: rb.build_codebook(s, k=k_cb, m=m))(sample_est)
    tau_pred = rerank.predict_tau(pred_state, count)

    if fused:
        stream_vecs = index.vectors[layout.order]
        est, bucket, hist, early, nmiss = ops.fused_scan_batch(
            stream_codes, stream_vecs, lane_valid, luts, qs,
            cbs.d_min, cbs.delta, cbs.ew_map, m,
            jnp.full((b,), tau_pred, jnp.int32), backend=backend)
        est = jnp.where(lane_valid, est, INF)
        n_early = (jnp.sum(lane_valid, axis=1) - nmiss).astype(jnp.int32)
    else:
        # CPU: no VMEM-residency win to collect inline — the whole pool goes
        # through the (much smaller than n_cand) fallback gather instead.
        est2 = ops.pq_adc_batch(stream_codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        bucket, hist = ops.bucket_hist_batch(
            est, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
            backend=backend)
        early = None
        n_early = jnp.zeros((b,), jnp.int32)

    # Survivors form an est-prefix (bucketize is monotone), so est-priority
    # truncation at a budget <= n_cand keeps the pool a SUBSET of the static
    # n_cand-by-estimate cut: the predictive result can only match or shrink
    # the static selection, never pull in ids the static path couldn't see.
    budget = min(_pred_budget(count, n_flat), n_cand)
    _, sel_pos, sel_ok, tau_true = _predictive_select(
        est, bucket, hist, lane_valid, tau_pred, count, budget)
    sel_ids = jnp.where(sel_ok, layout.order[sel_pos], -1)

    # Fallback pass (undershoot correctness): survivors not covered inline —
    # the fallback-plan mask at the selected positions.  On the unfused path
    # nothing was computed inline, so the whole selection is fallback work.
    if early is not None:
        e_at_sel = jnp.take_along_axis(early, sel_pos, axis=1)
        fb = rerank.predicted_fallback_mask(
            bucket, lane_valid, jnp.full((b,), tau_pred, jnp.int32), tau_true)
        miss = jnp.take_along_axis(fb, sel_pos, axis=1) & sel_ok
        have = sel_ok & ~miss
    else:
        e_at_sel = jnp.full(sel_pos.shape, INF, est.dtype)
        have = jnp.zeros(sel_pos.shape, bool)
        miss = sel_ok
    if not fused and 4 * budget >= n_flat:
        # pool is a large fraction of the stream (large-k regime): one shared
        # matmul beats per-row gathers, as in the static dense_rerank path
        exact_all = ops.l2_exact_batch(index.vectors[layout.order], qs,
                                       backend=backend)
        miss_d = jnp.take_along_axis(exact_all, jnp.maximum(sel_pos, 0),
                                     axis=1)
    else:
        miss_d = _exact_dists_rows(index.vectors,
                                   jnp.where(miss, sel_ids, 0), qs)
    ex = jnp.where(have, e_at_sel, jnp.where(miss, miss_d, INF))
    second = jnp.sum(miss, axis=1).astype(jnp.int32)

    neg, order = jax.lax.top_k(-ex, k)
    res = SearchResult(-neg, jnp.take_along_axis(sel_ids, order, axis=1),
                       n_early + second, second)
    return res, rerank.predictor_update(pred_state, hist)


def _rabitq_bounds_stream(codes_s: jax.Array, norm_o: jax.Array,
                          f_o: jax.Array, cl: jax.Array,
                          centroids: jax.Array, rot: jax.Array,
                          qs: jax.Array, d2: jax.Array,
                          lane_valid: jax.Array, eps0: float):
    """Batched RaBitQ estimator over a candidate stream (shared by the
    single-device and mesh-sharded paths — a shard's local stream is just a
    shorter stream).

    The per-(query, cluster) rotated residual decomposes as
    ``P(q - c) = Pq - Pc``, so the code inner products for every query are
    ONE (n_stream, d) x (d, B) matmul plus a per-lane centroid correction —
    the batched-native form of ``rabitq.query_factors`` + ``estimate``
    (mathematically identical; floating-point association differs from the
    per-cluster matvec of the single-query path).  ``d2`` is the (B, C)
    squared query-centroid distance matrix the routing pass already built;
    ``cl`` maps each stream lane to its (clamped) owning cluster.
    """
    g = qs @ rot.T                                            # (B, d) = Pq
    h = centroids @ rot.T                                     # (C, d) = Pc
    s1 = codes_s @ g.T                                        # (n_stream, B)
    s2 = jnp.sum(codes_s * h[cl], axis=1)                     # (n_stream,)
    nq = jnp.sqrt(d2)                                         # (B, C) norm_q
    nq_lane = nq[:, cl]                                       # (B, n_stream)
    d = codes_s.shape[1]
    xv = (s1.T - s2[None, :]) / (
        jnp.sqrt(jnp.float32(d)) * jnp.maximum(nq_lane, 1e-12))
    ip = xv / f_o[None, :]
    err = eps0 * jnp.sqrt((1.0 - f_o ** 2) / (f_o ** 2 * (d - 1)))
    scale = 2.0 * nq_lane * norm_o[None, :]
    base = nq_lane ** 2 + norm_o[None, :] ** 2
    zero = jnp.zeros_like(base)
    est = jnp.sqrt(jnp.maximum(base - scale * ip, zero))
    lb = jnp.sqrt(jnp.maximum(base - scale * (ip + err[None, :]), zero))
    ub = jnp.sqrt(jnp.maximum(base - scale * (ip - err[None, :]), zero))
    bad = ~lane_valid
    return (jnp.where(bad, INF, est), jnp.where(bad, INF, lb),
            jnp.where(bad, INF, ub))


def _rabitq_batch_bounds(index: RabitqIndex, layout: ivf_mod.FlatLayout,
                         qs: jax.Array, lane_valid: jax.Array, eps0: float,
                         d2: jax.Array):
    """Batched RaBitQ bounds over the single-device shared stream (see
    ``_rabitq_bounds_stream``)."""
    rq = index.rq
    ivf = index.ivf
    return _rabitq_bounds_stream(
        codes_s=rq.codes[layout.order].astype(jnp.float32),
        norm_o=rq.norm_o[layout.order],
        f_o=rq.f_o[layout.order],
        cl=jnp.minimum(layout.cluster_of, ivf.n_clusters - 1),
        centroids=ivf.centroids, rot=rq.rot, qs=qs, d2=d2,
        lane_valid=lane_valid, eps0=eps0)


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "use_bbc", "m", "eps0", "backend",
                     "pred_count"))
def ivf_rabitq_search_batch(
    index: RabitqIndex,
    qs: jax.Array,                 # (B, d)
    layout: ivf_mod.FlatLayout,
    k: int,
    n_probe: int,
    use_bbc: bool = False,
    m: int = 128,
    eps0: float = 3.0,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
) -> SearchResult:
    """Batched IVF+RaBitQ (±BBC) on the shared candidate stream.

    With ``pred_state``: RaBitQ's bounds already make the re-rank band
    minimal, so prediction cannot shrink it (the paper's RaBitQ gain is
    cache misses, not re-rank count).  ``n_second_pass`` becomes the MODELED
    second-pass gather volume of a bound-fused scan — band members whose
    lb-bucket lies above tau_pred, i.e. the lanes an inline early-exact pass
    keyed on the prediction would NOT have covered (the structural analogue
    of the paper's Table-2 cache-miss counts, like ``collector_stats``'s
    byte counts).  The executed math is unchanged on every backend: the
    whole band is evaluated in one shared matmul, and the result is
    bit-identical to the static path.  Returns ``(SearchResult, new_state)``;
    the EMA tracks the UPPER-bound histogram (the codebook's anchor).
    """
    if pred_state is not None and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    ivf = index.ivf
    b = qs.shape[0]
    cap = ivf.cap
    probed, lane_valid, d2 = _routing(ivf, layout, qs, n_probe)
    est, lb, ub = _rabitq_batch_bounds(index, layout, qs, lane_valid, eps0,
                                      d2=d2)
    n_flat = layout.n_flat
    stream_ids = layout.order

    if not use_bbc:
        # ---- baseline: per-cluster threshold re-ranking, vmapped ----------
        tpos, tok = ivf_mod.tile_positions(layout, probed, cap)
        lb_t = jnp.where(tok, jnp.take_along_axis(lb, tpos, axis=1), INF)
        ids_t = jnp.where(tok, stream_ids[tpos], -1)
        lb_t = lb_t.reshape(b, n_probe, cap)
        ids_t = ids_t.reshape(b, n_probe, cap)
        ok_t = tok.reshape(b, n_probe, cap)
        budget = min(cap, _rerank_budget(k, cap))

        def one_query(args):
            c_lb, c_ids, c_ok, q = args

            def step(carry, xs):
                pool_d, pool_i, n_rr = carry
                t_lb, t_ids, t_ok = xs
                thresh = pool_d[k - 1]
                mask = t_ok & (t_lb < thresh)
                pos, okc = rb.compact_mask(mask, budget)
                safe = jnp.minimum(pos, cap - 1)
                r_ids = jnp.where(okc, t_ids[safe], -1)
                r_d = _exact_dists(index.vectors, r_ids, q)
                r_d = jnp.where(okc, r_d, INF)
                alld = jnp.concatenate([pool_d, r_d])
                alli = jnp.concatenate([pool_i, r_ids])
                neg, idx = jax.lax.top_k(-alld, k)
                return (-neg, alli[idx], n_rr + jnp.sum(okc)), None

            pool0 = (jnp.full((k,), INF, lb.dtype),
                     jnp.full((k,), -1, jnp.int32), jnp.int32(0))
            (pd, pi, n_rr), _ = jax.lax.scan(step, pool0,
                                             (c_lb, c_ids, c_ok))
            order = jnp.argsort(pd)
            return pd[order], pi[order], n_rr

        pd, pi, n_rr = jax.lax.map(one_query, (lb_t, ids_t, ok_t, qs))
        return SearchResult(pd, pi, n_rr.astype(jnp.int32),
                            n_rr.astype(jnp.int32))

    # ---- BBC path (Alg. 3, batched greedy) ---------------------------------
    # Plan without per-query histogram scatters (order-statistic thresholds),
    # then resolve the whole uncertain band in ONE shared exact-distance
    # matmul over the stream.  The single-query path phases its evaluations
    # (est-priority, budgeted) to bound gather traffic; with the candidate
    # vectors already streaming through the batched L2 kernel, evaluating the
    # full band is cheaper than compacting it, and the final top-k is
    # unchanged: every band member the phases skip has lb above the phase-1
    # threshold, so its exact distance can never enter the top-k.
    plan = rerank.greedy_rerank_plan_batch(lb, ub, k, lane_valid, m=m)
    stream_vecs = index.vectors[layout.order]
    exact_all = ops.l2_exact_batch(stream_vecs, qs, backend=backend)
    exact_flat = jnp.where(plan.rerank_mask, exact_all, INF)

    res = jax.vmap(
        lambda p, ef, lbv, e: rerank.greedy_rerank_finalize(
            p, ef, lbv, stream_ids, k, est=e)
    )(plan, exact_flat, jnp.where(lane_valid, lb, INF), est)
    n_evals = jnp.sum(plan.rerank_mask, axis=1).astype(jnp.int32)
    if pred_state is not None:
        # inline coverage: band members predicted by the cross-batch tau; the
        # fallback (second-pass gather) shrinks to the unpredicted remainder
        count = max(pred_count, k) if pred_count is not None else k
        tau_pred = rerank.predict_tau(pred_state, count)
        covered = plan.rerank_mask & (plan.a_lb <= tau_pred)
        n_second = jnp.sum(plan.rerank_mask & ~covered,
                           axis=1).astype(jnp.int32)
        hist_ub = jax.vmap(rb.histogram, in_axes=(0, None, 0))(
            plan.a_ub, m, lane_valid)
        res_p = SearchResult(res.topk_dists, res.topk_ids, n_evals, n_second)
        return res_p, rerank.predictor_update(pred_state, hist_ub)
    return SearchResult(res.topk_dists, res.topk_ids, n_evals, n_evals)


# --------------------------------------------------------------------------
# Mesh-sharded searchers (corpus row-sharded over the mesh's 'model' axis)
# --------------------------------------------------------------------------
#
# The corpus stream is partitioned by ``ivf.sharded_layout`` (round-robin
# within each cluster) and the per-shard stream tensors (vectors / PQ codes /
# RaBitQ codes) are materialized offline with a leading shard axis, so under
# ``shard_map`` each chip scans ONLY its own rows.  One search step per batch:
#
#   1. replicated routing matmul (every chip computes the same probe sets),
#   2. per-shard fused scan over the local stream (the same ops.* kernels the
#      single-device batched path runs — a shard's stream is just shorter),
#   3. per-query local (m+1)-histograms; ``psum`` over 'model'
#      <- (m+1)*4 bytes per query, NOT k*8,
#   4. relaxed-threshold survivor compaction to a fixed per-shard budget
#      (~count/S * slack, key-priority),
#   5. exact re-rank of local survivors ON the shard that owns their rows
#      (the distributed analogue of Alg. 4's "compute exact while the vector
#      tile is hot": survivor vectors never cross the interconnect),
#   6. ``all_gather`` of survivors only, final replicated selection.
#
# ``use_bbc=False`` selects the naive distributed collector baseline: every
# shard maintains and gathers a full local top-k (k*8 bytes per shard on the
# wire), the quantity ``core.distributed.collective_cost_model`` prices.

SHARD_AXIS = "model"

_LAYOUT_SPEC = P(SHARD_AXIS, None)       # every ShardedLayout leaf: (S, ...)
_STREAM2_SPEC = P(SHARD_AXIS, None)          # (S, F) stream scalars
_STREAM3_SPEC = P(SHARD_AXIS, None, None)    # (S, F, d) stream tensors


def _shard_budget(budget: int | None, count: int, mesh, shard_flat: int,
                  slack: float) -> int:
    if budget is None:
        budget = dist.survivor_budget(count, mesh.shape[SHARD_AXIS],
                                      slack=slack)
    return max(8, min(budget, shard_flat))


def _local_block(sl: ivf_mod.ShardedLayout) -> ivf_mod.FlatLayout:
    """Inside a shard_map body the ShardedLayout arrives as a (1, ...) block;
    squeeze it into this shard's FlatLayout view."""
    return ivf_mod.FlatLayout(order=sl.order[0], cluster_of=sl.cluster_of[0],
                              offsets=sl.offsets[0], valid=sl.valid[0])


def _local_routing(centroids: jax.Array, qs: jax.Array, n_probe: int):
    """Replicated routing (identical on every shard): the same
    implementation the single-device path routes with, so probe sets match
    bit-for-bit."""
    return ivf_mod.route_batch_centroids(centroids, qs, n_probe)


def _exact_at_positions(svecs: jax.Array, qs: jax.Array, pos: jax.Array,
                        ok: jax.Array) -> jax.Array:
    """Per-query exact distances for (B, w) local stream positions (the
    budget-sized survivor sets; INF where not ok)."""

    def one(a):
        p, o, q = a
        v = svecs[jnp.where(o, p, 0)]
        d = jnp.sqrt(jnp.maximum(
            jnp.sum(v * v, -1) - 2.0 * (v @ q) + jnp.sum(q * q), 0.0))
        return jnp.where(o, d, INF)

    return jax.lax.map(one, (pos, ok, qs))


def _sharded_codebooks(layout: ivf_mod.FlatLayout, probed: jax.Array,
                       vals: jax.Array, st: int, cap_shard: int, k_cb: int,
                       m: int):
    """Per-query codebooks from the nearest ``st`` probed clusters, gathered
    across shards.  Each shard contributes its slice of those clusters; the
    union is exactly their full membership, so the codebook sees the same
    sample population as the single-device batched path (order differs,
    which build_codebook's top-k absorbs).  The gather is small: st * cap
    lanes per query, the codebook-sample prefix only."""
    spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], cap_shard)
    s_local = jnp.where(sok, jnp.take_along_axis(vals, spos, axis=1), INF)
    (sample,) = dist.gather_survivors(SHARD_AXIS, s_local)
    k_cb = min(k_cb, sample.shape[1])
    return jax.vmap(lambda s: rb.build_codebook(s, k=k_cb, m=m))(sample)


def _naive_local_topk(vals: jax.Array, layout: ivf_mod.FlatLayout, k: int):
    """Naive distributed collector's local half: full top-k per shard."""
    kk = min(k, vals.shape[1])
    neg, pos = jax.lax.top_k(-vals, kk)
    ok = jnp.isfinite(-neg)
    gids = jnp.where(ok, layout.order[pos], -1)
    return pos, ok, gids


def _final_topk(gd: jax.Array, gi: jax.Array, k: int):
    """Replicated final selection over the gathered survivors."""
    neg, order = jax.lax.top_k(-gd, k)
    d = -neg
    i = jnp.where(jnp.isfinite(d), jnp.take_along_axis(gi, order, axis=1), -1)
    return d, i


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_probe", "use_bbc", "m", "cap_shard",
                     "budget", "backend", "pred_count"))
def ivf_search_sharded(
    mesh,
    qs: jax.Array,                   # (B, d) replicated
    centroids: jax.Array,            # (C, d) replicated
    slayout: ivf_mod.ShardedLayout,  # (S, ...) sharded over 'model'
    svecs: jax.Array,                # (S, F, d) sharded stream vectors
    k: int,
    n_probe: int,
    use_bbc: bool = True,
    m: int = 128,
    cap_shard: int = 1,
    budget: int | None = None,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
) -> SearchResult:
    """Sharded batched IVF (exact distances in-scan).

    With ``pred_state`` the engine's predicted tau enters the survivor
    threshold as a floor (see ``dist.bbc_survivors_batch``) and the psum'd
    histogram feeds the EMA; returns ``(SearchResult, new_state)``.
    Distances are exact in-scan, so results match the static path exactly.
    """
    predictive = pred_state is not None
    if predictive and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    n_clusters = centroids.shape[0]
    shard_flat = svecs.shape[1]
    bud = _shard_budget(budget, k, mesh, shard_flat, slack=2.0)

    def body(qs, cent, sl, vecs, tau_floor=None):
        layout = _local_block(sl)
        vecs = vecs[0]
        probed, _ = _local_routing(cent, qs, n_probe)
        lane_valid = ivf_mod.probe_mask(layout, probed, n_clusters)
        dists = ops.l2_exact_batch(vecs, qs, backend=backend)
        dv = jnp.where(lane_valid, dists, INF)
        n = jax.lax.psum(jnp.sum(lane_valid, axis=1), SHARD_AXIS)
        ghist = None
        if use_bbc:
            st = min(4, n_probe)
            cbs = _sharded_codebooks(layout, probed, dv, st, cap_shard, k, m)
            bucket, hist = ops.bucket_hist_batch(
                dv, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
                backend=backend)
            pos, ok, _, _, ghist = dist.bbc_survivors_batch(
                bucket, dv, lane_valid, hist, k, bud, SHARD_AXIS,
                tau_floor=tau_floor)
            sd = jnp.where(ok, jnp.take_along_axis(dv, pos, axis=1), INF)
            gids = jnp.where(ok, layout.order[pos], -1)
        else:
            pos, ok, gids = _naive_local_topk(dv, layout, k)
            sd = jnp.where(ok, jnp.take_along_axis(dv, pos, axis=1), INF)
        gd, gi = dist.gather_survivors(SHARD_AXIS, sd, gids)
        d, i = _final_topk(gd, gi, k)
        if predictive:
            return d, i, n.astype(jnp.int32), ghist
        return d, i, n.astype(jnp.int32)

    in_specs = (P(), P(), _LAYOUT_SPEC, _STREAM3_SPEC)
    out_specs = (P(), P(), P())
    if predictive:
        count = max(pred_count, k) if pred_count is not None else k
        tau_p = rerank.predict_tau(pred_state, count)
        fn = dist.shard_map(body, mesh, in_specs=in_specs + (P(),),
                            out_specs=out_specs + (P(),))
        d, i, n, ghist = fn(qs, centroids, slayout, svecs, tau_p)
        res = SearchResult(d, i, n, jnp.zeros_like(n))
        return res, rerank.predictor_update(pred_state, ghist)
    fn = dist.shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs)
    d, i, n = fn(qs, centroids, slayout, svecs)
    return SearchResult(d, i, n, jnp.zeros_like(n))


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_probe", "n_cand", "use_bbc", "m",
                     "cap_shard", "budget", "backend", "pred_count"))
def ivf_pq_search_sharded(
    mesh,
    qs: jax.Array,
    pq_cb: pq_mod.PQCodebook,        # replicated codebook
    centroids: jax.Array,
    slayout: ivf_mod.ShardedLayout,
    scodes: jax.Array,               # (S, F, M) sharded PQ codes
    svecs: jax.Array,                # (S, F, d) sharded re-rank vectors
    k: int,
    n_probe: int,
    n_cand: int,
    use_bbc: bool = True,
    m: int = 128,
    cap_shard: int = 1,
    budget: int | None = None,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
) -> SearchResult:
    """Sharded batched IVF+PQ.

    BBC path: the histogram collective runs at ``n_cand`` granularity (the
    selection the single-device path makes by estimate), survivors are
    exact-re-ranked on their owning shard, and the final replicated pass
    re-applies the top-``n_cand``-by-estimate cut before the top-k by exact
    distance — the same selection semantics as ``ivf_pq_search_batch``.
    Naive path: each shard maintains a full local top-k by estimate and
    gathers k (dist, id) pairs (plus its local exact re-rank).

    Predictive path (``pred_state``): the histogram collective runs at
    ``pred_count`` granularity with the engine's tau_pred as a floor, each
    shard exact-re-ranks only its ~pred_count/S survivors (instead of
    ~n_cand/S), and the blunt post-gather n_cand-by-estimate re-cut is gone —
    the survivor pool IS the selection, matching the predictive batched
    path's semantics.  Returns ``(SearchResult, new_state)``.
    """
    predictive = pred_state is not None
    if predictive and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    n_clusters = centroids.shape[0]
    shard_flat = svecs.shape[1]
    count = _resolve_pred_count(pred_count, k, n_cand) if predictive \
        else n_cand
    bud = _shard_budget(budget, count, mesh, shard_flat, slack=2.0)

    def body(qs, cb, cent, sl, codes, vecs, tau_floor=None):
        layout = _local_block(sl)
        codes, vecs = codes[0], vecs[0]
        probed, _ = _local_routing(cent, qs, n_probe)
        lane_valid = ivf_mod.probe_mask(layout, probed, n_clusters)
        luts = jax.vmap(lambda q: pq_mod.adc_table(cb, q))(qs)
        est2 = ops.pq_adc_batch(codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        ghist = None
        if use_bbc:
            st = min(4, n_probe)
            cbs = _sharded_codebooks(layout, probed, est, st, cap_shard,
                                     n_cand, m)
            bucket, hist = ops.bucket_hist_batch(
                est, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
                backend=backend)
            pos, ok, _, _, ghist = dist.bbc_survivors_batch(
                bucket, est, lane_valid, hist, count, bud, SHARD_AXIS,
                tau_floor=tau_floor)
        else:
            pos, ok, _ = _naive_local_topk(est, layout, k)
        sel_est = jnp.where(ok, jnp.take_along_axis(est, pos, axis=1), INF)
        ex = _exact_at_positions(vecs, qs, pos, ok)
        gids = jnp.where(ok, layout.order[pos], -1)
        n_rr = jax.lax.psum(jnp.sum(ok, axis=1), SHARD_AXIS)
        ge, gx, gi = dist.gather_survivors(SHARD_AXIS, sel_est, ex, gids)
        if use_bbc:
            # Replicated selection alignment with the single-device batched
            # path.  Static: the blunt n_cand-by-estimate re-cut (the full
            # two-stage selection re-applied after the gather).  Predictive:
            # that re-cut is gone — the pool is already tau-thresholded at
            # pred_count granularity; only the SAME est-priority truncation
            # the batched predictive path applies (its static top_k width)
            # remains, so both deployments select the identical pool.
            if predictive:
                n_flat_global = shard_flat * mesh.shape[SHARD_AXIS]
                ncs = min(_pred_budget(count, n_flat_global), n_cand,
                          ge.shape[1])
            else:
                ncs = min(n_cand, ge.shape[1])
            nege, osel = jax.lax.top_k(-ge, ncs)
            keep = jnp.isfinite(-nege)
            gx = jnp.where(keep, jnp.take_along_axis(gx, osel, axis=1), INF)
            gi = jnp.where(keep, jnp.take_along_axis(gi, osel, axis=1), -1)
        d, i = _final_topk(gx, gi, k)
        if predictive:
            return d, i, n_rr.astype(jnp.int32), ghist
        return d, i, n_rr.astype(jnp.int32)

    in_specs = (P(), P(), P(), _LAYOUT_SPEC, _STREAM3_SPEC, _STREAM3_SPEC)
    out_specs = (P(), P(), P())
    if predictive:
        tau_p = rerank.predict_tau(pred_state, count)
        fn = dist.shard_map(body, mesh, in_specs=in_specs + (P(),),
                            out_specs=out_specs + (P(),))
        d, i, n_rr, ghist = fn(qs, pq_cb, centroids, slayout, scodes, svecs,
                               tau_p)
        res = SearchResult(d, i, n_rr, jnp.zeros_like(n_rr))
        return res, rerank.predictor_update(pred_state, ghist)
    fn = dist.shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs)
    d, i, n_rr = fn(qs, pq_cb, centroids, slayout, scodes, svecs)
    return SearchResult(d, i, n_rr, jnp.zeros_like(n_rr))


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_probe", "use_bbc", "m", "eps0",
                     "cap_shard", "budget", "backend", "pred_count"))
def ivf_rabitq_search_sharded(
    mesh,
    qs: jax.Array,
    rot: jax.Array,                  # (d, d) replicated rotation
    centroids: jax.Array,
    slayout: ivf_mod.ShardedLayout,
    scodes: jax.Array,               # (S, F, d) sharded ±1 codes
    snorm_o: jax.Array,              # (S, F)
    sf_o: jax.Array,                 # (S, F)
    svecs: jax.Array,                # (S, F, d) sharded re-rank vectors
    k: int,
    n_probe: int,
    use_bbc: bool = True,
    m: int = 128,
    eps0: float = 3.0,
    cap_shard: int = 1,
    budget: int | None = None,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
) -> SearchResult:
    """Sharded batched IVF+RaBitQ.

    BBC path: the codebook is built from upper bounds, the histogram
    collective thresholds the UB distribution at k (tau_ub), and a lane
    survives iff its LOWER bound bucketizes at or below tau_ub — the
    distributed form of Alg. 3's certainly-out test (lb above the relaxed
    k-th-ub threshold means at least k objects are surely closer).  Survivors
    are exact-re-ranked on their shard; the gathered top-k by exact distance
    therefore equals the single-device result set.

    Predictive path (``pred_state``): the survivor band is bound-determined
    (already minimal), so prediction does not floor tau here; the psum'd UB
    histogram feeds the engine's EMA so the batched/fused deployments of the
    same engine predict from serving traffic wherever it runs.  Returns
    ``(SearchResult, new_state)``; results are identical to the static path.
    """
    predictive = pred_state is not None
    if predictive and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    n_clusters = centroids.shape[0]
    shard_flat = svecs.shape[1]
    bud = _shard_budget(budget, k, mesh, shard_flat, slack=4.0)

    def body(qs, rot, cent, sl, codes, norm_o, f_o, vecs):
        layout = _local_block(sl)
        codes, norm_o, f_o, vecs = codes[0], norm_o[0], f_o[0], vecs[0]
        probed, d2 = _local_routing(cent, qs, n_probe)
        lane_valid = ivf_mod.probe_mask(layout, probed, n_clusters)
        cl = jnp.minimum(layout.cluster_of, n_clusters - 1)
        est, lb, ub = _rabitq_bounds_stream(
            codes.astype(jnp.float32), norm_o, f_o, cl, cent, rot, qs, d2,
            lane_valid, eps0)
        ghist = None
        if use_bbc:
            st = min(4, n_probe)
            cbs = _sharded_codebooks(layout, probed, ub, st, cap_shard, k, m)
            _, hist_ub = ops.bucket_hist_batch(
                ub, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
                backend=backend)
            bucket_lb = jax.vmap(rb.bucketize)(cbs, lb)
            pos, ok, _, _, ghist = dist.bbc_survivors_batch(
                bucket_lb, lb, lane_valid, hist_ub, k, bud, SHARD_AXIS)
        else:
            pos, ok, _ = _naive_local_topk(est, layout, k)
        ex = _exact_at_positions(vecs, qs, pos, ok)
        gids = jnp.where(ok, layout.order[pos], -1)
        n_rr = jax.lax.psum(jnp.sum(ok, axis=1), SHARD_AXIS)
        gx, gi = dist.gather_survivors(SHARD_AXIS, ex, gids)
        d, i = _final_topk(gx, gi, k)
        if predictive:
            return d, i, n_rr.astype(jnp.int32), ghist
        return d, i, n_rr.astype(jnp.int32)

    in_specs = (P(), P(), P(), _LAYOUT_SPEC, _STREAM3_SPEC, _STREAM2_SPEC,
                _STREAM2_SPEC, _STREAM3_SPEC)
    out_specs = (P(), P(), P())
    if predictive:
        fn = dist.shard_map(body, mesh, in_specs=in_specs,
                            out_specs=out_specs + (P(),))
        d, i, n_rr, ghist = fn(qs, rot, centroids, slayout, scodes, snorm_o,
                               sf_o, svecs)
        res = SearchResult(d, i, n_rr, jnp.zeros_like(n_rr))
        return res, rerank.predictor_update(pred_state, ghist)
    fn = dist.shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs)
    d, i, n_rr = fn(qs, rot, centroids, slayout, scodes, snorm_o, sf_o, svecs)
    return SearchResult(d, i, n_rr, jnp.zeros_like(n_rr))
