"""End-to-end ANN searchers: IVF / IVF+PQ / IVF+RaBitQ, each ± BBC.

Single-query functions, jit-compiled with static hyper-parameters; batch with
``jax.vmap`` (small batches — intermediates are O(n_probe * cap)).  All paths
return ``SearchResult`` with instrumentation counters used by the benchmark
suite (re-rank counts, second-pass gathers — the TPU analogues of the paper's
VTune/perf numbers).

Method map (paper Table / Fig. 1):
  ivf_search(use_bbc=False)          -> IVF
  ivf_pq_search(use_bbc=False)       -> IVF+PQ          (unbounded, n_cand)
  ivf_pq_search(use_bbc=True)        -> IVF+PQ+BBC      (Alg. 4 early rerank)
  ivf_rabitq_search(use_bbc=False)   -> IVF+RaBitQ      (threshold rerank)
  ivf_rabitq_search(use_bbc=True)    -> IVF+RaBitQ+BBC  (Alg. 3 greedy)
  flat.search                        -> BFC
(IVF+RaBitQ+MIN lives in benchmarks — host-side heap baseline, Alg. 2.)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import buffer as rb
from repro.core import collector as col
from repro.core import rerank
from repro.index import ivf as ivf_mod
from repro.index import pq as pq_mod
from repro.index import rabitq as rq_mod

INF = jnp.inf


class PQIndex(NamedTuple):
    ivf: ivf_mod.IVFIndex
    pq: pq_mod.PQCodebook
    codes: jax.Array    # (N, M) uint8
    vectors: jax.Array  # (N, d) fp32 (re-rank source)


class RabitqIndex(NamedTuple):
    ivf: ivf_mod.IVFIndex
    rq: rq_mod.RabitqCodes
    vectors: jax.Array


class SearchResult(NamedTuple):
    dists: jax.Array
    ids: jax.Array
    n_reranked: jax.Array       # exact distance computations spent
    n_second_pass: jax.Array    # re-rank gathers NOT covered inline (Alg. 4)


# --------------------------------------------------------------------------
# Index builders (offline)
# --------------------------------------------------------------------------

def build_pq_index(key, x, n_clusters: int, n_sub: int | None = None,
                   n_bits: int = 4, n_iter: int = 10) -> PQIndex:
    d = x.shape[1]
    n_sub = n_sub or d // 4          # paper: M = d/4, B = 4
    k1, k2 = jax.random.split(key)
    index = ivf_mod.build(k1, x, n_clusters, n_iter)
    cb = pq_mod.train(k2, x, n_sub, n_bits, n_iter)
    codes = pq_mod.encode(cb, x)
    return PQIndex(ivf=index, pq=cb, codes=codes, vectors=x)


def build_rabitq_index(key, x, n_clusters: int, n_iter: int = 10) -> RabitqIndex:
    k1, k2 = jax.random.split(key)
    index = ivf_mod.build(k1, x, n_clusters, n_iter)
    assignment = jnp.argmin(
        jnp.sum(x * x, 1, keepdims=True)
        - 2 * x @ index.centroids.T
        + jnp.sum(index.centroids ** 2, 1),
        axis=1,
    )
    rq = rq_mod.encode(k2, x, index.centroids, assignment)
    return RabitqIndex(ivf=index, rq=rq, vectors=x)


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------

def _exact_dists(vectors: jax.Array, ids: jax.Array, q: jax.Array) -> jax.Array:
    """Exact Euclidean distances for a gathered id set (ids may contain -1
    padding; callers mask)."""
    v = vectors[jnp.maximum(ids, 0)]
    return jnp.sqrt(jnp.maximum(
        jnp.sum(v * v, -1) - 2.0 * (v @ q) + jnp.sum(q * q), 0.0))


def _stream_from(est, ids, valid) -> col.StreamInput:
    return col.StreamInput(dists=est, ids=ids, valid=valid)


def _rerank_budget(k: int, cap: int) -> int:
    b = max(8 * k, 2048)
    return ((b + 127) // 128) * 128


# --------------------------------------------------------------------------
# IVF (no quantization): exact distances in-scan + collector
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "n_probe", "use_bbc", "m"))
def ivf_search(index: ivf_mod.IVFIndex, vectors: jax.Array, q: jax.Array,
               k: int, n_probe: int, use_bbc: bool = False,
               m: int = 128) -> SearchResult:
    probed = ivf_mod.route(index, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(index, probed)    # (n_probe, cap)
    dists = jax.vmap(lambda i: _exact_dists(vectors, i, q))(ids)
    dists = jnp.where(valid, dists, INF)
    s = _stream_from(dists, ids, valid)
    if use_bbc:
        d, i = col.bbc_collect(s, k, m=m)
    else:
        d, i = col.topk_collect(s, k)
    n = jnp.sum(valid)
    return SearchResult(d, i, n, jnp.int32(0))


# --------------------------------------------------------------------------
# IVF + PQ (unbounded): ADC estimate -> n_cand selection -> re-rank
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "n_cand", "use_bbc", "m", "early_slack"),
)
def ivf_pq_search(
    index: PQIndex,
    q: jax.Array,
    k: int,
    n_probe: int,
    n_cand: int,
    use_bbc: bool = False,
    m: int = 128,
    early_slack: float = 4.0,
) -> SearchResult:
    """IVF+PQ (baseline) and IVF+PQ+BBC (Alg. 4 early re-rank).

    Baseline: running top-n_cand by estimate across cluster tiles ("Heap"
    collector), then one gather+exact pass over the n_cand selection.

    +BBC: bucket collector for the n_cand selection, plus early re-ranking —
    per cluster tile, objects whose estimate bucketizes at or below tau_pred
    have exact distances computed inline while the cluster's vectors are
    resident (TPU: same VMEM tile; see kernels/fused_scan.py).  The second
    gather pass only covers the few selected-but-not-predicted stragglers
    (``n_second_pass`` — the cache-miss analogue the paper counts in Table 2).
    """
    ivf = index.ivf
    probed = ivf_mod.route(ivf, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(ivf, probed)       # (n_probe, cap)
    cap = ids.shape[1]
    lut = pq_mod.adc_table(index.pq, q)

    codes = index.codes[jnp.maximum(ids, 0)]                  # (n_probe, cap, M)
    est = jax.vmap(lambda c: pq_mod.estimate(lut, c))(codes)  # squared dists
    est = jnp.sqrt(jnp.maximum(jnp.where(valid, est, INF), 0.0))

    flat_est = est.reshape(-1)
    flat_ids = ids.reshape(-1)
    flat_valid = valid.reshape(-1)

    if not use_bbc:
        # ---- baseline: heap-analogue selection, full second-pass re-rank --
        s = _stream_from(est, ids, valid)
        cd, ci = col.topk_collect(s, n_cand)
        ex = _exact_dists(index.vectors, ci, q)
        ex = jnp.where(ci >= 0, ex, INF)
        neg, order = jax.lax.top_k(-ex, k)
        return SearchResult(-neg, ci[order], jnp.int32(n_cand),
                            jnp.int32(n_cand))

    # ---- BBC path (Alg. 4) ------------------------------------------------
    n_sample_tiles = min(4, n_probe)
    sample = jnp.where(valid[:n_sample_tiles],
                       est[:n_sample_tiles], INF).reshape(-1)
    n_total = flat_valid.shape[0]
    plan = rerank.early_rerank_plan(
        sample, n_cand=n_cand, n_sample=sample.shape[0],
        n_total=n_total, m=m)

    # Early re-rank: per-cluster inline exact for predicted survivors.
    early_budget = int(min(cap, max(128, round(n_cand / n_probe * early_slack))))
    early_budget = ((early_budget + 127) // 128) * 128
    early_budget = min(early_budget, cap)

    positions = jnp.arange(n_total, dtype=jnp.int32)
    flat_pos_matrix = positions.reshape(n_probe, cap)

    def per_cluster(c_est, c_ids, c_valid, row_pos):
        """Inline exact distances for predicted survivors of one cluster tile
        (Alg. 4 lines 9-11: the vectors are 'hot' — on TPU, the fused kernel
        streams them in the same VMEM tile as the codes)."""
        pred = rerank.early_rerank_mask(plan, c_est) & c_valid
        pos, ok = rb.compact_mask(pred, early_budget)
        safe = jnp.minimum(pos, cap - 1)
        e_ids = jnp.where(ok, c_ids[safe], -1)
        e_d = jnp.where(ok, _exact_dists(index.vectors, e_ids, q), INF)
        tgt = jnp.where(ok, row_pos[safe], n_total)  # flat scatter targets
        return e_d, tgt, jnp.sum(ok)

    e_d, e_tgt, e_counts = jax.vmap(per_cluster)(est, ids, valid, flat_pos_matrix)
    n_early = jnp.sum(e_counts)
    flat_e_d = jnp.full((n_total + 1,), INF, est.dtype)
    flat_e_d = flat_e_d.at[e_tgt.reshape(-1)].set(e_d.reshape(-1), mode="drop")
    flat_e_d = flat_e_d[:n_total]

    # n_cand selection by estimate with the bucket collector (Alg. 1 Collect).
    bucket_ids = rb.bucketize(plan.cb, flat_est)
    _, sel_pos = rb.collect(
        plan.cb, flat_est, positions, bucket_ids, n_cand, flat_valid)
    sel_ids = flat_ids[jnp.maximum(sel_pos, 0)]
    sel_ids = jnp.where(sel_pos >= 0, sel_ids, -1)

    # Inline results cover most of the selection; one small second pass for
    # the stragglers (n_second_pass ~ the paper's Table-2 cache-miss story).
    have = jnp.isfinite(flat_e_d[jnp.maximum(sel_pos, 0)]) & (sel_pos >= 0)
    miss = ~have & (sel_ids >= 0)
    second = jnp.sum(miss)
    miss_d = _exact_dists(index.vectors, jnp.where(miss, sel_ids, 0), q)
    ex = jnp.where(have, flat_e_d[jnp.maximum(sel_pos, 0)],
                   jnp.where(miss, miss_d, INF))

    neg, order = jax.lax.top_k(-ex, k)
    return SearchResult(-neg, sel_ids[order],
                        (n_early + second).astype(jnp.int32),
                        second.astype(jnp.int32))


# --------------------------------------------------------------------------
# IVF + RaBitQ (bounded): estimate+bounds -> rerank
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "use_bbc", "m", "eps0"),
)
def ivf_rabitq_search(
    index: RabitqIndex,
    q: jax.Array,
    k: int,
    n_probe: int,
    use_bbc: bool = False,
    m: int = 128,
    eps0: float = 3.0,
) -> SearchResult:
    """IVF+RaBitQ baseline (per-cluster threshold re-rank) and +BBC (Alg. 3
    closed-form greedy on two result buffers)."""
    ivf = index.ivf
    probed = ivf_mod.route(ivf, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(ivf, probed)
    n_probe_, cap = ids.shape
    rq = index.rq

    def est_cluster(cid, c_ids, c_valid):
        qf = rq_mod.query_factors(rq, q, ivf.centroids[cid])
        c = rq.codes[jnp.maximum(c_ids, 0)]
        no = rq.norm_o[jnp.maximum(c_ids, 0)]
        fo = rq.f_o[jnp.maximum(c_ids, 0)]
        est, lb, ub = rq_mod.estimate(c, no, fo, qf, eps0)
        bad = ~c_valid
        return (jnp.where(bad, INF, est), jnp.where(bad, INF, lb),
                jnp.where(bad, INF, ub))

    est, lb, ub = jax.vmap(est_cluster)(probed, ids, valid)

    if not use_bbc:
        # ---- baseline: per-cluster threshold re-ranking -------------------
        budget = min(cap, _rerank_budget(k, cap))

        def step(carry, xs):
            pool_d, pool_i, n_rr = carry
            c_lb, c_ids, c_valid = xs
            thresh = pool_d[k - 1]
            mask = c_valid & (c_lb < thresh)
            pos, ok = rb.compact_mask(mask, budget)
            safe = jnp.minimum(pos, cap - 1)
            r_ids = jnp.where(ok, c_ids[safe], -1)
            r_d = _exact_dists(index.vectors, r_ids, q)
            r_d = jnp.where(ok, r_d, INF)
            alld = jnp.concatenate([pool_d, r_d])
            alli = jnp.concatenate([pool_i, r_ids])
            neg, idx = jax.lax.top_k(-alld, k)
            return (-neg, alli[idx], n_rr + jnp.sum(ok)), None

        pool0 = (jnp.full((k,), INF, est.dtype), jnp.full((k,), -1, jnp.int32),
                 jnp.int32(0))
        (pd, pi, n_rr), _ = jax.lax.scan(step, pool0, (lb, ids, valid))
        order = jnp.argsort(pd)
        return SearchResult(pd[order], pi[order], n_rr, n_rr)

    # ---- BBC path (Alg. 3, two-phase greedy) -------------------------------
    flat_lb, flat_ub = lb.reshape(-1), ub.reshape(-1)
    flat_est = est.reshape(-1)
    flat_ids, flat_valid = ids.reshape(-1), valid.reshape(-1)
    n_flat = flat_ids.shape[0]
    plan = rerank.greedy_rerank_plan(flat_lb, flat_ub, k, flat_valid, m=m)

    exact_flat = jnp.full((n_flat,), INF, est.dtype)

    def eval_mask(mask, budget, exact_flat):
        """Exact distances for up to ``budget`` masked lanes (est-priority)."""
        key_est = jnp.where(mask, flat_est, INF)
        _, pos = jax.lax.top_k(-key_est, budget)
        ok = jnp.isfinite(key_est[pos])
        safe = jnp.minimum(pos, n_flat - 1)
        r_ids = jnp.where(ok, flat_ids[safe], -1)
        r_d = jnp.where(ok, _exact_dists(index.vectors, r_ids, q), INF)
        exact_flat = exact_flat.at[jnp.where(ok, safe, n_flat)].set(
            r_d, mode="drop")
        return exact_flat, r_d, jnp.sum(ok)

    # Phase 1: likely-in items (ub at/below the k-th-ub bucket).  Their exact
    # distances tighten the threshold, as in the paper's iterative loop.
    p1 = rerank.phase1_mask(plan)
    budget1 = min(n_flat, ((k + 1024 + 127) // 128) * 128)
    exact_flat, p1_d, n1 = eval_mask(p1, budget1, exact_flat)
    t2 = rerank.phase2_threshold(plan, p1_d, k)

    # Phase 2: remaining uncertain items whose lower bound is under the
    # tightened threshold (anything above is certainly out).
    p2 = plan.rerank_mask & ~p1 & jnp.isinf(exact_flat) & (flat_lb <= t2)
    budget2 = min(n_flat, _rerank_budget(k, cap))
    exact_flat, _, n2 = eval_mask(p2, budget2, exact_flat)

    res = rerank.greedy_rerank_finalize(
        plan, exact_flat, jnp.where(flat_valid, flat_lb, INF), flat_ids, k,
        est=flat_est)
    n_evals = (n1 + n2).astype(jnp.int32)
    return SearchResult(res.topk_dists, res.topk_ids, n_evals, n_evals)
