"""End-to-end ANN searchers: IVF / IVF+PQ / IVF+RaBitQ, each ± BBC.

Two families of entry points:

  * Single-query functions (``ivf_search`` & co.), jit-compiled with static
    hyper-parameters.  Intermediates are O(n_probe * cap) over the padded
    member table.
  * Natively batched ``*_batch`` functions: one routing matmul for the whole
    query batch, ONE shared candidate-stream gather (the compact
    ``ivf.FlatLayout``, zero per-cluster padding), per-query probe masks, and
    batched estimate / bucketize / histogram / re-rank matmuls that run
    through the Pallas kernels on TPU (``kernels.ops.*_batch``) and their
    jnp mirrors on CPU.  Use these instead of ``jax.vmap`` over the single
    query functions — vmap replicates the padded gathers per query.

All paths return ``SearchResult`` with instrumentation counters used by the
benchmark suite (re-rank counts, second-pass gathers — the TPU analogues of
the paper's VTune/perf numbers); batched paths return per-query (B,) counters.

The batched and sharded searchers additionally support the predictive
early-exact subsystem: pass ``pred_state`` (a ``rerank.PredictorState``, the
engine-owned EMA of previous batches' bucket histograms) and the call returns
``(SearchResult, new_state)`` with the re-rank pool sized by the predicted
threshold bucket instead of the static knobs (see the predictive section
below and ``core.rerank.predict_tau``).

Method map (paper Table / Fig. 1):
  ivf_search(use_bbc=False)          -> IVF
  ivf_pq_search(use_bbc=False)       -> IVF+PQ          (unbounded, n_cand)
  ivf_pq_search(use_bbc=True)        -> IVF+PQ+BBC      (Alg. 4 early rerank)
  ivf_rabitq_search(use_bbc=False)   -> IVF+RaBitQ      (threshold rerank)
  ivf_rabitq_search(use_bbc=True)    -> IVF+RaBitQ+BBC  (Alg. 3 greedy)
  flat.search                        -> BFC
(IVF+RaBitQ+MIN lives in benchmarks — host-side heap baseline, Alg. 2.)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core import buffer as rb
from repro.core import collector as col
from repro.core import distributed as dist
from repro.core import rerank
from repro.index import ivf as ivf_mod
from repro.index import pq as pq_mod
from repro.index import rabitq as rq_mod
from repro.kernels import ops
from repro.kernels import ref as kref

INF = jnp.inf


class PQIndex(NamedTuple):
    """IVF + PQ index bundle (codes plus fp32 vectors for exact re-rank)."""
    ivf: ivf_mod.IVFIndex
    pq: pq_mod.PQCodebook
    codes: jax.Array    # (N, M) uint8
    vectors: jax.Array  # (N, d) fp32 (re-rank source)


class RabitqIndex(NamedTuple):
    """IVF + RaBitQ index bundle (codes plus fp32 vectors for exact re-rank).
    """
    ivf: ivf_mod.IVFIndex
    rq: rq_mod.RabitqCodes
    vectors: jax.Array


class RabitqStream(NamedTuple):
    """Layout-ordered RaBitQ candidate stream (the per-call gather of the
    codes/vectors/factors into FlatLayout order, hoisted out of the
    searchers).  The engine materializes it once at build time — at stream
    scale the two 30+ MB gathers cost as much as the bounds matmul, every
    batch, on BOTH the fused and two-phase paths."""

    codes: jax.Array    # (n_flat, d) fp32 ±1
    vectors: jax.Array  # (n_flat, d) fp32
    norm_o: jax.Array   # (n_flat,)
    f_o: jax.Array      # (n_flat,)
    cl: jax.Array       # (n_flat,) clamped owning cluster per lane


def rabitq_stream(index: RabitqIndex,
                  layout: ivf_mod.FlatLayout) -> RabitqStream:
    rq = index.rq
    return RabitqStream(
        codes=rq.codes[layout.order].astype(jnp.float32),
        vectors=index.vectors[layout.order],
        norm_o=rq.norm_o[layout.order],
        f_o=rq.f_o[layout.order],
        cl=jnp.minimum(layout.cluster_of, index.ivf.n_clusters - 1))


class SearchResult(NamedTuple):
    """Top-k result with per-query re-rank work counters."""
    dists: jax.Array
    ids: jax.Array
    n_reranked: jax.Array       # exact distance computations spent
    n_second_pass: jax.Array    # re-rank gathers NOT covered inline (Alg. 4)


# --------------------------------------------------------------------------
# Index builders (offline)
# --------------------------------------------------------------------------

def build_pq_index(key, x, n_clusters: int, n_sub: int | None = None,
                   n_bits: int = 4, n_iter: int = 10) -> PQIndex:
    d = x.shape[1]
    n_sub = n_sub or d // 4          # paper: M = d/4, B = 4
    k1, k2 = jax.random.split(key)
    index = ivf_mod.build(k1, x, n_clusters, n_iter)
    cb = pq_mod.train(k2, x, n_sub, n_bits, n_iter)
    codes = pq_mod.encode(cb, x)
    return PQIndex(ivf=index, pq=cb, codes=codes, vectors=x)


def build_rabitq_index(key, x, n_clusters: int, n_iter: int = 10) -> RabitqIndex:
    k1, k2 = jax.random.split(key)
    index = ivf_mod.build(k1, x, n_clusters, n_iter)
    assignment = jnp.argmin(
        jnp.sum(x * x, 1, keepdims=True)
        - 2 * x @ index.centroids.T
        + jnp.sum(index.centroids ** 2, 1),
        axis=1,
    )
    rq = rq_mod.encode(k2, x, index.centroids, assignment)
    return RabitqIndex(ivf=index, rq=rq, vectors=x)


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------

def _exact_dists(vectors: jax.Array, ids: jax.Array, q: jax.Array) -> jax.Array:
    """Exact Euclidean distances for a gathered id set (ids may contain -1
    padding; callers mask)."""
    v = vectors[jnp.maximum(ids, 0)]
    return jnp.sqrt(jnp.maximum(
        jnp.sum(v * v, -1) - 2.0 * (v @ q) + jnp.sum(q * q), 0.0))


def _stream_from(est, ids, valid) -> col.StreamInput:
    return col.StreamInput(dists=est, ids=ids, valid=valid)


def _rerank_budget(k: int, cap: int) -> int:
    b = max(8 * k, 2048)
    return ((b + 127) // 128) * 128


# --------------------------------------------------------------------------
# Predictive early-exact re-rank (cross-batch tau_pred subsystem)
# --------------------------------------------------------------------------
#
# The static BBC paths size the exact-re-rank pool with a blunt static knob
# (n_cand for PQ; the full uncertain band for RaBitQ).  In predictive mode a
# searcher additionally takes the engine-owned ``rerank.PredictorState`` (the
# EMA of previous batches' bucket histograms) and returns
# ``(SearchResult, new_state)``:
#
#   * tau_pred = predict_tau(state, pred_count) is the bucket the cumulative
#     histogram is EXPECTED to reach pred_count at.  The scan early-exacts
#     lanes at or below it inline (fused kernel on TPU).
#   * tau_true from THIS batch's histogram guards correctness: survivors are
#     bucket <= max(tau_pred, tau_true), and survivors the prediction missed
#     (bucket in (tau_pred, tau_true]) get a fallback second-pass re-rank —
#     exactly the static path's gather, just (usually) empty.
#   * the new state folds this batch's histogram into the EMA.
#
# For PQ the pool shrinks from n_cand to ~pred_count (fewer re-ranks); for
# IVF/RaBitQ distances/bounds already bound the pool, so prediction moves
# work inline (fewer second-pass gathers) without changing the pool.


def _resolve_pred_count(pred_count: int | None, k: int,
                        n_cand: int | None = None) -> int:
    """Default predictive re-rank pool target (~2.5k): deep enough that the
    exact top-k inside it matches the static n_cand cut on realistic
    estimate error, ~3x shallower than the n_cand=8k default.  This is the
    single source of the default — the engine and bench_tau_pred both
    resolve through it (BENCH_tau_pred.json is measured at this value)."""
    if pred_count is None:
        pred_count = max(5 * k // 2, k + 1024)
    pred_count = max(pred_count, k)
    if n_cand is not None:
        pred_count = min(pred_count, n_cand)
    return pred_count


def _pred_budget(count: int, n: int) -> int:
    """Static selection width over the survivor pool: the threshold bucket
    overshoots ``count`` by at most its own occupancy; slack covers skew."""
    b = count + max(count // 2, 256)
    return int(min(n, ((b + 127) // 128) * 128))


def _sample_codebooks(layout: ivf_mod.FlatLayout, probed: jax.Array,
                      vals: jax.Array, st: int, cap: int, k_cb: int, m: int):
    """Per-query codebooks from the nearest ``st`` probed cluster tiles of a
    (B, n_flat) value matrix (the batched analogue of the paper's 5-10
    nearest-cluster sample)."""
    spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], cap)
    sample = jnp.where(sok, jnp.take_along_axis(vals, spos, axis=1), INF)
    k_cb = min(k_cb, sample.shape[1])
    return jax.vmap(lambda s: rb.build_codebook(s, k=k_cb, m=m))(sample)


def _pq_sample_est(layout: ivf_mod.FlatLayout, probed: jax.Array,
                   stream_codes: jax.Array, luts: jax.Array, st: int,
                   cap: int) -> jax.Array:
    """Per-query ADC estimates over the nearest ``st`` probed cluster tiles
    (the codebook sample of the batched PQ paths — static fused and
    predictive MUST sample identically so bucket indices stay comparable
    across batches for the EMA)."""
    spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], cap)

    def one(a):
        pos, ok, lut = a
        e = pq_mod.estimate(lut, stream_codes[pos])
        return jnp.where(ok, jnp.sqrt(jnp.maximum(e, 0.0)), INF)

    return jax.lax.map(one, (spos, sok, luts))


def _predictive_select(est: jax.Array, bucket: jax.Array, hist: jax.Array,
                       lane_valid: jax.Array, tau_pred: jax.Array,
                       count: int, budget: int, gids: jax.Array):
    """Survivor selection under the predicted threshold.

    Survivors are lanes with bucket <= max(tau_pred, tau_true-at-count);
    they are picked est-priority into the static ``budget`` (ascending,
    boundary ties broken by smallest global id — see ``_topk_est_id`` —
    so the truncated pool matches the sharded deployment's re-cut on tied
    estimates), and the first k columns are the exact top-k of the pool.
    Returns (sel_est ascending (B, budget), sel_pos, sel_ok, tau_true).
    """
    tau_true, _ = jax.vmap(rb.threshold_bucket, in_axes=(0, None))(hist, count)
    tau_used = jnp.maximum(tau_pred, tau_true)
    masked = jnp.where(lane_valid & (bucket <= tau_used[:, None]), est, INF)
    neg, sel_pos = _topk_est_id(masked, gids, budget)
    return -neg, sel_pos, jnp.isfinite(-neg), tau_true


# --------------------------------------------------------------------------
# IVF (no quantization): exact distances in-scan + collector
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "n_probe", "use_bbc", "m"))
def ivf_search(index: ivf_mod.IVFIndex, vectors: jax.Array, q: jax.Array,
               k: int, n_probe: int, use_bbc: bool = False,
               m: int = 128) -> SearchResult:
    probed = ivf_mod.route(index, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(index, probed)    # (n_probe, cap)
    dists = jax.vmap(lambda i: _exact_dists(vectors, i, q))(ids)
    dists = jnp.where(valid, dists, INF)
    s = _stream_from(dists, ids, valid)
    if use_bbc:
        d, i = col.bbc_collect(s, k, m=m)
    else:
        d, i = col.topk_collect(s, k)
    n = jnp.sum(valid)
    return SearchResult(d, i, n, jnp.int32(0))


# --------------------------------------------------------------------------
# IVF + PQ (unbounded): ADC estimate -> n_cand selection -> re-rank
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "n_cand", "use_bbc", "m", "early_slack"),
)
def ivf_pq_search(
    index: PQIndex,
    q: jax.Array,
    k: int,
    n_probe: int,
    n_cand: int,
    use_bbc: bool = False,
    m: int = 128,
    early_slack: float = 4.0,
) -> SearchResult:
    """IVF+PQ (baseline) and IVF+PQ+BBC (Alg. 4 early re-rank).

    Baseline: running top-n_cand by estimate across cluster tiles ("Heap"
    collector), then one gather+exact pass over the n_cand selection.

    +BBC: bucket collector for the n_cand selection, plus early re-ranking —
    per cluster tile, objects whose estimate bucketizes at or below tau_pred
    have exact distances computed inline while the cluster's vectors are
    resident (TPU: same VMEM tile; see kernels/fused_scan.py).  The second
    gather pass only covers the few selected-but-not-predicted stragglers
    (``n_second_pass`` — the cache-miss analogue the paper counts in Table 2).
    """
    ivf = index.ivf
    probed = ivf_mod.route(ivf, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(ivf, probed)       # (n_probe, cap)
    cap = ids.shape[1]
    lut = pq_mod.adc_table(index.pq, q)

    codes = index.codes[jnp.maximum(ids, 0)]                  # (n_probe, cap, M)
    est = jax.vmap(lambda c: pq_mod.estimate(lut, c))(codes)  # squared dists
    est = jnp.sqrt(jnp.maximum(jnp.where(valid, est, INF), 0.0))

    flat_est = est.reshape(-1)
    flat_ids = ids.reshape(-1)
    flat_valid = valid.reshape(-1)

    if not use_bbc:
        # ---- baseline: heap-analogue selection, full second-pass re-rank --
        s = _stream_from(est, ids, valid)
        cd, ci = col.topk_collect(s, n_cand)
        ex = _exact_dists(index.vectors, ci, q)
        ex = jnp.where(ci >= 0, ex, INF)
        neg, order = jax.lax.top_k(-ex, k)
        return SearchResult(-neg, ci[order], jnp.int32(n_cand),
                            jnp.int32(n_cand))

    # ---- BBC path (Alg. 4) ------------------------------------------------
    n_sample_tiles = min(4, n_probe)
    sample = jnp.where(valid[:n_sample_tiles],
                       est[:n_sample_tiles], INF).reshape(-1)
    n_total = flat_valid.shape[0]
    # The TPU formulation materializes the whole estimate pass before the
    # early re-rank (tile-parallel, not streamed), so the sample prefix
    # seeds the CODEBOOK only while tau_pred comes from the full scan at
    # Alg. 4 line-14 granularity — the nearest-cluster prefix is
    # distance-skewed and its rank heuristic (early_rerank_plan, used by
    # the streaming fused-kernel path) lands systematically low on
    # concentrated corpora.  The refresh is the O(m) histogram threshold
    # (bucketize is monotone, so the first bucket whose cumulative count
    # reaches n_cand IS the bucket of the n_cand-th estimate — no O(n_cand)
    # selection), and the histogram is reused by the collection.
    cb = rb.build_codebook(sample, k=min(n_cand, sample.shape[0]), m=m)
    bucket_ids = rb.bucketize(cb, flat_est)
    hist = rb.histogram(bucket_ids, m, flat_valid)
    tau_scan, _ = rb.threshold_bucket(hist, n_cand)
    plan = rerank.EarlyRerankPlan(tau_pred=tau_scan, cb=cb)

    # Early re-rank: per-cluster inline exact for predicted survivors.
    early_budget = int(min(cap, max(128, round(n_cand / n_probe * early_slack))))
    early_budget = ((early_budget + 127) // 128) * 128
    early_budget = min(early_budget, cap)

    positions = jnp.arange(n_total, dtype=jnp.int32)
    flat_pos_matrix = positions.reshape(n_probe, cap)

    def per_cluster(c_est, c_ids, c_valid, row_pos):
        """Inline exact distances for predicted survivors of one cluster tile
        (Alg. 4 lines 9-11: the vectors are 'hot' — on TPU, the fused kernel
        streams them in the same VMEM tile as the codes)."""
        pred = rerank.early_rerank_mask(plan, c_est) & c_valid
        pos, ok = rb.compact_mask(pred, early_budget)
        safe = jnp.minimum(pos, cap - 1)
        e_ids = jnp.where(ok, c_ids[safe], -1)
        e_d = jnp.where(ok, _exact_dists(index.vectors, e_ids, q), INF)
        tgt = jnp.where(ok, row_pos[safe], n_total)  # flat scatter targets
        return e_d, tgt, jnp.sum(ok)

    e_d, e_tgt, e_counts = jax.vmap(per_cluster)(est, ids, valid, flat_pos_matrix)
    n_early = jnp.sum(e_counts)
    flat_e_d = jnp.full((n_total + 1,), INF, est.dtype)
    flat_e_d = flat_e_d.at[e_tgt.reshape(-1)].set(e_d.reshape(-1), mode="drop")
    flat_e_d = flat_e_d[:n_total]

    # n_cand selection by estimate with the bucket collector (Alg. 1 Collect).
    _, sel_pos = rb.collect(
        plan.cb, flat_est, positions, bucket_ids, n_cand, flat_valid,
        hist=hist)
    sel_ids = flat_ids[jnp.maximum(sel_pos, 0)]
    sel_ids = jnp.where(sel_pos >= 0, sel_ids, -1)

    # Inline results cover most of the selection; one small second pass for
    # the stragglers (n_second_pass ~ the paper's Table-2 cache-miss story).
    have = jnp.isfinite(flat_e_d[jnp.maximum(sel_pos, 0)]) & (sel_pos >= 0)
    miss = ~have & (sel_ids >= 0)
    second = jnp.sum(miss)
    miss_d = _exact_dists(index.vectors, jnp.where(miss, sel_ids, 0), q)
    ex = jnp.where(have, flat_e_d[jnp.maximum(sel_pos, 0)],
                   jnp.where(miss, miss_d, INF))

    neg, order = jax.lax.top_k(-ex, k)
    return SearchResult(-neg, sel_ids[order],
                        (n_early + second).astype(jnp.int32),
                        second.astype(jnp.int32))


# --------------------------------------------------------------------------
# IVF + RaBitQ (bounded): estimate+bounds -> rerank
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "use_bbc", "m", "eps0"),
)
def ivf_rabitq_search(
    index: RabitqIndex,
    q: jax.Array,
    k: int,
    n_probe: int,
    use_bbc: bool = False,
    m: int = 128,
    eps0: float = 3.0,
) -> SearchResult:
    """IVF+RaBitQ baseline (per-cluster threshold re-rank) and +BBC (Alg. 3
    closed-form greedy on two result buffers)."""
    ivf = index.ivf
    probed = ivf_mod.route(ivf, q, n_probe)
    ids, valid = ivf_mod.gather_candidates(ivf, probed)
    n_probe_, cap = ids.shape
    rq = index.rq

    def est_cluster(cid, c_ids, c_valid):
        qf = rq_mod.query_factors(rq, q, ivf.centroids[cid])
        c = rq.codes[jnp.maximum(c_ids, 0)]
        no = rq.norm_o[jnp.maximum(c_ids, 0)]
        fo = rq.f_o[jnp.maximum(c_ids, 0)]
        est, lb, ub = rq_mod.estimate(c, no, fo, qf, eps0)
        bad = ~c_valid
        return (jnp.where(bad, INF, est), jnp.where(bad, INF, lb),
                jnp.where(bad, INF, ub))

    est, lb, ub = jax.vmap(est_cluster)(probed, ids, valid)

    if not use_bbc:
        # ---- baseline: per-cluster threshold re-ranking -------------------
        budget = min(cap, _rerank_budget(k, cap))

        def step(carry, xs):
            pool_d, pool_i, n_rr = carry
            c_lb, c_ids, c_valid = xs
            thresh = pool_d[k - 1]
            mask = c_valid & (c_lb < thresh)
            pos, ok = rb.compact_mask(mask, budget)
            safe = jnp.minimum(pos, cap - 1)
            r_ids = jnp.where(ok, c_ids[safe], -1)
            r_d = _exact_dists(index.vectors, r_ids, q)
            r_d = jnp.where(ok, r_d, INF)
            alld = jnp.concatenate([pool_d, r_d])
            alli = jnp.concatenate([pool_i, r_ids])
            neg, idx = jax.lax.top_k(-alld, k)
            return (-neg, alli[idx], n_rr + jnp.sum(ok)), None

        pool0 = (jnp.full((k,), INF, est.dtype), jnp.full((k,), -1, jnp.int32),
                 jnp.int32(0))
        (pd, pi, n_rr), _ = jax.lax.scan(step, pool0, (lb, ids, valid))
        order = jnp.argsort(pd)
        return SearchResult(pd[order], pi[order], n_rr, n_rr)

    # ---- BBC path (Alg. 3, two-phase greedy) -------------------------------
    flat_lb, flat_ub = lb.reshape(-1), ub.reshape(-1)
    flat_est = est.reshape(-1)
    flat_ids, flat_valid = ids.reshape(-1), valid.reshape(-1)
    n_flat = flat_ids.shape[0]
    plan = rerank.greedy_rerank_plan(flat_lb, flat_ub, k, flat_valid, m=m)

    exact_flat = jnp.full((n_flat,), INF, est.dtype)

    def eval_mask(mask, budget, exact_flat):
        """Exact distances for up to ``budget`` masked lanes (est-priority)."""
        key_est = jnp.where(mask, flat_est, INF)
        _, pos = jax.lax.top_k(-key_est, budget)
        ok = jnp.isfinite(key_est[pos])
        safe = jnp.minimum(pos, n_flat - 1)
        r_ids = jnp.where(ok, flat_ids[safe], -1)
        r_d = jnp.where(ok, _exact_dists(index.vectors, r_ids, q), INF)
        exact_flat = exact_flat.at[jnp.where(ok, safe, n_flat)].set(
            r_d, mode="drop")
        return exact_flat, r_d, jnp.sum(ok)

    # Phase 1: likely-in items (ub at/below the k-th-ub bucket).  Their exact
    # distances tighten the threshold, as in the paper's iterative loop.
    p1 = rerank.phase1_mask(plan)
    budget1 = min(n_flat, ((k + 1024 + 127) // 128) * 128)
    exact_flat, p1_d, n1 = eval_mask(p1, budget1, exact_flat)
    t2 = rerank.phase2_threshold(plan, p1_d, k)

    # Phase 2: remaining uncertain items whose lower bound is under the
    # tightened threshold (anything above is certainly out).
    p2 = plan.rerank_mask & ~p1 & jnp.isinf(exact_flat) & (flat_lb <= t2)
    budget2 = min(n_flat, _rerank_budget(k, cap))
    exact_flat, _, n2 = eval_mask(p2, budget2, exact_flat)

    res = rerank.greedy_rerank_finalize(
        plan, exact_flat, jnp.where(flat_valid, flat_lb, INF), flat_ids, k,
        est=flat_est)
    n_evals = (n1 + n2).astype(jnp.int32)
    return SearchResult(res.topk_dists, res.topk_ids, n_evals, n_evals)


# --------------------------------------------------------------------------
# Natively batched searchers (shared candidate stream + batched kernels)
# --------------------------------------------------------------------------

def _exact_dists_rows(vectors: jax.Array, ids: jax.Array,
                      qs: jax.Array) -> jax.Array:
    """Per-query exact distances for (B, w) id rows.  Sequential map keeps
    the (w, d) gather per query (the batched-gather alternative materializes
    (B, w, d)); each row uses the same formula as ``_exact_dists`` so values
    match the single-query path."""
    return jax.lax.map(lambda a: _exact_dists(vectors, a[0], a[1]), (ids, qs))


def _routing(ivf: ivf_mod.IVFIndex, layout: ivf_mod.FlatLayout,
             qs: jax.Array, n_probe: int):
    """Shared batch routing: probed clusters, per-query lane masks over the
    flat stream, and the (B, C) squared query-centroid distances (for
    estimators that need them, e.g. RaBitQ's norm_q)."""
    probed, d2 = ivf_mod.route_batch_d2(ivf, qs, n_probe)
    lane_valid = ivf_mod.probe_mask(layout, probed, ivf.n_clusters)
    return probed, lane_valid, d2


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "use_bbc", "m", "backend", "pred_count"))
def ivf_search_batch(
    index: ivf_mod.IVFIndex,
    vectors: jax.Array,
    qs: jax.Array,                 # (B, d)
    layout: ivf_mod.FlatLayout,
    k: int,
    n_probe: int,
    use_bbc: bool = False,
    m: int = 128,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
    live: jax.Array | None = None,
) -> SearchResult:
    """Batched IVF (exact distances in-scan): one shared vector-stream gather,
    one (B, n_flat) distance matmul, per-query bucket collection.

    With ``pred_state`` the selection runs predictively (survivors under
    max(tau_pred, tau_true) instead of a histogram-driven collect) and the
    call returns ``(SearchResult, new_state)``; distances are exact in-scan,
    so the result is identical to the static path for ANY prediction.

    ``live`` is an optional (n_flat,) stream-ordered tombstone mask
    (streaming-ingest deletes): dead lanes are ANDed out of the per-query
    probe masks, so every downstream consumer — distances, histograms, the
    collection — sees them exactly like unprobed lanes.  The value is
    traced (not static): flipping tombstones never recompiles.
    """
    probed, lane_valid, _ = _routing(index, layout, qs, n_probe)
    if live is not None:
        lane_valid = lane_valid & live[None, :]
    stream_vecs = vectors[layout.order]                       # shared gather
    dists = ops.l2_exact_batch(stream_vecs, qs, backend=backend)
    dists = jnp.where(lane_valid, dists, INF)
    n = jnp.sum(lane_valid, axis=1).astype(jnp.int32)
    if pred_state is not None:
        if not use_bbc:
            raise ValueError("predictive search requires use_bbc=True")
        # distances are exact in-scan, so the pool target is k itself
        count = max(pred_count, k) if pred_count is not None else k
        st = min(4, n_probe)
        cbs = _sample_codebooks(layout, probed, dists, st, index.cap, k, m)
        bucket, hist = ops.bucket_hist_batch(
            dists, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
            backend=backend)
        tau_pred = rerank.predict_tau(pred_state, count)
        budget = _pred_budget(count, layout.n_flat)
        sel_d, sel_pos, sel_ok, _ = _predictive_select(
            dists, bucket, hist, lane_valid, tau_pred, count, budget,
            layout.order)
        ids = jnp.where(sel_ok, layout.order[sel_pos], -1)
        res = SearchResult(sel_d[:, :k], ids[:, :k], n, jnp.zeros_like(n))
        return res, rerank.predictor_update(pred_state, hist)
    if use_bbc and ops.resolve_backend(backend) == "pallas":
        # Kernel path: O(m) histogram collection (bucket_hist kernel) + one
        # (k + slack)-wide selection.
        st = min(4, n_probe)
        spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], index.cap)
        sample = jnp.where(sok, jnp.take_along_axis(dists, spos, axis=1), INF)
        d, i = col.bbc_collect_batch(dists, layout.order, lane_valid, k, m=m,
                                     sample=sample, sample_valid=sok,
                                     backend=backend)
    else:
        # CPU fallback: XLA's flat top_k beats scatter-based compaction at
        # these widths; the selected set is identical (bucketize is monotone
        # in distance, so the bucket collection selects the exact top-k set).
        d, i = col.topk_collect_batch(dists, layout.order, lane_valid, k)
    return SearchResult(d, i, n, jnp.zeros_like(n))


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "n_cand", "use_bbc", "m", "backend",
                     "fused", "pred_count"),
)
def ivf_pq_search_batch(
    index: PQIndex,
    qs: jax.Array,                 # (B, d)
    layout: ivf_mod.FlatLayout,
    k: int,
    n_probe: int,
    n_cand: int,
    use_bbc: bool = False,
    m: int = 128,
    backend: str | None = None,
    fused: bool | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
    live: jax.Array | None = None,
) -> SearchResult:
    """Batched IVF+PQ (±BBC).

    The candidate stream (codes, and vectors for the fused path) is gathered
    once per batch; ADC runs for every query against the shared stream; the
    n_cand selection is the batched bucket collection.  With ``fused=True``
    (default on TPU) the whole estimate+bucketize+hist+early-exact pass is
    ``ops.fused_scan_batch`` — Alg. 4's early re-ranking happens while the
    vector tile is VMEM-resident and the second gather pass covers only the
    stragglers.  With ``fused=False`` (default on CPU, where there is no
    fusion win to collect) exact distances are computed once for the final
    selection; results are identical, only the ``n_second_pass`` accounting
    differs.

    With ``pred_state`` the blunt n_cand cut is replaced by the predictive
    early-exact pool: exact distances are spent on the ~pred_count candidates
    under max(tau_pred, tau_true) instead of all n_cand, tau_pred comes from
    the cross-batch EMA, and the call returns ``(SearchResult, new_state)``.
    """
    if fused is None:
        fused = ops.on_tpu()
    ivf = index.ivf
    b = qs.shape[0]
    probed, lane_valid, _ = _routing(ivf, layout, qs, n_probe)
    if live is not None:
        # tombstoned lanes (streaming-ingest deletes) behave exactly like
        # unprobed lanes from here on: masked out of estimates, histograms,
        # and the collection alike
        lane_valid = lane_valid & live[None, :]
    stream_codes = index.codes[layout.order]                  # shared gather
    luts = jax.vmap(lambda q: pq_mod.adc_table(index.pq, q))(qs)

    if pred_state is not None:
        if not use_bbc:
            raise ValueError("predictive search requires use_bbc=True")
        return _ivf_pq_predictive_batch(
            index, qs, layout, probed, lane_valid, stream_codes, luts, k,
            n_probe, n_cand, m, backend, fused, pred_state, pred_count)

    dense_rerank = 4 * n_cand >= layout.n_flat

    if not use_bbc:
        est2 = ops.pq_adc_batch(stream_codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        sel_est, sel_pos = jax.lax.top_k(-est, n_cand)
        ci = jnp.where(jnp.isfinite(sel_est), layout.order[sel_pos], -1)
        if dense_rerank:
            stream_vecs = index.vectors[layout.order]
            exact_all = ops.l2_exact_batch(stream_vecs, qs, backend=backend)
            ex = jnp.take_along_axis(exact_all, sel_pos, axis=1)
        else:
            ex = _exact_dists_rows(index.vectors, ci, qs)
        ex = jnp.where(ci >= 0, ex, INF)
        neg, order = jax.lax.top_k(-ex, k)
        counts = jnp.full((b,), n_cand, jnp.int32)
        return SearchResult(-neg, jnp.take_along_axis(ci, order, axis=1),
                            counts, counts)

    # ---- BBC path (Alg. 4, batched) ---------------------------------------
    n_flat = layout.n_flat
    if fused:
        # Kernel path: per-query codebooks + tau_pred from the nearest-tile
        # sample prefix, then ONE fused pass (est+bucketize+hist+early-exact)
        # over the shared stream; selection via the histogram; second gather
        # pass only for selected-but-not-predicted stragglers.
        st = min(4, n_probe)
        sample_est = _pq_sample_est(layout, probed, stream_codes, luts, st,
                                    ivf.cap)
        n_total = n_probe * ivf.cap
        plans = jax.vmap(
            lambda s: rerank.early_rerank_plan(
                s, n_cand=n_cand, n_sample=s.shape[0], n_total=n_total, m=m)
        )(sample_est)

        stream_vecs = index.vectors[layout.order]
        est, bucket, hist, early, nmiss = ops.fused_scan_batch(
            stream_codes, stream_vecs, lane_valid, luts, qs,
            plans.cb.d_min, plans.cb.delta, plans.cb.ew_map, m,
            plans.tau_pred, backend=backend)
        est = jnp.where(lane_valid, est, INF)
        positions = jnp.arange(n_flat, dtype=jnp.int32)
        _, sel_pos = col.collect_batch(est, positions, lane_valid, bucket,
                                       hist, n_cand, m)
        safe_pos = jnp.maximum(sel_pos, 0)
        sel_ids = jnp.where(sel_pos >= 0, layout.order[safe_pos], -1)
        e_at_sel = jnp.take_along_axis(early, safe_pos, axis=1)
        have = jnp.isfinite(e_at_sel) & (sel_pos >= 0)
        n_early = (jnp.sum(lane_valid, axis=1) - nmiss).astype(jnp.int32)
    else:
        # CPU fallback: there is no VMEM-residency win to collect inline, so
        # skip the prediction machinery and select the exact top-n_cand by
        # estimate with one batched top_k (same set the bucket collection
        # yields — bucketize is monotone in the estimate; boundary ties
        # break by global id to match the sharded re-cut), then one exact
        # pass over the selection.
        est2 = ops.pq_adc_batch(stream_codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        sel_est, sel_pos = _topk_est_id(est, layout.order, n_cand)
        sel_ids = jnp.where(jnp.isfinite(-sel_est), layout.order[sel_pos], -1)
        e_at_sel = jnp.full(sel_pos.shape, INF, est.dtype)
        have = jnp.zeros(sel_pos.shape, bool)
        n_early = jnp.zeros((b,), jnp.int32)

    miss = ~have & (sel_ids >= 0)
    if fused:
        # stragglers only — keep the targeted per-row gather
        miss_d = _exact_dists_rows(index.vectors,
                                   jnp.where(miss, sel_ids, 0), qs)
    elif dense_rerank:
        # the whole selection misses (no inline pass on CPU): one shared
        # matmul over the stream beats n_cand per-row gathers
        stream_vecs = index.vectors[layout.order]
        exact_all = ops.l2_exact_batch(stream_vecs, qs, backend=backend)
        miss_d = jnp.take_along_axis(exact_all, jnp.maximum(sel_pos, 0),
                                     axis=1)
    else:
        miss_d = _exact_dists_rows(index.vectors,
                                   jnp.where(miss, sel_ids, 0), qs)
    ex = jnp.where(have, e_at_sel, jnp.where(miss, miss_d, INF))
    second = jnp.sum(miss, axis=1).astype(jnp.int32)

    neg, order = jax.lax.top_k(-ex, k)
    return SearchResult(-neg, jnp.take_along_axis(sel_ids, order, axis=1),
                        n_early + second, second)


def _ivf_pq_predictive_batch(index, qs, layout, probed, lane_valid,
                             stream_codes, luts, k, n_probe, n_cand, m,
                             backend, fused, pred_state, pred_count):
    """Predictive early-exact IVF+PQ (the tau_pred subsystem's PQ core).

    The re-rank pool is {bucket <= max(tau_pred, tau_true-at-pred_count)}
    instead of the top-n_cand-by-estimate cut: with a warm predictor that is
    ~pred_count candidates (default ~2k) instead of n_cand (default 8k).  On
    the fused path lanes under tau_pred were exacted inline during the scan;
    the fallback pass re-ranks only survivors the prediction missed.  The
    per-query codebooks are built exactly like the static fused path's, so
    bucket indices stay comparable batch-to-batch for the EMA.
    """
    ivf = index.ivf
    b = qs.shape[0]
    n_flat = layout.n_flat
    count = _resolve_pred_count(pred_count, k, n_cand)
    st = min(4, n_probe)
    sample_est = _pq_sample_est(layout, probed, stream_codes, luts, st,
                                ivf.cap)
    k_cb = min(n_cand, sample_est.shape[1])
    cbs = jax.vmap(lambda s: rb.build_codebook(s, k=k_cb, m=m))(sample_est)
    tau_pred = rerank.predict_tau(pred_state, count)

    if fused:
        stream_vecs = index.vectors[layout.order]
        est, bucket, hist, early, nmiss = ops.fused_scan_batch(
            stream_codes, stream_vecs, lane_valid, luts, qs,
            cbs.d_min, cbs.delta, cbs.ew_map, m,
            jnp.full((b,), tau_pred, jnp.int32), backend=backend)
        est = jnp.where(lane_valid, est, INF)
        n_early = (jnp.sum(lane_valid, axis=1) - nmiss).astype(jnp.int32)
    else:
        # CPU: no VMEM-residency win to collect inline — the whole pool goes
        # through the (much smaller than n_cand) fallback gather instead.
        est2 = ops.pq_adc_batch(stream_codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        bucket, hist = ops.bucket_hist_batch(
            est, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
            backend=backend)
        early = None
        n_early = jnp.zeros((b,), jnp.int32)

    # Survivors form an est-prefix (bucketize is monotone), so est-priority
    # truncation at a budget <= n_cand keeps the pool a SUBSET of the static
    # n_cand-by-estimate cut: the predictive result can only match or shrink
    # the static selection, never pull in ids the static path couldn't see.
    budget = min(_pred_budget(count, n_flat), n_cand)
    _, sel_pos, sel_ok, tau_true = _predictive_select(
        est, bucket, hist, lane_valid, tau_pred, count, budget, layout.order)
    sel_ids = jnp.where(sel_ok, layout.order[sel_pos], -1)

    # Fallback pass (undershoot correctness): survivors not covered inline —
    # the fallback-plan mask at the selected positions.  On the unfused path
    # nothing was computed inline, so the whole selection is fallback work.
    if early is not None:
        e_at_sel = jnp.take_along_axis(early, sel_pos, axis=1)
        fb = rerank.predicted_fallback_mask(
            bucket, lane_valid, jnp.full((b,), tau_pred, jnp.int32), tau_true)
        miss = jnp.take_along_axis(fb, sel_pos, axis=1) & sel_ok
        have = sel_ok & ~miss
    else:
        e_at_sel = jnp.full(sel_pos.shape, INF, est.dtype)
        have = jnp.zeros(sel_pos.shape, bool)
        miss = sel_ok
    if not fused and 4 * budget >= n_flat:
        # pool is a large fraction of the stream (large-k regime): one shared
        # matmul beats per-row gathers, as in the static dense_rerank path
        exact_all = ops.l2_exact_batch(index.vectors[layout.order], qs,
                                       backend=backend)
        miss_d = jnp.take_along_axis(exact_all, jnp.maximum(sel_pos, 0),
                                     axis=1)
    else:
        miss_d = _exact_dists_rows(index.vectors,
                                   jnp.where(miss, sel_ids, 0), qs)
    ex = jnp.where(have, e_at_sel, jnp.where(miss, miss_d, INF))
    second = jnp.sum(miss, axis=1).astype(jnp.int32)

    neg, order = jax.lax.top_k(-ex, k)
    res = SearchResult(-neg, jnp.take_along_axis(sel_ids, order, axis=1),
                       n_early + second, second)
    return res, rerank.predictor_update(pred_state, hist)


def _rabitq_batch_bounds(index: RabitqIndex, stream: RabitqStream,
                         qs: jax.Array, lane_valid: jax.Array, eps0: float,
                         d2: jax.Array):
    """Batched RaBitQ bounds over the single-device shared stream.  The
    stream-level estimator itself lives with the kernels
    (``kernels.ref.rabitq_bounds_stream`` — it is the inner math of the
    bound-fused kernel's mirror, shared by the mesh-sharded path)."""
    return kref.rabitq_bounds_stream(
        codes_s=stream.codes, norm_o=stream.norm_o, f_o=stream.f_o,
        cl=stream.cl, centroids=index.ivf.centroids, rot=index.rq.rot,
        qs=qs, d2=d2, lane_valid=lane_valid, eps0=eps0)


# --------------------------------------------------------------------------
# Bound-fused RaBitQ scan plumbing (the executed Table-2 path)
# --------------------------------------------------------------------------
#
# The fused RaBitQ searchers size their band from per-query SAMPLE-prefix
# codebooks (the paper's 5-10-nearest-cluster sample, like the PQ paths and
# the sharded deployment) instead of the full-stream upper-bound top-k the
# two-phase path sorts for: the band threshold tau_ub then comes from the
# scan's own histogram/bucket outputs, which is exact at bucket granularity
# — any lane excluded has lb beyond the bucket containing the k-th smallest
# ub, hence beyond Dist_k (certainly out) for ANY codebook.  The inline
# gate tau_inline only decides WHERE a band member's exact distance comes
# from (the fused scan vs the straggler gather), never whether it is
# evaluated, so correctness cannot ride on it.

_TAU_INLINE_MARGIN = 2   # buckets of slack on the static sample-derived gate
# Stride of the predictor's ub-histogram subsample: the EMA must track the
# FULL probed set's upper-bound distribution (the nearest-tile sample prefix
# is distance-skewed and lands systematically low at depth — the same effect
# bench_tau_pred documents for PQ prefix ranks), but the full scatter
# histogram is the CPU bottleneck.  A strided slice of the cluster-ordered
# stream is an unbiased (roughly cluster-stratified) subsample; predict_tau
# is queried at the stride-scaled count.
_PRED_HIST_STRIDE = 8
# Predictive-gate margin: per-query band thresholds scatter a few buckets
# around the EMA's global prediction; overshooting certifies extra lanes for
# free (their exact distances ride the resident tile) while every
# undershot bucket is real second-gather traffic, so the gate leans high.
_PRED_GATE_MARGIN = 3


def _tau_bucket_search(bucket: jax.Array, valid: jax.Array, count: int,
                       m: int) -> jax.Array:
    """First bucket whose cumulative in-range count reaches ``count`` —
    exactly ``rb.threshold_bucket`` of the bucket histogram, computed by
    bisection over row-wise compare-sums.  On CPU the (m+1)-bin scatter
    histogram is the stream-scale bottleneck (~5x the cost of the bounds
    matmul); ceil(log2(m+2)) masked compare-sums replace it.  Rows are
    independent, so callers stack several searches (e.g. both bounds) into
    one call.  Returns m (overflow id) when fewer than ``count`` in-range
    lanes exist, matching ``threshold_bucket``."""
    rows = bucket.shape[0]
    # fold validity and the overflow bucket into one effective array so the
    # bisection body is a single compare + reduce per step
    eff = jnp.where(valid & (bucket < m), bucket, m)
    lo = jnp.zeros((rows,), jnp.int32)
    hi = jnp.full((rows,), m, jnp.int32)
    for _ in range((m + 1).bit_length()):
        mid = (lo + hi) // 2
        cnt = jnp.sum(eff <= mid[:, None], axis=1)
        ok = cnt >= count
        hi = jnp.where(ok, mid, hi)
        lo = jnp.where(ok, lo, mid + 1)
    return hi


def _rabitq_inline_rank(k: int, st: int, n_probe: int, k_cb: int) -> int:
    """Sample-prefix rank of the k-th upper bound (Alg. 4 line 4's
    |sample|/|O| scaling with the static tile ratio st/n_probe)."""
    return max(1, min(k_cb, round(k * st / max(n_probe, 1))))


def _rabitq_sample_plan(sample_ub: jax.Array, k: int, count: int, st: int,
                        n_probe: int, m: int):
    """Per-query codebook + static inline gate from the sample-prefix upper
    bounds.  One top-k serves both: the codebook quantiles (anchored at k,
    like the two-phase plan's ub top-k) and the rank-scaled ``count``-th-ub
    seed whose bucket (+ margin) is the static ``tau_inline``."""
    k_cb = min(k, sample_ub.shape[1])
    topk_s = -jax.lax.top_k(-sample_ub, k_cb)[0]              # (B, k_cb) asc
    cbs = jax.vmap(lambda t: rb.build_codebook_from_topk(t, m=m))(topk_s)
    rank = _rabitq_inline_rank(count, st, n_probe, k_cb)
    kth_s = topk_s[:, rank - 1]
    tau_static = jax.vmap(lambda c, v: rb.bucketize(c, v[None])[0])(cbs,
                                                                    kth_s)
    tau_static = jnp.minimum(tau_static + _TAU_INLINE_MARGIN, m - 1)
    return cbs, tau_static.astype(jnp.int32)


def _rabitq_sample_ub(codes, norm_o, f_o, cl, centroids, rot,
                      layout: ivf_mod.FlatLayout, probed: jax.Array,
                      qs: jax.Array, d2: jax.Array, st: int, cap: int,
                      eps0: float):
    """Sample-prefix upper bounds for the kernel paths: a small dedicated
    bounds pass over the nearest ``st`` probed tiles, run BEFORE the fused
    kernel (which needs the codebook as an input).  Stream-level arrays in,
    so the batched path (the engine's ``RabitqStream``) and each shard's
    local stream share the one implementation; the composed CPU path
    instead samples the full bounds it has already computed."""
    spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], cap)

    def one(a):
        pos, okr, q, d2q = a
        safe = jnp.where(okr, pos, 0)
        _, _, ubq = kref.rabitq_bounds_stream(
            codes[safe].astype(jnp.float32), norm_o[safe], f_o[safe],
            cl[safe], centroids, rot, q[None], d2q[None], okr[None], eps0)
        return ubq[0]

    sample_ub = jax.lax.map(one, (spos, sok, qs, d2))
    return sample_ub, sok


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_probe", "use_bbc", "m", "eps0", "backend",
                     "fused", "pred_count"))
def ivf_rabitq_search_batch(
    index: RabitqIndex,
    qs: jax.Array,                 # (B, d)
    layout: ivf_mod.FlatLayout,
    k: int,
    n_probe: int,
    use_bbc: bool = False,
    m: int = 128,
    eps0: float = 3.0,
    backend: str | None = None,
    fused: bool | None = None,
    stream: RabitqStream | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
    live: jax.Array | None = None,
) -> SearchResult:
    """Batched IVF+RaBitQ (±BBC) on the shared candidate stream.

    ``stream`` is the layout-ordered ``RabitqStream`` (pass the engine's
    build-time copy to skip the per-call gathers; built on the fly when
    None, e.g. for direct test calls).

    The BBC path runs the bound-fused scan by default (``fused=None`` ->
    True): per stream tile the scan computes estimates AND bounds,
    bucketizes them against the sample-prefix codebook, and exact-re-ranks
    lanes whose lower-bound bucket the inline gate certifies while the
    vector tile is resident — on TPU inside ``ops.fused_rabitq_scan_batch``
    (codes and vectors co-tiled in VMEM), on CPU as the composed
    restructure of the same math (one shared exact matmul; the win there is
    the planning — sample codebooks + bisected threshold buckets replace
    the two full-stream top-k sorts of the two-phase path).  Only
    bound-uncertain stragglers (band members the gate missed) take a second
    gather pass, and ``n_second_pass`` is their MEASURED count — the
    executed form of the Table-2 cache-miss story PR 3 only modeled.
    ``fused=False`` keeps the two-phase reference path (full-stream
    ub-top-k plan + one dense band matmul; its predictive counters are the
    modeled volume the fused path's measured counts are benchmarked
    against in ``bench_rabitq_fused``).

    With ``pred_state``: the bounds already make the band minimal, so
    prediction cannot shrink the re-rank count (the paper's RaBitQ gain is
    cache misses, not re-ranks); instead the engine's EMA ``tau_pred``
    gates the inline band exactly as it gates the PQ pool — while cold
    (tau_pred = -1) nothing is certified and the whole band goes through
    the gather, exactly like the two-phase path.  Returns
    ``(SearchResult, new_state)``; on this deployment the EMA tracks a
    strided-subsample upper-bound histogram and is queried at the
    stride-scaled count (``_PRED_HIST_STRIDE``) — the sharded deployment
    tracks the psum'd full histogram at k; states are engine-owned and
    never cross deployments.  Results are id-set identical to the
    two-phase path for any gate (the band always covers the bound-straddle
    set).
    """
    if pred_state is not None and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    if fused is None:
        fused = True
    if stream is None:
        stream = rabitq_stream(index, layout)
    ivf = index.ivf
    b = qs.shape[0]
    cap = ivf.cap
    probed, lane_valid, d2 = _routing(ivf, layout, qs, n_probe)
    if live is not None:
        # tombstones ride the lane-mask mechanism: every downstream
        # consumer (bounds, band, histogram, collection) already honors it
        lane_valid = lane_valid & live[None, :]
    n_flat = layout.n_flat
    stream_ids = layout.order

    if use_bbc and fused:
        return _ivf_rabitq_fused_batch(
            index, stream, qs, layout, probed, lane_valid, d2, k, n_probe,
            m, eps0, backend, pred_state, pred_count)

    est, lb, ub = _rabitq_batch_bounds(index, stream, qs, lane_valid, eps0,
                                       d2=d2)

    if not use_bbc:
        # ---- baseline: per-cluster threshold re-ranking, vmapped ----------
        tpos, tok = ivf_mod.tile_positions(layout, probed, cap)
        lb_t = jnp.where(tok, jnp.take_along_axis(lb, tpos, axis=1), INF)
        ids_t = jnp.where(tok, stream_ids[tpos], -1)
        lb_t = lb_t.reshape(b, n_probe, cap)
        ids_t = ids_t.reshape(b, n_probe, cap)
        ok_t = tok.reshape(b, n_probe, cap)
        budget = min(cap, _rerank_budget(k, cap))

        def one_query(args):
            c_lb, c_ids, c_ok, q = args

            def step(carry, xs):
                pool_d, pool_i, n_rr = carry
                t_lb, t_ids, t_ok = xs
                thresh = pool_d[k - 1]
                mask = t_ok & (t_lb < thresh)
                pos, okc = rb.compact_mask(mask, budget)
                safe = jnp.minimum(pos, cap - 1)
                r_ids = jnp.where(okc, t_ids[safe], -1)
                r_d = _exact_dists(index.vectors, r_ids, q)
                r_d = jnp.where(okc, r_d, INF)
                alld = jnp.concatenate([pool_d, r_d])
                alli = jnp.concatenate([pool_i, r_ids])
                neg, idx = jax.lax.top_k(-alld, k)
                return (-neg, alli[idx], n_rr + jnp.sum(okc)), None

            pool0 = (jnp.full((k,), INF, lb.dtype),
                     jnp.full((k,), -1, jnp.int32), jnp.int32(0))
            (pd, pi, n_rr), _ = jax.lax.scan(step, pool0,
                                             (c_lb, c_ids, c_ok))
            order = jnp.argsort(pd)
            return pd[order], pi[order], n_rr

        pd, pi, n_rr = jax.lax.map(one_query, (lb_t, ids_t, ok_t, qs))
        return SearchResult(pd, pi, n_rr.astype(jnp.int32),
                            n_rr.astype(jnp.int32))

    # ---- two-phase BBC reference path (Alg. 3, batched greedy) -------------
    # Plan from the full-stream ub top-k (order-statistic thresholds), then
    # resolve the whole uncertain band in ONE shared exact-distance matmul
    # over the stream — the separate estimate-then-gather structure whose
    # second-pass traffic the fused path eliminates.  Kept as the reference
    # contender (``fused=False``): bench_rabitq_fused measures the fused
    # path against it, and its predictive counters are the MODELED
    # second-pass volume the fused path's measured counts must reproduce.
    plan = rerank.greedy_rerank_plan_batch(lb, ub, k, lane_valid, m=m)
    exact_all = ops.l2_exact_batch(stream.vectors, qs, backend=backend)
    exact_flat = jnp.where(plan.rerank_mask, exact_all, INF)

    res = jax.vmap(
        lambda p, ef, lbv, e: rerank.greedy_rerank_finalize(
            p, ef, lbv, stream_ids, k, est=e)
    )(plan, exact_flat, jnp.where(lane_valid, lb, INF), est)
    n_evals = jnp.sum(plan.rerank_mask, axis=1).astype(jnp.int32)
    if pred_state is not None:
        # inline coverage: band members predicted by the cross-batch tau; the
        # fallback (second-pass gather) shrinks to the unpredicted remainder
        count = max(pred_count, k) if pred_count is not None else k
        tau_pred = rerank.predict_tau(pred_state, count)
        covered = plan.rerank_mask & (plan.a_lb <= tau_pred)
        n_second = jnp.sum(plan.rerank_mask & ~covered,
                           axis=1).astype(jnp.int32)
        hist_ub = jax.vmap(rb.histogram, in_axes=(0, None, 0))(
            plan.a_ub, m, lane_valid)
        res_p = SearchResult(res.topk_dists, res.topk_ids, n_evals, n_second)
        return res_p, rerank.predictor_update(pred_state, hist_ub)
    return SearchResult(res.topk_dists, res.topk_ids, n_evals, n_evals)


def _ivf_rabitq_fused_batch(index, stream, qs, layout, probed, lane_valid,
                            d2, k, n_probe, m, eps0, backend, pred_state,
                            pred_count):
    """Bound-fused RaBitQ batch core (the executed Table-2 path).

    One logical pass over the stream: estimates + bounds + bucketization +
    the inline exact re-rank of gate-certified lanes, then a straggler-only
    second gather for band members the gate missed.  The band itself is
    exact at bucket granularity for any codebook (tau_ub comes from the
    scan's own ub histogram at k), so the id set matches the two-phase path
    — the gate moves memory traffic, never correctness.
    """
    ivf = index.ivf
    rq = index.rq
    b = qs.shape[0]
    n_flat = layout.n_flat
    kernel = ops.resolve_backend(backend) == "pallas"
    st = min(4, n_probe)
    count = k if pred_count is None else max(pred_count, k)

    est = lb = ub = None
    if kernel:
        sample_ub, sok = _rabitq_sample_ub(
            stream.codes, stream.norm_o, stream.f_o, stream.cl,
            ivf.centroids, index.rq.rot, layout, probed, qs, d2, st,
            ivf.cap, eps0)
    else:
        est, lb, ub = _rabitq_batch_bounds(index, stream, qs, lane_valid,
                                           eps0, d2=d2)
        spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], ivf.cap)
        sample_ub = jnp.where(sok, jnp.take_along_axis(ub, spos, axis=1),
                              INF)
    cbs, tau_static = _rabitq_sample_plan(sample_ub, k, count, st, n_probe,
                                          m)
    if pred_state is not None:
        # the EMA gate, exactly as it gates the PQ pool: -1 while cold
        # (nothing certified inline — the first batch behaves like the
        # two-phase path), the predicted bucket once warm.  The EMA tracks
        # the strided-subsample ub histogram, so the query count scales by
        # the stride.
        count_s = max(1, -(-count // _PRED_HIST_STRIDE))
        # margin biased up: an overshooting gate certifies a few extra
        # lanes (free — their exact distances ride the resident tile), an
        # undershooting one pays real second-pass gathers
        tau_inline = jnp.full(
            (b,), rerank.predict_tau(pred_state, count_s,
                                     margin=_PRED_GATE_MARGIN),
            jnp.int32)
    else:
        tau_inline = tau_static

    if kernel:
        # the fused kernel: codes + vectors co-tiled through VMEM, exact
        # distances of certified lanes computed while the tile is resident
        (est, lb, ub, bucket_lb, bucket_ub, _hist_lb, hist_ub, exact_c,
         certified, _nmiss) = ops.fused_rabitq_scan_batch(
            stream.codes, stream.vectors, stream.norm_o, stream.f_o,
            stream.cl, ivf.centroids, rq.rot, qs, d2, lane_valid,
            cbs.d_min, cbs.delta, cbs.ew_map, m, tau_inline, eps0=eps0,
            backend=backend)
        tau_ub = jax.vmap(rb.threshold_bucket, in_axes=(0, None))(
            hist_ub, k)[0]
        tau_lb = jax.vmap(rb.threshold_bucket, in_axes=(0, None))(
            _hist_lb, k)[0]
    else:
        # composed CPU form of the same math: the scatter histograms the
        # kernel accumulates for free are replaced by bisected threshold
        # buckets (identical values), and the certified mask is applied to
        # one shared exact matmul — no gather/fusion axis exists on CPU,
        # so the restructured planning IS the speedup
        bucket_lb = jax.vmap(rb.bucketize)(cbs, lb)
        bucket_ub = jax.vmap(rb.bucketize)(cbs, ub)
        taus = _tau_bucket_search(
            jnp.concatenate([bucket_ub, bucket_lb], axis=0),
            jnp.concatenate([lane_valid, lane_valid], axis=0), k, m)
        tau_ub, tau_lb = taus[:b], taus[b:]
        if pred_state is None:
            # the stream-parallel CPU form has the full scan before the
            # re-rank leg, so the static gate refreshes to the true band
            # threshold (Alg. 4 line 14 at full progress — the same
            # refresh the single-query PQ path documents); the predictive
            # gate stays exactly tau_pred so the measured straggler count
            # is the EMA's miss, comparable with the modeled volume
            tau_inline = jnp.maximum(tau_inline, tau_ub)
        certified = lane_valid & (bucket_lb <= tau_inline[:, None])

    certain_in = lane_valid & (bucket_ub < tau_lb[:, None])
    band = lane_valid & (bucket_lb <= tau_ub[:, None]) & ~certain_in
    straggler = band & ~certified
    n_second = jnp.sum(straggler, axis=1).astype(jnp.int32)
    n_evals = jnp.sum(band, axis=1).astype(jnp.int32)

    if kernel:
        # straggler-only second gather (the measured residue of Table 2):
        # lb-priority compaction into a static budget, per-row exact, with
        # a dense fallback should the gate miss more than the budget (a
        # cold/undershooting predictor) — correctness never rides on it
        budget = int(min(n_flat, ((max(2 * k, 2048) + 127) // 128) * 128))
        key_lb = jnp.where(straggler, lb, INF)
        neg, pos = jax.lax.top_k(-key_lb, budget)
        okp = jnp.isfinite(-neg)
        sids = jnp.where(okp, layout.order[pos], -1)
        sd = _exact_dists_rows(index.vectors, jnp.where(okp, sids, 0), qs)
        filled = jnp.full((b, n_flat + 1), INF, sd.dtype)
        filled = jax.vmap(
            lambda f, p, v, o: f.at[jnp.where(o, p, n_flat)].set(v))(
                filled, pos, sd, okp)[:, :n_flat]
        exact_band = jnp.where(certified, exact_c, filled)

        def dense(_):
            allx = ops.l2_exact_batch(stream.vectors, qs, backend=backend)
            return jnp.where(certified, exact_c, allx)

        overflow = jnp.any(n_second > budget)
        exact_band = jax.lax.cond(overflow, dense,
                                  lambda _: exact_band, None)
        exact_band = jnp.where(band, exact_band, INF)
    else:
        # one shared matmul serves the inline AND straggler legs (single
        # float source: cold/warm/static variants stay bitwise identical);
        # the counter is still the straggler-lane count of the executed
        # certified gate — on TPU those lanes are the literal second gather
        exact_all = ops.l2_exact_batch(stream.vectors, qs, backend=backend)
        exact_band = jnp.where(band, exact_all, INF)

    plan = rerank.GreedyRerankPlan(
        rerank_mask=band, certain_in=certain_in,
        certain_out=lane_valid & ~band & ~certain_in,
        tau_ub=tau_ub, tau_lb=tau_lb, a_lb=bucket_lb, a_ub=bucket_ub)
    res = jax.vmap(
        lambda p, ef, lbv, e: rerank.greedy_rerank_finalize(
            p, ef, lbv, layout.order, k, est=e)
    )(plan, exact_band, lb, est)
    out = SearchResult(res.topk_dists, res.topk_ids, n_evals, n_second)
    if pred_state is not None:
        # EMA over the strided-subsample ub histogram: unbiased for the
        # full probed set (see _PRED_HIST_STRIDE) at 1/stride of the
        # scatter cost; bucket indices stay comparable batch-to-batch
        # because the codebooks are equal-depth over samples of the same
        # distribution
        hist_s = jax.vmap(rb.histogram, in_axes=(0, None, 0))(
            bucket_ub[:, ::_PRED_HIST_STRIDE], m,
            lane_valid[:, ::_PRED_HIST_STRIDE])
        return out, rerank.predictor_update(pred_state, hist_s)
    return out


# --------------------------------------------------------------------------
# Mesh-sharded searchers (corpus row-sharded over the mesh's 'model' axis)
# --------------------------------------------------------------------------
#
# The corpus stream is partitioned by ``ivf.sharded_layout`` (round-robin
# within each cluster) and the per-shard stream tensors (vectors / PQ codes /
# RaBitQ codes) are materialized offline with a leading shard axis, so under
# ``shard_map`` each chip scans ONLY its own rows.  One search step per batch:
#
#   1. replicated routing matmul (every chip computes the same probe sets),
#   2. per-shard fused scan over the local stream (the same ops.* kernels the
#      single-device batched path runs — a shard's stream is just shorter),
#   3. per-query local (m+1)-histograms; ``psum`` over 'model'
#      <- (m+1)*4 bytes per query, NOT k*8,
#   4. relaxed-threshold survivor compaction to a fixed per-shard budget
#      (~count/S * slack, key-priority),
#   5. exact re-rank of local survivors ON the shard that owns their rows
#      (the distributed analogue of Alg. 4's "compute exact while the vector
#      tile is hot": survivor vectors never cross the interconnect),
#   6. ``all_gather`` of survivors only, final replicated selection.
#
# ``use_bbc=False`` selects the naive distributed collector baseline: every
# shard maintains and gathers a full local top-k (k*8 bytes per shard on the
# wire), the quantity ``core.distributed.collective_cost_model`` prices.

SHARD_AXIS = "model"
HOST_AXIS = "host"


def _shard_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the corpus stream is sharded over.  A 2-D multi-host mesh
    (("host", "model")) selects the hierarchical collective schedule —
    intra-host reduce over 'model' first, then the inter-host round over
    'host' (see ``dist.hier_psum``); a flat 1-D mesh stays single-stage."""
    if HOST_AXIS in mesh.axis_names:
        return (HOST_AXIS, SHARD_AXIS)
    return (SHARD_AXIS,)


def _n_shards(mesh) -> int:
    n = 1
    for ax in _shard_axes(mesh):
        n *= mesh.shape[ax]
    return n


def _layout_spec(axes):
    return P(axes, None)        # every ShardedLayout leaf: (S, ...)


def _stream2_spec(axes):
    return P(axes, None)        # (S, F) stream scalars


def _stream3_spec(axes):
    return P(axes, None, None)  # (S, F, d) stream tensors


def _mesh_sizes(mesh, axes) -> tuple:
    """Static mesh axis sizes for ``dist.shard_rows`` call sites."""
    return tuple(int(mesh.shape[ax]) for ax in axes)


def _shard_budget(budget: int | None, count: int, mesh, shard_flat: int,
                  slack: float) -> int:
    if budget is None:
        budget = dist.survivor_budget(count, _n_shards(mesh), slack=slack)
    return max(8, min(budget, shard_flat))


def _local_block(sl: ivf_mod.ShardedLayout) -> ivf_mod.FlatLayout:
    """Inside a shard_map body the ShardedLayout arrives as a (1, ...) block;
    squeeze it into this shard's FlatLayout view."""
    return ivf_mod.FlatLayout(order=sl.order[0], cluster_of=sl.cluster_of[0],
                              offsets=sl.offsets[0], valid=sl.valid[0])


def _local_routing(centroids: jax.Array, qs: jax.Array, n_probe: int):
    """Replicated routing (identical on every shard): the same
    implementation the single-device path routes with, so probe sets match
    bit-for-bit."""
    return ivf_mod.route_batch_centroids(centroids, qs, n_probe)


def _exact_at_positions(svecs: jax.Array, qs: jax.Array, pos: jax.Array,
                        ok: jax.Array) -> jax.Array:
    """Per-query exact distances for (B, w) local stream positions (the
    budget-sized survivor sets; INF where not ok)."""

    def one(a):
        p, o, q = a
        v = svecs[jnp.where(o, p, 0)]
        d = jnp.sqrt(jnp.maximum(
            jnp.sum(v * v, -1) - 2.0 * (v @ q) + jnp.sum(q * q), 0.0))
        return jnp.where(o, d, INF)

    return jax.lax.map(one, (pos, ok, qs))


def _sharded_codebooks(layout: ivf_mod.FlatLayout, probed: jax.Array,
                       vals: jax.Array, st: int, cap_shard: int, k_cb: int,
                       m: int, axes=(SHARD_AXIS,), sizes=()):
    """Per-query codebooks from the nearest ``st`` probed clusters, gathered
    across shards.  Each shard contributes its slice of those clusters; the
    union is exactly their full membership, so the codebook sees the same
    sample population as the single-device batched path (order differs,
    which build_codebook's top-k absorbs).  The gather is small: st * cap
    lanes per query, the codebook-sample prefix only.  Returns
    ``(codebooks, sample)`` — the gathered sample doubles as the seed for
    the speculative compaction threshold (``_sample_spec_tau``)."""
    spos, sok = ivf_mod.tile_positions(layout, probed[:, :st], cap_shard)
    s_local = jnp.where(sok, jnp.take_along_axis(vals, spos, axis=1), INF)
    (sample,) = dist.gather_survivors(axes, s_local)
    k_cb = min(k_cb, sample.shape[1])

    # ONE ascending sort serves both consumers: the codebook prefix here
    # and the order-statistic threshold in _sample_spec_tau (which would
    # otherwise re-sort the same sample).  The sample is replicated after
    # the gather, so the sort + codebook build are row-split across the
    # shard axis instead of running S identical copies.
    def _sort_and_build(s):
        asc = jax.lax.sort(s, dimension=1)
        cbs = jax.vmap(lambda t: rb.build_codebook_from_topk(t, m=m))(
            asc[:, :k_cb])
        return cbs, asc

    return dist.shard_rows(axes, sizes, _sort_and_build, sample)


_SPEC_TAU_MARGIN = 2   # buckets of slack on the speculative threshold


def _sample_spec_tau(cbs, sample: jax.Array, count: int,
                     n_probed: jax.Array, m: int) -> jax.Array:
    """Sample-derived speculative compaction threshold for the fused
    shard-collect pass: the bucket of the rank-scaled ``count``-th smallest
    sample value (rank = count * |sample| / |probed|, Alg. 4 line 4's
    scaling), plus margin.  Overshoot is cheap — a few extra lanes in the
    budget buffer; undershoot costs the bounded correction pass — so the
    threshold leans high.  Returns m (compact the full in-range stream)
    when the scaled rank runs off the sample: that is the degenerate
    count >= n_probed regime, where the true tau is m as well.

    ``sample`` must be sorted ascending per query (``_sharded_codebooks``
    returns it that way — the sort is shared with the codebook build)."""
    ns = sample.shape[1]
    n_valid = jnp.sum(jnp.isfinite(sample), axis=1)
    frac = n_valid.astype(jnp.float32) / jnp.maximum(
        n_probed.astype(jnp.float32), 1.0)
    rank = jnp.ceil(count * frac).astype(jnp.int32)
    kth = jnp.take_along_axis(
        sample, jnp.clip(rank - 1, 0, ns - 1)[:, None], axis=1)[:, 0]
    tau = jax.vmap(lambda c, v: rb.bucketize(c, v[None])[0])(cbs, kth)
    tau = jnp.minimum(tau + _SPEC_TAU_MARGIN, m).astype(jnp.int32)
    return jnp.where(rank >= n_valid, m, tau)


def _kth_value_mask(vals: jax.Array, ids: jax.Array, kth: int) -> jax.Array:
    """Exact-width mask of the per-row ``kth`` smallest (value, global-id)
    pairs: every lane strictly below the kth-smallest value, plus the
    smallest-id lanes at the boundary value up to the remaining width.
    Global ids are unique, so the kept SET is a deterministic function of
    the (value, id) multiset — identical for the batched stream order and
    the sharded gathered-pool order.  PQ estimates tie exactly whenever two
    vectors share codes, and a tie-inclusive or pool-order-arbitrary cut
    diverges between the two deployments exactly there.  Bisection on int32
    bit patterns — monotone for the nonnegative-or-INF distances used here
    — so the cut costs ~62 compare-sum passes instead of a pool-wide
    ``top_k`` at ``kth`` ~ pool/2, the dominant replicated cost of the
    post-gather re-cut at large n_cand."""
    bits = jax.lax.bitcast_convert_type(vals, jnp.int32)
    rows = vals.shape[0]
    lo = jnp.zeros((rows,), jnp.int32)
    hi = jnp.full((rows,), jnp.int32(0x7F800000))   # +inf bit pattern
    for _ in range(31):
        mid = lo + (hi - lo) // 2
        cnt = jnp.sum(bits <= mid[:, None], axis=1)
        ok = cnt >= kth
        hi = jnp.where(ok, mid, hi)
        lo = jnp.where(ok, lo, mid + 1)
    below = bits < hi[:, None]
    tied = bits == hi[:, None]
    rem = (kth - jnp.sum(below, axis=1)).astype(jnp.int32)
    # Boundary ties: keep the ``rem`` smallest global ids among the tied
    # lanes.  Padding lanes (id -1) map to int32 max, so they lose every
    # tie-break against a real lane; they only tie at +inf, where keeping
    # them is harmless (masked to (INF, -1) downstream either way).
    eid = jnp.broadcast_to(ids, vals.shape) & jnp.int32(0x7FFFFFFF)
    tlo = jnp.zeros((rows,), jnp.int32)
    thi = jnp.full((rows,), jnp.int32(0x7FFFFFFF))
    for _ in range(31):
        mid = tlo + (thi - tlo) // 2
        cnt = jnp.sum(tied & (eid <= mid[:, None]), axis=1)
        ok = cnt >= rem
        thi = jnp.where(ok, mid, thi)
        tlo = jnp.where(ok, tlo, mid + 1)
    return below | (tied & (eid <= thi[:, None]))


def _topk_est_id(est: jax.Array, gids: jax.Array, width: int):
    """Top-``width``-smallest selection over ``est`` with boundary-value
    ties broken by smallest global id — the batched counterpart of the
    sharded paths' ``_kth_value_mask`` re-cut, so both deployments keep the
    identical candidate SET when estimates tie at the cut (PQ estimates tie
    whenever two vectors share codes, which makes straddles routine, not
    rare).  The tie-free case pays exactly the plain ``top_k`` (no straddle
    means every boundary-tied lane is already selected, making the set
    tie-order independent); the cond-gated repair needs no value bisection
    — the plain ``top_k`` already yields the boundary value, and the id
    threshold among its tied lanes is one more ``top_k`` — so even
    straddling batches pay ~3 top_k passes, not a stream-wide bisection.
    Returns ``(neg_est, sel_pos)`` with ``jax.lax.top_k(-est, width)``
    semantics."""
    _, pos = jax.lax.top_k(-est, width)
    # XLA CPU's fast TopK rewrite only fires when the sorted VALUES output
    # feeds nothing but the slice; any second consumer (even the boundary
    # column) demotes the whole thing to a ~4x full sort.  So the values
    # output stays dead and the selection is re-gathered from ``est`` —
    # bit-identical, and a gather is free next to the sort it avoids.
    sel = jnp.take_along_axis(est, pos, axis=1)
    neg = -sel
    v = sel[:, -1:]                        # width-th smallest value per row
    bits = jax.lax.bitcast_convert_type(est, jnp.int32)
    vb = jax.lax.bitcast_convert_type(v, jnp.int32)
    tied = bits == vb
    tsel = sel == v                        # boundary columns in the selection
    rem = jnp.sum(tsel, axis=1)            # boundary-tied lanes selected
    straddle = jnp.any(jnp.isfinite(v[:, 0])
                       & (jnp.sum(tied, axis=1) > rem))
    # padding ids (-1) map to int32 max, losing every tie-break that
    # matters; they only tie at +inf, where keeping them is harmless
    eid = jnp.broadcast_to(gids, est.shape) & jnp.int32(0x7FFFFFFF)
    # Integer top_k is pathologically slow on CPU XLA (~20x the float
    # form), so the tie-breaks run on a float view of the ids: patterns
    # below 0x7F800000 bitcast to nonnegative floats whose ordering IS the
    # bit-pattern (= id) ordering.  The clamp collapses only padding (and
    # ids beyond ~2.13B, far past the int32 stream-key bound) onto the max
    # finite pattern — duplicates only at +inf boundaries, harmless.
    fid = jax.lax.bitcast_convert_type(
        jnp.minimum(eid, jnp.int32(0x7F7FFFFF)), jnp.float32)
    cap = min(width, 256)

    def _patch(_):
        # Tied lanes all carry the SAME est value, so only positions need
        # fixing: swap the plain top_k's arbitrary tied subset for the
        # rem smallest-id tied lanes.  One narrow top_k finds their stream
        # positions (ascending id), a rank-gather drops them into the
        # boundary columns; ``neg`` is already correct as-is.
        _, cand = jax.lax.top_k(jnp.where(tied, -fid, -INF), cap)
        rank = jnp.cumsum(tsel, axis=1) - 1
        patched = jnp.take_along_axis(cand, jnp.clip(rank, 0, cap - 1),
                                      axis=1)
        return neg, jnp.where(tsel, patched, pos)

    def _exact(_):
        # > cap boundary lanes selected in some row (pathological tie
        # plateau): fall back to the full-width threshold construction
        nfid, _ = jax.lax.top_k(jnp.where(tied, -fid, -INF), width)
        thr = jnp.take_along_axis(
            -nfid, jnp.maximum(rem - 1, 0)[:, None], axis=1)
        keep = (bits < vb) | (tied & (fid <= thr))
        rneg, rpos = jax.lax.top_k(jnp.where(keep, -est, -INF), width)
        return rneg, rpos

    def _repair(_):
        return jax.lax.cond(jnp.any(rem > cap), _exact, _patch, None)

    return jax.lax.cond(straddle, _repair, lambda _: (neg, pos), None)


def _naive_local_topk(vals: jax.Array, layout: ivf_mod.FlatLayout, k: int):
    """Naive distributed collector's local half: full top-k per shard."""
    kk = min(k, vals.shape[1])
    neg, pos = jax.lax.top_k(-vals, kk)
    ok = jnp.isfinite(-neg)
    gids = jnp.where(ok, layout.order[pos], -1)
    return pos, ok, gids


def _final_topk(gd: jax.Array, gi: jax.Array, k: int):
    """Replicated final selection over the gathered survivors."""
    neg, order = jax.lax.top_k(-gd, k)
    d = -neg
    i = jnp.where(jnp.isfinite(d), jnp.take_along_axis(gi, order, axis=1), -1)
    return d, i


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_probe", "use_bbc", "m", "cap_shard",
                     "budget", "backend", "pred_count"))
def ivf_search_sharded(
    mesh,
    qs: jax.Array,                   # (B, d) replicated
    centroids: jax.Array,            # (C, d) replicated
    slayout: ivf_mod.ShardedLayout,  # (S, ...) sharded over 'model'
    svecs: jax.Array,                # (S, F, d) sharded stream vectors
    k: int,
    n_probe: int,
    use_bbc: bool = True,
    m: int = 128,
    cap_shard: int = 1,
    budget: int | None = None,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
    slive: jax.Array | None = None,
) -> SearchResult:
    """Sharded batched IVF (exact distances in-scan).

    With ``pred_state`` the engine's predicted tau enters the survivor
    threshold as a floor (see ``dist.bbc_survivors_batch``) and the psum'd
    histogram feeds the EMA; returns ``(SearchResult, new_state)``.
    Distances are exact in-scan, so results match the static path exactly.

    ``slive`` is an optional (S, F) stream-ordered tombstone mask, sharded
    like the other stream scalars; each shard ANDs its block into the local
    probe masks (tombstoned lanes == unprobed lanes everywhere downstream).
    """
    predictive = pred_state is not None
    if predictive and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    has_live = slive is not None
    n_clusters = centroids.shape[0]
    shard_flat = svecs.shape[1]
    axes = _shard_axes(mesh)
    sizes = _mesh_sizes(mesh, axes)
    bud = _shard_budget(budget, k, mesh, shard_flat, slack=2.0)

    def body(qs, cent, sl, vecs, *extra):
        rest = list(extra)
        live = rest.pop(0)[0] if has_live else None     # (1, F) block -> (F,)
        tau_floor = rest.pop(0) if predictive else None
        layout = _local_block(sl)
        vecs = vecs[0]
        probed, _ = _local_routing(cent, qs, n_probe)
        lane_valid = ivf_mod.probe_mask(layout, probed, n_clusters)
        if live is not None:
            lane_valid = lane_valid & live[None, :]
        dists = ops.l2_exact_batch(vecs, qs, backend=backend)
        dv = jnp.where(lane_valid, dists, INF)
        n = dist.hier_psum(jnp.sum(lane_valid, axis=1), axes)
        ghist = None
        if use_bbc:
            st = min(4, n_probe)
            cbs, sample = _sharded_codebooks(layout, probed, dv, st,
                                             cap_shard, k, m, axes, sizes)
            tau_spec = _sample_spec_tau(cbs, sample, k, n, m)
            if tau_floor is not None:
                tau_spec = jnp.maximum(tau_spec, tau_floor)
            bucket, hist, spos, sok, scnt = ops.shard_collect_batch(
                dv, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
                tau_spec, bud, backend=backend)
            pos, ok, _, _, ghist = dist.bbc_survivors_batch(
                bucket, dv, lane_valid, hist, k, bud, axes,
                tau_floor=tau_floor, spec=(spos, sok, scnt, tau_spec))
            sd = jnp.where(ok, jnp.take_along_axis(dv, pos, axis=1), INF)
            gids = jnp.where(ok, layout.order[pos], -1)
        else:
            pos, ok, gids = _naive_local_topk(dv, layout, k)
            sd = jnp.where(ok, jnp.take_along_axis(dv, pos, axis=1), INF)
        gd, gi = dist.gather_survivors(axes, sd, gids)
        # the gathered pool is replicated: row-split the final selection
        d, i = dist.shard_rows(axes, sizes,
                               lambda a, b_: _final_topk(a, b_, k), gd, gi)
        if predictive:
            return d, i, n.astype(jnp.int32), ghist
        return d, i, n.astype(jnp.int32)

    args = [qs, centroids, slayout, svecs]
    in_specs = [P(), P(), _layout_spec(axes), _stream3_spec(axes)]
    if has_live:
        args.append(slive)
        in_specs.append(_stream2_spec(axes))
    out_specs = (P(), P(), P())
    if predictive:
        count = max(pred_count, k) if pred_count is not None else k
        args.append(rerank.predict_tau(pred_state, count))
        in_specs.append(P())
        fn = dist.shard_map(body, mesh, in_specs=tuple(in_specs),
                            out_specs=out_specs + (P(),))
        d, i, n, ghist = fn(*args)
        res = SearchResult(d, i, n, jnp.zeros_like(n))
        return res, rerank.predictor_update(pred_state, ghist)
    fn = dist.shard_map(body, mesh, in_specs=tuple(in_specs),
                        out_specs=out_specs)
    d, i, n = fn(*args)
    return SearchResult(d, i, n, jnp.zeros_like(n))


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_probe", "n_cand", "use_bbc", "m",
                     "cap_shard", "budget", "backend", "pred_count"))
def ivf_pq_search_sharded(
    mesh,
    qs: jax.Array,
    pq_cb: pq_mod.PQCodebook,        # replicated codebook
    centroids: jax.Array,
    slayout: ivf_mod.ShardedLayout,
    scodes: jax.Array,               # (S, F, M) sharded PQ codes
    svecs: jax.Array,                # (S, F, d) sharded re-rank vectors
    k: int,
    n_probe: int,
    n_cand: int,
    use_bbc: bool = True,
    m: int = 128,
    cap_shard: int = 1,
    budget: int | None = None,
    backend: str | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
    slive: jax.Array | None = None,
) -> SearchResult:
    """Sharded batched IVF+PQ.

    BBC path: the histogram collective runs at ``n_cand`` granularity (the
    selection the single-device path makes by estimate), survivors are
    exact-re-ranked on their owning shard, and the final replicated pass
    re-applies the top-``n_cand``-by-estimate cut before the top-k by exact
    distance — the same selection semantics as ``ivf_pq_search_batch``.
    Naive path: each shard maintains a full local top-k by estimate and
    gathers k (dist, id) pairs (plus its local exact re-rank).

    Predictive path (``pred_state``): the histogram collective runs at
    ``pred_count`` granularity with the engine's tau_pred as a floor, each
    shard exact-re-ranks only its ~pred_count/S survivors (instead of
    ~n_cand/S), and the blunt post-gather n_cand-by-estimate re-cut is gone —
    the survivor pool IS the selection, matching the predictive batched
    path's semantics.  Returns ``(SearchResult, new_state)``.

    ``slive``: optional (S, F) sharded tombstone mask (see
    ``ivf_search_sharded``).
    """
    predictive = pred_state is not None
    if predictive and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    has_live = slive is not None
    n_clusters = centroids.shape[0]
    shard_flat = svecs.shape[1]
    axes = _shard_axes(mesh)
    sizes = _mesh_sizes(mesh, axes)
    count = _resolve_pred_count(pred_count, k, n_cand) if predictive \
        else n_cand
    bud = _shard_budget(budget, count, mesh, shard_flat, slack=2.0)

    def body(qs, cb, cent, sl, codes, vecs, *extra):
        rest = list(extra)
        live = rest.pop(0)[0] if has_live else None
        tau_floor = rest.pop(0) if predictive else None
        layout = _local_block(sl)
        codes, vecs = codes[0], vecs[0]
        probed, _ = _local_routing(cent, qs, n_probe)
        lane_valid = ivf_mod.probe_mask(layout, probed, n_clusters)
        if live is not None:
            lane_valid = lane_valid & live[None, :]
        luts = jax.vmap(lambda q: pq_mod.adc_table(cb, q))(qs)
        est2 = ops.pq_adc_batch(codes, luts, backend=backend)
        est = jnp.where(lane_valid, jnp.sqrt(jnp.maximum(est2, 0.0)), INF)
        ghist = None
        if use_bbc:
            st = min(4, n_probe)
            cbs, sample = _sharded_codebooks(layout, probed, est, st,
                                             cap_shard, n_cand, m, axes,
                                             sizes)
            n_probed = dist.hier_psum(jnp.sum(lane_valid, axis=1), axes)
            tau_spec = _sample_spec_tau(cbs, sample, count, n_probed, m)
            if tau_floor is not None:
                tau_spec = jnp.maximum(tau_spec, tau_floor)
            bucket, hist, spos, sok, scnt = ops.shard_collect_batch(
                est, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
                tau_spec, bud, backend=backend)
            pos, ok, _, _, ghist = dist.bbc_survivors_batch(
                bucket, est, lane_valid, hist, count, bud, axes,
                tau_floor=tau_floor, spec=(spos, sok, scnt, tau_spec))
        else:
            pos, ok, _ = _naive_local_topk(est, layout, k)
        sel_est = jnp.where(ok, jnp.take_along_axis(est, pos, axis=1), INF)
        ex = _exact_at_positions(vecs, qs, pos, ok)
        gids = jnp.where(ok, layout.order[pos], -1)
        n_rr = dist.hier_psum(jnp.sum(ok, axis=1), axes)
        ge, gx, gi = dist.gather_survivors(axes, sel_est, ex, gids)
        if use_bbc:
            # Replicated selection alignment with the single-device batched
            # path.  Static: the blunt n_cand-by-estimate re-cut (the full
            # two-stage selection re-applied after the gather).  Predictive:
            # that re-cut is gone — the pool is already tau-thresholded at
            # pred_count granularity; only the SAME est-priority truncation
            # the batched predictive path applies (its static top_k width)
            # remains, so both deployments select the identical pool.
            # Either way the cut only bites when the gathered pool holds
            # MORE than ncs finite lanes; n_rr (the psum'd survivor count)
            # is replicated, so when every query's pool already fits the
            # cut is provably vacuous and skipped at run time.
            if predictive:
                ncs = min(_pred_budget(count, shard_flat * _n_shards(mesh)),
                          n_cand, ge.shape[1])
            else:
                ncs = min(n_cand, ge.shape[1])
            fit = jnp.all(n_rr <= ncs)

            # re-cut + final selection over the replicated gathered pool,
            # row-split across the shard axis (one slice+gather covers
            # both).  The re-cut is a value threshold at the ncs-th
            # smallest estimate with boundary ties broken by smallest
            # global id (see _kth_value_mask) — the exact SET the batched
            # path's tie-broken top_k keeps, so tied PQ estimates cannot
            # make the two deployments' pools diverge.  Lanes outside are
            # masked, widths unchanged, so both cond branches are
            # shape-identical without re-padding
            def _tail(ge, gx, gi):
                def _recut(_):
                    keep = _kth_value_mask(ge, gi, ncs)
                    return (jnp.where(keep, gx, INF),
                            jnp.where(keep, gi, -1))

                cx, ci = jax.lax.cond(fit, lambda _: (gx, gi), _recut, None)
                return _final_topk(cx, ci, k)

            d, i = dist.shard_rows(axes, sizes, _tail, ge, gx, gi)
        else:
            d, i = dist.shard_rows(axes, sizes,
                                   lambda a, b_: _final_topk(a, b_, k),
                                   gx, gi)
        if predictive:
            return d, i, n_rr.astype(jnp.int32), ghist
        return d, i, n_rr.astype(jnp.int32)

    args = [qs, pq_cb, centroids, slayout, scodes, svecs]
    in_specs = [P(), P(), P(), _layout_spec(axes), _stream3_spec(axes),
                _stream3_spec(axes)]
    if has_live:
        args.append(slive)
        in_specs.append(_stream2_spec(axes))
    out_specs = (P(), P(), P())
    if predictive:
        args.append(rerank.predict_tau(pred_state, count))
        in_specs.append(P())
        fn = dist.shard_map(body, mesh, in_specs=tuple(in_specs),
                            out_specs=out_specs + (P(),))
        d, i, n_rr, ghist = fn(*args)
        res = SearchResult(d, i, n_rr, jnp.zeros_like(n_rr))
        return res, rerank.predictor_update(pred_state, ghist)
    fn = dist.shard_map(body, mesh, in_specs=tuple(in_specs),
                        out_specs=out_specs)
    d, i, n_rr = fn(*args)
    return SearchResult(d, i, n_rr, jnp.zeros_like(n_rr))


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_probe", "use_bbc", "m", "eps0",
                     "cap_shard", "budget", "backend", "fused",
                     "pred_count"))
def ivf_rabitq_search_sharded(
    mesh,
    qs: jax.Array,
    rot: jax.Array,                  # (d, d) replicated rotation
    centroids: jax.Array,
    slayout: ivf_mod.ShardedLayout,
    scodes: jax.Array,               # (S, F, d) sharded ±1 codes
    snorm_o: jax.Array,              # (S, F)
    sf_o: jax.Array,                 # (S, F)
    svecs: jax.Array,                # (S, F, d) sharded re-rank vectors
    k: int,
    n_probe: int,
    use_bbc: bool = True,
    m: int = 128,
    eps0: float = 3.0,
    cap_shard: int = 1,
    budget: int | None = None,
    backend: str | None = None,
    fused: bool | None = None,
    pred_state: rerank.PredictorState | None = None,
    pred_count: int | None = None,
    slive: jax.Array | None = None,
) -> SearchResult:
    """Sharded batched IVF+RaBitQ.

    BBC path: the codebook is built from upper bounds, the histogram
    collective thresholds the UB distribution at k (tau_ub), and a lane
    survives iff its LOWER bound bucketizes at or below tau_ub — the
    distributed form of Alg. 3's certainly-out test (lb above the relaxed
    k-th-ub threshold means at least k objects are surely closer).  Survivors
    are exact-re-ranked on their shard; the gathered top-k by exact distance
    therefore equals the single-device result set.

    Bound-fused form (``fused=None`` -> True): each shard's scan certifies
    survivors whose lb-bucket sits at or below the inline gate — the
    sample-derived static tau, or the engine's ``tau_pred`` floor on the
    predictive path, exactly as on the batched deployment — and the
    on-shard second gather pass covers ONLY the straggler survivors the
    gate missed (on TPU the certified survivors' exact distances come out
    of the fused kernel; survivor values and the collective payload are
    unchanged).  ``n_second_pass`` is the psum'd measured straggler count.

    Predictive path (``pred_state``): the survivor band is bound-determined
    (already minimal), so prediction does not floor the survivor tau; the
    psum'd UB histogram feeds the engine's EMA (full-histogram convention,
    queried at max(pred_count, k) — k under the engine's RaBitQ default;
    unlike the batched deployment's strided-subsample EMA; states never
    cross deployments).  Returns ``(SearchResult, new_state)``; results
    are identical to the static path.
    """
    predictive = pred_state is not None
    if predictive and not use_bbc:
        raise ValueError("predictive search requires use_bbc=True")
    if fused is None:
        fused = True
    has_live = slive is not None
    n_clusters = centroids.shape[0]
    shard_flat = svecs.shape[1]
    axes = _shard_axes(mesh)
    sizes = _mesh_sizes(mesh, axes)
    bud = _shard_budget(budget, k, mesh, shard_flat, slack=4.0)
    count = k if pred_count is None else max(pred_count, k)
    kernelized = fused and ops.resolve_backend(backend) == "pallas"
    tau_p_val = rerank.predict_tau(pred_state, count) \
        if predictive and fused else None
    has_tau = tau_p_val is not None

    def body(qs, rot, cent, sl, codes, norm_o, f_o, vecs, *extra):
        rest = list(extra)
        live = rest.pop(0)[0] if has_live else None
        tau_p = rest.pop(0) if has_tau else None
        layout = _local_block(sl)
        codes, norm_o, f_o, vecs = codes[0], norm_o[0], f_o[0], vecs[0]
        b = qs.shape[0]
        probed, d2 = _local_routing(cent, qs, n_probe)
        lane_valid = ivf_mod.probe_mask(layout, probed, n_clusters)
        if live is not None:
            lane_valid = lane_valid & live[None, :]
        cl = jnp.minimum(layout.cluster_of, n_clusters - 1)
        ghist = None
        n_second = jnp.zeros((b,), jnp.int32)
        if not use_bbc:
            est, _, _ = kref.rabitq_bounds_stream(
                codes.astype(jnp.float32), norm_o, f_o, cl, cent, rot, qs,
                d2, lane_valid, eps0)
            pos, ok, _ = _naive_local_topk(est, layout, k)
            ex = _exact_at_positions(vecs, qs, pos, ok)
        else:
            st = min(4, n_probe)
            if kernelized:
                s_local, _ = _rabitq_sample_ub(codes, norm_o, f_o, cl,
                                               cent, rot, layout, probed,
                                               qs, d2, st, cap_shard, eps0)
            else:
                _, lb, ub = kref.rabitq_bounds_stream(
                    codes.astype(jnp.float32), norm_o, f_o, cl, cent, rot,
                    qs, d2, lane_valid, eps0)
                spos, sok_l = ivf_mod.tile_positions(layout,
                                                     probed[:, :st],
                                                     cap_shard)
                s_local = jnp.where(sok_l,
                                    jnp.take_along_axis(ub, spos, axis=1),
                                    INF)
            # gathered sample = the union of the nearest st clusters' full
            # membership, as on every sharded path; identical codebooks to
            # the pre-fused formulation (build_codebook = topk + from_topk)
            (sample,) = dist.gather_survivors(axes, s_local)
            cbs, tau_static = dist.shard_rows(
                axes, sizes,
                lambda s: _rabitq_sample_plan(s, k, count, st, n_probe, m),
                sample)
            tau_spec = tau_static
            if fused:
                tau_inline = jnp.full((b,), tau_p, jnp.int32) \
                    if tau_p is not None else tau_static
                tau_spec = jnp.maximum(tau_spec, tau_inline)
            if kernelized:
                (_, lb, _, bucket_lb, _, _, hist_ub, exact_c, certified,
                 _nm) = ops.fused_rabitq_scan_batch(
                    codes, vecs, norm_o, f_o, cl, cent, rot, qs, d2,
                    lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
                    tau_inline, eps0=eps0, backend=backend)
            else:
                bucket_lb = jax.vmap(rb.bucketize)(cbs, lb)
                _, hist_ub = ops.bucket_hist_batch(
                    ub, lane_valid, cbs.d_min, cbs.delta, cbs.ew_map, m,
                    backend=backend)
                if fused:
                    certified = lane_valid & \
                        (bucket_lb <= tau_inline[:, None])
            # speculative survivor compaction over the lb buckets (one
            # extra compact-only pass here — the lb/ub value split means
            # the histogram and the survivor test read different bound
            # streams, so the fully-fused collect applies to the other
            # methods only)
            spos, sok_b, scnt = ops.spec_compact_batch(
                bucket_lb, lane_valid, tau_spec, bud, backend=backend)
            pos, ok, _, _, ghist = dist.bbc_survivors_batch(
                bucket_lb, lb, lane_valid, hist_ub, k, bud, axes,
                spec=(spos, sok_b, scnt, tau_spec))
            if fused:
                cert_pos, strag = dist.split_certified_survivors(
                    pos, ok, certified)
                n_second = dist.hier_psum(
                    jnp.sum(strag, axis=1), axes).astype(jnp.int32)
                if kernelized:
                    # certified survivors: inline exacts from the fused
                    # kernel; the on-shard gather covers only stragglers
                    ex_in = jnp.take_along_axis(exact_c, pos, axis=1)
                    ex_st = _exact_at_positions(vecs, qs, pos, strag)
                    ex = jnp.where(cert_pos, ex_in,
                                   jnp.where(strag, ex_st, INF))
                else:
                    # CPU: one position-gather serves both legs (single
                    # float source keeps static/cold/warm variants
                    # bitwise identical); the counter is the executed
                    # gate's straggler-survivor count
                    ex = _exact_at_positions(vecs, qs, pos, ok)
            else:
                ex = _exact_at_positions(vecs, qs, pos, ok)
        gids = jnp.where(ok, layout.order[pos], -1)
        n_rr = dist.hier_psum(jnp.sum(ok, axis=1), axes)
        gx, gi = dist.gather_survivors(axes, ex, gids)
        d, i = dist.shard_rows(axes, sizes,
                               lambda a, b_: _final_topk(a, b_, k), gx, gi)
        if predictive:
            return d, i, n_rr.astype(jnp.int32), n_second, ghist
        return d, i, n_rr.astype(jnp.int32), n_second

    args = [qs, rot, centroids, slayout, scodes, snorm_o, sf_o, svecs]
    in_specs = [P(), P(), P(), _layout_spec(axes), _stream3_spec(axes),
                _stream2_spec(axes), _stream2_spec(axes),
                _stream3_spec(axes)]
    if has_live:
        args.append(slive)
        in_specs.append(_stream2_spec(axes))
    if has_tau:
        args.append(tau_p_val)
        in_specs.append(P())
    out_specs = (P(), P(), P(), P())
    if predictive:
        fn = dist.shard_map(body, mesh, in_specs=tuple(in_specs),
                            out_specs=out_specs + (P(),))
        d, i, n_rr, n_second, ghist = fn(*args)
        res = SearchResult(d, i, n_rr, n_second)
        return res, rerank.predictor_update(pred_state, ghist)
    fn = dist.shard_map(body, mesh, in_specs=tuple(in_specs),
                        out_specs=out_specs)
    d, i, n_rr, n_second = fn(*args)
    return SearchResult(d, i, n_rr, n_second)
