"""RaBitQ (bounded estimator): 1-bit codes with a probabilistic error bound.

Faithful implementation of the 1-bit RaBitQ estimator (Gao & Long, 2024):

  index time (per object o, cluster centroid c):
    r = o - c, norm_o = ||r||, unit ō = r / norm_o
    u = P ō                      (P: random orthonormal rotation)
    b = sign(u) in {-1,+1}^d     (the stored code; x̄ = b/√d)
    f_o = <x̄, u> = (1/√d) Σ|u_i|   (stored fp32 factor)

  query time (per probed cluster):
    q_r = q - c, norm_q = ||q_r||, v = P (q_r / norm_q)
    <x̄, v> = (1/√d) Σ b_i v_i      (code matmul — MXU-friendly)
    ip_est = <x̄, v> / f_o  ~ <ō, q̄>
    err    = eps0 * sqrt((1 - f_o^2) / (f_o^2 (d - 1)))   (w.h.p. bound)
    dist^2 = norm_q^2 + norm_o^2 - 2 norm_q norm_o <ō, q̄>
    lb/ub  from ip_est ± err.

eps0 is a z-score in our normalization (the estimator error divided by the
formula above is empirically ~N(0,1)); default eps0 = 3.0 gives ~99.7%
validity.  The original paper quotes eps0 = 1.9 under a different constant
convention for the same confidence regime.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RabitqCodes(NamedTuple):
    """RaBitQ sign codes with the rotation and per-vector correction factors.
    """
    rot: jax.Array      # (d, d) orthonormal
    codes: jax.Array    # (n, d) int8 in {-1, +1}
    norm_o: jax.Array   # (n,)
    f_o: jax.Array      # (n,)


def random_rotation(key: jax.Array, d: int) -> jax.Array:
    g = jax.random.normal(key, (d, d), jnp.float32)
    qmat, r = jnp.linalg.qr(g)
    # fix signs for a Haar-ish distribution
    return qmat * jnp.sign(jnp.diag(r))[None, :]


def encode(key: jax.Array, x: jax.Array, centroids: jax.Array,
           assignment: jax.Array) -> RabitqCodes:
    d = x.shape[1]
    rot = random_rotation(key, d)
    r = x - centroids[assignment]
    norm_o = jnp.linalg.norm(r, axis=1)
    unit = r / jnp.maximum(norm_o, 1e-12)[:, None]
    u = unit @ rot.T                      # P ō
    codes = jnp.where(u >= 0, 1, -1).astype(jnp.int8)
    f_o = jnp.sum(jnp.abs(u), axis=1) / jnp.sqrt(jnp.float32(d))
    return RabitqCodes(rot=rot, codes=codes, norm_o=norm_o,
                       f_o=jnp.maximum(f_o, 1e-6))


class QueryFactors(NamedTuple):
    """Per-query RaBitQ factors: rotated unit residual and its norm."""
    v: jax.Array        # (d,) rotated unit residual
    norm_q: jax.Array   # scalar


def query_factors(rq: RabitqCodes, q: jax.Array, centroid: jax.Array) -> QueryFactors:
    qr = q - centroid
    norm_q = jnp.linalg.norm(qr)
    v = (qr / jnp.maximum(norm_q, 1e-12)) @ rq.rot.T
    return QueryFactors(v=v, norm_q=norm_q)


def estimate(
    codes: jax.Array,    # (c, d) int8 codes of one cluster's members
    norm_o: jax.Array,   # (c,)
    f_o: jax.Array,      # (c,)
    qf: QueryFactors,
    eps0: float = 3.0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (est_dist, lb, ub) — actual distances (sqrt of the squared
    form), lower bound clamped at 0."""
    d = codes.shape[1]
    xv = (codes.astype(jnp.float32) @ qf.v) / jnp.sqrt(jnp.float32(d))  # <x̄,v>
    ip = xv / f_o
    err = eps0 * jnp.sqrt((1.0 - f_o ** 2) / (f_o ** 2 * (d - 1)))
    scale = 2.0 * qf.norm_q * norm_o
    base = qf.norm_q ** 2 + norm_o ** 2
    est2 = base - scale * ip
    lb2 = base - scale * (ip + err)
    ub2 = base - scale * (ip - err)
    zero = jnp.zeros_like(est2)
    return (
        jnp.sqrt(jnp.maximum(est2, zero)),
        jnp.sqrt(jnp.maximum(lb2, zero)),
        jnp.sqrt(jnp.maximum(ub2, zero)),
    )
