"""Brute-force exact search (BFC baseline + ground-truth generator)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k",))
def search(x: jax.Array, q: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Exact top-k by Euclidean distance for one query."""
    d2 = jnp.sum(x * x, axis=1) - 2.0 * (x @ q) + jnp.sum(q * q)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def search_batch(x: jax.Array, qs: jax.Array, k: int):
    return jax.vmap(lambda q: search(x, q, k))(qs)
