"""Product Quantization (unbounded estimator) — encode + ADC tables.

Paper settings: M = d/4 sub-vectors, B = 4 bits (16 centroids / subspace).
The ADC (asymmetric distance computation) table is (M, 2^B) per query; the
estimate for an object is sum_m LUT[m, code[m]].  kernels/pq_adc.py performs
the lookup as a one-hot matmul on the MXU (the FastScan analogue); this module
provides training/encoding and the jnp reference estimator.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.index import kmeans as km


class PQCodebook(NamedTuple):
    """Product-quantization codebook: per-subspace centroid tables."""
    centroids: jax.Array  # (M, 2^B, dsub)

    @property
    def n_sub(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_codes(self) -> int:
        return self.centroids.shape[1]

    @property
    def dsub(self) -> int:
        return self.centroids.shape[2]


def train(key: jax.Array, x: jax.Array, n_sub: int, n_bits: int = 4,
          n_iter: int = 10) -> PQCodebook:
    n, d = x.shape
    assert d % n_sub == 0, (d, n_sub)
    dsub = d // n_sub
    xs = x.reshape(n, n_sub, dsub)
    keys = jax.random.split(key, n_sub)
    cents = []
    for m in range(n_sub):  # offline; loop fine
        c, _ = km.kmeans(keys[m], xs[:, m, :], 2 ** n_bits, n_iter)
        cents.append(c)
    return PQCodebook(centroids=jnp.stack(cents))


@jax.jit
def encode(cb: PQCodebook, x: jax.Array) -> jax.Array:
    """(n, M) uint8 codes."""
    n, d = x.shape
    xs = x.reshape(n, cb.n_sub, cb.dsub)

    def enc_sub(xm, cm):  # (n, dsub), (K, dsub)
        d2 = (
            jnp.sum(xm * xm, -1, keepdims=True)
            + jnp.sum(cm * cm, -1)
            - 2.0 * xm @ cm.T
        )
        return jnp.argmin(d2, -1)

    codes = jax.vmap(enc_sub, in_axes=(1, 0), out_axes=1)(xs, cb.centroids)
    return codes.astype(jnp.uint8)


@jax.jit
def adc_table(cb: PQCodebook, q: jax.Array) -> jax.Array:
    """(M, 2^B) table of squared sub-distances for one query."""
    qs = q.reshape(cb.n_sub, 1, cb.dsub)
    return jnp.sum((qs - cb.centroids) ** 2, axis=-1)


def estimate(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Reference ADC estimate: sum_m LUT[m, code[m]] -> squared distance."""
    m = lut.shape[0]
    take = jax.vmap(lambda row, c: row[c], in_axes=(0, 1), out_axes=1)(
        lut, codes.astype(jnp.int32)
    )
    return jnp.sum(take, axis=1)
