"""Batched Lloyd k-means — the coarse quantizer for IVF and PQ codebooks.

Pure JAX, jit-compiled, k-means++-lite init (random distinct picks + one
refinement round), fixed iteration count (Faiss-style niter=10 default).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _pairwise_sq(x: jax.Array, c: jax.Array) -> jax.Array:
    """||x - c||^2 via the matmul identity (MXU-friendly)."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    return x2 + c2 - 2.0 * (x @ c.T)


def assign(x: jax.Array, centroids: jax.Array) -> jax.Array:
    return jnp.argmin(_pairwise_sq(x, centroids), axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_iter"))
def kmeans(
    key: jax.Array, x: jax.Array, n_clusters: int, n_iter: int = 10
) -> tuple[jax.Array, jax.Array]:
    """Returns (centroids (n_clusters, d), assignment (n,))."""
    n, d = x.shape
    idx = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent0 = x[idx]

    def step(cent, _):
        a = assign(x, cent)
        one = jax.nn.one_hot(a, n_clusters, dtype=x.dtype)      # (n, K)
        counts = jnp.sum(one, axis=0)                            # (K,)
        sums = one.T @ x                                         # (K, d)
        newc = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep empty clusters where they were
        newc = jnp.where(counts[:, None] > 0, newc, cent)
        return newc, None

    cent, _ = jax.lax.scan(step, cent0, None, length=n_iter)
    return cent, assign(x, cent)
