"""repro — BBC (bucket-based result collector) for large-k ANN, on JAX/TPU.

Layers (bottom-up): kernels (Pallas) -> index (IVF/PQ/RaBitQ) -> core (BBC)
-> models (assigned LM architectures) -> launch (mesh/dryrun/train/serve).
"""
__version__ = "1.0.0"
