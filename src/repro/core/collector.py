"""Top-k collectors over a stream of per-cluster candidate tiles.

These mirror the paper's Exp-3 contenders, re-expressed for a tiled/vectorized
runtime.  All collectors consume the same input layout — estimated distances
``(n_tiles, tile)`` with a validity mask and global ids — and return the exact
top-k (distances ascending, ids):

  * ``bbc``    — the paper's result buffer (Alg. 1): codebook from a sample
                 prefix, bucket histogram accumulated tile-by-tile with
                 relaxed-threshold masking, one final in-threshold-bucket
                 selection.  Cross-tile state: (m+1,) histogram.
  * ``topk``   — "Heap" analogue: running top-k carried across tiles
                 (concat + top_k per tile).  Cross-tile state: 2k floats+ints.
  * ``sorted`` — "Sorted" analogue: materialize everything, full sort, slice.
  * ``lazy``   — "Lazy" analogue: threshold-filtered append buffer, periodic
                 partial selection (x86simdsort::qselect analogue = top_k on
                 the buffer) when it fills.

The structural quantities that determine TPU cost (bytes of cross-tile state,
selection width) are exposed via ``collector_stats`` for the roofline story.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import buffer as rb
from repro.kernels import ops

INF = jnp.inf


class StreamInput(NamedTuple):
    """Tiled candidate stream: estimated distances, global ids, validity."""
    dists: jax.Array  # (n_tiles, tile) estimated distances
    ids: jax.Array    # (n_tiles, tile) int32 global ids
    valid: jax.Array  # (n_tiles, tile) bool


def _flatten(s: StreamInput) -> StreamInput:
    return StreamInput(*(x.reshape(-1) for x in s))


# --------------------------------------------------------------------------
# BBC collector (paper Alg. 1)
# --------------------------------------------------------------------------

def bbc_collect(
    s: StreamInput,
    k: int,
    m: int = 128,
    sample_tiles: int = 4,
    n_ew: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Result-buffer collection: O(m) cross-tile state + one final selection.

    Single-pass formulation: one vectorized bucketize over the whole stream,
    one histogram, one in-threshold-bucket selection — no serialized
    ``lax.scan`` and no per-tile selection (the cross-tile state is exactly
    the (m+1,) histogram, as in the paper; see bucket_hist.py for the kernel
    that materializes this pass on TPU).

    The codebook is built from the first ``sample_tiles`` tiles (paper: the
    5-10 nearest clusters — IVF scans clusters nearest-first, so the prefix is
    the distance-skewed sample the paper wants).
    """
    n_tiles, tile = s.dists.shape
    st = min(sample_tiles, n_tiles)
    sample = jnp.where(s.valid[:st], s.dists[:st], INF).reshape(-1)
    cb = rb.build_codebook(sample, k=min(k, sample.shape[0]), m=m, n_ew=n_ew)
    flat = _flatten(s)
    bucket_ids = rb.bucketize(cb, flat.dists)
    hist = rb.histogram(bucket_ids, m, flat.valid)
    return rb.collect(cb, flat.dists, flat.ids, bucket_ids, k, flat.valid,
                      hist=hist)


def bbc_collect_streamed(
    s: StreamInput,
    k: int,
    m: int = 128,
    sample_tiles: int = 4,
    n_ew: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Tile-serial variant of ``bbc_collect`` (the paper's CPU streaming
    formulation: per-tile threshold update + relaxed-threshold masking).
    Kept as an Exp-3 contender to quantify what the single-pass rewrite
    saves; results are identical."""
    n_tiles, tile = s.dists.shape
    st = min(sample_tiles, n_tiles)
    sample = jnp.where(s.valid[:st], s.dists[:st], INF).reshape(-1)
    cb = rb.build_codebook(sample, k=min(k, sample.shape[0]), m=m, n_ew=n_ew)

    def step(hist, xs):
        d, v = xs
        # Push (Alg. 1 lines 1-4): relaxed-threshold mask instead of append.
        tau, _ = rb.threshold_bucket(hist, k)          # Update, once per tile
        b = rb.bucketize(cb, d)
        accept = v & (b <= tau)
        hist = hist + rb.histogram(b, m, accept)
        return hist, None

    hist0 = jnp.zeros((m + 1,), jnp.int32)
    hist, _ = jax.lax.scan(step, hist0, (s.dists, s.valid))

    flat = _flatten(s)
    bucket_ids = rb.bucketize(cb, flat.dists)
    return rb.collect(cb, flat.dists, flat.ids, bucket_ids, k, flat.valid,
                      hist=None)


# --------------------------------------------------------------------------
# Baseline collectors (Exp-3 contenders)
# --------------------------------------------------------------------------

def topk_collect(s: StreamInput, k: int) -> tuple[jax.Array, jax.Array]:
    """Single-pass exact top-k: one flat selection over the whole stream.

    Replaces the tile-serial scan + per-tile (k + tile)-wide ``top_k`` on the
    search hot path; ``topk_collect_streamed`` keeps the old structure as the
    Exp-3 "Heap" contender."""
    flat = _flatten(s)
    d = jnp.where(flat.valid, flat.dists, INF)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, flat.ids[idx]


def topk_collect_streamed(s: StreamInput, k: int) -> tuple[jax.Array, jax.Array]:
    """"Heap" analogue: carry the running exact top-k across tiles."""

    def step(carry, xs):
        cd, ci = carry
        d, i, v = xs
        d = jnp.where(v, d, INF)
        alld = jnp.concatenate([cd, d])
        alli = jnp.concatenate([ci, i])
        neg, idx = jax.lax.top_k(-alld, k)
        return (-neg, alli[idx]), None

    carry0 = (jnp.full((k,), INF, s.dists.dtype), jnp.full((k,), -1, jnp.int32))
    (cd, ci), _ = jax.lax.scan(step, carry0, (s.dists, s.ids, s.valid))
    order = jnp.argsort(cd)
    return cd[order], ci[order]


def sorted_collect(s: StreamInput, k: int) -> tuple[jax.Array, jax.Array]:
    """"Sorted" analogue: full sort of every scanned candidate."""
    flat = _flatten(s)
    d = jnp.where(flat.valid, flat.dists, INF)
    order = jnp.argsort(d)[:k]
    return d[order], flat.ids[order]


def lazy_collect(
    s: StreamInput, k: int, buffer_factor: int = 2
) -> tuple[jax.Array, jax.Array]:
    """"Lazy" analogue: threshold filter into a linear buffer, periodic qselect.

    Carries a ``buffer_factor * k`` buffer; each tile appends candidates below
    the current threshold via cumsum compaction; when the buffer would
    overflow, a partial selection (top_k) shrinks it back to k and tightens
    the threshold.
    """
    n_tiles, tile = s.dists.shape
    # After a shrink the buffer holds k items; one tile of appends must always
    # fit, so cap >= k + tile.
    cap = max(buffer_factor * k, k + tile)

    def shrink(bd, bi):
        neg, idx = jax.lax.top_k(-bd, k)
        sd = jnp.concatenate([-neg, jnp.full((cap - k,), INF, bd.dtype)])
        si = jnp.concatenate([bi[idx], jnp.full((cap - k,), -1, jnp.int32)])
        return sd, si, sd[k - 1]

    def step(carry, xs):
        bd, bi, count, thresh = carry
        d, i, v = xs
        would = count + jnp.sum(v & (d < thresh))

        # If this tile would overflow the buffer, run the partial selection
        # first (tightens the threshold, shrinks the buffer back to k).
        def do_shrink(args):
            bd, bi, _ = args
            sd, si, th = shrink(bd, bi)
            return sd, si, jnp.int32(k), th

        def no_shrink(args):
            bd, bi, count = args
            return bd, bi, count, thresh

        bd, bi, count, thresh = jax.lax.cond(
            would > cap, do_shrink, no_shrink, (bd, bi, count)
        )
        keep = v & (d < thresh)
        pos = count + jnp.cumsum(keep.astype(jnp.int32)) - 1
        slot = jnp.where(keep & (pos < cap), pos, cap)  # cap = spill slot
        bd = bd.at[slot].set(d, mode="drop")
        bi = bi.at[slot].set(i, mode="drop")
        count = jnp.minimum(count + jnp.sum(keep), cap)
        return (bd, bi, count, thresh), None

    carry0 = (
        jnp.full((cap,), INF, s.dists.dtype),
        jnp.full((cap,), -1, jnp.int32),
        jnp.int32(0),
        jnp.array(INF, s.dists.dtype),
    )
    (bd, bi, _, _), _ = jax.lax.scan(step, carry0, (s.dists, s.ids, s.valid))
    neg, idx = jax.lax.top_k(-bd, k)
    return -neg, bi[idx]


# --------------------------------------------------------------------------
# Batched (multi-query) collectors
# --------------------------------------------------------------------------

def bbc_collect_batch(
    dists: jax.Array,        # (B, n) estimated distances
    ids: jax.Array,          # (n,) shared candidate ids
    valid: jax.Array,        # (B, n) per-query validity
    k: int,
    m: int = 128,
    sample: jax.Array | None = None,        # (B, w) codebook sample, or None
    sample_valid: jax.Array | None = None,  # (B, w)
    n_ew: int = 256,
    slack_buckets: int = 2,
    backend: str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Bucket collection for a query batch over a shared candidate stream.

    Per-query codebooks are built from ``sample`` (or the full masked row);
    bucketize + histogram run through the batched kernel path
    (``ops.bucket_hist_batch``), and the final in-threshold-bucket selection
    is one batched ``top_k`` over a (B, k + slack) compacted buffer.  The
    exactness escape hatch (overflow / fewer than k in-range) is a single
    batch-level ``lax.cond``, so the full-width selection compiles but only
    runs when some query actually overflows.
    """
    b, n = dists.shape
    if sample is None:
        sample, sample_valid = dists, valid
    k_cb = min(k, sample.shape[1])
    cbs = jax.vmap(
        lambda sd, sv: rb.build_codebook(sd, k=k_cb, m=m, n_ew=n_ew, valid=sv)
    )(sample, sample_valid)
    dv = jnp.where(valid, dists, INF)
    bucket, hist = ops.bucket_hist_batch(
        dv, valid, cbs.d_min, cbs.delta, cbs.ew_map, m, backend=backend)
    return collect_batch(dists, ids, valid, bucket, hist, k, m,
                         slack_buckets=slack_buckets)


def collect_batch(
    dists: jax.Array,    # (B, n)
    ids: jax.Array,      # (n,) shared candidate ids
    valid: jax.Array,    # (B, n)
    bucket: jax.Array,   # (B, n) bucket ids
    hist: jax.Array,     # (B, m+1)
    k: int,
    m: int,
    slack_buckets: int = 2,
) -> tuple[jax.Array, jax.Array]:
    """Batched Alg. 1 Collect over precomputed bucket ids + histograms."""
    b, n = dists.shape
    tau, _ = jax.vmap(rb.threshold_bucket, in_axes=(0, None))(hist, k)
    survive = valid & (bucket <= tau[:, None])
    budget = rb._collect_budget(k, n, slack_buckets, m)
    idx, ok = jax.vmap(rb.compact_mask, in_axes=(0, None))(survive, budget)
    safe = jnp.minimum(idx, n - 1)
    cd = jnp.where(ok, jnp.take_along_axis(dists, safe, axis=1), INF)
    ci = jnp.where(ok, ids[safe], -1)

    def fast(_):
        neg, order = jax.lax.top_k(-cd, k)
        return -neg, jnp.take_along_axis(ci, order, axis=1)

    def fallback(_):
        d = jnp.where(valid, dists, INF)
        neg, order = jax.lax.top_k(-d, k)
        return -neg, jnp.where(jnp.isfinite(-neg), ids[order], -1)

    overflowed = jnp.any((tau >= m) | (jnp.sum(survive, axis=1) > budget))
    return jax.lax.cond(overflowed, fallback, fast, None)


def topk_collect_batch(
    dists: jax.Array, ids: jax.Array, valid: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Batched flat top-k over the shared stream (heap-analogue baseline).

    Under-filled slots (fewer than k live lanes) come back as (+inf, -1),
    matching the padded-table single-query collectors."""
    d = jnp.where(valid, dists, INF)
    neg, order = jax.lax.top_k(-d, k)
    return -neg, jnp.where(jnp.isfinite(-neg), ids[order], -1)


COLLECTORS = {
    "bbc": bbc_collect,
    "bbc_streamed": bbc_collect_streamed,
    "topk": topk_collect_streamed,
    "topk_flat": topk_collect,
    "sorted": sorted_collect,
    "lazy": lazy_collect,
}


def collector_stats(name: str, k: int, m: int, n: int, tile: int) -> dict:
    """Structural cost model (bytes of cross-tile state / selection width).

    These are the quantities that determine TPU cost independently of the CPU
    wall-clock this container can measure.  ``topk`` models the streaming
    heap analogue (the paper's contender); ``topk_flat`` is the single-pass
    flat selection the search hot path uses when not collecting via buckets.
    """
    if name in ("bbc", "bbc_streamed"):
        return {
            "cross_tile_state_bytes": 4 * (m + 1),
            "final_selection_width": min(n, k + 2 * max(k // m, 1) + 64),
            "per_tile_select_width": 0,
        }
    if name == "topk":
        return {
            "cross_tile_state_bytes": 8 * k,
            "final_selection_width": k,
            "per_tile_select_width": k + tile,
        }
    if name == "topk_flat":
        return {
            "cross_tile_state_bytes": 8 * n,
            "final_selection_width": n,
            "per_tile_select_width": 0,
        }
    if name == "sorted":
        return {
            "cross_tile_state_bytes": 8 * n,
            "final_selection_width": n,
            "per_tile_select_width": 0,
        }
    if name == "lazy":
        return {
            "cross_tile_state_bytes": 8 * 2 * k,
            "final_selection_width": 2 * k,
            "per_tile_select_width": 2 * k,
        }
    raise ValueError(name)
