"""Top-k collectors over a stream of per-cluster candidate tiles.

These mirror the paper's Exp-3 contenders, re-expressed for a tiled/vectorized
runtime.  All collectors consume the same input layout — estimated distances
``(n_tiles, tile)`` with a validity mask and global ids — and return the exact
top-k (distances ascending, ids):

  * ``bbc``    — the paper's result buffer (Alg. 1): codebook from a sample
                 prefix, bucket histogram accumulated tile-by-tile with
                 relaxed-threshold masking, one final in-threshold-bucket
                 selection.  Cross-tile state: (m+1,) histogram.
  * ``topk``   — "Heap" analogue: running top-k carried across tiles
                 (concat + top_k per tile).  Cross-tile state: 2k floats+ints.
  * ``sorted`` — "Sorted" analogue: materialize everything, full sort, slice.
  * ``lazy``   — "Lazy" analogue: threshold-filtered append buffer, periodic
                 partial selection (x86simdsort::qselect analogue = top_k on
                 the buffer) when it fills.

The structural quantities that determine TPU cost (bytes of cross-tile state,
selection width) are exposed via ``collector_stats`` for the roofline story.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import buffer as rb

INF = jnp.inf


class StreamInput(NamedTuple):
    dists: jax.Array  # (n_tiles, tile) estimated distances
    ids: jax.Array    # (n_tiles, tile) int32 global ids
    valid: jax.Array  # (n_tiles, tile) bool


def _flatten(s: StreamInput) -> StreamInput:
    return StreamInput(*(x.reshape(-1) for x in s))


# --------------------------------------------------------------------------
# BBC collector (paper Alg. 1)
# --------------------------------------------------------------------------

def bbc_collect(
    s: StreamInput,
    k: int,
    m: int = 128,
    sample_tiles: int = 4,
    n_ew: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Result-buffer collection: O(m) cross-tile state + one final selection.

    The codebook is built from the first ``sample_tiles`` tiles (paper: the
    5-10 nearest clusters — IVF scans clusters nearest-first, so the prefix is
    the distance-skewed sample the paper wants).
    """
    n_tiles, tile = s.dists.shape
    st = min(sample_tiles, n_tiles)
    sample = jnp.where(s.valid[:st], s.dists[:st], INF).reshape(-1)
    cb = rb.build_codebook(sample, k=min(k, sample.shape[0]), m=m, n_ew=n_ew)

    def step(hist, xs):
        d, v = xs
        # Push (Alg. 1 lines 1-4): relaxed-threshold mask instead of append.
        tau, _ = rb.threshold_bucket(hist, k)          # Update, once per tile
        b = rb.bucketize(cb, d)
        accept = v & (b <= tau)
        hist = hist + rb.histogram(b, m, accept)
        return hist, None

    hist0 = jnp.zeros((m + 1,), jnp.int32)
    hist, _ = jax.lax.scan(step, hist0, (s.dists, s.valid))

    flat = _flatten(s)
    bucket_ids = rb.bucketize(cb, flat.dists)
    return rb.collect(cb, flat.dists, flat.ids, bucket_ids, k, flat.valid, hist=None)


# --------------------------------------------------------------------------
# Baseline collectors (Exp-3 contenders)
# --------------------------------------------------------------------------

def topk_collect(s: StreamInput, k: int) -> tuple[jax.Array, jax.Array]:
    """"Heap" analogue: carry the running exact top-k across tiles."""

    def step(carry, xs):
        cd, ci = carry
        d, i, v = xs
        d = jnp.where(v, d, INF)
        alld = jnp.concatenate([cd, d])
        alli = jnp.concatenate([ci, i])
        neg, idx = jax.lax.top_k(-alld, k)
        return (-neg, alli[idx]), None

    carry0 = (jnp.full((k,), INF, s.dists.dtype), jnp.full((k,), -1, jnp.int32))
    (cd, ci), _ = jax.lax.scan(step, carry0, (s.dists, s.ids, s.valid))
    order = jnp.argsort(cd)
    return cd[order], ci[order]


def sorted_collect(s: StreamInput, k: int) -> tuple[jax.Array, jax.Array]:
    """"Sorted" analogue: full sort of every scanned candidate."""
    flat = _flatten(s)
    d = jnp.where(flat.valid, flat.dists, INF)
    order = jnp.argsort(d)[:k]
    return d[order], flat.ids[order]


def lazy_collect(
    s: StreamInput, k: int, buffer_factor: int = 2
) -> tuple[jax.Array, jax.Array]:
    """"Lazy" analogue: threshold filter into a linear buffer, periodic qselect.

    Carries a ``buffer_factor * k`` buffer; each tile appends candidates below
    the current threshold via cumsum compaction; when the buffer would
    overflow, a partial selection (top_k) shrinks it back to k and tightens
    the threshold.
    """
    n_tiles, tile = s.dists.shape
    # After a shrink the buffer holds k items; one tile of appends must always
    # fit, so cap >= k + tile.
    cap = max(buffer_factor * k, k + tile)

    def shrink(bd, bi):
        neg, idx = jax.lax.top_k(-bd, k)
        sd = jnp.concatenate([-neg, jnp.full((cap - k,), INF, bd.dtype)])
        si = jnp.concatenate([bi[idx], jnp.full((cap - k,), -1, jnp.int32)])
        return sd, si, sd[k - 1]

    def step(carry, xs):
        bd, bi, count, thresh = carry
        d, i, v = xs
        would = count + jnp.sum(v & (d < thresh))

        # If this tile would overflow the buffer, run the partial selection
        # first (tightens the threshold, shrinks the buffer back to k).
        def do_shrink(args):
            bd, bi, _ = args
            sd, si, th = shrink(bd, bi)
            return sd, si, jnp.int32(k), th

        def no_shrink(args):
            bd, bi, count = args
            return bd, bi, count, thresh

        bd, bi, count, thresh = jax.lax.cond(
            would > cap, do_shrink, no_shrink, (bd, bi, count)
        )
        keep = v & (d < thresh)
        pos = count + jnp.cumsum(keep.astype(jnp.int32)) - 1
        slot = jnp.where(keep & (pos < cap), pos, cap)  # cap = spill slot
        bd = bd.at[slot].set(d, mode="drop")
        bi = bi.at[slot].set(i, mode="drop")
        count = jnp.minimum(count + jnp.sum(keep), cap)
        return (bd, bi, count, thresh), None

    carry0 = (
        jnp.full((cap,), INF, s.dists.dtype),
        jnp.full((cap,), -1, jnp.int32),
        jnp.int32(0),
        jnp.array(INF, s.dists.dtype),
    )
    (bd, bi, _, _), _ = jax.lax.scan(step, carry0, (s.dists, s.ids, s.valid))
    neg, idx = jax.lax.top_k(-bd, k)
    return -neg, bi[idx]


COLLECTORS = {
    "bbc": bbc_collect,
    "topk": topk_collect,
    "sorted": sorted_collect,
    "lazy": lazy_collect,
}


def collector_stats(name: str, k: int, m: int, n: int, tile: int) -> dict:
    """Structural cost model (bytes of cross-tile state / selection width).

    These are the quantities that determine TPU cost independently of the CPU
    wall-clock this container can measure.
    """
    if name == "bbc":
        return {
            "cross_tile_state_bytes": 4 * (m + 1),
            "final_selection_width": min(n, k + 2 * max(k // m, 1) + 64),
            "per_tile_select_width": 0,
        }
    if name == "topk":
        return {
            "cross_tile_state_bytes": 8 * k,
            "final_selection_width": k,
            "per_tile_select_width": k + tile,
        }
    if name == "sorted":
        return {
            "cross_tile_state_bytes": 8 * n,
            "final_selection_width": n,
            "per_tile_select_width": 0,
        }
    if name == "lazy":
        return {
            "cross_tile_state_bytes": 8 * 2 * k,
            "final_selection_width": 2 * k,
            "per_tile_select_width": 2 * k,
        }
    raise ValueError(name)
