"""Distributed BBC: shard_map search step over the production mesh.

This is the beyond-paper extension recorded in DESIGN.md §2/§4: the paper's
L1-resident bucket histogram becomes the *collective payload* of a sharded
search.  The corpus (codes + vectors) is sharded row-wise over the ``model``
axis; query batches are sharded over ``data`` (and replicated groups over
``pod``).  One search step per query:

  1. every chip scans its local shard -> local estimated distances,
  2. local (m+1)-histogram; ``psum`` over 'model'   <- m*4 bytes, NOT k*8,
  3. global threshold bucket tau from the summed histogram,
  4. local relaxed-threshold pruning + cumsum compaction to a fixed
     per-chip survivor budget  ~ k / n_shards * slack,
  5. ``all_gather`` of survivors only (~k total, vs n_scanned naively),
  6. final in-threshold-bucket selection (Alg. 1 Collect).

A naive distributed top-k instead all-gathers each chip's running top-k
(k * 8 bytes per chip).  ``collective_cost_model`` quantifies both for the
roofline table.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import buffer as rb

INF = jnp.inf


def shard_map(f, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (jax >= 0.6 exposes it at top level;
    0.4.x under ``jax.experimental``).  Replication checking is disabled:
    the search bodies end in ``psum``/``all_gather`` + replicated math, which
    the checker cannot always prove."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _axes_tuple(axis_name) -> tuple[str, ...]:
    return (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)


def hier_psum(x: jax.Array, axis_name) -> jax.Array:
    """Hierarchical all-reduce: psum over the stream-sharding axes one at a
    time, innermost (last) first.  On a 1-D ("model",) mesh this is a plain
    psum; on a 2-D ("host", "model") mesh it is the intra-host ICI reduce
    followed by an inter-host psum of the already-reduced per-host partial —
    so the DCN tier carries the same O(m) histogram payload as the ICI tier
    instead of S_model copies of it."""
    for ax in reversed(_axes_tuple(axis_name)):
        x = jax.lax.psum(x, ax)
    return x


def _gather_cols(r: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """all_gather along axis=1, innermost mesh axis first (intra-host
    concatenation, then the inter-host hop carries whole per-host blocks)."""
    for ax in reversed(axes):
        r = jax.lax.all_gather(r, ax, axis=1, tiled=True)
    return r


def _gather_rows(r: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """all_gather along axis=0, innermost mesh axis first — row order after
    reassembly matches the outer-major composite shard index."""
    for ax in reversed(axes):
        r = jax.lax.all_gather(r, ax, axis=0, tiled=True)
    return r


def shard_rows(axis_name, sizes: tuple, fn, *arrays: jax.Array):
    """Split a REPLICATED per-row computation over the shard axes.

    Inside a shard_map body, math after a gather/psum runs identically on
    every shard — S serialized copies on an emulated host mesh, S-1 idle
    chips on real hardware.  For row-independent ``fn`` (a per-query sort /
    top-k over replicated input), each shard instead computes only its
    contiguous slice of the rows and the slices are all_gathered back, so
    the work is done once, spread across the axis.  ``sizes`` are the mesh
    axis sizes matching ``axis_name`` (static, from the caller's mesh).
    Rows are padded to a multiple of the shard count by wrapping, then
    trimmed after the gather.  Returns ``fn``'s output(s), replicated,
    with the original row count."""
    axes = _axes_tuple(axis_name)
    if not axes or len(sizes) != len(axes):
        return fn(*arrays)
    s = 1
    for z in sizes:
        s *= int(z)
    b = arrays[0].shape[0]
    rows = -(-b // s)
    bp = rows * s
    idx = jnp.int32(0)
    for ax, sz in zip(axes, sizes):      # outer-major composite index
        idx = idx * int(sz) + jax.lax.axis_index(ax)

    def _pad(a):
        if bp == b:
            return a
        return jnp.take(a, jnp.arange(bp) % b, axis=0)

    sls = [jax.lax.dynamic_slice_in_dim(_pad(a), idx * rows, rows, axis=0)
           for a in arrays]
    out = fn(*sls)
    leaves, treedef = jax.tree_util.tree_flatten(out)
    g = [_gather_rows(o, axes)[:b] for o in leaves]
    return jax.tree_util.tree_unflatten(treedef, g)


class ShardedSearchResult(NamedTuple):
    """Sharded BBC collective output: global top-k, tau, per-shard survivor
    counts."""
    topk_dists: jax.Array
    topk_ids: jax.Array
    tau: jax.Array
    survivors_per_shard: jax.Array


def survivor_budget(k: int, n_shards: int, slack: float = 2.0) -> int:
    """Fixed per-chip survivor budget: balanced shards hold ~k/n_shards of the
    global top-k; ``slack`` covers shard skew.  128-lane aligned."""
    b = int(k / max(n_shards, 1) * slack) + 128
    return ((b + 127) // 128) * 128


def bbc_shard_search(
    local_dists: jax.Array,   # (n_local,) estimated distances of this shard
    local_ids: jax.Array,     # (n_local,) global ids
    local_valid: jax.Array,   # (n_local,) bool
    cb: rb.BucketCodebook,    # replicated per-query codebook
    k: int,
    n_shards: int,
    axis_name: str = "model",
    budget: int | None = None,
) -> ShardedSearchResult:
    """Per-shard body (call under shard_map).  Single query; vmap for batches.

    ``n_shards`` must be the static size of ``axis_name`` (budgets are shapes).
    """
    m = cb.m
    if budget is None:
        budget = survivor_budget(k, n_shards)

    bucket_ids = rb.bucketize(cb, jnp.where(local_valid, local_dists, INF))
    local_hist = rb.histogram(bucket_ids, m, local_valid)

    # THE collective: m+1 int32 counters instead of k (dist,id) pairs.
    global_hist = jax.lax.psum(local_hist, axis_name)
    tau, _ = rb.threshold_bucket(global_hist, k)

    # Local relaxed-threshold pruning + O(n) compaction to the fixed budget.
    survive = local_valid & (bucket_ids <= tau)
    idx, ok = rb.compact_mask(survive, budget)
    safe = jnp.minimum(idx, local_dists.shape[0] - 1)
    sd = jnp.where(ok, local_dists[safe], INF)
    si = jnp.where(ok, local_ids[safe], -1)

    # Gather only survivors (~k total across shards).
    gd = jax.lax.all_gather(sd, axis_name, tiled=True)
    gi = jax.lax.all_gather(si, axis_name, tiled=True)

    # Final selection (replicated, tiny: budget * n_shards elements).
    neg, order = jax.lax.top_k(-gd, k)
    return ShardedSearchResult(
        topk_dists=-neg,
        topk_ids=gi[order],
        tau=tau,
        survivors_per_shard=jnp.sum(survive),
    )


# --------------------------------------------------------------------------
# Batched collective primitives (the real-index path; see index/search.py)
# --------------------------------------------------------------------------

def bbc_survivors_batch(
    bucket: jax.Array,   # (B, F) local bucket ids
    key: jax.Array,      # (B, F) local selection keys (distance-like, asc)
    valid: jax.Array,    # (B, F) local live-lane mask
    hist: jax.Array,     # (B, m+1) local histograms
    count: int,          # global selection size (k, or n_cand for IVF+PQ)
    budget: int,         # static per-shard survivor budget
    axis_name="model",   # str, or a tuple for the hierarchical schedule
    tau_floor: jax.Array | None = None,  # scalar int32 predicted threshold
    spec: tuple | None = None,  # speculative buffer (pos, ok, count, tau)
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched core of the distributed BBC collector (call under shard_map).

    THE collective is the ``psum`` of (B, m+1) int32 histograms — m counters
    per query instead of the k (dist, id) pairs a naive distributed top-k
    all-gathers.  From the summed histogram every shard derives the same
    per-query threshold bucket tau; lanes at or below tau survive, compacted
    into the fixed ``budget``.  The global top-``count`` stays intact as
    long as no single shard owns more than ``budget`` of it (round-robin
    sharding makes shares ~count/S; see ``survivor_budget``).

    ``tau_floor`` is the predictive subsystem's hook: the engine-owned
    cross-batch predictor supplies its tau_pred and the survivor threshold
    becomes max(tau, tau_floor), so a shard whose scan already early-exacted
    the predicted buckets keeps those lanes even when this batch's true tau
    lands lower (overshoot only widens the pool — the final exact top-k is
    unchanged; undershoot is a no-op because tau dominates).

    ``spec`` is the fused scan-collect fast path
    (``ops.shard_collect_batch``): ``(spec_pos, spec_ok, spec_count,
    tau_spec)`` — lanes at or below the provisional ``tau_spec`` already
    compacted in stream order while the scan tiles were resident.  Three
    tiers, cheapest that is exact wins:

      1. covered (tau_spec >= tau everywhere, no buffer overflow): filter
         the buffer down to tau — O(budget), no second stream pass;
      2. undershoot but every shard's survivors fit ``budget``: one bounded
         O(F) stream-order compaction correction pass;
      3. overflow: the exact key-priority ``top_k`` fallback (survivors
         beyond ``budget`` drop farthest-first, as the pre-fused collector
         always did).

    Every tier yields the same survivor ID SET as the pre-fused collector
    (tiers 1-2 are stream-ordered rather than key-ordered — downstream
    selection is order-invariant).  Without ``spec`` tier 3 runs
    unconditionally (the legacy behavior, with ``budget`` clamped to the
    stream length so short-stream shards cannot crash the top_k).

    Returns ``(pos, ok, tau, n_survive, global_hist)``: local survivor stream
    positions (B, budget) with validity, the per-query threshold bucket (B,),
    this shard's per-query survivor count (B,) before budgeting, and the
    psum'd (B, m+1) histogram (replicated — the predictor's update input).
    """
    f = key.shape[1]
    global_hist = hier_psum(hist, axis_name)
    tau, _ = jax.vmap(rb.threshold_bucket, in_axes=(0, None))(
        global_hist, count)
    if tau_floor is not None:
        tau = jnp.maximum(tau, tau_floor)
    survive = valid & (bucket <= tau[:, None])
    n_survive = jnp.sum(survive, axis=1)

    def exact_topk(_):
        kk = min(budget, f)
        masked = jnp.where(survive, key, INF)
        neg, pos = jax.lax.top_k(-masked, kk)
        ok = jnp.isfinite(-neg)
        if kk < budget:
            pos = jnp.pad(pos, ((0, 0), (0, budget - kk)))
            ok = jnp.pad(ok, ((0, 0), (0, budget - kk)))
        return pos, ok

    if spec is None:
        pos, ok = exact_topk(None)
        return pos, ok, tau, n_survive, global_hist

    spos, sok, scount, tau_spec = spec

    def fast(_):
        safe = jnp.minimum(spos, f - 1)
        sb = jnp.take_along_axis(bucket, safe, axis=1)
        sk = jnp.take_along_axis(key, safe, axis=1)
        keep = sok & (sb <= tau[:, None]) & jnp.isfinite(sk)
        return safe, keep

    def correction(_):
        idx, okc = jax.vmap(lambda s: rb.compact_mask(s, budget))(survive)
        return jnp.minimum(idx, f - 1), okc

    covered = jnp.all((tau_spec >= tau) & (scount <= budget))
    fits = jnp.all(n_survive <= budget)
    pos, ok = jax.lax.cond(
        covered, fast,
        lambda op: jax.lax.cond(fits, correction, exact_topk, op), None)
    return pos, ok, tau, n_survive, global_hist


def split_certified_survivors(pos: jax.Array, ok: jax.Array,
                              certified: jax.Array):
    """Partition a shard's budget-compacted survivors by the bound-fused
    scan's inline coverage.

    ``pos``/``ok`` are ``bbc_survivors_batch``'s (B, budget) local survivor
    positions; ``certified`` is the scan's (B, F) inline-coverage mask
    (lower-bound bucket at or below the gate — those lanes' exact distances
    came out of the fused kernel while their vector tile was resident).
    Returns ``(cert_ok, strag_ok)``: survivors whose values the scan already
    holds, and the STRAGGLERS — the only rows the on-shard second gather
    pass must touch, and the quantity the psum'd measured ``n_second_pass``
    counts.
    """
    cert_ok = jnp.take_along_axis(certified, pos, axis=1) & ok
    return cert_ok, ok & ~cert_ok


def gather_survivors(axis_name, *rows: jax.Array) -> tuple[jax.Array, ...]:
    """All-gather per-shard (B, budget) survivor rows into (B, S * budget)
    — the survivor-only collective (~count total elements across shards,
    vs n_scanned for a full gather).  ``axis_name`` may be a tuple of mesh
    axes for the hierarchical schedule (innermost gathered first)."""
    axes = _axes_tuple(axis_name)
    return tuple(_gather_cols(r, axes) for r in rows)


def naive_shard_search(
    local_dists: jax.Array,
    local_ids: jax.Array,
    local_valid: jax.Array,
    k: int,
    axis_name="model",
) -> tuple[jax.Array, jax.Array]:
    """Baseline distributed collector: local exact top-k, all-gather k per
    shard, re-select.  Collective payload k*8 bytes/chip."""
    axes = _axes_tuple(axis_name)
    d = jnp.where(local_valid, local_dists, INF)
    kk = min(k, d.shape[0])
    neg, idx = jax.lax.top_k(-d, kk)
    gd = _gather_cols(-neg[None], axes)[0]
    gi = _gather_cols(local_ids[idx][None], axes)[0]
    neg2, order = jax.lax.top_k(-gd, k)
    return -neg2, gi[order]


def collective_cost_model(k: int, m: int, n_shards: int, budget: int | None = None,
                          link_bw: float = 50e9, n_hosts: int = 1,
                          dcn_bw: float = 25e9) -> dict:
    """Bytes on the wire per query: BBC vs naive distributed top-k.

    ring all-reduce of h bytes  ~ 2*h*(S-1)/S per link;
    ring all-gather of b bytes/shard ~ b*(S-1) per link.

    ``n_hosts > 1`` additionally prices the hierarchical (intra-host ICI,
    then inter-host DCN) schedule: the DCN all-reduce moves the SAME O(m)
    histogram (already host-reduced) over the ``n_hosts`` ring, and the DCN
    all-gather moves each host's concatenated survivor block — the naive
    collector pays k pairs per *shard* on that tier too.
    """
    if budget is None:
        budget = survivor_budget(k, n_shards)
    s = n_shards
    hist_bytes = 4 * (m + 1)
    bbc_wire = 2 * hist_bytes * (s - 1) / s + 8 * budget * (s - 1)
    naive_wire = 8 * k * (s - 1)
    out = {
        "bbc_bytes_per_link": bbc_wire,
        "naive_bytes_per_link": naive_wire,
        "ratio": naive_wire / max(bbc_wire, 1e-9),
        "bbc_collective_seconds": bbc_wire / link_bw,
        "naive_collective_seconds": naive_wire / link_bw,
    }
    if n_hosts > 1:
        sh = n_hosts
        per_host = max(s // sh, 1)
        bbc_dcn = 2 * hist_bytes * (sh - 1) / sh \
            + 8 * budget * per_host * (sh - 1)
        naive_dcn = 8 * k * per_host * (sh - 1)
        out.update({
            "n_hosts": sh,
            "bbc_dcn_bytes_per_link": bbc_dcn,
            "naive_dcn_bytes_per_link": naive_dcn,
            "dcn_ratio": naive_dcn / max(bbc_dcn, 1e-9),
            "bbc_dcn_seconds": bbc_dcn / dcn_bw,
            "naive_dcn_seconds": naive_dcn / dcn_bw,
        })
    return out
