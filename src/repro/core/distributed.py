"""Distributed BBC: shard_map search step over the production mesh.

This is the beyond-paper extension recorded in DESIGN.md §2/§4: the paper's
L1-resident bucket histogram becomes the *collective payload* of a sharded
search.  The corpus (codes + vectors) is sharded row-wise over the ``model``
axis; query batches are sharded over ``data`` (and replicated groups over
``pod``).  One search step per query:

  1. every chip scans its local shard -> local estimated distances,
  2. local (m+1)-histogram; ``psum`` over 'model'   <- m*4 bytes, NOT k*8,
  3. global threshold bucket tau from the summed histogram,
  4. local relaxed-threshold pruning + cumsum compaction to a fixed
     per-chip survivor budget  ~ k / n_shards * slack,
  5. ``all_gather`` of survivors only (~k total, vs n_scanned naively),
  6. final in-threshold-bucket selection (Alg. 1 Collect).

A naive distributed top-k instead all-gathers each chip's running top-k
(k * 8 bytes per chip).  ``collective_cost_model`` quantifies both for the
roofline table.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import buffer as rb

INF = jnp.inf


def shard_map(f, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (jax >= 0.6 exposes it at top level;
    0.4.x under ``jax.experimental``).  Replication checking is disabled:
    the search bodies end in ``psum``/``all_gather`` + replicated math, which
    the checker cannot always prove."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


class ShardedSearchResult(NamedTuple):
    topk_dists: jax.Array
    topk_ids: jax.Array
    tau: jax.Array
    survivors_per_shard: jax.Array


def survivor_budget(k: int, n_shards: int, slack: float = 2.0) -> int:
    """Fixed per-chip survivor budget: balanced shards hold ~k/n_shards of the
    global top-k; ``slack`` covers shard skew.  128-lane aligned."""
    b = int(k / max(n_shards, 1) * slack) + 128
    return ((b + 127) // 128) * 128


def bbc_shard_search(
    local_dists: jax.Array,   # (n_local,) estimated distances of this shard
    local_ids: jax.Array,     # (n_local,) global ids
    local_valid: jax.Array,   # (n_local,) bool
    cb: rb.BucketCodebook,    # replicated per-query codebook
    k: int,
    n_shards: int,
    axis_name: str = "model",
    budget: int | None = None,
) -> ShardedSearchResult:
    """Per-shard body (call under shard_map).  Single query; vmap for batches.

    ``n_shards`` must be the static size of ``axis_name`` (budgets are shapes).
    """
    m = cb.m
    if budget is None:
        budget = survivor_budget(k, n_shards)

    bucket_ids = rb.bucketize(cb, jnp.where(local_valid, local_dists, INF))
    local_hist = rb.histogram(bucket_ids, m, local_valid)

    # THE collective: m+1 int32 counters instead of k (dist,id) pairs.
    global_hist = jax.lax.psum(local_hist, axis_name)
    tau, _ = rb.threshold_bucket(global_hist, k)

    # Local relaxed-threshold pruning + O(n) compaction to the fixed budget.
    survive = local_valid & (bucket_ids <= tau)
    idx, ok = rb.compact_mask(survive, budget)
    safe = jnp.minimum(idx, local_dists.shape[0] - 1)
    sd = jnp.where(ok, local_dists[safe], INF)
    si = jnp.where(ok, local_ids[safe], -1)

    # Gather only survivors (~k total across shards).
    gd = jax.lax.all_gather(sd, axis_name, tiled=True)
    gi = jax.lax.all_gather(si, axis_name, tiled=True)

    # Final selection (replicated, tiny: budget * n_shards elements).
    neg, order = jax.lax.top_k(-gd, k)
    return ShardedSearchResult(
        topk_dists=-neg,
        topk_ids=gi[order],
        tau=tau,
        survivors_per_shard=jnp.sum(survive),
    )


# --------------------------------------------------------------------------
# Batched collective primitives (the real-index path; see index/search.py)
# --------------------------------------------------------------------------

def bbc_survivors_batch(
    bucket: jax.Array,   # (B, F) local bucket ids
    key: jax.Array,      # (B, F) local selection keys (distance-like, asc)
    valid: jax.Array,    # (B, F) local live-lane mask
    hist: jax.Array,     # (B, m+1) local histograms
    count: int,          # global selection size (k, or n_cand for IVF+PQ)
    budget: int,         # static per-shard survivor budget
    axis_name: str = "model",
    tau_floor: jax.Array | None = None,  # scalar int32 predicted threshold
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched core of the distributed BBC collector (call under shard_map).

    THE collective is the ``psum`` of (B, m+1) int32 histograms — m counters
    per query instead of the k (dist, id) pairs a naive distributed top-k
    all-gathers.  From the summed histogram every shard derives the same
    per-query threshold bucket tau; lanes at or below tau survive.  Survivors
    are compacted key-priority (smallest keys first) into the fixed
    ``budget``, so even when a shard holds more than ``budget`` survivors the
    dropped ones are its farthest — the global top-``count`` stays intact as
    long as no single shard owns more than ``budget`` of it (round-robin
    sharding makes shares ~count/S; see ``survivor_budget``).

    ``tau_floor`` is the predictive subsystem's hook: the engine-owned
    cross-batch predictor supplies its tau_pred and the survivor threshold
    becomes max(tau, tau_floor), so a shard whose scan already early-exacted
    the predicted buckets keeps those lanes even when this batch's true tau
    lands lower (overshoot only widens the pool — the final exact top-k is
    unchanged; undershoot is a no-op because tau dominates).

    Returns ``(pos, ok, tau, n_survive, global_hist)``: local survivor stream
    positions (B, budget) with validity, the per-query threshold bucket (B,),
    this shard's per-query survivor count (B,) before budgeting, and the
    psum'd (B, m+1) histogram (replicated — the predictor's update input).
    """
    global_hist = jax.lax.psum(hist, axis_name)
    tau, _ = jax.vmap(rb.threshold_bucket, in_axes=(0, None))(
        global_hist, count)
    if tau_floor is not None:
        tau = jnp.maximum(tau, tau_floor)
    survive = valid & (bucket <= tau[:, None])
    masked = jnp.where(survive, key, INF)
    neg, pos = jax.lax.top_k(-masked, budget)
    return pos, jnp.isfinite(-neg), tau, jnp.sum(survive, axis=1), global_hist


def split_certified_survivors(pos: jax.Array, ok: jax.Array,
                              certified: jax.Array):
    """Partition a shard's budget-compacted survivors by the bound-fused
    scan's inline coverage.

    ``pos``/``ok`` are ``bbc_survivors_batch``'s (B, budget) local survivor
    positions; ``certified`` is the scan's (B, F) inline-coverage mask
    (lower-bound bucket at or below the gate — those lanes' exact distances
    came out of the fused kernel while their vector tile was resident).
    Returns ``(cert_ok, strag_ok)``: survivors whose values the scan already
    holds, and the STRAGGLERS — the only rows the on-shard second gather
    pass must touch, and the quantity the psum'd measured ``n_second_pass``
    counts.
    """
    cert_ok = jnp.take_along_axis(certified, pos, axis=1) & ok
    return cert_ok, ok & ~cert_ok


def gather_survivors(axis_name: str, *rows: jax.Array) -> tuple[jax.Array, ...]:
    """All-gather per-shard (B, budget) survivor rows into (B, S * budget)
    — the survivor-only collective (~count total elements across shards,
    vs n_scanned for a full gather)."""
    return tuple(
        jax.lax.all_gather(r, axis_name, axis=1, tiled=True) for r in rows
    )


def naive_shard_search(
    local_dists: jax.Array,
    local_ids: jax.Array,
    local_valid: jax.Array,
    k: int,
    axis_name: str = "model",
) -> tuple[jax.Array, jax.Array]:
    """Baseline distributed collector: local exact top-k, all-gather k per
    shard, re-select.  Collective payload k*8 bytes/chip."""
    d = jnp.where(local_valid, local_dists, INF)
    kk = min(k, d.shape[0])
    neg, idx = jax.lax.top_k(-d, kk)
    gd = jax.lax.all_gather(-neg, axis_name, tiled=True)
    gi = jax.lax.all_gather(local_ids[idx], axis_name, tiled=True)
    neg2, order = jax.lax.top_k(-gd, k)
    return -neg2, gi[order]


def collective_cost_model(k: int, m: int, n_shards: int, budget: int | None = None,
                          link_bw: float = 50e9) -> dict:
    """Bytes on the wire per query: BBC vs naive distributed top-k.

    ring all-reduce of h bytes  ~ 2*h*(S-1)/S per link;
    ring all-gather of b bytes/shard ~ b*(S-1) per link.
    """
    if budget is None:
        budget = survivor_budget(k, n_shards)
    s = n_shards
    hist_bytes = 4 * (m + 1)
    bbc_wire = 2 * hist_bytes * (s - 1) / s + 8 * budget * (s - 1)
    naive_wire = 8 * k * (s - 1)
    return {
        "bbc_bytes_per_link": bbc_wire,
        "naive_bytes_per_link": naive_wire,
        "ratio": naive_wire / max(bbc_wire, 1e-9),
        "bbc_collective_seconds": bbc_wire / link_bw,
        "naive_collective_seconds": naive_wire / link_bw,
    }
