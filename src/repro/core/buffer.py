"""Bucket-based result buffer (paper Alg. 1) — TPU-native formulation.

The paper's result buffer keeps per-bucket linear append buffers in L1 and a
threshold bucket updated from cumulative counts.  On TPU there is no per-object
insertion; the faithful re-expression is a *counting-sort top-k*:

  1. ``build_codebook``   — per-query equal-depth 1-D quantizer over a sampled
     prefix of estimated distances (paper: "Codebook Generation Based on
     Estimated Distance"; 256 equal-width bins remapped to ``m`` equal-depth
     buckets through a uint8 LUT, Eq. 6).
  2. ``bucketize``        — Eq. 6: clamp(floor((d - d_min)/delta)) -> LUT.
  3. ``histogram``        — the m-entry bucket histogram is the ONLY cross-tile
     state (the VMEM/L1-residency analogue).
  4. ``threshold_bucket`` — Alg. 1 Update: first bucket where the cumulative
     count reaches k; its upper edge is the relaxed threshold.
  5. ``collect``          — Alg. 1 Collect: everything in buckets < tau is in
     the exact top-k *set* unconditionally; one small selection inside the
     threshold bucket picks the remaining s = k - |preceding| items.  The
     compaction uses a cumsum scatter (O(n)), never an O(n log n) sort.

All functions are single-query; batch with ``jax.vmap``.  Shapes are static:
invalid / padded lanes are carried through a ``valid`` mask.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.inf


class BucketCodebook(NamedTuple):
    """Per-query 1-D quantizer: equal-width front end + equal-depth remap.

    ``edges``  : (m + 1,) ascending bucket boundaries c_1..c_{m+1} (Eq. 1/2).
    ``d_min``  : scalar lower edge of the equal-width range.
    ``delta``  : scalar equal-width bin width.
    ``ew_map`` : (n_ew,) int32 LUT mapping equal-width bin -> equal-depth
                 bucket id (paper stores this as uint8; int32 here, the
                 Pallas kernel packs it back down).
    """

    edges: jax.Array
    d_min: jax.Array
    delta: jax.Array
    ew_map: jax.Array

    @property
    def m(self) -> int:
        return self.edges.shape[0] - 1

    @property
    def n_ew(self) -> int:
        return self.ew_map.shape[0]


def default_num_buckets(
    vmem_bytes: int = 16 * 1024 * 1024,
    lut_bytes: int = 0,
    code_tile_bytes: int = 0,
    bytes_per_bucket: int = 2 * 2 * 64,
    cap: int = 512,
) -> int:
    """Eq. 3 adapted to TPU (Eq. 3' in DESIGN.md).

    The paper sizes m from L1 = 32KB minus quantization-code and LUT space,
    reserving 256 B of prefetchable tail per bucket.  On TPU the analogue is
    VMEM minus the ADC LUT and the streaming code tile; per-bucket state is a
    histogram counter + boundary, but we keep the paper's 256 B/bucket reserve
    so the active working set of a fused kernel instance stays VMEM-resident.
    TPU lanes are 128 wide, so we round to a multiple of 128 and cap at 512 —
    beyond that the threshold-update cost grows with no selection benefit
    (paper Exp-6 shows a flat optimum).
    """
    m = (vmem_bytes - lut_bytes - code_tile_bytes) // bytes_per_bucket
    m = max(128, min(int(m), cap))
    return (m // 128) * 128


def build_codebook(
    sample_dists: jax.Array,
    k: int,
    m: int,
    n_ew: int = 256,
    valid: jax.Array | None = None,
) -> BucketCodebook:
    """Equal-depth codebook over the local top-k of a sampled prefix.

    Paper: sample D_sample from the 5-10 nearest clusters, partial-sort once,
    take [d_min, d_max] from the local top-k, then equal-depth partition via an
    equal-width front end of ``n_ew`` bins.  ``sample_dists`` are the estimated
    distances of the sample; ``valid`` masks padding lanes.
    """
    if valid is not None:
        sample_dists = jnp.where(valid, sample_dists, INF)
    k = min(k, sample_dists.shape[0])
    # One partial sort over the sample (paper: "performed only once,
    # its computational cost is negligible").
    topk = -jax.lax.top_k(-sample_dists, k)[0]
    return build_codebook_from_topk(topk, m, n_ew)


def build_codebook_from_topk(
    topk: jax.Array,
    m: int,
    n_ew: int = 256,
) -> BucketCodebook:
    """Codebook from an ALREADY-SELECTED ascending local top-k of sampled
    distances.  Split out of ``build_codebook`` so callers that need the
    top-k values for other purposes (e.g. order-statistic threshold buckets
    in the batched planner) run the selection once."""
    # Sanitize +inf entries (under-filled samples: fewer valid lanes than the
    # requested top-k) — an infinite d_max makes delta infinite and every
    # distance lands in bucket 0, collapsing the histogram.  Clamp the range
    # to the largest finite value instead; the padding lanes then sit on the
    # top edge, which only widens the last bucket.
    finite = jnp.isfinite(topk)
    top_finite = jnp.max(jnp.where(finite, topk, -INF))
    # zero valid lanes (an empty shard's sample): fall back to a degenerate
    # all-zero range — the span guard below keeps delta finite, and the
    # histogram stays empty anyway because counts are valid-masked
    top_finite = jnp.where(jnp.isfinite(top_finite), top_finite, 0.0)
    topk = jnp.where(finite, topk, top_finite)
    d_min = topk[0]
    d_max = topk[-1]
    # Guard degenerate ranges (all-equal distances / tiny samples) and keep a
    # 2% margin above d_max: the paper's argument ("the sampled d_max is
    # necessarily farther than the true top-k distance") makes the range safe
    # when sampling, but when the sample IS the population the k-th item sits
    # exactly on the edge and front-end rounding could spill it to overflow.
    k = topk.shape[0]
    span = jnp.maximum(d_max - d_min, 1e-6) * 1.02
    delta = span / n_ew
    # Equal-depth edges from quantiles of the local top-k.  ``topk`` is
    # sorted ascending, so the (linear-interpolation) quantiles are direct
    # index arithmetic — no second sort.
    pos = jnp.linspace(0.0, k - 1.0, m + 1)
    lo = jnp.floor(pos).astype(jnp.int32)
    hi = jnp.minimum(lo + 1, k - 1)
    frac = (pos - lo).astype(topk.dtype)
    edges = topk[lo] + (topk[hi] - topk[lo]) * frac
    # Strictly increasing edges so searchsorted is well defined under ties.
    eps = span * 1e-7
    edges = edges + eps * jnp.arange(m + 1, dtype=edges.dtype)
    # Equal-width bin centers -> equal-depth bucket id.
    centers = d_min + (jnp.arange(n_ew, dtype=jnp.float32) + 0.5) * delta
    ew_map = jnp.clip(jnp.searchsorted(edges, centers, side="right") - 1, 0, m - 1)
    ew_map = ew_map.astype(jnp.int32)
    return BucketCodebook(edges=edges, d_min=d_min, delta=delta, ew_map=ew_map)


def bucketize(cb: BucketCodebook, dists: jax.Array) -> jax.Array:
    """Eq. 6: a_i = map[clamp(floor((d - d_min)/delta), 0, n_ew-1)].

    Distances beyond the codebook range land in the overflow bucket ``m``
    (they can never be in the top-k once the buffer holds k candidates);
    distances below d_min land in bucket 0 (paper's boundary control).
    """
    n_ew = cb.n_ew
    m = cb.m
    bin_id = jnp.floor((dists - cb.d_min) / cb.delta)
    overflow = bin_id >= n_ew
    bin_id = jnp.clip(bin_id, 0, n_ew - 1).astype(jnp.int32)
    bucket = cb.ew_map[bin_id]
    return jnp.where(overflow, m, bucket).astype(jnp.int32)


def histogram(bucket_ids: jax.Array, m: int, valid: jax.Array | None = None) -> jax.Array:
    """(m + 1,)-entry bucket histogram (bucket m = overflow)."""
    w = jnp.ones_like(bucket_ids, dtype=jnp.int32)
    if valid is not None:
        w = jnp.where(valid, w, 0)
    return jnp.zeros((m + 1,), jnp.int32).at[bucket_ids].add(w)


def threshold_bucket(hist: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Alg. 1 Update: first bucket index tau with cum-count >= k.

    Returns ``(tau, n_before)`` where ``n_before`` is the number of candidates
    in buckets strictly before tau.  If fewer than k candidates exist in total,
    tau = m (overflow id) — "the threshold bucket is set to inf, allowing all
    objects to be accepted".
    """
    m = hist.shape[0] - 1
    cum = jnp.cumsum(hist[:m])
    tau = jnp.searchsorted(cum, k, side="left").astype(jnp.int32)  # cum[tau] >= k
    tau = jnp.minimum(tau, m)
    n_before = jnp.where(tau > 0, cum[jnp.maximum(tau - 1, 0)], 0)
    n_before = jnp.where(tau == 0, 0, n_before).astype(jnp.int32)
    return tau, n_before


def relaxed_threshold(cb: BucketCodebook, tau: jax.Array) -> jax.Array:
    """Upper edge of the threshold bucket — the paper's relaxed threshold."""
    edges_ext = jnp.concatenate([cb.edges, jnp.array([INF], cb.edges.dtype)])
    return edges_ext[jnp.minimum(tau + 1, cb.m + 1)]


def compact_mask(mask: jax.Array, budget: int) -> tuple[jax.Array, jax.Array]:
    """Compaction of ``mask`` into ``budget`` slots.

    Returns (indices, valid): positions of the first ``budget`` set lanes, in
    order.  This replaces the paper's per-bucket linear append buffers.
    Implemented as an ascending sort of position-or-sentinel keys rather
    than the cumsum-scatter counting sort: XLA lowers CPU scatters to a
    serial element loop, so the vectorized sort is ~2.5x faster at bench
    shapes (and on TPU the fused Pallas collector owns this step anyway).
    """
    n = mask.shape[0]
    key = jnp.where(mask, jnp.arange(n, dtype=jnp.int32), n)
    out = jax.lax.sort(key)[:budget]
    if budget > n:
        out = jnp.concatenate(
            [out, jnp.full((budget - n,), n, jnp.int32)])
    return out, out < n


def collect(
    cb: BucketCodebook,
    dists: jax.Array,
    ids: jax.Array,
    bucket_ids: jax.Array,
    k: int,
    valid: jax.Array | None = None,
    hist: jax.Array | None = None,
    slack_buckets: int = 2,
) -> tuple[jax.Array, jax.Array]:
    """Alg. 1 Collect: exact top-k *set* via bucket-level order.

    Buckets < tau are accepted unconditionally; a single top-s selection inside
    the threshold bucket supplies the remaining s = k - n_before items.  The
    survivor compaction is cumsum-based (O(n)); the only sort-like op is the
    top-k over a ``k + slack`` sized compacted buffer, never over all n.

    Returns (top-k distances ascending, top-k ids).  Padding lanes (valid =
    False) never appear in the output provided at least k valid candidates
    exist.
    """
    m = cb.m
    if valid is None:
        valid = jnp.ones(dists.shape, bool)
    if hist is None:
        hist = histogram(bucket_ids, m, valid)
    tau, _ = threshold_bucket(hist, k)
    # Survivors: everything at or before the threshold bucket.  Their count is
    # in [k, k + |B_tau|]; budget covers the threshold bucket plus slack for
    # the (rare) case the equal-depth estimate concentrated mass in one bucket.
    survive = valid & (bucket_ids <= tau)
    budget = _collect_budget(k, dists.shape[0], slack_buckets, m)
    idx, in_budget = compact_mask(survive, budget)
    cd = jnp.where(in_budget, dists[jnp.minimum(idx, dists.shape[0] - 1)], INF)
    ci = jnp.where(in_budget, ids[jnp.minimum(idx, ids.shape[0] - 1)], -1)

    def fast(_):
        neg_d, order = jax.lax.top_k(-cd, k)
        return -neg_d, ci[order]

    def fallback(_):
        # Exactness escape hatch: tau hit the overflow bucket (fewer than k
        # in-range candidates) or survivors exceeded the budget (pathological
        # tie mass in one bucket).  One full top-k keeps the result exact;
        # this branch is compiled but not executed on the production path.
        d = jnp.where(valid, dists, INF)
        neg_d, order = jax.lax.top_k(-d, k)
        return -neg_d, ids[order]

    overflowed = (tau >= m) | (jnp.sum(survive) > budget)
    return jax.lax.cond(overflowed, fallback, fast, None)


def _collect_budget(k: int, n: int, slack_buckets: int, m: int) -> int:
    # Expected threshold-bucket occupancy under equal-depth is ~k/m; slack
    # covers skew.  Budget is clamped to n (can't select more than exists).
    per_bucket = max(k // max(m, 1), 1)
    return int(min(n, k + slack_buckets * per_bucket + 64))


def topk_oracle(
    dists: jax.Array, ids: jax.Array, k: int, valid: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Reference collector: full top-k (the heap-analogue baseline)."""
    if valid is not None:
        dists = jnp.where(valid, dists, INF)
    neg_d, idx = jax.lax.top_k(-dists, k)
    return -neg_d, ids[idx]
