"""BBC core: bucket-based result collection (the paper's contribution).

Public surface:
  buffer      — result buffer primitives (codebook / bucketize / histogram /
                threshold bucket / collect)
  collector   — stream collectors (bbc + Exp-3 baselines)
  rerank      — Algorithms 2-4 (minimal / greedy bounded / early re-rank)
  distributed — shard_map BBC search step (histogram all-reduce)
"""
from repro.core import buffer, collector, distributed, rerank  # noqa: F401
