"""Re-ranking algorithms (paper §3.3, Algorithms 2-4).

Three re-rankers for the two quantization families:

  * ``minimal_rerank_set``     — Observation 1 oracle: with the exact k-th
    distance in hand, the minimal set that must be re-ranked is
    {o : lb_o <= Dist_k <= ub_o}.  Used to measure how close the greedy
    algorithm gets (Exp-5) — not executable online (Dist_k is unknown).
  * ``minimal_rerank``         — Alg. 2: the executable two-heap solution.
    Host-side (numpy + heapq) exactly like the paper's baseline
    IVF+RaBitQ+MIN; the paper's point is that its heap overhead makes it
    *slower* than BBC despite re-ranking fewer objects.
  * ``greedy_bounded_rerank``  — Alg. 3: two result buffers (by upper / lower
    bound) sharing one codebook; iteratively re-rank the marginal buckets
    until the frontiers cross.  Fully vectorized: per-iteration work is one
    bucket of each buffer, the loop is a ``lax.while_loop`` over bucket
    frontiers (<= m iterations).
  * ``early_rerank_plan``      — Alg. 4 for unbounded methods: predict the
    threshold bucket from the scan prefix and compute exact distances inline
    for predicted survivors while their vectors are resident (on TPU: in the
    same VMEM tile — see kernels/fused_scan.py), avoiding the second
    gather pass over most of the re-rank set.
"""
from __future__ import annotations

import heapq
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buffer as rb

INF = jnp.inf


# --------------------------------------------------------------------------
# Observation 1: minimal re-rank set (oracle, for Exp-5 accounting)
# --------------------------------------------------------------------------

def minimal_rerank_set(lb: jax.Array, ub: jax.Array, exact: jax.Array, k: int,
                       valid: jax.Array | None = None) -> jax.Array:
    """Boolean mask of the theoretical minimal re-rank set.

    Dist_k is the exact k-th smallest distance; an object must be re-ranked
    iff its bound interval straddles it: lb <= Dist_k <= ub.
    """
    e = exact if valid is None else jnp.where(valid, exact, INF)
    dist_k = -jax.lax.top_k(-e, k)[0][-1]
    mask = (lb <= dist_k) & (dist_k <= ub)
    if valid is not None:
        mask = mask & valid
    return mask


# --------------------------------------------------------------------------
# Alg. 2: two-heap minimal re-ranking (host-side baseline, as in the paper)
# --------------------------------------------------------------------------

def minimal_rerank(
    lb: np.ndarray,
    ub: np.ndarray,
    k: int,
    exact_fn: Callable[[int], float],
) -> tuple[np.ndarray, np.ndarray, int]:
    """Paper Alg. 2 (IVF+RaBitQ+MIN baseline).

    ``exact_fn(i)`` returns the exact distance of object i.  Returns
    (top-k ids, top-k distances, number of exact evaluations).  This is the
    heap-heavy design the paper shows loses to BBC at large k; we keep it
    host-side (heapq) exactly as a CPU implementation would be.
    """
    n = len(lb)
    order = np.argsort(ub, kind="stable")
    # Candidate collection phase: H_u holds the k smallest upper bounds
    # (max-heap by ub); H_l holds the rest with lb below the k-th ub.
    h_u: list[tuple[float, float, int]] = []  # (-key, lb, i) max-heap by key
    h_l: list[tuple[float, float, int]] = []  # (lb, ub, i) min-heap by lb
    kth_ub = np.inf
    for i in range(n):
        if ub[i] < kth_ub or len(h_u) < k:
            heapq.heappush(h_u, (-ub[i], lb[i], i))
            if len(h_u) > k:
                nu, nl, ni = heapq.heappop(h_u)
                heapq.heappush(h_l, (nl, -nu, ni))
            kth_ub = -h_u[0][0]
        elif lb[i] < kth_ub:
            heapq.heappush(h_l, (lb[i], ub[i], i))

    # Re-ranking phase: iteratively resolve the frontier object.
    n_reranked = 0
    resolved: dict[int, float] = {}

    def key_u():  # (key, lb, i) of H_u top; key = ub or exact
        nu, nl, ni = h_u[0]
        return -nu, nl, ni

    while h_u and h_l:
        ku, lu, iu = key_u()
        ll, lu2, il = h_l[0]
        if ku <= ll:
            break  # largest key in top-k below smallest lb outside: done
        # Pick the unresolved object with the smaller lower bound.
        if lu <= ll and iu not in resolved:
            heapq.heappop(h_u)
            d = exact_fn(iu)
            n_reranked += 1
            resolved[iu] = d
            heapq.heappush(h_u, (-d, d, iu))
        else:
            heapq.heappop(h_l)
            if il in resolved:
                continue
            d = exact_fn(il)
            n_reranked += 1
            resolved[il] = d
            heapq.heappush(h_u, (-d, d, il))
            if len(h_u) > k:
                nu, nl, ni = heapq.heappop(h_u)
                if ni in resolved:
                    continue
                heapq.heappush(h_l, (nl, -nu, ni))
        # Trim H_u back to k.
        while len(h_u) > k:
            nu, nl, ni = heapq.heappop(h_u)
            if ni not in resolved:
                heapq.heappush(h_l, (nl, -nu, ni))

    # Finalize: every member of H_u must have an exact distance.
    ids, ds = [], []
    for nu, nl, ni in h_u:
        if ni not in resolved:
            resolved[ni] = exact_fn(ni)
            n_reranked += 1
        ids.append(ni)
        ds.append(resolved[ni])
    out = np.argsort(ds, kind="stable")[:k]
    return np.asarray(ids)[out], np.asarray(ds)[out], n_reranked


# --------------------------------------------------------------------------
# Alg. 3: greedy bounded re-ranking on result buffers (the BBC way)
# --------------------------------------------------------------------------

class GreedyRerankResult(NamedTuple):
    """Greedy bounded re-rank (Alg. 3) output with work accounting."""
    topk_dists: jax.Array
    topk_ids: jax.Array
    n_reranked: jax.Array        # how many exact evaluations were spent
    rerank_mask: jax.Array       # which objects were re-ranked
    certain_in: jax.Array        # skipped because provably inside the top-k


class GreedyRerankPlan(NamedTuple):
    """Bound-derived re-rank plan: the uncertain band plus certain-in/out
    masks."""
    rerank_mask: jax.Array       # uncertain band: exact distances needed
    certain_in: jax.Array        # provably inside the top-k (skip)
    certain_out: jax.Array       # provably outside (skip)
    tau_ub: jax.Array
    tau_lb: jax.Array
    a_lb: jax.Array              # lb bucket ids (for phased re-ranking)
    a_ub: jax.Array              # ub bucket ids


def phase1_mask(plan: GreedyRerankPlan) -> jax.Array:
    """Likely-in portion of the uncertain band: items whose UPPER bound sits
    at or below the k-th-ub bucket.  Re-ranking these first yields real exact
    distances that tighten the threshold for phase 2 — the vectorized
    equivalent of Alg. 3's iterative marginal-bucket loop."""
    return plan.rerank_mask & (plan.a_ub <= plan.tau_ub)


def phase2_threshold(plan: GreedyRerankPlan, exact_p1: jax.Array,
                     k: int) -> jax.Array:
    """Safe threshold after phase 1: with C certain-in members (all inside
    the top-k) the (k - C)-th smallest phase-1 exact distance upper-bounds
    Dist_k; anything with lb above it is certainly out."""
    c = jnp.sum(plan.certain_in)
    rank = jnp.clip(k - c, 1, exact_p1.shape[0])
    sorted_e = jnp.sort(exact_p1)
    return sorted_e[rank - 1]


def greedy_rerank_plan(
    lb: jax.Array,
    ub: jax.Array,
    k: int,
    valid: jax.Array | None = None,
    m: int = 128,
) -> GreedyRerankPlan:
    """Planning half of Alg. 3 (see ``greedy_bounded_rerank`` for the math).
    Lets the searcher compute exact distances lazily, only for the mask."""
    n = lb.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    lbv = jnp.where(valid, lb, INF)
    ubv = jnp.where(valid, ub, INF)
    cb = rb.build_codebook(ubv, k=min(k, n), m=m)
    a_lb = rb.bucketize(cb, lbv)
    a_ub = rb.bucketize(cb, ubv)
    hist_ub = rb.histogram(a_ub, m, valid)
    tau_ub, _ = rb.threshold_bucket(hist_ub, k)
    hist_lb = rb.histogram(a_lb, m, valid)
    tau_lb, _ = rb.threshold_bucket(hist_lb, k)
    certain_in = valid & (a_ub < tau_lb)
    maybe = valid & (a_lb <= tau_ub)
    return GreedyRerankPlan(
        rerank_mask=maybe & ~certain_in,
        certain_in=certain_in,
        certain_out=valid & ~maybe,
        tau_ub=tau_ub,
        tau_lb=tau_lb,
        a_lb=a_lb,
        a_ub=a_ub,
    )


def greedy_rerank_plan_batch(
    lb: jax.Array,       # (B, n)
    ub: jax.Array,       # (B, n)
    k: int,
    valid: jax.Array,    # (B, n)
    m: int = 128,
) -> GreedyRerankPlan:
    """Batched Alg. 3 planning — identical plans to ``vmap(greedy_rerank_plan)``
    without the per-query histogram scatters.

    Both threshold buckets are order statistics: bucketize is monotone
    non-decreasing in its input, so the first bucket whose cumulative count
    reaches k (``threshold_bucket``) is exactly the bucket of the k-th
    smallest value.  ``tau_ub`` therefore falls out of the same top-k that
    builds the codebook, and ``tau_lb`` costs one batched top-k instead of a
    histogram + cumsum.  (Padding lanes are +inf, which bucketize maps to the
    overflow id m — matching threshold_bucket's "fewer than k stored" case.)
    """
    b, n = lb.shape
    kk = min(k, n)
    lbv = jnp.where(valid, lb, INF)
    ubv = jnp.where(valid, ub, INF)
    # ONE top-k for both bounds (ub rows stacked over lb rows): the ub half
    # feeds the codebook build and tau_ub (its k-th element), the lb half
    # supplies tau_lb's order statistic.  Stacking matters: XLA's CPU TopK
    # rewrite only fires for one sort per module here — a second separate
    # top_k lowers to a full variadic sort, ~5x slower at this width.
    vals = -jax.lax.top_k(-jnp.concatenate([ubv, lbv], axis=0), kk)[0]
    ub_topk = vals[:b]                                        # (B, kk) asc
    kth_ub = ub_topk[:, -1]
    kth_lb = vals[b:, -1]
    cbs = jax.vmap(lambda t: rb.build_codebook_from_topk(t, m=m))(ub_topk)
    a_lb = jax.vmap(rb.bucketize)(cbs, lbv)
    a_ub = jax.vmap(rb.bucketize)(cbs, ubv)
    tau_ub = jax.vmap(lambda cb, x: rb.bucketize(cb, x[None])[0])(cbs, kth_ub)
    tau_lb = jax.vmap(lambda cb, x: rb.bucketize(cb, x[None])[0])(cbs, kth_lb)
    certain_in = valid & (a_ub < tau_lb[:, None])
    maybe = valid & (a_lb <= tau_ub[:, None])
    return GreedyRerankPlan(
        rerank_mask=maybe & ~certain_in,
        certain_in=certain_in,
        certain_out=valid & ~maybe,
        tau_ub=tau_ub,
        tau_lb=tau_lb,
        a_lb=a_lb,
        a_ub=a_ub,
    )


def greedy_rerank_finalize(
    plan: GreedyRerankPlan,
    exact_where_reranked: jax.Array,   # INF outside the rerank mask
    lb: jax.Array,
    ids: jax.Array,
    k: int,
    est: jax.Array | None = None,
    ub: jax.Array | None = None,
) -> GreedyRerankResult:
    resolved_key = jnp.where(plan.rerank_mask, exact_where_reranked, INF)
    sel_key = jnp.where(plan.certain_in, lb - 1e30, resolved_key)
    neg, idx = jax.lax.top_k(-sel_key, k)
    if est is not None:
        report = est
    elif ub is not None:
        report = (lb + ub) * 0.5
    else:
        report = lb
    out_d = jnp.where(plan.certain_in[idx], report[idx], exact_where_reranked[idx])
    return GreedyRerankResult(
        topk_dists=out_d,
        topk_ids=ids[idx],
        n_reranked=jnp.sum(plan.rerank_mask),
        rerank_mask=plan.rerank_mask,
        certain_in=plan.certain_in,
    )


def greedy_bounded_rerank(
    lb: jax.Array,
    ub: jax.Array,
    ids: jax.Array,
    k: int,
    exact_all: jax.Array,
    valid: jax.Array | None = None,
    m: int = 128,
    est: jax.Array | None = None,
) -> GreedyRerankResult:
    """Paper Alg. 3, collapsed to its bucket-level fixed point.

    The paper iterates two marginal-bucket frontiers because a heap-based CPU
    scan discovers candidates incrementally.  With the full bucket histograms
    in hand (one vectorized pass on TPU) both frontiers are computable in
    closed form — this is the fixed point the paper's loop converges to,
    coarsened to bucket granularity:

      * tau_ub : threshold bucket of the UB histogram.  The k-th smallest
        upper bound D̄ satisfies Dist_k <= D̄, and bucketize is monotone, so any
        object with a_lb > tau_ub has lb > D̄ >= Dist_k — **certainly out**
        (skip, exact).
      * tau_lb : threshold bucket of the LB histogram.  For any object x with
        a_ub < tau_lb:  #{y : lb_y < ub_x} <= cum_lb[tau_lb - 1] <= k - 1,
        and every y with exact_y < exact_x has lb_y <= exact_y < exact_x <=
        ub_x, hence #{exact < exact_x} <= k - 1 — **certainly in** (skip,
        exact).
      * re-rank set = {a_lb <= tau_ub} \\ certain_in — the uncertain band
        around the boundary, the bucket-granular version of Observation 1's
        minimal set.

    Given valid bounds (lb <= exact <= ub) the returned id set equals the
    exact top-k set; certain-in members are reported with their estimated
    distance (``est``, else the bound midpoint), as in the paper, where
    skipped objects keep their quantized distances.
    """
    n = lb.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    # Shared codebook (Alg. 3 line 2) built from the UPPER bounds so the range
    # is guaranteed to cover the k-th smallest ub (the relaxation anchor);
    # lower bounds below the range clamp into bucket 0, which only coarsens
    # tau_lb conservatively.
    plan = greedy_rerank_plan(lb, ub, k, valid=valid, m=m)
    exact_where = jnp.where(plan.rerank_mask, exact_all, INF)
    return greedy_rerank_finalize(
        plan, exact_where, jnp.where(valid, lb, INF), ids, k, est=est, ub=ub
    )


def threshold_only_rerank_mask(
    lb: jax.Array, ub: jax.Array, k: int, valid: jax.Array | None = None
) -> jax.Array:
    """Plain IVF+RaBitQ criterion (the paper's baseline): re-rank every object
    whose lower bound is below the running k-th upper bound.  Vectorized
    equivalent of the collector-threshold test the original code performs."""
    u = ub if valid is None else jnp.where(valid, ub, INF)
    kth_ub = -jax.lax.top_k(-u, k)[0][-1]
    mask = lb <= kth_ub
    if valid is not None:
        mask = mask & valid
    return mask


# --------------------------------------------------------------------------
# Alg. 4: early re-ranking for unbounded methods (PQ)
# --------------------------------------------------------------------------

class EarlyRerankPlan(NamedTuple):
    """Early re-rank (Alg. 4) plan: predicted threshold bucket + bucket
    codebook."""
    tau_pred: jax.Array      # predicted threshold bucket (int32)
    cb: rb.BucketCodebook


def early_rerank_plan(
    sample_est: jax.Array,
    n_cand: int,
    n_sample: int,
    n_total: int,
    m: int = 128,
    valid: jax.Array | None = None,
) -> EarlyRerankPlan:
    """Alg. 4 line 4: tau_pred from the (|sample|/|O| * n_cand)-th quantized
    distance of the sample prefix."""
    cb = rb.build_codebook(sample_est, k=min(n_cand, sample_est.shape[0]), m=m,
                           valid=valid)
    rank = max(int(round(n_cand * n_sample / max(n_total, 1))), 1)
    rank = min(rank, sample_est.shape[0])
    s = sample_est if valid is None else jnp.where(valid, sample_est, INF)
    kth = -jax.lax.top_k(-s, rank)[0][-1]
    tau_pred = rb.bucketize(cb, kth[None])[0]
    return EarlyRerankPlan(tau_pred=tau_pred, cb=cb)


def early_rerank_mask(plan: EarlyRerankPlan, est: jax.Array) -> jax.Array:
    """Objects predicted to enter the re-rank pool: exact distance is computed
    inline while their vector tile is resident (fused kernel)."""
    return rb.bucketize(plan.cb, est) <= plan.tau_pred


def update_tau_pred(
    plan: EarlyRerankPlan,
    est_so_far: jax.Array,
    n_scanned: int,
    n_total: int,
    n_cand: int,
    valid: jax.Array | None = None,
) -> EarlyRerankPlan:
    """Alg. 4 line 14: refresh tau_pred from the scanned prefix."""
    rank = max(int(round(n_cand * n_scanned / max(n_total, 1))), 1)
    rank = min(rank, est_so_far.shape[0])
    s = est_so_far if valid is None else jnp.where(valid, est_so_far, INF)
    kth = -jax.lax.top_k(-s, rank)[0][-1]
    tau_pred = rb.bucketize(plan.cb, kth[None])[0]
    return EarlyRerankPlan(tau_pred=tau_pred, cb=plan.cb)


# --------------------------------------------------------------------------
# Cross-batch threshold prediction (the predictive early-exact subsystem)
# --------------------------------------------------------------------------
#
# Alg. 4 predicts tau from the scan prefix of the CURRENT query.  The serving
# engine sees a stream of query batches whose distance distributions are
# stationary (same corpus, i.i.d. queries), so a better predictor is the
# exponential moving average of the per-query bucket histograms of PREVIOUS
# batches: the per-query codebooks are equal-depth over samples of the same
# distribution, which makes bucket indices comparable across batches, and the
# EMA'd histogram directly yields the bucket where the cumulative count
# reaches any target (k for bounded methods, the re-rank pool size for PQ).
#
# The prediction is advisory, never load-bearing: searchers take
# max(tau_pred, tau_true-from-this-batch's-histogram) as the survivor
# threshold, and survivors the prediction missed (bucket in
# (tau_pred, tau_true]) are re-ranked in a fallback pass exactly as the
# static path would — an undershooting predictor costs speed, not results.

class PredictorState(NamedTuple):
    """EMA over psum'd/batched (B, m+1) bucket histograms.

    ``ema``    : (m + 1,) float32 decayed sum of mean per-query histograms.
    ``weight`` : scalar float32 decayed sum of 1s (bias correction; 0 = cold,
                 no batches observed yet — predictions are disabled).
    """

    ema: jax.Array
    weight: jax.Array


def predictor_init(m: int) -> PredictorState:
    return PredictorState(ema=jnp.zeros((m + 1,), jnp.float32),
                          weight=jnp.float32(0.0))


def predictor_update(state: PredictorState, hist: jax.Array,
                     decay: float = 0.8) -> PredictorState:
    """Fold one batch's histograms into the EMA.

    ``hist`` is (B, m+1) int32 (batched paths) or (m+1,) (single query); the
    sharded paths pass the psum'd global histogram, so the EMA tracks the
    whole corpus regardless of deployment.
    """
    mean = jnp.mean(hist.reshape(-1, hist.shape[-1]).astype(jnp.float32),
                    axis=0)
    return PredictorState(
        ema=decay * state.ema + (1.0 - decay) * mean,
        weight=decay * state.weight + (1.0 - decay),
    )


def predict_tau(state: PredictorState, count: int,
                margin: int = 1) -> jax.Array:
    """Predicted threshold bucket: first bucket whose bias-corrected
    cumulative EMA count reaches ``count``, plus ``margin`` buckets of slack
    against batch-to-batch jitter.  Returns -1 while cold (no history) so the
    scan computes nothing inline and the fallback pass covers everything —
    the first batch behaves exactly like the static path.
    """
    m = state.ema.shape[0] - 1
    corrected = state.ema / jnp.maximum(state.weight, 1e-12)
    cum = jnp.cumsum(corrected[:m])
    tau = jnp.searchsorted(cum, jnp.float32(count),
                           side="left").astype(jnp.int32)
    tau = jnp.minimum(tau + margin, m - 1)
    return jnp.where(state.weight > 0, tau, jnp.int32(-1))


def predicted_fallback_mask(bucket: jax.Array, valid: jax.Array,
                            tau_pred: jax.Array,
                            tau_true: jax.Array) -> jax.Array:
    """Fallback-pass plan: survivors the prediction missed.

    A lane survives iff its bucket is at or below max(tau_pred, tau_true);
    lanes at or below tau_pred were early-exacted inline during the scan, so
    the second gather pass only needs bucket in (tau_pred, tau_true] — empty
    whenever the prediction covered the true threshold (tau_pred >= tau_true).
    ``tau_pred``/``tau_true`` broadcast over the trailing lane axis.
    """
    tau_used = jnp.maximum(tau_pred, tau_true)
    return valid & (bucket > tau_pred[..., None]) & \
        (bucket <= tau_used[..., None])
