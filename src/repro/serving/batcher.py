"""Deadline-aware micro-batcher over a small set of padded (B, k) shapes.

The engine's searchers are jit-compiled with static ``(B, k, n_probe)``
(`index/search.py`), so every distinct request shape is a fresh XLA
compile.  Real traffic has heterogeneous ``k``; serving it shape-for-shape
would thrash the jit cache.  The batcher therefore quantizes requests onto a
small grid of **shape buckets** — a fixed batch width ``B`` times a short
ladder of ``k`` ceilings — and serves every request at its bucket ceiling:

* a request with ``k <= bucket.k`` runs at ``bucket.k`` and the result is
  trimmed post-hoc to the first ``k`` rows (results come back sorted by
  distance, so the trim is exact: the top-k prefix of a top-``bucket.k``
  selection IS the top-k);
* a partial batch is padded to ``B`` rows by cycling the real queries (pad
  lanes are discarded at trim time; cycling real queries rather than zeros
  keeps the per-batch bucket histograms — which feed the cross-batch tau
  predictor — drawn from the live query distribution).

Batches fire under two rules (whichever comes first):

* **fill** — a bucket lane reaches ``B`` waiting requests;
* **slack expiry** — the oldest waiting request's remaining slack no longer
  covers one estimated service time for its bucket (waiting any longer
  would blow its deadline), where the estimate comes from the admission
  controller's per-bucket service-time EMA.

All methods take ``now`` explicitly — the batcher never reads a wall clock,
so the discrete-event server loop and the deterministic tests drive it with
whatever clock they own.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.serving.queue import Request


@dataclass(frozen=True, order=True)
class ShapeBucket:
    """One padded compile shape: (batch, k) plus the routing width."""

    k: int
    batch: int
    n_probe: int


def k_ceilings(ks: Iterable[int]) -> tuple[int, ...]:
    """Sorted unique k ceilings for a bucket ladder."""
    out = tuple(sorted({int(k) for k in ks}))
    if not out or out[0] < 1:
        raise ValueError(f"k ceilings must be positive, got {out}")
    return out


def bucket_of(k: int, n_probe: int, ceilings: Sequence[int],
              batch: int) -> ShapeBucket:
    """Smallest ladder ceiling that covers ``k`` (KeyError if none does —
    admission decides whether an oversized request is k-capped or shed)."""
    for c in ceilings:
        if k <= c:
            return ShapeBucket(k=int(c), batch=int(batch),
                               n_probe=int(n_probe))
    raise KeyError(
        f"k={k} exceeds the largest bucket ceiling {max(ceilings)}")


@dataclass(frozen=True, eq=False)
class Batch:
    """An assembled, padded batch ready for one engine call."""

    bucket: ShapeBucket
    requests: tuple[Request, ...]       # the real (unpadded) requests
    queries: np.ndarray                 # (bucket.batch, d), padded

    @property
    def n_real(self) -> int:
        return len(self.requests)


def assemble(bucket: ShapeBucket, requests: Sequence[Request]) -> Batch:
    """Stack request queries into the bucket's (B, d) shape, cycling real
    queries into the pad lanes."""
    if not 0 < len(requests) <= bucket.batch:
        raise ValueError(
            f"got {len(requests)} requests for a B={bucket.batch} bucket")
    rows = [np.asarray(r.q) for r in requests]
    for i in range(bucket.batch - len(rows)):
        rows.append(rows[i % len(requests)])
    return Batch(bucket=bucket, requests=tuple(requests),
                 queries=np.stack(rows))


class MicroBatcher:
    """Continuous batch assembly over per-bucket FIFO lanes."""

    def __init__(self, ceilings: Sequence[int], batch: int,
                 service_est: Callable[[ShapeBucket], float],
                 slack_margin: float = 0.0,
                 max_wait: float | None = None):
        self.ceilings = k_ceilings(ceilings)
        self.batch = int(batch)
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.service_est = service_est
        self.slack_margin = float(slack_margin)
        # optional cap on queueing wait: with a loose deadline a partial
        # batch would otherwise sit until its slack expires, so tail latency
        # under LOW load would equal the deadline; max_wait bounds it
        self.max_wait = None if max_wait is None else float(max_wait)
        self._lanes: dict[ShapeBucket, list[Request]] = {}

    # -- intake -------------------------------------------------------------

    def submit(self, req: Request) -> ShapeBucket:
        bucket = bucket_of(req.k, req.n_probe, self.ceilings, self.batch)
        self._lanes.setdefault(bucket, []).append(req)
        return bucket

    # -- introspection (admission reads these) ------------------------------

    def depth(self, bucket: ShapeBucket) -> int:
        return len(self._lanes.get(bucket, ()))

    def depths(self) -> dict[ShapeBucket, int]:
        return {b: len(lane) for b, lane in self._lanes.items() if lane}

    def pending(self) -> int:
        return sum(len(lane) for lane in self._lanes.values())

    # -- withdrawal (the retry path pulls timed-out requests back) -----------

    def withdraw(self, rid: int) -> Request | None:
        """Remove and return a queued (not yet fired) request by id, or
        None when it is not waiting here.  The multi-replica retry path
        uses this to pull a timed-out request out of a dead or stalled
        replica's lane before re-dispatching it elsewhere — without it the
        request could complete twice from one attempt."""
        for bucket in sorted(self._lanes):
            lane = self._lanes[bucket]
            for i, r in enumerate(lane):
                if r.rid == rid:
                    return lane.pop(i)
        return None

    def clear(self) -> int:
        """Drop every queued request (crash respawn: a restarted replica
        process has lost its queue; the requests are recovered by their
        timeouts).  Returns the number dropped."""
        n = self.pending()
        self._lanes.clear()
        return n

    # -- firing -------------------------------------------------------------

    # float jitter guard: next_fire_time's "due" instant must round-trip
    # through _slack_expired as expired, or the event loop would spin
    _EPS = 1e-9

    def _slack_expired(self, bucket: ShapeBucket, req: Request,
                       now: float) -> bool:
        est = self.service_est(bucket)
        if req.slack(now) <= est + self.slack_margin + self._EPS:
            return True
        return self.max_wait is not None and \
            now - req.arrival >= self.max_wait - self._EPS

    def pop_ready(self, now: float) -> list[tuple[ShapeBucket,
                                                  tuple[Request, ...]]]:
        """Pop every batch that must fire at ``now`` — full lanes first,
        then partial lanes whose oldest request's slack no longer covers
        one estimated service time — WITHOUT assembling the padded query
        arrays.  Buckets are visited in sorted order so firing is
        deterministic.  The double-buffered server loop assembles each
        popped batch inside the previous batch's device window
        (``Server._serve``'s overlap hook); ``fire_ready`` keeps the eager
        assemble-on-pop contract for consumers that want finished batches.
        """
        out: list[tuple[ShapeBucket, tuple[Request, ...]]] = []
        for bucket in sorted(self._lanes):
            lane = self._lanes[bucket]
            while len(lane) >= bucket.batch:
                out.append((bucket, tuple(lane[:bucket.batch])))
                del lane[:bucket.batch]
            if lane and self._slack_expired(bucket, lane[0], now):
                out.append((bucket, tuple(lane)))
                lane.clear()
        return out

    def fire_ready(self, now: float) -> list[Batch]:
        """``pop_ready`` with eager assembly: every due batch, padded and
        ready for the engine."""
        return [assemble(bucket, reqs)
                for bucket, reqs in self.pop_ready(now)]

    def next_fire_time(self, now: float) -> float | None:
        """Earliest future instant a slack-expiry fire is due (None when no
        requests wait).  Full lanes fire immediately via fire_ready, so only
        partial lanes contribute."""
        times = []
        for bucket, lane in self._lanes.items():
            if not lane:
                continue
            due = lane[0].deadline - self.service_est(bucket) - \
                self.slack_margin
            if self.max_wait is not None:
                due = min(due, lane[0].arrival + self.max_wait)
            times.append(due)
        if not times:
            return None
        return max(min(times), now)
