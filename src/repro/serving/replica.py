"""Replica: one engine-wrapping serving unit inside the multi-replica tier.

Each replica owns a ``ServingState`` FORK — the (immutable) built engines
are shared pool-wide via ``ServingState.fork()``, but every replica holds
its own per-bucket ``PredictorState``s, so the tau predictor self-tunes on
the traffic slice the affinity router sends THIS replica — plus its own
``MicroBatcher`` lanes, a single-executor service model (one batch in
flight at a time), and a decayed **probed-centroid working set** the router
scores affinity against.

Fault injection happens HERE, at the service boundary (``Replica.serve``):
the replica consults the ``FaultSchedule`` for slowdowns, stalls, crashes,
and payload corruption, and the router upstream sees only observable
consequences.  Responses carry an integrity checksum computed over the
true payload BEFORE corruption is applied, so a corrupt fault is
detectable (and only detectable) the way a wire checksum would make it.

``ReplicaPool`` owns construction, crash respawn (a respawned replica is a
fresh process: new ``ServingState`` fork via ``SearchEngine.replica_clone``,
cleared queue, cold health) and the predictor-state checkpoint loop: when a
checkpoint directory is configured, each replica's per-bucket predictor
states are saved through ``checkpoint.manager.CheckpointManager`` (content
checksummed) and a respawn restores the latest verified checkpoint —
falling back to cold states on ``CorruptCheckpointError`` instead of
resuming from garbage.
"""
from __future__ import annotations

import os
import time
from collections import deque
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, CorruptCheckpointError
from repro.core import rerank
from repro.serving import faults as flt
from repro.serving.batcher import Batch, MicroBatcher, ShapeBucket
from repro.serving.state import ServingState


class ReplicaResponse(NamedTuple):
    """One batch response as received by the router."""

    dists: np.ndarray        # (B, bucket.k)
    ids: np.ndarray          # (B, bucket.k)
    checksum: int            # computed replica-side over the TRUE payload

    def verified(self) -> bool:
        return flt.payload_checksum(self.dists, self.ids) == self.checksum


def _pred_key(bucket: ShapeBucket) -> str:
    return f"k{bucket.k}_b{bucket.batch}_np{bucket.n_probe}"


class WorkingSet:
    """Decayed probed-centroid working set: what is warm in one serving
    unit's caches and predictor.

    Shared by the in-process :class:`Replica` and the transport tier's
    worker handles (``repro.transport.core``) — both expose the same
    ``affinity`` surface to the one :class:`~repro.serving.router.Router`,
    so routing behaves identically whether the serving unit is a thread-on-
    a-timeline or a process-on-a-socket.  Weights decay exponentially with
    time constant ``decay`` seconds; entries below 1e-4 are dropped."""

    def __init__(self, decay: float = 2.0, t0: float = 0.0):
        self.decay = float(decay)
        self._ws: dict[int, float] = {}     # centroid id -> decayed weight
        self._t = float(t0)

    def _decay_to(self, now: float) -> None:
        dt = now - self._t
        if dt > 0:
            f = float(np.exp(-dt / max(self.decay, 1e-9)))
            self._ws = {c: w * f for c, w in self._ws.items() if w * f > 1e-4}
        self._t = now

    def note(self, cluster_ids: np.ndarray, now: float) -> None:
        """Fold a completed request/batch's probed centroids in."""
        self._decay_to(now)
        for c in np.asarray(cluster_ids).reshape(-1).tolist():
            self._ws[int(c)] = self._ws.get(int(c), 0.0) + 1.0

    def score(self, cluster_ids: np.ndarray, now: float) -> float:
        """Overlap between a query's top routed centroids and this set."""
        self._decay_to(now)
        return float(sum(self._ws.get(int(c), 0.0)
                         for c in np.asarray(cluster_ids).reshape(-1)))

    def reset(self, now: float) -> None:
        """Fresh process: the working set is gone."""
        self._ws = {}
        self._t = now


class Replica:
    """One serving replica: state fork + batcher lanes + working set."""

    def __init__(self, rid: int, state: ServingState, batcher: MicroBatcher,
                 *, ws_decay: float = 2.0):
        self.rid = rid
        self.state = state
        self.batcher = batcher
        self.ws_decay = float(ws_decay)     # working-set half-life-ish (s)
        self.fired: deque[Batch] = deque()  # assembled, waiting for executor
        self.in_flight: Batch | None = None
        self.busy_until_est = 0.0           # EMA-estimated completion time
        self.respawned_at = -np.inf         # last supervisor restart
        self.served_batches = 0
        self.ws = WorkingSet(decay=ws_decay)

    # -- the service boundary (fault injection lives here) -------------------

    def serve(self, batch: Batch, t_start: float,
              schedule: flt.FaultSchedule | None = None,
              service_time_fn: Callable[[ShapeBucket], float] | None = None,
              ) -> tuple[float | None, ReplicaResponse | None]:
        """Execute one batch; returns ``(t_done, response)``.

        ``t_done`` is the fault-adjusted completion instant, or None when a
        crash fault lands during service — the batch then never completes
        and its response is never materialized (the engine call is skipped
        when the service model makes the crash predictable up front, so
        chaos benches don't pay for work the crash discards).  A corrupt
        fault rewrites the payload AFTER the checksum is computed."""
        if service_time_fn is not None:
            dt = service_time_fn(batch.bucket)
            if schedule is not None:
                dt, completes = schedule.perturb(
                    self.rid, t_start, dt, since=self.respawned_at)
                if not completes:
                    return None, None
            res = self.state.run(batch)
            jax.block_until_ready((res.dists, res.ids))
        else:
            w0 = time.perf_counter()
            res = self.state.run(batch)
            jax.block_until_ready((res.dists, res.ids))
            dt = time.perf_counter() - w0
            if schedule is not None:
                dt, completes = schedule.perturb(
                    self.rid, t_start, dt, since=self.respawned_at)
                if not completes:
                    return None, None
        dists = np.asarray(res.dists)
        ids = np.asarray(res.ids)
        resp = ReplicaResponse(dists=dists, ids=ids,
                               checksum=flt.payload_checksum(dists, ids))
        if schedule is not None and \
                schedule.corrupts(self.rid, t_start, since=self.respawned_at):
            resp = ReplicaResponse(dists=resp.dists,
                                   ids=flt.corrupt_payload(resp.ids),
                                   checksum=resp.checksum)
        self.served_batches += 1
        return t_start + dt, resp

    # -- load / affinity introspection (the router reads these) --------------

    def load(self) -> int:
        """Requests queued, fired-but-waiting, or in flight."""
        waiting = sum(b.n_real for b in self.fired)
        running = self.in_flight.n_real if self.in_flight else 0
        return self.batcher.pending() + waiting + running

    def note_probed(self, cluster_ids: np.ndarray, now: float) -> None:
        """Fold a completed batch's probed centroids into the decayed
        working set (what is warm in this replica's caches and predictor)."""
        self.ws.note(cluster_ids, now)

    def affinity(self, cluster_ids: np.ndarray, now: float) -> float:
        """Overlap score between a query's top routed centroids and this
        replica's recent working set."""
        return self.ws.score(cluster_ids, now)

    @property
    def generation(self) -> int:
        """Index generation this replica currently serves."""
        return self.state.generation

    def swap_state(self, state: ServingState) -> None:
        """Zero-downtime engine swap: re-point ONLY the state fork.

        Unlike ``reset`` (crash respawn), the batcher lanes, fired batches,
        the in-flight batch, and the affinity working set all survive —
        requests queued before the swap execute against the new
        generation's engines on their normal schedule, so the roll sheds
        and fails nothing.  (The OLD state fork keeps the old generation's
        engine cache alive by reference until the last holder drops it —
        the copy-on-swap contract in ``ServingState.swap``.)"""
        self.state = state

    def reset(self, state: ServingState, now: float) -> None:
        """Crash respawn: fresh process — queue, executor, and working set
        are gone; the (new) state fork carries whatever predictor states
        the checkpoint restore recovered."""
        self.state = state
        self.batcher.clear()
        self.fired.clear()
        self.in_flight = None
        self.busy_until_est = now
        self.respawned_at = now
        self.ws.reset(now)


class ReplicaPool:
    """N replicas over one shared engine-build cache, plus respawn."""

    def __init__(self, base: ServingState, n_replicas: int,
                 ceilings, batch: int, *,
                 service_est: Callable[[ShapeBucket], float],
                 slack_margin: float = 0.0, max_wait: float | None = None,
                 ws_decay: float = 2.0,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 1):
        if n_replicas < 1:
            raise ValueError(f"need >= 1 replica, got {n_replicas}")
        self.base = base
        self.checkpoint_every = max(int(checkpoint_every), 1)
        self._ckpt_dir = checkpoint_dir
        self._managers: dict[int, CheckpointManager] = {}
        self._steps: dict[int, int] = {}
        # bucket-key registry so a respawn can rebuild {key: bucket} maps
        self._buckets: dict[str, ShapeBucket] = {}
        self.replicas = [
            Replica(rid, base.fork(),
                    MicroBatcher(ceilings, batch, service_est=service_est,
                                 slack_margin=slack_margin,
                                 max_wait=max_wait),
                    ws_decay=ws_decay)
            for rid in range(n_replicas)
        ]

    def __len__(self) -> int:
        return len(self.replicas)

    def __iter__(self):
        return iter(self.replicas)

    def __getitem__(self, rid: int) -> Replica:
        return self.replicas[rid]

    # -- predictor-state checkpointing ---------------------------------------

    def _manager(self, rid: int) -> CheckpointManager | None:
        if self._ckpt_dir is None:
            return None
        mgr = self._managers.get(rid)
        if mgr is None:
            mgr = CheckpointManager(
                os.path.join(self._ckpt_dir, f"replica_{rid}"), keep_last=2)
            self._managers[rid] = mgr
        return mgr

    def maybe_checkpoint(self, rid: int) -> bool:
        """Save replica ``rid``'s per-bucket predictor states every
        ``checkpoint_every`` completed batches (no-op without a configured
        directory).  Returns True when a checkpoint was written."""
        mgr = self._manager(rid)
        replica = self.replicas[rid]
        if mgr is None or \
                replica.served_batches % self.checkpoint_every != 0:
            return False
        states = replica.state.pred_states()
        for bucket in states:
            self._buckets[_pred_key(bucket)] = bucket
        tree = {_pred_key(b): s for b, s in states.items()}
        step = self._steps.get(rid, 0) + 1
        self._steps[rid] = step
        mgr.save(step, tree)
        return True

    def _restore_pred(self, rid: int) -> dict[ShapeBucket, object]:
        """Latest verified predictor checkpoint for ``rid`` as a
        {bucket: PredictorState} dict; empty (cold) when there is no
        checkpoint or the checkpoint fails its content checksum."""
        mgr = self._manager(rid)
        if mgr is None or mgr.latest_step() is None:
            return {}
        like = {key: rerank.predictor_init(self.base.m)
                for key in sorted(self._buckets)}
        if not like:
            return {}
        try:
            tree, _ = mgr.restore(like)
        except (CorruptCheckpointError, KeyError, ValueError):
            # verified-or-cold: never resume from garbage
            return {}
        return {self._buckets[key]: state for key, state in tree.items()}

    # -- streaming-ingest rolling swap ---------------------------------------

    def rolling_swap(self, index, *, vectors=None, live=None, probe_qs=None,
                     drift_threshold: float = 0.25, warm_buckets=None,
                     on_step=None) -> dict[tuple[int, int], dict]:
        """Roll a rebuilt index through the pool one replica at a time with
        zero shed requests.

        ``base.swap`` replaces the shared engine-build cache with a NEW dict
        (copy-on-swap), so every replica's existing fork keeps serving the
        old generation untouched; each roll step then takes a fresh fork
        (sharing the new cache) and re-points exactly one replica via
        ``Replica.swap_state`` — queues, fired batches, and working sets
        survive, so nothing in flight is shed or failed.  ``warm_buckets``
        precompiles the new generation's serving shapes BEFORE the first
        replica moves, keeping the roll's first post-swap batch off the
        compile path.

        Predictor warmth is tested per replica: warm states live in the
        REPLICA forks (each self-tuned on its affinity slice), so the pool
        probes each warm bucket once through the NEW engine (shared across
        replicas — the probe histogram depends on the engine, not the
        replica) and runs the drift test against every replica's own EMA.
        Carried states move into the replica's new fork; drifted ones
        cold-reset.  ``on_step(rid)`` (when given) runs after each replica
        flips — benches use it to drive traffic mid-roll and assert both
        generations answer correctly side by side.  Returns the aggregate
        drift report ``{(k, n_probe): {"tv": max over replicas, "carried":
        all replicas, "replicas": [...per-replica detail...]}}``."""
        self.base.swap(index, vectors=vectors, live=live, probe_qs=probe_qs,
                       drift_threshold=drift_threshold)
        if warm_buckets:
            self.base.warmup(warm_buckets)
        fresh: dict[tuple[int, int], object] = {}
        if self.base.tau_pred and probe_qs is not None:
            from repro.ingest import drift as drift_mod
            qs = jnp.asarray(probe_qs)
            buckets = {b for r in self.replicas for b in r.state.pred_states()}
            for bucket in sorted(buckets):
                fresh[(bucket.k, bucket.n_probe)] = \
                    drift_mod.probe_histogram(self.base.engine(bucket), qs)
        report: dict[tuple[int, int], dict] = {}
        for rid, replica in enumerate(self.replicas):
            old_states = replica.state.pred_states()
            ns = self.base.fork()
            carried = {}
            for bucket, st in old_states.items():
                key = (bucket.k, bucket.n_probe)
                probe = fresh.get(key)
                if probe is None:
                    carried[bucket] = st     # no probe signal: keep warm
                    continue
                from repro.ingest import drift as drift_mod
                kept, tv, ok = drift_mod.carry_state(st, probe,
                                                     drift_threshold)
                carried[bucket] = kept
                entry = report.setdefault(
                    key, {"tv": 0.0, "carried": True, "replicas": []})
                entry["tv"] = max(entry["tv"], tv)
                entry["carried"] = entry["carried"] and ok
                entry["replicas"].append(
                    {"rid": rid, "tv": tv, "carried": ok})
            ns._pred = carried
            replica.swap_state(ns)
            if on_step is not None:
                on_step(rid)
        self.base.drift_report = report
        return report

    # -- respawn -------------------------------------------------------------

    def respawn(self, rid: int, now: float) -> Replica:
        """Supervisor restart after a crash fault: fresh state fork (shared
        build artifacts via ``SearchEngine.replica_clone``), predictor
        states restored through the checksummed checkpoint path."""
        state = self.base.fork(clone_engines=True)
        state._pred = dict(self._restore_pred(rid))
        self.replicas[rid].reset(state, now)
        return self.replicas[rid]
