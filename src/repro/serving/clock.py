"""Injectable monotonic clocks for the serving + transport tiers.

Every serving component is written against an explicit ``now`` so the
discrete-event tests own the timeline.  The socket front-end
(``repro.transport``) runs on wall time instead — but it must share the
exact code paths the discrete-event tests exercise, so instead of
scattering ``time.time()`` through the loop, time comes from ONE injected
clock object:

* :class:`SystemClock` — wraps ``time.monotonic`` (never ``time.time``:
  wall time can step backwards under NTP, which would corrupt heartbeat
  ages and timer deadlines);
* :class:`ManualClock` — an advance-by-hand clock for tests and for the
  replay driver, which sets it to each recorded event's timestamp.

Components that accept a clock (``HealthView``, ``RetryPolicy``, the
transport drivers) still take an explicit ``now`` argument everywhere and
only fall back to ``clock.now()`` when the caller omits it, so the
discrete-event users are unchanged and the wall-clock users never touch a
time module directly.
"""
from __future__ import annotations

import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Anything with a monotonic ``now() -> float`` (seconds)."""

    def now(self) -> float:  # pragma: no cover - protocol stub
        ...


class SystemClock:
    """Wall-clock time from ``time.monotonic`` (steady, never steps back)."""

    def now(self) -> float:
        return time.monotonic()


class ManualClock:
    """Test / replay clock: advances only when told to.

    ``set`` enforces monotonicity (a replay transcript with out-of-order
    timestamps is corrupt and must fail loudly, not silently reorder the
    health view's beat ages).
    """

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a monotonic clock by {dt}")
        self._t += float(dt)
        return self._t

    def set(self, t: float) -> float:
        if t < self._t:
            raise ValueError(
                f"monotonic clock cannot step back: {t} < {self._t}")
        self._t = float(t)
        return self._t
