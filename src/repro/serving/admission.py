"""Admission control: per-shape-bucket service-time EMA + shed / k-cap.

An open-loop arrival stream can exceed the engine's capacity; without
admission control the queue grows without bound and EVERY request blows its
deadline.  The controller keeps the served set feasible by rejecting work at
enqueue time, using the only two facts it can know cheaply:

* a per-bucket **service-time EMA** (`ServiceEMA`) fed by the measured wall
  time of every completed batch — the same estimate the batcher's
  fire-on-slack rule uses, so scheduling and admission agree on capacity;
* the current **queue depth** per bucket, read from the batcher;
* the **in-flight batch**'s remaining EMA service time (``in_flight``):
  a request that arrives mid-batch cannot start before the executor frees
  up, so the server folds the currently-executing batch's estimated
  remainder into the wait — decided at ARRIVAL time with what a live
  server would know (the EMA estimate, not the eventually-measured time).

For a request whose deadline is unmeetable at its own bucket the controller
first tries to **degrade** it — cap ``k`` to a smaller bucket ceiling whose
(cheaper) service estimate fits the deadline; the caller gets fewer results,
flagged, never wrong ones — and only **sheds** when no ladder rung fits.
Shedding returns nothing for that request: absent, not incorrect.

``decide`` is a pure function of (request, now, queue depths, EMA state), so
a seeded trace with a fixed service model replays the exact same admission
decisions — the determinism test in ``tests/test_serving.py`` relies on it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

from repro.serving.batcher import ShapeBucket, bucket_of
from repro.serving.queue import Request

ACCEPT = "accept"
DEGRADE = "degrade"
SHED = "shed"


class ServiceEMA:
    """Exponential moving average of measured batch service seconds,
    per shape bucket.  ``cold`` is the optimistic prior returned before the
    first observation of a bucket (optimistic on purpose: a cold server
    should try to serve, not shed — the EMA corrects within a few batches).
    """

    def __init__(self, decay: float = 0.6, cold: float = 0.02):
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.decay = float(decay)
        self.cold = float(cold)
        self._ema: dict[ShapeBucket, float] = {}

    def observe(self, bucket: ShapeBucket, seconds: float) -> None:
        prev = self._ema.get(bucket)
        self._ema[bucket] = (seconds if prev is None else
                             self.decay * prev + (1 - self.decay) * seconds)

    def estimate(self, bucket: ShapeBucket) -> float:
        return self._ema.get(bucket, self.cold)

    def observed(self, bucket: ShapeBucket) -> bool:
        return bucket in self._ema


@dataclass(frozen=True)
class Decision:
    """Admission verdict for one request."""

    action: str                      # ACCEPT | DEGRADE | SHED
    bucket: ShapeBucket | None       # bucket to run in (None when shed)
    k: int                           # effective k (== request k on accept)
    finish_est: float                # estimated completion time


@dataclass(frozen=True)
class DegradeLadder:
    """Capacity-pressure degradation rungs for the multi-replica tier.

    When healthy capacity drops below offered load (replicas crashed or
    stalled), the serving tier should slide DOWN the recall/latency frontier
    — lower recall target, narrower n_probe, smaller k — before it starts
    shedding: fewer/coarser results beat no results.  Each rung is
    ``(load_factor, k_cap, n_probe_cap, recall_target)``: at
    ``offered/capacity >= load_factor`` requests are capped to ``k_cap`` /
    ``n_probe_cap`` and their recall target lowered to ``recall_target``
    (None leaves that knob alone; legacy 3-tuple rungs without the recall
    entry are accepted and padded).  Rungs are evaluated in ascending
    ``load_factor`` order and the LAST matching rung wins, so deeper
    overload degrades harder.  ``caps`` is a pure function of its argument
    — seeded fault runs replay identically.

    ``from_frontier`` builds the rungs from a TUNED recall/cost frontier
    (``tuning.solver.pareto_frontier`` / ``PointStore.frontier``) instead of
    hand-picked caps: each successively deeper overload rung serves the next
    cheaper tuned operating point, so degradation walks the measured
    recall/latency frontier rather than blunt k-capping.
    """

    rungs: tuple = ()   # ((load_factor, k_cap, np_cap[, recall_target]), …)

    def __post_init__(self):
        norm = tuple((r[0],) + tuple(r[1:]) + (None,) * (4 - len(r))
                     for r in self.rungs)
        if any(len(r) != 4 for r in norm):
            raise ValueError(f"rungs must be 3- or 4-tuples: {self.rungs}")
        object.__setattr__(self, "rungs", norm)
        thresholds = [r[0] for r in norm]
        if thresholds != sorted(thresholds):
            raise ValueError(
                f"ladder rungs must be sorted by load factor: {self.rungs}")
        targets = [r[3] for r in norm if r[3] is not None]
        if targets != sorted(targets, reverse=True):
            raise ValueError(
                "rung recall targets must be non-increasing (deeper "
                f"overload must not promise MORE recall): {self.rungs}")

    @classmethod
    def from_frontier(cls, frontier,
                      load_factors=(1.0, 1.5, 2.5)) -> "DegradeLadder":
        """Ladder whose rungs are tuned operating points.

        ``frontier`` is a recall-descending sequence of
        ``tuning.points.OperatingPoint`` (``PointStore.frontier``); the
        FIRST entry is the healthy serving point (no rung — it is what
        un-degraded traffic already gets) and each subsequent, cheaper
        point becomes one rung at the next ``load_factors`` threshold:
        the rung caps ``n_probe`` to the point's tuned routing width and
        lowers the request's recall target to the point's target.  ``k``
        is left alone — the tuned frontier trades recall for work at
        constant k, which is exactly the "degrade along the frontier, not
        blunt k-capping" contract.
        """
        rungs = []
        for lf, point in zip(load_factors, list(frontier)[1:]):
            rungs.append((float(lf), None, int(point.knobs.n_probe),
                          float(point.recall_target)))
        return cls(tuple(rungs))

    def caps(self, load_factor: float
             ) -> tuple[int | None, int | None, float | None]:
        k_cap = n_probe_cap = recall_target = None
        for threshold, kc, nc, rt in self.rungs:
            if load_factor >= threshold:
                k_cap, n_probe_cap, recall_target = kc, nc, rt
        return k_cap, n_probe_cap, recall_target

    def apply(self, req: Request, load_factor: float) -> Request:
        """Cap a request per the rung the current overload selects; the
        capped request is flagged (``k_requested`` / ``n_probe_requested``
        / ``recall_requested``) so its outcome reports ``degraded``."""
        k_cap, n_probe_cap, recall_target = self.caps(load_factor)
        if k_cap is not None:
            req = req.k_capped(k_cap)
        if n_probe_cap is not None:
            req = req.n_probe_capped(n_probe_cap)
        if recall_target is not None:
            req = req.recall_capped(recall_target)
        return req


class AdmissionController:
    """Shed-or-degrade admission over the bucket ladder."""

    def __init__(self, service: ServiceEMA, ceilings: Sequence[int],
                 batch: int, allow_degrade: bool = True,
                 slack_margin: float = 0.0):
        self.service = service
        self.ceilings = tuple(sorted(ceilings))
        self.batch = int(batch)
        self.allow_degrade = bool(allow_degrade)
        self.slack_margin = float(slack_margin)

    def _backlog(self, depths: Mapping[ShapeBucket, int]) -> float:
        """Estimated seconds to drain everything already queued: the
        executor serves one batch at a time, so the wait is the sum over
        buckets of (whole batches queued) x (that bucket's service EMA)."""
        return sum(-(-depth // b.batch) * self.service.estimate(b)
                   for b, depth in depths.items() if depth > 0)

    def decide(self, req: Request, now: float,
               depths: Mapping[ShapeBucket, int],
               in_flight: float = 0.0) -> Decision:
        """Admission verdict at time ``now``.  ``in_flight`` is the
        estimated remaining service time of the batch occupying the
        executor (0 when idle); it delays every queued batch, so it adds
        to the backlog wait.  Still a pure function of its arguments —
        seeded traces with a fixed service model replay identically."""
        wait = in_flight + self._backlog(depths)
        # own bucket first; then (k-cap) smaller ceilings, largest first,
        # so a degraded request keeps as much of its k as the deadline allows
        ladder = [c for c in self.ceilings if c >= req.k] or \
                 [self.ceilings[-1]]
        candidates = ladder[:1]
        if self.allow_degrade:
            candidates += [c for c in reversed(self.ceilings) if c < req.k]
        for i, ceil in enumerate(candidates):
            bucket = bucket_of(min(req.k, ceil), req.n_probe,
                               self.ceilings, self.batch)
            finish = now + wait + self.service.estimate(bucket)
            if finish <= req.deadline - self.slack_margin:
                action = ACCEPT if i == 0 and ceil >= req.k else DEGRADE
                return Decision(action=action, bucket=bucket,
                                k=min(req.k, ceil), finish_est=finish)
        return Decision(action=SHED, bucket=None, k=req.k,
                        finish_est=now + wait)
