"""Fault-tolerant multi-replica router: affinity routing, health-checked
dispatch, timeouts + capped-backoff retries, hedged sends, and graceful
degradation — as one deterministic discrete-event loop.

``ReplicaServer`` generalizes the single-engine ``server.Server`` event loop
to a :class:`~repro.serving.replica.ReplicaPool`: every replica is its own
executor (one batch in flight at a time) with its own micro-batcher lanes,
and the router decides — from OBSERVABLE state only — where each admitted
request goes:

1. **affinity** — the replica whose decayed probed-centroid working set
   best overlaps the query's top coarse centroids (warm caches, warm
   per-bucket tau predictor), among replicas the health view calls healthy;
2. **least-loaded** — when no healthy replica has observed the query's
   centroids, the healthy replica with the fewest queued + in-flight
   requests (ties to the lowest replica id, so routing is deterministic);
3. **brownout** — when NO replica is healthy, the least-loaded replica
   that is merely *alive* (heartbeating but anomaly-flagged) serves the
   request and its outcome is marked ``degraded``: stale-but-alive beats
   unavailable.

Failure recovery is attempt-based.  Every dispatched attempt carries a
timeout (``deadline + timeout_mult x service_est``); an attempt that times
out, crashes with its replica, or fails response checksum verification is
marked dead, and when a request has no live attempts left it is re-routed
to a different replica after a capped exponential backoff — up to
``RetryPolicy.max_retries`` times, after which the request terminates
``FAILED`` (counted, never silently dropped).  Requests with enough slack
also schedule one **hedged** duplicate (``HedgePolicy``): if the primary
has not answered by ``deadline - slack_mult x est``, a second replica gets
the same request and the first response wins; the loser is withdrawn from
its lane when possible and ignored otherwise (counted as wasted work).

A supervisor monitor watches the health view: a replica that stops
heartbeating (crash, or a stall longer than the miss window) is respawned
after ``respawn_delay`` through ``ReplicaPool.respawn`` — fresh process,
checksummed predictor-state checkpoint restore, stranded lane requests
recovered by their attempts' timeouts.

Everything is driven by one ``heapq`` event queue keyed ``(t, seq)``; all
tie-breaks are explicit and all per-replica iteration is sorted, so a
seeded trace + seeded :class:`~repro.serving.faults.FaultSchedule` + fixed
service model replays to byte-identical outcome summaries
(:func:`outcome_digest` is the replay contract's fingerprint).
"""
from __future__ import annotations

import hashlib
import heapq
import itertools
import json
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.serving import admission as adm
from repro.serving import faults as flt
from repro.serving import health as hlt
from repro.serving import server as srv
from repro.serving.batcher import Batch, ShapeBucket, assemble, bucket_of
from repro.serving.queue import Request
from repro.serving.replica import ReplicaPool, ReplicaResponse
from repro.serving.state import ServingState


@dataclass(frozen=True)
class RetryPolicy:
    """Timeout + capped-exponential-backoff retry knobs.

    Two timeout regimes share the policy:

    * ``relative=False`` (the discrete-event tier's default) — an attempt
      times out at ``deadline + timeout_mult * est``: the micro-batcher may
      legitimately hold a request until just before its deadline, so only
      overshooting the deadline itself is evidence of failure.
    * ``relative=True`` (the socket front-end) — an attempt times out at
      ``now + timeout_mult * est``, TCP-RTO style: transport dispatch is
      immediate (no lane wait), so a response more than a few service times
      late means the frame was dropped or the worker is gone, and waiting
      for the deadline would let one lost frame eat the whole budget.

    ``clock`` is the optional injected monotonic clock for wall-clock
    callers that omit ``now`` (``compare=False``: two policies with the
    same knobs are the same policy regardless of who tells them the time).
    """

    max_retries: int = 2        # re-dispatches after the primary attempt
    timeout_mult: float = 4.0   # attempt times out at deadline + mult * est
    backoff_base: float = 0.01  # first retry delay (seconds)
    backoff_cap: float = 0.25   # exponential backoff ceiling (seconds)
    relative: bool = False      # time out relative to dispatch, not deadline
    clock: "object | None" = field(default=None, compare=False)

    def _now(self, now: float | None) -> float:
        if now is not None:
            return now
        if self.clock is None:
            raise ValueError(
                "RetryPolicy needs an explicit `now` unless a clock was "
                "injected at construction")
        return self.clock.now()

    def timeout_at(self, now: float | None, deadline: float,
                   est: float) -> float:
        now = self._now(now)
        base = now if self.relative else max(now, deadline)
        return base + self.timeout_mult * max(est, 1e-6)

    def backoff(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based), capped exponential."""
        return min(self.backoff_base * (2.0 ** (attempt - 1)),
                   self.backoff_cap)


@dataclass(frozen=True)
class HedgePolicy:
    """Hedged-send knobs: a duplicate fires when remaining slack falls to
    ``slack_mult`` estimated service times and the primary is still out."""

    enabled: bool = True
    slack_mult: float = 2.0


@dataclass(frozen=True)
class RouteDecision:
    """Where one attempt goes and why (``reason`` feeds the assignment log
    the determinism property-tests replay)."""

    replica: int
    brownout: bool
    reason: str                 # "affinity" | "least-loaded" | "brownout"


class Router:
    """Centroid-affinity routing over the health view's candidate sets."""

    def __init__(self, pool: ReplicaPool, health: hlt.HealthView,
                 centroids: np.ndarray, *, top_c: int = 4):
        self.pool = pool
        self.health = health
        self.centroids = np.asarray(centroids, np.float32)
        self.top_c = int(min(top_c, len(self.centroids)))

    def top_centroids(self, q: np.ndarray) -> np.ndarray:
        """The query's ``top_c`` nearest coarse centroids — the working-set
        overlap key (argsort, not argpartition: stable ties by centroid id
        keep routing deterministic)."""
        d = ((self.centroids - np.asarray(q, np.float32)[None]) ** 2).sum(1)
        return np.argsort(d, kind="stable")[: self.top_c]

    def _least_loaded(self, cands: Sequence[int]) -> int:
        return min(cands, key=lambda r: (self.pool[r].load(), r))

    def route(self, req: Request, now: float,
              exclude: frozenset[int] = frozenset()) -> RouteDecision | None:
        """Pick a replica for one attempt; None when nothing is alive.

        ``exclude`` holds replicas this request already failed on (and any
        it currently has a live attempt on — a hedge must diversify).  When
        exclusion empties the alive set the last resort is a brownout on
        ANY alive replica: a possibly-repeat replica beats a guaranteed
        FAILED."""
        healthy = [r for r in self.health.healthy(now) if r not in exclude]
        if healthy:
            top = self.top_centroids(req.q)
            scores = [(self.pool[r].affinity(top, now), r) for r in healthy]
            best, rid = max(scores, key=lambda sr: (sr[0], -sr[1]))
            if best > 0.0:
                return RouteDecision(rid, brownout=False, reason="affinity")
            return RouteDecision(self._least_loaded(healthy), brownout=False,
                                 reason="least-loaded")
        alive = [r for r in self.health.alive(now) if r not in exclude]
        if not alive:
            alive = self.health.alive(now)     # last resort: relax exclude
        if not alive:
            return None
        return RouteDecision(self._least_loaded(alive), brownout=True,
                             reason="brownout")


def outcome_digest(outcomes: Sequence[srv.Outcome]) -> str:
    """Replay fingerprint: sha256 over every outcome's terminal facts, in
    rid order.  Two runs of the same seeded trace + fault schedule + service
    model must produce equal digests — the byte-identical-replay gate in
    ``tests/test_replica.py`` and ``benchmarks/bench_failover.py``."""
    rows = [[o.request.rid, o.status, o.replica, o.retries, bool(o.hedged),
             round(o.t_done, 9), o.k_effective,
             None if o.ids is None else [int(i) for i in o.ids]]
            for o in sorted(outcomes, key=lambda o: o.request.rid)]
    return hashlib.sha256(
        json.dumps(rows, sort_keys=True).encode()).hexdigest()


# -- per-request attempt bookkeeping ----------------------------------------


@dataclass
class _Attempt:
    aid: int
    replica: int
    brownout: bool
    bucket: ShapeBucket
    kind: str                   # "primary" | "retry" | "hedge"
    dead: bool = False          # timed out / crashed / corrupt-rejected


@dataclass
class _Track:
    req: Request                # post-admission (possibly capped) request
    attempts: dict[int, _Attempt] = field(default_factory=dict)
    retries_used: int = 0
    hedged: bool = False
    hedge_scheduled: bool = False
    done: bool = False

    def live(self) -> list[_Attempt]:
        return [a for a in self.attempts.values() if not a.dead]

    def exclude(self) -> frozenset[int]:
        return frozenset(a.replica for a in self.attempts.values())

    def attempt_on(self, rid: int) -> _Attempt | None:
        """Latest attempt dispatched to ``rid`` (dead ones included —
        first-response-wins accepts a completion from a timed-out attempt)."""
        mine = [a for a in self.attempts.values() if a.replica == rid]
        return max(mine, key=lambda a: a.aid) if mine else None


class ReplicaServer:
    """The fault-tolerant serving tier's composition root."""

    def __init__(self, state: ServingState, n_replicas: int,
                 ceilings: Sequence[int], batch: int, *,
                 retry: RetryPolicy = RetryPolicy(),
                 hedge: HedgePolicy = HedgePolicy(),
                 ladder: adm.DegradeLadder | None = None,
                 faults: flt.FaultSchedule | None = None,
                 service_time_fn: Callable[[ShapeBucket], float]
                 | None = None,
                 slack_margin: float = 0.0, max_wait: float | None = None,
                 service_decay: float = 0.6, service_cold: float = 0.02,
                 hb_interval: float = 0.05, miss_factor: float = 3.0,
                 anomaly_factor: float = 3.0, respawn_delay: float = 0.1,
                 ws_decay: float = 2.0, top_c: int = 4,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 4):
        self.state = state
        self.retry = retry
        self.hedge = hedge
        self.ladder = ladder or adm.DegradeLadder()
        self.faults = faults or flt.FaultSchedule()
        self.service_time_fn = service_time_fn
        self.respawn_delay = float(respawn_delay)
        self.service = adm.ServiceEMA(decay=service_decay,
                                      cold=service_cold)
        self.pool = ReplicaPool(state, n_replicas, ceilings, batch,
                                service_est=self.service.estimate,
                                slack_margin=slack_margin,
                                max_wait=max_wait, ws_decay=ws_decay,
                                checkpoint_dir=checkpoint_dir,
                                checkpoint_every=checkpoint_every)
        self.health = hlt.HealthView(n_replicas, hb_interval=hb_interval,
                                     miss_factor=miss_factor,
                                     anomaly_factor=anomaly_factor)
        self.router = Router(self.pool, self.health, state.centroids,
                             top_c=top_c)
        self.admission = adm.AdmissionController(
            self.service, self.pool[0].batcher.ceilings, batch,
            allow_degrade=True, slack_margin=slack_margin)
        self.batch = int(batch)
        # fresh per run_trace
        self._events: list = []
        self._seq = itertools.count()
        self._aid = itertools.count()
        self._tracks: dict[int, _Track] = {}
        self._outcomes: dict[int, srv.Outcome] = {}
        self._epoch = [0] * n_replicas
        self._fire_at = [np.inf] * n_replicas
        self._respawn_pending: set[int] = set()
        self.assignments: list[tuple] = []     # (rid, aid, replica, kind)
        self.stats = {k: 0 for k in (
            "dispatched", "retries_sent", "hedges_sent", "hedges_won",
            "hedges_wasted", "timeouts", "corrupt_detected", "withdrawn",
            "respawns", "stranded_cleared", "late_ignored", "brownouts")}

    # -- event plumbing -----------------------------------------------------

    def _push(self, t: float, kind: str, data=None) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, data))

    def _schedule_fire(self, rid: int, now: float) -> None:
        """(Re)arm the fire event for one replica's batcher: immediately if
        any lane is full, else at the earliest slack-expiry instant.  The
        ``_fire_at`` latch keeps duplicate submits from stacking duplicate
        event chains."""
        b = self.pool[rid].batcher
        full = any(d >= bucket.batch for bucket, d in b.depths().items())
        due = now if full else b.next_fire_time(now)
        if due is not None and due < self._fire_at[rid]:
            self._fire_at[rid] = due
            self._push(due, "fire", rid)

    # -- warmup -------------------------------------------------------------

    def _trace_buckets(self, trace: Sequence[Request]) -> list[ShapeBucket]:
        """Every shape bucket the trace can hit: its own (k, n_probe)
        grid plus the degrade ladder's capped variants (a rung engaging
        mid-run must not trigger a cold engine build on the timeline)."""
        ceilings = self.pool[0].batcher.ceilings
        caps = [(None, None)] + [(kc, nc)
                                 for _, kc, nc, _rt in self.ladder.rungs]
        buckets = set()
        for r in trace:
            for k_cap, np_cap in caps:
                k = min(r.k, k_cap) if k_cap else r.k
                n_probe = min(r.n_probe, np_cap) if np_cap else r.n_probe
                buckets.add(bucket_of(min(k, ceilings[-1]), n_probe,
                                      ceilings, self.batch))
        return sorted(buckets)

    def warmup(self, trace: Sequence[Request]) -> "ReplicaServer":
        """Off-timeline precompile + service-EMA seeding for every bucket
        the trace (and the degrade ladder) can reach.  Engine builds land in
        the pool-shared cache, so one warmup covers every replica."""
        buckets = self._trace_buckets(trace)
        self.state.warmup(buckets)
        by_bucket = {}
        ceilings = self.pool[0].batcher.ceilings
        for r in trace:
            by_bucket.setdefault(
                bucket_of(min(r.k, ceilings[-1]), r.n_probe, ceilings,
                          self.batch), []).append(r)
        for bucket in buckets:
            if self.service_time_fn is not None:
                self.service.observe(bucket, self.service_time_fn(bucket))
                continue
            reqs = by_bucket.get(bucket)
            if not reqs:       # ladder-only variant: seed from the model
                continue       # bucket of an actual request measures below
            t_done, _ = self.pool[0].serve(
                assemble(bucket, reqs[: self.batch]), 0.0)
            self.service.observe(bucket, t_done)
        return self

    # -- admission + dispatch -----------------------------------------------

    def _load_factor(self, now: float) -> float:
        alive = self.health.alive(now)
        if not alive:
            return np.inf
        queued = sum(self.pool[r].load() for r in alive)
        return queued / (len(alive) * self.batch)

    def _wait_estimate(self, now: float) -> float:
        """What a new request would wait before service starts: the best
        (minimum) over alive replicas of in-flight remainder + lane
        backlog, at EMA estimates — observable state only."""
        alive = self.health.alive(now)
        if not alive:
            return np.inf
        waits = []
        for r in alive:
            rep = self.pool[r]
            w = max(0.0, rep.busy_until_est - now)
            w += sum(self.service.estimate(b.bucket) for b in rep.fired)
            w += sum(-(-d // b.batch) * self.service.estimate(b)
                     for b, d in rep.batcher.depths().items())
            waits.append(w)
        return min(waits)

    def _admit(self, req: Request, now: float) -> None:
        """Arrival: degrade ladder -> admission -> first dispatch."""
        req = self.ladder.apply(req, self._load_factor(now))
        dec = self.admission.decide(req, now, {},
                                    in_flight=self._wait_estimate(now))
        if dec.action == adm.SHED:
            self._terminal(req, srv.SHED, now)
            return
        req = req.k_capped(dec.k)
        track = _Track(req=req)
        self._tracks[req.rid] = track
        if not self._dispatch(track, now, kind="primary"):
            self._retry_or_fail(track, now)

    def _dispatch(self, track: _Track, now: float, kind: str) -> bool:
        req = track.req
        exclude = track.exclude() if kind != "primary" else frozenset()
        decision = self.router.route(req, now, exclude)
        if decision is None:
            return False
        rid = decision.replica
        bucket = self.pool[rid].batcher.submit(req)
        aid = next(self._aid)
        track.attempts[aid] = _Attempt(aid=aid, replica=rid,
                                       brownout=decision.brownout,
                                       bucket=bucket, kind=kind)
        self.assignments.append((req.rid, aid, rid, kind, decision.reason))
        self.stats["dispatched"] += 1
        if decision.brownout:
            self.stats["brownouts"] += 1
        est = self.service.estimate(bucket)
        self._push(self.retry.timeout_at(now, req.deadline, est),
                   "timeout", (req.rid, aid))
        if kind == "primary" and self.hedge.enabled and \
                not track.hedge_scheduled:
            t_h = req.deadline - self.hedge.slack_mult * est
            if t_h > now:
                track.hedge_scheduled = True
                self._push(t_h, "hedge", req.rid)
        self._schedule_fire(rid, now)
        return True

    def _retry_or_fail(self, track: _Track, now: float) -> None:
        """No live attempts left: back off and re-route, or terminate."""
        if track.done:
            return
        if track.retries_used >= self.retry.max_retries:
            self._terminal(track.req, srv.FAILED, now, track=track)
            return
        track.retries_used += 1
        self._push(now + self.retry.backoff(track.retries_used),
                   "retry", track.req.rid)

    def _terminal(self, req: Request, status: str, now: float,
                  track: _Track | None = None) -> None:
        if track is not None:
            track.done = True
        self._outcomes[req.rid] = srv.Outcome(
            request=req, status=status, bucket=None, ids=None, dists=None,
            t_done=now, k_effective=0,
            retries=track.retries_used if track else 0,
            hedged=track.hedged if track else False)

    # -- executor -----------------------------------------------------------

    def _start_next(self, rid: int, now: float) -> None:
        rep = self.pool[rid]
        if rep.in_flight is not None or not rep.fired:
            return
        batch = rep.fired.popleft()
        rep.in_flight = batch
        est = self.service.estimate(batch.bucket)
        rep.busy_until_est = now + est
        t_done, resp = rep.serve(batch, now, self.faults,
                                 self.service_time_fn)
        if t_done is None:
            return     # crash mid-service: the batch never completes
        self._push(t_done, "done",
                   (rid, self._epoch[rid], batch, resp, now, est))

    def _on_done(self, rid: int, epoch: int, batch: Batch,
                 resp: ReplicaResponse, t_start: float, est: float,
                 now: float) -> None:
        if epoch != self._epoch[rid]:
            return     # completion from a pre-respawn process: discard
        rep = self.pool[rid]
        rep.in_flight = None
        dt = now - t_start
        self.health.beat(rid, now)                    # progress == liveness
        self.health.observe(rid, dt, baseline=est)    # anomaly ratio
        self.service.observe(batch.bucket, dt)
        ok = resp.verified()
        if not ok:
            self.stats["corrupt_detected"] += 1
        for j, req in enumerate(batch.requests):
            track = self._tracks.get(req.rid)
            if track is None or track.done:
                self.stats["late_ignored"] += 1
                continue
            att = track.attempt_on(rid)
            if not ok:
                if att is not None and not att.dead:
                    att.dead = True
                if not track.live():
                    self._retry_or_fail(track, now)
                continue
            self._accept(track, att, rid, batch, resp, j, now)
        for q_top in [self.router.top_centroids(r.q)
                      for r in batch.requests]:
            rep.note_probed(q_top, now)
        self.pool.maybe_checkpoint(rid)
        self._schedule_fire(rid, now)
        self._start_next(rid, now)

    def _accept(self, track: _Track, att: _Attempt | None, rid: int,
                batch: Batch, resp: ReplicaResponse, j: int,
                now: float) -> None:
        """First response wins: emit the outcome, withdraw or write off
        every other attempt."""
        track.done = True
        req = track.req
        d_j, i_j = srv.trim_topk(resp.dists[j], resp.ids[j], req.k)
        brownout = bool(att.brownout) if att is not None else False
        status = srv.DEGRADED if (req.degraded or brownout) else srv.OK
        won_hedge = att is not None and att.kind == "hedge"
        if won_hedge:
            self.stats["hedges_won"] += 1
        self._outcomes[req.rid] = srv.Outcome(
            request=req, status=status, bucket=batch.bucket,
            ids=i_j.copy(), dists=d_j.copy(), t_done=now,
            k_effective=req.k, replica=rid,
            retries=track.retries_used, hedged=track.hedged)
        for other in track.live():
            if other is att:
                continue
            if self.pool[other.replica].batcher.withdraw(req.rid) \
                    is not None:
                self.stats["withdrawn"] += 1
            other.dead = True
            if other.kind == "hedge" or won_hedge:
                self.stats["hedges_wasted"] += 1

    # -- failure-path handlers ----------------------------------------------

    def _on_timeout(self, rid_req: int, aid: int, now: float) -> None:
        track = self._tracks.get(rid_req)
        if track is None or track.done:
            return
        att = track.attempts.get(aid)
        if att is None or att.dead:
            return
        att.dead = True
        self.stats["timeouts"] += 1
        if self.pool[att.replica].batcher.withdraw(rid_req) is not None:
            self.stats["withdrawn"] += 1
        if not track.live():
            self._retry_or_fail(track, now)

    def _on_retry(self, rid_req: int, now: float) -> None:
        track = self._tracks.get(rid_req)
        if track is None or track.done:
            return
        self.stats["retries_sent"] += 1
        if not self._dispatch(track, now, kind="retry"):
            self._retry_or_fail(track, now)

    def _on_hedge(self, rid_req: int, now: float) -> None:
        track = self._tracks.get(rid_req)
        if track is None or track.done or len(track.live()) != 1:
            return     # already answered, or already on the retry path
        if self._dispatch(track, now, kind="hedge"):
            track.hedged = True
            self.stats["hedges_sent"] += 1

    # -- supervisor ---------------------------------------------------------

    def _on_heartbeat(self, rid: int, now: float) -> None:
        since = self.pool[rid].respawned_at
        if self.faults.crashed(rid, now, since=since):
            return     # dead process: beats stop until the respawn
        if not self.faults.stalled(rid, now, since=since):
            self.health.beat(rid, now)
        self._push(now + self.health.hb_interval, "hb", rid)

    def _on_monitor(self, now: float) -> None:
        """Supervisor sweep: respawn replicas the health view declares DOWN
        (crashed, or hung past the heartbeat-miss window)."""
        for rid in range(len(self.pool)):
            if rid in self._respawn_pending:
                continue
            if self.health.status(rid, now) == hlt.DOWN:
                self._respawn_pending.add(rid)
                self._push(now + self.respawn_delay, "respawn", rid)
        self._push(now + self.health.hb_interval * self.health.miss_factor,
                   "monitor", None)

    def _on_respawn(self, rid: int, now: float) -> None:
        self._respawn_pending.discard(rid)
        self.stats["respawns"] += 1
        stranded = self.pool[rid].batcher.pending()
        self.stats["stranded_cleared"] += stranded
        self.pool.respawn(rid, now)
        self._epoch[rid] += 1
        self._fire_at[rid] = np.inf
        self.health.reset(rid, now)
        self._push(now + self.health.hb_interval, "hb", rid)

    # -- the loop -----------------------------------------------------------

    def run_trace(self, trace: Sequence[Request],
                  warmup: bool = True) -> list[srv.Outcome]:
        """Serve a whole seeded trace through the pool; returns outcomes in
        rid order, one per offered request (conservation by construction:
        every request terminates OK, DEGRADED, SHED, or FAILED)."""
        trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        if warmup and trace:
            self.warmup(trace)
        self._events = []
        self._seq = itertools.count()
        self._aid = itertools.count()
        self._tracks = {}
        self._outcomes = {}
        self._epoch = [0] * len(self.pool)
        self._fire_at = [np.inf] * len(self.pool)
        self._respawn_pending = set()
        self.assignments = []
        t0 = trace[0].arrival if trace else 0.0
        self.health.start(t0)
        for rep in self.pool:
            rep.reset(rep.state, t0)
            rep.respawned_at = -np.inf
        for req in trace:
            self._push(req.arrival, "arrive", req)
        for rid in range(len(self.pool)):
            self._push(t0 + self.health.hb_interval, "hb", rid)
        self._push(t0 + self.health.hb_interval * self.health.miss_factor,
                   "monitor", None)

        while self._events and len(self._outcomes) < len(trace):
            t, _, kind, data = heapq.heappop(self._events)
            if kind == "arrive":
                self._admit(data, t)
            elif kind == "fire":
                rid = data
                self._fire_at[rid] = np.inf
                if self.faults.crashed(rid, t,
                                       since=self.pool[rid].respawned_at):
                    continue     # dead process: lanes strand until respawn
                self.pool[rid].fired.extend(
                    self.pool[rid].batcher.fire_ready(t))
                self._schedule_fire(rid, t)
                self._start_next(rid, t)
            elif kind == "done":
                rid, epoch, batch, resp, t_start, est = data
                self._on_done(rid, epoch, batch, resp, t_start, est, t)
            elif kind == "timeout":
                self._on_timeout(data[0], data[1], t)
            elif kind == "retry":
                self._on_retry(data, t)
            elif kind == "hedge":
                self._on_hedge(data, t)
            elif kind == "hb":
                self._on_heartbeat(data, t)
            elif kind == "monitor":
                self._on_monitor(t)
            elif kind == "respawn":
                self._on_respawn(data, t)

        # safety net: anything still untracked terminates FAILED (the event
        # queue draining early would otherwise drop requests silently and
        # break the conservation gate)
        t_end = max((o.t_done for o in self._outcomes.values()), default=t0)
        for req in trace:
            if req.rid not in self._outcomes:
                self._terminal(req, srv.FAILED, t_end,
                               track=self._tracks.get(req.rid))
        return [self._outcomes[r.rid]
                for r in sorted(trace, key=lambda r: r.rid)]
