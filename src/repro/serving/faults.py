"""Deterministic fault injection at the replica service boundary.

The multi-replica tier is only production-shaped if it survives replicas
that stall, crash, or lie — and a fault run is only debuggable if it
REPLAYS.  This module therefore models faults as a static, fully seeded
:class:`FaultSchedule`: a sorted tuple of :class:`Fault` records, each
pinned to (replica, time).  The schedule is consulted exclusively inside
``Replica.serve`` and the replica-side heartbeat — the service boundary —
so the router sees only the observable consequences (missed heartbeats,
overdue batches, checksum mismatches) and cannot cheat by peeking at the
schedule.

Fault taxonomy:

=========  ===============================================================
kind       effect at the service boundary
=========  ===============================================================
crash      the replica dies at ``t``: an in-flight batch never completes,
           queued work is stranded, heartbeats stop.  One-shot; a
           supervisor may respawn the replica after a delay (the respawn
           consumes the crash).
stall      for ``duration`` seconds from ``t`` the replica makes no
           progress: any batch whose service overlaps the window finishes
           ``duration`` late, and heartbeats inside the window are
           suppressed (so the health view sees the stall).
slow       batches STARTED inside ``[t, t + duration)`` take ``factor``
           times their normal service time (e.g. a noisy neighbor); the
           health view's service-time anomaly detector is the defense.
corrupt    responses to batches started inside the window have their
           payload corrupted AFTER the integrity checksum is computed —
           the router's checksum verification must catch it and retry.
=========  ===============================================================

Schedules come from either a spec string (``--faults`` on the serving CLI;
see :meth:`FaultSchedule.parse`) or a seeded generator
(:meth:`FaultSchedule.seeded`).  Both are pure data: identical spec/seed ⇒
identical schedule ⇒ (with a fixed service model) byte-identical outcome
summaries — the deterministic replay contract ``tests/test_replica.py``
and ``benchmarks/bench_failover.py`` gate on.
"""
from __future__ import annotations

import hashlib
import zlib
from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

CRASH = "crash"
STALL = "stall"
SLOW = "slow"
CORRUPT = "corrupt"
KINDS = (CRASH, STALL, SLOW, CORRUPT)

# -- wire-fault taxonomy (the transport tier's failure surface) --------------
#
# Process faults above model what a REPLICA does wrong; these model what the
# NETWORK does wrong, applied per frame at the proxy shim between the master
# and each worker connection (repro.transport):
#
# ==========  ==============================================================
# kind        effect at the shim
# ==========  ==============================================================
# drop        the frame silently never arrives (attempt timeouts recover it)
# dup         the frame is delivered twice (receivers must be idempotent;
#             the duplicate response is counted, never double-completed)
# slow        delivery is delayed by base + jitter seconds (slow network;
#             the per-attempt timeout and p99 gates are the defense)
# truncate    outbound only: a partial prefix of the frame's bytes is
#             written and the connection closed — the peer's frame reader
#             sees EOF mid-frame (the partial-write case)
# disconnect  the connection closes before the frame is delivered
#             (disconnect-mid-response when it hits a response frame)
# ==========  ==============================================================
WIRE_DROP = "drop"
WIRE_DUP = "dup"
WIRE_SLOW = "slow"
WIRE_TRUNCATE = "truncate"
WIRE_DISCONNECT = "disconnect"
WIRE_KINDS = (WIRE_DROP, WIRE_DUP, WIRE_SLOW, WIRE_TRUNCATE, WIRE_DISCONNECT)


@dataclass(frozen=True, order=True)
class Fault:
    """One injected fault, pinned to (time, replica)."""

    t: float                 # injection instant (trace clock, seconds)
    replica: int             # target replica id
    kind: str                # CRASH | STALL | SLOW | CORRUPT
    duration: float = 0.0    # window length (stall/slow/corrupt)
    factor: float = 1.0      # service-time multiplier (slow)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.kind != CRASH and self.duration <= 0:
            raise ValueError(f"{self.kind} fault needs duration > 0")
        if self.kind == SLOW and self.factor <= 1.0:
            raise ValueError(f"slow fault needs factor > 1, "
                             f"got {self.factor}")

    def active(self, now: float) -> bool:
        return self.t <= now < self.t + self.duration


class FaultSchedule:
    """Immutable, sorted set of faults with boundary-side query helpers."""

    def __init__(self, faults: Iterable[Fault] = ()):
        self.faults = tuple(sorted(faults))

    def __len__(self) -> int:
        return len(self.faults)

    def __bool__(self) -> bool:
        return bool(self.faults)

    def for_replica(self, rid: int) -> tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.replica == rid)

    # -- construction -------------------------------------------------------

    @staticmethod
    def parse(spec: str) -> "FaultSchedule":
        """Parse a ``--faults`` spec string.

        Grammar: ``kind@replica:key=val[,key=val…]`` joined by ``;`` —
        e.g. ``crash@1:t=0.5;stall@2:t=1.0,dur=0.4;``
        ``slow@0:t=0.2,dur=1.0,factor=4;corrupt@3:t=0.8,dur=0.3``.
        """
        faults = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            try:
                head, params = part.split(":", 1)
                kind, rid = head.split("@", 1)
                kv = dict(item.split("=", 1)
                          for item in params.split(",") if item)
                faults.append(Fault(
                    t=float(kv.pop("t")), replica=int(rid),
                    kind=kind.strip(),
                    duration=float(kv.pop("dur", 0.0)),
                    factor=float(kv.pop("factor", 1.0))))
                if kv:
                    raise ValueError(f"unknown keys {sorted(kv)}")
            except (KeyError, ValueError) as e:
                raise ValueError(
                    f"bad fault spec {part!r}: {e} — expected "
                    f"kind@replica:t=SECONDS[,dur=S][,factor=F]") from e
        return FaultSchedule(faults)

    @staticmethod
    def seeded(rng: np.random.Generator, n_replicas: int, horizon: float,
               n_faults: int = 4,
               kinds: Sequence[str] = KINDS) -> "FaultSchedule":
        """Seeded random schedule: ``n_faults`` faults uniform over the
        middle 80% of ``[0, horizon]`` (faults at the very edges are
        uninteresting — nothing in flight), kinds and replicas drawn from
        the rng.  Identical (seed, args) ⇒ identical schedule."""
        faults = []
        for _ in range(n_faults):
            kind = str(rng.choice(list(kinds)))
            faults.append(Fault(
                t=float(rng.uniform(0.1, 0.9)) * horizon,
                replica=int(rng.integers(n_replicas)),
                kind=kind,
                duration=(0.0 if kind == CRASH
                          else float(rng.uniform(0.05, 0.25)) * horizon),
                factor=(float(rng.choice([2.0, 4.0, 8.0]))
                        if kind == SLOW else 1.0)))
        return FaultSchedule(faults)

    # -- boundary-side queries ----------------------------------------------
    #
    # ``since`` is the replica's last respawn time: a supervisor restart
    # consumes every fault at or before it, so a respawned replica is only
    # subject to faults injected AFTER it came back.

    def crashed(self, rid: int, now: float, since: float = -np.inf) -> bool:
        return any(f.kind == CRASH and since < f.t <= now
                   for f in self.faults if f.replica == rid)

    def crash_times(self, rid: int) -> tuple[float, ...]:
        return tuple(f.t for f in self.faults
                     if f.replica == rid and f.kind == CRASH)

    def stalled(self, rid: int, now: float,
                since: float = -np.inf) -> bool:
        """True while a stall window covers ``now`` (heartbeats suppressed)."""
        return any(f.kind == STALL and f.t > since and f.active(now)
                   for f in self.faults if f.replica == rid)

    def corrupts(self, rid: int, t_start: float,
                 since: float = -np.inf) -> bool:
        """True when a batch STARTED at ``t_start`` gets a corrupt response."""
        return any(f.kind == CORRUPT and f.t > since and f.active(t_start)
                   for f in self.faults if f.replica == rid)

    def perturb(self, rid: int, t_start: float, dt: float,
                since: float = -np.inf) -> tuple[float, bool]:
        """Fault-adjusted service time for a batch started at ``t_start``.

        Returns ``(dt_adjusted, completes)``: slow faults active at the
        start multiply ``dt``, stall windows intersecting the (stretched)
        service interval add their full duration, and a crash anywhere in
        ``(since, t_start + dt_adjusted]`` means the batch NEVER completes
        (``completes=False`` — its requests are recovered by timeouts)."""
        out = float(dt)
        mine = [f for f in self.faults if f.replica == rid and f.t > since]
        for f in mine:
            if f.kind == SLOW and f.active(t_start):
                out *= f.factor
        for f in mine:     # stalls extend the already-stretched interval
            if f.kind == STALL and f.t < t_start + out and \
                    f.t + f.duration > t_start:
                out += f.duration
        for f in mine:
            if f.kind == CRASH and f.t <= t_start + out:
                return out, False
        return out, True


# --------------------------------------------------------------------------
# Response integrity (the corrupt fault's detection surface)
# --------------------------------------------------------------------------

def payload_checksum(dists: np.ndarray, ids: np.ndarray) -> int:
    """CRC over the result payload.  The replica computes it over the TRUE
    payload before the fault layer touches anything; the router recomputes
    it over what it received — a corrupt fault therefore surfaces as a
    checksum mismatch, exactly like a wire-level integrity check would."""
    crc = zlib.crc32(np.ascontiguousarray(dists).tobytes())
    return zlib.crc32(np.ascontiguousarray(ids).tobytes(), crc)


def corrupt_payload(ids: np.ndarray) -> np.ndarray:
    """Deterministic payload corruption: flip the low bit of every id —
    plausible-looking, definitely-wrong results (the worst case for a
    router that trusts payloads)."""
    return np.asarray(ids) ^ 1


# --------------------------------------------------------------------------
# Wire faults (the transport shim's schedule)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class WireDecision:
    """The shim's verdict for one frame: a fault kind (or None = deliver
    cleanly) plus the injected delay for ``slow``."""

    kind: str | None = None
    delay: float = 0.0


class WireSchedule:
    """Seeded per-frame wire-fault decisions, independent of wall time.

    A decision is a pure hash of ``(seed, worker, direction, seq)`` where
    ``seq`` is the per-(worker, direction) frame counter — NOT the clock —
    so the schedule commits to "the 7th frame up to worker 2 is dropped"
    before the run starts.  Two live runs under real-time jitter make the
    same per-frame calls, and the transcript a live run records needs to
    store only the decisions actually taken; nothing about the schedule
    depends on when a frame happened to be ready.

    Rates are independent probabilities per kind (their sum must stay
    <= 1; the remainder is clean delivery).  ``slow`` delays by
    ``slow_base + u * slow_jitter`` with ``u`` from the same hash, giving
    seeded latency jitter.
    """

    def __init__(self, *, seed: int = 0, drop: float = 0.0, dup: float = 0.0,
                 slow: float = 0.0, truncate: float = 0.0,
                 disconnect: float = 0.0, slow_base: float = 0.002,
                 slow_jitter: float = 0.004):
        rates = {WIRE_DROP: float(drop), WIRE_DUP: float(dup),
                 WIRE_SLOW: float(slow), WIRE_TRUNCATE: float(truncate),
                 WIRE_DISCONNECT: float(disconnect)}
        for kind, p in rates.items():
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{kind} rate must be in [0, 1], got {p}")
        if sum(rates.values()) > 1.0:
            raise ValueError(
                f"wire-fault rates must sum to <= 1, got {rates}")
        if slow_base < 0 or slow_jitter < 0:
            raise ValueError("slow_base / slow_jitter must be >= 0")
        self.seed = int(seed)
        self.rates = rates
        self.slow_base = float(slow_base)
        self.slow_jitter = float(slow_jitter)

    def __bool__(self) -> bool:
        return any(p > 0 for p in self.rates.values())

    def _uniforms(self, worker: int, direction: str,
                  seq: int) -> tuple[float, float]:
        h = hashlib.sha256(
            f"{self.seed}|{worker}|{direction}|{seq}".encode()).digest()
        u1 = int.from_bytes(h[:8], "big") / 2.0 ** 64
        u2 = int.from_bytes(h[8:16], "big") / 2.0 ** 64
        return u1, u2

    def decide(self, worker: int, direction: str, seq: int) -> WireDecision:
        """Fault verdict for frame ``seq`` in ``direction`` ("up" =
        master->worker, "down" = worker->master) on ``worker``'s link."""
        if direction not in ("up", "down"):
            raise ValueError(f"direction must be 'up' or 'down', "
                             f"got {direction!r}")
        u1, u2 = self._uniforms(worker, direction, seq)
        acc = 0.0
        for kind in WIRE_KINDS:
            acc += self.rates[kind]
            if u1 < acc:
                delay = (self.slow_base + u2 * self.slow_jitter
                         if kind == WIRE_SLOW else 0.0)
                return WireDecision(kind=kind, delay=delay)
        return WireDecision()

    # -- construction / reporting -------------------------------------------

    @staticmethod
    def parse(spec: str) -> "WireSchedule":
        """Parse a ``--wire-faults`` spec string.

        Grammar: comma-separated ``key=value`` — rate keys are the kinds
        (``drop=0.02,slow=0.1,disconnect=0.01``), ``slow_ms=BASE:JITTER``
        sets the slow-delay model in milliseconds, ``seed=N`` the decision
        seed.  Empty spec = no wire faults."""
        kw: dict = {}
        for item in filter(None, (p.strip() for p in spec.split(","))):
            try:
                key, val = item.split("=", 1)
            except ValueError as e:
                raise ValueError(
                    f"bad wire-fault item {item!r}: expected key=value") \
                    from e
            key = key.strip()
            if key == "seed":
                kw["seed"] = int(val)
            elif key == "slow_ms":
                base, _, jitter = val.partition(":")
                kw["slow_base"] = float(base) * 1e-3
                kw["slow_jitter"] = float(jitter or 0.0) * 1e-3
            elif key in WIRE_KINDS:
                kw[key] = float(val)
            else:
                raise ValueError(
                    f"unknown wire-fault key {key!r}; expected one of "
                    f"{WIRE_KINDS + ('slow_ms', 'seed')}")
        return WireSchedule(**kw)

    def to_dict(self) -> dict:
        """Transcript-header form: everything needed to reconstruct the
        schedule (replay never re-decides, but the header documents what
        the live run was subjected to)."""
        return {"seed": self.seed, **self.rates,
                "slow_base": self.slow_base, "slow_jitter": self.slow_jitter}
