"""Deterministic fault injection at the replica service boundary.

The multi-replica tier is only production-shaped if it survives replicas
that stall, crash, or lie — and a fault run is only debuggable if it
REPLAYS.  This module therefore models faults as a static, fully seeded
:class:`FaultSchedule`: a sorted tuple of :class:`Fault` records, each
pinned to (replica, time).  The schedule is consulted exclusively inside
``Replica.serve`` and the replica-side heartbeat — the service boundary —
so the router sees only the observable consequences (missed heartbeats,
overdue batches, checksum mismatches) and cannot cheat by peeking at the
schedule.

Fault taxonomy:

=========  ===============================================================
kind       effect at the service boundary
=========  ===============================================================
crash      the replica dies at ``t``: an in-flight batch never completes,
           queued work is stranded, heartbeats stop.  One-shot; a
           supervisor may respawn the replica after a delay (the respawn
           consumes the crash).
stall      for ``duration`` seconds from ``t`` the replica makes no
           progress: any batch whose service overlaps the window finishes
           ``duration`` late, and heartbeats inside the window are
           suppressed (so the health view sees the stall).
slow       batches STARTED inside ``[t, t + duration)`` take ``factor``
           times their normal service time (e.g. a noisy neighbor); the
           health view's service-time anomaly detector is the defense.
corrupt    responses to batches started inside the window have their
           payload corrupted AFTER the integrity checksum is computed —
           the router's checksum verification must catch it and retry.
=========  ===============================================================

Schedules come from either a spec string (``--faults`` on the serving CLI;
see :meth:`FaultSchedule.parse`) or a seeded generator
(:meth:`FaultSchedule.seeded`).  Both are pure data: identical spec/seed ⇒
identical schedule ⇒ (with a fixed service model) byte-identical outcome
summaries — the deterministic replay contract ``tests/test_replica.py``
and ``benchmarks/bench_failover.py`` gate on.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

CRASH = "crash"
STALL = "stall"
SLOW = "slow"
CORRUPT = "corrupt"
KINDS = (CRASH, STALL, SLOW, CORRUPT)


@dataclass(frozen=True, order=True)
class Fault:
    """One injected fault, pinned to (time, replica)."""

    t: float                 # injection instant (trace clock, seconds)
    replica: int             # target replica id
    kind: str                # CRASH | STALL | SLOW | CORRUPT
    duration: float = 0.0    # window length (stall/slow/corrupt)
    factor: float = 1.0      # service-time multiplier (slow)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.kind != CRASH and self.duration <= 0:
            raise ValueError(f"{self.kind} fault needs duration > 0")
        if self.kind == SLOW and self.factor <= 1.0:
            raise ValueError(f"slow fault needs factor > 1, "
                             f"got {self.factor}")

    def active(self, now: float) -> bool:
        return self.t <= now < self.t + self.duration


class FaultSchedule:
    """Immutable, sorted set of faults with boundary-side query helpers."""

    def __init__(self, faults: Iterable[Fault] = ()):
        self.faults = tuple(sorted(faults))

    def __len__(self) -> int:
        return len(self.faults)

    def __bool__(self) -> bool:
        return bool(self.faults)

    def for_replica(self, rid: int) -> tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.replica == rid)

    # -- construction -------------------------------------------------------

    @staticmethod
    def parse(spec: str) -> "FaultSchedule":
        """Parse a ``--faults`` spec string.

        Grammar: ``kind@replica:key=val[,key=val…]`` joined by ``;`` —
        e.g. ``crash@1:t=0.5;stall@2:t=1.0,dur=0.4;``
        ``slow@0:t=0.2,dur=1.0,factor=4;corrupt@3:t=0.8,dur=0.3``.
        """
        faults = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            try:
                head, params = part.split(":", 1)
                kind, rid = head.split("@", 1)
                kv = dict(item.split("=", 1)
                          for item in params.split(",") if item)
                faults.append(Fault(
                    t=float(kv.pop("t")), replica=int(rid),
                    kind=kind.strip(),
                    duration=float(kv.pop("dur", 0.0)),
                    factor=float(kv.pop("factor", 1.0))))
                if kv:
                    raise ValueError(f"unknown keys {sorted(kv)}")
            except (KeyError, ValueError) as e:
                raise ValueError(
                    f"bad fault spec {part!r}: {e} — expected "
                    f"kind@replica:t=SECONDS[,dur=S][,factor=F]") from e
        return FaultSchedule(faults)

    @staticmethod
    def seeded(rng: np.random.Generator, n_replicas: int, horizon: float,
               n_faults: int = 4,
               kinds: Sequence[str] = KINDS) -> "FaultSchedule":
        """Seeded random schedule: ``n_faults`` faults uniform over the
        middle 80% of ``[0, horizon]`` (faults at the very edges are
        uninteresting — nothing in flight), kinds and replicas drawn from
        the rng.  Identical (seed, args) ⇒ identical schedule."""
        faults = []
        for _ in range(n_faults):
            kind = str(rng.choice(list(kinds)))
            faults.append(Fault(
                t=float(rng.uniform(0.1, 0.9)) * horizon,
                replica=int(rng.integers(n_replicas)),
                kind=kind,
                duration=(0.0 if kind == CRASH
                          else float(rng.uniform(0.05, 0.25)) * horizon),
                factor=(float(rng.choice([2.0, 4.0, 8.0]))
                        if kind == SLOW else 1.0)))
        return FaultSchedule(faults)

    # -- boundary-side queries ----------------------------------------------
    #
    # ``since`` is the replica's last respawn time: a supervisor restart
    # consumes every fault at or before it, so a respawned replica is only
    # subject to faults injected AFTER it came back.

    def crashed(self, rid: int, now: float, since: float = -np.inf) -> bool:
        return any(f.kind == CRASH and since < f.t <= now
                   for f in self.faults if f.replica == rid)

    def crash_times(self, rid: int) -> tuple[float, ...]:
        return tuple(f.t for f in self.faults
                     if f.replica == rid and f.kind == CRASH)

    def stalled(self, rid: int, now: float,
                since: float = -np.inf) -> bool:
        """True while a stall window covers ``now`` (heartbeats suppressed)."""
        return any(f.kind == STALL and f.t > since and f.active(now)
                   for f in self.faults if f.replica == rid)

    def corrupts(self, rid: int, t_start: float,
                 since: float = -np.inf) -> bool:
        """True when a batch STARTED at ``t_start`` gets a corrupt response."""
        return any(f.kind == CORRUPT and f.t > since and f.active(t_start)
                   for f in self.faults if f.replica == rid)

    def perturb(self, rid: int, t_start: float, dt: float,
                since: float = -np.inf) -> tuple[float, bool]:
        """Fault-adjusted service time for a batch started at ``t_start``.

        Returns ``(dt_adjusted, completes)``: slow faults active at the
        start multiply ``dt``, stall windows intersecting the (stretched)
        service interval add their full duration, and a crash anywhere in
        ``(since, t_start + dt_adjusted]`` means the batch NEVER completes
        (``completes=False`` — its requests are recovered by timeouts)."""
        out = float(dt)
        mine = [f for f in self.faults if f.replica == rid and f.t > since]
        for f in mine:
            if f.kind == SLOW and f.active(t_start):
                out *= f.factor
        for f in mine:     # stalls extend the already-stretched interval
            if f.kind == STALL and f.t < t_start + out and \
                    f.t + f.duration > t_start:
                out += f.duration
        for f in mine:
            if f.kind == CRASH and f.t <= t_start + out:
                return out, False
        return out, True


# --------------------------------------------------------------------------
# Response integrity (the corrupt fault's detection surface)
# --------------------------------------------------------------------------

def payload_checksum(dists: np.ndarray, ids: np.ndarray) -> int:
    """CRC over the result payload.  The replica computes it over the TRUE
    payload before the fault layer touches anything; the router recomputes
    it over what it received — a corrupt fault therefore surfaces as a
    checksum mismatch, exactly like a wire-level integrity check would."""
    crc = zlib.crc32(np.ascontiguousarray(dists).tobytes())
    return zlib.crc32(np.ascontiguousarray(ids).tobytes(), crc)


def corrupt_payload(ids: np.ndarray) -> np.ndarray:
    """Deterministic payload corruption: flip the low bit of every id —
    plausible-looking, definitely-wrong results (the worst case for a
    router that trusts payloads)."""
    return np.asarray(ids) ^ 1
