"""Request queue + synthetic arrival traces for the async serving subsystem.

A serving request is one query vector plus the retrieval parameters the
paper's workload varies per caller (``k``, ``n_probe``) and the timing facts
the scheduler reasons about (arrival time, absolute deadline).  The queue is
a plain arrival-ordered FIFO: scheduling intelligence lives in ``batcher``
(shape-bucketed assembly) and ``admission`` (shed / k-cap) — the queue only
owns ordering, validation, and O(1) peeks at the oldest entry, which is what
the fire-on-slack rule needs.

Synthetic traces model the two open-loop arrival regimes the serving
benchmarks exercise: ``poisson`` (memoryless traffic at a target mean rate)
and ``bursty`` (the same mean rate arriving in fixed-size bursts — the worst
case for a fixed-batch loop and the motivating case for deadline-aware
micro-batching).  Both are fully determined by the caller's ``rng``, so a
seeded trace replays identically (the admission tests rely on this).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, replace
from typing import Iterable, Sequence

import numpy as np


@dataclass(frozen=True, eq=False)
class Request:
    """One retrieval request.

    ``deadline`` is absolute, on the same clock as ``arrival``.  When
    admission k-caps a request, ``k`` holds the effective value the engine
    will run and ``k_requested`` records what the caller asked for.

    ``recall_target`` is the caller's recall@k requirement (None = no
    stated requirement) — the DegradeLadder may lower it under overload
    (``recall_capped``), serving the request at a cheaper tuned operating
    point; ``recall_requested`` records the original so the outcome is
    flagged ``degraded``, never silently coarser.
    """

    rid: int
    q: np.ndarray            # (d,) query vector
    k: int
    n_probe: int
    arrival: float
    deadline: float
    k_requested: int | None = None
    n_probe_requested: int | None = None
    recall_target: float | None = None
    recall_requested: float | None = None

    def __post_init__(self):
        # Validate at construction, not only at queue intake: the fault /
        # retry layer synthesizes requests (k-caps, n_probe-caps, hedged
        # duplicates) that never pass through RequestQueue.push, and a
        # malformed retry must fail loudly instead of corrupting the
        # scheduler's timeline.
        if self.k <= 0:
            raise ValueError(
                f"request {self.rid}: k must be >= 1, got {self.k}")
        # the embedding itself is untrusted input at the transport boundary:
        # a NaN/Inf query poisons every distance it touches (NaN propagates
        # through the whole top-k selection), so it must die here with a
        # typed error instead of surfacing as garbage results downstream
        q = np.asarray(self.q)
        if q.ndim != 1 or q.size == 0:
            raise ValueError(
                f"request {self.rid}: q must be a non-empty 1-D vector, "
                f"got shape {q.shape}")
        if not np.issubdtype(q.dtype, np.floating) and \
                not np.issubdtype(q.dtype, np.integer):
            raise ValueError(
                f"request {self.rid}: q must be numeric, got dtype {q.dtype}")
        if not np.all(np.isfinite(q)):
            raise ValueError(
                f"request {self.rid}: q must be finite (no NaN/Inf)")
        if self.n_probe <= 0:
            raise ValueError(
                f"request {self.rid}: n_probe must be >= 1, "
                f"got {self.n_probe}")
        if not np.isfinite(self.deadline) or self.deadline < 0:
            raise ValueError(
                f"request {self.rid}: deadline must be finite and "
                f">= 0, got {self.deadline}")
        if not np.isfinite(self.arrival):
            raise ValueError(
                f"request {self.rid}: arrival must be finite, "
                f"got {self.arrival}")
        for label, rt in (("recall_target", self.recall_target),
                          ("recall_requested", self.recall_requested)):
            if rt is not None and not (np.isfinite(rt) and 0.0 < rt <= 1.0):
                raise ValueError(
                    f"request {self.rid}: {label} must be in (0, 1], "
                    f"got {rt}")

    def slack(self, now: float) -> float:
        return self.deadline - now

    def k_capped(self, k: int) -> "Request":
        if k >= self.k:
            return self
        return replace(self, k=k,
                       k_requested=self.k_requested or self.k)

    def n_probe_capped(self, n_probe: int) -> "Request":
        """Degrade the routing width (capacity-ladder brownout rung);
        ``n_probe_requested`` records the original so the outcome is
        flagged ``degraded``, never silently narrower."""
        if n_probe >= self.n_probe:
            return self
        return replace(self, n_probe=n_probe,
                       n_probe_requested=self.n_probe_requested
                       or self.n_probe)

    def recall_capped(self, target: float) -> "Request":
        """Lower the recall target (the tuned-frontier brownout rung);
        ``recall_requested`` records the original.  A request with no
        stated target adopts the rung's target un-flagged — it never
        promised more."""
        if self.recall_target is None:
            return replace(self, recall_target=target)
        if target >= self.recall_target:
            return self
        return replace(self, recall_target=target,
                       recall_requested=self.recall_requested
                       or self.recall_target)

    @property
    def degraded(self) -> bool:
        return self.k_requested is not None or \
            self.n_probe_requested is not None or \
            self.recall_requested is not None


class RequestQueue:
    """Arrival-ordered FIFO of :class:`Request`."""

    def __init__(self, requests: Iterable[Request] = ()):  # noqa: D107
        self._q: deque[Request] = deque()
        for r in requests:
            self.push(r)

    def push(self, req: Request) -> None:
        if req.k < 1:
            raise ValueError(f"request {req.rid}: k must be >= 1, got {req.k}")
        if req.n_probe < 1:
            raise ValueError(f"request {req.rid}: n_probe must be >= 1")
        if req.deadline < req.arrival:
            raise ValueError(
                f"request {req.rid}: deadline {req.deadline} precedes "
                f"arrival {req.arrival}")
        if self._q and req.arrival < self._q[-1].arrival:
            raise ValueError(
                f"request {req.rid}: arrivals must be non-decreasing")
        self._q.append(req)

    def pop(self) -> Request:
        return self._q.popleft()

    def peek(self) -> Request | None:
        return self._q[0] if self._q else None

    def drain_arrived(self, now: float) -> list[Request]:
        """Pop every request whose arrival time is at or before ``now``."""
        out = []
        while self._q and self._q[0].arrival <= now:
            out.append(self._q.popleft())
        return out

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


# --------------------------------------------------------------------------
# Synthetic arrival traces
# --------------------------------------------------------------------------

def poisson_arrivals(rng: np.random.Generator, n: int, rate: float,
                     t0: float = 0.0) -> np.ndarray:
    """``n`` arrival times of a Poisson process with mean ``rate`` (1/s)."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    return t0 + np.cumsum(rng.exponential(1.0 / rate, n))


def bursty_arrivals(rng: np.random.Generator, n: int, rate: float,
                    burst: int = 8, spread: float = 1e-4,
                    t0: float = 0.0) -> np.ndarray:
    """``n`` arrivals at the same mean ``rate`` but in bursts of ``burst``
    (burst epochs are Poisson at rate/burst; within-burst jitter ``spread``
    keeps arrivals strictly ordered without changing the regime)."""
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    n_bursts = -(-n // burst)
    epochs = poisson_arrivals(rng, n_bursts, rate / burst, t0)
    offsets = np.arange(burst) * spread
    times = (epochs[:, None] + offsets[None, :]).reshape(-1)[:n]
    # a short Poisson epoch gap can undercut the within-burst window;
    # sorting restores the monotone-arrivals contract RequestQueue enforces
    return np.sort(times)


def make_trace(
    rng: np.random.Generator,
    queries: np.ndarray,            # (n, d)
    ks: int | Sequence[int],
    *,
    rate: float,
    deadline: float,                # relative to each arrival, seconds
    n_probe: int,
    pattern: str = "poisson",
    burst: int = 8,
    t0: float = 0.0,
    recall_target: float | None = None,
) -> list[Request]:
    """Seeded synthetic request trace: one request per query row, arrival
    times from ``pattern``, per-request ``k`` sampled uniformly from ``ks``
    (heterogeneous-k traffic when a sequence is given); ``recall_target``
    stamps every request with the caller's recall requirement (the knob
    the DegradeLadder trades away under overload)."""
    n = len(queries)
    if pattern == "poisson":
        times = poisson_arrivals(rng, n, rate, t0)
    elif pattern == "bursty":
        times = bursty_arrivals(rng, n, rate, burst=burst, t0=t0)
    else:
        raise ValueError(f"unknown arrival pattern {pattern!r}")
    ks_arr = (np.full(n, ks, np.int64) if np.isscalar(ks)
              else np.asarray(rng.choice(np.asarray(ks, np.int64), n)))
    return [
        Request(rid=i, q=np.asarray(queries[i]), k=int(ks_arr[i]),
                n_probe=n_probe, arrival=float(times[i]),
                deadline=float(times[i]) + deadline,
                recall_target=recall_target)
        for i in range(n)
    ]


def zipf_query_ids(rng: np.random.Generator, n: int, pool: int,
                   alpha: float = 1.1) -> np.ndarray:
    """``n`` draws from a Zipf(``alpha``) distribution over a pool of
    ``pool`` distinct queries (rank-frequency, rank 0 hottest).

    Real query streams are head-heavy — the ANN-workload analyses the
    result-cache ISSUE cites report Zipf-like repeat rates — and an
    exact-key result cache only pays off under exactly this skew.  The
    draw is explicit inverse-CDF over the truncated support (not
    ``rng.zipf``, whose support is unbounded and whose tail would need
    rejection), so identical (seed, n, pool, alpha) ⇒ identical stream."""
    if pool < 1:
        raise ValueError(f"pool must be >= 1, got {pool}")
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    weights = 1.0 / np.power(np.arange(1, pool + 1, dtype=np.float64), alpha)
    cdf = np.cumsum(weights / weights.sum())
    return np.searchsorted(cdf, rng.random(n), side="right").astype(np.int64)


def make_zipf_trace(
    rng: np.random.Generator,
    pool_queries: np.ndarray,       # (pool, d) distinct query vectors
    n: int,
    ks: int | Sequence[int],
    *,
    rate: float,
    deadline: float,
    n_probe: int,
    alpha: float = 1.1,
    t0: float = 0.0,
) -> list[Request]:
    """Seeded head-heavy trace: ``n`` Poisson arrivals whose query vectors
    repeat from ``pool_queries`` with Zipf(``alpha``) rank-frequency.  ``k``
    is sampled per POOL ENTRY (not per request), so a repeated query repeats
    with the same retrieval parameters — the exact-key regime a result
    cache can serve."""
    pool = len(pool_queries)
    picks = zipf_query_ids(rng, n, pool, alpha)
    times = poisson_arrivals(rng, n, rate, t0)
    ks_pool = (np.full(pool, ks, np.int64) if np.isscalar(ks)
               else np.asarray(rng.choice(np.asarray(ks, np.int64), pool)))
    return [
        Request(rid=i, q=np.asarray(pool_queries[picks[i]]),
                k=int(ks_pool[picks[i]]), n_probe=n_probe,
                arrival=float(times[i]),
                deadline=float(times[i]) + deadline)
        for i in range(n)
    ]
