"""Per-(method, shape-bucket) engine and predictor-state ownership.

The cross-batch tau predictor (``core.rerank.PredictorState``, PR 3) is an
EMA over bucket histograms — but histograms are only comparable when they
come from the same search configuration: the per-query codebooks depend on
``n_probe`` and the prediction target (``pred_count``) depends on ``k``.
Under micro-batching the batch composition varies call to call, so a single
global predictor would mix histograms across shape buckets and drift.  This
module therefore keys BOTH the engines and the predictor states per
``ShapeBucket`` (a ``ServingState`` wraps exactly one index, so the method
dimension of the ISSUE's "(method, shape-bucket)" ownership is realized by
the instance itself): each compile shape self-tunes on its own request
stream, and a bucket's prediction quality is independent of which other
buckets the traffic hits.

``ServingState`` is the only stateful object the server loop owns; engines
stay immutable (`index.engine.SearchEngine`) and predictor states thread
functionally through each call exactly as in ``launch/serve.py --tau-pred``,
just one state per bucket instead of one per process.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rerank
from repro.index import engine as engine_mod
from repro.serving.batcher import Batch, ShapeBucket


class ServingState:
    """Engines + predictor states for every shape bucket the traffic hits.

    Engines are built lazily on first use of a bucket (one
    ``SearchEngine.build`` per (k ceiling, n_probe) — the flat-layout packing
    is shared work the engine redoes per build, so prefer ``warmup`` with
    the full bucket set at server start) and cached for the process
    lifetime.  ``mesh`` switches every bucket engine onto the sharded
    deployment; ``vectors`` is required for the plain-IVF method exactly as
    in ``SearchEngine.build``.
    """

    def __init__(self, index: Any, *, use_bbc: bool = True,
                 tau_pred: bool = False, vectors=None, mesh=None,
                 backend: str | None = None, m: int = 128,
                 shard_budget: int | None = None,
                 pred_count: int | None = None, tuned=None):
        self.index = index
        self.use_bbc = use_bbc
        self.tau_pred = bool(tau_pred)
        self.vectors = vectors
        self.mesh = mesh
        self.backend = backend
        self.m = m
        self.shard_budget = shard_budget
        self.pred_count = pred_count
        # tuned operating points (a tuning.points.PointStore) every
        # per-bucket engine build resolves its unset knobs from;
        # operating_points() reports the resulting per-bucket attribution
        self.tuned = tuned
        self.kind = engine_mod.resolve_kind(index, vectors)
        if self.tau_pred and not use_bbc:
            raise ValueError("tau_pred serving requires use_bbc=True")
        # streaming-ingest state: the generation counter keys engine swaps
        # (every bucket engine carries it), ``live`` is an optional
        # corpus-row tombstone mask applied to every built engine, and
        # ``drift_report`` records the last swap's per-bucket predictor
        # carry/reset decisions
        self.generation = 0
        self.live = None
        self.drift_report: dict[tuple[int, int], dict] = {}
        # engines depend only on (k, n_probe) — batch width is a call-shape
        # jit specializes on, not a build parameter — so two ShapeBuckets
        # differing only in batch share one engine (one layout packing, one
        # set of placed shard streams)
        self._engines: dict[tuple[int, int], engine_mod.SearchEngine] = {}
        self._pred: dict[ShapeBucket, rerank.PredictorState] = {}

    # -- engines ------------------------------------------------------------

    def engine(self, bucket: ShapeBucket) -> engine_mod.SearchEngine:
        key = (bucket.k, bucket.n_probe)
        eng = self._engines.get(key)
        if eng is None:
            eng = engine_mod.SearchEngine.build(
                self.index, k=bucket.k, n_probe=bucket.n_probe,
                use_bbc=self.use_bbc, m=self.m, backend=self.backend,
                vectors=self.vectors, mesh=self.mesh,
                shard_budget=self.shard_budget, pred_count=self.pred_count,
                tuned=self.tuned, generation=self.generation)
            if self.live is not None:
                eng = eng.with_live(self.live)
            self._engines[key] = eng
        return eng

    def operating_points(self) -> dict[str, str]:
        """Per-bucket knob provenance for serving summaries: which tuned
        operating point (or the hand-tuned fallback) each built engine's
        knobs came from, keyed ``"k<k>/np<n_probe>"``."""
        from repro.tuning.points import HAND_TUNED
        return {f"k{k}/np{np_}": eng.tuned_from or HAND_TUNED
                for (k, np_), eng in sorted(self._engines.items())}

    def warmup(self, buckets) -> "ServingState":
        """AOT-precompile every bucket's serving shapes: engine builds plus
        jit compiles for the padded (B, k) batch (with ``tau_pred``, its
        predictive variant too).  Partial batches are padded to B, so the
        batch shape is the ONLY one steady-state serving hits; the B=1
        shape the parity checks use compiles lazily on first use."""
        for bucket in sorted(set(buckets)):
            self.engine(bucket).warmup(batch_sizes=(bucket.batch,),
                                       predictive=self.tau_pred)
        return self

    # -- streaming-ingest swap ----------------------------------------------

    def swap(self, index: Any, *, vectors=None, live=None, probe_qs=None,
             drift_threshold: float = 0.25) -> dict[tuple[int, int], dict]:
        """Generation-aware engine swap (copy-on-swap): re-point this state
        at a rebuilt ``index`` without touching any fork serving the old
        generation.

        The engine cache is REPLACED with a fresh dict, never cleared in
        place — forks share the cache object by reference
        (``fork(clone_engines=False)``), so old forks keep resolving (and
        lazily completing) the OLD generation's engines while forks taken
        after the swap see only the new one.  That object-identity contract
        is what lets ``ReplicaPool.rolling_swap`` roll replicas one at a
        time with both generations live.

        ``live`` is an optional corpus-row tombstone mask for the new
        generation (deletes that landed during the merge); ``vectors``
        replaces the corpus for the plain-IVF method.

        Predictor warmth: with ``tau_pred`` on and ``probe_qs`` given, each
        warm bucket's EMA is tested against one probe batch through the NEW
        engine (``ingest.drift``) — carried when the bucket-histogram
        distribution shifted by at most ``drift_threshold`` (total
        variation), cold-reset otherwise.  Returns (and stores as
        ``drift_report``) ``{(k, n_probe): {"tv": .., "carried": ..}}``.
        """
        self.index = index
        if vectors is not None:
            self.vectors = vectors
        self.live = live
        self.kind = engine_mod.resolve_kind(self.index, self.vectors)
        self.generation += 1
        old_pred = self._pred
        self._engines = {}                      # copy-on-swap: NEW dict
        self._pred = {}
        report: dict[tuple[int, int], dict] = {}
        if self.tau_pred and probe_qs is not None and old_pred:
            from repro.ingest import drift as drift_mod
            qs = jnp.asarray(probe_qs)
            for bucket, state in old_pred.items():
                fresh = drift_mod.probe_histogram(self.engine(bucket), qs)
                kept, tv, carried = drift_mod.carry_state(
                    state, fresh, drift_threshold)
                self._pred[bucket] = kept
                report[(bucket.k, bucket.n_probe)] = {
                    "tv": tv, "carried": carried}
        self.drift_report = report
        return report

    # -- replica hooks ------------------------------------------------------

    @property
    def centroids(self) -> "np.ndarray":
        """Host copy of the index's coarse centroids — the routing geometry
        the affinity router scores queries against (PQ / RaBitQ indexes
        carry them on ``.ivf``; a bare IVF index carries them directly)."""
        ivf = self.index if hasattr(self.index, "centroids") \
            else self.index.ivf
        return np.asarray(ivf.centroids)

    def fork(self, clone_engines: bool = False) -> "ServingState":
        """Replica-build hook: a new ``ServingState`` sharing this one's
        (immutable) built engines but owning FRESH per-bucket predictor
        states — each replica self-tunes on the traffic slice the affinity
        router sends it.

        With ``clone_engines=False`` (pool construction) the lazy
        engine-build cache is the SAME dict, so a bucket's one-time layout
        packing is shared across the whole pool.  With ``clone_engines=True``
        (crash respawn) the fork gets its own cache seeded with
        ``SearchEngine.replica_clone()`` of every engine built so far —
        the respawned process re-reads shared build artifacts instead of
        re-packing the corpus, but later builds stay private to it."""
        twin = ServingState.__new__(ServingState)
        twin.__dict__.update(self.__dict__)
        if clone_engines:
            twin._engines = {key: eng.replica_clone()
                             for key, eng in self._engines.items()}
        twin._pred = {}
        return twin

    # -- predictor states ---------------------------------------------------

    def pred_state(self, bucket: ShapeBucket) -> rerank.PredictorState:
        state = self._pred.get(bucket)
        if state is None:
            state = self.engine(bucket).predictor_init()
            self._pred[bucket] = state
        return state

    def pred_states(self) -> dict[ShapeBucket, rerank.PredictorState]:
        return dict(self._pred)

    # -- serving ------------------------------------------------------------

    def run(self, batch: Batch):
        """One engine call for an assembled batch; threads (and retains)
        the bucket's predictor state when ``tau_pred`` is on."""
        eng = self.engine(batch.bucket)
        qs = jnp.asarray(batch.queries)
        if self.tau_pred:
            res, new_state = eng.search_batch(
                qs, pred_state=self.pred_state(batch.bucket))
            self._pred[batch.bucket] = jax.block_until_ready(new_state)
            return res
        return eng.search_batch(qs)
