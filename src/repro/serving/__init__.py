"""Async micro-batching serving subsystem (queue -> admission -> batcher ->
engine); see ``server.Server`` for the composition root."""
from repro.serving.admission import (ACCEPT, DEGRADE, SHED, # noqa: F401
                                     AdmissionController, Decision,
                                     DegradeLadder, ServiceEMA)
from repro.serving.batcher import (Batch, MicroBatcher,      # noqa: F401
                                   ShapeBucket, assemble, bucket_of,
                                   k_ceilings)
from repro.serving.clock import (Clock, ManualClock,        # noqa: F401
                                 SystemClock)
from repro.serving.faults import (Fault, FaultSchedule,      # noqa: F401
                                  WireDecision, WireSchedule,
                                  corrupt_payload, payload_checksum)
from repro.serving.health import HealthView                  # noqa: F401
from repro.serving.queue import (Request, RequestQueue,      # noqa: F401
                                 bursty_arrivals, make_trace,
                                 make_zipf_trace, poisson_arrivals,
                                 zipf_query_ids)
from repro.serving.replica import (Replica, ReplicaPool,     # noqa: F401
                                   ReplicaResponse, WorkingSet)
from repro.serving.router import (HedgePolicy, ReplicaServer,  # noqa: F401
                                  RetryPolicy, RouteDecision, Router,
                                  outcome_digest)
from repro.serving.server import (Outcome, Server,             # noqa: F401
                                  parity_vs_direct, summarize, trim_topk)
from repro.serving.state import ServingState                 # noqa: F401
