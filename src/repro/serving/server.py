"""Composition root: the async serving loop (trace in, outcomes out).

``Server`` wires queue -> admission -> micro-batcher -> engine into one
discrete-event loop.  Time is explicit: arrivals come from the (sorted)
request trace, service time is either measured around the real engine call
(production / benchmarks) or injected via ``service_time_fn`` (deterministic
tests), and the loop advances the clock to the next arrival or the next
slack-expiry fire when nothing is runnable.  A single executor is modeled:
batches serve one at a time and the clock advances by each batch's service
time, so queueing delay, deadline misses, and shed decisions all emerge from
the same timeline the latency percentiles are computed on.

Correctness contract (the acceptance bar in ISSUE/bench_serve): a completed
request's ids are EXACTLY the ids a direct engine call at its bucket — a
singleton batch through ``SearchEngine.search_batch``, the entry point
serving drives — would return, trimmed to its (possibly k-capped) ``k``:
padding, batch composition, and scheduling never change results.  (The
dedicated single-query RaBitQ searcher phases its evaluations differently
from the batched band evaluation and can legitimately differ near the k-th
boundary, which is why the contract is stated against the batched entry
point.)  Shed requests return nothing (``ids is None``): absent, never
incorrect.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import admission as adm
from repro.serving.batcher import Batch, MicroBatcher, ShapeBucket, \
    assemble, bucket_of
from repro.serving.queue import Request
from repro.serving.state import ServingState

OK = "ok"
DEGRADED = "degraded"
SHED = "shed"
# terminal failure: every attempt (retries included) timed out, crashed, or
# was corrupt-rejected, and no healthy replica remained to try.  Only the
# multi-replica tier (serving/router.py) emits it; the single-engine Server
# never does.  Like SHED it carries no results — absent, never incorrect —
# but it counts separately so "completed + shed + failed == offered" is
# checkable (the chaos-smoke conservation gate).
FAILED = "failed"
# refused at the front door: backpressure (bounded accept/inflight queues
# full) or a draining server — the caller got an explicit 429-style
# RETRY_AFTER and may resubmit.  Distinct from SHED (admitted, then dropped
# for deadline infeasibility): a rejected request consumed no scheduling
# budget and carries no failure signal about the backend.  Only the
# transport tier (repro.transport) emits it; with it the conservation
# identity reads completed + shed + failed + rejected == offered.
REJECTED = "rejected"


def trim_topk(dists: np.ndarray, ids: np.ndarray,
              k: int) -> tuple[np.ndarray, np.ndarray]:
    """Trim one bucket-ceiling result row to its request's ``k``.

    Rows are sorted by reported distance first: a no-op for the IVF / PQ
    paths (their rows come back ascending, so the prefix of a top-bucket.k
    selection IS the top-k), and for RaBitQ — whose rows interleave
    bound-certified members (reporting estimates) with re-ranked members
    (reporting exact distances) — it makes the prefix the method's best-k
    by reported distance.  Every consumer (the server, the parity checks in
    serve.py / bench_serve.py, the tests) trims through this one helper so
    "served result" and "direct engine call" always mean the same rows.
    """
    order = np.argsort(dists, kind="stable")[:k]
    return dists[order], ids[order]


def parity_vs_direct(state: ServingState,
                     outcomes: Sequence["Outcome"]) -> tuple[float, int]:
    """Fraction of completed outcomes whose ids exactly match a direct
    engine call at their bucket — a singleton batch through the same
    ``search_batch`` entry point serving drives, trimmed through
    ``trim_topk`` — plus the count checked.  This is THE correctness
    contract; the CI smoke (serve.py --check-parity) and the acceptance
    bench (bench_serve.py) both call it so "parity" cannot drift between
    them.  Callers must treat a zero count as a failure, not a pass: an
    all-shed run verified nothing."""
    done = [o for o in outcomes if o.ids is not None]
    bad = 0
    for o in done:
        direct = state.engine(o.bucket).search_batch(
            jnp.asarray(o.request.q)[None])
        _, want = trim_topk(np.asarray(direct.dists)[0],
                            np.asarray(direct.ids)[0], o.k_effective)
        if set(want.tolist()) != set(o.ids.tolist()):
            bad += 1
    return (1.0 - bad / max(len(done), 1)), len(done)


@dataclass(frozen=True, eq=False)
class Outcome:
    """Terminal record for one request."""

    request: Request
    status: str                     # OK | DEGRADED | SHED | FAILED | REJECTED
    bucket: ShapeBucket | None
    ids: np.ndarray | None          # (k_effective,) — None when shed/failed
    dists: np.ndarray | None
    t_done: float
    k_effective: int
    # multi-replica provenance (None / zero on the single-engine Server)
    replica: int | None = None      # replica whose response won
    retries: int = 0                # retry attempts consumed
    hedged: bool = False            # a hedged duplicate was sent

    @property
    def latency(self) -> float:
        return self.t_done - self.request.arrival

    @property
    def completed(self) -> bool:
        return self.status in (OK, DEGRADED)

    @property
    def deadline_met(self) -> bool:
        return self.completed and self.t_done <= self.request.deadline


class Server:
    """Deadline-aware micro-batching server over a ``ServingState``."""

    def __init__(self, state: ServingState, ceilings: Sequence[int],
                 batch: int, *, admission: bool = True,
                 allow_degrade: bool = True, slack_margin: float = 0.0,
                 max_wait: float | None = None,
                 service_decay: float = 0.6, service_cold: float = 0.02,
                 service_time_fn: Callable[[ShapeBucket], float]
                 | None = None, overlap: bool = True):
        self.state = state
        # double-buffer host batch assembly against device execution: while
        # batch j runs on the device, batch j+1's padded query array is
        # assembled on the host (inside _serve's dispatch->block window).
        # Outcomes are identical either way — assembly is pure and the
        # event-loop clock advances by the same measured dt — only the
        # host-side critical path shrinks.
        self.overlap = bool(overlap)
        self.service = adm.ServiceEMA(decay=service_decay, cold=service_cold)
        self.batcher = MicroBatcher(ceilings, batch,
                                    service_est=self.service.estimate,
                                    slack_margin=slack_margin,
                                    max_wait=max_wait)
        self.admission = adm.AdmissionController(
            self.service, self.batcher.ceilings, batch,
            allow_degrade=allow_degrade, slack_margin=slack_margin) \
            if admission else None
        self.service_time_fn = service_time_fn

    # -- engine execution ---------------------------------------------------

    def _serve(self, batch: Batch,
               overlap_fn: Callable[[], None] | None = None):
        t0 = time.perf_counter()
        res = self.state.run(batch)
        if overlap_fn is not None:
            # jax dispatch is async: the device is already executing this
            # batch; spend its service window on host work (next batch's
            # assembly) instead of blocking idle
            overlap_fn()
        jax.block_until_ready((res.dists, res.ids))
        dt = time.perf_counter() - t0
        if self.service_time_fn is not None:
            dt = self.service_time_fn(batch.bucket)
        return dt, res

    def warmup(self, trace: Sequence[Request]) -> "Server":
        """AOT warmup off the serving timeline: precompile every shape
        bucket the trace will hit (`ServingState.warmup` ->
        `SearchEngine.warmup`), then seed the service-time EMA with one
        measured post-compile batch per bucket so the first admission
        decisions already see realistic service estimates."""
        buckets = sorted({
            bucket_of(min(r.k, self.batcher.ceilings[-1]), r.n_probe,
                      self.batcher.ceilings, self.batcher.batch)
            for r in trace})
        self.state.warmup(buckets)
        for bucket in buckets:
            reqs = [r for r in trace
                    if bucket_of(min(r.k, self.batcher.ceilings[-1]),
                                 r.n_probe, self.batcher.ceilings,
                                 self.batcher.batch) == bucket]
            dt, _ = self._serve(assemble(bucket, reqs[:bucket.batch]))
            self.service.observe(bucket, dt)
        return self

    # -- the event loop -----------------------------------------------------

    def _admit(self, req: Request, now: float,
               outcomes: dict[int, Outcome], in_flight: float = 0.0) -> None:
        """Run one request through admission (or straight to the batcher
        when admission is off).  ``in_flight`` carries the estimated
        remaining service time of the batch occupying the executor — a
        request arriving mid-batch is decided at its ARRIVAL time with
        that estimate folded into its deadline feasibility."""
        if self.admission is None:
            self.batcher.submit(req.k_capped(self.batcher.ceilings[-1]))
            return
        dec = self.admission.decide(req, now, self.batcher.depths(),
                                    in_flight=in_flight)
        if dec.action == adm.SHED:
            outcomes[req.rid] = Outcome(
                request=req, status=SHED, bucket=None, ids=None,
                dists=None, t_done=now, k_effective=0)
        else:
            self.batcher.submit(req.k_capped(dec.k))

    def _finish(self, batch: Batch, res, t_done: float,
                outcomes: dict[int, Outcome]) -> None:
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        for j, req in enumerate(batch.requests):
            status = DEGRADED if req.k_requested is not None else OK
            d_j, i_j = trim_topk(dists[j], ids[j], req.k)
            outcomes[req.rid] = Outcome(
                request=req, status=status, bucket=batch.bucket,
                ids=i_j.copy(), dists=d_j.copy(),
                t_done=t_done, k_effective=req.k)

    def run_trace(self, trace: Sequence[Request],
                  warmup: bool = True) -> list[Outcome]:
        """Serve a whole (seeded) trace; returns outcomes in rid order."""
        trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        if warmup and trace:
            self.warmup(trace)
        outcomes: dict[int, Outcome] = {}
        t = trace[0].arrival if trace else 0.0
        i = 0
        while True:
            # ingest every arrival at or before now, through admission
            while i < len(trace) and trace[i].arrival <= t:
                req = trace[i]
                i += 1
                self._admit(req, t, outcomes)

            ready = self.batcher.pop_ready(t)
            if ready:
                # slot-based double buffer: batch j+1 is assembled while
                # batch j occupies the device (overlap on), or right after
                # it completes (overlap off); either way exactly one
                # assembled batch is in flight at a time
                slot: list[Batch | None] = [assemble(*ready[0])]
                for j in range(len(ready)):
                    batch = slot[0]
                    t0 = t
                    # what a live server knows while the batch runs: its
                    # EMA estimate, frozen before the measurement lands —
                    # plus the estimates of batches already fired behind it
                    # (popped from the queue, so invisible to depths())
                    est = self.service.estimate(batch.bucket)
                    pending = sum(self.service.estimate(b2)
                                  for b2, _ in ready[j + 1:])

                    def _prep_next():
                        slot[0] = assemble(*ready[j + 1]) \
                            if j + 1 < len(ready) else None

                    dt, res = self._serve(
                        batch, overlap_fn=_prep_next if self.overlap
                        else None)
                    if not self.overlap:
                        _prep_next()
                    t = t0 + dt
                    # requests that arrived DURING this batch's service are
                    # decided at their arrival instant, with the executor's
                    # estimated remainder folded into the wait (ROADMAP
                    # PR-4 future-work note: the backlog model previously
                    # ignored in-flight completion time — those arrivals
                    # were judged only after the batch finished)
                    while i < len(trace) and trace[i].arrival <= t:
                        req = trace[i]
                        i += 1
                        remaining = max(0.0, (t0 + est) - req.arrival)
                        self._admit(req, req.arrival, outcomes,
                                    in_flight=remaining + pending)
                    self.service.observe(batch.bucket, dt)
                    self._finish(batch, res, t, outcomes)
                continue   # service time passed: re-check arrivals first

            # idle: jump to the next arrival or the next slack-expiry fire
            nxt = []
            if i < len(trace):
                nxt.append(trace[i].arrival)
            fire_at = self.batcher.next_fire_time(t)
            if fire_at is not None:
                nxt.append(fire_at)
            if not nxt:
                break
            t = max(t, min(nxt))
        return [outcomes[r.rid] for r in sorted(trace, key=lambda r: r.rid)]


def _pctiles(sub: Sequence[Outcome]) -> dict:
    lat = np.array([o.latency for o in sub])
    return {
        "count": len(sub),
        # null, not a fabricated 0.0, when nothing completed
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3)
        if len(sub) else None,
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3)
        if len(sub) else None,
    }


def summarize(outcomes: Sequence[Outcome],
              state: ServingState | None = None) -> dict:
    """Aggregate serving metrics for reporting: QPS over the busy span,
    latency percentiles over completed requests, per-outcome counts AND
    per-outcome p50/p99 (``by_status``), shed / degrade / failure /
    deadline-met rates, retry / hedge counts, and the request-conservation
    check (completed + shed + failed + rejected == offered — zero
    unaccounted requests).  Degraded and retried traffic is surfaced explicitly instead
    of hiding inside the headline QPS number.  Passing the ``state`` that
    served the trace adds ``operating_points``: which tuned operating point
    (or "hand-tuned fallback") each engine bucket's knobs came from."""
    n = len(outcomes)
    done = [o for o in outcomes if o.completed]
    shed = [o for o in outcomes if o.status == SHED]
    failed = [o for o in outcomes if o.status == FAILED]
    rejected = [o for o in outcomes if o.status == REJECTED]
    t0 = min(o.request.arrival for o in outcomes) if outcomes else 0.0
    t1 = max(o.t_done for o in done) if done else t0
    span = max(t1 - t0, 1e-9)
    extra = {"operating_points": state.operating_points()} \
        if state is not None else {}
    return {
        **extra,
        "requests": n,
        "completed": len(done),
        "shed": len(shed),
        "failed": len(failed),
        "rejected": len(rejected),
        "degraded": sum(o.status == DEGRADED for o in outcomes),
        "retried": sum(o.retries > 0 for o in outcomes),
        "hedged": sum(o.hedged for o in outcomes),
        # zero unaccounted requests: every offered request is terminal
        "conserved": bool(len(done) + len(shed) + len(failed)
                          + len(rejected) == n),
        "qps": round(len(done) / span, 2),
        "p50_ms": _pctiles(done)["p50_ms"],
        "p99_ms": _pctiles(done)["p99_ms"],
        "by_status": {
            status: _pctiles([o for o in done if o.status == status])
            for status in (OK, DEGRADED)
        },
        "shed_rate": round(len(shed) / max(n, 1), 4),
        "failed_rate": round(len(failed) / max(n, 1), 4),
        "rejected_rate": round(len(rejected) / max(n, 1), 4),
        "degraded_rate": round(
            sum(o.status == DEGRADED for o in outcomes) / max(n, 1), 4),
        "deadline_met_rate": round(
            sum(o.deadline_met for o in outcomes) / max(n, 1), 4),
    }
