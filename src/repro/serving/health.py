"""Replica health: heartbeat liveness + service-time anomaly detection.

The router must not route to a replica that is dead or limping, but it can
only know what is OBSERVABLE from outside the service boundary:

* **heartbeats** — each replica beats every ``hb_interval`` while its
  process is making progress (completions also count as beats).  A crash
  stops the beats; a stall suppresses them for the stall window.  A replica
  whose last beat is older than ``miss_factor`` intervals is ``DOWN``.
* **service-time anomalies** — per-replica EMA of the ratio
  ``measured_service / pool_baseline`` for each completed batch, where the
  baseline is the shared per-bucket service EMA the admission controller
  and batcher already use.  A healthy replica hovers near 1.0; a replica
  under a ``slow`` fault (or a noisy neighbor) drifts to its slowdown
  factor and is marked ``SUSPECT`` when the EMA exceeds
  ``anomaly_factor`` — still alive, deprioritized for routing, eligible
  for brownout serving.

``status`` is a pure function of the recorded observations and ``now``, so
seeded fault runs replay the exact same health transitions.

Time handling: every method takes an explicit ``now`` — the discrete-event
loops own their timeline.  Wall-clock callers (the socket front-end in
``repro.transport``) instead inject a monotonic :class:`~.clock.Clock` at
construction and omit ``now``; the two never mix inside one view, so the
identical code path serves both regimes without a single direct
``time.time()`` call.
"""
from __future__ import annotations

from repro.serving.clock import Clock

HEALTHY = "healthy"
SUSPECT = "suspect"
DOWN = "down"


class HealthView:
    """What the router knows about each replica, from observations only."""

    def __init__(self, n_replicas: int, *, hb_interval: float = 0.05,
                 miss_factor: float = 3.0, anomaly_factor: float = 3.0,
                 anomaly_decay: float = 0.5, clock: Clock | None = None):
        if n_replicas < 1:
            raise ValueError(f"need >= 1 replica, got {n_replicas}")
        if miss_factor <= 1.0:
            raise ValueError("miss_factor must exceed 1 heartbeat interval")
        self.n_replicas = int(n_replicas)
        self.hb_interval = float(hb_interval)
        self.miss_factor = float(miss_factor)
        self.anomaly_factor = float(anomaly_factor)
        self.anomaly_decay = float(anomaly_decay)
        self.clock = clock
        self._last_beat = [0.0] * n_replicas
        self._ratio: list[float | None] = [None] * n_replicas

    def _now(self, now: float | None) -> float:
        if now is not None:
            return now
        if self.clock is None:
            raise ValueError(
                "HealthView needs an explicit `now` unless a clock was "
                "injected at construction")
        return self.clock.now()

    # -- observations --------------------------------------------------------

    def start(self, now: float | None = None) -> None:
        """Mark every replica as freshly alive (server start)."""
        self._last_beat = [self._now(now)] * self.n_replicas

    def beat(self, rid: int, now: float | None = None) -> None:
        self._last_beat[rid] = max(self._last_beat[rid], self._now(now))

    def observe(self, rid: int, seconds: float, baseline: float) -> None:
        """Fold one completed batch's measured service time into the
        replica's anomaly ratio (``baseline`` = the shared per-bucket EMA
        estimate at completion time)."""
        ratio = seconds / max(baseline, 1e-9)
        prev = self._ratio[rid]
        self._ratio[rid] = ratio if prev is None else \
            self.anomaly_decay * prev + (1 - self.anomaly_decay) * ratio

    def reset(self, rid: int, now: float | None = None) -> None:
        """Respawn: the replica is a fresh process — history is gone."""
        self._last_beat[rid] = self._now(now)
        self._ratio[rid] = None

    # -- the view ------------------------------------------------------------

    def beat_age(self, rid: int, now: float | None = None) -> float:
        return self._now(now) - self._last_beat[rid]

    def anomaly(self, rid: int) -> float:
        """Current service-time ratio EMA (1.0 until first observation)."""
        r = self._ratio[rid]
        return 1.0 if r is None else r

    def status(self, rid: int, now: float | None = None) -> str:
        now = self._now(now)
        if self.beat_age(rid, now) > self.miss_factor * self.hb_interval:
            return DOWN
        if self.anomaly(rid) > self.anomaly_factor:
            return SUSPECT
        return HEALTHY

    def healthy(self, now: float | None = None) -> list[int]:
        now = self._now(now)
        return [r for r in range(self.n_replicas)
                if self.status(r, now) == HEALTHY]

    def alive(self, now: float | None = None) -> list[int]:
        """Replicas not conclusively dead — the brownout candidate set."""
        now = self._now(now)
        return [r for r in range(self.n_replicas)
                if self.status(r, now) != DOWN]
