"""AdamW + global-norm clipping + cosine schedule, from scratch (no optax).

State is a pytree mirroring params (m, v) + a scalar step.  ``shard_like``
returns PartitionSpecs matching the param shardings so optimizer state is
ZeRO-sharded exactly like the weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    """AdamW optimizer state (step plus first/second moments)."""
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    """AdamW + cosine-schedule hyper-parameters."""
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (
            step_ + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
