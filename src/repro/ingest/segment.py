"""Append-only delta segments: the mutable tier's brute-force substrate.

A ``DeltaSegment`` is a fixed-capacity host-side row buffer (vectors,
external ids, live flags).  Inserts append; deletes flip ``live``; neither
touches the frozen base index.  At query time each segment is scanned
exactly (the same ``ops.l2_exact_batch`` path the IVF searcher uses —
a segment is small, so brute force beats any structure) and its top-k is
merged with the base engine's results by the ``MutableIndex``.

Device buffers are shaped by the segment CAPACITY, not its fill level, so
the jitted scan compiles once per (capacity, batch) shape and appends /
deletes never retrace — they only flip rows of the ``live`` mask, exactly
like the engine-side tombstones.

Segments align with ``ivf.ShardedLayout``: ``shard_delta`` deals rows
round-robin (``j::n_shards``, the same rule ``ivf.sharded_layout`` applies
per cluster) so a delta segment places onto the serving mesh next to the
main sharded stream and is scanned under the same ``shard_map`` collective
idiom (local top-k, survivor-only gather).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed as dist
from repro.index import search as search_mod
from repro.kernels import ops

LANE = 128


class DeltaSegment:
    """Fixed-capacity append-only row buffer with tombstone flags.

    External ids are assigned by the owning ``MutableIndex`` and must fit
    int32 (the device id dtype across the repo's kernel paths).
    ``version`` bumps on every append/delete so scan-side device caches
    know when their copy is stale.
    """

    def __init__(self, capacity: int, d: int):
        if capacity < 1:
            raise ValueError(f"segment capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.d = int(d)
        self.vectors = np.zeros((self.capacity, self.d), np.float32)
        self.ids = np.full((self.capacity,), -1, np.int64)
        self.live = np.zeros((self.capacity,), bool)
        self.size = 0          # rows ever appended (dead rows included)
        self.version = 0

    @property
    def room(self) -> int:
        """Rows that can still be appended."""
        return self.capacity - self.size

    @property
    def full(self) -> bool:
        """True when no more rows fit (dead rows still occupy their slot)."""
        return self.size >= self.capacity

    @property
    def n_live(self) -> int:
        """Live (not tombstoned) row count."""
        return int(self.live.sum())

    def append(self, vecs: np.ndarray, ids: np.ndarray) -> int:
        """Append rows (must fit: check ``room`` first).  Returns the count."""
        vecs = np.atleast_2d(np.asarray(vecs, np.float32))
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        n = len(ids)
        if n > self.room:
            raise ValueError(f"segment overflow: {n} rows into {self.room}")
        s = self.size
        self.vectors[s:s + n] = vecs
        self.ids[s:s + n] = ids
        self.live[s:s + n] = True
        self.size += n
        self.version += 1
        return n

    def delete(self, ext_id: int) -> bool:
        """Tombstone one external id; False if it is not live here."""
        hit = np.nonzero((self.ids[:self.size] == ext_id)
                         & self.live[:self.size])[0]
        if len(hit) == 0:
            return False
        self.live[hit[0]] = False
        self.version += 1
        return True


@functools.partial(jax.jit, static_argnames=("k", "backend"))
def delta_scan(vectors: jax.Array, ids: jax.Array, live: jax.Array,
               qs: jax.Array, *, k: int, backend: str | None = None):
    """Exact masked scan of one segment: (B, k') ascending distances +
    external ids (k' = min(k, capacity); -1 ids past the live rows).

    Dead and never-filled rows ride the same mask the engine's tombstones
    use — their distances are INF, so they can never enter the top-k.
    """
    d = ops.l2_exact_batch(vectors, qs, backend=backend)
    d = jnp.where(live[None, :], d, search_mod.INF)
    kk = min(k, vectors.shape[0])
    neg, pos = jax.lax.top_k(-d, kk)
    out_ids = jnp.where(jnp.isfinite(neg), ids[pos], -1)
    return -neg, out_ids


def shard_delta(seg: DeltaSegment, n_shards: int, lane: int = LANE):
    """Deal a segment's rows round-robin over ``n_shards`` (row j to shard
    ``j % n_shards`` — the ``j::n_shards`` rule ``ivf.sharded_layout``
    applies per cluster), padded to a common lane-rounded width.

    Returns host arrays ``(svecs (S, F, d) f32, sids (S, F) i32,
    slive (S, F) bool)``; padding rows are dead (id -1, live False).  The
    FULL capacity is dealt (dead rows included) so the placed arrays keep
    one static shape for the segment's whole lifetime.
    """
    cap = seg.capacity
    f = (cap + n_shards - 1) // n_shards
    f = max(((f + lane - 1) // lane) * lane, lane)
    svecs = np.zeros((n_shards, f, seg.d), np.float32)
    sids = np.full((n_shards, f), -1, np.int32)
    slive = np.zeros((n_shards, f), bool)
    for j in range(n_shards):
        rows = np.arange(j, cap, n_shards)
        svecs[j, :len(rows)] = seg.vectors[rows]
        sids[j, :len(rows)] = seg.ids[rows].astype(np.int32)
        slive[j, :len(rows)] = seg.live[rows]
    return svecs, sids, slive


def place_delta(mesh, seg: DeltaSegment):
    """Shard + device_put a segment onto the serving mesh (the delta tier's
    analogue of the engine's build-time stream placement)."""
    axes = search_mod._shard_axes(mesh)
    svecs, sids, slive = shard_delta(seg, search_mod._n_shards(mesh))
    return (jax.device_put(svecs, NamedSharding(mesh, P(axes, None, None))),
            jax.device_put(sids, NamedSharding(mesh, P(axes, None))),
            jax.device_put(slive, NamedSharding(mesh, P(axes, None))))


@functools.partial(jax.jit, static_argnames=("mesh", "k", "backend"))
def delta_scan_sharded(mesh, qs: jax.Array, svecs: jax.Array,
                       sids: jax.Array, slive: jax.Array, *, k: int,
                       backend: str | None = None):
    """Mesh-sharded exact segment scan: each shard scans only its dealt
    rows, keeps a local top-k', and the survivor-only gather assembles the
    replicated (B, S*k') pool (same collective idiom as the main sharded
    searchers — a segment's candidates never cross the interconnect in
    bulk).  Returns (dists, ids); the caller's merge re-sorts.
    """
    axes = search_mod._shard_axes(mesh)

    def body(qs, vecs, ids, live):
        vecs, ids, live = vecs[0], ids[0], live[0]
        d = ops.l2_exact_batch(vecs, qs, backend=backend)
        d = jnp.where(live[None, :], d, search_mod.INF)
        kk = min(k, vecs.shape[0])
        neg, pos = jax.lax.top_k(-d, kk)
        lids = jnp.where(jnp.isfinite(neg), ids[pos], -1)
        return dist.gather_survivors(axes, -neg, lids)

    fn = dist.shard_map(
        body, mesh,
        in_specs=(P(), P(axes, None, None), P(axes, None), P(axes, None)),
        out_specs=(P(), P()))
    return fn(qs, svecs, sids, slive)
