"""Background merge: checkpointed re-cluster/re-quantize fold.

The merge job turns accumulated churn back into a frozen base index:

1. ``begin_merge`` seals the delta segments and snapshots the live corpus
   (serving continues on the sealed state, untouched).
2. The snapshot is written through ``checkpoint.CheckpointManager`` —
   checksummed, atomically renamed — BEFORE any rebuild work, so a crash
   at any later point recovers from a verified copy of the merge input.
3. The rebuild (k-means + quantization + engine build) runs off the
   serving path.
4. ``complete_merge`` swaps the new generation in atomically (one engine
   reference assignment) and re-applies any deletes that landed mid-merge.

A crash between (2) and (4) leaves the mutable index fully serviceable
(sealed segments still scanned, old base still live); ``resume_merge``
restores the checkpoint — verifying every checksum first — and finishes
the fold.  A corrupt checkpoint raises ``CorruptCheckpointError`` before
anything is deserialized; the caller aborts the merge (sealed segments
return to the active set) and re-runs it fresh from live state.  Either
way the serving index is never left corrupted.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.ingest.mutable import MutableIndex


class MergeCrash(RuntimeError):
    """Injected merge crash (tests/bench): raised after the checkpoint is
    durable but before the swap — the window crash recovery must cover."""


class MergeJob:
    """One merge execution against a ``MutableIndex``, checkpointed through
    ``checkpoint_dir``."""

    def __init__(self, mutable: MutableIndex, checkpoint_dir: str, *,
                 keep_last: int = 2):
        self.mutable = mutable
        self.manager = CheckpointManager(checkpoint_dir, keep_last=keep_last)

    def run(self, *, crash_after_checkpoint: bool = False):
        """Seal -> checkpoint -> rebuild -> swap.  Returns the new engine.

        ``crash_after_checkpoint`` raises ``MergeCrash`` right after the
        snapshot is durable (fault injection for the recovery path); the
        sealed state is left in place for ``resume_merge``.  Any OTHER
        failure unwinds the seal (``abort_merge``) and re-raises — the
        index keeps serving exactly what it served before.
        """
        snap = self.mutable.begin_merge()
        try:
            self.manager.save(snap.step, {
                "vectors": snap.vectors,
                "row_ids": snap.ids.astype(np.int32),
            })
            if crash_after_checkpoint:
                raise MergeCrash(
                    f"injected crash merging to generation {snap.step}")
            return _finish(self.mutable, snap.vectors, snap.ids, snap.step)
        except MergeCrash:
            raise
        except Exception:
            self.mutable.abort_merge()
            raise


def resume_merge(mutable: MutableIndex, checkpoint_dir: str, *,
                 keep_last: int = 2):
    """Finish a crashed merge from its checksummed checkpoint.

    Verifies the checkpoint (``CorruptCheckpointError`` on any mismatch —
    the caller should ``abort_merge`` and re-run fresh), restores the
    snapshot, rebuilds, and swaps.  Returns the new engine.
    """
    mgr = CheckpointManager(checkpoint_dir, keep_last=keep_last)
    step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no merge checkpoint in {checkpoint_dir}")
    if step != mutable.generation + 1:
        raise RuntimeError(
            f"checkpoint step {step} does not continue generation "
            f"{mutable.generation}")
    like = _like_from_manifest(checkpoint_dir, step)
    tree, _ = mgr.restore(like, step)
    x = np.asarray(tree["vectors"], np.float32)
    ids = np.asarray(tree["row_ids"], np.int64)
    return _finish(mutable, x, ids, step)


def _finish(mutable: MutableIndex, x: np.ndarray, ids: np.ndarray,
            step: int):
    eng = mutable.build_engine(x, step)
    mutable.complete_merge(eng, x, ids, step)
    return eng


def _like_from_manifest(checkpoint_dir: str, step: int) -> dict:
    """Shape/dtype skeleton for ``CheckpointManager.restore`` built from
    the manifest itself — recovery must not depend on in-memory state that
    died with the crashed process."""
    path = os.path.join(checkpoint_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    return {key: np.zeros(tuple(meta["shape"]), np.dtype(meta["dtype"]))
            for key, meta in manifest["leaves"].items()}
