"""MutableIndex: frozen base generation + delta segments + tombstones.

The mutability model keeps every frozen invariant intact:

- The BASE is a normal built index (IVF / IVF+PQ / IVF+RaBitQ) wrapped in
  a ``SearchEngine``; it never mutates.  Base deletes are tombstone masks
  (``SearchEngine.with_live``) ANDed into the scan's lane masks.
- INSERTS land in append-only ``DeltaSegment`` buffers, scanned exactly
  per query and merged with the base results host-side (id spaces are
  disjoint — base rows carry ids assigned before the segment's, so the
  merge is a plain sort, no dedup).
- A background MERGE (``ingest.merge``) seals the current segments,
  checkpoints the live corpus, re-clusters/re-quantizes it into a new base
  generation off the serving path, and atomically swaps it in
  (``complete_merge``).  Queries keep serving the old generation + sealed
  segments until the instant of the swap; deletes arriving mid-merge are
  re-applied to the new generation at swap time, so a merge can never
  resurrect a deleted row.

External ids are monotonically assigned and NEVER reused; ``row_ids`` is
kept sorted ascending (initial ids are 0..n-1 and each merge folds
segments whose ids all exceed the previous base's), which makes base
delete lookups a binary search.  Ids must stay below 2**31 (the kernel
paths' int32 id dtype).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import engine as engine_mod
from repro.index import ivf as ivf_mod
from repro.index import search as search_mod
from repro.ingest import segment as segment_mod


@dataclass(frozen=True)
class IngestConfig:
    """Streaming-ingest knobs (see docs/tuning.md for the full entries)."""

    segment_capacity: int = 4096   # rows per delta segment
    merge_trigger: float = 0.10    # churn fraction that requests a merge
    drift_threshold: float = 0.25  # TV shift that cold-resets the predictor


@dataclass(frozen=True)
class MergeSnapshot:
    """Frozen input of an in-flight merge (what the checkpoint records)."""

    vectors: np.ndarray   # (n, d) live rows at seal time
    ids: np.ndarray       # (n,) external ids, ascending
    step: int             # target generation


class MutableIndex:
    """Segmented mutable ANN index over the frozen ``SearchEngine``.

    ``kind`` picks the base method ("ivf" | "ivfpq" | "ivfrabitq"); the
    engine-build knobs (``n_probe``/``n_cand``/``tuned``/...) are captured
    once and re-used by every generation rebuild.  ``mesh`` switches the
    base AND the delta scans to the sharded deployment.
    """

    def __init__(self, vectors, kind: str = "ivfpq", *, k: int,
                 n_probe: int | None = None, n_clusters: int | None = None,
                 n_cand: int | None = None, use_bbc: bool = True,
                 m: int = 128, backend: str | None = None, mesh=None,
                 shard_budget: int | None = None,
                 pred_count: int | None = None, fused: bool | None = None,
                 tuned=None, recall_target: float = 0.95,
                 config: IngestConfig | None = None, seed: int = 0):
        vectors = np.ascontiguousarray(np.asarray(vectors, np.float32))
        self.kind = kind
        self.k = int(k)
        self.config = config or IngestConfig()
        self.seed = int(seed)
        self.mesh = mesh
        self.backend = backend
        self.n_clusters = n_clusters or max(
            4, int(round(math.sqrt(len(vectors)))))
        self._tuned = tuned
        self._recall_target = recall_target
        self._build_kw = dict(
            n_probe=n_probe, n_cand=n_cand, use_bbc=use_bbc, m=m,
            backend=backend, mesh=mesh, shard_budget=shard_budget,
            pred_count=pred_count, fused=fused)
        self.row_vectors = vectors
        self.row_ids = np.arange(len(vectors), dtype=np.int64)
        self.row_live = np.ones(len(vectors), bool)
        self.segments: list[segment_mod.DeltaSegment] = []
        self._sealed: list[segment_mod.DeltaSegment] | None = None
        self.next_id = len(vectors)
        self.generation = 0
        self._inserted = 0
        self._deleted = 0
        self._scan_cache: dict[int, tuple[int, tuple]] = {}
        self.engine = self.build_engine(vectors, 0)

    # -- index / engine construction ---------------------------------------

    def _build_index(self, x: np.ndarray, generation: int):
        key = jax.random.key(self.seed + generation)
        xj = jnp.asarray(x)
        if self.kind == "ivf":
            return ivf_mod.build(key, xj, self.n_clusters, n_iter=6)
        if self.kind == "ivfpq":
            return search_mod.build_pq_index(key, xj, self.n_clusters,
                                             n_iter=6)
        if self.kind == "ivfrabitq":
            return search_mod.build_rabitq_index(key, xj, self.n_clusters,
                                                 n_iter=6)
        raise ValueError(f"unknown kind: {self.kind!r}")

    def build_engine(self, x: np.ndarray, generation: int):
        """Re-cluster/re-quantize ``x`` into a generation-``generation``
        engine (the merge job's off-serving-path rebuild; also the initial
        build).  Tuned-point resolution passes the CURRENT churn fraction
        as ``drift`` so a point solved on the pre-churn corpus is flagged
        (never a silent stale hit) — ``tuned_from`` carries the drifted
        provenance onto the engine."""
        index = self._build_index(x, generation)
        kw = dict(self._build_kw)
        if self.kind == "ivf":
            kw["vectors"] = jnp.asarray(x)
        tuned, tuned_from = self._tuned, None
        if tuned is not None and hasattr(tuned, "resolve"):
            from repro.tuning import points as tpoints
            point, prov = tuned.resolve(
                self.kind, self.k, target=self._recall_target,
                corpus_fp=tpoints.corpus_fingerprint(jnp.asarray(x)),
                drift=self.churn_fraction())
            tuned = point
            if point is not None:
                tuned_from = f"{point.name} ({prov})"
        eng = engine_mod.SearchEngine.build(
            index, self.k, tuned=tuned, recall_target=self._recall_target,
            generation=generation, **kw)
        if tuned_from is not None:
            eng = dataclasses.replace(eng, tuned_from=tuned_from)
        return eng

    # -- mutation ------------------------------------------------------------

    def insert(self, vecs) -> np.ndarray:
        """Append rows to the delta tier; returns their external ids.
        Visible to the very next ``search`` call (no rebuild)."""
        vecs = np.atleast_2d(np.asarray(vecs, np.float32))
        out, i = [], 0
        while i < len(vecs):
            seg = self._active_segment()
            take = min(seg.room, len(vecs) - i)
            ids = np.arange(self.next_id, self.next_id + take,
                            dtype=np.int64)
            seg.append(vecs[i:i + take], ids)
            self.next_id += take
            self._inserted += take
            out.append(ids)
            i += take
        return np.concatenate(out) if out else np.empty(0, np.int64)

    def delete(self, ext_ids) -> int:
        """Tombstone external ids (base rows via the engine's lane mask,
        segment rows via the segment's live flags).  Returns the number of
        rows actually deleted.  Deletes during an in-flight merge are
        recorded on the sealed segments / base mask too, so the merge's
        swap re-applies them to the new generation."""
        ext = np.atleast_1d(np.asarray(ext_ids, np.int64))
        count, base_changed = 0, False
        for e in ext:
            pos = int(np.searchsorted(self.row_ids, e))
            if (pos < len(self.row_ids) and self.row_ids[pos] == e
                    and self.row_live[pos]):
                self.row_live[pos] = False
                base_changed = True
                count += 1
                continue
            for seg in self._all_segments():
                if seg.delete(int(e)):
                    count += 1
                    break
        if base_changed:
            self.engine = self.engine.with_live(self.row_live)
        self._deleted += count
        return count

    # -- query ---------------------------------------------------------------

    def search(self, qs, pred_state=None):
        """Search the LIVE corpus: base engine + every segment, one merged
        top-k.  (B, d) or (d,) queries; with ``pred_state`` returns
        ``(SearchResult, new_state)`` like the engine entry points."""
        qs = jnp.asarray(qs)
        single = qs.ndim == 1
        if single:
            qs = qs[None]
        out = self.engine.search_batch(qs, pred_state=pred_state)
        res, new_state = out if pred_state is not None else (out, None)
        d = np.asarray(res.dists)
        ids_int = np.asarray(res.ids)
        safe = np.clip(ids_int, 0, len(self.row_ids) - 1)
        i = np.where(ids_int >= 0, self.row_ids[safe], -1)
        parts_d, parts_i = [d], [i]
        for seg in self._all_segments():
            if seg.n_live == 0:
                continue
            sd, si = self._scan_segment(seg, qs)
            parts_d.append(np.asarray(sd))
            parts_i.append(np.asarray(si, np.int64))
        if len(parts_d) > 1:
            d = np.concatenate(parts_d, axis=1)
            i = np.concatenate(parts_i, axis=1)
            order = np.argsort(d, axis=1, kind="stable")[:, :self.k]
            d = np.take_along_axis(d, order, axis=1)
            i = np.take_along_axis(i, order, axis=1)
        i = np.where(np.isfinite(d), i, -1)
        res = search_mod.SearchResult(d, i, np.asarray(res.n_reranked),
                                      np.asarray(res.n_second_pass))
        if single:
            res = search_mod.SearchResult(*(x[0] for x in res))
        return (res, new_state) if pred_state is not None else res

    # -- merge lifecycle -----------------------------------------------------

    def churn_fraction(self) -> float:
        """(inserts + deletes since the current generation was built) over
        the base size — the merge trigger's and the tuned-point drift
        flag's input."""
        return (self._inserted + self._deleted) / max(len(self.row_ids), 1)

    def needs_merge(self) -> bool:
        """True when accumulated churn crossed ``config.merge_trigger``."""
        return (self.churn_fraction() >= self.config.merge_trigger
                and (self._inserted + self._deleted) > 0)

    def live_corpus(self) -> tuple[np.ndarray, np.ndarray]:
        """(vectors, ids) of every live row (base + segments), ascending by
        id — the exact ground-truth corpus for recall gates."""
        parts_v = [self.row_vectors[self.row_live]]
        parts_i = [self.row_ids[self.row_live]]
        for seg in self._all_segments():
            mask = seg.live[:seg.size]
            parts_v.append(seg.vectors[:seg.size][mask])
            parts_i.append(seg.ids[:seg.size][mask])
        v = np.concatenate(parts_v, axis=0)
        i = np.concatenate(parts_i, axis=0)
        order = np.argsort(i)
        return v[order], i[order]

    def begin_merge(self) -> MergeSnapshot:
        """Seal the current segments and snapshot the live corpus (the
        merge input).  Serving continues on the sealed state; new inserts
        open fresh segments and ride through the merge as delta."""
        if self._sealed is not None:
            raise RuntimeError("a merge is already in flight")
        self._sealed = self.segments
        self.segments = []
        v, i = self.live_corpus()
        return MergeSnapshot(vectors=v, ids=i, step=self.generation + 1)

    def abort_merge(self) -> None:
        """Unwind ``begin_merge``: sealed segments return to the active
        set (prepended — their rows predate the post-seal segments)."""
        if self._sealed is None:
            return
        self.segments = self._sealed + self.segments
        self._sealed = None

    def complete_merge(self, engine, x: np.ndarray, ids: np.ndarray,
                       step: int) -> None:
        """Atomic swap: the rebuilt engine becomes the base generation.
        Deletes recorded while the merge ran (base mask or sealed-segment
        tombstones) are re-applied as the new generation's lane mask, so
        the swap can never resurrect a deleted row."""
        ids = np.asarray(ids, np.int64)
        live_now = np.concatenate(
            [self.row_ids[self.row_live]]
            + [s.ids[:s.size][s.live[:s.size]] for s in (self._sealed or [])]
        ).astype(np.int64)
        keep = np.isin(ids, live_now)
        self.row_vectors = np.asarray(x, np.float32)
        self.row_ids = ids
        self.row_live = keep
        self.engine = engine.with_live(keep) if not keep.all() else engine
        self._sealed = None
        self.generation = int(step)
        self._inserted = sum(s.size for s in self.segments)
        self._deleted = int((~keep).sum()) + sum(
            s.size - s.n_live for s in self.segments)
        self._scan_cache.clear()

    # -- internals -----------------------------------------------------------

    def _all_segments(self):
        return (self._sealed or []) + self.segments

    def _active_segment(self) -> segment_mod.DeltaSegment:
        if not self.segments or self.segments[-1].full:
            self.segments.append(segment_mod.DeltaSegment(
                self.config.segment_capacity, self.row_vectors.shape[1]))
        return self.segments[-1]

    def _scan_segment(self, seg: segment_mod.DeltaSegment, qs: jax.Array):
        ent = self._scan_cache.get(id(seg))
        if ent is None or ent[0] != seg.version:
            if self.mesh is not None:
                arrays = segment_mod.place_delta(self.mesh, seg)
            else:
                arrays = (jnp.asarray(seg.vectors),
                          jnp.asarray(seg.ids.astype(np.int32)),
                          jnp.asarray(seg.live))
            ent = (seg.version, arrays)
            self._scan_cache[id(seg)] = ent
        arrays = ent[1]
        if self.mesh is not None:
            return segment_mod.delta_scan_sharded(
                self.mesh, qs, *arrays, k=self.k, backend=self.backend)
        return segment_mod.delta_scan(*arrays, qs, k=self.k,
                                      backend=self.backend)
