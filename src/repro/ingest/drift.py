"""Predictor-warmth drift detector: histogram-distribution shift test.

The cross-batch ``PredictorState`` EMA is a distribution over bucket
indices; it stays valid across an engine swap only while the NEW engine's
bucket histograms look like the old ones.  The test is direct: run one
probe batch through the new engine from a cold state (its updated EMA is
exactly the mean probe histogram), normalize both EMAs to distributions,
and compare by total-variation distance.  Below the threshold the warm
state carries over (slow drift — the EMA keeps adapting); above it the
state cold-resets (predict_tau returns -1 until re-warmed, which the
searchers treat as "no prediction" — correctness never rides on this
either way, only the early-exact hit rate does).
"""
from __future__ import annotations

import numpy as np

from repro.core import rerank


def normalized_ema(state: rerank.PredictorState) -> np.ndarray | None:
    """Bias-corrected EMA as a probability distribution over the (m+1)
    buckets; None while the state is cold (nothing to compare)."""
    w = float(state.weight)
    if w <= 0.0:
        return None
    p = np.asarray(state.ema, np.float64) / w
    s = p.sum()
    if s <= 0.0:
        return None
    return p / s


def tv_distance(p: np.ndarray, q: np.ndarray) -> float:
    """Total-variation distance between two bucket distributions."""
    return 0.5 * float(np.abs(np.asarray(p) - np.asarray(q)).sum())


def probe_histogram(engine, probe_qs) -> rerank.PredictorState:
    """One predictive probe batch through ``engine`` from a cold state —
    the returned state's EMA is the mean probe-batch histogram (weight 1),
    i.e. the new engine's bucket distribution on held-out queries."""
    _, fresh = engine.search_batch(probe_qs, pred_state=engine.predictor_init())
    return fresh


def carry_state(old_state: rerank.PredictorState,
                fresh_state: rerank.PredictorState,
                threshold: float) -> tuple[rerank.PredictorState, float, bool]:
    """Decide whether a warm predictor survives an engine swap.

    Returns ``(state, tv, carried)``: the old state (carried) when the TV
    shift between its normalized EMA and the fresh probe histogram is at
    most ``threshold``; a cold reset otherwise.  A cold old state carries
    trivially (nothing at risk); a missing probe signal keeps the old
    state (no evidence to reset on).
    """
    p = normalized_ema(old_state)
    if p is None:
        return old_state, 0.0, True
    q = normalized_ema(fresh_state)
    if q is None:
        return old_state, 0.0, True
    tv = tv_distance(p, q)
    if tv > threshold:
        m = int(np.asarray(old_state.ema).shape[0]) - 1
        return rerank.predictor_init(m), tv, False
    return old_state, tv, True
