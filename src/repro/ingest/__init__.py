"""Streaming ingest: a segmented mutable index over the frozen engine.

The frozen machinery (layouts, quantized streams, ``SearchEngine``) never
mutates; mutability is layered on top of it:

- ``segment``: append-only ``DeltaSegment`` rows, brute-force scanned via
  the exact-L2 kernel path (single-device and mesh-sharded forms).
- ``mutable``: ``MutableIndex`` — the frozen base generation + delta
  segments + tombstones, merged into one result stream per query.
- ``merge``: the background re-cluster/re-quantize job that folds sealed
  segments into a new base generation through a checksummed checkpoint.
- ``drift``: the histogram-distribution shift test deciding whether the
  cross-batch ``PredictorState`` stays warm across an engine swap.

See ``docs/ingest.md`` for the lifecycle and semantics contracts.
"""
from repro.ingest.drift import carry_state, probe_histogram, tv_distance
from repro.ingest.merge import MergeCrash, MergeJob, resume_merge
from repro.ingest.mutable import IngestConfig, MergeSnapshot, MutableIndex
from repro.ingest.segment import DeltaSegment

__all__ = [
    "DeltaSegment",
    "IngestConfig",
    "MergeCrash",
    "MergeJob",
    "MergeSnapshot",
    "MutableIndex",
    "carry_state",
    "probe_histogram",
    "resume_merge",
    "tv_distance",
]
