"""Checkpointing: sharded-pytree save/restore with async writes and
elastic re-sharding.

Layout:  <dir>/step_<n>/manifest.json + one .npy per leaf.  The manifest
records a sha256 per leaf file plus a whole-checkpoint content checksum;
``restore`` verifies both BEFORE deserializing and raises
``CorruptCheckpointError`` on any mismatch (the serving tier's replica
respawn path loads through here after a crash fault).
Writes land in a tmp dir and are renamed atomically; a background thread
performs the serialization so the train loop is not blocked (async_save).
Restore accepts a target sharding tree — the arrays are placed with
``jax.device_put`` against the CURRENT mesh, which is what makes restarts
elastic: a checkpoint written on one mesh restores onto any other mesh whose
axis sizes divide the array dims (shrink/grow tested in tests/test_checkpoint).

On a real multi-host pod each process writes its addressable shards and the
manifest records the global layout; this single-host implementation writes
full arrays (the manifest schema already carries the spec strings).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


class CorruptCheckpointError(RuntimeError):
    """A checkpoint's on-disk bytes do not match its manifest checksums.

    Raised on restore BEFORE any array is deserialized, so a replica
    respawning after a crash fault (serving tier) either loads a verified
    state or falls back to a cold start — it never resumes from garbage."""


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    """Checksummed checkpoint save/restore with bounded retention and optional
    async writes."""
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, wait: bool = True):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if wait:
            self._write(step, host_tree)
        else:
            self.wait()  # one outstanding write at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        treedef = jax.tree.structure(host_tree)
        manifest["treedef"] = str(treedef)
        digests = []
        for i, (key, leaf) in enumerate(_flatten(host_tree)):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            digest = _file_sha256(os.path.join(tmp, fname))
            digests.append(digest)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(np.shape(leaf)),
                "dtype": str(np.asarray(leaf).dtype), "index": i,
                "sha256": digest,
            }
        # whole-checkpoint content checksum: order-stable over leaf digests,
        # so a truncated/garbled leaf OR a manifest/leaf mismatch both fail
        # verification on load
        manifest["checksum"] = hashlib.sha256(
            "".join(digests).encode()).hexdigest()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> None:
        """Check a checkpoint's content checksums without deserializing it.

        Raises :class:`CorruptCheckpointError` when any leaf file's bytes
        disagree with the manifest, or the manifest-level checksum disagrees
        with the per-leaf digests.  Pre-checksum checkpoints (no ``sha256``
        entries) pass: they carry nothing to verify against."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            raise CorruptCheckpointError(
                f"{d}: unreadable manifest ({e})") from e
        metas = sorted(manifest["leaves"].values(), key=lambda m: m["index"])
        digests = []
        for meta in metas:
            want = meta.get("sha256")
            if want is None:
                return  # legacy manifest: nothing recorded to verify
            path = os.path.join(d, meta["file"])
            if not os.path.exists(path):
                raise CorruptCheckpointError(
                    f"{d}: missing leaf file {meta['file']}")
            got = _file_sha256(path)
            if got != want:
                raise CorruptCheckpointError(
                    f"{d}: leaf {meta['file']} checksum mismatch "
                    f"(manifest {want[:12]}…, on disk {got[:12]}…)")
            digests.append(got)
        want_total = manifest.get("checksum")
        if want_total is not None:
            got_total = hashlib.sha256(
                "".join(digests).encode()).hexdigest()
            if got_total != want_total:
                raise CorruptCheckpointError(
                    f"{d}: manifest checksum mismatch")

    def restore(self, like, step: int | None = None, shardings=None):
        """Restore into the structure of ``like``; optionally re-shard with
        ``shardings`` (a matching pytree of Sharding) — the elastic path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            raise CorruptCheckpointError(
                f"{d}: unreadable manifest ({e})") from e
        self.verify(step)
        flat_like = _flatten(like)
        leaves = []
        for key, leaf_like in flat_like:
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(d, meta["file"]))
            want = tuple(np.shape(leaf_like))
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: ckpt {arr.shape} vs want {want}")
            leaves.append(arr)
        treedef = jax.tree.structure(like)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda x, like: jax.numpy.asarray(x, dtype=getattr(like, "dtype", None)),
                tree, like)
        return tree, step
