"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT frontend STUB (precomputed patch embeddings) +
InternLM2 backbone [arXiv:2404.16821]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
        n_heads=16, n_kv=8, d_ff=8192, vocab=92553, n_patches=256,
        dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="internvl2-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, n_patches=16,
        dtype=jnp.float32)
