"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752,
vocab=100352, MoE 16 experts top-4 (fine-grained) [hf:databricks/dbrx-base]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig


def full(dtype=jnp.bfloat16):
    return LMConfig(
        arch_id="dbrx-132b", family="moe", n_layers=40, d_model=6144,
        n_heads=48, n_kv=8, d_ff=10752, vocab=100352, n_experts=16, top_k=4,
        dtype=dtype, remat=True)


def smoke():
    return LMConfig(
        arch_id="dbrx-smoke", family="moe", n_layers=2, d_model=96,
        n_heads=6, n_kv=2, d_ff=160, vocab=256, n_experts=4, top_k=2,
        dtype=jnp.float32)


def full_cf1(dtype=None):
    """Hillclimb cell B, iteration 3: capacity factor 1.0 for inference
    (balanced routing drops ~nothing; -20% expert FLOPs)."""
    import dataclasses
    import jax.numpy as jnp
    cfg = full(dtype or jnp.bfloat16)
    return dataclasses.replace(cfg, arch_id="dbrx-132b-cf1",
                               capacity_factor=1.0)
